//! Pipeline-parallel DP-LoRA fine-tuning with per-device clipping
//! (Algorithm 2) — the GPT-3-analog workflow of Table 6, small scale.
//!
//!     cargo run --release --example pipeline_gpt [-- --steps 20 --mode per-device]
//!
//! Partitions the 8-layer LM over 4 simulated devices, trains only the
//! LoRA adapters under DP, and prints per-step schedule costs so the
//! per-device vs flat-sync overhead (paper section 4) is visible.

use anyhow::Result;

use gwclip::coordinator::accountant;
use gwclip::data::lm::DialogSumCorpus;
use gwclip::data::Dataset;
use gwclip::pipeline::{PipelineEngine, PipelineMode, PipelineOpts};
use gwclip::runtime::Runtime;
use gwclip::util::cli::Args;
use gwclip::util::rng::Xoshiro;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let steps = args.get_usize("steps", 20)?;
    let mode = match args.get("mode", "per-device").as_str() {
        "per-device" => PipelineMode::PerDevice,
        "flat-sync" => PipelineMode::FlatSync,
        "non-private" => PipelineMode::NonPrivate,
        m => anyhow::bail!("unknown mode {m}"),
    };

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let config = "lm_mid_pipe_lora";
    let cfg = rt.manifest.config(config)?.clone();
    let data = DialogSumCorpus::new(1024, cfg.hyper.seq, cfg.hyper.vocab, 2);

    let n_micro = 4;
    let minibatch = cfg.batch * n_micro;
    let epsilon = args.get_f64("epsilon", 1.0)?;
    let sigma = accountant::noise_multiplier(
        minibatch as f64 / data.len() as f64,
        steps as u64,
        epsilon,
        1e-5,
    );
    println!(
        "pipeline: {} stages x {} microbatches of {} | eps={epsilon} -> sigma {:.3} | mode {}",
        cfg.stages.as_ref().unwrap().stages.len(),
        n_micro,
        cfg.batch,
        sigma,
        mode.name()
    );

    let opts = PipelineOpts { mode, n_micro, clip: 1e-2, sigma, lr: 5e-3, ..Default::default() };
    let mut eng = PipelineEngine::new(&rt, config, opts)?;
    let mut rng = Xoshiro::seeded(0);
    for s in 0..steps {
        let idx: Vec<usize> = (0..minibatch).map(|_| rng.below(data.len())).collect();
        let st = eng.step(&data, &idx)?;
        println!(
            "step {s:>3}: loss {:.4} | simulated 4-device step {:.3}s | syncs {} | calls {}",
            st.loss, st.sim_secs, st.syncs, st.calls
        );
    }
    let nll = eng.evaluate(&data)?;
    println!("\ntrain-set NLL after {steps} steps: {nll:.4}");
    println!("per-device thresholds: {:?}", eng.thresholds);
    Ok(())
}
