//! Pipeline-parallel DP-LoRA fine-tuning with per-device clipping
//! (Algorithm 2) — the GPT-3-analog workflow of Table 6, small scale.
//!
//!     cargo run --release --example pipeline_gpt [-- --steps 20 --mode per-device]
//!
//! Partitions the 8-layer LM over 4 simulated devices, trains only the
//! LoRA adapters under DP, and prints per-step schedule costs so the
//! per-device vs flat-sync overhead (paper section 4) is visible. Sigma is
//! accountant-derived from (--epsilon, --delta) — the same session path as
//! `gwclip run --spec`.

use anyhow::Result;

use gwclip::data::lm::DialogSumCorpus;
use gwclip::data::Dataset;
use gwclip::pipeline::PipelineMode;
use gwclip::runtime::Runtime;
use gwclip::session::{ClipPolicy, OptimSpec, PrivacySpec, Session};
use gwclip::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let steps = args.get_usize("steps", 20)?;
    let mode: PipelineMode = args.get("mode", "per-device").parse()?;

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let config = "lm_mid_pipe_lora";
    let cfg = rt.manifest.config(config)?.clone();
    let data = DialogSumCorpus::new(1024, cfg.hyper.seq, cfg.hyper.vocab, 2);

    let epsilon = args.get_f64("epsilon", 1.0)?;
    let mut sess = Session::builder(&rt, config)
        .privacy(PrivacySpec { epsilon, delta: 1e-5, quantile_r: 0.0 })
        .clip(ClipPolicy { clip_init: 1e-2, ..ClipPolicy::from_pipeline_mode(mode, false) })
        .optim(OptimSpec::adam(5e-3))
        .n_micro(4)
        .steps(steps)
        .build(data.len())?;

    let plan = sess.plan();
    println!(
        "pipeline: {} stages x 4 microbatches of {} | eps={epsilon} -> sigma {:.3} | mode {}",
        cfg.stages.as_ref().unwrap().stages.len(),
        cfg.batch,
        plan.map(|p| p.sigma_grad).unwrap_or(0.0),
        mode.name()
    );

    for s in 0..steps {
        let st = sess.step(&data)?;
        println!(
            "step {s:>3}: loss {:.4} | simulated 4-device step {:.3}s | syncs {} | calls {}",
            st.loss, st.sim_secs, st.syncs, st.calls
        );
    }
    let (nll, _) = sess.evaluate(&data)?;
    println!("\ntrain-set NLL after {steps} steps: {nll:.4}");
    println!("per-device thresholds: {:?}", sess.thresholds());
    Ok(())
}
