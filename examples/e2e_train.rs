//! End-to-end validation driver (EXPERIMENTS.md §E2E): DP-train the
//! ~14M-parameter `lm_e2e` transformer on a synthetic corpus for a few
//! hundred steps with adaptive per-layer clipping, logging the loss curve
//! and proving all three layers compose at realistic scale.
//!
//!     cargo run --release --example e2e_train [-- --steps 300 --epsilon 8]
//!
//! Writes results/e2e_loss.csv and prints a summary block that
//! EXPERIMENTS.md quotes.

use std::time::Instant;

use anyhow::Result;

use gwclip::data::lm::MarkovCorpus;
use gwclip::metrics::LossMeter;
use gwclip::runtime::Runtime;
use gwclip::session::{ClipMode, ClipPolicy, GroupBy, OptimSpec, PrivacySpec, Session};
use gwclip::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let steps = args.get_u64("steps", 300)?;
    let epsilon = args.get_f64("epsilon", 8.0)?;

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let config = "lm_e2e";
    let cfg = rt.manifest.config(config)?.clone();
    let n_params: u64 = cfg.params.iter().map(|p| p.size).sum();
    println!(
        "model: {} params ({} tensors, {} clip groups), vocab {}, seq {}",
        n_params,
        cfg.params.len(),
        cfg.groups.len(),
        cfg.hyper.vocab,
        cfg.hyper.seq
    );

    let train = MarkovCorpus::new(4096, cfg.hyper.seq, cfg.hyper.vocab, 6, 0);
    let eval = MarkovCorpus::new(512, cfg.hyper.seq, cfg.hyper.vocab, 6, 900);

    // epochs chosen so total_steps == requested steps
    let expected_batch = cfg.batch * 4 / 5;
    let epochs = steps as f64 * expected_batch as f64 / train.seqs.len() as f64;
    let mut sess = Session::builder(&rt, config)
        .privacy(PrivacySpec { epsilon, delta: 1e-5, quantile_r: 0.01 })
        .clip(ClipPolicy {
            clip_init: 0.1,
            target_q: 0.5,
            ..ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive)
        })
        .optim(OptimSpec::adam(1e-3))
        .epochs(epochs)
        .expected_batch(expected_batch)
        .build(train.seqs.len())?;
    let plan = sess.plan().unwrap();
    println!(
        "privacy: eps={epsilon} delta=1e-5, q={:.4}, T={} -> sigma_grad={:.3}",
        plan.q, sess.total_steps, plan.sigma_grad
    );

    let mut meter = LossMeter::default();
    let t0 = Instant::now();
    let (e0, _) = sess.evaluate(&eval)?;
    println!("eval NLL before training: {e0:.4} (uniform = ln V = {:.4})", (cfg.hyper.vocab as f64).ln());
    for s in 0..sess.total_steps {
        let st = sess.step(&train)?;
        meter.push(s, st.loss);
        if s % 25 == 0 || s == sess.total_steps - 1 {
            println!(
                "step {s:>4}/{} loss {:.4} (ema {:.4}) elapsed {:.0}s",
                sess.total_steps,
                st.loss,
                meter.ema(),
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (e1, _) = sess.evaluate(&eval)?;

    std::fs::create_dir_all("results")?;
    meter.write_csv("results/e2e_loss.csv")?;

    println!("\n===== E2E SUMMARY =====");
    println!("params:            {n_params}");
    println!("steps:             {}", sess.total_steps);
    println!("wall time:         {wall:.1}s ({:.2} s/step)", wall / sess.total_steps as f64);
    println!("train loss:        {:.4} -> {:.4}", meter.history[0].1, meter.ema());
    println!("eval NLL:          {e0:.4} -> {e1:.4}");
    println!("privacy:           (eps={epsilon}, delta=1e-5), sigma_grad={:.3}", plan.sigma_grad);
    println!("loss curve:        results/e2e_loss.csv");
    Ok(())
}
