//! Quickstart: DP-train a small classifier with adaptive per-layer
//! clipping in ~30 seconds on CPU.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the whole stack: loads an AOT-compiled HLO artifact (whose
//! backward pass embeds the fused ghost-clipping kernels), plans the
//! privacy budget with the RDP accountant, trains with Algorithm 1, and
//! reports the final privacy guarantee and accuracy.

use anyhow::Result;

use gwclip::coordinator::{Method, TrainOpts, Trainer};
use gwclip::data::classif::MixtureImages;
use gwclip::data::Dataset;
use gwclip::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new(gwclip::artifact_dir())?;

    // synthetic 10-class task (CIFAR-10 stand-in; see DESIGN.md §3)
    let train = MixtureImages::new(4096, 64, 10, 0);
    let eval = MixtureImages::new(1024, 64, 10, 900);

    let opts = TrainOpts {
        method: Method::PerLayerAdaptive,
        epsilon: 3.0,
        delta: 1e-5,
        epochs: 3.0,
        lr: 0.25,
        target_q: 0.6,
        quantile_r: 0.01,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&rt, "resmlp", train.len(), opts)?;

    let plan = trainer.plan.expect("private method has a plan");
    println!(
        "privacy plan: (eps={}, delta={}) over {} steps -> sigma={:.3} \
         (grad {:.3} after Prop 3.1 split, quantile sigma_b={:.1})",
        plan.epsilon, plan.delta, trainer.total_steps,
        plan.sigma_base, plan.sigma_grad, plan.sigma_quantile
    );

    trainer.run(&train, 10)?;

    let (loss, acc) = trainer.evaluate(&eval)?;
    println!("\nfinal adaptive thresholds (first 5 groups):");
    for (g, c) in trainer.groups().iter().zip(&trainer.quantiles.thresholds).take(5) {
        println!("  {g:<12} C = {c:.4}");
    }
    println!("\neval: loss {loss:.4}, accuracy {:.1}% at eps=3", 100.0 * acc);
    Ok(())
}
