//! Quickstart: DP-train a small classifier with adaptive per-layer
//! clipping in ~30 seconds on CPU.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the whole stack through the session API: loads an AOT-compiled
//! HLO artifact (whose backward pass embeds the fused ghost-clipping
//! kernels), plans the privacy budget with the RDP accountant, trains with
//! Algorithm 1, and reports the final privacy guarantee and accuracy.

use anyhow::Result;

use gwclip::runtime::Runtime;
use gwclip::session::{ClipMode, ClipPolicy, DataSpec, GroupBy, OptimSpec, PrivacySpec, Session};

fn main() -> Result<()> {
    let rt = Runtime::new(gwclip::artifact_dir())?;

    // one declarative spec: privacy target, clip policy, optimizer, data
    // (synthetic 10-class task — CIFAR-10 stand-in; see DESIGN.md §3)
    let (mut sess, train, eval) = Session::builder(&rt, "resmlp")
        .privacy(PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.01 })
        .clip(ClipPolicy {
            target_q: 0.6,
            ..ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive)
        })
        .optim(OptimSpec::sgd(0.25))
        .data(DataSpec { task: "mixture".into(), n_data: 4096, seed: 0 })
        .epochs(3.0)
        .build_with_data()?;

    let plan = sess.plan().expect("private run has a plan");
    println!(
        "privacy plan: (eps={}, delta={}) over {} steps -> sigma={:.3} \
         (grad {:.3} after Prop 3.1 split, quantile sigma_b={:.1})",
        plan.epsilon, plan.delta, sess.total_steps,
        plan.sigma_base, plan.sigma_grad, plan.sigma_quantile
    );

    sess.run(&*train, 10)?;

    let (loss, acc) = sess.evaluate(&*eval)?;
    println!("\nfinal adaptive thresholds (first 5 groups):");
    for (g, c) in sess.group_labels().iter().zip(sess.thresholds()).take(5) {
        println!("  {g:<12} C = {c:.4}");
    }
    println!("\neval: loss {loss:.4}, accuracy {:.1}% at eps=3", 100.0 * acc);
    Ok(())
}
