//! DP fine-tune the GPT-2-analog LM on the E2E-analog table-to-text task
//! with adaptive per-layer clipping, then greedy-decode a few samples and
//! report BLEU-4 / ROUGE-L (a miniature of Table 5).
//!
//!     cargo run --release --example lm_finetune [-- --epsilon 8 --epochs 2]

use anyhow::Result;

use gwclip::coordinator::optimizer::OptimizerKind;
use gwclip::coordinator::{Method, TrainOpts, Trainer};
use gwclip::data::lm::TableToTextCorpus;
use gwclip::data::Dataset;
use gwclip::exp::genexp::greedy_decode;
use gwclip::metrics::bleu::{corpus_bleu, rouge_l};
use gwclip::runtime::Runtime;
use gwclip::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let epsilon = args.get_f64("epsilon", 8.0)?;
    let epochs = args.get_f64("epochs", 2.0)?;

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let cfg = rt.manifest.config("lm_small")?.clone();
    let train = TableToTextCorpus::new(1024, cfg.hyper.seq, cfg.hyper.vocab, 3, 0);
    let eval = TableToTextCorpus::new(96, cfg.hyper.seq, cfg.hyper.vocab, 3, 999);

    let opts = TrainOpts {
        method: Method::PerLayerAdaptive,
        epsilon,
        epochs,
        lr: 2e-3,
        optimizer: OptimizerKind::Adam { beta1: 0.9, beta2: 0.98, eps: 1e-6 },
        clip_init: 0.1,
        target_q: 0.5,
        quantile_r: 0.01,
        ..Default::default()
    };
    let mut tr = Trainer::new(&rt, "lm_small", train.len(), opts)?;
    tr.run(&train, 10)?;
    let (nll, _) = tr.evaluate(&eval)?;

    // decode a few eval prefixes
    let exec = rt.load("lm_small", "logits")?;
    let n = 32;
    let prefixes: Vec<Vec<i32>> = (0..n).map(|i| eval.prefix(i).to_vec()).collect();
    let hyps = greedy_decode(&exec, &tr.params, &prefixes, cfg.batch, cfg.hyper.seq)?;
    let refs: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let r = eval.reference_suffix(i);
            r[..r.len().min(cfg.hyper.seq - eval.prefix_len)].to_vec()
        })
        .collect();

    println!("\nsample generation (token ids), first example:");
    println!("  prefix: {:?}", prefixes[0]);
    println!("  hyp:    {:?}", &hyps[0]);
    println!("  ref:    {:?}", &refs[0]);
    println!(
        "\neval NLL {nll:.3} | BLEU-4 {:.1} | ROUGE-L {:.1} at eps={epsilon}",
        100.0 * corpus_bleu(&hyps, &refs, 4),
        100.0 * rouge_l(&hyps, &refs),
    );
    Ok(())
}
