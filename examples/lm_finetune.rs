//! DP fine-tune the GPT-2-analog LM on the E2E-analog table-to-text task
//! with adaptive per-layer clipping, then greedy-decode a few samples and
//! report BLEU-4 / ROUGE-L (a miniature of Table 5).
//!
//!     cargo run --release --example lm_finetune [-- --epsilon 8 --epochs 2]

use anyhow::Result;

use gwclip::data::lm::TableToTextCorpus;
use gwclip::data::Dataset;
use gwclip::exp::genexp::greedy_decode;
use gwclip::metrics::bleu::{corpus_bleu, rouge_l};
use gwclip::runtime::Runtime;
use gwclip::session::{ClipMode, ClipPolicy, GroupBy, OptimSpec, PrivacySpec, Session};
use gwclip::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let epsilon = args.get_f64("epsilon", 8.0)?;
    let epochs = args.get_f64("epochs", 2.0)?;

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let cfg = rt.manifest.config("lm_small")?.clone();
    let train = TableToTextCorpus::new(1024, cfg.hyper.seq, cfg.hyper.vocab, 3, 0);
    let eval = TableToTextCorpus::new(96, cfg.hyper.seq, cfg.hyper.vocab, 3, 999);

    let mut sess = Session::builder(&rt, "lm_small")
        .privacy(PrivacySpec { epsilon, delta: 1e-5, quantile_r: 0.01 })
        .clip(ClipPolicy {
            clip_init: 0.1,
            target_q: 0.5,
            ..ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive)
        })
        .optim(OptimSpec::adam(2e-3))
        .epochs(epochs)
        .build(train.len())?;
    sess.run(&train, 10)?;
    let (nll, _) = sess.evaluate(&eval)?;

    // decode a few eval prefixes
    let exec = rt.load("lm_small", "logits")?;
    let n = 32;
    let prefixes: Vec<Vec<i32>> = (0..n).map(|i| eval.prefix(i).to_vec()).collect();
    let hyps = greedy_decode(&exec, sess.params()?, &prefixes, cfg.batch, cfg.hyper.seq)?;
    let refs: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let r = eval.reference_suffix(i);
            r[..r.len().min(cfg.hyper.seq - eval.prefix_len)].to_vec()
        })
        .collect();

    println!("\nsample generation (token ids), first example:");
    println!("  prefix: {:?}", prefixes[0]);
    println!("  hyp:    {:?}", &hyps[0]);
    println!("  ref:    {:?}", &refs[0]);
    println!(
        "\neval NLL {nll:.3} | BLEU-4 {:.1} | ROUGE-L {:.1} at eps={epsilon}",
        100.0 * corpus_bleu(&hyps, &refs, 4),
        100.0 * rouge_l(&hyps, &refs),
    );
    Ok(())
}
