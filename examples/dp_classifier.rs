//! Compare all clipping schemes head-to-head on the CIFAR-10 analog —
//! a miniature of Tables 1/2/11.
//!
//!     cargo run --release --example dp_classifier [-- --epsilon 3 --epochs 4]

use anyhow::Result;

use gwclip::coordinator::{Method, TrainOpts, Trainer};
use gwclip::data::classif::MixtureImages;
use gwclip::data::Dataset;
use gwclip::runtime::Runtime;
use gwclip::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let epsilon = args.get_f64("epsilon", 3.0)?;
    let epochs = args.get_f64("epochs", 4.0)?;

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let train = MixtureImages::with_spread(4096, 64, 10, 0xC1FA, 0, 0.55);
    let eval = MixtureImages::with_spread(1024, 64, 10, 0xC1FA, 900, 0.55);

    println!("{:<22} {:>9} {:>9}", "method", "loss", "acc %");
    for method in [
        Method::NonPrivate,
        Method::FlatFixed,
        Method::FlatAdaptive,
        Method::PerLayerFixed,
        Method::PerLayerAdaptive,
    ] {
        let opts = TrainOpts {
            method,
            epsilon,
            epochs,
            lr: 0.25,
            target_q: 0.6,
            quantile_r: 0.01,
            ..Default::default()
        };
        let mut tr = Trainer::new(&rt, "resmlp", train.len(), opts)?;
        tr.run(&train, 0)?;
        let (loss, acc) = tr.evaluate(&eval)?;
        println!("{:<22} {:>9.4} {:>9.1}", method.name(), loss, 100.0 * acc);
    }
    Ok(())
}
