//! Compare all clipping schemes head-to-head on the CIFAR-10 analog —
//! a miniature of Tables 1/2/11, one `ClipPolicy` per row.
//!
//!     cargo run --release --example dp_classifier [-- --epsilon 3 --epochs 4]

use anyhow::Result;

use gwclip::data::classif::MixtureImages;
use gwclip::data::Dataset;
use gwclip::runtime::Runtime;
use gwclip::session::{ClipMode, ClipPolicy, GroupBy, OptimSpec, PrivacySpec, Session};
use gwclip::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let epsilon = args.get_f64("epsilon", 3.0)?;
    let epochs = args.get_f64("epochs", 4.0)?;

    let rt = Runtime::new(gwclip::artifact_dir())?;
    let train = MixtureImages::with_spread(4096, 64, 10, 0xC1FA, 0, 0.55);
    let eval = MixtureImages::with_spread(1024, 64, 10, 0xC1FA, 900, 0.55);

    println!("{:<22} {:>9} {:>9}", "policy", "loss", "acc %");
    for (label, group_by, mode) in [
        ("non-private", GroupBy::Flat, ClipMode::NonPrivate),
        ("flat fixed", GroupBy::Flat, ClipMode::Fixed),
        ("flat adaptive", GroupBy::Flat, ClipMode::Adaptive),
        ("per-layer fixed", GroupBy::PerLayer, ClipMode::Fixed),
        ("per-layer adaptive", GroupBy::PerLayer, ClipMode::Adaptive),
    ] {
        let mut sess = Session::builder(&rt, "resmlp")
            .privacy(PrivacySpec { epsilon, delta: 1e-5, quantile_r: 0.01 })
            .clip(ClipPolicy { target_q: 0.6, ..ClipPolicy::new(group_by, mode) })
            .optim(OptimSpec::sgd(0.25))
            .epochs(epochs)
            .build(train.len())?;
        sess.run(&train, 0)?;
        let (loss, acc) = sess.evaluate(&eval)?;
        println!("{label:<22} {loss:>9.4} {:>9.1}", 100.0 * acc);
    }
    Ok(())
}
