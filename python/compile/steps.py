"""Entry-point builders: one function per clipping scheme, each closed over
a ModelConfig and lowered to HLO by aot.py.

The schemes and their cost structures (what Figure 1 measures):

  nonprivate_step    one fwd+bwd, plain summed grads.
  dp_step_perlayer   one fwd+bwd; at each layer, ghost norms -> per-group
                     clip factor -> fused clipped sum. No per-example
                     gradients, no second pass. (the paper's section 3.1)
  dp_step_flat       one fwd+bwd caching (a, delta) for every layer; global
                     norm -> single factor -> clipped sums. Memory: all
                     (a, delta) pairs live until the norms are known.
  dp_step_ghost      flat clipping via TWO backward passes (Li et al. 2022b):
                     pass 1 ghost norms only, pass 2 autodiff of the
                     coeff-weighted loss. Memory-light, compute-heavy.
  dp_step_naive      Opacus-style: vmap(grad) materializes B per-example
                     gradients, clips, sums. Memory-heavy baseline.

All dp steps take `weights` [B] in {0,1} (Poisson-sample padding mask) and
`thresholds` (per group [K], or scalar for flat), and return per-example
norms so the rust coordinator can run quantile estimation (Algorithm 1
lines 15-18) without extra round trips.

Returned grads are SUMS over the batch (unnormalized); the coordinator
adds noise and divides by the (expected) batch size, matching Algorithm 1
line 14.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import model as M


def _group_index(cfg) -> tuple[list[str], dict[str, int]]:
    groups = M.group_names(cfg)
    gidx = {g: i for i, g in enumerate(groups)}
    return groups, gidx


def _trainable_specs(cfg):
    return [s for s in M.param_specs(cfg) if s.trainable]


def _tape_group_norms(cfg, tape) -> jnp.ndarray:
    """Stack per-example per-group gradient norms -> [B, K] (not squared)."""
    groups, gidx = _group_index(cfg)
    acc = [None] * len(groups)
    for s in _trainable_specs(cfg):
        ns = tape.norm_sq(s.name)
        k = gidx[s.group]
        acc[k] = ns if acc[k] is None else acc[k] + ns
    return jnp.sqrt(jnp.maximum(jnp.stack(acc, axis=1), 0.0))


def _clip_coeff(norms: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(1.0, thresh / jnp.maximum(norms, 1e-12))


def _weighted_mean_loss(loss_i, weights):
    return jnp.sum(loss_i * weights) / jnp.maximum(jnp.sum(weights), 1.0)


# ---------------------------------------------------------------------------

def make_nonprivate_step(cfg):
    bwd = M.backward_fn(cfg)

    def step(params, x, y):
        tape, loss_i, _ = bwd(params, x, y)
        b = float(cfg.batch)
        grads = [tape.sum_grad(s.name) / b for s in _trainable_specs(cfg)]
        return (jnp.mean(loss_i), *grads)

    return step


def make_dp_step_perlayer(cfg):
    """Algorithm 1 lines 7-12: group-wise clip fused into backprop."""
    bwd = M.backward_fn(cfg)
    groups, gidx = _group_index(cfg)

    def step(params, x, y, thresholds, weights):
        tape, loss_i, _ = bwd(params, x, y)
        norms = _tape_group_norms(cfg, tape)                  # [B,K]
        coeff = _clip_coeff(norms, thresholds[None, :]) * weights[:, None]
        grads = [
            tape.clipped_sum(s.name, coeff[:, gidx[s.group]])
            for s in _trainable_specs(cfg)
        ]
        return (_weighted_mean_loss(loss_i, weights), *grads, norms)

    return step


def make_dp_step_flat(cfg):
    """Flat clipping with ghost norms: one backward, (a, delta) cached for
    every layer until the global norm is known."""
    bwd = M.backward_fn(cfg)

    def step(params, x, y, threshold, weights):
        tape, loss_i, _ = bwd(params, x, y)
        norms_k = _tape_group_norms(cfg, tape)
        gnorm = jnp.sqrt(jnp.sum(norms_k * norms_k, axis=1))  # [B]
        coeff = _clip_coeff(gnorm, threshold) * weights
        grads = [tape.clipped_sum(s.name, coeff) for s in _trainable_specs(cfg)]
        return (_weighted_mean_loss(loss_i, weights), *grads, gnorm)

    return step


def make_dp_step_ghost(cfg):
    """Ghost clipping (Li et al. 2022b): norms pass + second backward of the
    coefficient-weighted loss. Same output as dp_step_flat, 2x backward."""
    bwd = M.backward_fn(cfg)
    loss_fn = M.forward_loss_fn(cfg)
    specs = M.param_specs(cfg)
    t_idx = [i for i, s in enumerate(specs) if s.trainable]

    def step(params, x, y, threshold, weights):
        tape, loss_i, _ = bwd(params, x, y)
        norms_k = _tape_group_norms(cfg, tape)
        gnorm = jnp.sqrt(jnp.sum(norms_k * norms_k, axis=1))
        coeff = _clip_coeff(gnorm, threshold) * weights

        def weighted(plist):
            return jnp.sum(loss_fn(plist, x, y) * coeff)

        all_grads = jax.grad(weighted)(params)
        grads = [all_grads[i] for i in t_idx]
        return (_weighted_mean_loss(loss_i, weights), *grads, gnorm)

    return step


def make_dp_step_naive(cfg):
    """Opacus-style flat clipping: materialize per-example gradients."""
    loss_fn = M.forward_loss_fn(cfg)
    specs = M.param_specs(cfg)
    t_idx = [i for i, s in enumerate(specs) if s.trainable]

    def step(params, x, y, threshold, weights):
        def single(plist, xi, yi):
            return loss_fn(plist, xi[None], yi[None])[0]

        loss_i = loss_fn(params, x, y)
        per_ex = jax.vmap(jax.grad(single), in_axes=(None, 0, 0))(params, x, y)
        per_ex = [per_ex[i] for i in t_idx]                    # each [B, ...]
        sq = sum(jnp.sum(g * g, axis=tuple(range(1, g.ndim))) for g in per_ex)
        gnorm = jnp.sqrt(sq)
        coeff = _clip_coeff(gnorm, threshold) * weights
        grads = [jnp.tensordot(coeff, g, axes=(0, 0)) for g in per_ex]
        return (_weighted_mean_loss(loss_i, weights), *grads, gnorm)

    return step


def make_eval_batch(cfg):
    loss_fn = M.forward_loss_fn(cfg)

    def step(params, x, y, weights):
        loss_i = loss_fn(params, x, y)
        if cfg.kind == "lm":
            correct = jnp.zeros_like(loss_i)
        elif cfg.kind == "classifier":
            logits = M.classifier_forward_logits(cfg, params, x)
            correct = (jnp.argmax(logits, -1) == y).astype(jnp.float32)
        else:
            logits = M.resmlp_forward_logits(cfg, params, x)
            correct = (jnp.argmax(logits, -1) == y).astype(jnp.float32)
        return (
            jnp.sum(loss_i * weights),
            jnp.sum(correct * weights),
            jnp.sum(weights),
        )

    return step


def make_forward_logits(cfg):
    """Next-token logits for decoding (LM only): returns logits [B,T,V]."""
    def step(params, x):
        p = M.as_dict(cfg, params)
        h, _ = M._trunk_fwd(cfg, p, x, causal=True)
        import compile.layers as layers
        hf, _ = layers.layernorm_fwd(h, p["ln_f.g"], p["ln_f.b"])
        return (layers.linear_fwd(hf, p["head.w"], p["head.b"]),)

    return step


# ---------------------------------------------------------------------------
# pipeline-parallel stage entry points (per-device clipping, Algorithm 2)
# ---------------------------------------------------------------------------

def stage_param_specs(cfg, boundaries: list[int], stage: int):
    """Specs owned by `stage` when blocks are split at `boundaries`
    (len = n_stages+1, boundaries[0]=0, boundaries[-1]=n_layers).
    Stage 0 additionally owns the embeddings; the last owns ln_f + head."""
    lo, hi = boundaries[stage], boundaries[stage + 1]
    last = stage == len(boundaries) - 2
    names = set()
    if stage == 0:
        names |= {"tok_emb", "pos_emb"}
    for i in range(lo, hi):
        names |= {s.name for s in M.param_specs(cfg) if s.name.startswith(f"block{i}.")}
    if last:
        names |= {"ln_f.g", "ln_f.b", "head.w", "head.b"}
    return [s for s in M.param_specs(cfg) if s.name in names]


def _stage_fwd(cfg, p, stage_specs, x_or_tokens, lo, hi, first, last, want_caches):
    if first:
        xx, caches = M._trunk_fwd(cfg, p, x_or_tokens, causal=True, lo=lo, hi=hi, embed=True)
    else:
        xx, caches = M._trunk_fwd(cfg, p, None, causal=True, lo=lo, hi=hi,
                                  embed=False, x=x_or_tokens)
    return xx, caches


def make_stage_fwd(cfg, boundaries, stage):
    lo, hi = boundaries[stage], boundaries[stage + 1]
    first = stage == 0
    specs = stage_param_specs(cfg, boundaries, stage)

    def step(params, x):
        p = {s.name: v for s, v in zip(specs, params)}
        xx, _ = _stage_fwd(cfg, p, specs, x, lo, hi, first, False, False)
        return (xx,)

    return step


def _stage_backward(cfg, p, specs, x, dy, lo, hi, first):
    """Recompute fwd (pipeline rematerialization) then bwd; fill tape."""
    from compile.layers import Tape
    tape = Tape(cfg.use_pallas)
    if first:
        xx, caches = M._trunk_fwd(cfg, p, x, causal=True, lo=lo, hi=hi, embed=True)
        dx = M._trunk_bwd(tape, cfg, p, x, dy, caches, lo, hi, embed=True)
    else:
        xx, caches = M._trunk_fwd(cfg, p, None, causal=True, lo=lo, hi=hi,
                                  embed=False, x=x)
        dx = M._trunk_bwd(tape, cfg, p, None, dy, caches, lo, hi, embed=False)
    return tape, dx


def _stage_norms(cfg, tape, specs) -> jnp.ndarray:
    """Per-device clipping treats the WHOLE hosted piece as one group."""
    tr = [s for s in specs if s.trainable]
    sq = None
    for s in tr:
        ns = tape.norm_sq(s.name)
        sq = ns if sq is None else sq + ns
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def make_stage_bwd(cfg, boundaries, stage):
    """Per-device clipping bwd: (dx, clipped sums for local piece, norms)."""
    lo, hi = boundaries[stage], boundaries[stage + 1]
    first = stage == 0
    specs = stage_param_specs(cfg, boundaries, stage)
    tr = [s for s in specs if s.trainable]

    def step(params, x, dy, threshold, weights):
        p = {s.name: v for s, v in zip(specs, params)}
        tape, dx = _stage_backward(cfg, p, specs, x, dy, lo, hi, first)
        norms = _stage_norms(cfg, tape, specs)
        coeff = _clip_coeff(norms, threshold) * weights
        grads = [tape.clipped_sum(s.name, coeff) for s in tr]
        return (dx, *grads, norms)

    return step


def make_stage_bwd_norm(cfg, boundaries, stage):
    """Flat-over-pipeline baseline pass 1: dx + local norms, NO grads."""
    lo, hi = boundaries[stage], boundaries[stage + 1]
    first = stage == 0
    specs = stage_param_specs(cfg, boundaries, stage)

    def step(params, x, dy):
        p = {s.name: v for s, v in zip(specs, params)}
        tape, dx = _stage_backward(cfg, p, specs, x, dy, lo, hi, first)
        return (dx, _stage_norms(cfg, tape, specs))

    return step


def make_stage_regrad(cfg, boundaries, stage):
    """Flat-over-pipeline baseline pass 2 (approach (iii), section 4):
    rematerialize fwd+bwd, emit clipped sums for a now-known coeff."""
    lo, hi = boundaries[stage], boundaries[stage + 1]
    first = stage == 0
    specs = stage_param_specs(cfg, boundaries, stage)
    tr = [s for s in specs if s.trainable]

    def step(params, x, dy, coeff):
        p = {s.name: v for s, v in zip(specs, params)}
        tape, _ = _stage_backward(cfg, p, specs, x, dy, lo, hi, first)
        grads = [tape.clipped_sum(s.name, coeff) for s in tr]
        return tuple(grads)

    return step


def make_stage_loss_bwd(cfg, boundaries, stage, mode: str):
    """Last stage: loss head + bwd. mode in {'perdevice','norm','regrad'}."""
    lo, hi = boundaries[stage], boundaries[stage + 1]
    assert stage == len(boundaries) - 2
    first = stage == 0
    specs = stage_param_specs(cfg, boundaries, stage)
    tr = [s for s in specs if s.trainable]

    def run(params, x, targets):
        import compile.layers as layers
        from compile.layers import Tape
        p = {s.name: v for s, v in zip(specs, params)}
        tape = Tape(cfg.use_pallas)
        if first:
            h, caches = M._trunk_fwd(cfg, p, x, causal=True, lo=lo, hi=hi, embed=True)
        else:
            h, caches = M._trunk_fwd(cfg, p, None, causal=True, lo=lo, hi=hi,
                                     embed=False, x=x)
        hf, c_lnf = layers.layernorm_fwd(h, p["ln_f.g"], p["ln_f.b"])
        logits = layers.linear_fwd(hf, p["head.w"], p["head.b"])
        loss_i, dlogits = layers.lm_loss_fwd(logits, targets)
        head_tr = cfg.train_base or cfg.lora_rank > 0
        if head_tr:
            dhf = layers.linear_bwd(tape, "head", dlogits, hf, p["head.w"], p["head.b"])
        else:
            dhf = dlogits @ p["head.w"].T
        if cfg.train_base:
            dh = layers.layernorm_bwd(tape, "ln_f", dhf, c_lnf, p["ln_f.g"])
        else:
            dh = M._ln_bwd_nograd(dhf, c_lnf, p["ln_f.g"])
        if first:
            dx = M._trunk_bwd(tape, cfg, p, x, dh, caches, lo, hi, embed=True)
        else:
            dx = M._trunk_bwd(tape, cfg, p, None, dh, caches, lo, hi, embed=False)
        return tape, loss_i, dx

    if mode == "perdevice":
        def step(params, x, targets, threshold, weights):
            tape, loss_i, dx = run(params, x, targets)
            norms = _stage_norms(cfg, tape, specs)
            coeff = _clip_coeff(norms, threshold) * weights
            grads = [tape.clipped_sum(s.name, coeff) for s in tr]
            return (_weighted_mean_loss(loss_i, weights), dx, *grads, norms)
        return step
    if mode == "norm":
        def step(params, x, targets):
            tape, loss_i, dx = run(params, x, targets)
            return (jnp.mean(loss_i), dx, _stage_norms(cfg, tape, specs))
        return step

    def step(params, x, targets, coeff):
        tape, _, _ = run(params, x, targets)
        grads = [tape.clipped_sum(s.name, coeff) for s in tr]
        return tuple(grads)
    return step


def make_stage_eval(cfg, boundaries, stage):
    """Last stage eval: per-example loss summed with weights."""
    lo, hi = boundaries[stage], boundaries[stage + 1]
    first = stage == 0
    specs = stage_param_specs(cfg, boundaries, stage)

    def step(params, x, targets, weights):
        import compile.layers as layers
        p = {s.name: v for s, v in zip(specs, params)}
        if first:
            h, _ = M._trunk_fwd(cfg, p, x, causal=True, lo=lo, hi=hi, embed=True)
        else:
            h, _ = M._trunk_fwd(cfg, p, None, causal=True, lo=lo, hi=hi,
                                embed=False, x=x)
        hf, _ = layers.layernorm_fwd(h, p["ln_f.g"], p["ln_f.b"])
        logits = layers.linear_fwd(hf, p["head.w"], p["head.b"])
        loss_i, _ = layers.lm_loss_fwd(logits, targets)
        return (jnp.sum(loss_i * weights), jnp.sum(weights))

    return step
