"""AOT compile path: lower every entry point of every model config to HLO
*text* and emit artifacts/manifest.json + init checkpoints.

This is the only place python runs; after `make artifacts` the rust binary
is self-contained. Interchange is HLO text, NOT serialized HloModuleProto:
jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Conventions consumed by rust/src/runtime/manifest.rs:
  * inputs  = [params in spec order] ++ extra inputs (manifest order)
  * outputs = tuple, names listed in the manifest ("loss", "grad:<name>",
    "norms", "dx", ...)
  * checkpoints: "GWCK" | version u32 | json_len u32 | header json |
    raw f32 little-endian payloads at header offsets.
"""
from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import steps

jax.config.update("jax_platform_name", "cpu")

F32, I32 = "f32", "i32"


# ---------------------------------------------------------------------------
# model configurations (see DESIGN.md section 6 for the experiment mapping)
# ---------------------------------------------------------------------------

def configs() -> dict[str, dict]:
    """name -> {cfg: ModelConfig, entries: [...], stages: [...]|None}.

    Tiny configs route norm/clip through the real Pallas kernels
    (use_pallas=True) to prove the L1 integration end-to-end; larger
    perf-oriented configs use the numerically identical jnp oracles which
    XLA fuses better on CPU (test_pallas_and_jnp_paths_agree pins equality).
    """
    all_dp = ["nonprivate", "perlayer", "flat", "ghost", "naive", "eval"]
    no_naive = ["nonprivate", "perlayer", "flat", "ghost", "eval"]
    out = {
        # rust unit/integration tests — small and pallas-powered
        "resmlp_tiny": dict(
            cfg=M.ModelConfig(kind="resmlp", batch=8, features=16, width=32,
                              blocks=2, n_classes=10, use_pallas=True),
            entries=all_dp),
        "lm_tiny": dict(
            cfg=M.ModelConfig(kind="lm", batch=4, vocab=64, seq=16, d_model=32,
                              n_heads=2, n_layers=2, d_ff=64, use_pallas=True),
            entries=all_dp + ["logits"]),
        # single-stage pipeline twin of lm_tiny (same ModelConfig, hence
        # identical init checkpoint): backs the backend-parity integration
        # test — per-device clipping over one stage must reproduce the
        # single-device flat run's privacy plan and Poisson draws
        "lm_tiny_pipe": dict(
            cfg=M.ModelConfig(kind="lm", batch=4, vocab=64, seq=16, d_model=32,
                              n_heads=2, n_layers=2, d_ff=64, use_pallas=True),
            entries=[], stages=[0, 2]),
        # CIFAR-10 analog (WRN16-4 -> WideResMLP), Tables 1a/2/11a, Figs 2/3/5
        "resmlp": dict(
            cfg=M.ModelConfig(kind="resmlp", batch=256, features=64, width=256,
                              blocks=4, n_classes=10, use_pallas=False),
            entries=no_naive),
        # GLUE/SST-2 analog (RoBERTa -> encoder classifier), Tables 1b/3/4/10/11b
        "cls_small": dict(
            cfg=M.ModelConfig(kind="classifier", batch=64, vocab=400, seq=32,
                              d_model=64, n_heads=4, n_layers=3, d_ff=256,
                              n_classes=4, use_pallas=False),
            entries=no_naive),
        # GPT-2 analog (E2E/DART table-to-text), Table 5, Figs 1/7/8
        "lm_small": dict(
            cfg=M.ModelConfig(kind="lm", batch=32, vocab=512, seq=32,
                              d_model=128, n_heads=4, n_layers=4, d_ff=512,
                              use_pallas=False),
            entries=all_dp + ["logits"]),
        # GPT-2-xl analog for Table 6 (single-device flat-clipped LoRA)
        "lm_small_lora": dict(
            cfg=M.ModelConfig(kind="lm", batch=32, vocab=512, seq=32,
                              d_model=128, n_heads=4, n_layers=4, d_ff=512,
                              lora_rank=4, train_base=False, use_pallas=False),
            entries=["nonprivate", "flat", "perlayer", "eval", "logits"]),
        # GPT-3 analog for Table 6: bigger LM partitioned over 4 devices,
        # LoRA adapters only, per-device clipping (Algorithm 2)
        "lm_mid_pipe_lora": dict(
            cfg=M.ModelConfig(kind="lm", batch=8, vocab=512, seq=32,
                              d_model=256, n_heads=8, n_layers=8, d_ff=1024,
                              lora_rank=4, train_base=False, use_pallas=False),
            entries=[], stages=[0, 2, 4, 6, 8]),
        # full-model pipeline (pretraining the GPT-3 analog + section 4 bench)
        "lm_mid_pipe": dict(
            cfg=M.ModelConfig(kind="lm", batch=8, vocab=512, seq=32,
                              d_model=256, n_heads=8, n_layers=8, d_ff=1024,
                              use_pallas=False),
            entries=["nonprivate", "eval", "logits"], stages=[0, 2, 4, 6, 8]),
        # end-to-end driver (examples/e2e_train.rs): ~14M param LM
        "lm_e2e": dict(
            cfg=M.ModelConfig(kind="lm", batch=8, vocab=4096, seq=64,
                              d_model=384, n_heads=6, n_layers=6, d_ff=1536,
                              use_pallas=False),
            entries=["nonprivate", "perlayer", "flat", "eval"]),
    }
    return out


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def dt(dtype):
    return I32 if dtype in (jnp.int32, "i32") else F32


def lower_entry(fn, arg_specs, out_dir, fname) -> str:
    # keep_unused=True: the rust runtime feeds every manifest input, so the
    # lowered module must keep parameters XLA would otherwise DCE (e.g.
    # frozen biases in LoRA stages).
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    return fname


# ---------------------------------------------------------------------------
# checkpoint writer
# ---------------------------------------------------------------------------

def write_checkpoint(path: str, names: list[str], arrays: list[np.ndarray]):
    header, offset = [], 0
    for n, a in zip(names, arrays):
        a = np.asarray(a, np.float32)
        header.append({"name": n, "shape": list(a.shape), "offset": offset})
        offset += a.size * 4
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(b"GWCK")
        f.write(struct.pack("<II", 1, len(hjson)))
        f.write(hjson)
        for a in arrays:
            f.write(np.asarray(a, np.float32).tobytes())


# ---------------------------------------------------------------------------
# per-config export
# ---------------------------------------------------------------------------

def batch_specs(cfg):
    if cfg.kind == "resmlp":
        return [spec((cfg.batch, cfg.features)), spec((cfg.batch,), jnp.int32)], \
               [("x", (cfg.batch, cfg.features), F32), ("y", (cfg.batch,), I32)]
    if cfg.kind == "classifier":
        return [spec((cfg.batch, cfg.seq), jnp.int32), spec((cfg.batch,), jnp.int32)], \
               [("x", (cfg.batch, cfg.seq), I32), ("y", (cfg.batch,), I32)]
    return [spec((cfg.batch, cfg.seq), jnp.int32), spec((cfg.batch, cfg.seq), jnp.int32)], \
           [("x", (cfg.batch, cfg.seq), I32), ("y", (cfg.batch, cfg.seq), I32)]


def export_config(name: str, info: dict, out_dir: str) -> dict:
    cfg = info["cfg"]
    specs = M.param_specs(cfg)
    groups = M.group_names(cfg)
    gidx = {g: i for i, g in enumerate(groups)}
    tr = [s for s in specs if s.trainable]
    group_dims = [0] * len(groups)
    for s in tr:
        group_dims[gidx[s.group]] += int(np.prod(s.shape))

    pspecs = [spec(s.shape) for s in specs]
    bspecs, binfo = batch_specs(cfg)
    b = cfg.batch
    K = len(groups)
    w_in = ("weights", (b,), F32)
    thK_in = ("thresholds", (K,), F32)
    th1_in = ("threshold", (), F32)

    grad_outs = [(f"grad:{s.name}", list(s.shape), F32) for s in tr]
    entries = {}

    def emit(ename, fn, extra_specs, extra_info, outputs, params_specs=None):
        fname = f"{name}__{ename}.hlo.txt"
        lower_entry(fn, (params_specs or pspecs,) + tuple(extra_specs), out_dir, fname)
        entries[ename] = {
            "file": fname,
            "extra_inputs": [{"name": n, "shape": list(sh), "dtype": d}
                             for n, sh, d in extra_info],
            "outputs": [{"name": n, "shape": list(sh), "dtype": d}
                        for n, sh, d in outputs],
        }
        print(f"  {fname}")

    for ename in info.get("entries", []):
        if ename == "nonprivate":
            emit("nonprivate", steps.make_nonprivate_step(cfg), bspecs, binfo,
                 [("loss", [], F32)] + grad_outs)
        elif ename == "perlayer":
            emit("dp_perlayer", steps.make_dp_step_perlayer(cfg),
                 bspecs + [spec((K,)), spec((b,))], binfo + [thK_in, w_in],
                 [("loss", [], F32)] + grad_outs + [("norms", [b, K], F32)])
        elif ename == "flat":
            emit("dp_flat", steps.make_dp_step_flat(cfg),
                 bspecs + [spec(()), spec((b,))], binfo + [th1_in, w_in],
                 [("loss", [], F32)] + grad_outs + [("norms", [b], F32)])
        elif ename == "ghost":
            emit("dp_ghost", steps.make_dp_step_ghost(cfg),
                 bspecs + [spec(()), spec((b,))], binfo + [th1_in, w_in],
                 [("loss", [], F32)] + grad_outs + [("norms", [b], F32)])
        elif ename == "naive":
            emit("dp_naive", steps.make_dp_step_naive(cfg),
                 bspecs + [spec(()), spec((b,))], binfo + [th1_in, w_in],
                 [("loss", [], F32)] + grad_outs + [("norms", [b], F32)])
        elif ename == "eval":
            emit("eval", steps.make_eval_batch(cfg), bspecs + [spec((b,))],
                 binfo + [w_in],
                 [("loss_sum", [], F32), ("correct_sum", [], F32), ("weight_sum", [], F32)])
        elif ename == "logits":
            emit("logits", steps.make_forward_logits(cfg), bspecs[:1], binfo[:1],
                 [("logits", [b, cfg.seq, cfg.vocab], F32)])

    # ---- pipeline stages -------------------------------------------------
    stages_meta = None
    bounds = info.get("stages")
    if bounds:
        n_stages = len(bounds) - 1
        stages_meta = {"boundaries": bounds, "stages": []}
        d = cfg.d_model
        t = cfg.seq
        act = ("x", (b, t, d), F32)
        dy = ("dy", (b, t, d), F32)
        for st in range(n_stages):
            sspecs = steps.stage_param_specs(cfg, bounds, st)
            str_ = [s for s in sspecs if s.trainable]
            sp = [spec(s.shape) for s in sspecs]
            sgrads = [(f"grad:{s.name}", list(s.shape), F32) for s in str_]
            first, last = st == 0, st == n_stages - 1
            xin = binfo[0] if first else act
            xin_spec = bspecs[0] if first else spec((b, t, d))
            pre = f"stage{st}"
            if not last:
                emit(f"{pre}_fwd", steps.make_stage_fwd(cfg, bounds, st),
                     [xin_spec], [xin], [("x_out", (b, t, d), F32)], sp)
                emit(f"{pre}_bwd", steps.make_stage_bwd(cfg, bounds, st),
                     [xin_spec, spec((b, t, d)), spec(()), spec((b,))],
                     [xin, dy, th1_in, w_in],
                     [("dx", [b, t, d], F32)] + sgrads
                     + [("norms", [b], F32)], sp)
                emit(f"{pre}_bwd_norm", steps.make_stage_bwd_norm(cfg, bounds, st),
                     [xin_spec, spec((b, t, d))], [xin, dy],
                     [("dx", [b, t, d], F32), ("norms", [b], F32)], sp)
                emit(f"{pre}_regrad", steps.make_stage_regrad(cfg, bounds, st),
                     [xin_spec, spec((b, t, d)), spec((b,))],
                     [xin, dy, ("coeff", (b,), F32)], sgrads, sp)
            else:
                tgt = binfo[1]
                tgt_spec = bspecs[1]
                emit(f"{pre}_loss_bwd",
                     steps.make_stage_loss_bwd(cfg, bounds, st, "perdevice"),
                     [xin_spec, tgt_spec, spec(()), spec((b,))],
                     [xin, tgt, th1_in, w_in],
                     [("loss", [], F32), ("dx", [b, t, d], F32)] + sgrads
                     + [("norms", [b], F32)], sp)
                emit(f"{pre}_loss_norm",
                     steps.make_stage_loss_bwd(cfg, bounds, st, "norm"),
                     [xin_spec, tgt_spec], [xin, tgt],
                     [("loss", [], F32), ("dx", [b, t, d], F32), ("norms", [b], F32)], sp)
                emit(f"{pre}_loss_regrad",
                     steps.make_stage_loss_bwd(cfg, bounds, st, "regrad"),
                     [xin_spec, tgt_spec, spec((b,))],
                     [xin, tgt, ("coeff", (b,), F32)], sgrads, sp)
                emit(f"{pre}_eval", steps.make_stage_eval(cfg, bounds, st),
                     [xin_spec, tgt_spec, spec((b,))], [xin, tgt, w_in],
                     [("loss_sum", [], F32), ("weight_sum", [], F32)], sp)
            stages_meta["stages"].append({
                "params": [s.name for s in sspecs],
                "trainable": [s.name for s in str_],
                "d_stage": sum(int(np.prod(s.shape)) for s in str_),
            })

    # ---- init checkpoint --------------------------------------------------
    ck = f"ckpt_{name}_init.bin"
    params = M.init_params(cfg, seed=0)
    write_checkpoint(os.path.join(out_dir, ck),
                     [s.name for s in specs], [np.asarray(p) for p in params])

    hyper = {k: v for k, v in vars(cfg).items()}
    return {
        "model": cfg.kind,
        "hyper": hyper,
        "batch": b,
        "params": [{"name": s.name, "shape": list(s.shape), "group": s.group,
                    "trainable": s.trainable,
                    "size": int(np.prod(s.shape))} for s in specs],
        "groups": groups,
        "group_dims": group_dims,
        "entries": entries,
        "stages": stages_meta,
        "init_checkpoint": ck,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated config names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "configs": {}}
    only = set(args.only.split(",")) if args.only else None
    for name, info in configs().items():
        if only and name not in only:
            continue
        print(f"[aot] lowering config {name}")
        manifest["configs"][name] = export_config(name, info, args.out_dir)
    path = os.path.join(args.out_dir, "manifest.json")
    # merge with any existing manifest when --only is used
    if only and os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        old["configs"].update(manifest["configs"])
        manifest = old
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path}")


if __name__ == "__main__":
    main()
