"""L2 building blocks: layers with *manual* forward/backward.

Why manual backprop? The paper's efficiency contribution (section 3.1) is
that per-layer clipping can be performed *in conjunction with*
backpropagation: when the backward pass reaches layer k we already hold the
layer inputs `a` and output gradients `delta`, which is all the ghost
kernels need to (1) compute per-example gradient norms and (2) emit the
clipped gradient sum -- without materializing per-example gradients and
without a second backward pass. Autodiff hides that structure; writing the
backward by hand exposes it, exactly like the custom CUDA autograd hooks in
the paper's implementation.

Every parameter gradient is captured as a `Rec` on a `Tape`:
    kind = linear : (a [B,T,din], delta [B,T,dout])      grad [din,dout]
    kind = bias   : (delta [B,T,dout])                   grad [dout]
    kind = embed  : (ids [B,T], delta [B,T,D], vocab)    grad [vocab,D]
    kind = direct : (g [B, *shape])                      grad [*shape]

From a Rec we can produce, per example i:
    norm_sq(rec)            -> [B]    ||g_i||^2 contribution
    clipped_sum(rec, coeff) -> grad   sum_i coeff_i g_i

`use_pallas=True` routes norm/clip through the L1 Pallas kernels
(interpret=True); `False` uses the numerically identical pure-jnp oracles,
which XLA fuses better on CPU -- perf-oriented configs use the latter, the
integration-proof configs the former (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import ghost, ref


@dataclasses.dataclass
class Rec:
    kind: str
    tensors: tuple
    shape: tuple  # parameter shape


class Tape:
    """Collects one Rec per parameter tensor during the backward pass."""

    def __init__(self, use_pallas: bool):
        self.recs: dict[str, Rec] = {}
        self.use_pallas = use_pallas

    def linear(self, name: str, a, delta, w_shape):
        self.recs[name] = Rec("linear", (a, delta), w_shape)

    def bias(self, name: str, delta, b_shape):
        self.recs[name] = Rec("bias", (delta,), b_shape)

    def embed(self, name: str, ids, delta, vocab):
        self.recs[name] = Rec("embed", (ids, delta, vocab), (vocab, delta.shape[-1]))

    def direct(self, name: str, g):
        self.recs[name] = Rec("direct", (g,), g.shape[1:])

    # -- per-example squared norm of this tensor's gradient ----------------
    def norm_sq(self, name: str) -> jnp.ndarray:
        rec = self.recs[name]
        if rec.kind == "linear":
            a, delta = rec.tensors
            fn = ghost.ghost_norm if self.use_pallas else ref.ref_ghost_norm
            return fn(a, delta)
        if rec.kind == "bias":
            (delta,) = rec.tensors
            s = jnp.sum(delta, axis=1)  # [B, dout]
            return jnp.sum(s * s, axis=-1)
        if rec.kind == "embed":
            ids, delta, _ = rec.tensors
            fn = ghost.embed_ghost_norm if self.use_pallas else ref.ref_embed_ghost_norm
            return fn(ids, delta)
        (g,) = rec.tensors
        return jnp.sum(g * g, axis=tuple(range(1, g.ndim)))

    # -- sum_i coeff_i g_i ---------------------------------------------------
    def clipped_sum(self, name: str, coeff: jnp.ndarray) -> jnp.ndarray:
        rec = self.recs[name]
        if rec.kind == "linear":
            a, delta = rec.tensors
            fn = ghost.clip_matmul if self.use_pallas else ref.ref_clip_matmul
            return fn(a, delta, coeff)
        if rec.kind == "bias":
            (delta,) = rec.tensors
            return jnp.einsum("b,bto->o", coeff, delta)
        if rec.kind == "embed":
            ids, delta, vocab = rec.tensors
            fn = ghost.clip_scatter_embed if self.use_pallas else ref.ref_clip_scatter_embed
            return fn(ids, delta, coeff, vocab)
        (g,) = rec.tensors
        return jnp.tensordot(coeff, g, axes=(0, 0))

    # -- plain summed gradient (non-private path, no clip machinery) --------
    def sum_grad(self, name: str) -> jnp.ndarray:
        rec = self.recs[name]
        if rec.kind == "linear":
            a, delta = rec.tensors
            return jnp.einsum("bti,bto->io", a, delta)
        if rec.kind == "bias":
            (delta,) = rec.tensors
            return jnp.sum(delta, axis=(0, 1))
        if rec.kind == "embed":
            ids, delta, vocab = rec.tensors
            b, t, d = delta.shape
            return jnp.zeros((vocab, d), jnp.float32).at[ids.reshape(-1)].add(
                delta.reshape(b * t, d)
            )
        (g,) = rec.tensors
        return jnp.sum(g, axis=0)


# ===========================================================================
# layer primitives (forward returns caches needed by the matching backward)
# ===========================================================================

def linear_fwd(x, w, b):
    """x [B,T,din] @ w [din,dout] + b."""
    return x @ w + b


def linear_bwd(tape: Tape, prefix: str, dy, x, w, b):
    """Record grads for w/b; return dx."""
    tape.linear(prefix + ".w", x, dy, w.shape)
    tape.bias(prefix + ".b", dy, b.shape)
    return dy @ w.T


def layernorm_fwd(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mu) * inv
    return xhat * g + b, (xhat, inv)


def layernorm_bwd(tape: Tape, prefix: str, dy, cache, g):
    xhat, inv = cache
    # per-example parameter grads are tiny vectors -> record directly
    tape.direct(prefix + ".g", jnp.sum(dy * xhat, axis=1))  # [B, D]
    tape.direct(prefix + ".b", jnp.sum(dy, axis=1))
    dxhat = dy * g
    d = xhat.shape[-1]
    dx = inv * (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    )
    return dx


def gelu_fwd(x):
    return jax.nn.gelu(x, approximate=True)


def gelu_bwd(dy, x):
    # derivative of tanh-approx gelu
    c = jnp.sqrt(2.0 / jnp.pi)
    u = c * (x + 0.044715 * x ** 3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * x ** 2)
    return dy * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t ** 2) * du)


def relu_fwd(x):
    return jnp.maximum(x, 0.0)


def relu_bwd(dy, x):
    return dy * (x > 0.0)


def softmax_bwd(dy, p):
    """Backward of p = softmax(s) along last axis."""
    return p * (dy - jnp.sum(dy * p, axis=-1, keepdims=True))


# ---------------------------------------------------------------------------
# multi-head causal self-attention (manual)
# ---------------------------------------------------------------------------

def attention_fwd(h, wqkv, bqkv, wo, bo, n_heads: int, causal: bool,
                  lora: dict | None = None):
    """h [B,T,D]. Returns (out, cache).

    If `lora` is given it holds {'qkv': (A,B,scale), 'o': (A,B,scale)} with
    A [din,r], B [r,dout]; effective weight = W + scale * A @ B and only
    A/B receive gradients (the frozen base is a constant on the tape).
    """
    b, t, d = h.shape
    hd = d // n_heads
    qkv = linear_fwd(h, wqkv, bqkv)
    lqkv_cache = None
    if lora is not None and "qkv" in lora:
        la, lb, scale = lora["qkv"]
        u = h @ la                     # [B,T,r]
        qkv = qkv + scale * (u @ lb)
        lqkv_cache = u
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(x):
        return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)  # [B,H,T,hd]

    qh, kh, vh = heads(q), heads(k), heads(v)
    scores = qh @ kh.transpose(0, 1, 3, 2) / jnp.sqrt(float(hd))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        scores = jnp.where(mask[None, None], scores, -1e9)
    p = jax.nn.softmax(scores, axis=-1)          # [B,H,T,T]
    oh = p @ vh                                   # [B,H,T,hd]
    o = oh.transpose(0, 2, 1, 3).reshape(b, t, d)
    out = linear_fwd(o, wo, bo)
    lo_cache = None
    if lora is not None and "o" in lora:
        la, lb, scale = lora["o"]
        u = o @ la
        out = out + scale * (u @ lb)
        lo_cache = u
    cache = (h, qkv, qh, kh, vh, p, o, lqkv_cache, lo_cache)
    return out, cache


def attention_bwd(tape: Tape, prefix: str, dy, cache, wqkv, bqkv, wo, bo,
                  n_heads: int, lora: dict | None = None,
                  train_base: bool = True):
    h, qkv, qh, kh, vh, p, o, lqkv_cache, lo_cache = cache
    b, t, d = h.shape
    hd = d // n_heads

    # --- output projection ---
    if lora is not None and "o" in lora:
        la, lb, scale = lora["o"]
        # y = o@wo + bo + scale*(o@la)@lb
        dv_lb = scale * dy                     # delta for lb with a = u
        tape.linear(prefix + ".o.lora_b", lo_cache, dv_lb, lb.shape)
        du = scale * (dy @ lb.T)               # [B,T,r]
        tape.linear(prefix + ".o.lora_a", o, du, la.shape)
        do = dy @ wo.T + du @ la.T
        if train_base:
            tape.linear(prefix + ".o.w", o, dy, wo.shape)
            tape.bias(prefix + ".o.b", dy, bo.shape)
    else:
        if train_base:
            do = linear_bwd(tape, prefix + ".o", dy, o, wo, bo)
        else:
            do = dy @ wo.T

    doh = do.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)   # [B,H,T,hd]
    dp = doh @ vh.transpose(0, 1, 3, 2)                          # [B,H,T,T]
    dvh = p.transpose(0, 1, 3, 2) @ doh
    ds = softmax_bwd(dp, p) / jnp.sqrt(float(hd))
    dqh = ds @ kh
    dkh = ds.transpose(0, 1, 3, 2) @ qh

    def unheads(x):
        return x.transpose(0, 2, 1, 3).reshape(b, t, d)

    dqkv = jnp.concatenate([unheads(dqh), unheads(dkh), unheads(dvh)], axis=-1)

    if lora is not None and "qkv" in lora:
        la, lb, scale = lora["qkv"]
        tape.linear(prefix + ".qkv.lora_b", lqkv_cache, scale * dqkv, lb.shape)
        du = scale * (dqkv @ lb.T)
        tape.linear(prefix + ".qkv.lora_a", h, du, la.shape)
        dh = dqkv @ wqkv.T + du @ la.T
        if train_base:
            tape.linear(prefix + ".qkv.w", h, dqkv, wqkv.shape)
            tape.bias(prefix + ".qkv.b", dqkv, bqkv.shape)
    else:
        if train_base:
            dh = linear_bwd(tape, prefix + ".qkv", dqkv, h, wqkv, bqkv)
        else:
            dh = dqkv @ wqkv.T
    return dh


# ---------------------------------------------------------------------------
# losses (per-example, so per-example gradients stay separable)
# ---------------------------------------------------------------------------

def lm_loss_fwd(logits, targets):
    """Mean-over-tokens cross entropy per example.

    logits [B,T,V], targets [B,T] -> (loss_per_example [B], dlogits-of-l_i).
    dlogits rows of example i are d l_i / d logits_i (unscaled by 1/B), so
    the resulting tape deltas give *per-example* gradients of l_i.
    """
    b, t, v = logits.shape
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]  # [B,T]
    loss_i = jnp.mean(nll, axis=-1)                                        # [B]
    probs = jnp.exp(logp)
    onehot = jax.nn.one_hot(targets, v, dtype=logits.dtype)
    dlogits = (probs - onehot) / float(t)
    return loss_i, dlogits


def ce_loss_fwd(logits, labels):
    """Classifier cross entropy. logits [B,C], labels [B]."""
    c = logits.shape[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss_i = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    dlogits = jnp.exp(logp) - jax.nn.one_hot(labels, c, dtype=logits.dtype)
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return loss_i, dlogits, correct
