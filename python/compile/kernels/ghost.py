"""L1 Pallas kernels: the paper's compute hot-spot.

Per-layer clipping fused into backprop needs, at each linear layer and for
each microbatch:

  1. ghost_norm(a, delta)          -> per-example grad norms^2      [B]
  2. clip_matmul(a, delta, coeff)  -> sum_i c_i a_i^T delta_i       [din,dout]

plus embedding-table variants. These are written as Pallas kernels with
`interpret=True` (the CPU PJRT plugin cannot execute Mosaic custom-calls;
see /opt/xla-example/README.md) so they lower into the same HLO module as
the surrounding L2 computation.

TPU mapping (DESIGN.md section Hardware-Adaptation): the grid iterates over
examples; each program keeps one example's A [T,din] and D [T,dout] tiles
in VMEM, forms the [T,T] Gram matrices on the MXU, and reduces on-chip --
the Grams never reach HBM. clip_matmul accumulates c_i * A_i^T D_i into an
output block across the batch grid dimension, which is the fused-epilogue
analog of the paper's CUDA implementation: the clip costs one scalar
multiply per tile, no extra HBM pass over gradients.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret=True is mandatory on this image (CPU PJRT); keep a single switch
# so a real-TPU build flips one flag.
INTERPRET = True


# ---------------------------------------------------------------------------
# ghost_norm
# ---------------------------------------------------------------------------

def _ghost_norm_kernel(a_ref, d_ref, o_ref):
    """One grid step = one example: sum((A A^T) * (D D^T))."""
    a = a_ref[0].astype(jnp.float32)      # [T, din]
    d = d_ref[0].astype(jnp.float32)      # [T, dout]
    gram_a = jnp.dot(a, a.T)              # [T, T] -- VMEM-resident
    gram_d = jnp.dot(d, d.T)              # [T, T]
    o_ref[0] = jnp.sum(gram_a * gram_d)


def ghost_norm(a: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Per-example squared Frobenius norm of the linear weight gradient.

    a [B,T,din], delta [B,T,dout] -> [B] float32, no [B,din,dout] buffer.
    """
    b, t, din = a.shape
    dout = delta.shape[-1]
    return pl.pallas_call(
        _ghost_norm_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, din), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dout), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=INTERPRET,
    )(a, delta)


# ---------------------------------------------------------------------------
# clip_matmul
# ---------------------------------------------------------------------------

def _clip_matmul_kernel(a_ref, d_ref, c_ref, o_ref):
    """Grid (B,): accumulate c_i * A_i^T D_i into the single output block."""
    i = pl.program_id(0)
    a = a_ref[0].astype(jnp.float32)      # [T, din]
    d = d_ref[0].astype(jnp.float32)      # [T, dout]
    c = c_ref[0].astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += c * jnp.dot(a.T, d)


def clip_matmul(a: jnp.ndarray, delta: jnp.ndarray, coeff: jnp.ndarray) -> jnp.ndarray:
    """Fused clip+reduce: sum_i coeff_i a_i^T delta_i -> [din, dout]."""
    b, t, din = a.shape
    dout = delta.shape[-1]
    return pl.pallas_call(
        _clip_matmul_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, din), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dout), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        # every grid step maps to the same output block -> accumulate
        out_specs=pl.BlockSpec((din, dout), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((din, dout), jnp.float32),
        interpret=INTERPRET,
    )(a, delta, coeff)


# ---------------------------------------------------------------------------
# embedding variants
# ---------------------------------------------------------------------------

def _embed_ghost_norm_kernel(ids_ref, d_ref, o_ref):
    ids = ids_ref[0]                       # [T] int32
    d = d_ref[0].astype(jnp.float32)       # [T, D]
    same = (ids[:, None] == ids[None, :]).astype(jnp.float32)  # [T,T]
    gram_d = jnp.dot(d, d.T)
    o_ref[0] = jnp.sum(same * gram_d)


def embed_ghost_norm(ids: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Per-example squared norm of the embedding-table gradient.

    ids [B,T] int32, delta [B,T,D] -> [B] float32. Token collisions within
    an example are handled by the id-equality mask on the Gram matrix.
    """
    b, t = ids.shape
    d = delta.shape[-1]
    return pl.pallas_call(
        _embed_ghost_norm_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=INTERPRET,
    )(ids, delta)


@functools.partial(jax.jit, static_argnames=("vocab",))
def clip_scatter_embed(
    ids: jnp.ndarray, delta: jnp.ndarray, coeff: jnp.ndarray, vocab: int
) -> jnp.ndarray:
    """Fused clip + scatter-add of embedding gradients -> [vocab, D].

    Scatter is not a good fit for a Pallas grid on the CPU interpreter (the
    per-row collision pattern is data-dependent); we keep it as a fused XLA
    segment-sum, which XLA lowers to a single scatter. The clip multiply is
    still fused in (no unclipped [vocab,D] intermediate per example).
    """
    b, t, d = delta.shape
    w = (coeff[:, None, None] * delta.astype(jnp.float32)).reshape(b * t, d)
    flat = ids.reshape(b * t)
    return jnp.zeros((vocab, d), jnp.float32).at[flat].add(w)
