"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the *specification*: every Pallas kernel in this package must
match its `ref_*` counterpart to float32 tolerance (enforced by
python/tests/test_kernels.py, including hypothesis shape/dtype sweeps).

Notation (matches the paper, section 3.1 and Li et al. 2022b section 4):
  a      [B, T, din]   layer input activations for a microbatch
  delta  [B, T, dout]  gradient of the loss w.r.t. the layer outputs
  g_i = a_i^T delta_i  per-example gradient of the linear weight [din, dout]

The whole point of the ghost trick is that ||g_i||_F^2 and sum_i c_i g_i are
computable without ever materializing the [B, din, dout] tensor.
"""
from __future__ import annotations

import jax.numpy as jnp


def ref_ghost_norm(a: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Per-example squared Frobenius norm of the linear-layer gradient.

    ||a_i^T delta_i||_F^2 = sum_{t,t'} (a_t . a_t') (d_t . d_t')
                          = sum( (A A^T) * (D D^T) )   per example.

    Returns [B] float32.
    """
    a = a.astype(jnp.float32)
    delta = delta.astype(jnp.float32)
    gram_a = jnp.einsum("bti,bsi->bts", a, a)
    gram_d = jnp.einsum("bto,bso->bts", delta, delta)
    return jnp.sum(gram_a * gram_d, axis=(1, 2))


def ref_ghost_norm_direct(a: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Same quantity by materializing per-example gradients (the thing the
    ghost trick avoids). Used only as an independent cross-check."""
    g = jnp.einsum("bti,bto->bio", a.astype(jnp.float32), delta.astype(jnp.float32))
    return jnp.sum(g * g, axis=(1, 2))


def ref_clip_matmul(a: jnp.ndarray, delta: jnp.ndarray, coeff: jnp.ndarray) -> jnp.ndarray:
    """Fused clip + reduce: sum_i c_i a_i^T delta_i  ->  [din, dout].

    `coeff` [B] are the per-example clip factors min(1, C_k/||g_k^(i)||).
    """
    a = a.astype(jnp.float32)
    delta = delta.astype(jnp.float32)
    return jnp.einsum("b,bti,bto->io", coeff.astype(jnp.float32), a, delta)


def ref_embed_ghost_norm(ids: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    """Per-example squared norm of an embedding-table gradient.

    The per-example gradient scatters delta_t into row ids_t; rows collide
    when the same token appears twice, so
      ||g_i||^2 = sum_{t,t'} 1[ids_t == ids_t'] (d_t . d_t').
    Returns [B] float32.
    """
    delta = delta.astype(jnp.float32)
    same = (ids[:, :, None] == ids[:, None, :]).astype(jnp.float32)  # [B,T,T]
    gram_d = jnp.einsum("bto,bso->bts", delta, delta)
    return jnp.sum(same * gram_d, axis=(1, 2))


def ref_clip_scatter_embed(
    ids: jnp.ndarray, delta: jnp.ndarray, coeff: jnp.ndarray, vocab: int
) -> jnp.ndarray:
    """Fused clip + scatter-add for embedding gradients: [vocab, D]."""
    delta = delta.astype(jnp.float32)
    onehot = (ids[..., None] == jnp.arange(vocab)[None, None, :]).astype(jnp.float32)
    return jnp.einsum("b,btv,btd->vd", coeff.astype(jnp.float32), onehot, delta)
