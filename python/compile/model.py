"""L2 models: manual-backprop networks whose backward pass exposes the
per-layer (a, delta) pairs that the paper's fused clipping consumes.

Three model families, matching the paper's experiment suite under the
DESIGN.md substitutions:

  * TransformerLM        -- decoder-only LM (GPT-2/GPT-3 analog), LoRA
                            option, partitionable into pipeline stages.
  * TransformerClassifier-- encoder + mean-pool head (RoBERTa analog).
  * ResMLP               -- residual MLP with layernorm (WRN16-4 analog).

A model is a plain namespace of functions; parameters travel as a list of
arrays in `param_specs` order (that order *is* the HLO parameter order the
rust runtime feeds).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from compile import layers
from compile.layers import Tape


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModelConfig:
    kind: str                      # "lm" | "classifier" | "resmlp"
    batch: int
    # transformer fields
    vocab: int = 0
    seq: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_layers: int = 0
    d_ff: int = 0
    n_classes: int = 0
    # resmlp fields
    features: int = 0
    width: int = 0
    blocks: int = 0
    # lora
    lora_rank: int = 0             # 0 = no lora
    lora_scale: float = 2.0
    train_base: bool = True        # False => only LoRA params trainable
    # kernel routing
    use_pallas: bool = False


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple
    group: str
    init: str          # "normal" | "zeros" | "ones" | "normal_small"
    trainable: bool = True


# ---------------------------------------------------------------------------
# parameter specs / init
# ---------------------------------------------------------------------------

def _transformer_specs(cfg: ModelConfig) -> list[ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    base = cfg.train_base
    sp: list[ParamSpec] = [
        ParamSpec("tok_emb", (cfg.vocab, d), "embed", "normal", base),
        ParamSpec("pos_emb", (cfg.seq, d), "embed", "normal", base),
    ]
    for i in range(cfg.n_layers):
        p = f"block{i}"
        sp += [
            ParamSpec(f"{p}.ln1.g", (d,), f"{p}.ln1", "ones", base),
            ParamSpec(f"{p}.ln1.b", (d,), f"{p}.ln1", "zeros", base),
            ParamSpec(f"{p}.qkv.w", (d, 3 * d), f"{p}.attn", "normal", base),
            ParamSpec(f"{p}.qkv.b", (3 * d,), f"{p}.attn", "zeros", base),
            ParamSpec(f"{p}.o.w", (d, d), f"{p}.attn", "normal_small", base),
            ParamSpec(f"{p}.o.b", (d,), f"{p}.attn", "zeros", base),
        ]
        if cfg.lora_rank > 0:
            r = cfg.lora_rank
            sp += [
                ParamSpec(f"{p}.qkv.lora_a", (d, r), f"{p}.attn", "normal", True),
                ParamSpec(f"{p}.qkv.lora_b", (r, 3 * d), f"{p}.attn", "zeros", True),
                ParamSpec(f"{p}.o.lora_a", (d, r), f"{p}.attn", "normal", True),
                ParamSpec(f"{p}.o.lora_b", (r, d), f"{p}.attn", "zeros", True),
            ]
        sp += [
            ParamSpec(f"{p}.ln2.g", (d,), f"{p}.ln2", "ones", base),
            ParamSpec(f"{p}.ln2.b", (d,), f"{p}.ln2", "zeros", base),
            ParamSpec(f"{p}.mlp1.w", (d, f), f"{p}.mlp", "normal", base),
            ParamSpec(f"{p}.mlp1.b", (f,), f"{p}.mlp", "zeros", base),
            ParamSpec(f"{p}.mlp2.w", (f, d), f"{p}.mlp", "normal_small", base),
            ParamSpec(f"{p}.mlp2.b", (d,), f"{p}.mlp", "zeros", base),
        ]
    sp += [
        ParamSpec("ln_f.g", (d,), "ln_f", "ones", base),
        ParamSpec("ln_f.b", (d,), "ln_f", "zeros", base),
    ]
    if cfg.kind == "lm":
        # LoRA fine-tuning trains the output head alongside the adapters
        # (standard practice; Hu et al. 2021 train task heads too).
        head_tr = base or cfg.lora_rank > 0
        sp += [
            ParamSpec("head.w", (d, cfg.vocab), "head", "normal", head_tr),
            ParamSpec("head.b", (cfg.vocab,), "head", "zeros", head_tr),
        ]
    else:
        sp += [
            ParamSpec("head.w", (d, cfg.n_classes), "head", "normal", True),
            ParamSpec("head.b", (cfg.n_classes,), "head", "zeros", True),
        ]
    return sp


def _resmlp_specs(cfg: ModelConfig) -> list[ParamSpec]:
    w = cfg.width
    sp = [
        ParamSpec("input.w", (cfg.features, w), "input", "normal", True),
        ParamSpec("input.b", (w,), "input", "zeros", True),
    ]
    for i in range(cfg.blocks):
        p = f"block{i}"
        sp += [
            ParamSpec(f"{p}.ln.g", (w,), p, "ones", True),
            ParamSpec(f"{p}.ln.b", (w,), p, "zeros", True),
            ParamSpec(f"{p}.fc1.w", (w, w), p, "normal", True),
            ParamSpec(f"{p}.fc1.b", (w,), p, "zeros", True),
            ParamSpec(f"{p}.fc2.w", (w, w), p, "normal_small", True),
            ParamSpec(f"{p}.fc2.b", (w,), p, "zeros", True),
        ]
    sp += [
        ParamSpec("ln_f.g", (w,), "ln_f", "ones", True),
        ParamSpec("ln_f.b", (w,), "ln_f", "zeros", True),
        ParamSpec("head.w", (w, cfg.n_classes), "head", "normal", True),
        ParamSpec("head.b", (cfg.n_classes,), "head", "zeros", True),
    ]
    return sp


def param_specs(cfg: ModelConfig) -> list[ParamSpec]:
    if cfg.kind == "resmlp":
        return _resmlp_specs(cfg)
    return _transformer_specs(cfg)


def group_names(cfg: ModelConfig) -> list[str]:
    out: list[str] = []
    for s in param_specs(cfg):
        if s.trainable and s.group not in out:
            out.append(s.group)
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> list[jnp.ndarray]:
    specs = param_specs(cfg)
    n_res = 2 * max(cfg.n_layers, cfg.blocks, 1)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(specs))
    out = []
    for k, s in zip(keys, specs):
        if s.init == "ones":
            out.append(jnp.ones(s.shape, jnp.float32))
        elif s.init == "zeros":
            out.append(jnp.zeros(s.shape, jnp.float32))
        else:
            std = 0.02 if s.init == "normal" else 0.02 / jnp.sqrt(float(n_res))
            out.append(std * jax.random.normal(k, s.shape, jnp.float32))
    return out


def as_dict(cfg: ModelConfig, plist) -> dict:
    return {s.name: p for s, p in zip(param_specs(cfg), plist)}


# ---------------------------------------------------------------------------
# transformer trunk: forward with caches + manual backward
# ---------------------------------------------------------------------------

def _lora_for(cfg: ModelConfig, p: dict, blk: str) -> Optional[dict]:
    if cfg.lora_rank == 0:
        return None
    return {
        "qkv": (p[f"{blk}.qkv.lora_a"], p[f"{blk}.qkv.lora_b"], cfg.lora_scale / cfg.lora_rank),
        "o": (p[f"{blk}.o.lora_a"], p[f"{blk}.o.lora_b"], cfg.lora_scale / cfg.lora_rank),
    }


def _block_fwd(cfg, p, blk, x, causal):
    a1, c_ln1 = layers.layernorm_fwd(x, p[f"{blk}.ln1.g"], p[f"{blk}.ln1.b"])
    att, c_att = layers.attention_fwd(
        a1, p[f"{blk}.qkv.w"], p[f"{blk}.qkv.b"], p[f"{blk}.o.w"], p[f"{blk}.o.b"],
        cfg.n_heads, causal, _lora_for(cfg, p, blk),
    )
    x1 = x + att
    a2, c_ln2 = layers.layernorm_fwd(x1, p[f"{blk}.ln2.g"], p[f"{blk}.ln2.b"])
    h1 = layers.linear_fwd(a2, p[f"{blk}.mlp1.w"], p[f"{blk}.mlp1.b"])
    h2 = layers.gelu_fwd(h1)
    m = layers.linear_fwd(h2, p[f"{blk}.mlp2.w"], p[f"{blk}.mlp2.b"])
    x2 = x1 + m
    return x2, (c_ln1, c_att, a1, c_ln2, a2, h1, h2, x1)


def _block_bwd(tape, cfg, p, blk, dy, cache):
    c_ln1, c_att, a1, c_ln2, a2, h1, h2, x1 = cache
    tb = cfg.train_base
    # mlp branch
    if tb:
        dh2 = layers.linear_bwd(tape, f"{blk}.mlp2", dy, h2, p[f"{blk}.mlp2.w"], p[f"{blk}.mlp2.b"])
    else:
        dh2 = dy @ p[f"{blk}.mlp2.w"].T
    dh1 = layers.gelu_bwd(dh2, h1)
    if tb:
        da2 = layers.linear_bwd(tape, f"{blk}.mlp1", dh1, a2, p[f"{blk}.mlp1.w"], p[f"{blk}.mlp1.b"])
        dx1 = dy + layers.layernorm_bwd(tape, f"{blk}.ln2", da2, c_ln2, p[f"{blk}.ln2.g"])
    else:
        da2 = dh1 @ p[f"{blk}.mlp1.w"].T
        dx1 = dy + _ln_bwd_nograd(da2, c_ln2, p[f"{blk}.ln2.g"])
    da1 = layers.attention_bwd(
        tape, blk, dx1, c_att, p[f"{blk}.qkv.w"], p[f"{blk}.qkv.b"],
        p[f"{blk}.o.w"], p[f"{blk}.o.b"], cfg.n_heads,
        _lora_for(cfg, p, blk), train_base=tb,
    )
    if tb:
        dx = dx1 + layers.layernorm_bwd(tape, f"{blk}.ln1", da1, c_ln1, p[f"{blk}.ln1.g"])
    else:
        dx = dx1 + _ln_bwd_nograd(da1, c_ln1, p[f"{blk}.ln1.g"])
    return dx


def _ln_bwd_nograd(dy, cache, g):
    xhat, inv = cache
    dxhat = dy * g
    return inv * (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    )


def _trunk_fwd(cfg, p, tokens, causal, lo: int = 0, hi: Optional[int] = None,
               embed: bool = True, x: Optional[jnp.ndarray] = None):
    """Run blocks [lo, hi) (whole trunk by default). `embed` controls the
    token/position embedding; pipeline stages > 0 take `x` directly."""
    hi = cfg.n_layers if hi is None else hi
    if embed:
        t = tokens.shape[1]
        x = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
    caches = []
    for i in range(lo, hi):
        x, c = _block_fwd(cfg, p, f"block{i}", x, causal)
        caches.append(c)
    return x, caches


def _trunk_bwd(tape, cfg, p, tokens, dy, caches, lo: int, hi: int, embed: bool):
    dx = dy
    for i in reversed(range(lo, hi)):
        dx = _block_bwd(tape, cfg, p, f"block{i}", dx, caches[i - lo])
    if embed and cfg.train_base:
        tape.embed("tok_emb", tokens, dx, cfg.vocab)
        tape.direct("pos_emb", dx)  # per-example grad for pos rows is dx itself
    return dx


# ---------------------------------------------------------------------------
# model heads: loss forward + full manual backward filling a Tape
# ---------------------------------------------------------------------------

def lm_forward_loss(cfg, plist, tokens, targets):
    """Pure forward (autodiff-able): per-example LM loss [B]."""
    p = as_dict(cfg, plist)
    x, _ = _trunk_fwd(cfg, p, tokens, causal=True)
    xf, _ = layers.layernorm_fwd(x, p["ln_f.g"], p["ln_f.b"])
    logits = layers.linear_fwd(xf, p["head.w"], p["head.b"])
    loss_i, _ = layers.lm_loss_fwd(logits, targets)
    return loss_i


def lm_backward(cfg, plist, tokens, targets) -> tuple[Tape, jnp.ndarray]:
    """One fused forward+backward; returns tape of per-layer (a, delta)
    records plus per-example losses. This is the paper's 'clipping in
    conjunction with backpropagation' code path."""
    p = as_dict(cfg, plist)
    tape = Tape(cfg.use_pallas)
    x, caches = _trunk_fwd(cfg, p, tokens, causal=True)
    xf, c_lnf = layers.layernorm_fwd(x, p["ln_f.g"], p["ln_f.b"])
    logits = layers.linear_fwd(xf, p["head.w"], p["head.b"])
    loss_i, dlogits = layers.lm_loss_fwd(logits, targets)
    head_tr = cfg.train_base or cfg.lora_rank > 0
    if head_tr:
        dxf = layers.linear_bwd(tape, "head", dlogits, xf, p["head.w"], p["head.b"])
    else:
        dxf = dlogits @ p["head.w"].T
    if cfg.train_base:
        dx = layers.layernorm_bwd(tape, "ln_f", dxf, c_lnf, p["ln_f.g"])
    else:
        dx = _ln_bwd_nograd(dxf, c_lnf, p["ln_f.g"])
    _trunk_bwd(tape, cfg, p, tokens, dx, caches, 0, cfg.n_layers, embed=True)
    return tape, loss_i


def classifier_forward_logits(cfg, plist, tokens):
    p = as_dict(cfg, plist)
    x, _ = _trunk_fwd(cfg, p, tokens, causal=False)
    xf, _ = layers.layernorm_fwd(x, p["ln_f.g"], p["ln_f.b"])
    pool = jnp.mean(xf, axis=1, keepdims=True)               # [B,1,D]
    return layers.linear_fwd(pool, p["head.w"], p["head.b"])[:, 0, :]


def classifier_forward_loss(cfg, plist, tokens, labels):
    logits = classifier_forward_logits(cfg, plist, tokens)
    loss_i, _, _ = layers.ce_loss_fwd(logits, labels)
    return loss_i


def classifier_backward(cfg, plist, tokens, labels):
    p = as_dict(cfg, plist)
    tape = Tape(cfg.use_pallas)
    x, caches = _trunk_fwd(cfg, p, tokens, causal=False)
    xf, c_lnf = layers.layernorm_fwd(x, p["ln_f.g"], p["ln_f.b"])
    pool = jnp.mean(xf, axis=1, keepdims=True)
    logits = layers.linear_fwd(pool, p["head.w"], p["head.b"])[:, 0, :]
    loss_i, dlogits, correct = layers.ce_loss_fwd(logits, labels)
    dpool = layers.linear_bwd(tape, "head", dlogits[:, None, :], pool,
                              p["head.w"], p["head.b"])       # [B,1,D]
    t = x.shape[1]
    dxf = jnp.broadcast_to(dpool / float(t), x.shape)
    if cfg.train_base:
        dx = layers.layernorm_bwd(tape, "ln_f", dxf, c_lnf, p["ln_f.g"])
    else:
        dx = _ln_bwd_nograd(dxf, c_lnf, p["ln_f.g"])
    _trunk_bwd(tape, cfg, p, tokens, dx, caches, 0, cfg.n_layers, embed=True)
    return tape, loss_i, correct


def resmlp_forward_logits(cfg, plist, feats):
    p = as_dict(cfg, plist)
    h = layers.linear_fwd(feats[:, None, :], p["input.w"], p["input.b"])  # [B,1,W]
    for i in range(cfg.blocks):
        blk = f"block{i}"
        a, _ = layers.layernorm_fwd(h, p[f"{blk}.ln.g"], p[f"{blk}.ln.b"])
        u = layers.relu_fwd(layers.linear_fwd(a, p[f"{blk}.fc1.w"], p[f"{blk}.fc1.b"]))
        h = h + layers.linear_fwd(u, p[f"{blk}.fc2.w"], p[f"{blk}.fc2.b"])
    hf, _ = layers.layernorm_fwd(h, p["ln_f.g"], p["ln_f.b"])
    return layers.linear_fwd(hf, p["head.w"], p["head.b"])[:, 0, :]


def resmlp_forward_loss(cfg, plist, feats, labels):
    logits = resmlp_forward_logits(cfg, plist, feats)
    loss_i, _, _ = layers.ce_loss_fwd(logits, labels)
    return loss_i


def resmlp_backward(cfg, plist, feats, labels):
    p = as_dict(cfg, plist)
    tape = Tape(cfg.use_pallas)
    h = layers.linear_fwd(feats[:, None, :], p["input.w"], p["input.b"])
    hs, caches = [feats[:, None, :]], []
    for i in range(cfg.blocks):
        blk = f"block{i}"
        a, c_ln = layers.layernorm_fwd(h, p[f"{blk}.ln.g"], p[f"{blk}.ln.b"])
        pre = layers.linear_fwd(a, p[f"{blk}.fc1.w"], p[f"{blk}.fc1.b"])
        u = layers.relu_fwd(pre)
        h = h + layers.linear_fwd(u, p[f"{blk}.fc2.w"], p[f"{blk}.fc2.b"])
        caches.append((c_ln, a, pre, u))
    hf, c_lnf = layers.layernorm_fwd(h, p["ln_f.g"], p["ln_f.b"])
    logits = layers.linear_fwd(hf, p["head.w"], p["head.b"])[:, 0, :]
    loss_i, dlogits, correct = layers.ce_loss_fwd(logits, labels)

    dhf = layers.linear_bwd(tape, "head", dlogits[:, None, :], hf,
                            p["head.w"], p["head.b"])
    dh = layers.layernorm_bwd(tape, "ln_f", dhf, c_lnf, p["ln_f.g"])
    for i in reversed(range(cfg.blocks)):
        blk = f"block{i}"
        c_ln, a, pre, u = caches[i]
        du = layers.linear_bwd(tape, f"{blk}.fc2", dh, u,
                               p[f"{blk}.fc2.w"], p[f"{blk}.fc2.b"])
        dpre = layers.relu_bwd(du, pre)
        da = layers.linear_bwd(tape, f"{blk}.fc1", dpre, a,
                               p[f"{blk}.fc1.w"], p[f"{blk}.fc1.b"])
        dh = dh + layers.layernorm_bwd(tape, f"{blk}.ln", da, c_ln, p[f"{blk}.ln.g"])
    layers.linear_bwd(tape, "input", dh, hs[0], p["input.w"], p["input.b"])
    return tape, loss_i, correct


# dispatch tables --------------------------------------------------------

def backward_fn(cfg: ModelConfig):
    if cfg.kind == "lm":
        return lambda pl, a, b: lm_backward(cfg, pl, a, b) + (None,)
    if cfg.kind == "classifier":
        return lambda pl, a, b: classifier_backward(cfg, pl, a, b)
    return lambda pl, a, b: resmlp_backward(cfg, pl, a, b)


def forward_loss_fn(cfg: ModelConfig):
    if cfg.kind == "lm":
        return lambda pl, a, b: lm_forward_loss(cfg, pl, a, b)
    if cfg.kind == "classifier":
        return lambda pl, a, b: classifier_forward_loss(cfg, pl, a, b)
    return lambda pl, a, b: resmlp_forward_loss(cfg, pl, a, b)
