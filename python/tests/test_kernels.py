"""Kernel-vs-reference correctness: the core L1 signal.

Every Pallas kernel must match its pure-jnp oracle. Hypothesis sweeps
shapes and dtypes; fixed-seed cases pin down exact regressions.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ghost, ref

jax.config.update("jax_platform_name", "cpu")

DTYPES = [jnp.float32, jnp.bfloat16]


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- ghost_norm
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,t,din,dout", [(4, 8, 16, 12), (1, 1, 1, 1), (3, 5, 7, 2)])
def test_ghost_norm_matches_ref(b, t, din, dout, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(b * 100 + t))
    a, d = rand(k1, (b, t, din), dtype), rand(k2, (b, t, dout), dtype)
    got = ghost.ghost_norm(a, d)
    want = ref.ref_ghost_norm(a, d)
    np.testing.assert_allclose(got, want, **tol(dtype))


def test_ghost_norm_equals_direct_materialization():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a, d = rand(k1, (6, 9, 11, ), jnp.float32).reshape(6, 9, 11), rand(k2, (6, 9, 5), jnp.float32)
    np.testing.assert_allclose(
        ref.ref_ghost_norm(a, d), ref.ref_ghost_norm_direct(a, d), rtol=1e-4
    )
    np.testing.assert_allclose(
        ghost.ghost_norm(a, d), ref.ref_ghost_norm_direct(a, d), rtol=1e-4
    )


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 6), t=st.integers(1, 10), din=st.integers(1, 24),
    dout=st.integers(1, 24), seed=st.integers(0, 2**16),
)
def test_ghost_norm_hypothesis(b, t, din, dout, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, d = rand(k1, (b, t, din), jnp.float32), rand(k2, (b, t, dout), jnp.float32)
    np.testing.assert_allclose(
        ghost.ghost_norm(a, d), ref.ref_ghost_norm(a, d), rtol=1e-4, atol=1e-4
    )


def test_ghost_norm_nonnegative():
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a, d = rand(k1, (8, 4, 6), jnp.float32), rand(k2, (8, 4, 3), jnp.float32)
    assert (np.asarray(ghost.ghost_norm(a, d)) >= -1e-6).all()


# -------------------------------------------------------------- clip_matmul
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,t,din,dout", [(4, 8, 16, 12), (1, 1, 1, 1), (5, 3, 2, 9)])
def test_clip_matmul_matches_ref(b, t, din, dout, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b + t), 3)
    a, d = rand(k1, (b, t, din), dtype), rand(k2, (b, t, dout), dtype)
    c = jax.random.uniform(k3, (b,), jnp.float32)
    got = ghost.clip_matmul(a, d, c)
    want = ref.ref_clip_matmul(a, d, c)
    np.testing.assert_allclose(got, want, **tol(dtype))


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 6), t=st.integers(1, 10), din=st.integers(1, 16),
    dout=st.integers(1, 16), seed=st.integers(0, 2**16),
)
def test_clip_matmul_hypothesis(b, t, din, dout, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, d = rand(k1, (b, t, din), jnp.float32), rand(k2, (b, t, dout), jnp.float32)
    c = jax.random.uniform(k3, (b,), jnp.float32)
    np.testing.assert_allclose(
        ghost.clip_matmul(a, d, c), ref.ref_clip_matmul(a, d, c), rtol=1e-4, atol=1e-4
    )


def test_clip_matmul_zero_coeff_gives_zero():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a, d = rand(k1, (4, 5, 6), jnp.float32), rand(k2, (4, 5, 3), jnp.float32)
    out = ghost.clip_matmul(a, d, jnp.zeros((4,)))
    np.testing.assert_allclose(out, np.zeros((6, 3)), atol=1e-7)


def test_clip_matmul_unit_coeff_is_plain_gradient():
    """coeff=1 must reproduce the standard summed gradient A^T D."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a, d = rand(k1, (4, 5, 6), jnp.float32), rand(k2, (4, 5, 3), jnp.float32)
    out = ghost.clip_matmul(a, d, jnp.ones((4,)))
    want = jnp.einsum("bti,bto->io", a, d)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- embedding ops
@pytest.mark.parametrize("b,t,d,v", [(4, 8, 6, 16), (2, 3, 4, 5), (1, 12, 8, 4)])
def test_embed_ghost_norm_matches_ref(b, t, d, v):
    k1, k2 = jax.random.split(jax.random.PRNGKey(b * t))
    ids = jax.random.randint(k1, (b, t), 0, v).astype(jnp.int32)
    delta = rand(k2, (b, t, d), jnp.float32)
    got = ghost.embed_ghost_norm(ids, delta)
    want = ref.ref_embed_ghost_norm(ids, delta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_embed_ghost_norm_collisions_counted():
    """Two occurrences of the same token must add their deltas, not their norms."""
    ids = jnp.array([[3, 3]], jnp.int32)
    delta = jnp.ones((1, 2, 4), jnp.float32)
    # grad row 3 = [2,2,2,2] -> norm^2 = 16 (not 4+4)
    np.testing.assert_allclose(ghost.embed_ghost_norm(ids, delta), [16.0], rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 4), t=st.integers(1, 8), d=st.integers(1, 8),
       v=st.integers(2, 12), seed=st.integers(0, 2**16))
def test_clip_scatter_embed_hypothesis(b, t, d, v, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    ids = jax.random.randint(k1, (b, t), 0, v).astype(jnp.int32)
    delta = rand(k2, (b, t, d), jnp.float32)
    c = jax.random.uniform(k3, (b,), jnp.float32)
    got = ghost.clip_scatter_embed(ids, delta, c, v)
    want = ref.ref_clip_scatter_embed(ids, delta, c, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------- end-to-end clip identity
def test_clipped_gradient_norm_respects_threshold():
    """After clipping with coeff = min(1, C/norm), every per-example
    contribution has norm <= C. Exercises ghost_norm + clip_matmul jointly
    (the invariant the DP guarantee rests on)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    a, d = rand(k1, (6, 4, 8), jnp.float32), rand(k2, (6, 4, 3), jnp.float32)
    c_thresh = 0.37
    norms = jnp.sqrt(ghost.ghost_norm(a, d))
    coeff = jnp.minimum(1.0, c_thresh / jnp.maximum(norms, 1e-12))
    for i in range(6):
        gi = ghost.clip_matmul(a[i:i + 1], d[i:i + 1], coeff[i:i + 1])
        assert float(jnp.linalg.norm(gi)) <= c_thresh * (1 + 1e-4)
