"""L2 correctness: manual backprop vs autodiff, and clipping-scheme
equivalences. These are the tests that license trusting the fused per-layer
path: the tape's summed gradients must equal jax.grad of the mean loss, and
flat == ghost == naive clipping must agree exactly (they compute the same
mathematical object three different ways)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import steps

jax.config.update("jax_platform_name", "cpu")


def lm_cfg(**kw):
    d = dict(kind="lm", batch=4, vocab=17, seq=6, d_model=8, n_heads=2,
             n_layers=2, d_ff=16, use_pallas=False)
    d.update(kw)
    return M.ModelConfig(**d)


def cls_cfg(**kw):
    d = dict(kind="classifier", batch=4, vocab=13, seq=5, d_model=8,
             n_heads=2, n_layers=2, d_ff=16, n_classes=3, use_pallas=False)
    d.update(kw)
    return M.ModelConfig(**d)


def mlp_cfg(**kw):
    d = dict(kind="resmlp", batch=5, features=7, width=12, blocks=2,
             n_classes=4, use_pallas=False)
    d.update(kw)
    return M.ModelConfig(**d)


def batch_for(cfg, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if cfg.kind == "resmlp":
        x = jax.random.normal(k1, (cfg.batch, cfg.features), jnp.float32)
        y = jax.random.randint(k2, (cfg.batch,), 0, cfg.n_classes).astype(jnp.int32)
    elif cfg.kind == "classifier":
        x = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab).astype(jnp.int32)
        y = jax.random.randint(k2, (cfg.batch,), 0, cfg.n_classes).astype(jnp.int32)
    else:
        x = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab).astype(jnp.int32)
        y = jax.random.randint(k2, (cfg.batch, cfg.seq), 0, cfg.vocab).astype(jnp.int32)
    return x, y


CFGS = [lm_cfg, cls_cfg, mlp_cfg]


# ------------------------------------------------------- tape vs autodiff
@pytest.mark.parametrize("mk", CFGS)
def test_manual_backward_matches_autodiff(mk):
    cfg = mk()
    params = M.init_params(cfg, seed=1)
    # perturb so layernorm gains etc. are not at init symmetry
    params = [p + 0.05 * jax.random.normal(jax.random.PRNGKey(i), p.shape)
              for i, p in enumerate(params)]
    x, y = batch_for(cfg)
    loss_fn = M.forward_loss_fn(cfg)
    want = jax.grad(lambda pl: jnp.mean(loss_fn(pl, x, y)))(params)

    step = steps.make_nonprivate_step(cfg)
    out = step(params, x, y)
    got = out[1:]
    specs = M.param_specs(cfg)
    assert len(got) == len([s for s in specs if s.trainable])
    for s, g, w in zip(specs, got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-3, atol=2e-5,
            err_msg=f"grad mismatch for {s.name}")


def test_lora_backward_matches_autodiff():
    cfg = lm_cfg(lora_rank=2, train_base=False)
    params = M.init_params(cfg, seed=2)
    # make lora_b nonzero so the adapter path carries signal both ways
    specs = M.param_specs(cfg)
    params = [
        p + 0.1 * jax.random.normal(jax.random.PRNGKey(i), p.shape)
        if "lora_b" in s.name else p
        for i, (s, p) in enumerate(zip(specs, params))
    ]
    x, y = batch_for(cfg)
    loss_fn = M.forward_loss_fn(cfg)
    all_grads = jax.grad(lambda pl: jnp.mean(loss_fn(pl, x, y)))(params)
    t_idx = [i for i, s in enumerate(specs) if s.trainable]
    # LoRA configs train the adapters + the LM head (Hu et al. 2021)
    assert all("lora" in specs[i].name or specs[i].name.startswith("head")
               for i in t_idx)

    out = steps.make_nonprivate_step(cfg)(params, x, y)
    for j, i in enumerate(t_idx):
        np.testing.assert_allclose(
            np.asarray(out[1 + j]), np.asarray(all_grads[i]),
            rtol=2e-3, atol=2e-5, err_msg=specs[i].name)


# --------------------------------------------- per-example norms are true
@pytest.mark.parametrize("mk", CFGS)
def test_group_norms_match_per_example_autodiff(mk):
    cfg = mk()
    params = M.init_params(cfg, seed=3)
    params = [p + 0.05 * jax.random.normal(jax.random.PRNGKey(i + 9), p.shape)
              for i, p in enumerate(params)]
    x, y = batch_for(cfg, seed=5)
    loss_fn = M.forward_loss_fn(cfg)

    def single(pl, xi, yi):
        return loss_fn(pl, xi[None], yi[None])[0]

    per_ex = jax.vmap(jax.grad(single), in_axes=(None, 0, 0))(params, x, y)
    specs = M.param_specs(cfg)
    groups = M.group_names(cfg)
    want = np.zeros((cfg.batch, len(groups)))
    for s, g in zip(specs, per_ex):
        k = groups.index(s.group)
        want[:, k] += np.sum(np.asarray(g) ** 2, axis=tuple(range(1, g.ndim)))
    want = np.sqrt(want)

    step = steps.make_dp_step_perlayer(cfg)
    out = step(params, x, y, jnp.full((len(groups),), 1e9), jnp.ones((cfg.batch,)))
    norms = np.asarray(out[-1])
    np.testing.assert_allclose(norms, want, rtol=2e-3, atol=1e-5)


# -------------------------------------- flat == ghost == naive equivalence
def test_flat_ghost_naive_agree():
    cfg = cls_cfg()
    params = M.init_params(cfg, seed=4)
    params = [p + 0.05 * jax.random.normal(jax.random.PRNGKey(i + 3), p.shape)
              for i, p in enumerate(params)]
    x, y = batch_for(cfg, seed=7)
    w = jnp.ones((cfg.batch,))
    c = jnp.asarray(0.05)  # small so clipping actually bites
    flat = steps.make_dp_step_flat(cfg)(params, x, y, c, w)
    ghost_ = steps.make_dp_step_ghost(cfg)(params, x, y, c, w)
    naive = steps.make_dp_step_naive(cfg)(params, x, y, c, w)
    # norms agree
    np.testing.assert_allclose(flat[-1], naive[-1], rtol=2e-3, atol=1e-6)
    np.testing.assert_allclose(flat[-1], ghost_[-1], rtol=1e-5)
    # some clipping occurred
    assert float(jnp.max(flat[-1])) > float(c)
    # grads agree pairwise
    for a, b_, n in zip(flat[1:-1], ghost_[1:-1], naive[1:-1]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=3e-3, atol=3e-6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(n), rtol=3e-3, atol=3e-6)


def test_perlayer_with_huge_thresholds_equals_unclipped():
    cfg = mlp_cfg()
    params = M.init_params(cfg, seed=5)
    x, y = batch_for(cfg, seed=8)
    groups = M.group_names(cfg)
    out = steps.make_dp_step_perlayer(cfg)(
        params, x, y, jnp.full((len(groups),), 1e9), jnp.ones((cfg.batch,)))
    plain = steps.make_nonprivate_step(cfg)(params, x, y)
    for a, b_ in zip(out[1:-1], plain[1:]):
        np.testing.assert_allclose(
            np.asarray(a) / cfg.batch, np.asarray(b_), rtol=1e-4, atol=1e-6)


def test_weights_zero_out_examples():
    """weight=0 examples must contribute nothing (Poisson padding)."""
    cfg = mlp_cfg()
    params = M.init_params(cfg, seed=6)
    x, y = batch_for(cfg, seed=9)
    groups = M.group_names(cfg)
    th = jnp.full((len(groups),), 0.1)
    w_full = jnp.ones((cfg.batch,))
    w_cut = w_full.at[-1].set(0.0)
    step = steps.make_dp_step_perlayer(cfg)
    out_cut = step(params, x, y, th, w_cut)

    # reference: run with batch minus last example, pad with a copy of ex 0
    x2 = jnp.concatenate([x[:-1], x[:1]], 0)
    y2 = jnp.concatenate([y[:-1], y[:1]], 0)
    out_ref = step(params, x2, y2, th, w_cut)
    for a, b_ in zip(out_cut[1:-1], out_ref[1:-1]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-6)


def test_clipped_update_norm_bounded():
    """DP invariant: the total clipped sum has norm <= sum_k C_k * B'."""
    cfg = cls_cfg()
    params = M.init_params(cfg, seed=7)
    x, y = batch_for(cfg, seed=11)
    groups = M.group_names(cfg)
    th = jnp.full((len(groups),), 0.02)
    out = steps.make_dp_step_perlayer(cfg)(params, x, y, th, jnp.ones((cfg.batch,)))
    specs = [s for s in M.param_specs(cfg) if s.trainable]
    gidx = {g: i for i, g in enumerate(groups)}
    per_group = np.zeros(len(groups))
    for s, g in zip(specs, out[1:-1]):
        per_group[gidx[s.group]] += float(jnp.sum(g * g))
    for k in range(len(groups)):
        assert np.sqrt(per_group[k]) <= cfg.batch * 0.02 * (1 + 1e-4)


# --------------------------------------------------- pipeline stage algebra
def test_pipeline_stages_compose_to_full_model():
    cfg = lm_cfg(n_layers=4)
    params = M.init_params(cfg, seed=8)
    params = [p + 0.05 * jax.random.normal(jax.random.PRNGKey(i + 1), p.shape)
              for i, p in enumerate(params)]
    x, y = batch_for(cfg, seed=12)
    bounds = [0, 2, 4]
    s0 = steps.stage_param_specs(cfg, bounds, 0)
    s1 = steps.stage_param_specs(cfg, bounds, 1)
    pd = M.as_dict(cfg, params)
    p0 = [pd[s.name] for s in s0]
    p1 = [pd[s.name] for s in s1]

    h = steps.make_stage_fwd(cfg, bounds, 0)(p0, x)[0]
    w = jnp.ones((cfg.batch,))
    loss, dx1, *rest = steps.make_stage_loss_bwd(cfg, bounds, 1, "perdevice")(
        p1, h, y, jnp.asarray(1e9), w)
    want = float(jnp.mean(M.lm_forward_loss(cfg, params, x, y)))
    assert abs(float(loss) - want) < 1e-4

    # chain bwd through stage 0 with huge threshold -> grads match nonprivate
    out0 = steps.make_stage_bwd(cfg, bounds, 0)(p0, x, dx1, jnp.asarray(1e9), w)
    grads0 = out0[1:-1]
    plain = steps.make_nonprivate_step(cfg)(params, x, y)
    specs = M.param_specs(cfg)
    plain_by_name = {s.name: g for s, g in zip(specs, plain[1:])}
    tr0 = [s for s in s0 if s.trainable]
    for s, g in zip(tr0, grads0):
        np.testing.assert_allclose(
            np.asarray(g) / cfg.batch, np.asarray(plain_by_name[s.name]),
            rtol=2e-3, atol=2e-5, err_msg=s.name)


def test_pipeline_norm_regrad_match_perdevice():
    cfg = lm_cfg(n_layers=2)
    params = M.init_params(cfg, seed=9)
    x, y = batch_for(cfg, seed=13)
    bounds = [0, 1, 2]
    pd = M.as_dict(cfg, params)
    s0 = steps.stage_param_specs(cfg, bounds, 0)
    s1 = steps.stage_param_specs(cfg, bounds, 1)
    p0 = [pd[s.name] for s in s0]
    p1 = [pd[s.name] for s in s1]
    w = jnp.ones((cfg.batch,))
    c = jnp.asarray(0.05)

    h = steps.make_stage_fwd(cfg, bounds, 0)(p0, x)[0]
    # per-device path
    loss, dx, *gn = steps.make_stage_loss_bwd(cfg, bounds, 1, "perdevice")(p1, h, y, c, w)
    grads_pd, norms_pd = gn[:-1], gn[-1]
    # norm+regrad path
    loss2, dx2, norms2 = steps.make_stage_loss_bwd(cfg, bounds, 1, "norm")(p1, h, y)
    np.testing.assert_allclose(np.asarray(norms_pd), np.asarray(norms2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx2), rtol=1e-5, atol=1e-7)
    coeff = jnp.minimum(1.0, c / jnp.maximum(norms2, 1e-12)) * w
    grads_rg = steps.make_stage_loss_bwd(cfg, bounds, 1, "regrad")(p1, h, y, coeff)
    for a, b_ in zip(grads_pd, grads_rg):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-7)


def test_pallas_and_jnp_paths_agree():
    """The use_pallas flag must not change any number."""
    cfg_a = mlp_cfg(use_pallas=True, batch=3, width=8, blocks=1)
    cfg_b = mlp_cfg(use_pallas=False, batch=3, width=8, blocks=1)
    params = M.init_params(cfg_a, seed=10)
    x, y = batch_for(cfg_a, seed=14)
    groups = M.group_names(cfg_a)
    th = jnp.full((len(groups),), 0.5)
    w = jnp.ones((3,))
    out_a = steps.make_dp_step_perlayer(cfg_a)(params, x, y, th, w)
    out_b = steps.make_dp_step_perlayer(cfg_b)(params, x, y, th, w)
    for a, b_ in zip(out_a, out_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-6)
