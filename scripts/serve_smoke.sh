#!/usr/bin/env bash
# Smoke test for the `gwclip serve` daemon: submit a session, let it
# step, request a snapshot, kill the daemon with SIGKILL, restart it on
# the same state dir and assert the resident session is re-registered.
#
# With AOT artifacts present (`make artifacts`) the script additionally
# asserts the hard contract: the resumed run finishes bitwise identical
# to an uninterrupted standalone `gwclip run` (same digest), the
# restarted daemon's event stream continues the step numbering instead
# of starting over, the finished run populates the session-labeled
# /metrics families and /phases breakdown, and `gwclip run --trace-out`
# writes loadable Chrome trace-event JSON. Without artifacts (CI) it
# degrades to the API/restart-resilience checks plus the artifact-free
# observability surface (/metrics parses, /phases serves the full phase
# taxonomy) — every session build fails loudly, but submit validation,
# sidecar persistence and kill -9 recovery are all still exercised for
# real.
#
# Honors GWCLIP_THREADS (CI runs this twice: unset and =4) and
# GWCLIP_BIN / GWCLIP_ARTIFACTS overrides.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

BIN="${GWCLIP_BIN:-}"
if [ -z "$BIN" ]; then
    for cand in "$ROOT/rust/target/release/gwclip" "$ROOT/rust/target/debug/gwclip"; do
        if [ -x "$cand" ]; then
            BIN="$cand"
            break
        fi
    done
fi
if [ -z "$BIN" ] || [ ! -x "$BIN" ]; then
    echo "serve_smoke: no gwclip binary (build with \`cargo build\` or set GWCLIP_BIN)" >&2
    exit 1
fi

export GWCLIP_ARTIFACTS="${GWCLIP_ARTIFACTS:-$ROOT/rust/artifacts}"
HAVE_ARTIFACTS=0
if [ -f "$GWCLIP_ARTIFACTS/manifest.json" ]; then
    HAVE_ARTIFACTS=1
fi

STATE="$(mktemp -d)"
DPID=""
cleanup() {
    if [ -n "$DPID" ]; then
        kill -9 "$DPID" 2>/dev/null || true
    fi
    rm -rf "$STATE"
}
trap cleanup EXIT

RESP="$STATE/resp.json"

fail() {
    echo "serve_smoke: FAIL: $*" >&2
    if [ -f "$STATE/daemon.log" ]; then
        tail -n 50 "$STATE/daemon.log" >&2
    fi
    exit 1
}

# http METHOD PATH [BODY] -> prints the status code; body lands in $RESP
http() {
    local method=$1 path=$2 body=${3:-}
    if [ -n "$body" ]; then
        curl -s -o "$RESP" -w '%{http_code}' -X "$method" \
            --data-binary "$body" "http://$HOSTPORT$path"
    else
        curl -s -o "$RESP" -w '%{http_code}' -X "$method" "http://$HOSTPORT$path"
    fi
}

expect() { # expect WANT_CODE METHOD PATH [BODY]
    local want=$1 got
    shift
    got=$(http "$@") || fail "curl error on $1 $2"
    if [ "$got" != "$want" ]; then
        fail "$1 $2: expected HTTP $want, got $got: $(cat "$RESP")"
    fi
}

json_field() { # json_field FIELD [FILE] -> value or empty
    python3 -c '
import json, sys
v = json.load(open(sys.argv[2])).get(sys.argv[1])
print("" if v is None else v)' "$1" "${2:-$RESP}"
}

start_daemon() {
    # the previous incarnation's addr file must not be mistaken for the
    # new port
    rm -f "$STATE/addr"
    "$BIN" serve --addr 127.0.0.1:0 --state-dir "$STATE" --snapshot-every 1 \
        >"$STATE/daemon.log" 2>&1 &
    DPID=$!
    local t=0
    until [ -s "$STATE/addr" ]; do
        kill -0 "$DPID" 2>/dev/null || fail "daemon exited during startup"
        t=$((t + 1))
        if [ "$t" -gt 100 ]; then
            fail "daemon never published $STATE/addr"
        fi
        sleep 0.2
    done
    HOSTPORT="$(cat "$STATE/addr")"
}

await_phase() { # await_phase NAME WANT_PHASE [FORBIDDEN_PHASE]
    local name=$1 want=$2 forbid=${3:-} got t=0
    while :; do
        expect 200 GET "/sessions/$name"
        got=$(json_field phase)
        if [ "$got" = "$want" ]; then
            return 0
        fi
        if [ -n "$forbid" ] && [ "$got" = "$forbid" ]; then
            fail "session $name hit phase $forbid: $(cat "$RESP")"
        fi
        t=$((t + 1))
        if [ "$t" -gt 1500 ]; then
            fail "timed out waiting for $name -> $want (at $got)"
        fi
        sleep 0.2
    done
}

SPEC_FILE="$STATE/spec.toml"
cat >"$SPEC_FILE" <<'EOF'
config = "resmlp_tiny"
epochs = 5.0
seed = 909

[privacy]
epsilon = 8.0

[clip]
group_by = "per-layer"
mode = "adaptive"
target_q = 0.6

[data]
task = "mixture"
n_data = 64
EOF
SUBMIT_BODY=$(python3 -c '
import json, sys
print(json.dumps({"name": "smoke", "spec": open(sys.argv[1]).read(),
                  "snapshot_every": 1}))' "$SPEC_FILE")

if [ "$HAVE_ARTIFACTS" = 1 ]; then
    echo "serve_smoke: binary $BIN (artifacts: yes)"
else
    echo "serve_smoke: binary $BIN (artifacts: no — API/restart checks only)"
fi
start_daemon

# --- API surface -----------------------------------------------------------
expect 200 GET /healthz
grep -q '"ok":true' "$RESP" || fail "healthz body: $(cat "$RESP")"
expect 404 GET /nope
expect 404 GET /sessions/ghost
expect 400 POST /sessions 'not json'
expect 400 POST /sessions '{"name":"bad/name","spec":"config = \"resmlp_tiny\""}'
expect 201 POST /sessions "$SUBMIT_BODY"
expect 409 POST /sessions "$SUBMIT_BODY"
if [ ! -f "$STATE/smoke/serve.json" ]; then
    fail "submit left no sidecar in $STATE/smoke"
fi
expect 202 POST /sessions/smoke/snapshot

# --- observability surface -------------------------------------------------
# /metrics must always serve a well-formed Prometheus text exposition —
# the daemon-level gwclip_sessions gauge exists even before any session
# has stepped, so this half of the check is artifact-free
expect 200 GET /metrics
python3 - "$RESP" <<'PY' || fail "/metrics exposition malformed"
import sys
text = open(sys.argv[1]).read()
helps = [l.split()[2] for l in text.splitlines() if l.startswith("# HELP ")]
assert len(helps) == len(set(helps)), "duplicate HELP lines: %r" % sorted(helps)
assert "gwclip_sessions" in helps, "missing gwclip_sessions family:\n" + text
for l in text.splitlines():
    if not l or l.startswith("#"):
        continue
    float(l.rpartition(" ")[2])  # every sample line must end in a number
PY
echo "serve_smoke: /metrics exposition parses"

expect 404 GET /sessions/ghost/phases
expect 200 GET /sessions/smoke/phases
python3 - "$RESP" <<'PY' || fail "/phases breakdown malformed"
import json, sys
j = json.load(open(sys.argv[1]))
want = {"deal", "collect", "noise", "merge", "normalize", "apply", "quantile"}
assert set(j["phase_secs"]) == want, j
assert "collect_busy_ratio" in j, j
PY
echo "serve_smoke: /sessions/N/phases reports the full phase taxonomy"

# --- kill -9 the daemon mid-run, restart on the same state dir -------------
if [ "$HAVE_ARTIFACTS" = 1 ]; then
    # let a few steps land so SIGKILL strikes mid-run with snapshots on
    # disk (snapshot-every=1 -> one per step)
    t=0
    while :; do
        expect 200 GET /sessions/smoke
        if [ "$(json_field phase)" = "failed" ]; then
            fail "session failed: $(cat "$RESP")"
        fi
        step=$(json_field step)
        if [ "${step:-0}" -ge 3 ]; then
            break
        fi
        t=$((t + 1))
        if [ "$t" -gt 1500 ]; then
            fail "session never reached step 3: $(cat "$RESP")"
        fi
        sleep 0.2
    done
    KILL_STEP=$step
else
    # no artifacts: the runner fails loudly, but registration + sidecar
    # survive — that is the path under test here
    await_phase smoke failed
    json_field detail | grep -qi artifacts || fail "failure detail: $(cat "$RESP")"
fi

kill -9 "$DPID"
wait "$DPID" 2>/dev/null || true
start_daemon
expect 200 GET /sessions/smoke
echo "serve_smoke: resident session re-registered after kill -9"

# --- bitwise resume parity (artifacts only) --------------------------------
if [ "$HAVE_ARTIFACTS" = 1 ]; then
    await_phase smoke done failed
    DAEMON_DIGEST=$(python3 -c '
import json, sys
j = json.load(open(sys.argv[1]))
print(json.dumps(j["digest"], sort_keys=True, separators=(",", ":")))' "$RESP")

    "$BIN" run --spec "$SPEC_FILE" --digest >"$STATE/standalone.log" 2>&1 ||
        fail "standalone reference run: $(tail -n 20 "$STATE/standalone.log")"
    REF_DIGEST=$(sed -n 's/^digest: //p' "$STATE/standalone.log" | python3 -c '
import json, sys
print(json.dumps(json.load(sys.stdin), sort_keys=True, separators=(",", ":")))')
    if [ "$DAEMON_DIGEST" != "$REF_DIGEST" ]; then
        fail "digest mismatch after kill -9 resume:
  daemon:     $DAEMON_DIGEST
  standalone: $REF_DIGEST"
    fi

    # event numbering must continue where the last snapshot left off,
    # not restart from step 1
    FIRST=$(curl -s "http://$HOSTPORT/sessions/smoke/events?wait=0" | python3 -c '
import json, sys
for line in sys.stdin:
    line = line.strip()
    if not line:
        continue
    try:
        j = json.loads(line)
    except ValueError:
        continue
    if "step" in j:
        print(j["step"])
        break')
    if [ -z "$FIRST" ]; then
        fail "restarted daemon streamed no step events"
    fi
    if [ "$FIRST" -lt 2 ] || [ "$FIRST" -gt $((KILL_STEP + 1)) ]; then
        fail "resumed stream starts at step $FIRST (killed at step $KILL_STEP)"
    fi
    echo "serve_smoke: resumed at step $FIRST after kill at step $KILL_STEP; digests match"

    # the finished run must have populated the session-labeled metric
    # families (counters, the phase split, latency histograms, eps)
    expect 200 GET /metrics
    python3 - "$RESP" <<'PY' || fail "finished run left /metrics unpopulated"
import sys
text = open(sys.argv[1]).read()
for fam in ("gwclip_steps_total", "gwclip_phase_seconds_total",
            "gwclip_step_seconds_count", "gwclip_eps_spent"):
    assert fam + '{session="smoke"' in text, "missing %s:\n%s" % (fam, text)
PY
    expect 200 GET /sessions/smoke/phases
    python3 - "$RESP" <<'PY' || fail "finished run left /phases empty"
import json, sys
j = json.load(open(sys.argv[1]))
assert j["steps"] > 0 and j["total_secs"] > 0, j
PY
    echo "serve_smoke: metric families + phase breakdown populated"

    # --- Chrome trace export smoke -----------------------------------------
    "$BIN" run --spec "$SPEC_FILE" --trace-out "$STATE/trace.json" \
        >"$STATE/trace.log" 2>&1 ||
        fail "traced run: $(tail -n 20 "$STATE/trace.log")"
    python3 - "$STATE/trace.json" <<'PY' || fail "trace.json shape wrong"
import json, sys
j = json.load(open(sys.argv[1]))
assert j["displayTimeUnit"] == "ms", sorted(j)
ev = j["traceEvents"]
assert ev, "empty traceEvents"
assert any(e.get("ph") == "X" and e.get("name") == "noise" for e in ev), \
    "no noise-phase span in %d events" % len(ev)
PY
    echo "serve_smoke: Chrome trace export OK"
fi

expect 200 POST /shutdown
wait "$DPID" 2>/dev/null || true
DPID=""
echo "serve_smoke: OK"
