//! Generation experiments: Table 5 (E2E/DART analog with the GPT-2-analog
//! LM) and greedy decoding + BLEU/ROUGE-L scoring shared with Table 6.

use anyhow::Result;

use crate::coordinator::trainer::Method;
use crate::data::lm::TableToTextCorpus;
use crate::metrics::bleu::{corpus_bleu, rouge_l};
use crate::metrics::{fmt_f, MdTable};
use crate::runtime::{Exec, HostValue, IntTensor, Runtime, Tensor};

use super::harness::{session_for, Scale};
use super::tables::text_spec;

/// Greedy-decode continuations with a full-sequence `logits` entry.
/// `prefixes` are ragged; each is completed to `seq` tokens. Returns the
/// generated suffixes (excluding the prefix).
pub fn greedy_decode(
    exec: &Exec,
    params: &[Tensor],
    prefixes: &[Vec<i32>],
    batch: usize,
    seq: usize,
) -> Result<Vec<Vec<i32>>> {
    let mut out = Vec::with_capacity(prefixes.len());
    for chunk in prefixes.chunks(batch) {
        // working buffer [batch, seq]
        let mut toks = vec![0i32; batch * seq];
        let mut cur: Vec<usize> = Vec::with_capacity(chunk.len());
        for (i, p) in chunk.iter().enumerate() {
            let l = p.len().min(seq);
            toks[i * seq..i * seq + l].copy_from_slice(&p[..l]);
            cur.push(l);
        }
        let max_cur = seq;
        while cur.iter().any(|&c| c < max_cur) {
            let x = IntTensor::from_vec(&[batch, seq], toks.clone())?;
            let outs = exec.call(params, &[HostValue::I32(x)])?;
            let logits = &outs[0]; // [B, T, V]
            let v = logits.shape[2];
            for (i, c) in cur.iter_mut().enumerate() {
                if *c >= max_cur || i >= chunk.len() {
                    continue;
                }
                let off = (i * seq + (*c - 1)) * v;
                let row = &logits.data[off..off + v];
                let mut best = 0usize;
                for (j, &val) in row.iter().enumerate() {
                    if val > row[best] {
                        best = j;
                    }
                }
                toks[i * seq + *c] = best as i32;
                *c += 1;
            }
        }
        for (i, p) in chunk.iter().enumerate() {
            out.push(toks[i * seq + p.len()..(i + 1) * seq].to_vec());
        }
    }
    Ok(out)
}

/// Decode + score a fine-tuned LM on the table-to-text eval set.
fn score_generation(
    rt: &Runtime,
    config: &str,
    params: &[Tensor],
    eval: &TableToTextCorpus,
    n_eval: usize,
) -> Result<(f64, f64)> {
    let cfg = rt.manifest.config(config)?;
    let exec = rt.load(config, "logits")?;
    let prefixes: Vec<Vec<i32>> = (0..n_eval).map(|i| eval.prefix(i).to_vec()).collect();
    let hyps = greedy_decode(&exec, params, &prefixes, cfg.batch, cfg.hyper.seq)?;
    let refs: Vec<Vec<i32>> = (0..n_eval)
        .map(|i| {
            let r = eval.reference_suffix(i);
            r[..r.len().min(cfg.hyper.seq - eval.prefix_len)].to_vec()
        })
        .collect();
    Ok((100.0 * corpus_bleu(&hyps, &refs, 4), 100.0 * rouge_l(&hyps, &refs)))
}

/// Table 5: adaptive per-layer vs flat on the E2E/DART analog (full
/// fine-tuning of the GPT-2-analog LM), BLEU / ROUGE-L / NLL.
pub fn table5(rt: &Runtime, scale: Scale) -> Result<()> {
    let config = "lm_small";
    let cfg = rt.manifest.config(config)?.clone();
    let n = scale.data / 2;
    let train = TableToTextCorpus::new(n, cfg.hyper.seq, cfg.hyper.vocab, 3, 0);
    let eval = TableToTextCorpus::new(160, cfg.hyper.seq, cfg.hyper.vocab, 3, 999);
    let n_eval = 64.min(eval.len());

    let mut t = MdTable::new(&["DP guarantee", "Method", "eval NLL", "BLEU", "ROUGE-L"]);
    let runs: Vec<(String, Method, f64)> = vec![
        ("eps = 3".into(), Method::PerLayerAdaptive, 3.0),
        ("eps = 3".into(), Method::FlatFixed, 3.0),
        ("eps = 8".into(), Method::PerLayerAdaptive, 8.0),
        ("eps = 8".into(), Method::FlatFixed, 8.0),
        ("non-private".into(), Method::NonPrivate, 0.0),
    ];
    let pre = super::pipexp::pretrain_base(rt, config, 2.0)?;
    for (label, method, eps) in runs {
        let mut spec = text_spec(method, eps.max(1.0), scale.epochs, 0);
        spec.config = config.to_string();
        spec.optim.lr = 2e-3;
        spec.clip.clip_init = 0.1;
        spec.clip.target_q = 0.5;
        if method == Method::NonPrivate {
            spec.optim.lr = 1e-3;
        }
        let mut sess = session_for(rt, spec, train.len())?;
        sess.load_param_map(&pre)?;
        sess.run(&train, 0)?;
        let (nll, _) = sess.evaluate(&eval)?;
        let (bleu, rl) = score_generation(rt, config, sess.params()?, &eval, n_eval)?;
        t.row(&[
            label.clone(),
            method.name().to_string(),
            fmt_f(nll, 3),
            fmt_f(bleu, 1),
            fmt_f(rl, 1),
        ]);
        eprintln!("[table5] {label} {} nll {:.3} bleu {:.1} rouge {:.1}", method.name(), nll, bleu, rl);
    }
    t.save(
        "results/table5.md",
        "Table 5: E2E/DART analog — adaptive per-layer matches flat clipping at equal epochs",
    )?;
    println!("{}", t.render());
    Ok(())
}
