//! Shared helpers for the table/figure runners.

use anyhow::Result;

use crate::coordinator::{Method, TrainOpts, Trainer};
use crate::data::Dataset;
use crate::runtime::Runtime;

/// Scale knob: default configs are CPU-budget sized; `--paper-scale`
/// raises epochs / dataset sizes toward the paper's.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub data: usize,
    pub epochs: f64,
    pub seeds: usize,
}

impl Scale {
    pub fn quick() -> Self {
        Scale { data: 2048, epochs: 3.0, seeds: 1 }
    }

    pub fn paper() -> Self {
        Scale { data: 8192, epochs: 10.0, seeds: 3 }
    }
}

/// Train with `opts` on `data`, return (final train-ema loss, eval acc).
pub fn train_eval(
    rt: &Runtime,
    config: &str,
    data: &dyn Dataset,
    eval_data: &dyn Dataset,
    opts: TrainOpts,
) -> Result<(f64, f64)> {
    let mut tr = Trainer::new(rt, config, data.len(), opts)?;
    let hist = tr.run(data, 0)?;
    let tail = hist.iter().rev().take(20).map(|s| s.loss).sum::<f64>()
        / hist.len().min(20).max(1) as f64;
    let (_, acc) = tr.evaluate(eval_data)?;
    Ok((tail, acc))
}

/// Mean and std over seeds of a per-seed experiment.
pub fn over_seeds<F: FnMut(u64) -> Result<f64>>(seeds: usize, mut f: F) -> Result<(f64, f64)> {
    let mut vals = Vec::with_capacity(seeds);
    for s in 0..seeds {
        vals.push(f(s as u64)?);
    }
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
    Ok((mean, var.sqrt()))
}

/// Convenience: default TrainOpts for a method at a given epsilon.
pub fn opts_for(method: Method, epsilon: f64, epochs: f64, seed: u64) -> TrainOpts {
    TrainOpts { method, epsilon, epochs, seed, ..Default::default() }
}
