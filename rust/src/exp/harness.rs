//! Shared helpers for the table/figure runners — all built on the session
//! API so every experiment constructs (and collects `StepEvent`s) through
//! the same path as the CLI.

use anyhow::Result;

use crate::runtime::Runtime;
use crate::session::{RunSpec, Session, SessionBuilder};

/// Scale knob: default configs are CPU-budget sized; `--paper-scale`
/// raises epochs / dataset sizes toward the paper's.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub data: usize,
    pub epochs: f64,
    pub seeds: usize,
}

impl Scale {
    pub fn quick() -> Self {
        Scale { data: 2048, epochs: 3.0, seeds: 1 }
    }

    pub fn paper() -> Self {
        Scale { data: 8192, epochs: 10.0, seeds: 3 }
    }
}

/// Build a session for `spec` against a caller-owned dataset size.
pub fn session_for<'r>(rt: &'r Runtime, spec: RunSpec, n_data: usize) -> Result<Session<'r>> {
    SessionBuilder::from_spec(rt, spec).build(n_data)
}

/// Mean and std over seeds of a per-seed experiment.
pub fn over_seeds<F: FnMut(u64) -> Result<f64>>(seeds: usize, mut f: F) -> Result<(f64, f64)> {
    let mut vals = Vec::with_capacity(seeds);
    for s in 0..seeds {
        vals.push(f(s as u64)?);
    }
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
    Ok((mean, var.sqrt()))
}
