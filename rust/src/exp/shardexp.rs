//! Sharding experiments: the data-parallel scaling table. How does the
//! simulated step latency evolve with the worker count, and how much of
//! the all-reduce does the overlapped tree-reduction hide relative to the
//! barrier baseline — while the privacy plan stays *fixed* (one release
//! per step at q = E[B]/n, independent of N)?

use anyhow::Result;

use crate::data::classif::MixtureImages;
use crate::data::Dataset;
use crate::metrics::{fmt_f, MdTable};
use crate::runtime::Runtime;
use crate::session::{
    ClipMode, ClipPolicy, CompressKind, CompressSpec, GroupBy, OptimSpec, PrivacySpec, RunSpec,
    SessionBuilder, ShardSpec,
};

use super::harness::Scale;

/// Sharding scaling table over N in {1, 2, 4, 8}: per-device clipping on
/// the CIFAR-analog config, fixed (eps, delta), reporting tree rounds,
/// overlapped vs barrier simulated step latency, and the accountant's
/// sigma (which must not move with N).
pub fn shard_scaling(rt: &Runtime, scale: Scale) -> Result<()> {
    let data = MixtureImages::new(scale.data, 64, 10, 3);
    let steps = if scale.seeds > 1 { 5 } else { 3 };
    let mut t = MdTable::new(&[
        "workers",
        "tree rounds",
        "sim overlap (s)",
        "sim barrier (s)",
        "reduction hidden",
        "host step (s)",
        "sigma_grad",
        "q",
    ]);
    // Pin E[B] to one value divisible by every tested worker count (and
    // within the N=1 static capacity, resmlp's batch of 256): the plan —
    // q = E[B]/n, step count, sigma — is then literally identical across
    // rows, which is the point of the table.
    let expected_batch = 200usize;
    for workers in [1usize, 2, 4, 8] {
        let mut spec = RunSpec::for_config("resmlp");
        spec.clip = ClipPolicy {
            clip_init: 1.0,
            ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed)
        };
        spec.privacy = PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.0 };
        spec.optim = OptimSpec::sgd(0.25);
        spec.epochs = 1.0;
        spec.expected_batch = expected_batch;
        spec.shard = Some(ShardSpec::with_workers(workers));
        let mut sess = SessionBuilder::from_spec(rt, spec).build(data.len())?;
        let plan = sess.plan().expect("private sharded run must carry a plan");
        // warmup (first PJRT call pays compilation)
        sess.step(&data)?;
        let (mut ov, mut ba, mut host, mut rounds) = (0.0, 0.0, 0.0, 0usize);
        for _ in 0..steps {
            let st = sess.step(&data)?;
            ov += st.sim_overlap_secs;
            ba += st.sim_barrier_secs;
            host += st.host_secs;
            rounds = st.syncs;
        }
        let (ov, ba, host) = (ov / steps as f64, ba / steps as f64, host / steps as f64);
        let hidden = if ba > 0.0 { 1.0 - ov / ba } else { 0.0 };
        t.row(&[
            format!("{workers}"),
            format!("{rounds}"),
            fmt_f(ov, 4),
            fmt_f(ba, 4),
            format!("{:.0}%", 100.0 * hidden),
            fmt_f(host, 4),
            fmt_f(plan.sigma_grad, 3),
            fmt_f(plan.q, 4),
        ]);
        eprintln!(
            "[shard] N={workers} sim overlap {ov:.4}s barrier {ba:.4}s \
             ({:.0}% hidden) host {host:.4}s",
            100.0 * hidden
        );
    }
    t.save(
        "results/shard_scaling.md",
        "Sharded data-parallel scaling: overlapped tree-reduction hides the all-reduce; \
         the privacy plan is invariant in the worker count",
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Compression scaling table: error-feedback top-k sparsification on the
/// reduction path at N = 4 and 8 workers, sweeping the keep ratio. The
/// simulated reduction cost shrinks with the ratio (compression acts on
/// already-noised shares, so the privacy plan — printed per row — is
/// literally identical down the column), while the final eval loss shows
/// the utility cost of sparsification (error feedback keeps it small).
pub fn compress_scaling(rt: &Runtime, scale: Scale) -> Result<()> {
    let data = MixtureImages::new(scale.data, 64, 10, 3);
    let eval = MixtureImages::new(scale.data / 4, 64, 10, 777);
    let steps = if scale.seeds > 1 { 6 } else { 3 };
    let mut t = MdTable::new(&[
        "workers",
        "compress",
        "sim overlap (s)",
        "sim barrier (s)",
        "vs dense overlap",
        "eval loss",
        "sigma_grad",
        "q",
    ]);
    let expected_batch = 200usize;
    for workers in [4usize, 8] {
        let mut dense_overlap = 0.0f64;
        for ratio in [1.0f64, 0.5, 0.25, 0.1] {
            let mut spec = RunSpec::for_config("resmlp");
            spec.clip = ClipPolicy {
                clip_init: 1.0,
                ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed)
            };
            spec.privacy = PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.0 };
            spec.optim = OptimSpec::sgd(0.25);
            spec.epochs = 1.0;
            spec.expected_batch = expected_batch;
            spec.shard = Some(ShardSpec::with_workers(workers));
            let label = if ratio >= 1.0 {
                "dense".to_string()
            } else {
                spec.compress = Some(CompressSpec {
                    kind: CompressKind::TopK,
                    ratio,
                    error_feedback: true,
                });
                format!("topk {:.0}%+ef", 100.0 * ratio)
            };
            let mut sess = SessionBuilder::from_spec(rt, spec).build(data.len())?;
            let plan = sess.plan().expect("private compressed run must carry a plan");
            // warmup (first PJRT call pays compilation)
            sess.step(&data)?;
            let (mut ov, mut ba) = (0.0, 0.0);
            for _ in 0..steps {
                let st = sess.step(&data)?;
                ov += st.sim_overlap_secs;
                ba += st.sim_barrier_secs;
            }
            let (ov, ba) = (ov / steps as f64, ba / steps as f64);
            if ratio >= 1.0 {
                dense_overlap = ov;
            }
            let (loss, _) = sess.evaluate(&eval)?;
            t.row(&[
                format!("{workers}"),
                label.clone(),
                fmt_f(ov, 4),
                fmt_f(ba, 4),
                format!("{:.2}x", if dense_overlap > 0.0 { ov / dense_overlap } else { 1.0 }),
                fmt_f(loss, 4),
                fmt_f(plan.sigma_grad, 3),
                fmt_f(plan.q, 4),
            ]);
            eprintln!(
                "[compress] N={workers} {label} sim overlap {ov:.4}s barrier {ba:.4}s \
                 eval loss {loss:.4}"
            );
        }
    }
    t.save(
        "results/compress_scaling.md",
        "Gradient compression on the reduction path: error-feedback top-k shrinks the \
         simulated all-reduce (post-noise, so the privacy plan is ratio-invariant); eval \
         loss tracks the utility cost",
    )?;
    println!("{}", t.render());
    Ok(())
}
