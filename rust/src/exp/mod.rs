//! Experiment harness: one runner per paper table/figure (DESIGN.md §6).
//! `gwclip exp <name>` writes results/<name>.md (+ CSV series where the
//! paper plots curves).

pub mod fedexp;
pub mod figures;
pub mod genexp;
pub mod harness;
pub mod hybridexp;
pub mod pipexp;
pub mod shardexp;
pub mod tables;

use anyhow::Result;

use crate::runtime::Runtime;

use harness::Scale;

/// Dispatch an experiment by name ("table1".."table11", "fig1".."fig7",
/// "pipeline-overhead", "accountant", "shard-scaling", "compress-scaling",
/// "hybrid-scaling", "user-vs-example", or "all").
pub fn run(rt: &Runtime, which: &str, paper_scale: bool) -> Result<()> {
    let scale = if paper_scale { Scale::paper() } else { Scale::quick() };
    std::fs::create_dir_all("results")?;
    match which {
        "table1" => tables::table1(rt, scale),
        "table2" => tables::table2(rt, scale),
        "table3" => tables::table3(rt, scale),
        "table4" => tables::table4(rt, scale),
        "table5" => genexp::table5(rt, scale),
        "table6" => pipexp::table6(rt, scale),
        "table10" => tables::table10(rt, scale),
        "table11" => tables::table11(rt, scale),
        "fig1" => figures::fig1(rt, scale),
        "fig2" => figures::fig2(rt, scale),
        "fig3" => figures::fig3(rt, scale),
        "fig5" => figures::fig5(rt, scale),
        "fig6" => figures::fig6(rt, scale),
        "fig7" => figures::fig7(rt, scale),
        "pipeline-overhead" => pipexp::pipeline_overhead(rt, scale),
        "accountant" => pipexp::accountant_table(rt, scale),
        "shard-scaling" => shardexp::shard_scaling(rt, scale),
        "compress-scaling" => shardexp::compress_scaling(rt, scale),
        "hybrid-scaling" => hybridexp::hybrid_scaling(rt, scale),
        "user-vs-example" => fedexp::user_vs_example(rt, scale),
        "all" => {
            for name in [
                "accountant", "fig1", "pipeline-overhead", "shard-scaling", "compress-scaling",
                "hybrid-scaling", "user-vs-example", "table1", "table2", "fig3", "fig2",
                "table6", "table5", "table11", "table3", "table4", "table10", "fig5", "fig6",
                "fig7",
            ] {
                eprintln!("==== exp {name} ====");
                run(rt, name, paper_scale)?;
            }
            Ok(())
        }
        _ => anyhow::bail!("unknown experiment '{which}' (see gwclip --help)"),
    }
}
