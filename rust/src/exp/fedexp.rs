//! Federated experiments: the user-level vs example-level contrast table.
//! At matched compute — the same expected number of *examples* per step
//! and the same step count — what does moving the unit of privacy from
//! one example to one user cost in utility, and what does the (eps,
//! delta) guarantee then actually cover? The degenerate cohort (one
//! example per user, population = n) is the bridge row: it processes the
//! same data as the example-level baseline yet reads at the user level.

use anyhow::Result;

use crate::data::lm::MarkovCorpus;
use crate::data::Dataset;
use crate::metrics::{fmt_f, MdTable};
use crate::runtime::Runtime;
use crate::session::{
    ClipMode, ClipPolicy, FederatedSpec, GroupBy, OptimSpec, PrivacySpec, RunSpec, SessionBuilder,
    ShardSpec,
};

use super::harness::Scale;

/// User-level vs example-level accounting on lm_tiny at matched compute.
///
/// Every row targets the same (eps, delta) and processes an expected
/// `E_EXAMPLES` examples per step over the same number of scheduled
/// steps, so host compute per step is matched; what changes is the unit
/// the accountant protects. Example-level rows sample examples at
/// q = E[B]/n; user-level rows sample users at q = E[U]/population. With
/// k examples per user the two sampling rates coincide (E[B]/n =
/// (E[B]/k)/(n/k)), so sigma is identical down the column — the table
/// shows the stronger guarantee is a *re-interpretation* at matched
/// noise, with the utility cost of coarser (whole-delta) clipping and
/// local steps in the eval-loss column.
pub fn user_vs_example(rt: &Runtime, scale: Scale) -> Result<()> {
    let cfg = rt.manifest.config("lm_tiny")?.clone();
    // an even example count so k-example users partition it exactly
    let n = scale.data & !1usize;
    let data = MarkovCorpus::new(n, cfg.hyper.seq, cfg.hyper.vocab, 4, 0);
    let eval = MarkovCorpus::new(n / 4, cfg.hyper.seq, cfg.hyper.vocab, 4, 777);
    let steps = if scale.seeds > 1 { 6 } else { 3 };
    const E_EXAMPLES: usize = 8;
    let mut t = MdTable::new(&[
        "unit",
        "backend",
        "population",
        "ex/user",
        "local steps",
        "E[units]/step",
        "q",
        "sigma_grad",
        "eps",
        "delta",
        "eval loss",
    ]);
    // (tag, examples_per_user, local_steps); ex/user = 0 marks the
    // example-level sharded baseline
    let rows: &[(&str, usize, usize)] = &[
        ("sharded", 0, 0),
        ("federated", 1, 1), // degenerate cohort: users ARE examples
        ("federated", 2, 1), // coarser unit, same q and step count
        ("federated", 2, 2), // + local work before transmit
    ];
    for &(tag, e_per_u, local_steps) in rows {
        let mut spec = RunSpec::for_config("lm_tiny");
        spec.clip =
            ClipPolicy { clip_init: 0.5, ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed) };
        spec.privacy = PrivacySpec { epsilon: 8.0, delta: 1e-5, quantile_r: 0.0 };
        spec.optim = OptimSpec::sgd(0.25);
        spec.epochs = 1.0;
        spec.seed = 11;
        let (population, expected_units) = if e_per_u == 0 {
            spec.expected_batch = E_EXAMPLES;
            spec.shard = Some(ShardSpec::with_workers(2));
            (n, E_EXAMPLES)
        } else {
            let population = n / e_per_u;
            let expected = E_EXAMPLES / e_per_u;
            spec.federated = Some(FederatedSpec {
                examples_per_user: e_per_u,
                local_steps,
                ..FederatedSpec::with_population(population, expected as f64 / population as f64)
            });
            (population, expected)
        };
        let mut sess = SessionBuilder::from_spec(rt, spec).build(data.len())?;
        let plan = sess.plan().expect("private run must carry a plan");
        // warmup (first PJRT call pays compilation)
        sess.step(&data)?;
        let mut unit = "example";
        for _ in 0..steps {
            let st = sess.step(&data)?;
            unit = st.unit;
        }
        let (loss, _) = sess.evaluate(&eval)?;
        t.row(&[
            unit.to_string(),
            tag.to_string(),
            format!("{population}"),
            if e_per_u == 0 { "-".into() } else { format!("{e_per_u}") },
            if e_per_u == 0 { "-".into() } else { format!("{local_steps}") },
            format!("{expected_units}"),
            fmt_f(plan.q, 4),
            fmt_f(plan.sigma_grad, 3),
            fmt_f(plan.epsilon, 2),
            format!("{:.0e}", plan.delta),
            fmt_f(loss, 4),
        ]);
        eprintln!(
            "[user-vs-example] {tag} ex/user={e_per_u} local={local_steps}: \
             {unit}-level q={:.4} sigma {:.3} eval loss {loss:.4}",
            plan.q, plan.sigma_grad
        );
    }
    t.save(
        "results/user_vs_example.md",
        "User-level vs example-level DP at matched compute: with k-example users the \
         sampling rates coincide, so sigma is identical — the user-level rows buy the \
         strictly stronger guarantee at the utility cost of whole-delta clipping and \
         local steps",
    )?;
    println!("{}", t.render());
    Ok(())
}
