//! Pipeline experiments: Table 6 (GPT-3-analog DP-LoRA fine-tuning with
//! per-device clipping) and the section-4 scheduling-overhead comparison.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::accountant;
use crate::coordinator::{Method, Trainer};
use crate::data::lm::{DialogSumCorpus, MarkovCorpus};
use crate::data::Dataset;
use crate::metrics::bleu::{corpus_bleu, rouge_l};
use crate::metrics::{fmt_f, MdTable};
use crate::pipeline::{merge_lora, PipelineEngine, PipelineMode, PipelineOpts};
use crate::runtime::{checkpoint, HostValue, IntTensor, Runtime, Tensor};

use super::harness::Scale;
use super::tables::text_opts;

/// Pretrain the GPT-3-analog base LM non-privately (single device, full
/// model) and cache the checkpoint under results/. Returns the param map.
pub fn pretrain_base(
    rt: &Runtime,
    config: &str,
    steps_budget: f64,
) -> Result<HashMap<String, Tensor>> {
    let path = format!("results/pretrained_{config}.bin");
    if let Ok(map) = checkpoint::read(&path) {
        eprintln!("[pretrain] reusing {path}");
        return Ok(map);
    }
    let cfg = rt.manifest.config(config)?.clone();
    let data = MarkovCorpus::new(2048, cfg.hyper.seq, cfg.hyper.vocab, 4, 7);
    let mut opts = text_opts(Method::NonPrivate, 0.0, steps_budget, 0);
    opts.lr = 2e-3;
    opts.expected_batch = cfg.batch;
    let mut tr = Trainer::new(rt, config, data.len(), opts)?;
    tr.run(&data, 25)?;
    let map: HashMap<String, Tensor> = cfg
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| (p.name.clone(), tr.params[i].clone()))
        .collect();
    std::fs::create_dir_all("results")?;
    let mut items: Vec<(String, &Tensor)> = map.iter().map(|(k, v)| (k.clone(), v)).collect();
    items.sort_by(|a, b| a.0.cmp(&b.0));
    checkpoint::write(&path, &items)?;
    Ok(map)
}

fn decode_score(
    rt: &Runtime,
    base_config: &str,
    params_map: &HashMap<String, Tensor>,
    eval: &DialogSumCorpus,
    n_eval: usize,
) -> Result<(f64, f64)> {
    let cfg = rt.manifest.config(base_config)?;
    let ordered = crate::runtime::params_from_map(cfg, params_map)?;
    let exec = rt.load(base_config, "logits")?;
    let prefixes: Vec<Vec<i32>> = (0..n_eval).map(|i| eval.prefix(i).to_vec()).collect();
    let hyps =
        super::genexp::greedy_decode(&exec, &ordered, &prefixes, cfg.batch, cfg.hyper.seq)?;
    let refs: Vec<Vec<i32>> = (0..n_eval)
        .map(|i| {
            let r = eval.reference_summary(i);
            r[..r.len().min(cfg.hyper.seq - eval.prefix(i).len())].to_vec()
        })
        .collect();
    Ok((100.0 * corpus_bleu(&hyps, &refs, 2), 100.0 * rouge_l(&hyps, &refs)))
}

/// Table 6: SAMSum-analog dialog summarization. Rows:
///   - GPT-2 analog (lm_small_lora), single device, flat-clipped DP LoRA
///   - GPT-3 analog (lm_mid_pipe_lora), 4-device pipeline, per-device
///     clipping DP LoRA (Algorithm 2)
///   - 0-shot (pretrained base, no fine-tuning)
/// at eps in {0.25, 1, 4} + non-private.
pub fn table6(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["Model + method", "eps", "BLEU-2", "ROUGE-L", "eval NLL"]);
    let n = scale.data / 2;
    let epss = [0.25, 1.0, 4.0, f64::INFINITY];

    // ---- GPT-2 analog: single-device flat-clipped LoRA -------------------
    {
        let config = "lm_small_lora";
        let base = "lm_small";
        let cfg = rt.manifest.config(config)?.clone();
        let pre = pretrain_base(rt, base, 2.0)?;
        let train = DialogSumCorpus::new(n, cfg.hyper.seq, cfg.hyper.vocab, 1);
        let eval = DialogSumCorpus::new(96, cfg.hyper.seq, cfg.hyper.vocab, 991);
        for &eps in &epss {
            let method = if eps.is_finite() { Method::FlatFixed } else { Method::NonPrivate };
            let mut opts = text_opts(method, eps.min(1e6), scale.epochs, 0);
            opts.lr = 5e-3;
            opts.clip_init = 1e-2;
            let mut tr = Trainer::new(rt, config, train.len(), opts)?;
            // load pretrained base weights under the LoRA param layout
            let specs = rt.manifest.config(config)?.params.clone();
            let mut params = tr.params.clone();
            for (i, s) in specs.iter().enumerate() {
                if let Some(w) = pre.get(&s.name) {
                    params[i] = w.clone();
                }
            }
            tr.set_params(params)?;
            tr.run(&train, 0)?;
            let (nll, _) = tr.evaluate(&eval)?;
            // merge lora into base and decode
            let mut merged = pre.clone();
            let tuned: HashMap<String, Tensor> = specs
                .iter()
                .enumerate()
                .map(|(i, s)| (s.name.clone(), tr.params[i].clone()))
                .collect();
            merge_lora(&mut merged, &tuned, cfg.hyper.lora_rank, cfg.hyper.lora_scale)?;
            let (bleu, rl) = decode_score(rt, base, &merged, &eval, 48)?;
            let label = if eps.is_finite() { format!("{eps}") } else { "non-private".into() };
            t.row(&[
                "GPT-2 analog LoRA (flat clipping)".into(),
                label.clone(),
                fmt_f(bleu, 1),
                fmt_f(rl, 1),
                fmt_f(nll, 3),
            ]);
            eprintln!("[table6] gpt2-analog eps={label} bleu {bleu:.1} rouge {rl:.1} nll {nll:.3}");
        }
        // 0-shot row (pretrained, no fine-tune)
        let (bleu, rl) = decode_score(rt, base, &pre, &eval, 48)?;
        t.row(&["GPT-2 analog 0-shot".into(), "-".into(), fmt_f(bleu, 1), fmt_f(rl, 1), "-".into()]);
    }

    // ---- GPT-3 analog: pipeline per-device-clipped LoRA -------------------
    {
        let config = "lm_mid_pipe_lora";
        let base = "lm_mid_pipe";
        let cfg = rt.manifest.config(config)?.clone();
        let pre = pretrain_base(rt, base, 2.0)?;
        let train = DialogSumCorpus::new(n, cfg.hyper.seq, cfg.hyper.vocab, 2);
        let eval = DialogSumCorpus::new(96, cfg.hyper.seq, cfg.hyper.vocab, 992);
        for &eps in &epss {
            let n_micro = 4usize;
            let minibatch = cfg.batch * n_micro;
            let steps = ((scale.epochs * n as f64) / minibatch as f64).ceil() as usize;
            let sigma = if eps.is_finite() {
                accountant::noise_multiplier(minibatch as f64 / n as f64, steps as u64, eps, 1e-5)
            } else {
                0.0
            };
            let opts = PipelineOpts {
                mode: if eps.is_finite() { PipelineMode::PerDevice } else { PipelineMode::NonPrivate },
                n_micro,
                clip: 1e-2,
                sigma,
                lr: 5e-3,
                adaptive: false,
                ..Default::default()
            };
            let mut eng = PipelineEngine::new(rt, config, opts)?;
            eng.load_params(&pre)?;
            let mut rng = crate::coordinator::noise::Rng::seeded(11);
            for _ in 0..steps {
                let idx: Vec<usize> = (0..minibatch).map(|_| rng.gen_range(train.len())).collect();
                eng.step(&train, &idx)?;
            }
            let nll = eng.evaluate(&eval)?;
            let mut merged = pre.clone();
            merge_lora(&mut merged, &eng.dump_params(), cfg.hyper.lora_rank, cfg.hyper.lora_scale)?;
            let (bleu, rl) = decode_score(rt, base, &merged, &eval, 48)?;
            let label = if eps.is_finite() { format!("{eps}") } else { "non-private".into() };
            t.row(&[
                "GPT-3 analog LoRA (per-device clipping, 4-way pipeline)".into(),
                label.clone(),
                fmt_f(bleu, 1),
                fmt_f(rl, 1),
                fmt_f(nll, 3),
            ]);
            eprintln!("[table6] gpt3-analog eps={label} bleu {bleu:.1} rouge {rl:.1} nll {nll:.3}");
        }
        let (bleu, rl) = decode_score(rt, base, &pre, &eval, 48)?;
        t.row(&["GPT-3 analog 0-shot".into(), "-".into(), fmt_f(bleu, 1), fmt_f(rl, 1), "-".into()]);
    }

    t.save(
        "results/table6.md",
        "Table 6: SAMSum analog — DP LoRA via per-device clipping scales to the pipeline-parallel model",
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Section 4 overhead: per-device clipping vs flat-sync over the pipeline.
pub fn pipeline_overhead(rt: &Runtime, scale: Scale) -> Result<()> {
    let config = "lm_mid_pipe_lora";
    let cfg = rt.manifest.config(config)?.clone();
    let data = MarkovCorpus::new(1024, cfg.hyper.seq, cfg.hyper.vocab, 4, 3);
    let steps = if scale.seeds > 1 { 6 } else { 3 };
    let mut t = MdTable::new(&[
        "Mode", "sim step (s)", "host step (s)", "syncs/step", "exec calls/step", "rel. sim time",
    ]);
    let mut base_sim = 0.0;
    for mode in [PipelineMode::PerDevice, PipelineMode::FlatSync] {
        let opts = PipelineOpts { mode, n_micro: 4, sigma: 0.5, clip: 1e-2, ..Default::default() };
        let mut eng = PipelineEngine::new(rt, config, opts)?;
        let mb = eng.minibatch();
        // warmup
        let idx: Vec<usize> = (0..mb).collect();
        eng.step(&data, &idx)?;
        let (mut sim, mut host, mut syncs, mut calls) = (0.0, 0.0, 0usize, 0usize);
        for s in 0..steps {
            let idx: Vec<usize> = (0..mb).map(|i| (s * mb + i) % data.len()).collect();
            let st = eng.step(&data, &idx)?;
            sim += st.sim_secs;
            host += st.host_secs;
            syncs += st.syncs;
            calls += st.calls;
        }
        let sim_avg = sim / steps as f64;
        if mode == PipelineMode::PerDevice {
            base_sim = sim_avg;
        }
        t.row(&[
            mode.name().to_string(),
            fmt_f(sim_avg, 3),
            fmt_f(host / steps as f64, 3),
            fmt_f(syncs as f64 / steps as f64, 1),
            fmt_f(calls as f64 / steps as f64, 0),
            format!("{:.2}x", sim_avg / base_sim),
        ]);
        eprintln!("[pipe] {} sim {:.3}s host {:.3}s", mode.name(), sim_avg, host / steps as f64);
    }
    t.save(
        "results/pipeline_overhead.md",
        "Section 4: per-device clipping avoids the flat-clipping sync + rematerialization overhead",
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Accountant supplementary: sigma values + Prop 3.1 splits for the main
/// experiment settings.
pub fn accountant_table(_rt: &Runtime, _scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["setting", "q", "T", "eps", "sigma", "r", "sigma_grad", "sigma_b"]);
    for (name, q, steps, eps, r, k) in [
        ("CIFAR analog (resmlp)", 0.05, 120u64, 3.0, 0.01, 15usize),
        ("CIFAR analog (resmlp)", 0.05, 120, 8.0, 0.01, 15),
        ("SST-2 analog (cls_small)", 0.025, 240, 3.0, 0.1, 17),
        ("SST-2 analog (cls_small)", 0.025, 240, 8.0, 0.1, 17),
        ("E2E analog (lm_small)", 0.025, 240, 3.0, 0.01, 19),
        ("SAMSum analog pipeline", 0.03, 100, 1.0, 0.0, 4),
    ] {
        let plan = accountant::plan(eps, 1e-5, q, steps, r, k);
        t.row(&[
            name.to_string(),
            format!("{q}"),
            format!("{steps}"),
            format!("{eps}"),
            fmt_f(plan.sigma_base, 3),
            format!("{r}"),
            fmt_f(plan.sigma_grad, 3),
            fmt_f(plan.sigma_quantile, 2),
        ]);
    }
    t.save("results/accountant.md", "Privacy accountant: noise multipliers and Prop 3.1 budget splits")?;
    println!("{}", t.render());
    Ok(())
}

#[allow(unused)]
fn unused_types(_: IntTensor, _: HostValue, _: &dyn Dataset) {}
