//! Pipeline experiments: Table 6 (GPT-3-analog DP-LoRA fine-tuning with
//! per-device clipping) and the section-4 scheduling-overhead comparison.
//! Both backends are driven through the session API; pipeline sigma comes
//! from the accountant (never hand-picked).

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::accountant;
use crate::coordinator::trainer::Method;
use crate::data::lm::{DialogSumCorpus, MarkovCorpus};
use crate::metrics::bleu::{corpus_bleu, rouge_l};
use crate::metrics::{fmt_f, MdTable};
use crate::pipeline::{merge_lora, PipelineMode};
use crate::runtime::{checkpoint, Runtime, Tensor};
use crate::session::{ClipMode, ClipPolicy, GroupBy, OptimSpec, PrivacySpec, Sampling};

use super::harness::{session_for, Scale};
use super::tables::text_spec;

/// Pretrain the GPT-3-analog base LM non-privately (single device, full
/// model) and cache the checkpoint under results/. Returns the param map.
pub fn pretrain_base(
    rt: &Runtime,
    config: &str,
    epochs_budget: f64,
) -> Result<HashMap<String, Tensor>> {
    let path = format!("results/pretrained_{config}.bin");
    if let Ok(map) = checkpoint::read(&path) {
        eprintln!("[pretrain] reusing {path}");
        return Ok(map);
    }
    let cfg = rt.manifest.config(config)?.clone();
    let data = MarkovCorpus::new(2048, cfg.hyper.seq, cfg.hyper.vocab, 4, 7);
    let mut spec = text_spec(Method::NonPrivate, 0.0, epochs_budget, 0);
    spec.config = config.to_string();
    spec.optim.lr = 2e-3;
    spec.expected_batch = cfg.batch;
    let mut sess = session_for(rt, spec, data.len())?;
    sess.run(&data, 25)?;
    let map = sess.param_map();
    std::fs::create_dir_all("results")?;
    let mut items: Vec<(String, &Tensor)> = map.iter().map(|(k, v)| (k.clone(), v)).collect();
    items.sort_by(|a, b| a.0.cmp(&b.0));
    checkpoint::write(&path, &items)?;
    Ok(map)
}

fn decode_score(
    rt: &Runtime,
    base_config: &str,
    params_map: &HashMap<String, Tensor>,
    eval: &DialogSumCorpus,
    n_eval: usize,
) -> Result<(f64, f64)> {
    let cfg = rt.manifest.config(base_config)?;
    let ordered = crate::runtime::params_from_map(cfg, params_map)?;
    let exec = rt.load(base_config, "logits")?;
    let prefixes: Vec<Vec<i32>> = (0..n_eval).map(|i| eval.prefix(i).to_vec()).collect();
    let hyps =
        super::genexp::greedy_decode(&exec, &ordered, &prefixes, cfg.batch, cfg.hyper.seq)?;
    let refs: Vec<Vec<i32>> = (0..n_eval)
        .map(|i| {
            let r = eval.reference_summary(i);
            r[..r.len().min(cfg.hyper.seq - eval.prefix(i).len())].to_vec()
        })
        .collect();
    Ok((100.0 * corpus_bleu(&hyps, &refs, 2), 100.0 * rouge_l(&hyps, &refs)))
}

/// Per-device clipping spec for the pipeline configs: DP-Adam LoRA
/// fine-tuning at threshold `clip`, sigma accountant-derived. Poisson
/// sampling is pinned explicitly: the runs below report the amplified
/// accountant (q = E[B]/n, E[B] = 0.8x the minibatch), not the legacy
/// q = 1 composition.
fn pipe_spec(config: &str, eps: f64, clip: f64, steps: usize, seed: u64) -> crate::session::RunSpec {
    let mut spec = crate::session::RunSpec::for_config(config);
    spec.clip = ClipPolicy {
        clip_init: clip,
        ..ClipPolicy::new(
            if eps.is_finite() { GroupBy::PerDevice } else { GroupBy::Flat },
            if eps.is_finite() { ClipMode::Fixed } else { ClipMode::NonPrivate },
        )
    };
    spec.privacy = PrivacySpec { epsilon: eps.min(1e6).max(1e-9), delta: 1e-5, quantile_r: 0.0 };
    spec.optim = OptimSpec {
        kind: crate::coordinator::optimizer::OptimizerKind::Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        },
        lr: 5e-3,
        weight_decay: 0.0,
        lr_decay: false,
    };
    spec.pipe.n_micro = 4;
    spec.pipe.steps = steps;
    spec.pipe.sampling = Sampling::Poisson;
    spec.seed = seed;
    spec
}

/// Table 6: SAMSum-analog dialog summarization. Rows:
///   - GPT-2 analog (lm_small_lora), single device, flat-clipped DP LoRA
///   - GPT-3 analog (lm_mid_pipe_lora), 4-device pipeline, per-device
///     clipping DP LoRA (Algorithm 2)
///   - 0-shot (pretrained base, no fine-tuning)
/// at eps in {0.25, 1, 4} + non-private.
pub fn table6(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["Model + method", "eps", "BLEU-2", "ROUGE-L", "eval NLL"]);
    let n = scale.data / 2;
    let epss = [0.25, 1.0, 4.0, f64::INFINITY];

    // ---- GPT-2 analog: single-device flat-clipped LoRA -------------------
    {
        let config = "lm_small_lora";
        let base = "lm_small";
        let cfg = rt.manifest.config(config)?.clone();
        let pre = pretrain_base(rt, base, 2.0)?;
        let train = DialogSumCorpus::new(n, cfg.hyper.seq, cfg.hyper.vocab, 1);
        let eval = DialogSumCorpus::new(96, cfg.hyper.seq, cfg.hyper.vocab, 991);
        for &eps in &epss {
            let method = if eps.is_finite() { Method::FlatFixed } else { Method::NonPrivate };
            let mut spec = text_spec(method, eps.min(1e6), scale.epochs, 0);
            spec.config = config.to_string();
            spec.optim.lr = 5e-3;
            spec.clip.clip_init = 1e-2;
            let mut sess = session_for(rt, spec, train.len())?;
            // load pretrained base weights under the LoRA param layout
            // (names absent from the map — the adapters — keep their init)
            sess.load_param_map(&pre)?;
            sess.run(&train, 0)?;
            let (nll, _) = sess.evaluate(&eval)?;
            // merge lora into base and decode
            let mut merged = pre.clone();
            merge_lora(&mut merged, &sess.param_map(), cfg.hyper.lora_rank, cfg.hyper.lora_scale)?;
            let (bleu, rl) = decode_score(rt, base, &merged, &eval, 48)?;
            let label = if eps.is_finite() { format!("{eps}") } else { "non-private".into() };
            t.row(&[
                "GPT-2 analog LoRA (flat clipping)".into(),
                label.clone(),
                fmt_f(bleu, 1),
                fmt_f(rl, 1),
                fmt_f(nll, 3),
            ]);
            eprintln!("[table6] gpt2-analog eps={label} bleu {bleu:.1} rouge {rl:.1} nll {nll:.3}");
        }
        // 0-shot row (pretrained, no fine-tune)
        let (bleu, rl) = decode_score(rt, base, &pre, &eval, 48)?;
        t.row(&["GPT-2 analog 0-shot".into(), "-".into(), fmt_f(bleu, 1), fmt_f(rl, 1), "-".into()]);
    }

    // ---- GPT-3 analog: pipeline per-device-clipped LoRA -------------------
    {
        let config = "lm_mid_pipe_lora";
        let base = "lm_mid_pipe";
        let cfg = rt.manifest.config(config)?.clone();
        let pre = pretrain_base(rt, base, 2.0)?;
        let train = DialogSumCorpus::new(n, cfg.hyper.seq, cfg.hyper.vocab, 2);
        let eval = DialogSumCorpus::new(96, cfg.hyper.seq, cfg.hyper.vocab, 992);
        for &eps in &epss {
            let n_micro = 4usize;
            let minibatch = cfg.batch * n_micro;
            let steps = ((scale.epochs * n as f64) / minibatch as f64).ceil() as usize;
            let mut sess = session_for(rt, pipe_spec(config, eps, 1e-2, steps.max(1), 11), train.len())?;
            sess.load_param_map(&pre)?;
            sess.run(&train, 0)?;
            let (nll, _) = sess.evaluate(&eval)?;
            let mut merged = pre.clone();
            merge_lora(&mut merged, &sess.param_map(), cfg.hyper.lora_rank, cfg.hyper.lora_scale)?;
            let (bleu, rl) = decode_score(rt, base, &merged, &eval, 48)?;
            let label = if eps.is_finite() { format!("{eps}") } else { "non-private".into() };
            t.row(&[
                "GPT-3 analog LoRA (per-device clipping, 4-way pipeline)".into(),
                label.clone(),
                fmt_f(bleu, 1),
                fmt_f(rl, 1),
                fmt_f(nll, 3),
            ]);
            eprintln!("[table6] gpt3-analog eps={label} bleu {bleu:.1} rouge {rl:.1} nll {nll:.3}");
        }
        let (bleu, rl) = decode_score(rt, base, &pre, &eval, 48)?;
        t.row(&["GPT-3 analog 0-shot".into(), "-".into(), fmt_f(bleu, 1), fmt_f(rl, 1), "-".into()]);
    }

    t.save(
        "results/table6.md",
        "Table 6: SAMSum analog — DP LoRA via per-device clipping scales to the pipeline-parallel model",
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Section 4 overhead: per-device clipping vs flat-sync over the pipeline.
pub fn pipeline_overhead(rt: &Runtime, scale: Scale) -> Result<()> {
    let config = "lm_mid_pipe_lora";
    let cfg = rt.manifest.config(config)?.clone();
    let data = MarkovCorpus::new(1024, cfg.hyper.seq, cfg.hyper.vocab, 4, 3);
    let steps = if scale.seeds > 1 { 6 } else { 3 };
    let mut t = MdTable::new(&[
        "Mode", "sim step (s)", "host step (s)", "syncs/step", "exec calls/step", "rel. sim time",
    ]);
    let mut base_sim = 0.0;
    for mode in [PipelineMode::PerDevice, PipelineMode::FlatSync] {
        // timing comparison: both modes at eps=1 over the same schedule
        let mut spec = pipe_spec(config, 1.0, 1e-2, steps + 1, 0);
        spec.clip = ClipPolicy {
            clip_init: 1e-2,
            ..ClipPolicy::from_pipeline_mode(mode, false)
        };
        let mut sess = session_for(rt, spec, data.len())?;
        // warmup
        sess.step(&data)?;
        let (mut sim, mut host, mut syncs, mut calls) = (0.0, 0.0, 0usize, 0usize);
        for _ in 0..steps {
            let st = sess.step(&data)?;
            sim += st.sim_secs;
            host += st.host_secs;
            syncs += st.syncs;
            calls += st.calls;
        }
        let sim_avg = sim / steps as f64;
        if mode == PipelineMode::PerDevice {
            base_sim = sim_avg;
        }
        t.row(&[
            mode.name().to_string(),
            fmt_f(sim_avg, 3),
            fmt_f(host / steps as f64, 3),
            fmt_f(syncs as f64 / steps as f64, 1),
            fmt_f(calls as f64 / steps as f64, 0),
            format!("{:.2}x", sim_avg / base_sim),
        ]);
        eprintln!("[pipe] {} sim {:.3}s host {:.3}s", mode.name(), sim_avg, host / steps as f64);
    }
    t.save(
        "results/pipeline_overhead.md",
        "Section 4: per-device clipping avoids the flat-clipping sync + rematerialization overhead",
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Accountant supplementary: sigma values + Prop 3.1 splits for the main
/// experiment settings. The last two rows contrast the pipeline's Poisson
/// accounting (amplification at q = E[B]/n over T steps) with the
/// legacy round-robin bound (q = 1 over the ~T*q participations per
/// example): the amplified branch needs strictly less noise.
pub fn accountant_table(_rt: &Runtime, _scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["setting", "q", "T", "eps", "sigma", "r", "sigma_grad", "sigma_b"]);
    for (name, q, steps, eps, r, k) in [
        ("CIFAR analog (resmlp)", 0.05, 120u64, 3.0, 0.01, 15usize),
        ("CIFAR analog (resmlp)", 0.05, 120, 8.0, 0.01, 15),
        ("SST-2 analog (cls_small)", 0.025, 240, 3.0, 0.1, 17),
        ("SST-2 analog (cls_small)", 0.025, 240, 8.0, 0.1, 17),
        ("E2E analog (lm_small)", 0.025, 240, 3.0, 0.01, 19),
        ("SAMSum analog pipeline (poisson)", 0.03, 100, 1.0, 0.0, 4),
        ("SAMSum analog pipeline (round_robin)", 1.0, 3, 1.0, 0.0, 4),
    ] {
        let plan = accountant::plan(eps, 1e-5, q, steps, r, k);
        t.row(&[
            name.to_string(),
            format!("{q}"),
            format!("{steps}"),
            format!("{eps}"),
            fmt_f(plan.sigma_base, 3),
            format!("{r}"),
            fmt_f(plan.sigma_grad, 3),
            fmt_f(plan.sigma_quantile, 2),
        ]);
    }
    t.save("results/accountant.md", "Privacy accountant: noise multipliers and Prop 3.1 budget splits")?;
    println!("{}", t.render());
    Ok(())
}
