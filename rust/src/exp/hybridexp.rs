//! Hybrid 2D-parallel scaling experiments: the R x S sweep table. How
//! does the simulated step latency evolve as data-parallel replicas are
//! added to a fixed pipeline partitioning, and how much of the
//! cross-replica all-reduce does overlapping it with the pipeline's own
//! backward hide — while the privacy plan stays *fixed* (one release per
//! step at q = E[B]/n, independent of both R and S)?

use anyhow::Result;

use crate::data::lm::MarkovCorpus;
use crate::data::Dataset;
use crate::metrics::{fmt_f, MdTable};
use crate::runtime::Runtime;
use crate::session::{
    ClipMode, ClipPolicy, GroupBy, HybridSpec, OptimSpec, PrivacySpec, RunSpec, SessionBuilder,
};

use super::harness::Scale;

/// Hybrid scaling table over the (R, S) grid: per-piece clipping on the
/// staged LM configs (S = 1 and S = 4 partitionings) with R in {1, 2, 4}
/// replicas each, fixed (eps, delta), reporting tree rounds, overlapped
/// vs barrier simulated step latency, and the accountant's (sigma, q) —
/// which must not move with R or S.
pub fn hybrid_scaling(rt: &Runtime, scale: Scale) -> Result<()> {
    let steps = if scale.seeds > 1 { 4 } else { 2 };
    let mut t = MdTable::new(&[
        "config",
        "S",
        "R",
        "tree rounds",
        "sim overlap (s)",
        "sim barrier (s)",
        "reduction hidden",
        "host step (s)",
        "sigma_grad",
        "q",
    ]);
    // Pin the GLOBAL E[B] per config to one value divisible by every
    // tested replica count (and within the per-replica static minibatch):
    // the plan — q = E[B]/n, step count, sigma — is then literally
    // identical across that config's rows, which is the point.
    for (config, expected_batch) in [("lm_tiny_pipe", 8usize), ("lm_mid_pipe_lora", 24usize)] {
        let cfg = rt.manifest.config(config)?.clone();
        let data = MarkovCorpus::new(scale.data, cfg.hyper.seq, cfg.hyper.vocab, 4, 0);
        for replicas in [1usize, 2, 4] {
            let mut spec = RunSpec::for_config(config);
            spec.clip = ClipPolicy {
                clip_init: 1e-2,
                ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed)
            };
            spec.privacy = PrivacySpec { epsilon: 2.0, delta: 1e-5, quantile_r: 0.0 };
            spec.optim = OptimSpec::adam(1e-3);
            spec.epochs = 1.0;
            spec.expected_batch = expected_batch;
            spec.pipe.n_micro = 4;
            spec.pipe.steps = steps + 1;
            spec.hybrid = Some(HybridSpec::with_replicas(replicas));
            let mut sess = SessionBuilder::from_spec(rt, spec).build(data.len())?;
            let plan = sess.plan().expect("private hybrid run must carry a plan");
            let s_stages = sess.hybrid_engine().expect("hybrid backend").n_stages;
            // warmup (first PJRT call pays compilation)
            sess.step(&data)?;
            let (mut ov, mut ba, mut host, mut rounds) = (0.0, 0.0, 0.0, 0usize);
            for _ in 0..steps {
                let st = sess.step(&data)?;
                ov += st.sim_overlap_secs;
                ba += st.sim_barrier_secs;
                host += st.host_secs;
                rounds = st.syncs;
            }
            let (ov, ba, host) = (ov / steps as f64, ba / steps as f64, host / steps as f64);
            let hidden = if ba > 0.0 { 1.0 - ov / ba } else { 0.0 };
            t.row(&[
                config.to_string(),
                format!("{s_stages}"),
                format!("{replicas}"),
                format!("{rounds}"),
                fmt_f(ov, 4),
                fmt_f(ba, 4),
                format!("{:.0}%", 100.0 * hidden),
                fmt_f(host, 4),
                fmt_f(plan.sigma_grad, 3),
                fmt_f(plan.q, 4),
            ]);
            eprintln!(
                "[hybrid] {config} S={s_stages} R={replicas} sim overlap {ov:.4}s barrier \
                 {ba:.4}s ({:.0}% hidden) host {host:.4}s",
                100.0 * hidden
            );
        }
    }
    t.save(
        "results/hybrid_scaling.md",
        "Hybrid 2D-parallel scaling: overlapping each stage's cross-replica reduction with \
         the pipeline backward hides the all-reduce; the privacy plan is invariant in both \
         the replica and the stage count",
    )?;
    println!("{}", t.render());
    Ok(())
}
