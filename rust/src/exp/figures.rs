//! Figure harnesses: Figures 1/9 (efficiency), 2/4 (norm shift), 3
//! (adaptive rescues fixed), 5 (quantile sweep), 6 (budget-r sweep),
//! 7/8 (metric vs wall time). Each writes results/<name>.md (+ CSV
//! series). All runs construct through the session API.

use std::fmt::Write as _;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::trainer::Method;
use crate::data::lm::MarkovCorpus;
use crate::metrics::memmodel::{Scheme, WorkloadDims};
use crate::metrics::{fmt_f, MdTable};
use crate::runtime::Runtime;

use super::harness::{session_for, Scale};
use super::tables::{cifar_like, session_with_init, sst2_like, text_spec, vision_spec};

fn sst2_box() -> Box<dyn Fn(usize, u64) -> Box<dyn crate::data::Dataset>> {
    Box::new(|n, s| Box::new(sst2_like(n, s)) as Box<dyn crate::data::Dataset>)
}

/// Figure 1 / 9 / Appendix G: per-update efficiency of the clipping
/// schemes, measured on the GPT-2 analog config, plus the analytic memory
/// panel at GPT-2 scale.
pub fn fig1(rt: &Runtime, scale: Scale) -> Result<()> {
    let config = "lm_small";
    let cfg = rt.manifest.config(config)?.clone();
    let data = MarkovCorpus::new(512, cfg.hyper.seq, cfg.hyper.vocab, 4, 0);
    let steps = if scale.seeds > 1 { 8 } else { 5 };

    let mut t = MdTable::new(&["Method", "steps/sec", "rel. to non-private", "extra bwd", "peak mem @GPT-2 (GB, analytic)"]);
    let mem_dims = WorkloadDims {
        batch: 32,
        seq: 128,
        d_model: 768,
        d_ff: 3072,
        n_layers: 12,
        vocab: 50257,
        n_params: 124_000_000,
        n_groups: 50,
    };
    let mut base_rate = 0.0;
    for (method, scheme) in [
        (Method::NonPrivate, Scheme::NonPrivate),
        (Method::PerLayerAdaptive, Scheme::PerLayerFused),
        (Method::FlatFixed, Scheme::FlatGhostNorms),
        (Method::Ghost, Scheme::Ghost),
        (Method::Naive, Scheme::NaiveFlat),
    ] {
        let mut spec = text_spec(method, 8.0, 1.0, 0);
        spec.config = config.to_string();
        spec.expected_batch = cfg.batch * 4 / 5;
        let mut sess = session_for(rt, spec, data.len())?;
        // warmup (compile+cache)
        sess.step(&data)?;
        let t0 = Instant::now();
        for _ in 0..steps {
            sess.step(&data)?;
        }
        let rate = steps as f64 / t0.elapsed().as_secs_f64();
        if method == Method::NonPrivate {
            base_rate = rate;
        }
        let gb = scheme.peak_bytes(&mem_dims) as f64 / 1e9;
        t.row(&[
            scheme.name().to_string(),
            fmt_f(rate, 3),
            format!("{:.2}x", rate / base_rate),
            format!("{}", scheme.n_backwards() - 1),
            fmt_f(gb, 2),
        ]);
        eprintln!("[fig1] {} {:.3} steps/s", scheme.name(), rate);
    }
    t.save(
        "results/fig1.md",
        "Figure 1/9: per-update throughput (measured, lm_small) and peak memory (analytic, GPT-2 dims)",
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Figure 2 (+ Figure 4): per-layer gradient-norm distribution shift over
/// training. Dumps norms[B,K] snapshots at several epochs to CSV.
pub fn fig2(rt: &Runtime, scale: Scale) -> Result<()> {
    let data = cifar_like(scale.data, 0);
    let mut spec = vision_spec(Method::PerLayerAdaptive, 8.0, scale.epochs.max(4.0), 0);
    spec.privacy.quantile_r = 0.01;
    let mut sess = session_for(rt, spec, data.len())?;
    sess.collect_norms(true)?;
    let total = sess.total_steps;
    let groups = sess.group_labels();
    let k = groups.len();
    let snaps = [0u64, total / 4, total / 2, 3 * total / 4, total - 1];
    let mut csv = String::from("step,group,mean_norm,p50,p90\n");
    for s in 0..total {
        sess.step(&data)?;
        if snaps.contains(&s) {
            // summarize the latest [B,K] matrix per group
            let mat = sess.collected_norms().unwrap().last().unwrap().clone();
            let b = mat.len() / k;
            for g in 0..k {
                let mut col: Vec<f32> = (0..b).map(|i| mat[i * k + g]).collect();
                col.sort_by(|a, x| a.partial_cmp(x).unwrap());
                let mean: f64 = col.iter().map(|&v| v as f64).sum::<f64>() / b as f64;
                writeln!(
                    csv,
                    "{s},{},{mean:.6},{:.6},{:.6}",
                    groups[g],
                    col[b / 2],
                    col[(b * 9 / 10).min(b - 1)]
                )?;
            }
        }
        // keep memory bounded
        if let Some(tr) = sess.trainer_mut() {
            if let Some(c) = &mut tr.collect_norms {
                if c.len() > 2 {
                    c.remove(0);
                }
            }
        }
    }
    std::fs::create_dir_all("results")?;
    crate::util::fsio::write_atomic(std::path::Path::new("results/fig2_norms.csv"), csv.as_bytes())?;
    let doc = "# Figure 2/4: per-layer gradient-norm shift across training\n\n\
        Per-group mean/median/p90 of per-example gradient norms at 5 training\n\
        checkpoints (CSV: fig2_norms.csv). The paper's observation reproduces:\n\
        early in training norms are uniformly small; later, input-side layers'\n\
        norms grow and the distribution spreads, which is why fixed per-layer\n\
        thresholds mis-clip and adaptive thresholds are needed.\n";
    crate::util::fsio::write_atomic(std::path::Path::new("results/fig2.md"), doc.as_bytes())?;
    println!("wrote results/fig2.md + fig2_norms.csv");
    Ok(())
}

/// Figure 3: training curves — adaptive per-layer rescues fixed per-layer.
pub fn fig3(rt: &Runtime, scale: Scale) -> Result<()> {
    let data = cifar_like(scale.data, 0);
    let eval = cifar_like(scale.data / 4, 777);
    let mut csv = String::from("method,step,eval_acc\n");
    let mut t = MdTable::new(&["Method", "final eval acc (eps=3)"]);
    for method in [
        Method::NonPrivate,
        Method::FlatFixed,
        Method::PerLayerFixed,
        Method::PerLayerAdaptive,
    ] {
        let spec = vision_spec(method, 3.0, scale.epochs.max(4.0), 0);
        let mut sess = session_for(rt, spec, data.len())?;
        let total = sess.total_steps;
        let evals = 8u64;
        for s in 0..total {
            sess.step(&data)?;
            if s % (total / evals).max(1) == 0 || s == total - 1 {
                let (_, acc) = sess.evaluate(&eval)?;
                writeln!(csv, "{},{s},{acc:.4}", method.name())?;
            }
        }
        let (_, acc) = sess.evaluate(&eval)?;
        t.row(&[method.name().to_string(), fmt_f(100.0 * acc, 1)]);
        eprintln!("[fig3] {} -> {:.1}", method.name(), 100.0 * acc);
    }
    std::fs::create_dir_all("results")?;
    crate::util::fsio::write_atomic(std::path::Path::new("results/fig3_curves.csv"), csv.as_bytes())?;
    t.save("results/fig3.md", "Figure 3: adaptive per-layer clipping eliminates fixed per-layer's loss (curves in fig3_curves.csv)")?;
    println!("{}", t.render());
    Ok(())
}

/// Figure 5: sensitivity to the target quantile q.
pub fn fig5(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["Task", "q", "eval acc"]);
    let qs_vision = [0.3, 0.5, 0.7, 0.9];
    let data = cifar_like(scale.data, 0);
    let eval = cifar_like(scale.data / 4, 777);
    for q in qs_vision {
        let mut spec = vision_spec(Method::PerLayerAdaptive, 3.0, scale.epochs, 0);
        spec.clip.target_q = q;
        let mut sess = session_for(rt, spec, data.len())?;
        sess.run(&data, 0)?;
        let (_, acc) = sess.evaluate(&eval)?;
        t.row(&["CIFAR analog".into(), format!("{q}"), fmt_f(100.0 * acc, 1)]);
        eprintln!("[fig5] cifar q={q} -> {:.1}", 100.0 * acc);
    }
    let dtext = sst2_like(scale.data, 0);
    let etext = sst2_like(scale.data / 4, 777);
    for q in [0.05, 0.4, 0.6, 0.85, 0.95] {
        let mut spec = text_spec(Method::PerLayerAdaptive, 3.0, scale.epochs, 0);
        spec.clip.target_q = q;
        let mk = sst2_box();
        let mut sess = session_with_init(rt, spec, dtext.len(), Some(("sst2", &*mk)))?;
        sess.run(&dtext, 0)?;
        let (_, acc) = sess.evaluate(&etext)?;
        t.row(&["SST-2 analog".into(), format!("{q}"), fmt_f(100.0 * acc, 1)]);
        eprintln!("[fig5] sst2 q={q} -> {:.1}", 100.0 * acc);
    }
    t.save("results/fig5.md", "Figure 5: accuracy vs target quantile q (adaptive per-layer, eps=3)")?;
    println!("{}", t.render());
    Ok(())
}

/// Figure 6: sensitivity to the quantile-estimation budget fraction r.
pub fn fig6(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["r", "sigma_grad/sigma", "eps=3 acc", "eps=8 acc"]);
    let data = sst2_like(scale.data, 0);
    let eval = sst2_like(scale.data / 4, 777);
    for r in [0.0001, 0.001, 0.01, 0.05, 0.1, 0.2, 0.4, 0.8] {
        let mut cells = vec![format!("{r}")];
        let mut ratio = 0.0;
        for eps in [3.0, 8.0] {
            let mut spec = text_spec(Method::PerLayerAdaptive, eps, scale.epochs, 0);
            spec.privacy.quantile_r = r;
            let mk = sst2_box();
            let mut sess = session_with_init(rt, spec, data.len(), Some(("sst2", &*mk)))?;
            if eps == 3.0 {
                let p = sess.plan().unwrap();
                ratio = p.sigma_grad / p.sigma_base;
            }
            sess.run(&data, 0)?;
            let (_, acc) = sess.evaluate(&eval)?;
            cells.push(fmt_f(100.0 * acc, 1));
            eprintln!("[fig6] r={r} eps={eps} -> {:.1}", 100.0 * acc);
        }
        cells.insert(1, fmt_f(ratio, 3));
        t.row(&cells);
    }
    t.save("results/fig6.md", "Figure 6: accuracy vs quantile-estimation budget r (Prop 3.1 split)")?;
    println!("{}", t.render());
    Ok(())
}

/// Figures 7/8: test NLL vs wall time — per-layer's per-step speed buys
/// lower loss at equal wall time.
pub fn fig7(rt: &Runtime, scale: Scale) -> Result<()> {
    use crate::data::lm::TableToTextCorpus;
    let cfg = rt.manifest.config("lm_small")?.clone();
    let data = TableToTextCorpus::new(scale.data / 2, cfg.hyper.seq, cfg.hyper.vocab, 3, 0);
    let eval = TableToTextCorpus::new(128, cfg.hyper.seq, cfg.hyper.vocab, 3, 999);
    let mut csv = String::from("method,wall_s,eval_nll\n");
    let mut t = MdTable::new(&["Method", "wall time (s)", "final eval NLL"]);
    let pre = super::pipexp::pretrain_base(rt, "lm_small", 2.0)?;
    for method in [Method::PerLayerAdaptive, Method::FlatFixed, Method::Ghost] {
        let mut spec = text_spec(method, 8.0, scale.epochs, 0);
        spec.config = "lm_small".to_string();
        spec.optim.lr = 2e-3;
        spec.clip.clip_init = 0.1;
        let mut sess = session_for(rt, spec, data.len())?;
        sess.load_param_map(&pre)?;
        let total = sess.total_steps;
        let t0 = Instant::now();
        for s in 0..total {
            sess.step(&data)?;
            if s % (total / 6).max(1) == 0 || s == total - 1 {
                let (nll, _) = sess.evaluate(&eval)?;
                writeln!(csv, "{},{:.2},{nll:.4}", method.name(), t0.elapsed().as_secs_f64())?;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (nll, _) = sess.evaluate(&eval)?;
        t.row(&[method.name().to_string(), fmt_f(wall, 1), fmt_f(nll, 4)]);
        eprintln!("[fig7] {} wall {:.1}s nll {:.4}", method.name(), wall, nll);
    }
    std::fs::create_dir_all("results")?;
    crate::util::fsio::write_atomic(std::path::Path::new("results/fig7_curves.csv"), csv.as_bytes())?;
    t.save("results/fig7.md", "Figures 7/8: eval NLL vs wall time on the E2E analog (curves in fig7_curves.csv)")?;
    println!("{}", t.render());
    Ok(())
}
