//! Accuracy-table harnesses: Tables 1, 2, 3, 4, 10, 11 of the paper.
//! Each writes results/<name>.md with the same rows the paper reports.
//! All runs go through the session API (one construction path with the
//! CLI; `StepEvent` streams; accountant-derived noise).

use anyhow::Result;

use crate::coordinator::noise::Allocation;
use crate::coordinator::trainer::Method;
use crate::data::classif::{MixtureImages, SentimentCorpus, TextTask};
use crate::data::Dataset;
use crate::metrics::{fmt_f, MdTable};
use crate::runtime::{checkpoint, Runtime, Tensor};
use crate::session::{ClipPolicy, OptimSpec, PrivacySpec, RunSpec, Session};

use super::harness::{session_for, Scale};

/// Non-privately pretrain `config` on a held-out shard of the task (the
/// public-data analog of the paper's pretrained RoBERTa) and cache the
/// checkpoint. DP runs then *fine-tune* from this init, matching the
/// paper's setting where per-example gradients are small and few epochs
/// suffice.
pub fn pretrained_params(
    rt: &Runtime,
    config: &str,
    label: &str,
    mk_data: &dyn Fn(usize, u64) -> Box<dyn Dataset>,
) -> Result<Vec<Tensor>> {
    let cfg = rt.manifest.config(config)?;
    let path = format!("results/pretrained_{config}_{label}.bin");
    if let Ok(map) = checkpoint::read(&path) {
        if let Ok(p) = crate::runtime::params_from_map(cfg, &map) {
            return Ok(p);
        }
    }
    let data = mk_data(4096, 7777);
    let mut spec = text_spec(Method::NonPrivate, 0.0, 4.0, 7);
    spec.config = config.to_string();
    spec.optim.lr = 1e-3;
    let mut sess = session_for(rt, spec, data.len())?;
    sess.run(&*data, 0)?;
    std::fs::create_dir_all("results")?;
    let params = sess.params()?.to_vec();
    let named: Vec<(String, &Tensor)> = cfg
        .params
        .iter()
        .zip(&params)
        .map(|(pi, t)| (pi.name.clone(), t))
        .collect();
    checkpoint::write(&path, &named)?;
    eprintln!("[pretrain] cached {path}");
    Ok(params)
}

/// Build a session, fine-tuning from the cached pretrained checkpoint when
/// `pretrain` labels one.
pub fn session_with_init<'r>(
    rt: &'r Runtime,
    spec: RunSpec,
    n_data: usize,
    pretrain: Option<(&str, &dyn Fn(usize, u64) -> Box<dyn Dataset>)>,
) -> Result<Session<'r>> {
    let config = spec.config.clone();
    let mut sess = session_for(rt, spec, n_data)?;
    if let Some((label, mk)) = pretrain {
        sess.set_params(pretrained_params(rt, &config, label, mk)?)?;
    }
    Ok(sess)
}

/// The CIFAR-10 analog task (harder spread so clipping bias is visible).
pub fn cifar_like(n: usize, seed: u64) -> MixtureImages {
    MixtureImages::with_spread(n, 64, 10, 0xC1FA, seed, 0.55)
}

pub fn sst2_like(n: usize, seed: u64) -> SentimentCorpus {
    SentimentCorpus::new(TextTask::Sst2, n, 32, 400, seed)
}

/// The paper's vision hyperparameters (DP-SGD, C=1, q-target 0.6) as a
/// run spec for the `resmlp` family.
pub fn vision_spec(method: Method, epsilon: f64, epochs: f64, seed: u64) -> RunSpec {
    let mut spec = RunSpec::for_config("resmlp");
    spec.clip = ClipPolicy {
        clip_init: 1.0,
        target_q: 0.6,
        ..ClipPolicy::from_method(method)
    };
    spec.privacy = PrivacySpec { epsilon: epsilon.max(1e-9), delta: 1e-5, quantile_r: 0.01 };
    spec.optim = OptimSpec::sgd(0.25);
    spec.epochs = epochs;
    spec.seed = seed;
    spec
}

/// The paper's text hyperparameters (DP-Adam, C=0.1, q-target 0.85) as a
/// run spec for the classifier/LM families.
pub fn text_spec(method: Method, epsilon: f64, epochs: f64, seed: u64) -> RunSpec {
    let mut spec = RunSpec::for_config("cls_small");
    spec.clip = ClipPolicy {
        clip_init: 0.1,
        target_q: 0.85,
        ..ClipPolicy::from_method(method)
    };
    spec.privacy = PrivacySpec { epsilon: epsilon.max(1e-9), delta: 1e-5, quantile_r: 0.1 };
    spec.optim = OptimSpec::adam(1e-3);
    spec.epochs = epochs;
    spec.seed = seed;
    spec
}

pub struct Acc {
    pub mean: f64,
    pub std: f64,
    pub train_acc: f64,
}

/// Train `method` on `config` and report eval accuracy over seeds.
#[allow(clippy::too_many_arguments)]
pub fn run_acc(
    rt: &Runtime,
    config: &str,
    method: Method,
    epsilon: f64,
    epochs: f64,
    scale: Scale,
    mk_spec: fn(Method, f64, f64, u64) -> RunSpec,
    mk_data: &dyn Fn(usize, u64) -> Box<dyn Dataset>,
    pretrain: Option<&str>,
) -> Result<Acc> {
    let mut vals = Vec::new();
    let mut train_acc = 0.0;
    for seed in 0..scale.seeds as u64 {
        let train = mk_data(scale.data, seed);
        let eval = mk_data(scale.data / 4, seed + 500);
        let mut spec = mk_spec(method, epsilon, epochs, seed);
        spec.config = config.to_string();
        let mut sess =
            session_with_init(rt, spec, train.len(), pretrain.map(|l| (l, mk_data)))?;
        sess.run(&*train, 0)?;
        let (_, acc) = sess.evaluate(&*eval)?;
        let (_, tacc) = sess.evaluate(&*train)?;
        vals.push(acc);
        train_acc += tacc;
    }
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
    Ok(Acc { mean: 100.0 * mean, std: 100.0 * var.sqrt(), train_acc: 100.0 * train_acc / vals.len() as f64 })
}

fn cifar_data(scale: Scale) -> Box<dyn Fn(usize, u64) -> Box<dyn Dataset>> {
    let _ = scale;
    Box::new(|n, s| Box::new(cifar_like(n, s)) as Box<dyn Dataset>)
}

fn sst2_data() -> Box<dyn Fn(usize, u64) -> Box<dyn Dataset>> {
    Box::new(|n, s| Box::new(sst2_like(n, s)) as Box<dyn Dataset>)
}

/// Table 1: fixed per-layer underperforms fixed flat (both tasks).
pub fn table1(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["Task", "Method", "eps=3", "eps=8"]);
    let setups: Vec<(&str, &str, fn(Method, f64, f64, u64) -> RunSpec, Box<dyn Fn(usize, u64) -> Box<dyn Dataset>>, Option<&str>)> = vec![
        ("CIFAR-10 analog (WideResMLP)", "resmlp", vision_spec, cifar_data(scale), None),
        ("SST-2 analog (encoder)", "cls_small", text_spec, sst2_data(), Some("sst2")),
    ];
    for (task, config, spec_fn, data, pre) in setups {
        for method in [Method::PerLayerFixed, Method::FlatFixed] {
            let mut cells = vec![task.to_string(), method.name().to_string()];
            for eps in [3.0, 8.0] {
                let a = run_acc(rt, config, method, eps, scale.epochs, scale, spec_fn, &*data, pre)?;
                cells.push(format!("{} ({})", fmt_f(a.mean, 1), fmt_f(a.std, 2)));
            }
            t.row(&cells);
            eprintln!("[table1] {} {} done", task, method.name());
        }
    }
    t.save("results/table1.md", "Table 1: fixed per-layer clipping underperforms fixed flat clipping")?;
    println!("{}", t.render());
    Ok(())
}

/// Table 2: CIFAR analog, flat vs adaptive per-layer across eps.
pub fn table2(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&[
        "Method", "e=1 train", "e=1 valid", "e=3 train", "e=3 valid",
        "e=5 train", "e=5 valid", "e=8 train", "e=8 valid",
    ]);
    let data = cifar_data(scale);
    for method in [Method::FlatFixed, Method::PerLayerAdaptive] {
        let mut cells = vec![method.name().to_string()];
        for eps in [1.0, 3.0, 5.0, 8.0] {
            let a = run_acc(rt, "resmlp", method, eps, scale.epochs, scale, vision_spec, &*data, None)?;
            cells.push(fmt_f(a.train_acc, 1));
            cells.push(fmt_f(a.mean, 1));
            eprintln!("[table2] {} eps={eps} -> {:.1}", method.name(), a.mean);
        }
        t.row(&cells);
    }
    t.save("results/table2.md", "Table 2: adaptive per-layer matches flat clipping on CIFAR-10 analog")?;
    println!("{}", t.render());
    Ok(())
}

/// Table 3: GLUE-analog suite, adaptive per-layer vs flat, eps in {3,8}.
pub fn table3(rt: &Runtime, scale: Scale) -> Result<()> {
    let tasks = [TextTask::MnliLike, TextTask::Qqp, TextTask::Qnli, TextTask::Sst2];
    let mut t = MdTable::new(&["Method", "eps", "MNLI", "QQP", "QNLI", "SST-2"]);
    for method in [Method::FlatFixed, Method::PerLayerAdaptive] {
        for eps in [3.0, 8.0] {
            let mut cells = vec![method.name().to_string(), format!("{eps}")];
            for task in tasks {
                let data: Box<dyn Fn(usize, u64) -> Box<dyn Dataset>> = Box::new(move |n, s| {
                    Box::new(SentimentCorpus::new(task, n, 32, 400, s)) as Box<dyn Dataset>
                });
                let a = run_acc(rt, "cls_small", method, eps, scale.epochs, scale, text_spec, &*data, Some(task.name()))?;
                cells.push(fmt_f(a.mean, 1));
                eprintln!("[table3] {} {} eps={eps} -> {:.1}", method.name(), task.name(), a.mean);
            }
            t.row(&cells);
        }
    }
    t.save("results/table3.md", "Table 3: GLUE-analog accuracy, adaptive per-layer vs flat")?;
    println!("{}", t.render());
    Ok(())
}

/// Tables 4 + 12: accuracy under fixed epoch budgets, eps in {3, 8}.
pub fn table4(rt: &Runtime, scale: Scale) -> Result<()> {
    let epoch_grid: Vec<f64> = if scale.epochs > 5.0 {
        vec![3.0, 10.0, 20.0, 30.0]
    } else {
        vec![1.0, 2.0, 4.0, 6.0]
    };
    let mut t = MdTable::new(&["eps", "Method", "E1", "E2", "E3", "E4"]);
    let data = sst2_data();
    for eps in [3.0, 8.0] {
        for method in [Method::FlatFixed, Method::PerLayerAdaptive] {
            let mut cells = vec![format!("{eps}"), method.name().to_string()];
            for &e in &epoch_grid {
                let a = run_acc(rt, "cls_small", method, eps, e, scale, text_spec, &*data, Some("sst2"))?;
                cells.push(format!("{} ({})", fmt_f(a.mean, 1), fmt_f(a.std, 2)));
                eprintln!("[table4] eps={eps} {} E={e} -> {:.1}", method.name(), a.mean);
            }
            t.row(&cells);
        }
    }
    t.save(
        "results/table4.md",
        &format!(
            "Tables 4/12: SST-2 analog accuracy under epoch budgets {:?} (adaptive per-layer is also ~1.3-2x faster per epoch; see fig1)",
            epoch_grid
        ),
    )?;
    println!("{}", t.render());
    Ok(())
}

/// Table 10: noise-allocation strategies (Appendix E).
pub fn table10(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["Strategy", "eps=3 train", "eps=3 valid", "eps=8 train", "eps=8 valid"]);
    let data = sst2_data();
    for (name, alloc) in [
        ("Global", Allocation::Global),
        ("Equal budget", Allocation::EqualBudget),
        ("Weighted (equal SNR)", Allocation::Weighted),
    ] {
        let mut cells = vec![name.to_string()];
        for eps in [3.0, 8.0] {
            let mut vals = Vec::new();
            let mut tacc_sum = 0.0;
            for seed in 0..scale.seeds as u64 {
                let train = data(scale.data, seed);
                let eval = data(scale.data / 4, seed + 500);
                let mut spec = text_spec(Method::PerLayerAdaptive, eps, scale.epochs, seed);
                spec.clip.allocation = alloc;
                let mut sess =
                    session_with_init(rt, spec, train.len(), Some(("sst2", &*data)))?;
                sess.run(&*train, 0)?;
                let (_, acc) = sess.evaluate(&*eval)?;
                let (_, tacc) = sess.evaluate(&*train)?;
                vals.push(acc);
                tacc_sum += tacc;
            }
            let mean = 100.0 * vals.iter().sum::<f64>() / vals.len() as f64;
            cells.push(fmt_f(100.0 * tacc_sum / vals.len() as f64, 1));
            cells.push(fmt_f(mean, 1));
            eprintln!("[table10] {name} eps={eps} -> {mean:.1}");
        }
        t.row(&cells);
    }
    t.save("results/table10.md", "Table 10: noise allocation strategies (adaptive per-layer, SST-2 analog)")?;
    println!("{}", t.render());
    Ok(())
}

/// Table 11: adaptivity ablation — fixed/adaptive x flat/per-layer.
pub fn table11(rt: &Runtime, scale: Scale) -> Result<()> {
    let mut t = MdTable::new(&["Task", "Method", "eps=3", "eps=8"]);
    let setups: Vec<(&str, &str, fn(Method, f64, f64, u64) -> RunSpec, Box<dyn Fn(usize, u64) -> Box<dyn Dataset>>, Option<&str>)> = vec![
        ("CIFAR analog", "resmlp", vision_spec, cifar_data(scale), None),
        ("SST-2 analog", "cls_small", text_spec, sst2_data(), Some("sst2")),
    ];
    for (task, config, spec_fn, data, pre) in setups {
        for method in [
            Method::FlatFixed,
            Method::FlatAdaptive,
            Method::PerLayerFixed,
            Method::PerLayerAdaptive,
        ] {
            let mut cells = vec![task.to_string(), method.name().to_string()];
            for eps in [3.0, 8.0] {
                let a = run_acc(rt, config, method, eps, scale.epochs, scale, spec_fn, &*data, pre)?;
                cells.push(format!("{} ({})", fmt_f(a.mean, 1), fmt_f(a.std, 2)));
                eprintln!("[table11] {task} {} eps={eps} -> {:.1}", method.name(), a.mean);
            }
            t.row(&cells);
        }
    }
    t.save("results/table11.md", "Table 11: adaptivity helps per-layer clipping much more than flat clipping")?;
    println!("{}", t.render());
    Ok(())
}
