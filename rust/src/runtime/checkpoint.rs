//! GWCK checkpoint format, shared with python/compile/aot.py:
//!   b"GWCK" | u32 version | u32 json_len | header json | raw f32 LE data
//! header = [{name, shape, offset}] with offsets into the payload region.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug)]
struct Entry {
    name: String,
    shape: Vec<usize>,
    offset: u64,
}

/// Read a checkpoint into name -> Tensor.
pub fn read(path: impl AsRef<Path>) -> Result<HashMap<String, Tensor>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"GWCK" {
        return Err(anyhow!("bad checkpoint magic {:?}", magic));
    }
    let mut hdr = [0u8; 8];
    f.read_exact(&mut hdr)?;
    let version = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    if version != 1 {
        return Err(anyhow!("unsupported checkpoint version {version}"));
    }
    let json_len = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let mut jbuf = vec![0u8; json_len];
    f.read_exact(&mut jbuf)?;
    let j = Json::parse(std::str::from_utf8(&jbuf)?)?;
    let entries: Vec<Entry> = j
        .arr()?
        .iter()
        .map(|e| {
            Ok(Entry {
                name: e.get("name")?.str()?.to_string(),
                shape: e.get("shape")?.usizes()?,
                offset: e.get("offset")?.u64()?,
            })
        })
        .collect::<Result<_>>()?;
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;

    let mut out = HashMap::new();
    for e in entries {
        let n: usize = e.shape.iter().product();
        let start = e.offset as usize;
        let end = start + n * 4;
        if end > payload.len() {
            return Err(anyhow!("checkpoint truncated at tensor {}", e.name));
        }
        let mut data = vec![0f32; n];
        for (i, ch) in payload[start..end].chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(ch.try_into().unwrap());
        }
        out.insert(e.name, Tensor { shape: e.shape, data });
    }
    Ok(out)
}

/// Write tensors in the given order.
pub fn write(path: impl AsRef<Path>, tensors: &[(String, &Tensor)]) -> Result<()> {
    let mut entries = Vec::new();
    let mut offset = 0u64;
    for (name, t) in tensors {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(name.clone()));
        m.insert(
            "shape".to_string(),
            Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        m.insert("offset".to_string(), Json::Num(offset as f64));
        entries.push(Json::Obj(m));
        offset += (t.data.len() * 4) as u64;
    }
    let json = Json::Arr(entries).render().into_bytes();
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating checkpoint {}", path.as_ref().display()))?;
    f.write_all(b"GWCK")?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(json.len() as u32).to_le_bytes())?;
    f.write_all(&json)?;
    for (_, t) in tensors {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("gwck_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ck.bin");
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[3], vec![-1., 0., 9.5]).unwrap();
        write(&p, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let m = read(&p).unwrap();
        assert_eq!(m["a"], a);
        assert_eq!(m["b"], b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("gwck_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(read(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
