//! Parse artifacts/manifest.json — the contract between the AOT compile
//! path (python/compile/aot.py) and the rust runtime. Parsed with the
//! in-tree JSON module (no serde offline).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub configs: HashMap<String, ConfigManifest>,
    pub root: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ConfigManifest {
    pub model: String,
    pub hyper: Hyper,
    pub batch: usize,
    pub params: Vec<ParamInfo>,
    pub groups: Vec<String>,
    pub group_dims: Vec<u64>,
    pub entries: HashMap<String, EntryInfo>,
    pub stages: Option<StagesInfo>,
    pub init_checkpoint: String,
}

#[derive(Debug, Clone, Default)]
pub struct Hyper {
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_classes: usize,
    pub features: usize,
    pub width: usize,
    pub blocks: usize,
    pub lora_rank: usize,
    pub lora_scale: f64,
    pub use_pallas: bool,
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub group: String,
    pub trainable: bool,
    pub size: u64,
}

#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub file: String,
    pub extra_inputs: Vec<IoInfo>,
    pub outputs: Vec<IoInfo>,
}

#[derive(Debug, Clone)]
pub struct IoInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Debug, Clone)]
pub struct StagesInfo {
    pub boundaries: Vec<usize>,
    pub stages: Vec<StageInfo>,
}

#[derive(Debug, Clone)]
pub struct StageInfo {
    pub params: Vec<String>,
    pub trainable: Vec<String>,
    pub d_stage: u64,
}

fn io_info(j: &Json) -> Result<IoInfo> {
    Ok(IoInfo {
        name: j.get("name")?.str()?.to_string(),
        shape: j.get("shape")?.usizes()?,
        dtype: j.get("dtype")?.str()?.to_string(),
    })
}

fn opt_usize(j: &Json, key: &str) -> usize {
    j.opt(key).and_then(|v| v.usize().ok()).unwrap_or(0)
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut configs = HashMap::new();
        for (name, c) in j.get("configs")?.obj()? {
            configs.insert(name.clone(), Self::parse_config(c).with_context(|| name.clone())?);
        }
        Ok(Manifest {
            version: j.get("version")?.u64()?,
            configs,
            root: dir.to_path_buf(),
        })
    }

    fn parse_config(c: &Json) -> Result<ConfigManifest> {
        let h = c.get("hyper")?;
        let hyper = Hyper {
            vocab: opt_usize(h, "vocab"),
            seq: opt_usize(h, "seq"),
            d_model: opt_usize(h, "d_model"),
            n_heads: opt_usize(h, "n_heads"),
            n_layers: opt_usize(h, "n_layers"),
            d_ff: opt_usize(h, "d_ff"),
            n_classes: opt_usize(h, "n_classes"),
            features: opt_usize(h, "features"),
            width: opt_usize(h, "width"),
            blocks: opt_usize(h, "blocks"),
            lora_rank: opt_usize(h, "lora_rank"),
            lora_scale: h.opt("lora_scale").and_then(|v| v.f64().ok()).unwrap_or(2.0),
            use_pallas: h.opt("use_pallas").and_then(|v| v.bool().ok()).unwrap_or(false),
        };
        let params = c
            .get("params")?
            .arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.str()?.to_string(),
                    shape: p.get("shape")?.usizes()?,
                    group: p.get("group")?.str()?.to_string(),
                    trainable: p.get("trainable")?.bool()?,
                    size: p.get("size")?.u64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut entries = HashMap::new();
        for (ename, e) in c.get("entries")?.obj()? {
            entries.insert(
                ename.clone(),
                EntryInfo {
                    file: e.get("file")?.str()?.to_string(),
                    extra_inputs: e
                        .get("extra_inputs")?
                        .arr()?
                        .iter()
                        .map(io_info)
                        .collect::<Result<_>>()?,
                    outputs: e
                        .get("outputs")?
                        .arr()?
                        .iter()
                        .map(io_info)
                        .collect::<Result<_>>()?,
                },
            );
        }
        let stages = match c.opt("stages") {
            None => None,
            Some(s) => Some(StagesInfo {
                boundaries: s.get("boundaries")?.usizes()?,
                stages: s
                    .get("stages")?
                    .arr()?
                    .iter()
                    .map(|st| {
                        Ok(StageInfo {
                            params: st.get("params")?.strings()?,
                            trainable: st.get("trainable")?.strings()?,
                            d_stage: st.get("d_stage")?.u64()?,
                        })
                    })
                    .collect::<Result<_>>()?,
            }),
        };
        Ok(ConfigManifest {
            model: c.get("model")?.str()?.to_string(),
            hyper,
            batch: c.get("batch")?.usize()?,
            params,
            groups: c.get("groups")?.strings()?,
            group_dims: c
                .get("group_dims")?
                .arr()?
                .iter()
                .map(|v| v.u64())
                .collect::<Result<_>>()?,
            entries,
            stages,
            init_checkpoint: c.get("init_checkpoint")?.str()?.to_string(),
        })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigManifest> {
        self.configs.get(name).ok_or_else(|| {
            let mut v: Vec<_> = self.configs.keys().collect();
            v.sort();
            anyhow!("config '{}' not in manifest (have: {:?})", name, v)
        })
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.root.join(file)
    }
}

impl ConfigManifest {
    pub fn entry(&self, name: &str) -> Result<&EntryInfo> {
        self.entries.get(name).ok_or_else(|| {
            let mut v: Vec<_> = self.entries.keys().collect();
            v.sort();
            anyhow!("entry '{}' not exported for this config (have: {:?})", name, v)
        })
    }

    pub fn trainable(&self) -> Vec<&ParamInfo> {
        self.params.iter().filter(|p| p.trainable).collect()
    }

    /// Index of each group name.
    pub fn group_index(&self) -> HashMap<&str, usize> {
        self.groups.iter().enumerate().map(|(i, g)| (g.as_str(), i)).collect()
    }

    /// Total trainable parameter count.
    pub fn n_trainable(&self) -> u64 {
        self.group_dims.iter().sum()
    }
}
