//! L3 runtime: load AOT artifacts (HLO text) and execute them on the PJRT
//! CPU client. This is the only module that touches the `xla` crate; the
//! rest of the coordinator works with host [`Tensor`]s.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs: HLO *text* ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`.

pub mod checkpoint;
pub mod manifest;
pub mod tensor;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};
use xla::Literal;

pub use manifest::{ConfigManifest, EntryInfo, Manifest};
pub use tensor::{HostValue, IntTensor, Tensor};

/// A compiled entry point plus its manifest IO description.
pub struct Exec {
    pub info: EntryInfo,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the xla binding does not mark PjRtLoadedExecutable Send/Sync,
// but the PJRT C API guarantees a loaded executable is immutable after
// compilation and supports concurrent PJRT_LoadedExecutable_Execute calls
// from multiple threads (the CPU client serializes internally where it
// must). `Exec` exposes only `&self` execution over that handle — no
// interior mutation on our side — so sharing an `Arc<Exec>` across the
// step loop's collect threads is sound.
unsafe impl Send for Exec {}
unsafe impl Sync for Exec {}

impl Exec {
    /// Execute with pre-marshalled literals; returns the decomposed tuple.
    pub fn call_literals(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with params + extra inputs; returns outputs as host values.
    pub fn call(&self, params: &[Tensor], extras: &[HostValue]) -> Result<Vec<Tensor>> {
        if extras.len() != self.info.extra_inputs.len() {
            return Err(anyhow!(
                "entry {} wants {} extra inputs, got {}",
                self.info.file,
                self.info.extra_inputs.len(),
                extras.len()
            ));
        }
        let mut args = Vec::with_capacity(params.len() + extras.len());
        for p in params {
            args.push(p.to_literal()?);
        }
        for e in extras {
            args.push(e.to_literal()?);
        }
        let outs = self.call_literals(&args)?;
        if outs.len() != self.info.outputs.len() {
            return Err(anyhow!(
                "entry {} returned {} outputs, manifest says {}",
                self.info.file,
                outs.len(),
                self.info.outputs.len()
            ));
        }
        let mut res = Vec::with_capacity(outs.len());
        for (lit, io) in outs.iter().zip(&self.info.outputs) {
            res.push(Tensor::from_literal(lit, &io.shape)?);
        }
        Ok(res)
    }

    /// Position of a named output.
    pub fn out_index(&self, name: &str) -> Result<usize> {
        self.info
            .outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| anyhow!("no output '{}' in {}", name, self.info.file))
    }
}

/// PJRT client + compiled-executable cache, manifest-driven.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Exec>>>,
}

// SAFETY: sharing `&Runtime` across the step loop's collect threads is
// sound because every PJRT C API function is thread-safe unless its
// documentation says otherwise (compilation included — the CPU plugin
// locks internally), the executable cache is already mutex-guarded, and
// the manifest is plain immutable host data. Engines hold `&Runtime`
// inside the per-unit collect closures, which is what forces this bound;
// the `Runtime` value itself is never moved off the thread that built it.
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    /// Load (and cache) a compiled entry point for `config`.
    pub fn load(&self, config: &str, entry: &str) -> Result<Arc<Exec>> {
        let key = format!("{config}/{entry}");
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let cfg = self.manifest.config(config)?;
        let info = cfg.entry(entry)?.clone();
        let path = self.manifest.hlo_path(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", info.file))?;
        let exec = Arc::new(Exec { info, exe });
        self.cache.lock().unwrap().insert(key, exec.clone());
        Ok(exec)
    }

    /// Load the init checkpoint for a config, in manifest param order.
    pub fn init_params(&self, config: &str) -> Result<Vec<Tensor>> {
        let cfg = self.manifest.config(config)?;
        let path = self.manifest.hlo_path(&cfg.init_checkpoint);
        let map = checkpoint::read(&path)?;
        params_from_map(cfg, &map)
    }

    /// Replica checkpoint fan-out for the sharded data-parallel backend:
    /// read the init checkpoint once and hand out `n` bit-identical
    /// full-model parameter sets (one per simulated worker). Cloning on
    /// the host models the broadcast a real cluster performs at startup.
    pub fn init_replicas(&self, config: &str, n: usize) -> Result<Vec<Vec<Tensor>>> {
        if n == 0 {
            return Err(anyhow!("init_replicas needs n > 0"));
        }
        let base = self.init_params(config)?;
        let mut replicas = Vec::with_capacity(n);
        for _ in 1..n {
            replicas.push(base.clone());
        }
        replicas.push(base);
        Ok(replicas)
    }
}

/// Order a name->Tensor map by a config's param specs.
pub fn params_from_map(
    cfg: &ConfigManifest,
    map: &HashMap<String, Tensor>,
) -> Result<Vec<Tensor>> {
    cfg.params
        .iter()
        .map(|p| {
            let t = map
                .get(&p.name)
                .ok_or_else(|| anyhow!("checkpoint missing tensor {}", p.name))?;
            if t.shape != p.shape {
                return Err(anyhow!(
                    "tensor {} shape {:?} != manifest {:?}",
                    p.name,
                    t.shape,
                    p.shape
                ));
            }
            Ok(t.clone())
        })
        .collect()
}
