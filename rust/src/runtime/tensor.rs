//! Host-side tensors and conversion to/from PJRT literals.
//!
//! The coordinator owns all mutable state (parameters, optimizer moments) as
//! flat f32 buffers; literals are created right before each executable call.

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

/// A dense f32 tensor on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {} elems, got {}", shape, n, data.len()));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &self.shape,
            bytes,
        )?)
    }

    pub fn from_literal(lit: &Literal, shape: &[usize]) -> Result<Self> {
        let data: Vec<f32> = lit.to_vec()?;
        Tensor::from_vec(shape, data)
    }
}

/// A dense i32 tensor on the host (tokens, labels).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        IntTensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {} elems, got {}", shape, n, data.len()));
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &self.shape,
            bytes,
        )?)
    }
}

/// Either dtype, as the manifest's extra-input list is heterogeneous.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(IntTensor),
}

impl HostValue {
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            HostValue::F32(t) => t.to_literal(),
            HostValue::I32(t) => t.to_literal(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_through_literal() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0]).is_err());
        assert!(IntTensor::from_vec(&[3], vec![1, 2]).is_err());
    }

    #[test]
    fn norm_is_euclidean() {
        let t = Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap();
        assert!((t.norm() - 5.0).abs() < 1e-9);
    }
}
