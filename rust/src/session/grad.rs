//! Shared gradient containers for the [`StepLoop`] — the merged-gradient /
//! clip-count shapes every backend's [`BackendStep`] hooks speak.
//!
//! A step's pre-noise output is a set of [`GradUnit`]s, one per
//! data-parallel participant (the single-device and pipeline backends have
//! exactly one; the sharded backend one per worker; the hybrid backend one
//! per replica). Each unit flattens its summed trainable gradients into
//! ONE tensor sequence whose iteration order IS the backend's RNG
//! discipline: the loop walks units in order and tensors within a unit in
//! order when drawing gradient noise, so a backend encodes its documented
//! noise order (worker-major for sharded, replica-major/stage-major for
//! hybrid, stage-major for pipeline) purely by how it lays the tensors
//! out — no backend touches the RNG itself.
//!
//! [`StepLoop`]: super::steploop::StepLoop
//! [`BackendStep`]: super::steploop::BackendStep

use std::collections::HashMap;

use crate::pipeline::schedule::Op;
use crate::runtime::Tensor;

/// One data-parallel participant's pre-noise gradient contribution.
pub(crate) struct GradUnit {
    /// summed trainable gradients, flattened in this unit's noise order
    /// (the backend's documented tensor order within the unit)
    pub tensors: Vec<Tensor>,
    /// threshold/noise group index per tensor (indexes the shared
    /// `DpCore` thresholds and per-group noise stds); len == tensors.len()
    pub groups: Vec<usize>,
}

/// Backend-measured timings the merge hook turns into simulated
/// makespans. Backends fill only the fields their latency model reads.
#[derive(Default)]
pub(crate) struct StepTiming {
    /// per-(stage, micro, phase) op durations, one map per unit
    /// (pipeline: one map; hybrid: one per replica)
    pub durations: Vec<HashMap<Op, f64>>,
    /// per-worker whole-backward seconds (sharded backend)
    pub bwd_secs: Vec<f64>,
}

/// One unit's contribution to a step's collection, produced by a single
/// [`BackendStep::collect_tasks`] task — the Send closure the loop may run
/// on its own OS thread. Tasks are RNG-free and touch only their own
/// unit's state, so the threaded fan-out is bitwise identical to running
/// the same closures sequentially; everything order- or backend-sensitive
/// (loss convention, mean-norm denominators, clip_frac denominators)
/// happens afterwards on the main thread in
/// [`BackendStep::finish_collect`].
///
/// [`BackendStep::collect_tasks`]: super::steploop::BackendStep::collect_tasks
/// [`BackendStep::finish_collect`]: super::steploop::BackendStep::finish_collect
pub(crate) struct UnitCollected {
    /// this unit's summed pre-noise gradients
    pub unit: GradUnit,
    /// full-K clip-count contribution (zeros where this unit counts none)
    pub clip_counts: Vec<f64>,
    /// full-K per-example norm sums; `finish_collect` picks denominators
    pub norm_sums: Vec<f64>,
    /// weighted loss sum and its weight, in the backend's convention
    /// (step loss = sum(loss_wsum) / sum(weight_sum).max(1.0))
    pub loss_wsum: f64,
    pub weight_sum: f64,
    /// live examples this unit processed
    pub live: usize,
    /// executable invocations / sync barriers this unit incurred
    pub calls: usize,
    pub syncs: usize,
    /// measured whole-backward seconds (prefix-sum latency models)
    pub bwd_secs: f64,
    /// wall seconds the task spent executing — measured by the loop's
    /// task runner, not the backend; feeds the measured StepEvent columns
    pub busy_secs: f64,
    /// when the task runner started this task (observability only —
    /// becomes a per-unit collect span when tracing is enabled)
    pub task_t0: Option<std::time::Instant>,
    /// hashed OS-thread id the task ran on (observability only — keys
    /// the per-thread collect track in the Chrome trace export)
    pub task_thread: u64,
    /// per-(stage, micro, phase) op durations (pipeline-style units)
    pub durations: HashMap<Op, f64>,
    /// raw per-example norms when the backend is asked to keep them
    pub norms: Vec<f32>,
}

impl UnitCollected {
    /// A zeroed contribution around `unit` with `k` threshold groups.
    pub fn new(unit: GradUnit, k: usize) -> Self {
        UnitCollected {
            unit,
            clip_counts: vec![0.0; k],
            norm_sums: vec![0.0; k],
            loss_wsum: 0.0,
            weight_sum: 0.0,
            live: 0,
            calls: 0,
            syncs: 0,
            bwd_secs: 0.0,
            busy_secs: 0.0,
            task_t0: None,
            task_thread: 0,
            durations: HashMap::new(),
            norms: Vec::new(),
        }
    }
}

/// The order-preserving fold of per-unit contributions every backend's
/// `finish_collect` starts from: units in task (unit-major) order, counts
/// and sums accumulated in that same order so the threaded path reduces
/// exactly like the old sequential loops did.
pub(crate) struct FoldedParts {
    pub units: Vec<GradUnit>,
    pub clip_counts: Vec<f64>,
    pub norm_sums: Vec<f64>,
    pub loss_wsum: f64,
    pub weight_sum: f64,
    pub live: usize,
    pub calls: usize,
    pub syncs: usize,
    /// per-unit live counts, in unit order (per-device denominators)
    pub lives: Vec<usize>,
    /// per-unit measured backward seconds, in unit order
    pub bwd_secs: Vec<f64>,
    /// per-unit op-duration maps, in unit order
    pub durations: Vec<HashMap<Op, f64>>,
    /// per-unit raw norm vectors (empty unless collected)
    pub norms: Vec<Vec<f32>>,
}

pub(crate) fn fold_parts(parts: Vec<UnitCollected>, k: usize) -> FoldedParts {
    let mut f = FoldedParts {
        units: Vec::with_capacity(parts.len()),
        clip_counts: vec![0.0; k],
        norm_sums: vec![0.0; k],
        loss_wsum: 0.0,
        weight_sum: 0.0,
        live: 0,
        calls: 0,
        syncs: 0,
        lives: Vec::with_capacity(parts.len()),
        bwd_secs: Vec::with_capacity(parts.len()),
        durations: Vec::with_capacity(parts.len()),
        norms: Vec::new(),
    };
    for p in parts {
        for (a, b) in f.clip_counts.iter_mut().zip(&p.clip_counts) {
            *a += *b;
        }
        for (a, b) in f.norm_sums.iter_mut().zip(&p.norm_sums) {
            *a += *b;
        }
        f.loss_wsum += p.loss_wsum;
        f.weight_sum += p.weight_sum;
        f.live += p.live;
        f.calls += p.calls;
        f.syncs += p.syncs;
        f.lives.push(p.live);
        f.bwd_secs.push(p.bwd_secs);
        f.durations.push(p.durations);
        if !p.norms.is_empty() {
            f.norms.push(p.norms);
        }
        f.units.push(p.unit);
    }
    f
}

/// Pre-noise output of one collection phase: everything the
/// generic loop needs to finish the step — per-unit gradients for the
/// noise/merge phases, raw clip counts for the private quantile release,
/// and the step's reporting fields. Assembled from per-unit
/// [`UnitCollected`] parts by [`BackendStep::finish_collect`].
///
/// [`BackendStep::finish_collect`]: super::steploop::BackendStep::finish_collect
pub(crate) struct Collected {
    /// one entry per data-parallel unit, in RNG (unit-major) order
    pub units: Vec<GradUnit>,
    /// raw per-threshold-group clip counts (the quantile statistic);
    /// always len == DpCore::k(), zeros when nothing was counted
    pub clip_counts: Vec<f64>,
    /// per-group denominators turning clip counts into clipped fractions
    /// for reporting; empty = this backend does not report clip_frac
    pub clip_denoms: Vec<f64>,
    /// mean per-example norm per group (empty where not collected)
    pub mean_norms: Vec<f64>,
    /// step loss in this backend's reporting convention
    pub loss: f64,
    /// live examples across all units this step
    pub live: usize,
    /// examples the draw included but static capacity dropped
    pub truncated: usize,
    /// executable invocations (0 on the single-device backend, whose
    /// one fused call is the baseline the others are compared against)
    pub calls: usize,
    /// synchronization barriers incurred during collection (pipeline
    /// modes); the merge hook adds its own reduction rounds on top
    pub syncs: usize,
    /// measured timings for the merge hook's latency model
    pub timing: StepTiming,
}

/// Output of one [`BackendStep::merge`] phase: the reduced gradient set
/// (flattened in the same order as a unit's tensors) plus the simulated
/// makespans of the cross-unit reduction.
///
/// [`BackendStep::merge`]: super::steploop::BackendStep::merge
pub(crate) struct Merged {
    /// reduced gradients, same flattened order as each unit's tensors
    pub tensors: Vec<Tensor>,
    /// simulated step latency under the backend's configured reduction
    pub sim_secs: f64,
    /// simulated latency with the reduction overlapped into backprop
    pub sim_overlap_secs: f64,
    /// simulated latency with a reduce-after-backward barrier
    pub sim_barrier_secs: f64,
    /// reduction tree rounds this merge traversed
    pub syncs: usize,
}

impl Merged {
    /// The identity merge of backends with a single unit (single-device,
    /// pipeline): the unit's tensors pass through bitwise untouched.
    pub fn identity(mut units: Vec<GradUnit>) -> Merged {
        debug_assert_eq!(units.len(), 1, "identity merge expects one unit");
        Merged {
            tensors: units.pop().map(|u| u.tensors).unwrap_or_default(),
            sim_secs: 0.0,
            sim_overlap_secs: 0.0,
            sim_barrier_secs: 0.0,
            syncs: 0,
        }
    }
}
