//! Shared gradient containers for the [`StepLoop`] — the merged-gradient /
//! clip-count shapes every backend's [`BackendStep`] hooks speak.
//!
//! A step's pre-noise output is a set of [`GradUnit`]s, one per
//! data-parallel participant (the single-device and pipeline backends have
//! exactly one; the sharded backend one per worker; the hybrid backend one
//! per replica). Each unit flattens its summed trainable gradients into
//! ONE tensor sequence whose iteration order IS the backend's RNG
//! discipline: the loop walks units in order and tensors within a unit in
//! order when drawing gradient noise, so a backend encodes its documented
//! noise order (worker-major for sharded, replica-major/stage-major for
//! hybrid, stage-major for pipeline) purely by how it lays the tensors
//! out — no backend touches the RNG itself.
//!
//! [`StepLoop`]: super::steploop::StepLoop
//! [`BackendStep`]: super::steploop::BackendStep

use std::collections::HashMap;

use crate::pipeline::schedule::Op;
use crate::runtime::Tensor;

/// One data-parallel participant's pre-noise gradient contribution.
pub(crate) struct GradUnit {
    /// summed trainable gradients, flattened in this unit's noise order
    /// (the backend's documented tensor order within the unit)
    pub tensors: Vec<Tensor>,
    /// threshold/noise group index per tensor (indexes the shared
    /// `DpCore` thresholds and per-group noise stds); len == tensors.len()
    pub groups: Vec<usize>,
}

/// Backend-measured timings the merge hook turns into simulated
/// makespans. Backends fill only the fields their latency model reads.
#[derive(Default)]
pub(crate) struct StepTiming {
    /// per-(stage, micro, phase) op durations, one map per unit
    /// (pipeline: one map; hybrid: one per replica)
    pub durations: Vec<HashMap<Op, f64>>,
    /// per-worker whole-backward seconds (sharded backend)
    pub bwd_secs: Vec<f64>,
}

/// Pre-noise output of one [`BackendStep::collect`] phase: everything the
/// generic loop needs to finish the step — per-unit gradients for the
/// noise/merge phases, raw clip counts for the private quantile release,
/// and the step's reporting fields.
///
/// [`BackendStep::collect`]: super::steploop::BackendStep::collect
pub(crate) struct Collected {
    /// one entry per data-parallel unit, in RNG (unit-major) order
    pub units: Vec<GradUnit>,
    /// raw per-threshold-group clip counts (the quantile statistic);
    /// always len == DpCore::k(), zeros when nothing was counted
    pub clip_counts: Vec<f64>,
    /// per-group denominators turning clip counts into clipped fractions
    /// for reporting; empty = this backend does not report clip_frac
    pub clip_denoms: Vec<f64>,
    /// mean per-example norm per group (empty where not collected)
    pub mean_norms: Vec<f64>,
    /// step loss in this backend's reporting convention
    pub loss: f64,
    /// live examples across all units this step
    pub live: usize,
    /// examples the draw included but static capacity dropped
    pub truncated: usize,
    /// executable invocations (0 on the single-device backend, whose
    /// one fused call is the baseline the others are compared against)
    pub calls: usize,
    /// synchronization barriers incurred during collection (pipeline
    /// modes); the merge hook adds its own reduction rounds on top
    pub syncs: usize,
    /// measured timings for the merge hook's latency model
    pub timing: StepTiming,
}

/// Output of one [`BackendStep::merge`] phase: the reduced gradient set
/// (flattened in the same order as a unit's tensors) plus the simulated
/// makespans of the cross-unit reduction.
///
/// [`BackendStep::merge`]: super::steploop::BackendStep::merge
pub(crate) struct Merged {
    /// reduced gradients, same flattened order as each unit's tensors
    pub tensors: Vec<Tensor>,
    /// simulated step latency under the backend's configured reduction
    pub sim_secs: f64,
    /// simulated latency with the reduction overlapped into backprop
    pub sim_overlap_secs: f64,
    /// simulated latency with a reduce-after-backward barrier
    pub sim_barrier_secs: f64,
    /// reduction tree rounds this merge traversed
    pub syncs: usize,
}

impl Merged {
    /// The identity merge of backends with a single unit (single-device,
    /// pipeline): the unit's tensors pass through bitwise untouched.
    pub fn identity(mut units: Vec<GradUnit>) -> Merged {
        debug_assert_eq!(units.len(), 1, "identity merge expects one unit");
        Merged {
            tensors: units.pop().map(|u| u.tensors).unwrap_or_default(),
            sim_secs: 0.0,
            sim_overlap_secs: 0.0,
            sim_barrier_secs: 0.0,
            syncs: 0,
        }
    }
}
