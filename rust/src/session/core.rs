//! `DpCore` — the one DP state machine shared by every backend.
//!
//! Before the session refactor, `Trainer::new` and `PipelineEngine::new`
//! each wired their own `QuantileEstimator`, privacy plan, noise stds and
//! RNG (and the pipeline path skipped the accountant entirely). Both
//! backends now *receive* a `DpCore` built in exactly one place from the
//! declarative specs; a backend's job reduces to running executables and
//! feeding gradients/clip-counts through the core.
//!
//! The core owns, per Algorithm 1/2:
//! * the accountant-derived [`PrivacyPlan`] (line 2 + Prop 3.1 split),
//! * the per-group thresholds via [`QuantileEstimator`] (lines 15-18),
//! * the noise [`Allocation`] and per-group stds (line 13),
//! * the single deterministic [`Rng`] every stochastic choice draws from
//!   (Poisson sampling, gradient noise, quantile-release noise), which is
//!   what makes seed-for-seed parity across entry points possible.

use anyhow::{bail, Result};

use crate::coordinator::accountant::{self, PrivacyPlan};
use crate::coordinator::noise::{Allocation, Rng};
use crate::coordinator::quantile::QuantileEstimator;

use super::spec::{ClipPolicy, PrivacySpec};

/// Inputs for the accountant-driven construction path.
#[derive(Debug, Clone)]
pub struct CoreCfg<'a> {
    pub privacy: &'a PrivacySpec,
    pub clip: &'a ClipPolicy,
    /// Poisson sampling rate rho = E[B] / n
    pub sample_rate: f64,
    /// planned number of optimizer steps T
    pub steps: u64,
    /// number of clipping groups K (layers, devices, or 1)
    pub k: usize,
    /// per-group trainable dims (for the Weighted allocation); len == k
    pub group_dims: Vec<u64>,
    /// expected batch size B (normalizes quantile counts)
    pub expected_batch: f64,
    pub seed: u64,
}

pub struct DpCore {
    /// accountant output; `None` when non-private
    pub plan: Option<PrivacyPlan>,
    /// gradient noise multiplier actually applied (0 = no noise)
    pub sigma_grad: f64,
    pub quantiles: QuantileEstimator,
    pub allocation: Allocation,
    pub group_dims: Vec<u64>,
    /// global-equivalent threshold C (for the A.1 rescale)
    pub clip_init: f64,
    pub rescale_global: bool,
    pub rng: Rng,
}

impl DpCore {
    /// Build a core from specs, deriving sigma from the accountant. This
    /// is the only construction path — the legacy raw-sigma shim
    /// (`with_raw_sigma`) is retired with `Trainer::new` /
    /// `PipelineEngine::new`.
    pub fn from_accountant(cfg: CoreCfg) -> Result<Self> {
        cfg.clip.validate()?;
        let k = cfg.k.max(1);
        if cfg.group_dims.len() != k {
            bail!("DpCore: group_dims len {} != k {}", cfg.group_dims.len(), k);
        }
        let init = cfg.clip.init_thresholds(k);
        let adaptive = cfg.clip.is_adaptive();
        let (plan, sigma_grad) = if cfg.clip.is_private() {
            cfg.privacy.validate()?;
            if !(cfg.sample_rate > 0.0 && cfg.sample_rate <= 1.0) {
                bail!("DpCore: sampling rate {} outside (0, 1]", cfg.sample_rate);
            }
            if cfg.steps == 0 {
                bail!("DpCore: a private run needs steps > 0");
            }
            let r = if adaptive { cfg.privacy.quantile_r } else { 0.0 };
            // defense in depth behind RunSpec::validate: a private adaptive
            // core with r = 0 would release exact clip counts each step
            if adaptive && !(r > 0.0) {
                bail!(
                    "adaptive clipping needs privacy.quantile_r > 0 so the per-step \
                     clip-count releases are noised (Prop 3.1); got {r}"
                );
            }
            let p = accountant::plan(
                cfg.privacy.epsilon,
                cfg.privacy.delta,
                cfg.sample_rate,
                cfg.steps,
                r,
                k,
            );
            let sigma = p.sigma_grad;
            (Some(p), sigma)
        } else {
            (None, 0.0)
        };
        let quantiles = if adaptive && cfg.clip.is_private() {
            QuantileEstimator::adaptive(
                init,
                cfg.clip.target_q,
                cfg.clip.quantile_eta,
                plan.map(|p| p.sigma_quantile).unwrap_or(0.0),
                cfg.expected_batch,
            )
        } else {
            QuantileEstimator::fixed(init)
        };
        Ok(DpCore {
            plan,
            sigma_grad,
            quantiles,
            allocation: cfg.clip.allocation,
            group_dims: cfg.group_dims,
            clip_init: cfg.clip.clip_init,
            rescale_global: cfg.clip.rescale_global && k > 1,
            rng: Rng::seeded(cfg.seed),
        })
    }

    pub fn k(&self) -> usize {
        self.quantiles.k()
    }

    pub fn thresholds(&self) -> &[f64] {
        &self.quantiles.thresholds
    }

    pub fn is_adaptive(&self) -> bool {
        self.quantiles.is_adaptive()
    }

    /// Effective per-group noise stds at the current thresholds
    /// (Algorithm 1 line 13 / Algorithm 2 line 6). For K=1 every
    /// allocation degenerates to `sigma * C`; for the equal-budget
    /// allocation group k's std is `sigma * sqrt(K) * C_k`, the
    /// communication-free per-device formula.
    pub fn noise_stds(&self) -> Vec<f64> {
        if self.sigma_grad == 0.0 {
            return vec![0.0; self.k()];
        }
        self.allocation.stds(self.sigma_grad, &self.quantiles.thresholds, &self.group_dims)
    }

    /// Private quantile update from per-group clip counts (Algorithm 1
    /// lines 15-18), followed by the Appendix A.1 global rescale when the
    /// policy asks for it. Returns the noisy fractions for diagnostics.
    pub fn update_thresholds(&mut self, clip_counts: &[f64]) -> Vec<f64> {
        let fracs = self.quantiles.update(clip_counts, &mut self.rng);
        if self.rescale_global && self.quantiles.is_adaptive() {
            let s2: f64 = self.quantiles.thresholds.iter().map(|c| c * c).sum();
            let scale = self.clip_init / s2.sqrt().max(1e-12);
            for c in self.quantiles.thresholds.iter_mut() {
                *c *= scale;
            }
        }
        fracs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::noise::per_device_std;
    use crate::session::spec::{ClipMode, GroupBy};

    fn privacy() -> PrivacySpec {
        PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.01 }
    }

    fn core_for(clip: ClipPolicy, k: usize) -> DpCore {
        DpCore::from_accountant(CoreCfg {
            privacy: &privacy(),
            clip: &clip,
            sample_rate: 0.05,
            steps: 100,
            k,
            group_dims: vec![10; k.max(1)],
            expected_batch: 64.0,
            seed: 0,
        })
        .unwrap()
    }

    #[test]
    fn per_device_core_matches_algorithm2_noise() {
        let clip = ClipPolicy { clip_init: 0.01, ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed) };
        let core = core_for(clip, 4);
        let stds = core.noise_stds();
        for (st, &c) in core.thresholds().iter().enumerate() {
            let want = per_device_std(core.sigma_grad, c, 4);
            assert!((stds[st] - want).abs() < 1e-12, "stage {st}: {} vs {want}", stds[st]);
        }
    }

    #[test]
    fn flat_core_noise_is_sigma_times_c() {
        let clip = ClipPolicy { clip_init: 0.5, ..ClipPolicy::new(GroupBy::Flat, ClipMode::Fixed) };
        let core = core_for(clip, 1);
        let stds = core.noise_stds();
        assert_eq!(stds.len(), 1);
        assert!((stds[0] - core.sigma_grad * 0.5).abs() < 1e-12);
    }

    #[test]
    fn nonprivate_core_is_silent() {
        let core = core_for(ClipPolicy::non_private(), 1);
        assert!(core.plan.is_none());
        assert_eq!(core.noise_stds(), vec![0.0]);
        assert!(!core.is_adaptive());
    }

    #[test]
    fn adaptive_core_gets_prop31_split() {
        let clip = ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive);
        let core = core_for(clip, 8);
        let p = core.plan.unwrap();
        assert!(p.sigma_grad > p.sigma_base, "Prop 3.1 must tax the gradient budget");
        assert!(p.sigma_quantile > 0.0);
        assert!(core.is_adaptive());
        assert_eq!(core.k(), 8);
    }

    #[test]
    fn fixed_mode_spends_nothing_on_quantiles() {
        let clip = ClipPolicy::new(GroupBy::PerLayer, ClipMode::Fixed);
        let core = core_for(clip, 8);
        let p = core.plan.unwrap();
        assert_eq!(p.sigma_grad, p.sigma_base);
        assert_eq!(p.sigma_quantile, 0.0);
    }

    #[test]
    fn rejects_bad_rates_and_steps() {
        let clip = ClipPolicy::new(GroupBy::Flat, ClipMode::Fixed);
        let bad_rate = DpCore::from_accountant(CoreCfg {
            privacy: &privacy(),
            clip: &clip,
            sample_rate: 0.0,
            steps: 100,
            k: 1,
            group_dims: vec![1],
            expected_batch: 64.0,
            seed: 0,
        });
        assert!(bad_rate.is_err());
        let bad_steps = DpCore::from_accountant(CoreCfg {
            privacy: &privacy(),
            clip: &clip,
            sample_rate: 0.1,
            steps: 0,
            k: 1,
            group_dims: vec![1],
            expected_batch: 64.0,
            seed: 0,
        });
        assert!(bad_steps.is_err());
    }

    #[test]
    fn global_rescale_pins_threshold_norm() {
        let clip = ClipPolicy { clip_init: 1.0, ..ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive) };
        let mut core = core_for(clip, 4);
        // all-clipped counts force the thresholds up, then rescale pins C
        core.update_thresholds(&[0.0, 16.0, 32.0, 64.0]);
        let s2: f64 = core.thresholds().iter().map(|c| c * c).sum();
        assert!((s2.sqrt() - 1.0).abs() < 1e-9, "global-equivalent norm {}", s2.sqrt());
    }
}
