//! Declarative run specifications for the session API.
//!
//! Every training scenario in the crate — flat / per-layer / per-device
//! clipping, fixed or adaptive thresholds, single-device or
//! pipeline-parallel — is described by one [`RunSpec`]:
//!
//! * [`PrivacySpec`] — the (epsilon, delta) target plus the Prop-3.1
//!   budget fraction. Noise is always accountant-derived; raw sigma never
//!   appears in a spec.
//! * [`ClipPolicy`] — the paper's group-wise clipping taxonomy as a
//!   product [`GroupBy`] x [`ClipMode`], replacing the disjoint
//!   `Method` / `PipelineMode` enums at the API surface.
//! * [`OptimSpec`] — optimizer, learning rate, decay.
//! * [`DataSpec`] — which synthetic substrate to build and how large.
//!
//! Specs (de)serialize through the in-tree JSON value ([`Json`]) — the
//! same no-serde-offline policy as the manifest — and load from TOML or
//! JSON files (`RunSpec::from_path`).

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::coordinator::noise::Allocation;
use crate::coordinator::optimizer::OptimizerKind;
use crate::kernels::KernelMode;
use crate::coordinator::trainer::Method;
use crate::pipeline::PipelineMode;
use crate::shard::compress::CompressKind;
use crate::util::json::Json;

// ---------------------------------------------------------------- privacy

/// Accountant-facing privacy target. `sigma` is always derived from this
/// via `accountant::plan` — specs never carry a raw noise multiplier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrivacySpec {
    pub epsilon: f64,
    pub delta: f64,
    /// Prop 3.1 budget fraction spent on private quantile estimation
    /// (only consumed by adaptive policies, which require it to be > 0 —
    /// otherwise the clip-count releases would be unnoised; paper uses
    /// 0.0001-0.1).
    pub quantile_r: f64,
}

impl Default for PrivacySpec {
    fn default() -> Self {
        PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.01 }
    }
}

impl PrivacySpec {
    pub fn new(epsilon: f64, delta: f64) -> Self {
        PrivacySpec { epsilon, delta, ..Default::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.epsilon > 0.0) {
            bail!("privacy.epsilon must be > 0, got {}", self.epsilon);
        }
        if !(self.delta > 0.0 && self.delta < 1.0) {
            bail!("privacy.delta must be in (0, 1), got {}", self.delta);
        }
        if !(0.0..1.0).contains(&self.quantile_r) {
            bail!("privacy.quantile_r must be in [0, 1), got {}", self.quantile_r);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("epsilon".into(), Json::Num(self.epsilon));
        m.insert("delta".into(), Json::Num(self.delta));
        m.insert("quantile_r".into(), Json::Num(self.quantile_r));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = PrivacySpec::default();
        Ok(PrivacySpec {
            epsilon: opt_f64(j, "epsilon", d.epsilon)?,
            delta: opt_f64(j, "delta", d.delta)?,
            quantile_r: opt_f64(j, "quantile_r", d.quantile_r)?,
        })
    }
}

// ------------------------------------------------------------ clip policy

/// How per-example gradients are grouped before clipping (paper sections
/// 2-4): one global group, one group per layer, or one group per pipeline
/// device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupBy {
    Flat,
    PerLayer,
    PerDevice,
}

impl GroupBy {
    pub fn token(&self) -> &'static str {
        match self {
            GroupBy::Flat => "flat",
            GroupBy::PerLayer => "per-layer",
            GroupBy::PerDevice => "per-device",
        }
    }
}

impl FromStr for GroupBy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "flat" | "global" => GroupBy::Flat,
            "per-layer" | "perlayer" | "per_layer" => GroupBy::PerLayer,
            "per-device" | "perdevice" | "per_device" => GroupBy::PerDevice,
            _ => bail!("unknown group_by '{s}' (flat|per-layer|per-device)"),
        })
    }
}

/// Whether thresholds stay fixed, track a private quantile, or clipping
/// (and noise) is disabled entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClipMode {
    NonPrivate,
    Fixed,
    Adaptive,
}

impl ClipMode {
    pub fn token(&self) -> &'static str {
        match self {
            ClipMode::NonPrivate => "non-private",
            ClipMode::Fixed => "fixed",
            ClipMode::Adaptive => "adaptive",
        }
    }
}

impl FromStr for ClipMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "non-private" | "nonprivate" | "none" => ClipMode::NonPrivate,
            "fixed" => ClipMode::Fixed,
            "adaptive" => ClipMode::Adaptive,
            _ => bail!("unknown clip mode '{s}' (non-private|fixed|adaptive)"),
        })
    }
}

/// Kernel used for flat clipping on the single-device backend: the fused
/// ghost-norm path (default) or the efficiency baselines of Figure 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlatImpl {
    Fused,
    Ghost,
    Naive,
}

impl FlatImpl {
    pub fn token(&self) -> &'static str {
        match self {
            FlatImpl::Fused => "fused",
            FlatImpl::Ghost => "ghost",
            FlatImpl::Naive => "naive",
        }
    }
}

impl FromStr for FlatImpl {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "fused" => FlatImpl::Fused,
            "ghost" => FlatImpl::Ghost,
            "naive" => FlatImpl::Naive,
            _ => bail!("unknown flat impl '{s}' (fused|ghost|naive)"),
        })
    }
}

/// The unified clipping policy: `GroupBy x ClipMode` plus thresholds and
/// noise-allocation knobs. Both backends are configured from this one
/// struct; the legacy `Method` / `PipelineMode` enums are derived views.
#[derive(Debug, Clone, PartialEq)]
pub struct ClipPolicy {
    pub group_by: GroupBy,
    pub mode: ClipMode,
    /// global-equivalent initial threshold C (per-layer groups start at
    /// C/sqrt(K); per-device thresholds start at C per device).
    pub clip_init: f64,
    /// target gradient-norm quantile for adaptive modes
    pub target_q: f64,
    /// quantile learning rate eta
    pub quantile_eta: f64,
    pub allocation: Allocation,
    /// Appendix A.1: rescale adaptive per-layer thresholds so their
    /// global-equivalent norm stays at `clip_init`.
    pub rescale_global: bool,
    /// flat-clipping efficiency baseline selector (single-device only)
    pub flat_impl: FlatImpl,
}

impl Default for ClipPolicy {
    fn default() -> Self {
        ClipPolicy {
            group_by: GroupBy::PerLayer,
            mode: ClipMode::Adaptive,
            clip_init: 1.0,
            target_q: 0.5,
            quantile_eta: 0.3,
            allocation: Allocation::Global,
            rescale_global: true,
            flat_impl: FlatImpl::Fused,
        }
    }
}

impl ClipPolicy {
    pub fn new(group_by: GroupBy, mode: ClipMode) -> Self {
        let rescale_global = group_by == GroupBy::PerLayer;
        let allocation = match group_by {
            GroupBy::PerDevice => Allocation::EqualBudget,
            _ => Allocation::Global,
        };
        ClipPolicy { group_by, mode, rescale_global, allocation, ..Default::default() }
    }

    pub fn non_private() -> Self {
        ClipPolicy::new(GroupBy::Flat, ClipMode::NonPrivate)
    }

    pub fn is_private(&self) -> bool {
        self.mode != ClipMode::NonPrivate
    }

    pub fn is_adaptive(&self) -> bool {
        self.mode == ClipMode::Adaptive
    }

    /// Initial per-group thresholds for `k` groups (A.1 conventions).
    pub fn init_thresholds(&self, k: usize) -> Vec<f64> {
        match self.group_by {
            GroupBy::Flat => vec![self.clip_init],
            GroupBy::PerLayer => vec![self.clip_init / (k.max(1) as f64).sqrt(); k.max(1)],
            GroupBy::PerDevice => vec![self.clip_init; k.max(1)],
        }
    }

    /// Number of clipping groups given the model's layer-group count and
    /// the pipeline stage count.
    pub fn n_groups(&self, n_layer_groups: usize, n_stages: usize) -> usize {
        match self.group_by {
            GroupBy::Flat => 1,
            GroupBy::PerLayer => n_layer_groups.max(1),
            GroupBy::PerDevice => n_stages.max(1),
        }
    }

    /// Legacy single-device `Method` implementing this policy.
    pub fn method(&self) -> Result<Method> {
        Ok(match (self.mode, self.group_by) {
            (ClipMode::NonPrivate, _) => Method::NonPrivate,
            (_, GroupBy::PerDevice) => {
                bail!("per-device clipping needs a pipeline config (manifest with stages)")
            }
            (ClipMode::Fixed, GroupBy::Flat) => match self.flat_impl {
                FlatImpl::Fused => Method::FlatFixed,
                FlatImpl::Ghost => Method::Ghost,
                FlatImpl::Naive => Method::Naive,
            },
            (ClipMode::Adaptive, GroupBy::Flat) => {
                if self.flat_impl != FlatImpl::Fused {
                    bail!("adaptive flat clipping supports only the fused impl");
                }
                Method::FlatAdaptive
            }
            (ClipMode::Fixed, GroupBy::PerLayer) => Method::PerLayerFixed,
            (ClipMode::Adaptive, GroupBy::PerLayer) => Method::PerLayerAdaptive,
        })
    }

    /// Legacy pipeline mode implementing this policy.
    pub fn pipeline_mode(&self) -> Result<PipelineMode> {
        Ok(match (self.mode, self.group_by) {
            (ClipMode::NonPrivate, _) => PipelineMode::NonPrivate,
            (_, GroupBy::PerDevice) => PipelineMode::PerDevice,
            (ClipMode::Fixed, GroupBy::Flat) => PipelineMode::FlatSync,
            (ClipMode::Adaptive, GroupBy::Flat) => {
                bail!("adaptive flat clipping is not implemented for the pipeline backend")
            }
            (_, GroupBy::PerLayer) => {
                bail!("per-layer clipping is not implemented for the pipeline backend")
            }
        })
    }

    /// Inverse view: the policy equivalent to a legacy `Method`.
    pub fn from_method(m: Method) -> Self {
        let (group_by, mode, flat_impl) = match m {
            Method::NonPrivate => (GroupBy::Flat, ClipMode::NonPrivate, FlatImpl::Fused),
            Method::FlatFixed => (GroupBy::Flat, ClipMode::Fixed, FlatImpl::Fused),
            Method::FlatAdaptive => (GroupBy::Flat, ClipMode::Adaptive, FlatImpl::Fused),
            Method::PerLayerFixed => (GroupBy::PerLayer, ClipMode::Fixed, FlatImpl::Fused),
            Method::PerLayerAdaptive => (GroupBy::PerLayer, ClipMode::Adaptive, FlatImpl::Fused),
            Method::Ghost => (GroupBy::Flat, ClipMode::Fixed, FlatImpl::Ghost),
            Method::Naive => (GroupBy::Flat, ClipMode::Fixed, FlatImpl::Naive),
        };
        ClipPolicy {
            flat_impl,
            // keep the legacy TrainOpts default: rescale applies to
            // per-layer adaptive only, but the flag itself defaults on
            rescale_global: true,
            ..ClipPolicy::new(group_by, mode)
        }
    }

    /// Inverse view: the policy equivalent to a legacy `PipelineMode`.
    /// `adaptive` only applies to `PerDevice`; the flat-sync baseline and
    /// non-private mode have no adaptive variant, so the flag is ignored
    /// there (matching `pipeline_mode()`, which rejects adaptive flat).
    pub fn from_pipeline_mode(m: PipelineMode, adaptive: bool) -> Self {
        let mode = if adaptive { ClipMode::Adaptive } else { ClipMode::Fixed };
        match m {
            PipelineMode::PerDevice => ClipPolicy::new(GroupBy::PerDevice, mode),
            PipelineMode::FlatSync => ClipPolicy::new(GroupBy::Flat, ClipMode::Fixed),
            PipelineMode::NonPrivate => ClipPolicy::non_private(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.clip_init > 0.0 && self.clip_init.is_finite()) {
            bail!("clip.clip_init must be a positive finite number, got {}", self.clip_init);
        }
        if self.is_adaptive() {
            if !(self.target_q > 0.0 && self.target_q < 1.0) {
                bail!("clip.target_q must be in (0, 1), got {}", self.target_q);
            }
            if !(self.quantile_eta > 0.0) {
                bail!("clip.quantile_eta must be > 0, got {}", self.quantile_eta);
            }
        }
        if self.flat_impl != FlatImpl::Fused && self.group_by != GroupBy::Flat {
            bail!("clip.flat_impl={} requires group_by=flat", self.flat_impl.token());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("group_by".into(), Json::Str(self.group_by.token().into()));
        m.insert("mode".into(), Json::Str(self.mode.token().into()));
        m.insert("clip_init".into(), Json::Num(self.clip_init));
        m.insert("target_q".into(), Json::Num(self.target_q));
        m.insert("quantile_eta".into(), Json::Num(self.quantile_eta));
        m.insert("allocation".into(), Json::Str(self.allocation.name().into()));
        m.insert("rescale_global".into(), Json::Bool(self.rescale_global));
        m.insert("flat_impl".into(), Json::Str(self.flat_impl.token().into()));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let group_by: GroupBy = opt_str(j, "group_by", "per-layer")?.parse()?;
        let mode: ClipMode = opt_str(j, "mode", "adaptive")?.parse()?;
        let base = ClipPolicy::new(group_by, mode);
        Ok(ClipPolicy {
            clip_init: opt_f64(j, "clip_init", base.clip_init)?,
            target_q: opt_f64(j, "target_q", base.target_q)?,
            quantile_eta: opt_f64(j, "quantile_eta", base.quantile_eta)?,
            allocation: match j.opt("allocation") {
                Some(v) => Allocation::parse(v.str()?)?,
                None => base.allocation,
            },
            rescale_global: opt_bool(j, "rescale_global", base.rescale_global)?,
            flat_impl: opt_str(j, "flat_impl", "fused")?.parse()?,
            group_by,
            mode,
        })
    }
}

// -------------------------------------------------------------- optimizer

/// Optimizer + schedule selection shared by both backends.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptimSpec {
    pub kind: OptimizerKind,
    pub lr: f64,
    pub weight_decay: f64,
    pub lr_decay: bool,
}

impl Default for OptimSpec {
    fn default() -> Self {
        OptimSpec {
            kind: OptimizerKind::Sgd { momentum: 0.0 },
            lr: 0.5,
            weight_decay: 0.0,
            lr_decay: false,
        }
    }
}

impl OptimSpec {
    pub fn sgd(lr: f64) -> Self {
        OptimSpec { lr, ..Default::default() }
    }

    pub fn momentum(lr: f64, momentum: f64) -> Self {
        OptimSpec { kind: OptimizerKind::Sgd { momentum }, lr, ..Default::default() }
    }

    /// The paper's DP-Adam setting for language tasks.
    pub fn adam(lr: f64) -> Self {
        OptimSpec {
            kind: OptimizerKind::Adam { beta1: 0.9, beta2: 0.98, eps: 1e-6 },
            lr,
            ..Default::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("optim.lr must be a positive finite number, got {}", self.lr);
        }
        if self.weight_decay < 0.0 {
            bail!("optim.weight_decay must be >= 0, got {}", self.weight_decay);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self.kind {
            OptimizerKind::Sgd { momentum } => {
                m.insert("kind".into(), Json::Str("sgd".into()));
                m.insert("momentum".into(), Json::Num(momentum));
            }
            OptimizerKind::Adam { beta1, beta2, eps } => {
                m.insert("kind".into(), Json::Str("adam".into()));
                m.insert("beta1".into(), Json::Num(beta1));
                m.insert("beta2".into(), Json::Num(beta2));
                m.insert("eps".into(), Json::Num(eps));
            }
        }
        m.insert("lr".into(), Json::Num(self.lr));
        m.insert("weight_decay".into(), Json::Num(self.weight_decay));
        m.insert("lr_decay".into(), Json::Bool(self.lr_decay));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = OptimSpec::default();
        let kind = match opt_str(j, "kind", "sgd")?.as_str() {
            "sgd" => OptimizerKind::Sgd { momentum: opt_f64(j, "momentum", 0.0)? },
            "momentum" => OptimizerKind::Sgd { momentum: opt_f64(j, "momentum", 0.9)? },
            "adam" => OptimizerKind::Adam {
                beta1: opt_f64(j, "beta1", 0.9)?,
                beta2: opt_f64(j, "beta2", 0.98)?,
                eps: opt_f64(j, "eps", 1e-6)?,
            },
            o => bail!("unknown optimizer kind '{o}' (sgd|momentum|adam)"),
        };
        Ok(OptimSpec {
            kind,
            lr: opt_f64(j, "lr", d.lr)?,
            weight_decay: opt_f64(j, "weight_decay", d.weight_decay)?,
            lr_decay: opt_bool(j, "lr_decay", d.lr_decay)?,
        })
    }
}

// ------------------------------------------------------------------- data

/// Which synthetic substrate to build for a run (`data::build_for_config`).
#[derive(Debug, Clone, PartialEq)]
pub struct DataSpec {
    /// "auto" picks from the config's model family; explicit names:
    /// mixture|cifar|sst2|qnli|qqp|mnli|markov|table2text|dialogsum
    pub task: String,
    pub n_data: usize,
    pub seed: u64,
}

impl Default for DataSpec {
    fn default() -> Self {
        DataSpec { task: "auto".into(), n_data: 4096, seed: 0 }
    }
}

impl DataSpec {
    pub fn validate(&self) -> Result<()> {
        if self.n_data == 0 {
            bail!("data.n_data must be > 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("task".into(), Json::Str(self.task.clone()));
        m.insert("n_data".into(), Json::Num(self.n_data as f64));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = DataSpec::default();
        Ok(DataSpec {
            task: opt_str(j, "task", &d.task)?,
            n_data: opt_usize(j, "n_data", d.n_data)?,
            seed: match j.opt("seed") {
                Some(v) => v.u64()?,
                None => d.seed,
            },
        })
    }

    /// User-partition assignment for the federated backend: which dataset
    /// indices user `u` contributes. The same corpora serve both privacy
    /// regimes — example-level runs index the corpus directly, user-level
    /// runs index it through this map.
    ///
    /// Deterministic in `(self.seed, population, examples_per_user,
    /// dist)` and independent of the training RNG stream: the partition is
    /// data, not a mechanism release, so building it must not perturb the
    /// seeded noise/sampling sequence. Users own contiguous index blocks
    /// (wrapping modulo `n_data` when the simulated population outgrows
    /// the finite corpus, which stands in for a larger one); with
    /// `population == n_data`, one example per user and `Fixed` sizing the
    /// map degenerates to the identity `u -> [u]`, which is what makes the
    /// federated backend's degenerate parity with the example-level
    /// sharded backend possible.
    pub fn user_partition(
        &self,
        population: usize,
        examples_per_user: usize,
        dist: ExamplesDist,
    ) -> Vec<Vec<usize>> {
        assert!(population > 0 && examples_per_user > 0 && self.n_data > 0);
        // splitmix64 over (seed, u): stable per-user sizes with no shared
        // stream to contend with
        let size_of = |u: usize| -> usize {
            match dist {
                ExamplesDist::Fixed => examples_per_user,
                ExamplesDist::Uniform => {
                    let mut z = self
                        .seed
                        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                        .wrapping_add(u as u64)
                        .wrapping_add(0x9e37_79b9_7f4a_7c15);
                    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
                    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
                    z ^= z >> 31;
                    1 + (z as usize) % (2 * examples_per_user - 1).max(1)
                }
            }
        };
        let mut start = 0usize;
        (0..population)
            .map(|u| {
                let sz = size_of(u);
                let block: Vec<usize> = (0..sz).map(|j| (start + j) % self.n_data).collect();
                start = (start + sz) % self.n_data;
                block
            })
            .collect()
    }
}

// --------------------------------------------------------------- pipeline

/// How the pipeline backend draws its minibatches — and therefore how the
/// accountant composes its releases.
///
/// * `Poisson` (default): genuine Poisson draws padded to the static
///   minibatch with weight-0 slots the stage executables mask out; the
///   accountant applies subsampling amplification at rate `q = E[B] / n`,
///   where the expected batch E[B] defaults to 0.8x the static minibatch
///   (the same headroom convention as the single-device backend, keeping
///   capacity-bound truncation — the standard fixed-capacity
///   approximation of the Poisson mechanism, surfaced via
///   `StepEvent::truncated` — rare), exactly like the single-device
///   backend.
/// * `RoundRobin`: the legacy deterministic cursor. No amplification can
///   be claimed, so the accountant composes at q = 1 over the number of
///   releases each example participates in — conservative but valid, kept
///   as a reproducibility escape hatch for pre-Poisson results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    Poisson,
    RoundRobin,
}

impl Sampling {
    /// Canonical spec/CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            Sampling::Poisson => "poisson",
            Sampling::RoundRobin => "round_robin",
        }
    }
}

impl FromStr for Sampling {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => Sampling::Poisson,
            "round_robin" | "round-robin" | "roundrobin" => Sampling::RoundRobin,
            _ => bail!("unknown sampling '{s}' (poisson|round_robin)"),
        })
    }
}

/// Pipeline-backend knobs (ignored by the single-device backend).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipeSpec {
    /// microbatches per minibatch (J in Algorithm 2)
    pub n_micro: usize,
    /// explicit step count; 0 = derive from epochs and dataset size
    pub steps: usize,
    /// simulated all-gather latency charged per sync barrier (seconds)
    pub sync_latency: f64,
    /// minibatch sampling strategy (drives the accountant's q)
    pub sampling: Sampling,
}

impl Default for PipeSpec {
    fn default() -> Self {
        PipeSpec { n_micro: 4, steps: 0, sync_latency: 0.002, sampling: Sampling::Poisson }
    }
}

impl PipeSpec {
    pub fn validate(&self) -> Result<()> {
        if self.n_micro == 0 {
            bail!("pipeline.n_micro must be > 0");
        }
        if self.sync_latency < 0.0 {
            bail!("pipeline.sync_latency must be >= 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("n_micro".into(), Json::Num(self.n_micro as f64));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("sync_latency".into(), Json::Num(self.sync_latency));
        m.insert("sampling".into(), Json::Str(self.sampling.token().into()));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = PipeSpec::default();
        Ok(PipeSpec {
            n_micro: opt_usize(j, "n_micro", d.n_micro)?,
            steps: opt_usize(j, "steps", d.steps)?,
            sync_latency: opt_f64(j, "sync_latency", d.sync_latency)?,
            sampling: opt_str(j, "sampling", d.sampling.token())?.parse()?,
        })
    }
}

// ------------------------------------------------------------------ shard

/// How the sharded backend maps clipping-threshold groups onto workers.
///
/// * `Auto` (default): mirror `clip.group_by` — `per-device` gives every
///   worker its own threshold (the paper's scheme over replicas), `flat` a
///   single shared threshold, `per-layer` shared per-layer thresholds.
/// * `Flat` / `PerDevice`: explicit pins; a private spec whose
///   `clip.group_by` disagrees is rejected at validation time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardGrouping {
    Auto,
    Flat,
    PerDevice,
}

impl ShardGrouping {
    /// Canonical spec/CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            ShardGrouping::Auto => "auto",
            ShardGrouping::Flat => "flat",
            ShardGrouping::PerDevice => "per-device",
        }
    }
}

impl FromStr for ShardGrouping {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => ShardGrouping::Auto,
            "flat" | "global" => ShardGrouping::Flat,
            "per-device" | "perdevice" | "per_device" | "per-worker" => ShardGrouping::PerDevice,
            _ => bail!("unknown shard grouping '{s}' (auto|flat|per-device)"),
        })
    }
}

/// Sharded data-parallel backend knobs. Presence of a `[shard]` section
/// (or `SessionBuilder::shard`) selects `Backend::Sharded` for stage-less
/// configs; pipeline configs reject it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardSpec {
    /// simulated data-parallel workers N (each a full model replica)
    pub workers: usize,
    /// tree-reduction fanout (>= 2)
    pub fanout: usize,
    /// overlap reduction rounds with backprop (false = barrier baseline)
    pub overlap: bool,
    /// threshold-group topology (see [`ShardGrouping`])
    pub grouping: ShardGrouping,
    /// per-reduction-round link latency charged by the makespan model (s)
    pub link_latency: f64,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec {
            workers: 4,
            fanout: 2,
            overlap: true,
            grouping: ShardGrouping::Auto,
            link_latency: 5e-4,
        }
    }
}

impl ShardSpec {
    pub fn with_workers(workers: usize) -> Self {
        ShardSpec { workers, ..Default::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("shard.workers must be > 0 (one replica per data-parallel worker)");
        }
        if self.fanout < 2 {
            bail!("shard.fanout must be >= 2, got {}", self.fanout);
        }
        if !(self.link_latency >= 0.0) {
            bail!("shard.link_latency must be >= 0, got {}", self.link_latency);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("fanout".into(), Json::Num(self.fanout as f64));
        m.insert("overlap".into(), Json::Bool(self.overlap));
        m.insert("grouping".into(), Json::Str(self.grouping.token().into()));
        m.insert("link_latency".into(), Json::Num(self.link_latency));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = ShardSpec::default();
        Ok(ShardSpec {
            workers: opt_usize(j, "workers", d.workers)?,
            fanout: opt_usize(j, "fanout", d.fanout)?,
            overlap: opt_bool(j, "overlap", d.overlap)?,
            grouping: opt_str(j, "grouping", d.grouping.token())?.parse()?,
            link_latency: opt_f64(j, "link_latency", d.link_latency)?,
        })
    }
}

// ----------------------------------------------------------------- hybrid

/// How the hybrid backend tiles clipping-threshold groups over the
/// (replica, stage) grid.
///
/// * `Auto` (default): the paper's per-device scheme on the full grid —
///   every one of the R x S pieces owns its threshold (= `PerPiece`).
/// * `PerPiece` / `PerStage`: explicit pins; `per-stage` shares one
///   threshold per stage across replicas (K = S).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HybridGrouping {
    Auto,
    PerPiece,
    PerStage,
}

impl HybridGrouping {
    /// Canonical spec/CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            HybridGrouping::Auto => "auto",
            HybridGrouping::PerPiece => "per-piece",
            HybridGrouping::PerStage => "per-stage",
        }
    }
}

impl FromStr for HybridGrouping {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => HybridGrouping::Auto,
            "per-piece" | "perpiece" | "per_piece" | "per-device" => HybridGrouping::PerPiece,
            "per-stage" | "perstage" | "per_stage" => HybridGrouping::PerStage,
            _ => bail!("unknown hybrid grouping '{s}' (auto|per-piece|per-stage)"),
        })
    }
}

/// Hybrid 2D-parallel backend knobs: R data-parallel replicas, each a
/// full S-stage pipeline (S comes from the manifest). Presence of a
/// `[hybrid]` section (or `SessionBuilder::hybrid`) selects
/// `Backend::Hybrid` on staged configs; on a stage-less config the grid
/// has no pipeline axis and the run routes to the sharded backend,
/// bit-identical to the same spec spelled with `[shard]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HybridSpec {
    /// simulated data-parallel replicas R (each a full S-stage pipeline)
    pub replicas: usize,
    /// cross-replica tree-reduction fanout (>= 2)
    pub fanout: usize,
    /// overlap each stage's cross-replica reduction with the remaining
    /// backward pass (false = reduce-after-backward barrier baseline)
    pub overlap: bool,
    /// threshold-group tiling over the grid (see [`HybridGrouping`])
    pub grouping: HybridGrouping,
    /// per-reduction-round link latency charged by the makespan model (s)
    pub link_latency: f64,
}

impl Default for HybridSpec {
    fn default() -> Self {
        HybridSpec {
            replicas: 2,
            fanout: 2,
            overlap: true,
            grouping: HybridGrouping::Auto,
            link_latency: 5e-4,
        }
    }
}

impl HybridSpec {
    pub fn with_replicas(replicas: usize) -> Self {
        HybridSpec { replicas, ..Default::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.replicas == 0 {
            bail!("hybrid.replicas must be > 0 (one full pipeline per data-parallel replica)");
        }
        if self.fanout < 2 {
            bail!("hybrid.fanout must be >= 2, got {}", self.fanout);
        }
        if !(self.link_latency >= 0.0) {
            bail!("hybrid.link_latency must be >= 0, got {}", self.link_latency);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("replicas".into(), Json::Num(self.replicas as f64));
        m.insert("fanout".into(), Json::Num(self.fanout as f64));
        m.insert("overlap".into(), Json::Bool(self.overlap));
        m.insert("grouping".into(), Json::Str(self.grouping.token().into()));
        m.insert("link_latency".into(), Json::Num(self.link_latency));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = HybridSpec::default();
        Ok(HybridSpec {
            replicas: opt_usize(j, "replicas", d.replicas)?,
            fanout: opt_usize(j, "fanout", d.fanout)?,
            overlap: opt_bool(j, "overlap", d.overlap)?,
            grouping: opt_str(j, "grouping", d.grouping.token())?.parse()?,
            link_latency: opt_f64(j, "link_latency", d.link_latency)?,
        })
    }
}

// -------------------------------------------------------------- federated

/// How the federated backend maps clipping-threshold groups onto the
/// sampled user cohort.
///
/// * `Auto` (default): mirror `clip.group_by` — `per-device` gives every
///   aggregation slot its own threshold (per-user adaptive clipping, the
///   group-wise cell with users as the clipped records), `flat` a single
///   threshold shared by every user's delta. `per-layer` has no federated
///   implementation and is rejected.
/// * `Flat` / `PerUser`: explicit pins; a private spec whose
///   `clip.group_by` disagrees is rejected at validation time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FederatedGrouping {
    Auto,
    Flat,
    PerUser,
}

impl FederatedGrouping {
    /// Canonical spec/CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            FederatedGrouping::Auto => "auto",
            FederatedGrouping::Flat => "flat",
            FederatedGrouping::PerUser => "per-user",
        }
    }
}

impl FromStr for FederatedGrouping {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => FederatedGrouping::Auto,
            "flat" | "global" => FederatedGrouping::Flat,
            "per-user" | "peruser" | "per_user" => FederatedGrouping::PerUser,
            _ => bail!("unknown federated grouping '{s}' (auto|flat|per-user)"),
        })
    }
}

/// How many examples each simulated user contributes.
///
/// * `Fixed`: every user owns exactly `examples_per_user` indices.
/// * `Uniform`: user u owns a deterministic (data-seeded) size drawn
///   uniformly from `1..=2*examples_per_user - 1`, mean
///   `examples_per_user` — heterogeneous cohorts without touching the
///   training RNG stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExamplesDist {
    Fixed,
    Uniform,
}

impl ExamplesDist {
    /// Canonical spec/CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            ExamplesDist::Fixed => "fixed",
            ExamplesDist::Uniform => "uniform",
        }
    }
}

impl FromStr for ExamplesDist {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "fixed" => ExamplesDist::Fixed,
            "uniform" => ExamplesDist::Uniform,
            _ => bail!("unknown examples_dist '{s}' (fixed|uniform)"),
        })
    }
}

/// Federated user-level DP backend knobs. Presence of a `[federated]`
/// section (or `SessionBuilder::federated`) selects `Backend::Federated`
/// on stage-less configs; staged configs reject it. The dealt — and
/// privacy-accounted — unit is the *user*: each step Poisson-samples users
/// at `user_rate` from a simulated `population`, runs every sampled user's
/// local update against the current checkpoint, clips the full per-user
/// delta (per-user clipping as group-wise clipping), and aggregates on the
/// tree-reduction seam. The accountant composes at `q = E[U]/population`
/// with [`PrivacyUnit::User`] recorded in the plan.
///
/// [`PrivacyUnit::User`]: crate::coordinator::accountant::PrivacyUnit
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FederatedSpec {
    /// simulated user population U (the accountant's denominator)
    pub population: usize,
    /// Poisson sampling rate q over users, in (0, 1]
    pub user_rate: f64,
    /// examples each user contributes (mean under `examples_dist`)
    pub examples_per_user: usize,
    /// shape of the per-user example-count distribution
    pub examples_dist: ExamplesDist,
    /// local update steps each sampled user takes before transmitting
    pub local_steps: usize,
    /// aggregation tree-reduction fanout (>= 2)
    pub fanout: usize,
    /// overlap reduction rounds with backprop (false = barrier baseline)
    pub overlap: bool,
    /// threshold-group topology (see [`FederatedGrouping`])
    pub grouping: FederatedGrouping,
    /// per-reduction-round link latency charged by the makespan model (s)
    pub link_latency: f64,
}

impl Default for FederatedSpec {
    fn default() -> Self {
        FederatedSpec {
            population: 1_000_000,
            user_rate: 2e-4,
            examples_per_user: 1,
            examples_dist: ExamplesDist::Fixed,
            local_steps: 1,
            fanout: 2,
            overlap: true,
            grouping: FederatedGrouping::Auto,
            link_latency: 5e-4,
        }
    }
}

impl FederatedSpec {
    pub fn with_population(population: usize, user_rate: f64) -> Self {
        FederatedSpec { population, user_rate, ..Default::default() }
    }

    /// Expected sampled cohort size E[U] = q * population, rounded to the
    /// nearest whole user (the accountant re-derives q from this integer
    /// so the sampler and the plan agree exactly).
    pub fn expected_users(&self) -> usize {
        ((self.user_rate * self.population as f64).round() as usize).max(1)
    }

    pub fn validate(&self) -> Result<()> {
        if self.population == 0 {
            bail!("federated.population must be > 0");
        }
        if !(self.user_rate > 0.0 && self.user_rate <= 1.0) {
            bail!("federated.user_rate must be in (0, 1], got {}", self.user_rate);
        }
        if self.examples_per_user == 0 {
            bail!("federated.examples_per_user must be > 0");
        }
        if self.local_steps == 0 {
            bail!("federated.local_steps must be > 0");
        }
        if self.fanout < 2 {
            bail!("federated.fanout must be >= 2, got {}", self.fanout);
        }
        if !(self.link_latency >= 0.0) {
            bail!("federated.link_latency must be >= 0, got {}", self.link_latency);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("population".into(), Json::Num(self.population as f64));
        m.insert("user_rate".into(), Json::Num(self.user_rate));
        m.insert("examples_per_user".into(), Json::Num(self.examples_per_user as f64));
        m.insert("examples_dist".into(), Json::Str(self.examples_dist.token().into()));
        m.insert("local_steps".into(), Json::Num(self.local_steps as f64));
        m.insert("fanout".into(), Json::Num(self.fanout as f64));
        m.insert("overlap".into(), Json::Bool(self.overlap));
        m.insert("grouping".into(), Json::Str(self.grouping.token().into()));
        m.insert("link_latency".into(), Json::Num(self.link_latency));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = FederatedSpec::default();
        Ok(FederatedSpec {
            population: opt_usize(j, "population", d.population)?,
            user_rate: opt_f64(j, "user_rate", d.user_rate)?,
            examples_per_user: opt_usize(j, "examples_per_user", d.examples_per_user)?,
            examples_dist: opt_str(j, "examples_dist", d.examples_dist.token())?.parse()?,
            local_steps: opt_usize(j, "local_steps", d.local_steps)?,
            fanout: opt_usize(j, "fanout", d.fanout)?,
            overlap: opt_bool(j, "overlap", d.overlap)?,
            grouping: opt_str(j, "grouping", d.grouping.token())?.parse()?,
            link_latency: opt_f64(j, "link_latency", d.link_latency)?,
        })
    }
}

// --------------------------------------------------------------- compress

/// Gradient compression on the cross-replica reduction path (sharded and
/// hybrid backends — the backends with a reduction seam). Each worker /
/// replica sparsifies its ALREADY-NOISED gradient share to the top-k (or
/// a random-k) entries per tensor before the tree-reduction, carrying the
/// dropped mass in a local error-feedback residual. DP-safe by
/// post-processing: the noise phase has already run when compression
/// sees the share (see `docs/SESSION_API.md`, "Gradient compression").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressSpec {
    /// selection rule (see [`CompressKind`])
    pub kind: CompressKind,
    /// keep ratio k/d in (0, 1]; 1.0 keeps everything (bitwise identity)
    pub ratio: f64,
    /// carry dropped mass into the next step's share (recommended; off =
    /// plain sparsification, dropped mass is lost)
    pub error_feedback: bool,
}

impl Default for CompressSpec {
    fn default() -> Self {
        CompressSpec { kind: CompressKind::TopK, ratio: 0.25, error_feedback: true }
    }
}

impl CompressSpec {
    pub fn validate(&self) -> Result<()> {
        if !(self.ratio > 0.0 && self.ratio <= 1.0) {
            bail!("compress.ratio must be in (0, 1], got {}", self.ratio);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("kind".into(), Json::Str(self.kind.token().into()));
        m.insert("ratio".into(), Json::Num(self.ratio));
        m.insert("error_feedback".into(), Json::Bool(self.error_feedback));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = CompressSpec::default();
        Ok(CompressSpec {
            kind: opt_str(j, "kind", d.kind.token())?.parse()?,
            ratio: opt_f64(j, "ratio", d.ratio)?,
            error_feedback: opt_bool(j, "error_feedback", d.error_feedback)?,
        })
    }
}

// --------------------------------------------------------------- run spec

/// Everything needed to execute one training run, on either backend.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// manifest config name; backend = pipeline iff the config has stages
    pub config: String,
    pub epochs: f64,
    /// expected (Poisson) batch size E[B]; 0 = 0.8 x the compiled batch
    /// (single-device: the config's static B; pipeline: the static
    /// minibatch `B x n_micro`)
    pub expected_batch: usize,
    pub seed: u64,
    pub privacy: PrivacySpec,
    pub clip: ClipPolicy,
    pub optim: OptimSpec,
    pub data: DataSpec,
    pub pipe: PipeSpec,
    /// `Some` selects the sharded data-parallel backend (stage-less
    /// configs only); `None` keeps the manifest-driven single/pipeline
    /// choice
    pub shard: Option<ShardSpec>,
    /// `Some` selects the hybrid 2D-parallel backend on staged configs
    /// (pipeline stages x data-parallel replicas); on a stage-less config
    /// it degenerates to the sharded backend. Mutually exclusive with
    /// `shard`.
    pub hybrid: Option<HybridSpec>,
    /// `Some` selects the federated user-level DP backend (stage-less
    /// configs only): users become the dealt, clipped and accounted unit.
    /// Mutually exclusive with both `shard` and `hybrid`.
    pub federated: Option<FederatedSpec>,
    /// `Some` enables error-feedback gradient sparsification on the
    /// cross-replica reduction path; needs a `[shard]` or `[hybrid]`
    /// section (the backends with a reduction seam).
    pub compress: Option<CompressSpec>,
    /// OS threads fanning out the per-unit collect tasks and per-unit
    /// noise jobs (1 = sequential, the reproducibility default). The
    /// threaded path is bitwise identical to the sequential one — every
    /// unit noises on its own seed-derived RNG stream — so this is purely
    /// a wall-clock knob. `GWCLIP_THREADS` overrides it at run time.
    pub threads: usize,
    /// Host-side kernel dispatch mode. `scalar` (the default) keeps every
    /// host loop on the bit-reference scalar kernels — byte-identical to
    /// historical runs. `auto` picks the fastest detected ISA for the
    /// elementwise kernels (bitwise identical to scalar by construction)
    /// AND switches the reassociating kernels (squared norms, pair-folded
    /// tree reduction, batched gaussian fill) to their blocked variants,
    /// which produce different — but mode-deterministic, host-independent
    /// — bits. `--kernels` / `GWCLIP_KERNELS` override it at run time.
    pub kernels: KernelMode,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            config: "resmlp".into(),
            epochs: 3.0,
            expected_batch: 0,
            seed: 0,
            privacy: PrivacySpec::default(),
            clip: ClipPolicy::default(),
            optim: OptimSpec::default(),
            data: DataSpec::default(),
            pipe: PipeSpec::default(),
            shard: None,
            hybrid: None,
            federated: None,
            compress: None,
            threads: 1,
            kernels: KernelMode::Scalar,
        }
    }
}

/// The one thread-count precedence rule, shared by the CLI, the session
/// builder and the serve daemon: spec < per-invocation override (a
/// `--threads` flag or a daemon submit's `threads` field) < the
/// `GWCLIP_THREADS` environment of the process that *runs* the steps,
/// floored at 1. Pure so the precedence is testable without touching
/// the process environment; callers pass `std::env::var("GWCLIP_THREADS")`
/// (the daemon evaluates it at submit time, not build time, so a
/// long-lived daemon sees the environment it was launched with per
/// session, not a stale build-time constant).
pub fn resolve_threads(spec: usize, flag: Option<usize>, env: Option<&str>) -> usize {
    env.and_then(|v| v.trim().parse::<usize>().ok())
        .or(flag)
        .unwrap_or(spec)
        .max(1)
}

/// The one kernel-mode precedence rule, mirroring [`resolve_threads`]:
/// spec < per-invocation override (the `--kernels` flag) < the
/// `GWCLIP_KERNELS` environment of the process that runs the steps. An
/// unparseable environment token falls through silently (same contract as
/// `GWCLIP_THREADS`: the environment is advisory); bad spec/CLI tokens
/// are rejected loudly at parse time instead, before reaching here.
pub fn resolve_kernels(
    spec: KernelMode,
    flag: Option<KernelMode>,
    env: Option<&str>,
) -> KernelMode {
    env.and_then(|v| v.trim().parse::<KernelMode>().ok())
        .or(flag)
        .unwrap_or(spec)
}

impl RunSpec {
    pub fn for_config(config: &str) -> Self {
        RunSpec { config: config.to_string(), ..Default::default() }
    }

    /// The thread count the step loop should actually run with: the
    /// `GWCLIP_THREADS` environment override when set and parseable,
    /// otherwise the spec's `threads` field, floored at 1. The override
    /// never touches the spec itself (serialization round-trips are
    /// unaffected), mirroring how `GWCLIP_ARTIFACTS` selects artifacts
    /// without entering the manifest.
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads, None, std::env::var("GWCLIP_THREADS").ok().as_deref())
    }

    /// The kernel mode the session should actually run with: the
    /// `GWCLIP_KERNELS` environment override when set and parseable,
    /// otherwise the spec's `kernels` field. Like `resolved_threads`, the
    /// override never touches the spec itself, so serialization
    /// round-trips are unaffected.
    pub fn resolved_kernels(&self) -> KernelMode {
        resolve_kernels(self.kernels, None, std::env::var("GWCLIP_KERNELS").ok().as_deref())
    }

    /// Builder-time validation of every nonsensical-spec class (satellite
    /// of the session redesign): bad privacy targets, quantile targets
    /// outside (0,1), empty schedules, zero microbatches.
    pub fn validate(&self) -> Result<()> {
        if self.config.is_empty() {
            bail!("spec.config must name a manifest config");
        }
        if !(self.epochs > 0.0) && self.pipe.steps == 0 {
            bail!("spec.epochs must be > 0 (or pipeline.steps set explicitly)");
        }
        if self.clip.is_private() {
            self.privacy.validate().context("invalid [privacy] section")?;
            // adaptive clipping releases per-group clip counts every step;
            // without a Prop-3.1 budget slice those releases are unnoised
            // and the claimed (eps, delta) no longer covers them
            if self.clip.is_adaptive() && !(self.privacy.quantile_r > 0.0) {
                bail!(
                    "clip.mode = adaptive needs privacy.quantile_r > 0 (the Prop 3.1 \
                     budget fraction noising the quantile releases); got {}",
                    self.privacy.quantile_r
                );
            }
        }
        self.clip.validate().context("invalid [clip] section")?;
        self.optim.validate().context("invalid [optim] section")?;
        self.data.validate().context("invalid [data] section")?;
        self.pipe.validate().context("invalid [pipeline] section")?;
        if let Some(c) = &self.compress {
            c.validate().context("invalid [compress] section")?;
            // compression rides the cross-replica reduction seam; the
            // single-device and pure-pipeline backends have no reduction
            // to compress, so a [compress] section there would silently
            // do nothing — reject it instead
            if self.shard.is_none() && self.hybrid.is_none() {
                bail!(
                    "[compress] sparsifies the cross-replica reduction path; add a [shard] \
                     or [hybrid] section (single-device and pipeline runs have no reduction)"
                );
            }
        }
        // exactly one data-parallel section may govern a spec: [hybrid]
        // already defines the replica axis, so carrying both is ambiguous
        if self.shard.is_some() && self.hybrid.is_some() {
            bail!(
                "spec carries both [shard] and [hybrid]; the hybrid grid already defines \
                 the data-parallel axis — keep exactly one section"
            );
        }
        if let Some(hy) = &self.hybrid {
            hy.validate().context("invalid [hybrid] section")?;
            // the hybrid backend always draws one global Poisson batch;
            // silently ignoring a sampler override would hand the user a
            // different privacy analysis than the spec reads as requesting
            if self.pipe.sampling != Sampling::Poisson {
                bail!(
                    "[hybrid] runs always Poisson-sample (one global draw, amplified \
                     accounting); pipeline.sampling = \"{}\" would have no effect — remove it",
                    self.pipe.sampling.token()
                );
            }
            // an explicit global E[B] must deal evenly across the replicas,
            // or the disjoint Poisson slices cannot target it
            if self.expected_batch > 0 && self.expected_batch % hy.replicas != 0 {
                bail!(
                    "expected_batch {} is not divisible across hybrid.replicas {}",
                    self.expected_batch,
                    hy.replicas
                );
            }
            // private hybrid runs clip per (replica, stage) piece — the
            // per-device cell of the taxonomy; flat/per-layer policies
            // have no hybrid implementation
            if self.clip.is_private() && self.clip.group_by != GroupBy::PerDevice {
                bail!(
                    "[hybrid] requires clip.group_by = per-device for private runs \
                     (per-piece clipping over the replica x stage grid); got {}",
                    self.clip.group_by.token()
                );
            }
        }
        if let Some(sh) = &self.shard {
            sh.validate().context("invalid [shard] section")?;
            // the sharded backend always draws one global Poisson batch
            // and derives its step count from epochs; silently ignoring
            // the pipeline knobs that change the sampler or the schedule
            // would hand the user a different privacy analysis than the
            // spec reads as requesting
            if self.pipe.sampling != Sampling::Poisson {
                bail!(
                    "[shard] runs always Poisson-sample (one global draw, amplified \
                     accounting); pipeline.sampling = \"{}\" would have no effect — remove it",
                    self.pipe.sampling.token()
                );
            }
            if self.pipe.steps > 0 {
                bail!(
                    "[shard] runs derive their step count from epochs; pipeline.steps \
                     is pipeline-only"
                );
            }
            // an explicit E[B] must deal evenly across the workers, or the
            // disjoint Poisson slices cannot target it
            if self.expected_batch > 0 && self.expected_batch % sh.workers != 0 {
                bail!(
                    "expected_batch {} is not divisible across shard.workers {}",
                    self.expected_batch,
                    sh.workers
                );
            }
            // explicit grouping pins must agree with the clip policy; the
            // per-layer taxonomy cell is reachable only through `auto`
            if self.clip.is_private() {
                match (sh.grouping, self.clip.group_by) {
                    (ShardGrouping::Auto, _) => {}
                    (ShardGrouping::Flat, GroupBy::Flat) => {}
                    (ShardGrouping::PerDevice, GroupBy::PerDevice) => {}
                    (g, c) => bail!(
                        "shard.grouping = {} conflicts with clip.group_by = {} \
                         (use grouping = \"auto\" or align the two)",
                        g.token(),
                        c.token()
                    ),
                }
            }
        }
        if let Some(fed) = &self.federated {
            fed.validate().context("invalid [federated] section")?;
            // the federated backend IS a data-parallel topology of its
            // own (users dealt over aggregation slots); a second
            // data-parallel section would define the axis twice
            if self.shard.is_some() || self.hybrid.is_some() {
                bail!(
                    "spec carries [federated] together with [shard]/[hybrid]; the federated \
                     cohort already defines the data-parallel axis — keep exactly one section"
                );
            }
            // sampling users at rate q must be able to target the expected
            // cohort: an explicit E[U] override larger than the population
            // is unsatisfiable
            if self.expected_batch > 0 && self.expected_batch > fed.population {
                bail!(
                    "expected_batch {} exceeds federated.population {} — the expected \
                     sampled cohort cannot outnumber the user population",
                    self.expected_batch,
                    fed.population
                );
            }
            // one global Poisson draw over users, amplified accounting:
            // sampler overrides and explicit pipeline schedules are
            // meaningless here, same as for [shard]
            if self.pipe.sampling != Sampling::Poisson {
                bail!(
                    "[federated] runs always Poisson-sample users (one global draw, \
                     amplified user-level accounting); pipeline.sampling = \"{}\" would \
                     have no effect — remove it",
                    self.pipe.sampling.token()
                );
            }
            if self.pipe.steps > 0 {
                bail!(
                    "[federated] runs derive their step count from epochs over the user \
                     population; pipeline.steps is pipeline-only"
                );
            }
            // the whole point of the backend is the user-level guarantee;
            // non-private federated averaging has no clipping threshold
            // to factor over users and is out of scope
            if !self.clip.is_private() {
                bail!(
                    "[federated] models user-level DP (per-user delta clipping + noise); \
                     clip.mode = nonprivate has no federated implementation"
                );
            }
            // both collection paths go through the fused flat entry (the
            // general path re-uses it with a saturating threshold)
            if self.clip.flat_impl != FlatImpl::Fused {
                bail!(
                    "[federated] collection runs on the fused clipping entry; \
                     clip.flat_impl = \"{}\" is single-device-only",
                    self.clip.flat_impl.token()
                );
            }
            // explicit grouping pins must agree with the clip policy:
            // per-user thresholds are the per-device taxonomy cell with
            // users as the clipped records; per-layer has no federated
            // implementation
            if self.clip.is_private() {
                match (fed.grouping, self.clip.group_by) {
                    (FederatedGrouping::Auto, GroupBy::PerLayer) => bail!(
                        "clip.group_by = per-layer has no federated implementation \
                         (the clipped record is the whole per-user delta); use flat or \
                         per-device"
                    ),
                    (FederatedGrouping::Auto, _) => {}
                    (FederatedGrouping::Flat, GroupBy::Flat) => {}
                    (FederatedGrouping::PerUser, GroupBy::PerDevice) => {}
                    (g, c) => bail!(
                        "federated.grouping = {} conflicts with clip.group_by = {} \
                         (per-user thresholds pair with group_by = per-device; use \
                         grouping = \"auto\" or align the two)",
                        g.token(),
                        c.token()
                    ),
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("config".into(), Json::Str(self.config.clone()));
        m.insert("epochs".into(), Json::Num(self.epochs));
        m.insert("expected_batch".into(), Json::Num(self.expected_batch as f64));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("threads".into(), Json::Num(self.threads as f64));
        m.insert("kernels".into(), Json::Str(self.kernels.token().to_string()));
        m.insert("privacy".into(), self.privacy.to_json());
        m.insert("clip".into(), self.clip.to_json());
        m.insert("optim".into(), self.optim.to_json());
        m.insert("data".into(), self.data.to_json());
        m.insert("pipeline".into(), self.pipe.to_json());
        if let Some(sh) = &self.shard {
            m.insert("shard".into(), sh.to_json());
        }
        if let Some(hy) = &self.hybrid {
            m.insert("hybrid".into(), hy.to_json());
        }
        if let Some(fed) = &self.federated {
            m.insert("federated".into(), fed.to_json());
        }
        if let Some(c) = &self.compress {
            m.insert("compress".into(), c.to_json());
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = RunSpec::default();
        Ok(RunSpec {
            config: j.get("config").context("spec needs a `config` key")?.str()?.to_string(),
            epochs: opt_f64(j, "epochs", d.epochs)?,
            expected_batch: opt_usize(j, "expected_batch", d.expected_batch)?,
            threads: opt_usize(j, "threads", d.threads)?,
            kernels: opt_str(j, "kernels", d.kernels.token())?.parse()?,
            seed: match j.opt("seed") {
                Some(v) => v.u64()?,
                None => d.seed,
            },
            privacy: section(j, "privacy", PrivacySpec::from_json, d.privacy)?,
            clip: section(j, "clip", ClipPolicy::from_json, d.clip)?,
            optim: section(j, "optim", OptimSpec::from_json, d.optim)?,
            data: section(j, "data", DataSpec::from_json, d.data)?,
            pipe: section(j, "pipeline", PipeSpec::from_json, d.pipe)?,
            shard: match j.opt("shard") {
                Some(v) => {
                    Some(ShardSpec::from_json(v).context("in [shard] section")?)
                }
                None => None,
            },
            hybrid: match j.opt("hybrid") {
                Some(v) => {
                    Some(HybridSpec::from_json(v).context("in [hybrid] section")?)
                }
                None => None,
            },
            federated: match j.opt("federated") {
                Some(v) => {
                    Some(FederatedSpec::from_json(v).context("in [federated] section")?)
                }
                None => None,
            },
            compress: match j.opt("compress") {
                Some(v) => {
                    Some(CompressSpec::from_json(v).context("in [compress] section")?)
                }
                None => None,
            },
        })
    }

    /// Parse a spec from TOML or JSON text (sniffed from the first
    /// non-whitespace byte).
    pub fn parse(text: &str) -> Result<Self> {
        let j = if text.trim_start().starts_with('{') {
            Json::parse(text).context("parsing spec as JSON")?
        } else {
            crate::util::toml::parse(text).context("parsing spec as TOML")?
        };
        let spec = RunSpec::from_json(&j)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec file {}", path.display()))?;
        RunSpec::parse(&text).with_context(|| format!("in spec file {}", path.display()))
    }

    pub fn render_json(&self) -> String {
        self.to_json().render()
    }
}

// ---------------------------------------------------------------- helpers

fn opt_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.opt(key) {
        Some(v) => v.f64().with_context(|| format!("key `{key}`")),
        None => Ok(default),
    }
}

fn opt_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.opt(key) {
        Some(v) => v.usize().with_context(|| format!("key `{key}`")),
        None => Ok(default),
    }
}

fn opt_bool(j: &Json, key: &str, default: bool) -> Result<bool> {
    match j.opt(key) {
        Some(v) => v.bool().with_context(|| format!("key `{key}`")),
        None => Ok(default),
    }
}

fn opt_str(j: &Json, key: &str, default: &str) -> Result<String> {
    match j.opt(key) {
        Some(v) => Ok(v.str().with_context(|| format!("key `{key}`"))?.to_string()),
        None => Ok(default.to_string()),
    }
}

fn section<T>(j: &Json, key: &str, parse: fn(&Json) -> Result<T>, default: T) -> Result<T> {
    match j.opt(key) {
        Some(v) => parse(v).with_context(|| format!("in [{key}] section")),
        None => Ok(default),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_method_mapping_is_total_over_legacy_methods() {
        for m in [
            Method::NonPrivate,
            Method::FlatFixed,
            Method::FlatAdaptive,
            Method::PerLayerFixed,
            Method::PerLayerAdaptive,
            Method::Ghost,
            Method::Naive,
        ] {
            let p = ClipPolicy::from_method(m);
            assert_eq!(p.method().unwrap(), m, "round-trip through ClipPolicy");
        }
    }

    #[test]
    fn policy_pipeline_mapping() {
        let p = ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed);
        assert_eq!(p.pipeline_mode().unwrap(), PipelineMode::PerDevice);
        assert!(p.method().is_err(), "per-device has no single-device method");
        let f = ClipPolicy::new(GroupBy::Flat, ClipMode::Fixed);
        assert_eq!(f.pipeline_mode().unwrap(), PipelineMode::FlatSync);
        let n = ClipPolicy::non_private();
        assert_eq!(n.pipeline_mode().unwrap(), PipelineMode::NonPrivate);
        assert_eq!(n.method().unwrap(), Method::NonPrivate);
        assert!(ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive).pipeline_mode().is_err());
    }

    #[test]
    fn init_thresholds_follow_a1_conventions() {
        let p = ClipPolicy { clip_init: 2.0, ..ClipPolicy::new(GroupBy::PerLayer, ClipMode::Fixed) };
        let t = p.init_thresholds(4);
        assert_eq!(t.len(), 4);
        assert!((t[0] - 1.0).abs() < 1e-12, "C/sqrt(K) = 2/2");
        let d = ClipPolicy { clip_init: 2.0, ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed) };
        assert_eq!(d.init_thresholds(4), vec![2.0; 4]);
        let f = ClipPolicy { clip_init: 2.0, ..ClipPolicy::new(GroupBy::Flat, ClipMode::Fixed) };
        assert_eq!(f.init_thresholds(4), vec![2.0]);
    }

    #[test]
    fn runspec_json_roundtrip() {
        let mut spec = RunSpec::for_config("lm_small");
        spec.epochs = 2.5;
        spec.seed = 9;
        spec.privacy = PrivacySpec { epsilon: 8.0, delta: 1e-6, quantile_r: 0.1 };
        spec.clip = ClipPolicy {
            clip_init: 0.1,
            target_q: 0.85,
            ..ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive)
        };
        spec.optim = OptimSpec::adam(1e-3);
        spec.data = DataSpec { task: "table2text".into(), n_data: 512, seed: 3 };
        spec.pipe =
            PipeSpec { n_micro: 2, steps: 7, sync_latency: 0.001, sampling: Sampling::RoundRobin };
        let back = RunSpec::from_json(&Json::parse(&spec.render_json()).unwrap()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn runspec_parses_toml() {
        let doc = r#"
config = "lm_mid_pipe_lora"
epochs = 1.0
seed = 4

[privacy]
epsilon = 1.0
delta = 1e-5

[clip]
group_by = "per-device"
mode = "fixed"
clip_init = 0.01

[optim]
kind = "adam"
lr = 5e-3

[data]
task = "dialogsum"
n_data = 1024

[pipeline]
n_micro = 4
steps = 20
sampling = "round_robin"
"#;
        let spec = RunSpec::parse(doc).unwrap();
        assert_eq!(spec.config, "lm_mid_pipe_lora");
        assert_eq!(spec.clip.group_by, GroupBy::PerDevice);
        assert_eq!(spec.clip.pipeline_mode().unwrap(), PipelineMode::PerDevice);
        assert_eq!(spec.pipe.steps, 20);
        assert_eq!(spec.pipe.sampling, Sampling::RoundRobin);
        assert_eq!(spec.data.task, "dialogsum");
        assert!(matches!(spec.optim.kind, OptimizerKind::Adam { .. }));
        // TOML and JSON deserialize through the same path
        let json_back = RunSpec::parse(&spec.render_json()).unwrap();
        assert_eq!(spec, json_back);
    }

    #[test]
    fn validation_rejects_nonsense() {
        let ok = RunSpec::for_config("resmlp");
        ok.validate().unwrap();
        let mut s = ok.clone();
        s.privacy.epsilon = 0.0;
        assert!(s.validate().is_err(), "epsilon <= 0");
        let mut s = ok.clone();
        s.privacy.epsilon = -3.0;
        assert!(s.validate().is_err(), "negative epsilon");
        let mut s = ok.clone();
        s.privacy.delta = 1.0;
        assert!(s.validate().is_err(), "delta >= 1");
        let mut s = ok.clone();
        s.clip.target_q = 1.5;
        assert!(s.validate().is_err(), "target_q outside (0,1)");
        let mut s = ok.clone();
        s.clip.target_q = 0.0;
        assert!(s.validate().is_err(), "target_q == 0");
        let mut s = ok.clone();
        s.pipe.n_micro = 0;
        assert!(s.validate().is_err(), "n_micro == 0");
        let mut s = ok.clone();
        s.epochs = 0.0;
        assert!(s.validate().is_err(), "no schedule");
        let mut s = ok.clone();
        s.data.n_data = 0;
        assert!(s.validate().is_err(), "empty dataset");
        // the default policy is adaptive: unnoised quantile releases are out
        let mut s = ok.clone();
        s.privacy.quantile_r = 0.0;
        assert!(s.validate().is_err(), "adaptive with quantile_r == 0");
        // ...but fixed clipping legitimately spends nothing on quantiles
        let mut s = ok.clone();
        s.clip = ClipPolicy::new(GroupBy::PerLayer, ClipMode::Fixed);
        s.privacy.quantile_r = 0.0;
        s.validate().unwrap();
        // non-private specs don't need a meaningful privacy section
        let mut s = ok.clone();
        s.clip = ClipPolicy::non_private();
        s.privacy.epsilon = -1.0;
        s.validate().unwrap();
    }

    #[test]
    fn token_parsers_roundtrip() {
        for g in [GroupBy::Flat, GroupBy::PerLayer, GroupBy::PerDevice] {
            assert_eq!(g.token().parse::<GroupBy>().unwrap(), g);
        }
        for c in [ClipMode::NonPrivate, ClipMode::Fixed, ClipMode::Adaptive] {
            assert_eq!(c.token().parse::<ClipMode>().unwrap(), c);
        }
        for f in [FlatImpl::Fused, FlatImpl::Ghost, FlatImpl::Naive] {
            assert_eq!(f.token().parse::<FlatImpl>().unwrap(), f);
        }
        for s in [Sampling::Poisson, Sampling::RoundRobin] {
            assert_eq!(s.token().parse::<Sampling>().unwrap(), s);
        }
        for g in [FederatedGrouping::Auto, FederatedGrouping::Flat, FederatedGrouping::PerUser] {
            assert_eq!(g.token().parse::<FederatedGrouping>().unwrap(), g);
        }
        for e in [ExamplesDist::Fixed, ExamplesDist::Uniform] {
            assert_eq!(e.token().parse::<ExamplesDist>().unwrap(), e);
        }
        for (alias, want) in [
            ("round-robin", Sampling::RoundRobin),
            ("roundrobin", Sampling::RoundRobin),
        ] {
            assert_eq!(alias.parse::<Sampling>().unwrap(), want, "alias {alias}");
        }
        assert!("bernoulli".parse::<Sampling>().is_err());
    }

    #[test]
    fn user_partition_degenerate_case_is_identity() {
        // population == n_data, one example per user, fixed sizing: the
        // map the degenerate-parity pin relies on
        let d = DataSpec { task: "auto".into(), n_data: 64, seed: 7 };
        let part = d.user_partition(64, 1, ExamplesDist::Fixed);
        for (u, block) in part.iter().enumerate() {
            assert_eq!(block, &vec![u], "user {u}");
        }
    }

    #[test]
    fn user_partition_is_deterministic_and_sized() {
        let d = DataSpec { task: "auto".into(), n_data: 128, seed: 3 };
        let a = d.user_partition(1000, 4, ExamplesDist::Uniform);
        let b = d.user_partition(1000, 4, ExamplesDist::Uniform);
        assert_eq!(a, b, "partition must be pure in (seed, shape)");
        assert_eq!(a.len(), 1000);
        let mut total = 0usize;
        for block in &a {
            assert!(!block.is_empty() && block.len() <= 7, "uniform sizes live in 1..=2e-1");
            assert!(block.iter().all(|&i| i < 128));
            total += block.len();
        }
        // mean ~ examples_per_user
        let mean = total as f64 / 1000.0;
        assert!((mean - 4.0).abs() < 0.3, "mean block size {mean} strayed from 4");
        // fixed sizing is exact
        for block in d.user_partition(100, 4, ExamplesDist::Fixed) {
            assert_eq!(block.len(), 4);
        }
    }

    #[test]
    fn federated_spec_roundtrips_json_and_toml() {
        let mut spec = RunSpec::for_config("lm_tiny");
        spec.clip = ClipPolicy::new(GroupBy::PerDevice, ClipMode::Adaptive);
        spec.federated = Some(FederatedSpec {
            population: 250_000,
            user_rate: 1e-3,
            examples_per_user: 3,
            examples_dist: ExamplesDist::Uniform,
            local_steps: 2,
            fanout: 4,
            overlap: false,
            grouping: FederatedGrouping::PerUser,
            link_latency: 1e-3,
        });
        let back = RunSpec::from_json(&Json::parse(&spec.render_json()).unwrap()).unwrap();
        assert_eq!(spec, back);
        let toml = r#"
config = "lm_tiny"
epochs = 1.0

[clip]
group_by = "per-device"
mode = "adaptive"

[federated]
population = 250000
user_rate = 1e-3
examples_per_user = 3
examples_dist = "uniform"
local_steps = 2
fanout = 4
overlap = false
grouping = "per-user"
link_latency = 1e-3
"#;
        let parsed = RunSpec::parse(toml).unwrap();
        assert_eq!(parsed.federated, spec.federated);
        assert_eq!(parsed.federated.unwrap().expected_users(), 250);
    }

    #[test]
    fn pipe_spec_defaults_to_poisson_sampling() {
        // an omitted [pipeline] section (and an omitted sampling key) must
        // land on the amplified Poisson path, not the legacy cursor
        assert_eq!(PipeSpec::default().sampling, Sampling::Poisson);
        let spec = RunSpec::parse("config = \"lm_mid_pipe_lora\"\nepochs = 1.0\n").unwrap();
        assert_eq!(spec.pipe.sampling, Sampling::Poisson);
    }
}
