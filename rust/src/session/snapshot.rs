//! Versioned on-disk snapshots of a running [`Session`] — the
//! crash-safe half of `gwclip serve`.
//!
//! A DP guarantee is a statement about the *whole* mechanism trace, so a
//! killed-and-resumed run must be **bitwise identical** to an
//! uninterrupted one or the (eps, delta) accounting silently breaks: a
//! replayed noise draw is a second release the accountant never
//! composed, and a drifted threshold changes the sensitivity the noise
//! was calibrated for. A snapshot therefore captures every piece of
//! mutable DP-critical state:
//!
//! - **RNG stream positions** for the core stream (noise + quantile
//!   releases) and the draw stream (Poisson/shard sampling), each as the
//!   full 256-bit xoshiro state *plus the buffered Marsaglia spare
//!   value* — `StreamPos` records only the spare's presence, but the
//!   next `gauss()` returns the buffered value verbatim, so a bitwise
//!   resume must restore it exactly.
//! - **Adaptive quantile thresholds**, as f64 bit patterns: they set the
//!   clipping sensitivity of every subsequent release.
//! - **The accountant ledger** — `steps_done`, i.e. how many releases
//!   have been composed. The plan itself is deterministically re-derived
//!   from the spec (the calibration bisection is fixed-iteration), and
//!   the snapshot stores its figures as a loud cross-check so a resumed
//!   `describe()`/eps can never drift from the run that wrote them.
//! - **Optimizer moments** (step counter + m/v buffers) and **model
//!   parameters** as f32 bit patterns — not DP state, but required for
//!   the resumed trajectory to be the same trajectory.
//! - **Engine-held cursors**: the pipeline round-robin data cursor, and
//!   the compressor's per-unit error-feedback residuals + selection
//!   stream (unit-local mutable state on the reduction seam).
//!
//! Serialization uses the in-tree `util::json` (no serde). Values that
//! don't survive a `f64` JSON number — `u64` RNG words, f32/f64 bit
//! patterns — are hex strings. Files are written atomically
//! ([`crate::util::fsio::write_atomic`]) and carry a `format`/`version`
//! header that is rejected loudly on mismatch, never mis-restored.
//!
//! Snapshots are taken at step boundaries only. The resume entry points
//! step the session sequentially (`Session::step`), which is bitwise
//! identical to the threaded prefetch loop by the PR 7 parity contract —
//! the prefetch path deals draw `t + 1` before step `t` executes, so
//! snapshotting mid-lookahead would double-consume the draw stream.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::noise::Rng;
use crate::coordinator::optimizer::Optimizer;
use crate::runtime::Tensor;
use crate::util::fsio;
use crate::util::json::Json;

use super::{Backend, RunSpec, Session};

/// Magic tag in every snapshot's `format` field.
pub const FORMAT: &str = "gwclip-snapshot";
/// Schema version this build writes and the only one it reads.
pub const VERSION: u64 = 1;

// ------------------------------------------------------------ hex encoding

/// 16-hex-char encoding of a `u64`. JSON numbers are f64 (53-bit
/// mantissa), so RNG state words and bit patterns go through strings.
pub fn hex_u64(x: u64) -> String {
    format!("{x:016x}")
}

pub fn parse_hex_u64(s: &str) -> Result<u64> {
    ensure!(s.len() == 16, "expected 16 hex chars, got {:?}", s);
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 {s:?}"))
}

/// An `f64` as its exact bit pattern — survives NaN/inf and never
/// rounds, unlike decimal text.
pub fn hex_f64(x: f64) -> String {
    hex_u64(x.to_bits())
}

pub fn parse_hex_f64(s: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_hex_u64(s)?))
}

/// An f32 buffer as one little-ordered hex blob, 8 chars per element —
/// ~2.7x denser than decimal JSON and exact by construction.
pub fn hex_f32s(v: &[f32]) -> String {
    let mut s = String::with_capacity(v.len() * 8);
    for x in v {
        s.push_str(&format!("{:08x}", x.to_bits()));
    }
    s
}

pub fn parse_hex_f32s(s: &str) -> Result<Vec<f32>> {
    ensure!(s.len() % 8 == 0, "f32 hex blob length {} is not a multiple of 8", s.len());
    ensure!(s.is_ascii(), "f32 hex blob contains non-ascii bytes");
    s.as_bytes()
        .chunks(8)
        .map(|c| {
            let chunk = std::str::from_utf8(c).expect("ascii checked above");
            Ok(f32::from_bits(
                u32::from_str_radix(chunk, 16).with_context(|| format!("bad hex f32 {chunk:?}"))?,
            ))
        })
        .collect()
}

// --------------------------------------------------------- value encoders

fn rng_to_json(r: &Rng) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "state".to_string(),
        Json::Arr(r.state().iter().map(|w| Json::Str(hex_u64(*w))).collect()),
    );
    m.insert(
        "spare".to_string(),
        match r.spare() {
            Some(v) => Json::Str(hex_f64(v)),
            None => Json::Null,
        },
    );
    Json::Obj(m)
}

fn rng_from_json(j: &Json) -> Result<Rng> {
    let words = j.get("state")?.arr()?;
    ensure!(words.len() == 4, "rng state has {} words, expected 4", words.len());
    let mut state = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        state[i] = parse_hex_u64(w.str()?)?;
    }
    let spare = match j.opt("spare") {
        Some(v) => Some(parse_hex_f64(v.str()?)?),
        None => None,
    };
    Ok(Rng::from_parts(state, spare))
}

fn tensor_to_json(t: &Tensor) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "shape".to_string(),
        Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    m.insert("data".to_string(), Json::Str(hex_f32s(&t.data)));
    Json::Obj(m)
}

fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape = j.get("shape")?.usizes()?;
    let data = parse_hex_f32s(j.get("data")?.str()?)?;
    Tensor::from_vec(&shape, data)
}

fn optimizer_to_json(o: &Optimizer) -> Json {
    let mut m = BTreeMap::new();
    m.insert("step".to_string(), Json::Num(o.step_count() as f64));
    m.insert(
        "m".to_string(),
        Json::Arr(o.moments_m().iter().map(|b| Json::Str(hex_f32s(b))).collect()),
    );
    m.insert(
        "v".to_string(),
        Json::Arr(o.moments_v().iter().map(|b| Json::Str(hex_f32s(b))).collect()),
    );
    Json::Obj(m)
}

type OptState = (u64, Vec<Vec<f32>>, Vec<Vec<f32>>);

fn optimizer_state_from_json(j: &Json) -> Result<OptState> {
    let step = j.get("step")?.u64()?;
    let decode = |key: &str| -> Result<Vec<Vec<f32>>> {
        j.get(key)?.arr()?.iter().map(|b| parse_hex_f32s(b.str()?)).collect()
    };
    Ok((step, decode("m")?, decode("v")?))
}

// ----------------------------------------------------------------- capture

/// Serialize the session's full mutable state as a snapshot document.
pub fn capture(sess: &Session) -> Json {
    let mut top = BTreeMap::new();
    top.insert("format".to_string(), Json::Str(FORMAT.to_string()));
    top.insert("version".to_string(), Json::Num(VERSION as f64));
    top.insert("spec".to_string(), sess.spec.to_json());
    // the RUNNING kernel mode (spec < GWCLIP_KERNELS as resolved at build
    // time), not the spec field: `auto` reassociates the noise fill and
    // the reduction trees, so the mode binds the bit trace and a resume
    // must run under the same one
    top.insert("kernels".to_string(), Json::Str(sess.kernels().mode().token().to_string()));
    top.insert("steps_done".to_string(), Json::Num(sess.steploop.steps_done as f64));
    top.insert("total_steps".to_string(), Json::Num(sess.total_steps as f64));

    let mut rng = BTreeMap::new();
    rng.insert("core".to_string(), rng_to_json(&sess.steploop.core.rng));
    rng.insert("draw".to_string(), rng_to_json(&sess.steploop.draw_rng));
    top.insert("rng".to_string(), Json::Obj(rng));

    top.insert(
        "thresholds".to_string(),
        Json::Arr(sess.thresholds().iter().map(|&t| Json::Str(hex_f64(t))).collect()),
    );

    top.insert(
        "accountant".to_string(),
        match sess.plan() {
            None => Json::Null,
            Some(p) => {
                let mut a = BTreeMap::new();
                a.insert("epsilon".to_string(), Json::Num(p.epsilon));
                a.insert("delta".to_string(), Json::Num(p.delta));
                a.insert("q".to_string(), Json::Str(hex_f64(p.q)));
                a.insert("steps".to_string(), Json::Num(p.steps as f64));
                a.insert("unit".to_string(), Json::Str(p.unit.token().to_string()));
                a.insert("sigma_base".to_string(), Json::Str(hex_f64(p.sigma_base)));
                a.insert("sigma_grad".to_string(), Json::Str(hex_f64(p.sigma_grad)));
                a.insert("sigma_quantile".to_string(), Json::Str(hex_f64(p.sigma_quantile)));
                a.insert(
                    "quantile_fraction".to_string(),
                    Json::Str(hex_f64(p.quantile_fraction)),
                );
                Json::Obj(a)
            }
        },
    );

    let mut be = BTreeMap::new();
    be.insert("kind".to_string(), Json::Str(sess.backend.name().to_string()));
    let mut params = BTreeMap::new();
    for (name, t) in sess.param_map() {
        params.insert(name, tensor_to_json(&t));
    }
    be.insert("params".to_string(), Json::Obj(params));
    let optimizers: Vec<Json> = match &sess.backend {
        Backend::Single(t) => vec![optimizer_to_json(t.optimizer())],
        Backend::Pipeline(e) => e.stage_optimizers().into_iter().map(optimizer_to_json).collect(),
        Backend::Sharded(e) => vec![optimizer_to_json(e.optimizer())],
        Backend::Hybrid(e) => e.stage_optimizers().into_iter().map(optimizer_to_json).collect(),
        Backend::Federated(e) => vec![optimizer_to_json(e.optimizer())],
    };
    be.insert("optimizers".to_string(), Json::Arr(optimizers));
    if let Backend::Pipeline(e) = &sess.backend {
        be.insert("cursor".to_string(), Json::Num(e.cursor() as f64));
    }
    let compressor = match &sess.backend {
        Backend::Sharded(e) => e.compressor(),
        Backend::Hybrid(e) => e.compressor(),
        _ => None,
    };
    if let Some(c) = compressor {
        let mut cm = BTreeMap::new();
        cm.insert(
            "residuals".to_string(),
            Json::Arr(
                c.residuals()
                    .iter()
                    .map(|unit| Json::Arr(unit.iter().map(tensor_to_json).collect()))
                    .collect(),
            ),
        );
        cm.insert(
            "rng".to_string(),
            Json::Arr(c.rng_state().iter().map(|w| Json::Str(hex_u64(*w))).collect()),
        );
        be.insert("compressor".to_string(), Json::Obj(cm));
    }
    top.insert("backend".to_string(), Json::Obj(be));

    Json::Obj(top)
}

/// Capture and atomically publish a snapshot file. A crash at any point
/// leaves either the previous file or the new one, never a prefix.
pub fn write(sess: &Session, path: &Path) -> Result<()> {
    fsio::write_atomic(path, capture(sess).render().as_bytes())
        .with_context(|| format!("writing snapshot {}", path.display()))
}

// -------------------------------------------------------------------- read

fn validate_header(j: &Json) -> Result<()> {
    let fmt = j
        .get("format")
        .and_then(|v| v.str())
        .map_err(|_| anyhow!("not a gwclip snapshot (no `format` field)"))?;
    ensure!(fmt == FORMAT, "not a gwclip snapshot (format {fmt:?}, expected {FORMAT:?})");
    let version = j.get("version")?.u64()?;
    ensure!(
        version == VERSION,
        "snapshot schema version {version} is not supported by this build (reads version \
         {VERSION} only); refusing to restore rather than risk a mis-restored DP state"
    );
    Ok(())
}

/// Parse and header-validate a snapshot document from text. Truncated
/// or corrupt files fail the JSON parse; wrong formats and schema
/// versions are rejected loudly — never best-effort restored.
pub fn parse(text: &str) -> Result<Json> {
    let j = Json::parse(text).context("snapshot is corrupt or truncated (JSON parse failed)")?;
    validate_header(&j)?;
    Ok(j)
}

/// Read, parse and header-validate a snapshot file.
pub fn read_file(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    parse(&text).with_context(|| format!("in snapshot {}", path.display()))
}

/// The run spec embedded in a snapshot — resume rebuilds the session
/// from this, so the snapshot file alone identifies the run.
pub fn spec_of(snap: &Json) -> Result<RunSpec> {
    RunSpec::from_json(snap.get("spec")?).context("snapshot spec")
}

/// How many steps the snapshotted session had completed.
pub fn steps_done_of(snap: &Json) -> Result<u64> {
    snap.get("steps_done")?.u64()
}

/// The newest `step-*.json` snapshot in a directory (by step number —
/// the zero-padded name makes lexicographic and numeric order agree).
pub fn latest_in_dir(dir: &Path) -> Result<Option<PathBuf>> {
    let mut best: Option<PathBuf> = None;
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(None),
    };
    for entry in entries {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if name.starts_with("step-")
            && name.ends_with(".json")
            && best.as_ref().and_then(|b| b.file_name()).and_then(|n| n.to_str()) < Some(name)
        {
            best = Some(path.clone());
        }
    }
    Ok(best)
}

/// Standard snapshot file name for a step count.
pub fn file_name(step: u64) -> String {
    format!("step-{step:010}.json")
}

// ----------------------------------------------------------------- restore

/// Restore a snapshot into a freshly built session. The session must
/// have been built from the same spec (resume rebuilds from
/// [`spec_of`]); every structural mismatch — spec drift, backend kind,
/// tensor shapes, optimizer layout, accountant figures — is rejected
/// loudly before any state is overwritten that could leave the session
/// half-restored: all decoding happens up front, mutation last.
pub fn restore(sess: &mut Session, snap: &Json) -> Result<()> {
    validate_header(snap)?;

    // spec must match (thread count aside: it has no bitwise effect by
    // the PR 7 parity contract, so resuming under a different thread
    // count is allowed and documented; the spec's `kernels` field is
    // likewise neutralized because the binding check is on the RESOLVED
    // running mode below, which `GWCLIP_KERNELS` may override)
    let snap_spec = spec_of(snap)?;
    let mut a = snap_spec.clone();
    let mut b = sess.spec.clone();
    a.threads = 0;
    b.threads = 0;
    a.kernels = Default::default();
    b.kernels = Default::default();
    ensure!(
        a == b,
        "snapshot was taken under a different spec; rebuild the session from the snapshot's \
         embedded spec (gwclip resume) instead of restoring across specs"
    );

    // kernel-mode continuity: `scalar` and `auto` produce different (both
    // deterministic) noise/reduction bit traces, so resuming under a
    // different mode would splice two incompatible trajectories. Older
    // snapshots without the field predate the knob and were scalar runs.
    let snap_mode = match snap.opt("kernels") {
        Some(v) => v.str()?.to_string(),
        None => "scalar".to_string(),
    };
    let live_mode = sess.kernels().mode().token();
    ensure!(
        snap_mode == live_mode,
        "snapshot was written by a `kernels = {snap_mode}` run but this session resolved \
         `kernels = {live_mode}`; the two modes produce different bit traces — resume with \
         the snapshot's mode (spec `kernels` field, --kernels, or GWCLIP_KERNELS)"
    );

    let kind = snap.get("backend")?.get("kind")?.str()?;
    ensure!(
        kind == sess.backend.name(),
        "snapshot backend {kind:?} does not match session backend {:?}",
        sess.backend.name()
    );

    let total = snap.get("total_steps")?.u64()?;
    ensure!(
        total == sess.total_steps,
        "snapshot plans {total} total steps, session plans {}",
        sess.total_steps
    );
    let steps_done = steps_done_of(snap)?;
    ensure!(steps_done <= total, "snapshot claims {steps_done} steps done of {total} total");

    // accountant cross-check: the plan is re-derived deterministically
    // from the spec, so these can only disagree if the calibration code
    // changed between write and read — which silently changes (eps,
    // delta) and must fail loudly
    match (snap.opt("accountant"), sess.plan()) {
        (None, None) => {}
        (Some(a), Some(p)) => {
            let figs = [
                ("q", hex_f64(p.q)),
                ("sigma_base", hex_f64(p.sigma_base)),
                ("sigma_grad", hex_f64(p.sigma_grad)),
                ("sigma_quantile", hex_f64(p.sigma_quantile)),
                ("quantile_fraction", hex_f64(p.quantile_fraction)),
            ];
            for (key, want) in figs {
                let got = a.get(key)?.str()?;
                ensure!(
                    got == want,
                    "accountant drift on {key}: snapshot has {got}, this build derives {want} — \
                     the (eps, delta) calibration changed; refusing to resume"
                );
            }
            ensure!(a.get("epsilon")?.f64()? == p.epsilon, "accountant drift on epsilon");
            ensure!(a.get("delta")?.f64()? == p.delta, "accountant drift on delta");
            ensure!(a.get("steps")?.u64()? == p.steps, "accountant drift on release count");
            ensure!(a.get("unit")?.str()? == p.unit.token(), "accountant drift on privacy unit");
        }
        (snap_has, _) => bail!(
            "snapshot {} an accountant plan but the session {} one",
            if snap_has.is_some() { "has" } else { "lacks" },
            if sess.plan().is_some() { "has" } else { "lacks" },
        ),
    }

    // decode everything before mutating anything
    let thr: Vec<f64> = snap
        .get("thresholds")?
        .arr()?
        .iter()
        .map(|t| parse_hex_f64(t.str()?))
        .collect::<Result<_>>()?;
    ensure!(
        thr.len() == sess.thresholds().len(),
        "snapshot has {} thresholds, session has {} groups",
        thr.len(),
        sess.thresholds().len()
    );

    let be = snap.get("backend")?;
    let mut params = std::collections::HashMap::new();
    for (name, t) in be.get("params")?.obj()? {
        params.insert(name.clone(), tensor_from_json(t)?);
    }
    let current = sess.param_map();
    ensure!(
        params.len() == current.len(),
        "snapshot has {} parameter tensors, session has {}",
        params.len(),
        current.len()
    );
    for name in current.keys() {
        ensure!(params.contains_key(name), "snapshot is missing parameter {name:?}");
    }

    let opt_states: Vec<OptState> = be
        .get("optimizers")?
        .arr()?
        .iter()
        .map(optimizer_state_from_json)
        .collect::<Result<_>>()?;

    let core_rng = rng_from_json(snap.get("rng")?.get("core")?)?;
    let draw_rng = rng_from_json(snap.get("rng")?.get("draw")?)?;

    // ---- mutate ----
    sess.load_param_map(&params)?;
    match &mut sess.backend {
        Backend::Single(t) => {
            ensure!(opt_states.len() == 1, "single-device snapshot needs 1 optimizer state");
            let (step, m, v) = opt_states.into_iter().next().unwrap();
            t.optimizer_mut().restore_state(step, m, v)?;
        }
        Backend::Pipeline(e) => {
            let opts = e.stage_optimizers_mut();
            ensure!(
                opt_states.len() == opts.len(),
                "pipeline snapshot has {} stage optimizers, engine has {}",
                opt_states.len(),
                opts.len()
            );
            for (opt, (step, m, v)) in opts.into_iter().zip(opt_states) {
                opt.restore_state(step, m, v)?;
            }
            e.set_cursor(be.get("cursor")?.usize()?);
        }
        Backend::Sharded(e) => {
            ensure!(opt_states.len() == 1, "sharded snapshot needs 1 optimizer state");
            let (step, m, v) = opt_states.into_iter().next().unwrap();
            e.restore_optimizers(step, m, v)?;
        }
        Backend::Hybrid(e) => {
            e.restore_stage_optimizers(&opt_states)?;
        }
        Backend::Federated(e) => {
            ensure!(opt_states.len() == 1, "federated snapshot needs 1 optimizer state");
            let (step, m, v) = opt_states.into_iter().next().unwrap();
            e.restore_optimizers(step, m, v)?;
        }
    }

    // compressor residuals (unit-local error-feedback state)
    let comp_snap = be.opt("compressor");
    let live_has = match &sess.backend {
        Backend::Sharded(e) => e.compressor().is_some(),
        Backend::Hybrid(e) => e.compressor().is_some(),
        _ => false,
    };
    if comp_snap.is_some() != live_has {
        bail!(
            "snapshot {} compressor state but the session {} a compressor",
            if comp_snap.is_some() { "has" } else { "lacks" },
            if live_has { "has" } else { "lacks" },
        );
    }
    if let Some(cj) = comp_snap {
        let residuals: Vec<Vec<Tensor>> = cj
            .get("residuals")?
            .arr()?
            .iter()
            .map(|unit| -> Result<Vec<Tensor>> {
                unit.arr()?.iter().map(tensor_from_json).collect()
            })
            .collect::<Result<_>>()?;
        let words = cj.get("rng")?.arr()?;
        ensure!(words.len() == 4, "compressor rng state needs 4 words");
        let mut state = [0u64; 4];
        for (i, w) in words.iter().enumerate() {
            state[i] = parse_hex_u64(w.str()?)?;
        }
        let c = match &mut sess.backend {
            Backend::Sharded(e) => e.compressor_mut(),
            Backend::Hybrid(e) => e.compressor_mut(),
            _ => None,
        }
        .expect("presence checked above");
        c.restore_residuals(residuals)?;
        c.restore_rng(state);
    }

    sess.core_mut().quantiles.thresholds = thr;
    sess.steploop.core.rng = core_rng;
    sess.steploop.draw_rng = draw_rng;
    sess.steploop.steps_done = steps_done;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_round_trips() {
        for x in [0u64, 1, u64::MAX, 0x9E3779B97F4A7C15, 1u64 << 63] {
            assert_eq!(parse_hex_u64(&hex_u64(x)).unwrap(), x);
        }
        for x in [0.0f64, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, f64::NEG_INFINITY] {
            assert_eq!(parse_hex_f64(&hex_f64(x)).unwrap().to_bits(), x.to_bits());
        }
        let nan = parse_hex_f64(&hex_f64(f64::NAN)).unwrap();
        assert!(nan.is_nan());
        let v: Vec<f32> = vec![0.0, -1.25, 3.4e38, f32::MIN_POSITIVE, -0.0];
        let back = parse_hex_f32s(&hex_f32s(&v)).unwrap();
        assert_eq!(v.len(), back.len());
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(parse_hex_f32s("12345").is_err(), "odd-length blob rejected");
        assert!(parse_hex_u64("xyz").is_err());
    }

    #[test]
    fn header_rejects_wrong_version_and_format() {
        let doc = format!("{{\"format\":\"{FORMAT}\",\"version\":999}}");
        let err = parse(&doc).unwrap_err();
        assert!(err.to_string().contains("version 999"), "{err:#}");
        let err = parse("{\"format\":\"something-else\",\"version\":1}").unwrap_err();
        assert!(err.to_string().contains("not a gwclip snapshot"), "{err:#}");
        let err = parse("{\"version\":1}").unwrap_err();
        assert!(err.to_string().contains("format"), "{err:#}");
    }

    #[test]
    fn truncated_file_is_rejected_not_restored() {
        let doc = format!("{{\"format\":\"{FORMAT}\",\"version\":1,\"steps_done\":7}}");
        for cut in [1, doc.len() / 2, doc.len() - 1] {
            let err = parse(&doc[..cut]).unwrap_err();
            assert!(err.to_string().contains("corrupt or truncated"), "cut={cut}: {err:#}");
        }
        assert!(parse(&doc).is_ok());
    }

    #[test]
    fn latest_in_dir_picks_highest_step() {
        let d = std::env::temp_dir()
            .join(format!("gwclip_snap_latest_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        assert!(latest_in_dir(&d).unwrap().is_none());
        for step in [2u64, 10, 9] {
            std::fs::write(d.join(file_name(step)), b"{}").unwrap();
        }
        std::fs::write(d.join("unrelated.txt"), b"x").unwrap();
        let best = latest_in_dir(&d).unwrap().unwrap();
        assert_eq!(best.file_name().unwrap().to_str().unwrap(), file_name(10));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rng_json_round_trips_spare_value() {
        let mut r = Rng::seeded(42);
        // drive to a position with a buffered spare
        while r.spare().is_none() {
            r.gauss();
        }
        let j = rng_to_json(&r);
        let mut back = rng_from_json(&j).unwrap();
        assert_eq!(back.stream_pos(), r.stream_pos());
        for _ in 0..64 {
            assert_eq!(back.gauss().to_bits(), r.gauss().to_bits());
            assert_eq!(back.uniform().to_bits(), r.uniform().to_bits());
        }
    }

    #[test]
    fn optimizer_json_round_trips() {
        use crate::coordinator::optimizer::{OptimizerKind, Schedule};
        let t = Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]).unwrap();
        let mut o = Optimizer::new(
            OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            Schedule::constant(0.01),
            0.0,
            std::slice::from_ref(&t),
        );
        let mut p = t.clone();
        for _ in 0..5 {
            o.apply(&mut [&mut p], &[t.clone()]);
        }
        let (step, m, v) = optimizer_state_from_json(&optimizer_to_json(&o)).unwrap();
        assert_eq!(step, 5);
        assert_eq!(m, o.moments_m());
        assert_eq!(v, o.moments_v());
    }
}
