//! Background batch prefetching for the threaded step loop.
//!
//! Poisson draws come from the session's dedicated draw stream (split off
//! the core RNG at construction), so the loop can deal step t+1 while
//! step t is still collecting — RNG-neutrally. [`with_prefetch`] runs a
//! loader thread fed through a bounded [`sync_channel`]: the run loop
//! sends the NEXT step's batch index lists (one per `ModelBatch` the
//! backend will assemble, from [`BackendStep::prefetch_lists`]), the
//! loader materializes them into a [`PrefetchDataset`] store, and the
//! collect phase's `Dataset::batch` calls pop them by exact index-list
//! match. A miss (the loader hasn't gotten there yet) falls back to
//! assembling inline, so prefetching can only ever change wall-clock
//! time, never a single byte of a batch.
//!
//! The channel capacity is the double-buffer depth: at most `DEPTH`
//! steps' worth of lists are in flight, which bounds the store to the
//! current step's leftovers plus the next draws' batches — backpressure,
//! not an unbounded queue.
//!
//! [`sync_channel`]: std::sync::mpsc::sync_channel
//! [`BackendStep::prefetch_lists`]: super::steploop::BackendStep::prefetch_lists

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Mutex;

use crate::data::{Dataset, ModelBatch};

/// Steps' worth of batch lists that may be in flight at once (the current
/// step's and the dealt-ahead draw's) before `send` blocks.
pub(crate) const DEPTH: usize = 2;

/// A [`Dataset`] view backed by a store of pre-assembled batches. Batches
/// are keyed by their exact index list and removed on first use; misses
/// fall through to the wrapped dataset.
pub(crate) struct PrefetchDataset<'d> {
    inner: &'d dyn Dataset,
    store: Mutex<Vec<(Vec<usize>, ModelBatch)>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<'d> PrefetchDataset<'d> {
    pub fn new(inner: &'d dyn Dataset) -> Self {
        PrefetchDataset {
            inner,
            store: Mutex::new(Vec::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Assemble `indices` now and park the result for a later
    /// [`Dataset::batch`] call with the same list (loader-thread side).
    pub fn preload(&self, indices: &[usize]) {
        let batch = self.inner.batch(indices);
        self.store.lock().unwrap().push((indices.to_vec(), batch));
    }

    /// (served from the store, assembled inline) counters.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Batches parked but never consumed (leftover diagnostics).
    pub fn parked(&self) -> usize {
        self.store.lock().unwrap().len()
    }
}

impl Dataset for PrefetchDataset<'_> {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn batch(&self, indices: &[usize]) -> ModelBatch {
        let parked = {
            let mut store = self.store.lock().unwrap();
            store
                .iter()
                .position(|(key, _)| key == indices)
                .map(|pos| store.remove(pos).1)
        };
        match parked {
            Some(b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.inner.batch(indices)
            }
        }
    }
}

/// Run `body` against a prefetching view of `data` and a sender feeding
/// the background loader. Each message is one step's batch index lists;
/// the loader assembles them in arrival order. The loader thread is
/// scoped: it drains and exits when `body` returns (the sender side is
/// dropped here), so no thread outlives the call.
pub(crate) fn with_prefetch<R>(
    data: &dyn Dataset,
    body: impl FnOnce(&PrefetchDataset<'_>, &SyncSender<Vec<Vec<usize>>>) -> R,
) -> R {
    let pf = PrefetchDataset::new(data);
    let (tx, rx) = sync_channel::<Vec<Vec<usize>>>(DEPTH);
    std::thread::scope(|scope| {
        let pf_ref = &pf;
        scope.spawn(move || {
            while let Ok(lists) = rx.recv() {
                for idx in lists {
                    pf_ref.preload(&idx);
                }
            }
        });
        let r = body(&pf, &tx);
        drop(tx); // closes the channel; the loader drains and joins
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::TrySendError;

    /// Deterministic index-addressed dataset: batch(i..) encodes the
    /// indices so equality checks prove WHICH assembly served a call.
    struct Probe {
        n: usize,
        calls: AtomicUsize,
    }

    impl Dataset for Probe {
        fn len(&self) -> usize {
            self.n
        }
        fn batch(&self, indices: &[usize]) -> ModelBatch {
            self.calls.fetch_add(1, Ordering::Relaxed);
            let data: Vec<f32> = indices.iter().map(|&i| i as f32).collect();
            ModelBatch::Feat {
                x: crate::runtime::Tensor::from_vec(&[indices.len()], data).unwrap(),
                y: crate::runtime::IntTensor {
                    shape: vec![indices.len()],
                    data: vec![0; indices.len()],
                },
            }
        }
    }

    fn payload(b: &ModelBatch) -> Vec<f32> {
        match b {
            ModelBatch::Feat { x, .. } => x.data.clone(),
            _ => panic!("probe emits Feat batches"),
        }
    }

    /// Preloaded batches come back bitwise identical to inline assembly,
    /// are served in the requested order whatever order they were parked
    /// in, and each parked entry is consumed exactly once.
    #[test]
    fn prefetch_serves_parked_batches_in_request_order() {
        let probe = Probe { n: 16, calls: AtomicUsize::new(0) };
        let pf = PrefetchDataset::new(&probe);
        // park out of request order
        pf.preload(&[4, 5]);
        pf.preload(&[0, 1]);
        pf.preload(&[2, 3]);
        assert_eq!(pf.parked(), 3);
        let direct = Probe { n: 16, calls: AtomicUsize::new(0) };
        for want in [[0usize, 1], [2, 3], [4, 5]] {
            let got = pf.batch(&want);
            assert_eq!(payload(&got), payload(&direct.batch(&want)));
        }
        assert_eq!(pf.stats(), (3, 0));
        assert_eq!(pf.parked(), 0);
        // a list that was never parked falls through to the inner dataset
        let got = pf.batch(&[7, 9]);
        assert_eq!(payload(&got), vec![7.0, 9.0]);
        assert_eq!(pf.stats(), (3, 1));
        // 3 preloads + 1 fallback hit the inner dataset; the 3 store
        // hits did not
        assert_eq!(probe.calls.load(Ordering::Relaxed), 4);
    }

    /// The loader channel exerts backpressure: with `DEPTH` lists parked
    /// unread, a further `try_send` reports Full instead of queueing
    /// unboundedly.
    #[test]
    fn prefetch_channel_backpressure_caps_inflight_steps() {
        let (tx, rx) = sync_channel::<Vec<Vec<usize>>>(DEPTH);
        for _ in 0..DEPTH {
            tx.try_send(vec![vec![0]]).unwrap();
        }
        match tx.try_send(vec![vec![1]]) {
            Err(TrySendError::Full(_)) => {}
            other => panic!("expected Full backpressure, got {other:?}"),
        }
        // draining one slot frees exactly one send
        rx.recv().unwrap();
        tx.try_send(vec![vec![2]]).unwrap();
    }

    /// End-to-end through `with_prefetch`: the loop sends the next step's
    /// lists, the loader parks them, and every batch read agrees with
    /// inline assembly regardless of hit/miss timing.
    #[test]
    fn with_prefetch_round_trip_matches_inline_assembly() {
        let probe = Probe { n: 32, calls: AtomicUsize::new(0) };
        let steps: Vec<Vec<Vec<usize>>> =
            (0..4).map(|s| vec![vec![2 * s, 2 * s + 1], vec![8 + s, 16 + s]]).collect();
        let collected = with_prefetch(&probe, |pf, tx| {
            let mut got = Vec::new();
            for lists in &steps {
                tx.send(lists.clone()).unwrap();
                for idx in lists {
                    got.push(payload(&pf.batch(idx)));
                }
            }
            got
        });
        let direct = Probe { n: 32, calls: AtomicUsize::new(0) };
        let want: Vec<Vec<f32>> = steps
            .iter()
            .flat_map(|lists| lists.iter().map(|idx| payload(&direct.batch(idx))))
            .collect();
        assert_eq!(collected, want);
    }
}
