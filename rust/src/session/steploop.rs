//! `StepLoop` — the one DP training step, shared by every backend.
//!
//! The paper's central claim is that group-wise clipping composes with the
//! structure of the computation: per-layer clipping overlaps with
//! backprop, per-device clipping overlaps with cross-device reduction.
//! That composition used to be hand-rolled four times (single-device,
//! pipeline, sharded, hybrid), quadruplicating the DP-critical sequence.
//! This module owns it once:
//!
//! ```text
//!  1. deal      one global Poisson (or round-robin) draw   [core RNG]
//!  2. collect   backend fwd/bwd + clip vs EXPLICIT thresholds  [no RNG]
//!  3. noise     local shares sigma_g/sqrt(U) per unit      [core RNG]
//!  4. merge     cross-unit reduction + sim makespans       [no RNG]
//!  5. scale     /E[B] normalization (Algorithm 1 line 14)
//!  6. apply     optimizer update on every replica
//!  7. quantile  ONE private release over all groups        [core RNG]
//!  8. emit      one StepEvent
//! ```
//!
//! A backend is an implementation of [`BackendStep`]: it deals the draw
//! into local slices, collects pre-noise per-group gradients + clip
//! counts + timings, and merges the (already-noised) unit gradients —
//! everything DP-critical (thresholds, noise calibration, RNG order,
//! quantile adaptation, accountant-facing normalization) lives here and
//! cannot drift between backends.
//!
//! RNG discipline: the loop consumes the shared [`DpCore`] RNG in exactly
//! the order each backend documented before the refactor — one draw, then
//! gradient noise walking units in order and each unit's flattened
//! tensors in order (the unit layout encodes worker-major / replica-major
//! / stage-major), then the quantile release. `add_noise` is a no-op at
//! std 0, so non-private phases consume nothing. The per-unit noise share
//! is `std_g / sqrt(U)` with U = number of units, so U independent shares
//! merge (variances add) to exactly the accountant's per-group std — and
//! U = 1 degenerates to the full std, which is what keeps the 1-worker /
//! 1-replica parity pins bitwise.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::noise::{add_noise, Rng};
use crate::data::Dataset;

use super::core::DpCore;
use super::grad::{Collected, GradUnit, Merged, StepTiming};
use super::StepEvent;

/// The three-hook backend contract (plus the update application): how one
/// engine plugs into the shared [`StepLoop`]. Hooks must not touch the
/// core RNG except through the arguments the loop passes them — `deal`
/// receives it for the draw; `collect` and `merge` are RNG-free.
pub(crate) trait BackendStep {
    /// Backend-specific view of one dealt draw (padded per-worker slices,
    /// a single padded batch, a round-robin window, ...).
    type Slices;

    /// Draw this step's batch from the shared RNG and deal it into the
    /// backend's local slices. `n_data` is the live dataset size (the
    /// round-robin cursor wraps over it).
    fn deal(&mut self, n_data: usize, rng: &mut Rng) -> Self::Slices;

    /// Run the pre-noise collection: forward/backward + clip against the
    /// EXPLICIT `thresholds` (indexed by the backend's group mapping),
    /// returning per-unit summed gradients, clip counts and timings.
    /// Consumes no RNG and reads no thresholds from anywhere else.
    fn collect(
        &mut self,
        data: &dyn Dataset,
        slices: &Self::Slices,
        thresholds: &[f64],
    ) -> Result<Collected>;

    /// Merge the units' (already-noised) gradients across the
    /// data-parallel axis and report the simulated reduction makespans.
    /// Single-unit backends return [`Merged::identity`].
    fn merge(&mut self, units: Vec<GradUnit>, timing: &StepTiming) -> Merged;

    /// Apply the merged, normalized gradient set (flattened in unit
    /// tensor order) to every parameter replica this backend holds.
    fn apply(&mut self, grads: &[crate::runtime::Tensor]);

    /// Post-merge normalization factor: `(1/E[B]) as f32` for private
    /// runs (Algorithm 1 line 14 normalizes by the EXPECTED batch), and
    /// the backend's documented non-private convention otherwise
    /// (1.0 = no rescale). Applied once to every merged element.
    fn update_scale(&self, live: usize) -> f32;
}

/// The DP-invariant per-step state machine: owns the shared [`DpCore`]
/// (plan, thresholds, noise allocation, RNG) and the step counter, and
/// drives any [`BackendStep`] through the eight phases.
pub struct StepLoop {
    /// shared DP state — plan, thresholds, noise, the ONE RNG
    pub core: DpCore,
    /// steps completed (1-based in emitted events)
    pub steps_done: u64,
}

impl StepLoop {
    pub fn new(core: DpCore) -> Self {
        StepLoop { core, steps_done: 0 }
    }

    /// One full DP step of `backend` over `data`; emits the unified
    /// [`StepEvent`].
    pub(crate) fn step<B: BackendStep>(
        &mut self,
        backend: &mut B,
        data: &dyn Dataset,
    ) -> Result<StepEvent> {
        let host_t0 = Instant::now();

        // 1. deal: the only RNG the draw consumes
        let slices = backend.deal(data.len(), &mut self.core.rng);

        // 2. collect: pre-noise gradients against the current thresholds
        let thresholds = self.core.thresholds().to_vec();
        let mut col = backend.collect(data, &slices, &thresholds)?;

        // 3. noise: each unit adds its local share sigma_g/sqrt(U) in the
        // unit's flattened tensor order (std 0 consumes no RNG)
        let stds = self.core.noise_stds();
        let share = 1.0 / (col.units.len().max(1) as f64).sqrt();
        for unit in col.units.iter_mut() {
            debug_assert_eq!(unit.tensors.len(), unit.groups.len());
            for (t, &g) in unit.tensors.iter_mut().zip(&unit.groups) {
                add_noise(&mut t.data, stds[g] * share, &mut self.core.rng);
            }
        }

        // 4. merge: cross-unit reduction (identity for single-unit
        // backends) + the overlap-vs-barrier latency model
        let mut merged = backend.merge(col.units, &col.timing);

        // 5. scale: one normalization of the merged sum
        let scale = backend.update_scale(col.live);
        if scale != 1.0 {
            for t in merged.tensors.iter_mut() {
                for v in t.data.iter_mut() {
                    *v *= scale;
                }
            }
        }

        // 6. apply: one update, broadcast to every replica by the backend
        backend.apply(&merged.tensors);

        // 7. quantile: ONE private release over all threshold groups
        // (adaptive cores are private by construction; fixed cores no-op)
        if self.core.is_adaptive() {
            self.core.update_thresholds(&col.clip_counts);
        }

        // 8. emit
        self.steps_done += 1;
        let clip_frac: Vec<f64> = col
            .clip_denoms
            .iter()
            .zip(&col.clip_counts)
            .map(|(&d, &c)| 1.0 - c / d)
            .collect();
        Ok(StepEvent {
            step: self.steps_done,
            loss: col.loss,
            batch_size: col.live,
            clip_frac,
            mean_norms: col.mean_norms,
            host_secs: host_t0.elapsed().as_secs_f64(),
            sim_secs: merged.sim_secs,
            sim_overlap_secs: merged.sim_overlap_secs,
            sim_barrier_secs: merged.sim_barrier_secs,
            syncs: col.syncs + merged.syncs,
            calls: col.calls,
            truncated: col.truncated,
            unit: self.core.plan.map(|p| p.unit.token()).unwrap_or("example"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampler::PoissonSampler;
    use crate::data::ModelBatch;
    use crate::runtime::{IntTensor, Tensor};
    use crate::session::spec::{ClipMode, ClipPolicy, GroupBy, PrivacySpec};
    use crate::session::{CoreCfg, DpCore};

    struct NullData(usize);
    impl Dataset for NullData {
        fn len(&self) -> usize {
            self.0
        }
        fn batch(&self, indices: &[usize]) -> ModelBatch {
            ModelBatch::Cls {
                x: IntTensor::zeros(&[indices.len(), 1]),
                y: IntTensor::zeros(&[indices.len()]),
            }
        }
    }

    /// A two-unit, two-groups-per-unit stub: unit u's tensor for group g
    /// starts at zero, so after the loop runs the tensor values ARE the
    /// noise the loop drew for (u, g) — which lets the test replay the
    /// documented RNG discipline by hand.
    struct StubBackend {
        sampler: PoissonSampler,
        units: usize,
        k: usize,
        applied: Vec<Tensor>,
        scale: f32,
        last_live: usize,
    }

    impl BackendStep for StubBackend {
        type Slices = crate::coordinator::sampler::Batch;

        fn deal(&mut self, _n: usize, rng: &mut Rng) -> Self::Slices {
            self.sampler.sample_padded(rng)
        }

        fn collect(
            &mut self,
            _data: &dyn Dataset,
            slices: &Self::Slices,
            thresholds: &[f64],
        ) -> Result<Collected> {
            assert_eq!(thresholds.len(), self.k);
            self.last_live = slices.live();
            let units = (0..self.units)
                .map(|_| GradUnit {
                    tensors: (0..self.k).map(|_| Tensor::zeros(&[3])).collect(),
                    groups: (0..self.k).collect(),
                })
                .collect();
            Ok(Collected {
                units,
                clip_counts: vec![1.0; self.k],
                clip_denoms: vec![slices.live().max(1) as f64; self.k],
                mean_norms: vec![0.5; self.k],
                loss: 1.25,
                live: slices.live(),
                truncated: slices.truncated,
                calls: self.units,
                syncs: 0,
                timing: StepTiming::default(),
            })
        }

        fn merge(&mut self, units: Vec<GradUnit>, _t: &StepTiming) -> Merged {
            // plain sum across units (fanout irrelevant for the stub)
            let mut it = units.into_iter();
            let mut acc = it.next().unwrap().tensors;
            for u in it {
                for (a, b) in acc.iter_mut().zip(&u.tensors) {
                    for (x, y) in a.data.iter_mut().zip(&b.data) {
                        *x += *y;
                    }
                }
            }
            Merged {
                tensors: acc,
                sim_secs: 0.0,
                sim_overlap_secs: 0.0,
                sim_barrier_secs: 0.0,
                syncs: 0,
            }
        }

        fn apply(&mut self, grads: &[Tensor]) {
            self.applied = grads.to_vec();
        }

        fn update_scale(&self, _live: usize) -> f32 {
            self.scale
        }
    }

    fn core(k: usize, seed: u64) -> DpCore {
        let clip = ClipPolicy {
            clip_init: 1.0,
            ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Adaptive)
        };
        DpCore::from_accountant(CoreCfg {
            privacy: &PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.01 },
            clip: &clip,
            sample_rate: 0.1,
            steps: 10,
            k,
            group_dims: vec![3; k],
            expected_batch: 8.0,
            seed,
        })
        .unwrap()
    }

    #[test]
    fn steploop_rng_discipline_is_draw_then_unit_major_noise_then_quantile() {
        // run the loop, then replay the documented RNG order by hand on a
        // fresh RNG with the same seed; the stub's applied gradients must
        // equal the replayed noise (scaled), and the threshold trajectory
        // must match a manual quantile update — proving the loop consumes
        // the stream as (1) draw, (2) unit-major tensor noise at
        // std_g/sqrt(U), (3) one quantile release.
        let (units, k, seed) = (2usize, 2usize, 7u64);
        let mut lp = StepLoop::new(core(k, seed));
        let stds = lp.core.noise_stds();
        let init_thr = lp.core.thresholds().to_vec();
        let mut backend = StubBackend {
            sampler: PoissonSampler::new(64, 0.1, 16),
            units,
            k,
            applied: Vec::new(),
            scale: 0.5,
            last_live: 0,
        };
        let data = NullData(64);
        let ev = lp.step(&mut backend, &data).unwrap();
        assert_eq!(ev.step, 1);
        assert_eq!(ev.batch_size, backend.last_live);
        assert_eq!(ev.clip_frac.len(), k);

        // ---- replay ----
        let mut replay = Rng::seeded(seed);
        let drawn = PoissonSampler::new(64, 0.1, 16).sample_padded(&mut replay);
        assert_eq!(drawn.live(), backend.last_live, "same draw");
        let share = 1.0 / (units as f64).sqrt();
        let mut expect: Vec<Vec<f32>> = vec![vec![0.0; 3]; k];
        for _u in 0..units {
            for (g, e) in expect.iter_mut().enumerate() {
                for slot in e.iter_mut() {
                    *slot += (stds[g] * share * replay.gauss()) as f32;
                }
            }
        }
        for (g, t) in backend.applied.iter().enumerate() {
            for (a, e) in t.data.iter().zip(&expect[g]) {
                assert!((a - e * 0.5).abs() < 1e-6, "group {g}: {a} vs {}", e * 0.5);
            }
        }
        // the quantile release consumed exactly k gaussians after the
        // noise phase: replaying it reproduces the threshold trajectory
        let mut q = crate::coordinator::quantile::QuantileEstimator::adaptive(
            init_thr,
            lp.core.quantiles.target_q,
            lp.core.quantiles.eta,
            lp.core.quantiles.sigma_b,
            lp.core.quantiles.batch,
        );
        q.update(&vec![1.0; k], &mut replay);
        // (no A.1 rescale: per-device policies default rescale_global off)
        assert_eq!(lp.core.thresholds(), &q.thresholds[..], "same trajectory");
        // streams fully aligned afterwards
        assert_eq!(lp.core.rng.uniform(), replay.uniform());
    }

    #[test]
    fn steploop_scale_one_skips_rescale_and_nonprivate_core_draws_no_noise() {
        let clip = ClipPolicy::non_private();
        let core = DpCore::from_accountant(CoreCfg {
            privacy: &PrivacySpec::default(),
            clip: &clip,
            sample_rate: 0.1,
            steps: 10,
            k: 1,
            group_dims: vec![3],
            expected_batch: 8.0,
            seed: 3,
        })
        .unwrap();
        let mut lp = StepLoop::new(core);
        let mut backend = StubBackend {
            sampler: PoissonSampler::new(64, 0.1, 16),
            units: 1,
            k: 1,
            applied: Vec::new(),
            scale: 1.0,
            last_live: 0,
        };
        let data = NullData(64);
        lp.step(&mut backend, &data).unwrap();
        // zero noise std => gradients stay exactly zero, RNG only drew the
        // Poisson batch
        assert!(backend.applied[0].data.iter().all(|&v| v == 0.0));
        let mut replay = Rng::seeded(3);
        PoissonSampler::new(64, 0.1, 16).sample_padded(&mut replay);
        assert_eq!(lp.core.rng.uniform(), replay.uniform());
    }
}
