//! `StepLoop` — the one DP training step, shared by every backend.
//!
//! The paper's central claim is that group-wise clipping composes with the
//! structure of the computation: per-layer clipping overlaps with
//! backprop, per-device clipping overlaps with cross-device reduction.
//! That composition used to be hand-rolled four times (single-device,
//! pipeline, sharded, hybrid), quadruplicating the DP-critical sequence.
//! This module owns it once:
//!
//! ```text
//!  1. deal      one global Poisson (or round-robin) draw    [draw RNG]
//!  2. collect   backend fwd/bwd + clip vs EXPLICIT thresholds  [no RNG]
//!  3. noise     local shares sigma_g/sqrt(U) per unit   [pre-split RNG]
//!  4. merge     cross-unit reduction + sim makespans        [no RNG]
//!  5. scale     /E[B] normalization (Algorithm 1 line 14)
//!  6. apply     optimizer update on every replica
//!  7. quantile  ONE private release over all groups         [core RNG]
//!  8. emit      one StepEvent
//! ```
//!
//! A backend is an implementation of [`BackendStep`]: it deals the draw
//! into local slices, exposes one Send collection task per data-parallel
//! unit, folds the tasks' results back into a [`Collected`], and merges
//! the (already-noised) unit gradients — everything DP-critical
//! (thresholds, noise calibration, RNG order, quantile adaptation,
//! accountant-facing normalization) lives here and cannot drift between
//! backends.
//!
//! Real threads: `collect` tasks are RNG-free and own disjoint state, so
//! with `threads > 1` the loop fans them out across a
//! [`std::thread::scope`] and joins in unit order — bitwise identical to
//! running the same closures sequentially. The noise phase is threadable
//! for the same reason once each unit has its own stream.
//!
//! RNG discipline (stream-split form): the core RNG is split ONCE at
//! construction into a dedicated draw stream (so `deal` can run a step
//! ahead of the noise/quantile stream for the prefetching loader). Each
//! private step then drains the core spare and splits one independent
//! child stream per unit, in unit order — the unit-major layout encodes
//! worker-major / replica-major / stage-major exactly as before, but the
//! parent now advances one u64 per unit regardless of element counts
//! (Marsaglia rejection makes position-splitting impossible). When every
//! group's std is 0 (non-private), the noise phase performs NO splits and
//! consumes nothing. The quantile release draws from the core stream and
//! drains its spare afterwards, so every phase boundary is at a
//! well-defined [`StreamPos`](crate::coordinator::noise::StreamPos). The
//! per-unit noise share is `std_g / sqrt(U)` with U = number of units, so
//! U independent shares merge (variances add) to exactly the accountant's
//! per-group std — and U = 1 degenerates to the full std, which is what
//! keeps the 1-worker / 1-replica parity pins bitwise.

use std::collections::hash_map::DefaultHasher;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::noise::{add_noise, Rng};
use crate::data::Dataset;
use crate::kernels::{GaussFill, Kernels};
use crate::obs::{PhaseSecs, Span, Tracer};

use super::core::DpCore;
use super::grad::{Collected, GradUnit, Merged, StepTiming, UnitCollected};
use super::StepEvent;

/// One unit's collection task: a Send closure the loop may execute on any
/// thread. Borrows the backend's per-unit state disjointly (`iter_mut`
/// over replicas) plus shared read-only context (dataset, thresholds,
/// `Arc<Exec>` clones).
pub(crate) type UnitTask<'a> = Box<dyn FnOnce() -> Result<UnitCollected> + Send + 'a>;

/// The backend contract: how one engine plugs into the shared
/// [`StepLoop`]. Hooks must not touch the core RNG except through the
/// arguments the loop passes them — `deal` receives the draw stream;
/// collection tasks and `merge` are RNG-free.
pub(crate) trait BackendStep {
    /// Backend-specific view of one dealt draw (padded per-worker slices,
    /// a single padded batch, a round-robin window, ...).
    type Slices;

    /// Draw this step's batch from the dedicated draw stream and deal it
    /// into the backend's local slices. `n_data` is the live dataset size
    /// (the round-robin cursor wraps over it).
    fn deal(&mut self, n_data: usize, rng: &mut Rng) -> Self::Slices;

    /// One Send task per data-parallel unit, in unit (noise) order. Each
    /// task runs the pre-noise collection for its unit: forward/backward
    /// + clip against the EXPLICIT `thresholds` (indexed by the backend's
    /// group mapping), returning the unit's summed gradients, counts and
    /// timings. Tasks consume no RNG, read no thresholds from anywhere
    /// else, and share no mutable state — the loop may run them on real
    /// OS threads.
    fn collect_tasks<'a>(
        &'a mut self,
        data: &'a dyn Dataset,
        slices: &'a Self::Slices,
        thresholds: &'a [f64],
    ) -> Vec<UnitTask<'a>>;

    /// Fold the per-unit results (returned in unit order, however they
    /// were scheduled) into one [`Collected`]: the backend picks its loss
    /// convention, mean-norm denominators and clip_frac denominators
    /// here, on the main thread.
    fn finish_collect(
        &mut self,
        slices: &Self::Slices,
        parts: Vec<UnitCollected>,
    ) -> Result<Collected>;

    /// Merge the units' (already-noised) gradients across the
    /// data-parallel axis and report the simulated reduction makespans.
    /// Single-unit backends return [`Merged::identity`].
    fn merge(&mut self, units: Vec<GradUnit>, timing: &StepTiming) -> Merged;

    /// Apply the merged, normalized gradient set (flattened in unit
    /// tensor order) to every parameter replica this backend holds.
    fn apply(&mut self, grads: &[crate::runtime::Tensor]);

    /// Post-merge normalization factor: `(1/E[B]) as f32` for private
    /// runs (Algorithm 1 line 14 normalizes by the EXPECTED batch), and
    /// the backend's documented non-private convention otherwise
    /// (1.0 = no rescale). Applied once to every merged element.
    fn update_scale(&self, live: usize) -> f32;

    /// The index lists this step's collection will pass to
    /// [`Dataset::batch`], for the prefetching loader. Backends that
    /// return an empty vec opt out of prefetching (the loader falls back
    /// to computing batches on demand either way).
    fn prefetch_lists(&self, _slices: &Self::Slices) -> Vec<Vec<usize>> {
        Vec::new()
    }
}

/// Wrap a task with the runner's busy-clock: `busy_secs` is wall time the
/// task spent executing, summed into the measured StepEvent columns. The
/// start instant and (hashed) executing-thread id ride along for the
/// tracer's per-unit collect spans — wall-clock bookkeeping only, no RNG.
fn run_timed(task: UnitTask<'_>) -> Result<UnitCollected> {
    let t0 = Instant::now();
    task().map(|mut p| {
        p.busy_secs = t0.elapsed().as_secs_f64();
        p.task_t0 = Some(t0);
        let mut h = DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        p.task_thread = h.finish();
        p
    })
}

/// Run `items` through `f`, fanning out over at most `threads` OS threads
/// (round-robin assignment, results returned in item order). `threads <=
/// 1` or a single item runs inline — the SAME code path the threaded
/// workers execute, so the two modes cannot drift.
pub(crate) fn run_buckets<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let workers = threads.min(n);
    let mut buckets: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        buckets[i % workers].push((i, item));
    }
    let f = &f;
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                s.spawn(move || {
                    bucket.into_iter().map(|(i, item)| (i, f(item))).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("step-loop worker thread panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|r| r.expect("bucket worker dropped a unit")).collect()
}

/// The DP-invariant per-step state machine: owns the shared [`DpCore`]
/// (plan, thresholds, noise allocation, RNG) and the step counter, and
/// drives any [`BackendStep`] through the eight phases.
pub struct StepLoop {
    /// shared DP state — plan, thresholds, noise, the core RNG (noise
    /// splits + quantile release)
    pub core: DpCore,
    /// dedicated draw stream, split from the core RNG at construction:
    /// `deal` consumes ONLY this stream, so the next step's draw can run
    /// ahead of the current step's noise/quantile without reordering
    /// either stream
    pub draw_rng: Rng,
    /// steps completed (1-based in emitted events)
    pub steps_done: u64,
    /// worker threads for the collect/noise fan-out; 1 = sequential
    /// (the reproducibility default — the threaded path is bitwise
    /// identical, but sequential keeps single-threaded determinism
    /// trivially auditable)
    pub threads: usize,
    /// per-phase span recorder (`None` = tracing disabled, the
    /// default). Strictly observational: spans record wall-clock only,
    /// never touch any RNG stream, and are pushed on the main thread —
    /// a traced run is bitwise identical to an untraced one (see
    /// [`crate::obs`])
    pub trace: Option<Tracer>,
    /// total steps this run plans to take (0 = unknown): the
    /// denominator of the per-step `eps_spent` release fraction. Set by
    /// the session builder; reporting-only
    pub planned_steps: u64,
    /// dispatched SIMD kernel vtable for the loop's own hot loops (noise
    /// fill + noise add, update rescale). `Kernels::scalar()` (the
    /// default) keeps the legacy one-gaussian-at-a-time bit-reference;
    /// the session builder installs the vtable the spec's `kernels` mode
    /// resolves to (see [`crate::kernels`])
    pub kernels: Kernels,
    /// durations of dealt-but-unconsumed draws (FIFO): the prefetching
    /// loader deals step t+1 during step t, so each deal's wall time is
    /// queued here and popped by the step that consumes the draw
    deal_secs: VecDeque<f64>,
}

impl StepLoop {
    pub fn new(core: DpCore) -> Self {
        Self::with_threads(core, 1)
    }

    pub fn with_threads(mut core: DpCore, threads: usize) -> Self {
        let draw_rng = core.rng.split();
        StepLoop {
            core,
            draw_rng,
            steps_done: 0,
            threads: threads.max(1),
            trace: None,
            planned_steps: 0,
            kernels: Kernels::default(),
            deal_secs: VecDeque::new(),
        }
    }

    /// Deal the next step's draw (consumes only the draw stream). Safe to
    /// run ahead of [`StepLoop::step_dealt`] for the current step — the
    /// prefetching loader uses this one-step lookahead.
    pub(crate) fn deal<B: BackendStep>(&mut self, backend: &mut B, n_data: usize) -> B::Slices {
        let t0 = Instant::now();
        let slices = backend.deal(n_data, &mut self.draw_rng);
        let t1 = Instant::now();
        // attribute this deal to the step that will CONSUME the draw:
        // under the prefetch lookahead that is one past the queue depth
        let step = self.steps_done + self.deal_secs.len() as u64 + 1;
        if let Some(tr) = self.trace.as_mut() {
            tr.record("deal", step, t0, t1);
        }
        self.deal_secs.push_back(t1.saturating_duration_since(t0).as_secs_f64());
        slices
    }

    /// One full DP step of `backend` over `data`; emits the unified
    /// [`StepEvent`].
    pub(crate) fn step<B: BackendStep>(
        &mut self,
        backend: &mut B,
        data: &dyn Dataset,
    ) -> Result<StepEvent> {
        // 1. deal: the only RNG the draw consumes
        let slices = self.deal(backend, data.len());
        self.step_dealt(backend, data, &slices)
    }

    /// Phases 2-8 over an already-dealt draw.
    pub(crate) fn step_dealt<B: BackendStep>(
        &mut self,
        backend: &mut B,
        data: &dyn Dataset,
        slices: &B::Slices,
    ) -> Result<StepEvent> {
        let host_t0 = Instant::now();
        // deal time of the draw this step consumes (queued by `deal`,
        // possibly one step ago under the prefetch lookahead)
        let deal_secs = self.deal_secs.pop_front().unwrap_or(0.0);

        // 2. collect: pre-noise gradients against the current thresholds,
        // one Send task per unit, fanned across real threads when asked
        let thresholds = self.core.thresholds().to_vec();
        let collect_t0 = Instant::now();
        let tasks = backend.collect_tasks(data, slices, &thresholds);
        let results = run_buckets(tasks, self.threads, run_timed);
        let collect_t1 = Instant::now();
        let collect_wall_secs = collect_t1.saturating_duration_since(collect_t0).as_secs_f64();
        let mut parts = Vec::with_capacity(results.len());
        for r in results {
            parts.push(r?);
        }
        let collect_busy_secs: f64 = parts.iter().map(|p| p.busy_secs).sum();
        // per-unit span metadata, lifted out before finish_collect
        // consumes the parts (tracing only)
        let unit_meta: Vec<(Option<Instant>, f64, u64)> = if self.trace.is_some() {
            parts.iter().map(|p| (p.task_t0, p.busy_secs, p.task_thread)).collect()
        } else {
            Vec::new()
        };
        let mut col = backend.finish_collect(slices, parts)?;

        // 3. noise: each unit adds its local share sigma_g/sqrt(U) on its
        // OWN pre-split stream, split from the core RNG in unit order.
        // All-zero stds (non-private) split nothing and consume nothing.
        let noise_t0 = Instant::now();
        let stds = self.core.noise_stds();
        if stds.iter().any(|&s| s > 0.0) {
            // unit boundary: child streams must derive from a spare-free
            // parent position
            self.core.rng.drain_spare();
            let share = 1.0 / (col.units.len().max(1) as f64).sqrt();
            let jobs: Vec<(&mut GradUnit, Rng)> = col
                .units
                .iter_mut()
                .map(|u| {
                    let stream = self.core.rng.split();
                    (u, stream)
                })
                .collect();
            let stds = &stds;
            let kn = self.kernels;
            run_buckets(jobs, self.threads, move |(unit, mut rng)| {
                debug_assert_eq!(unit.tensors.len(), unit.groups.len());
                if kn.reassociate() {
                    // batched fill: four lanes split off the unit's child
                    // stream generate gaussians in blocks, added through
                    // the bit-exact add_noise_from kernel. The core RNG
                    // still advances exactly one split per unit, so the
                    // scalar-vs-auto difference is confined to the bits of
                    // the noise itself (the documented `kernels` contract)
                    let mut fill = GaussFill::new(&mut rng);
                    let mut scratch: Vec<f64> = Vec::new();
                    for (t, &g) in unit.tensors.iter_mut().zip(&unit.groups) {
                        let std = stds[g] * share;
                        if std == 0.0 {
                            continue;
                        }
                        scratch.resize(t.data.len(), 0.0);
                        fill.fill(&kn, &mut scratch);
                        kn.add_noise_from(&mut t.data, &scratch, std);
                    }
                } else {
                    // the sequential bit-reference: one Marsaglia draw at
                    // a time on the unit's child stream
                    for (t, &g) in unit.tensors.iter_mut().zip(&unit.groups) {
                        add_noise(&mut t.data, stds[g] * share, &mut rng);
                    }
                }
            });
        }

        // 4. merge: cross-unit reduction (identity for single-unit
        // backends) + the overlap-vs-barrier latency model
        let merge_t0 = Instant::now();
        let mut merged = backend.merge(col.units, &col.timing);

        // 5. scale: one normalization of the merged sum
        let norm_t0 = Instant::now();
        let scale = backend.update_scale(col.live);
        if scale != 1.0 {
            for t in merged.tensors.iter_mut() {
                self.kernels.scale(&mut t.data, scale);
            }
        }

        // 6. apply: one update, broadcast to every replica by the backend
        let apply_t0 = Instant::now();
        backend.apply(&merged.tensors);

        // 7. quantile: ONE private release over all threshold groups
        // (adaptive cores are private by construction; fixed cores no-op)
        let quantile_t0 = Instant::now();
        if self.core.is_adaptive() {
            self.core.update_thresholds(&col.clip_counts);
            // phase boundary: the release's gaussians may buffer a
            // Marsaglia spare; drain so the next step's unit streams
            // derive from a well-defined position
            self.core.rng.drain_spare();
        }
        let quantile_t1 = Instant::now();

        // 8. emit
        self.steps_done += 1;
        let step_no = self.steps_done;
        let secs = |a: Instant, b: Instant| b.saturating_duration_since(a).as_secs_f64();
        let phase = PhaseSecs {
            deal: deal_secs,
            collect: collect_wall_secs,
            noise: secs(noise_t0, merge_t0),
            merge: secs(merge_t0, norm_t0),
            normalize: secs(norm_t0, apply_t0),
            apply: secs(apply_t0, quantile_t0),
            quantile: secs(quantile_t0, quantile_t1),
        };
        // spans land AFTER all DP work for the step: the tracer is pure
        // wall-clock bookkeeping appended on the main thread
        if let Some(tr) = self.trace.as_mut() {
            tr.record("collect", step_no, collect_t0, collect_t1);
            for (i, (t0, busy, thash)) in unit_meta.iter().enumerate() {
                if let Some(t0) = t0 {
                    let track = tr.track_for(*thash);
                    let start_us = tr.us_since_epoch(*t0);
                    tr.push(Span {
                        name: "collect",
                        start_us,
                        dur_us: (busy * 1e6) as u64,
                        step: step_no,
                        track,
                        unit: Some(i),
                    });
                }
            }
            tr.record("noise", step_no, noise_t0, merge_t0);
            tr.record("merge", step_no, merge_t0, norm_t0);
            tr.record("normalize", step_no, norm_t0, apply_t0);
            tr.record("apply", step_no, apply_t0, quantile_t0);
            tr.record("quantile", step_no, quantile_t0, quantile_t1);
        }
        let eps_spent =
            super::epsilon_spent_at(self.core.plan, self.steps_done, self.planned_steps);
        let clip_frac: Vec<f64> = col
            .clip_denoms
            .iter()
            .zip(&col.clip_counts)
            // an empty Poisson draw reports denominator 0: nothing was
            // clipped OR kept, so the clipped fraction is 0, not NaN
            .map(|(&d, &c)| if d > 0.0 { 1.0 - c / d } else { 0.0 })
            .collect();
        Ok(StepEvent {
            step: self.steps_done,
            loss: col.loss,
            batch_size: col.live,
            clip_frac,
            mean_norms: col.mean_norms,
            host_secs: host_t0.elapsed().as_secs_f64(),
            sim_secs: merged.sim_secs,
            sim_overlap_secs: merged.sim_overlap_secs,
            sim_barrier_secs: merged.sim_barrier_secs,
            collect_wall_secs,
            collect_busy_secs,
            threads: self.threads,
            syncs: col.syncs + merged.syncs,
            calls: col.calls,
            truncated: col.truncated,
            unit: self.core.plan.map(|p| p.unit.token()).unwrap_or("example"),
            phase,
            eps_spent,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampler::PoissonSampler;
    use crate::data::ModelBatch;
    use crate::runtime::{IntTensor, Tensor};
    use crate::session::spec::{ClipMode, ClipPolicy, GroupBy, PrivacySpec};
    use crate::session::{CoreCfg, DpCore};

    struct NullData(usize);
    impl Dataset for NullData {
        fn len(&self) -> usize {
            self.0
        }
        fn batch(&self, indices: &[usize]) -> ModelBatch {
            ModelBatch::Cls {
                x: IntTensor::zeros(&[indices.len(), 1]),
                y: IntTensor::zeros(&[indices.len()]),
            }
        }
    }

    /// A two-unit, two-groups-per-unit stub: unit u's tensor for group g
    /// starts at zero, so after the loop runs the tensor values ARE the
    /// noise the loop drew for (u, g) — which lets the test replay the
    /// documented RNG discipline by hand.
    struct StubBackend {
        sampler: PoissonSampler,
        units: usize,
        k: usize,
        applied: Vec<Tensor>,
        scale: f32,
        last_live: usize,
    }

    impl BackendStep for StubBackend {
        type Slices = crate::coordinator::sampler::Batch;

        fn deal(&mut self, _n: usize, rng: &mut Rng) -> Self::Slices {
            self.sampler.sample_padded(rng)
        }

        fn collect_tasks<'a>(
            &'a mut self,
            _data: &'a dyn Dataset,
            _slices: &'a Self::Slices,
            thresholds: &'a [f64],
        ) -> Vec<UnitTask<'a>> {
            assert_eq!(thresholds.len(), self.k);
            let k = self.k;
            (0..self.units)
                .map(|_| {
                    let task: UnitTask<'a> = Box::new(move || {
                        Ok(UnitCollected::new(
                            GradUnit {
                                tensors: (0..k).map(|_| Tensor::zeros(&[3])).collect(),
                                groups: (0..k).collect(),
                            },
                            k,
                        ))
                    });
                    task
                })
                .collect()
        }

        fn finish_collect(
            &mut self,
            slices: &Self::Slices,
            parts: Vec<UnitCollected>,
        ) -> Result<Collected> {
            self.last_live = slices.live();
            Ok(Collected {
                units: parts.into_iter().map(|p| p.unit).collect(),
                clip_counts: vec![1.0; self.k],
                // TRUE denominator: 0 on an empty draw (the loop guards
                // the division)
                clip_denoms: vec![slices.live() as f64; self.k],
                mean_norms: vec![0.5; self.k],
                loss: 1.25,
                live: slices.live(),
                truncated: slices.truncated,
                calls: self.units,
                syncs: 0,
                timing: StepTiming::default(),
            })
        }

        fn merge(&mut self, units: Vec<GradUnit>, _t: &StepTiming) -> Merged {
            // plain sum across units (fanout irrelevant for the stub)
            let mut it = units.into_iter();
            let mut acc = it.next().unwrap().tensors;
            for u in it {
                for (a, b) in acc.iter_mut().zip(&u.tensors) {
                    for (x, y) in a.data.iter_mut().zip(&b.data) {
                        *x += *y;
                    }
                }
            }
            Merged {
                tensors: acc,
                sim_secs: 0.0,
                sim_overlap_secs: 0.0,
                sim_barrier_secs: 0.0,
                syncs: 0,
            }
        }

        fn apply(&mut self, grads: &[Tensor]) {
            self.applied = grads.to_vec();
        }

        fn update_scale(&self, _live: usize) -> f32 {
            self.scale
        }
    }

    fn stub(units: usize, k: usize) -> StubBackend {
        StubBackend {
            sampler: PoissonSampler::new(64, 0.1, 16),
            units,
            k,
            applied: Vec::new(),
            scale: 0.5,
            last_live: 0,
        }
    }

    fn core(k: usize, seed: u64) -> DpCore {
        let clip = ClipPolicy {
            clip_init: 1.0,
            ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Adaptive)
        };
        DpCore::from_accountant(CoreCfg {
            privacy: &PrivacySpec { epsilon: 3.0, delta: 1e-5, quantile_r: 0.01 },
            clip: &clip,
            sample_rate: 0.1,
            steps: 10,
            k,
            group_dims: vec![3; k],
            expected_batch: 8.0,
            seed,
        })
        .unwrap()
    }

    #[test]
    fn steploop_rng_discipline_is_draw_stream_then_per_unit_splits_then_quantile() {
        // run the loop, then replay the documented RNG order by hand on a
        // fresh RNG with the same seed; the stub's applied gradients must
        // equal the replayed noise (scaled), and the threshold trajectory
        // must match a manual quantile update — proving the loop consumes
        // the streams as (0) one construction split for the draw stream,
        // (1) draw on the draw stream, (2) one child split per unit in
        // unit order with noise at std_g/sqrt(U) on the child, (3) one
        // quantile release on the core stream, spare drained.
        let (units, k, seed) = (2usize, 2usize, 7u64);
        let mut lp = StepLoop::new(core(k, seed));
        let stds = lp.core.noise_stds();
        let init_thr = lp.core.thresholds().to_vec();
        let mut backend = stub(units, k);
        backend.scale = 0.5;
        let data = NullData(64);
        let ev = lp.step(&mut backend, &data).unwrap();
        assert_eq!(ev.step, 1);
        assert_eq!(ev.batch_size, backend.last_live);
        assert_eq!(ev.clip_frac.len(), k);
        assert_eq!(ev.threads, 1);
        assert!(ev.collect_wall_secs >= 0.0 && ev.collect_busy_secs >= 0.0);

        // ---- replay ----
        let mut replay = Rng::seeded(seed);
        let mut draw = replay.split(); // construction split
        let drawn = PoissonSampler::new(64, 0.1, 16).sample_padded(&mut draw);
        assert_eq!(drawn.live(), backend.last_live, "same draw");
        let share = 1.0 / (units as f64).sqrt();
        let mut expect: Vec<Vec<f32>> = vec![vec![0.0; 3]; k];
        replay.drain_spare(); // no-op here, but part of the contract
        for _u in 0..units {
            let mut child = replay.split();
            for (g, e) in expect.iter_mut().enumerate() {
                for slot in e.iter_mut() {
                    *slot += (stds[g] * share * child.gauss()) as f32;
                }
            }
        }
        for (g, t) in backend.applied.iter().enumerate() {
            for (a, e) in t.data.iter().zip(&expect[g]) {
                assert!((a - e * 0.5).abs() < 1e-6, "group {g}: {a} vs {}", e * 0.5);
            }
        }
        // the quantile release consumed exactly k gaussians after the
        // noise splits: replaying it reproduces the threshold trajectory
        let mut q = crate::coordinator::quantile::QuantileEstimator::adaptive(
            init_thr,
            lp.core.quantiles.target_q,
            lp.core.quantiles.eta,
            lp.core.quantiles.sigma_b,
            lp.core.quantiles.batch,
        );
        q.update(&vec![1.0; k], &mut replay);
        replay.drain_spare();
        // (no A.1 rescale: per-device policies default rescale_global off)
        assert_eq!(lp.core.thresholds(), &q.thresholds[..], "same trajectory");
        // streams fully aligned afterwards — position, not a uniform()
        // sample (which cannot see a buffered Marsaglia spare)
        assert_eq!(lp.core.rng.stream_pos(), replay.stream_pos());
        assert_eq!(lp.draw_rng.stream_pos(), draw.stream_pos());
        assert!(!lp.core.rng.stream_pos().has_spare, "quantile spare must be drained");
    }

    #[test]
    fn steploop_scale_one_skips_rescale_and_nonprivate_core_draws_no_noise() {
        let clip = ClipPolicy::non_private();
        let core = DpCore::from_accountant(CoreCfg {
            privacy: &PrivacySpec::default(),
            clip: &clip,
            sample_rate: 0.1,
            steps: 10,
            k: 1,
            group_dims: vec![3],
            expected_batch: 8.0,
            seed: 3,
        })
        .unwrap();
        let mut lp = StepLoop::new(core);
        let mut backend = stub(1, 1);
        backend.scale = 1.0;
        let data = NullData(64);
        lp.step(&mut backend, &data).unwrap();
        // zero noise std => gradients stay exactly zero, no unit streams
        // were split, and the core RNG advanced ONLY by the construction
        // split for the draw stream
        assert!(backend.applied[0].data.iter().all(|&v| v == 0.0));
        let mut replay = Rng::seeded(3);
        let mut draw = replay.split();
        PoissonSampler::new(64, 0.1, 16).sample_padded(&mut draw);
        assert_eq!(lp.core.rng.stream_pos(), replay.stream_pos());
        assert_eq!(lp.draw_rng.stream_pos(), draw.stream_pos());
    }

    #[test]
    fn steploop_threaded_collect_and_noise_are_bitwise_identical_to_sequential() {
        // the tentpole's parity property at the unit level: same seed,
        // threads = 1 vs threads = 4, several adaptive private steps —
        // applied updates, thresholds, events and post-run stream
        // positions must be IDENTICAL to the bit. Units (2) < threads (4)
        // and units (5) > threads (2) both exercised.
        for (units, threads) in [(2usize, 4usize), (5, 2), (3, 3)] {
            let k = 2;
            let seed = 21;
            let mut seq = StepLoop::new(core(k, seed));
            let mut par = StepLoop::with_threads(core(k, seed), threads);
            assert_eq!(par.threads, threads);
            let mut b_seq = stub(units, k);
            let mut b_par = stub(units, k);
            let data = NullData(64);
            for step in 0..4 {
                let e1 = seq.step(&mut b_seq, &data).unwrap();
                let e2 = par.step(&mut b_par, &data).unwrap();
                assert_eq!(e1.batch_size, e2.batch_size, "step {step}");
                assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
                for (a, b) in e1.clip_frac.iter().zip(&e2.clip_frac) {
                    assert_eq!(a.to_bits(), b.to_bits(), "step {step}");
                }
                assert_eq!(b_seq.applied.len(), b_par.applied.len());
                for (ta, tb) in b_seq.applied.iter().zip(&b_par.applied) {
                    for (x, y) in ta.data.iter().zip(&tb.data) {
                        assert_eq!(x.to_bits(), y.to_bits(), "step {step}: update diverged");
                    }
                }
                assert_eq!(seq.core.thresholds(), par.core.thresholds(), "step {step}");
            }
            assert_eq!(seq.core.rng.stream_pos(), par.core.rng.stream_pos());
            assert_eq!(seq.draw_rng.stream_pos(), par.draw_rng.stream_pos());
        }
    }

    #[test]
    fn steploop_auto_kernels_keep_stream_positions_but_change_noise_bits() {
        // kernels = auto swaps the noise-fill algorithm (batched 4-lane
        // polar) but the core RNG discipline is unchanged: one split per
        // unit, quantile on the core stream. So thresholds and every
        // stream position must match scalar bitwise, while the noise
        // itself differs — exactly the documented `kernels` contract.
        let (units, k, seed) = (2usize, 2usize, 7u64);
        let mut a = StepLoop::new(core(k, seed));
        let mut b = StepLoop::new(core(k, seed));
        b.kernels = Kernels::for_mode(crate::kernels::KernelMode::Auto);
        let mut ba = stub(units, k);
        let mut bb = stub(units, k);
        let data = NullData(64);
        let mut noise_differs = false;
        for step in 0..3 {
            let e1 = a.step(&mut ba, &data).unwrap();
            let e2 = b.step(&mut bb, &data).unwrap();
            assert_eq!(e1.batch_size, e2.batch_size, "step {step}: same draw");
            assert_eq!(a.core.thresholds(), b.core.thresholds(), "step {step}");
            for (ta, tb) in ba.applied.iter().zip(&bb.applied) {
                if ta.data != tb.data {
                    noise_differs = true;
                }
            }
        }
        assert_eq!(a.core.rng.stream_pos(), b.core.rng.stream_pos());
        assert_eq!(a.draw_rng.stream_pos(), b.draw_rng.stream_pos());
        assert!(noise_differs, "auto mode must draw a different noise stream");
    }

    #[test]
    fn steploop_empty_draw_reports_zero_clip_frac_not_nan() {
        // regression (ISSUE 7 satellite): a Poisson draw with live == 0
        // used to divide by a zero denominator and put NaN into the event
        let mut lp = StepLoop::new(core(2, 5));
        let mut backend = stub(2, 2);
        // a rate this small makes an empty draw near-certain immediately;
        // loop a few steps to be safe and require at least one empty
        backend.sampler = PoissonSampler::new(64, 1e-9, 4);
        let data = NullData(64);
        let mut saw_empty = false;
        for _ in 0..8 {
            let ev = lp.step(&mut backend, &data).unwrap();
            for (g, f) in ev.clip_frac.iter().enumerate() {
                assert!(f.is_finite(), "group {g}: clip_frac {f} not finite");
            }
            if ev.batch_size == 0 {
                saw_empty = true;
                assert!(ev.clip_frac.iter().all(|&f| f == 0.0), "empty draw must report 0");
            }
        }
        assert!(saw_empty, "sampler at rate 1e-9 never drew an empty batch?");
    }

    #[test]
    fn steploop_tracing_is_bitwise_neutral_and_records_phase_spans() {
        // same seed, tracer on vs off: applied updates, thresholds and
        // post-run stream positions must be identical to the bit — the
        // tracer only reads the wall clock. Spans must cover the full
        // phase taxonomy with one collect span per unit per step.
        let (units, k, seed, steps) = (2usize, 2usize, 33u64, 3u64);
        let mut plain = StepLoop::new(core(k, seed));
        let mut traced = StepLoop::new(core(k, seed));
        traced.trace = Some(Tracer::new());
        let mut b1 = stub(units, k);
        let mut b2 = stub(units, k);
        let data = NullData(64);
        for _ in 0..steps {
            let e1 = plain.step(&mut b1, &data).unwrap();
            let e2 = traced.step(&mut b2, &data).unwrap();
            assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
            assert_eq!(e1.batch_size, e2.batch_size);
            for (ta, tb) in b1.applied.iter().zip(&b2.applied) {
                for (x, y) in ta.data.iter().zip(&tb.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "tracing changed the update");
                }
            }
            assert_eq!(plain.core.thresholds(), traced.core.thresholds());
            // every phase is timed on both loops (>= 0 wall seconds)
            for (name, v) in e2.phase.iter() {
                assert!(v >= 0.0, "phase {name} negative: {v}");
            }
            assert!(e2.phase.collect >= 0.0 && e1.phase.total() >= 0.0);
        }
        assert_eq!(plain.core.rng.stream_pos(), traced.core.rng.stream_pos());
        assert_eq!(plain.draw_rng.stream_pos(), traced.draw_rng.stream_pos());

        let tr = traced.trace.as_ref().unwrap();
        // 7 main-track phase spans + one per-unit collect span, per step
        assert_eq!(tr.len() as u64, steps * (7 + units as u64));
        for step in 1..=steps {
            let names: Vec<&str> =
                tr.spans().filter(|s| s.step == step && s.unit.is_none()).map(|s| s.name).collect();
            for want in PhaseSecs::NAMES {
                assert!(names.contains(&want), "step {step} missing span {want}");
            }
            let unit_spans: Vec<usize> = tr
                .spans()
                .filter(|s| s.step == step && s.unit.is_some())
                .map(|s| s.unit.unwrap())
                .collect();
            assert_eq!(unit_spans, vec![0, 1], "one collect span per unit, in unit order");
        }
        // the export renders and parses
        let doc = tr.to_chrome_json();
        assert!(doc.get("traceEvents").unwrap().arr().unwrap().len() > tr.len());
    }

    #[test]
    fn steploop_traced_deal_ahead_attributes_deal_to_consuming_step() {
        // the prefetch lookahead deals draw t+1 during step t: the deal
        // span (and the PhaseSecs.deal attribution) must follow the draw
        // to the step that consumes it, via the FIFO queue
        let (units, k, seed) = (2usize, 2usize, 9u64);
        let mut lp = StepLoop::new(core(k, seed));
        lp.trace = Some(Tracer::new());
        let mut b = stub(units, k);
        let data = NullData(64);
        let mut pending = lp.deal(&mut b, data.len());
        for _ in 0..3 {
            let slices = std::mem::replace(&mut pending, lp.deal(&mut b, data.len()));
            lp.step_dealt(&mut b, &data, &slices).unwrap();
        }
        let tr = lp.trace.as_ref().unwrap();
        let deal_steps: Vec<u64> =
            tr.spans().filter(|s| s.name == "deal").map(|s| s.step).collect();
        // 4 deals: steps 1..=3 consumed, step 4 dealt ahead and pending
        assert_eq!(deal_steps, vec![1, 2, 3, 4]);
    }

    #[test]
    fn steploop_deal_ahead_matches_deal_in_step() {
        // the prefetch lookahead contract: dealing step t+1 BEFORE
        // executing step t is invisible to both streams, because deal
        // consumes only the dedicated draw stream
        let (units, k, seed) = (2usize, 2usize, 9u64);
        let mut inline = StepLoop::new(core(k, seed));
        let mut ahead = StepLoop::new(core(k, seed));
        let mut b1 = stub(units, k);
        let mut b2 = stub(units, k);
        let data = NullData(64);

        let mut pending = ahead.deal(&mut b2, data.len());
        for _ in 0..3 {
            let e1 = inline.step(&mut b1, &data).unwrap();
            let slices = std::mem::replace(&mut pending, ahead.deal(&mut b2, data.len()));
            let e2 = ahead.step_dealt(&mut b2, &data, &slices).unwrap();
            assert_eq!(e1.batch_size, e2.batch_size);
            assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
            assert_eq!(inline.core.thresholds(), ahead.core.thresholds());
            for (ta, tb) in b1.applied.iter().zip(&b2.applied) {
                for (x, y) in ta.data.iter().zip(&tb.data) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
        // ahead has dealt one extra draw; consuming one more on the
        // inline loop's draw stream lands both on the same position
        inline.deal(&mut b1, data.len());
        assert_eq!(inline.draw_rng.stream_pos(), ahead.draw_rng.stream_pos());
        assert_eq!(inline.core.rng.stream_pos(), ahead.core.rng.stream_pos());
    }
}
