//! The session API — single entry point for every training scenario.
//!
//! The paper's framing is that flat, per-layer and per-device clipping are
//! instances of one abstraction (group-wise clipping); this module makes
//! the crate's API match: one declarative [`RunSpec`] (privacy target,
//! [`ClipPolicy`], optimizer, data), one [`SessionBuilder`], and one
//! [`Session`] that selects the backend from the manifest + spec —
//! configs with pipeline stages train on the [`PipelineEngine`] (or, with
//! a `[hybrid]` section, on the 2D pipeline-x-data-parallel
//! [`HybridEngine`](crate::hybrid::HybridEngine)), stage-less specs with
//! a `[shard]` section on the data-parallel
//! [`ShardEngine`](crate::shard::ShardEngine), everything else on the
//! single-device [`Trainer`]. All backends share one [`DpCore`] (plan,
//! thresholds, noise, RNG) and emit one [`StepEvent`] stream.
//!
//! ```no_run
//! use gwclip::runtime::Runtime;
//! use gwclip::session::{ClipMode, ClipPolicy, GroupBy, PrivacySpec, Session};
//!
//! let rt = Runtime::new("artifacts").unwrap();
//! let (mut sess, train, eval) = Session::builder(&rt, "resmlp")
//!     .privacy(PrivacySpec::new(3.0, 1e-5))
//!     .clip(ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive))
//!     .epochs(3.0)
//!     .build_with_data()
//!     .unwrap();
//! sess.run(&*train, 10).unwrap();
//! let (loss, acc) = sess.evaluate(&*eval).unwrap();
//! println!("loss {loss:.3} acc {acc:.3}");
//! ```
//!
//! Specs serialize to TOML/JSON (`gwclip run --spec run.toml`); see
//! `docs/SESSION_API.md`.

pub mod core;
pub(crate) mod grad;
pub(crate) mod prefetch;
pub mod snapshot;
pub mod spec;
pub mod steploop;

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::accountant::PrivacyPlan;
use crate::coordinator::noise::StreamPos;
use crate::coordinator::sampler::PoissonSampler;
use crate::coordinator::trainer::{derive_schedule, TrainOpts, Trainer};
use crate::data::Dataset;
use crate::federated::engine::FederatedWiring;
use crate::federated::{CohortGrouping, FederatedEngine};
use crate::hybrid::engine::HybridWiring;
use crate::hybrid::{HybridEngine, PieceGrouping};
use crate::kernels::Kernels;
use crate::pipeline::{PipelineEngine, PipelineMode, PipelineOpts};
use crate::runtime::{Runtime, Tensor};
use crate::shard::engine::ShardWiring;
use crate::shard::{ShardEngine, WorkerGrouping};

pub use crate::kernels::KernelMode;
pub use crate::shard::compress::CompressKind;

pub use self::core::{CoreCfg, DpCore};
pub use self::spec::{
    ClipMode, ClipPolicy, CompressSpec, DataSpec, ExamplesDist, FederatedGrouping, FederatedSpec,
    FlatImpl, GroupBy, HybridGrouping, HybridSpec, OptimSpec, PipeSpec, PrivacySpec, RunSpec,
    Sampling, ShardGrouping, ShardSpec,
};
pub use self::steploop::StepLoop;

// -------------------------------------------------------------- step event

/// One training step, emitted by the shared [`StepLoop`] for every
/// backend so the CLI and the experiment harness print/collect through a
/// single path. This is the ONLY per-step report in the crate — the
/// legacy per-backend stat structs (`StepStats`, `PipeStepStats`,
/// `ShardStepStats`, `HybridStepStats`) are retired.
#[derive(Debug, Clone)]
pub struct StepEvent {
    /// 1-based step index
    pub step: u64,
    pub loss: f64,
    /// live examples this step (Poisson draw / pipeline minibatch)
    pub batch_size: usize,
    /// fraction of examples clipped, per group (empty for pipeline runs)
    pub clip_frac: Vec<f64>,
    /// mean per-example norm per group (empty for pipeline/hybrid runs)
    pub mean_norms: Vec<f64>,
    /// measured host seconds for the whole step
    pub host_secs: f64,
    /// simulated multi-device makespan (0 for the single-device backend)
    pub sim_secs: f64,
    /// simulated latency with the cross-replica reduction overlapped into
    /// backprop (sharded/hybrid backends; 0 elsewhere)
    pub sim_overlap_secs: f64,
    /// simulated latency with a reduce-after-backward barrier
    /// (sharded/hybrid backends; 0 elsewhere)
    pub sim_barrier_secs: f64,
    /// MEASURED wall-clock seconds of the collect phase — the real-time
    /// column next to the simulated `sim_overlap_secs`/`sim_barrier_secs`
    /// makespans. With `threads > 1` the per-unit tasks overlap, so this
    /// drops below `collect_busy_secs`
    pub collect_wall_secs: f64,
    /// summed per-unit busy seconds inside the collect tasks; wall ==
    /// busy (almost) when sequential, wall < busy when the thread fan-out
    /// overlaps units — their ratio is the measured speedup the benches
    /// compare against the modeled one
    pub collect_busy_secs: f64,
    /// OS threads the step loop fanned collect/noise across this step
    /// (1 = sequential, the reproducibility default)
    pub threads: usize,
    /// sync barriers this step (0 for the single-device backend)
    pub syncs: usize,
    /// executable invocations (0 for the single-device backend)
    pub calls: usize,
    /// examples the Poisson draw included but the static batch capacity
    /// dropped (0 for round-robin pipeline steps; rare when capacity is
    /// sized ~1.25x the expected batch)
    pub truncated: usize,
    /// the unit of privacy this step's release protects — `"example"`
    /// for DP-SGD-style backends, `"user"` for the federated backend
    /// (add/remove one user and every example they contribute);
    /// `"example"` for non-private runs, where no guarantee is claimed
    pub unit: &'static str,
    /// measured wall seconds per DP phase (deal, collect, noise, merge,
    /// normalize, apply, quantile) — observational timing only, always
    /// populated whether or not span tracing is enabled
    pub phase: crate::obs::PhaseSecs,
    /// privacy spent through this step: (eps, delta)-composition over
    /// the releases made so far, computed from already-released
    /// accountant values (pure post-processing — no new query). `None`
    /// for non-private runs
    pub eps_spent: Option<f64>,
}

impl StepEvent {
    /// The event as a JSON object (the serve daemon's ndjson event
    /// stream). Numbers render through Rust's shortest-round-trip f64
    /// formatting, so finite values parse back to equal floats. EVERY
    /// struct field is serialized — the key set is pinned by
    /// `step_event_json_carries_every_field`, so a field added here
    /// without a key (or vice versa) fails the suite.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let nums = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        let mut m = std::collections::BTreeMap::new();
        m.insert("step".to_string(), Json::Num(self.step as f64));
        m.insert("loss".to_string(), Json::Num(self.loss));
        m.insert("batch_size".to_string(), Json::Num(self.batch_size as f64));
        m.insert("clip_frac".to_string(), nums(&self.clip_frac));
        m.insert("mean_norms".to_string(), nums(&self.mean_norms));
        m.insert("host_secs".to_string(), Json::Num(self.host_secs));
        m.insert("sim_secs".to_string(), Json::Num(self.sim_secs));
        m.insert("sim_overlap_secs".to_string(), Json::Num(self.sim_overlap_secs));
        m.insert("sim_barrier_secs".to_string(), Json::Num(self.sim_barrier_secs));
        m.insert("collect_wall_secs".to_string(), Json::Num(self.collect_wall_secs));
        m.insert("collect_busy_secs".to_string(), Json::Num(self.collect_busy_secs));
        m.insert("threads".to_string(), Json::Num(self.threads as f64));
        m.insert("syncs".to_string(), Json::Num(self.syncs as f64));
        m.insert("calls".to_string(), Json::Num(self.calls as f64));
        m.insert("truncated".to_string(), Json::Num(self.truncated as f64));
        m.insert("unit".to_string(), Json::Str(self.unit.to_string()));
        let mut ph = std::collections::BTreeMap::new();
        for (name, v) in self.phase.iter() {
            ph.insert(name.to_string(), Json::Num(v));
        }
        m.insert("phase_secs".to_string(), Json::Obj(ph));
        m.insert(
            "eps_spent".to_string(),
            match self.eps_spent {
                Some(e) => Json::Num(e),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    /// One-line human-readable progress report. Backends that simulate a
    /// cross-replica reduction (sharded, hybrid) also report both the
    /// overlapped and barrier makespans; capacity-bound truncated draws
    /// are flagged on any backend.
    pub fn log_line(&self, total_steps: u64, label: &str) -> String {
        let trunc = if self.truncated > 0 {
            format!(" trunc {}", self.truncated)
        } else {
            String::new()
        };
        if self.calls > 0 {
            let reduction = if self.sim_barrier_secs > 0.0 {
                format!(
                    " ovl {:.3}s/bar {:.3}s",
                    self.sim_overlap_secs, self.sim_barrier_secs
                )
            } else {
                String::new()
            };
            let measured = if self.threads > 1 {
                format!(
                    " coll {:.2}s/{:.2}s x{}",
                    self.collect_wall_secs, self.collect_busy_secs, self.threads
                )
            } else {
                String::new()
            };
            format!(
                "[{label}] step {}/{} loss {:.4} host {:.2}s sim {:.3}s{reduction}{measured} \
                 syncs {} calls {}{trunc}",
                self.step, total_steps, self.loss, self.host_secs, self.sim_secs, self.syncs,
                self.calls
            )
        } else {
            format!(
                "[{label}] step {}/{} loss {:.4} |B|={} clip~{:.2}{trunc}",
                self.step,
                total_steps,
                self.loss,
                self.batch_size,
                self.clip_frac.first().copied().unwrap_or(0.0)
            )
        }
    }
}

// ----------------------------------------------------------------- backend

/// The executor a session selected from the manifest + spec: pipeline for
/// staged configs, hybrid (pipeline x data-parallel) when a staged
/// config's spec carries a `[hybrid]` section, sharded when a stage-less
/// config's spec carries `[shard]` (or `[hybrid]`, whose grid then has no
/// pipeline axis), federated (user-level DP over a simulated population)
/// when it carries `[federated]`, single-device otherwise.
pub enum Backend<'r> {
    Single(Trainer<'r>),
    Pipeline(PipelineEngine<'r>),
    Sharded(ShardEngine<'r>),
    Hybrid(HybridEngine<'r>),
    Federated(FederatedEngine<'r>),
}

impl Backend<'_> {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Single(_) => "single-device",
            Backend::Pipeline(_) => "pipeline",
            Backend::Sharded(_) => "sharded",
            Backend::Hybrid(_) => "hybrid",
            Backend::Federated(_) => "federated",
        }
    }
}

// ----------------------------------------------------------------- builder

/// Fluent construction of a [`Session`] from a [`RunSpec`].
pub struct SessionBuilder<'r> {
    runtime: &'r Runtime,
    spec: RunSpec,
}

impl<'r> SessionBuilder<'r> {
    pub fn new(runtime: &'r Runtime, config: &str) -> Self {
        SessionBuilder { runtime, spec: RunSpec::for_config(config) }
    }

    pub fn from_spec(runtime: &'r Runtime, spec: RunSpec) -> Self {
        SessionBuilder { runtime, spec }
    }

    pub fn spec(&self) -> &RunSpec {
        &self.spec
    }

    pub fn privacy(mut self, p: PrivacySpec) -> Self {
        self.spec.privacy = p;
        self
    }

    pub fn clip(mut self, c: ClipPolicy) -> Self {
        self.spec.clip = c;
        self
    }

    pub fn optim(mut self, o: OptimSpec) -> Self {
        self.spec.optim = o;
        self
    }

    pub fn data(mut self, d: DataSpec) -> Self {
        self.spec.data = d;
        self
    }

    pub fn epochs(mut self, epochs: f64) -> Self {
        self.spec.epochs = epochs;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    pub fn expected_batch(mut self, b: usize) -> Self {
        self.spec.expected_batch = b;
        self
    }

    /// OS threads fanning out the per-unit collect tasks and noise jobs
    /// (1 = sequential, the default). The threaded path is bitwise
    /// identical to the sequential one; `threads > 1` also turns on the
    /// background prefetching data loader in [`Session::run`].
    /// `GWCLIP_THREADS` overrides this at run time.
    pub fn threads(mut self, n: usize) -> Self {
        self.spec.threads = n;
        self
    }

    /// Host-side kernel dispatch mode (default [`KernelMode::Scalar`],
    /// the bit-reference). `auto` runs elementwise kernels on the fastest
    /// detected ISA (bitwise identical to scalar) and switches the
    /// reassociating kernels — squared norms, pair-folded tree reduction,
    /// batched gaussian fill — to their blocked, mode-deterministic
    /// variants. `GWCLIP_KERNELS` overrides this at run time.
    pub fn kernels(mut self, mode: KernelMode) -> Self {
        self.spec.kernels = mode;
        self
    }

    pub fn n_micro(mut self, j: usize) -> Self {
        self.spec.pipe.n_micro = j;
        self
    }

    /// Pipeline minibatch sampling strategy (default [`Sampling::Poisson`]).
    pub fn sampling(mut self, s: Sampling) -> Self {
        self.spec.pipe.sampling = s;
        self
    }

    /// Select the sharded data-parallel backend (stage-less configs only).
    pub fn shard(mut self, sh: ShardSpec) -> Self {
        self.spec.shard = Some(sh);
        self
    }

    /// Select the hybrid 2D-parallel backend: R data-parallel replicas,
    /// each a full pipeline over the config's stages (stage-less configs
    /// degenerate to the sharded backend).
    pub fn hybrid(mut self, hy: HybridSpec) -> Self {
        self.spec.hybrid = Some(hy);
        self
    }

    /// Select the federated user-level backend (stage-less configs only):
    /// Poisson-sample users from a simulated population and clip each
    /// sampled user's full model delta — group-wise clipping with
    /// groups = users.
    pub fn federated(mut self, f: FederatedSpec) -> Self {
        self.spec.federated = Some(f);
        self
    }

    /// Enable error-feedback gradient compression on the cross-replica
    /// reduction path (sharded and hybrid backends only).
    pub fn compress(mut self, c: CompressSpec) -> Self {
        self.spec.compress = Some(c);
        self
    }

    /// Explicit pipeline step count (overrides the epochs-derived count).
    pub fn steps(mut self, steps: usize) -> Self {
        self.spec.pipe.steps = steps;
        self
    }

    /// Build against a caller-supplied dataset of `n_data` examples (the
    /// sampling rate and step count depend on it).
    pub fn build(self, n_data: usize) -> Result<Session<'r>> {
        let mut sess = self.build_inner(n_data)?;
        // reporting-only: lets the step loop emit eps_spent per event
        // without re-deriving the schedule
        sess.steploop.planned_steps = sess.total_steps;
        // one insertion point installs the resolved kernel mode on the
        // step loop and every backend hot loop (spec < GWCLIP_KERNELS)
        let mode = sess.spec.resolved_kernels();
        sess.set_kernels(Kernels::for_mode(mode));
        Ok(sess)
    }

    fn build_inner(self, n_data: usize) -> Result<Session<'r>> {
        let SessionBuilder { runtime, spec } = self;
        spec.validate().context("invalid run spec")?;
        let threads = spec.resolved_threads();
        let cfg = runtime.manifest.config(&spec.config)?.clone();
        if n_data == 0 {
            bail!("session needs a non-empty dataset");
        }

        if let Some(stages) = &cfg.stages {
            // proper hybrid validation replaces the old blanket rejection
            // of shard-style knobs on staged configs: [shard] still cannot
            // govern a pipeline model, but the error now points at the 2D
            // backend that composes both axes
            if spec.shard.is_some() {
                bail!(
                    "config '{}' has pipeline stages; the sharded backend replicates a \
                     stage-less model — use a [hybrid] section to compose pipeline stages \
                     with data-parallel replicas",
                    spec.config
                );
            }
            if spec.federated.is_some() {
                bail!(
                    "config '{}' has pipeline stages; the federated backend replicates a \
                     stage-less model per aggregation slot — user cohorts have no stage \
                     axis",
                    spec.config
                );
            }
            if let Some(hy) = spec.hybrid {
                // ---------- hybrid 2D backend (stages x replicas) ---------
                let mode = spec.clip.pipeline_mode().with_context(|| {
                    format!("config '{}' trains on the hybrid backend", spec.config)
                })?;
                if mode == PipelineMode::FlatSync {
                    bail!(
                        "the hybrid backend supports per-device clipping (or non-private); \
                         flat-sync is pipeline-only"
                    );
                }
                let n_stages = stages.stages.len();
                let minibatch = cfg.batch * spec.pipe.n_micro;
                // Per-replica E[B] keeps the pipeline headroom convention
                // (0.8x the static minibatch, overridable via
                // spec.expected_batch, dealt evenly across replicas); the
                // global E[B] is R x that — so an R = 1 hybrid derives the
                // identical schedule (and plan) as the pipeline backend.
                let per_replica = if spec.expected_batch > 0 {
                    spec.expected_batch / hy.replicas
                } else {
                    ((minibatch as f64) * 0.8).round().max(1.0) as usize
                };
                if per_replica == 0 {
                    bail!(
                        "expected_batch {} spreads below one example per replica",
                        spec.expected_batch
                    );
                }
                if per_replica > minibatch {
                    bail!(
                        "expected batch {} exceeds static capacity {} ({} replicas x \
                         minibatch {})",
                        per_replica * hy.replicas,
                        minibatch * hy.replicas,
                        hy.replicas,
                        minibatch
                    );
                }
                let expected = per_replica * hy.replicas;
                let steps = if spec.pipe.steps > 0 {
                    spec.pipe.steps as u64
                } else {
                    ((spec.epochs * n_data as f64) / expected as f64).ceil() as u64
                };
                if steps == 0 {
                    bail!("hybrid schedule is empty: raise epochs or set pipeline.steps");
                }
                let rate = (expected as f64 / n_data as f64).min(1.0);
                let grouping = match hy.grouping {
                    HybridGrouping::Auto | HybridGrouping::PerPiece => PieceGrouping::PerPiece,
                    HybridGrouping::PerStage => PieceGrouping::PerStage,
                };
                let stage_dims: Vec<u64> =
                    stages.stages.iter().map(|s| s.d_stage.max(1)).collect();
                // One accountant release per step at q = E[B]/n regardless
                // of (R, S): the replicas jointly hold ONE Poisson draw,
                // and each piece's local noise share sigma_g/sqrt(R) merges
                // (variances add) to the accountant's per-group std on the
                // stage's merged gradient. One example lives on one replica
                // and is clipped per stage piece, so the merged clipped-L2
                // bound is the quadrature sum over ALL R x S piece
                // thresholds (docs/SESSION_API.md, "Hybrid backend").
                // Per-piece quantile groups each see only their replica's
                // slice, E[B]/R; per-stage groups see the whole draw.
                let (k, group_dims, quantile_batch) = if !spec.clip.is_private() {
                    (1, vec![cfg.n_trainable().max(1)], expected as f64)
                } else {
                    match grouping {
                        PieceGrouping::PerPiece => (
                            hy.replicas * n_stages,
                            (0..hy.replicas)
                                .flat_map(|_| stage_dims.iter().copied())
                                .collect(),
                            expected as f64 / hy.replicas as f64,
                        ),
                        PieceGrouping::PerStage => (n_stages, stage_dims.clone(), expected as f64),
                    }
                };
                let core = DpCore::from_accountant(CoreCfg {
                    privacy: &spec.privacy,
                    clip: &spec.clip,
                    sample_rate: rate,
                    steps,
                    k,
                    group_dims,
                    expected_batch: quantile_batch,
                    seed: spec.seed,
                })?;
                let wiring = HybridWiring {
                    replicas: hy.replicas,
                    fanout: hy.fanout,
                    overlap: hy.overlap,
                    link_latency: hy.link_latency,
                    grouping,
                    mode,
                    n_micro: spec.pipe.n_micro,
                    expected_batch: expected,
                    rate,
                    total_steps: steps,
                    n_data,
                    optimizer: spec.optim.kind,
                    lr: spec.optim.lr,
                    seed: spec.seed,
                    sync_latency: spec.pipe.sync_latency,
                    clip_init: spec.clip.clip_init,
                    target_q: spec.clip.target_q,
                    quantile_eta: spec.clip.quantile_eta,
                    compress: spec.compress,
                };
                let engine = HybridEngine::with_core(runtime, &spec.config, wiring, &core)?;
                return Ok(Session {
                    backend: Backend::Hybrid(engine),
                    total_steps: steps,
                    steploop: StepLoop::with_threads(core, threads),
                    spec,
                });
            }
            // ---------------- pipeline backend (manifest has stages) -----
            let mode = spec
                .clip
                .pipeline_mode()
                .with_context(|| format!("config '{}' trains on the pipeline backend", spec.config))?;
            let n_stages = stages.stages.len();
            let minibatch = cfg.batch * spec.pipe.n_micro;
            // Expected live batch E[B] per step. Poisson draws target the
            // single-device headroom convention — E[B] = 0.8 x the static
            // minibatch (overridable via spec.expected_batch) so the
            // capacity rarely binds and truncation stays rare; round-robin
            // minibatches are always full.
            let expected = match spec.pipe.sampling {
                Sampling::Poisson => {
                    let e = if spec.expected_batch > 0 {
                        spec.expected_batch
                    } else {
                        ((minibatch as f64) * 0.8).round().max(1.0) as usize
                    };
                    if e > minibatch {
                        bail!(
                            "expected batch {} exceeds static pipeline minibatch {}",
                            e,
                            minibatch
                        );
                    }
                    e
                }
                Sampling::RoundRobin => minibatch,
            };
            let steps = if spec.pipe.steps > 0 {
                spec.pipe.steps as u64
            } else {
                ((spec.epochs * n_data as f64) / expected as f64).ceil() as u64
            };
            if steps == 0 {
                bail!("pipeline schedule is empty: raise epochs or set pipeline.steps");
            }
            // The sampling strategy decides how the accountant composes:
            // * Poisson (default): the session draws genuine Poisson
            //   batches from the shared core RNG, padded to the static
            //   minibatch with weight-0 slots the stage executables mask
            //   out — so subsampling amplification applies at rate
            //   q = E[B] / n over `steps` releases, exactly like the
            //   single-device backend.
            // * RoundRobin: the legacy deterministic cursor. No
            //   amplification can be claimed; account at q = 1 over the
            //   number of releases each example participates in — a
            //   conservative, valid Gaussian-composition bound kept as a
            //   reproducibility escape hatch.
            let (sample_rate, acct_steps) = match spec.pipe.sampling {
                Sampling::Poisson => ((expected as f64 / n_data as f64).min(1.0), steps),
                Sampling::RoundRobin => {
                    let participations = ((steps as f64 * minibatch as f64) / n_data as f64)
                        .ceil()
                        .max(1.0) as u64;
                    (1.0, participations)
                }
            };
            let k = if mode == PipelineMode::PerDevice { n_stages } else { 1 };
            let group_dims = if mode == PipelineMode::PerDevice {
                stages.stages.iter().map(|s| s.d_stage.max(1)).collect()
            } else {
                vec![cfg.n_trainable().max(1)]
            };
            let core = DpCore::from_accountant(CoreCfg {
                privacy: &spec.privacy,
                clip: &spec.clip,
                sample_rate,
                steps: acct_steps,
                k,
                group_dims,
                expected_batch: expected as f64,
                seed: spec.seed,
            })?;
            let opts = PipelineOpts {
                mode,
                n_micro: spec.pipe.n_micro,
                expected_batch: expected,
                clip: spec.clip.clip_init,
                lr: spec.optim.lr,
                optimizer: spec.optim.kind,
                seed: spec.seed,
                sync_latency: spec.pipe.sync_latency,
                adaptive: spec.clip.is_adaptive(),
                target_q: spec.clip.target_q,
                quantile_eta: spec.clip.quantile_eta,
            };
            let mut engine = PipelineEngine::with_core(runtime, &spec.config, opts, &core)?;
            // Poisson runs draw padded minibatches from this sampler (via
            // the shared core RNG); round-robin keeps the legacy cursor.
            engine.set_sampler(match spec.pipe.sampling {
                Sampling::Poisson => Some(PoissonSampler::new(n_data, sample_rate, minibatch)),
                Sampling::RoundRobin => None,
            });
            Ok(Session {
                backend: Backend::Pipeline(engine),
                total_steps: steps,
                steploop: StepLoop::with_threads(core, threads),
                spec,
            })
        } else if let Some(fed) = spec.federated.clone() {
            // ---------------- federated user-level backend ----------------
            // spec validation already guaranteed: private clip policy with
            // the fused flat entry, no [shard]/[hybrid], Poisson sampling,
            // no explicit pipeline.steps, grouping/clip agreement.
            if !(spec.epochs > 0.0) {
                bail!("federated runs need epochs > 0");
            }
            // Expected sampled cohort E[U]: explicit override or
            // q x population rounded to the nearest user. The rounding is
            // what makes the degenerate case exact — with
            // population == n_data and user_rate = E[B]/n this recovers
            // the sharded backend's E[B] bit-for-bit.
            let expected = if spec.expected_batch > 0 {
                spec.expected_batch
            } else {
                fed.expected_users()
            };
            if expected > fed.population {
                bail!(
                    "expected cohort {} exceeds federated.population {}",
                    expected,
                    fed.population
                );
            }
            // Aggregation slots follow the replica-holding schedule
            // convention (trainer::derive_schedule_n): each slot hosts the
            // single-device 0.8x-headroom share of the cohort, so the
            // degenerate federated run lands on the same slot count —
            // and the same (rate, steps) schedule — as the matching
            // sharded worker count.
            let per_slot = ((cfg.batch as f64) * 0.8).round().max(1.0) as usize;
            let slots = (expected + per_slot - 1) / per_slot;
            let rate = (expected as f64 / fed.population as f64).min(1.0);
            let total_steps =
                ((spec.epochs * fed.population as f64) / expected as f64).ceil() as u64;
            if total_steps == 0 {
                bail!("federated schedule is empty: raise epochs");
            }
            let grouping = match (fed.grouping, spec.clip.group_by) {
                (FederatedGrouping::Flat, _) | (FederatedGrouping::Auto, GroupBy::Flat) => {
                    CohortGrouping::Flat
                }
                (FederatedGrouping::PerUser, _)
                | (FederatedGrouping::Auto, GroupBy::PerDevice) => CohortGrouping::PerUser,
                (FederatedGrouping::Auto, GroupBy::PerLayer) => {
                    unreachable!("rejected by RunSpec::validate")
                }
            };
            // One accountant release per step at q = E[U]/population: the
            // slots jointly hold ONE Poisson draw over users, and each
            // slot's local noise share sigma_g/sqrt(slots) merges
            // (variances add) to the accountant's per-group std on the
            // aggregated update — at ANY realized cohort size. Per-user
            // slot groups each see E[U]/slots users per quantile release;
            // the flat group sees the whole cohort.
            let (k, group_dims, quantile_batch) = match grouping {
                CohortGrouping::Flat => (1, vec![cfg.n_trainable().max(1)], expected as f64),
                CohortGrouping::PerUser => (
                    slots,
                    vec![cfg.n_trainable().max(1); slots],
                    expected as f64 / slots as f64,
                ),
            };
            let mut core = DpCore::from_accountant(CoreCfg {
                privacy: &spec.privacy,
                clip: &spec.clip,
                sample_rate: rate,
                steps: total_steps.max(1),
                k,
                group_dims,
                expected_batch: quantile_batch,
                seed: spec.seed,
            })?;
            // same releases, same composition, same multipliers — only the
            // neighbouring relation changes: q is a USER sampling rate and
            // the clipped record is the whole per-user delta (see
            // PrivacyPlan::at_user_level)
            if let Some(p) = core.plan {
                core.plan = Some(p.at_user_level());
            }
            // the user partition maps the simulated population onto the
            // dataset actually handed to build(): user u contributes the
            // examples of block u
            let dspec = DataSpec { n_data, ..spec.data.clone() };
            let partition =
                dspec.user_partition(fed.population, fed.examples_per_user, fed.examples_dist);
            let wiring = FederatedWiring {
                slots,
                fanout: fed.fanout,
                overlap: fed.overlap,
                link_latency: fed.link_latency,
                grouping,
                rate,
                expected_users: expected,
                total_steps,
                population: fed.population,
                local_steps: fed.local_steps,
                partition,
                optimizer: spec.optim.kind,
                lr: spec.optim.lr,
                weight_decay: spec.optim.weight_decay,
                lr_decay: spec.optim.lr_decay,
            };
            let engine = FederatedEngine::with_core(runtime, &spec.config, wiring, &core)?;
            Ok(Session {
                backend: Backend::Federated(engine),
                total_steps,
                steploop: StepLoop::with_threads(core, threads),
                spec,
            })
        } else if spec.shard.is_some() || spec.hybrid.is_some() {
            // ---------------- sharded data-parallel backend ---------------
            // A stage-less config has no pipeline axis: a [hybrid] grid
            // degenerates to R pure data-parallel replicas, which IS the
            // sharded backend — route it there, so the degenerate case is
            // bit-identical to the same run spelled with [shard] (the S=1
            // backend-parity contract).
            let sh = match (spec.shard, &spec.hybrid) {
                (Some(sh), _) => sh,
                (None, Some(hy)) => ShardSpec {
                    workers: hy.replicas,
                    fanout: hy.fanout,
                    overlap: hy.overlap,
                    grouping: match hy.grouping {
                        HybridGrouping::Auto => ShardGrouping::Auto,
                        HybridGrouping::PerPiece => ShardGrouping::PerDevice,
                        HybridGrouping::PerStage => bail!(
                            "config '{}' has no pipeline stages, so hybrid grouping = \
                             per-stage has no stage axis — use [shard] with grouping = \
                             \"flat\" for one shared threshold",
                            spec.config
                        ),
                    },
                    link_latency: hy.link_latency,
                },
                (None, None) => unreachable!("branch guarded by shard/hybrid presence"),
            };
            if spec.hybrid.is_some() && spec.pipe.steps > 0 {
                bail!(
                    "config '{}' has no pipeline stages; a [hybrid] run here derives its \
                     step count from epochs — pipeline.steps needs a staged config",
                    spec.config
                );
            }
            if !(spec.epochs > 0.0) {
                bail!("sharded runs need epochs > 0");
            }
            // resolve the threshold-group topology; spec validation already
            // rejected explicit grouping/clip mismatches. Non-private runs
            // have no thresholds, so the topology degenerates to flat.
            let grouping = if !spec.clip.is_private() {
                WorkerGrouping::Flat
            } else {
                match (sh.grouping, spec.clip.group_by) {
                    (ShardGrouping::Flat, _) => WorkerGrouping::Flat,
                    (ShardGrouping::PerDevice, _) => WorkerGrouping::PerDevice,
                    (ShardGrouping::Auto, GroupBy::Flat) => WorkerGrouping::Flat,
                    (ShardGrouping::Auto, GroupBy::PerDevice) => WorkerGrouping::PerDevice,
                    (ShardGrouping::Auto, GroupBy::PerLayer) => WorkerGrouping::PerLayer,
                }
            };
            // One schedule formula for every replica-holding backend
            // (trainer::derive_schedule_n): per-worker E[B] keeps the
            // single-device 0.8x headroom default, the global E[B] is
            // N x that — so a 1-worker sharded run derives the identical
            // schedule (and plan) as the single-device backend.
            let (expected, rate, total_steps) = crate::coordinator::trainer::derive_schedule_n(
                &cfg,
                n_data,
                spec.epochs,
                spec.expected_batch,
                sh.workers,
            )?;
            let (k, group_dims) = match grouping {
                WorkerGrouping::Flat => (1, vec![cfg.n_trainable().max(1)]),
                WorkerGrouping::PerLayer => (cfg.groups.len().max(1), cfg.group_dims.clone()),
                WorkerGrouping::PerDevice => {
                    (sh.workers, vec![cfg.n_trainable().max(1); sh.workers])
                }
            };
            // One accountant release per step at q = E[B]/n regardless of
            // the worker count: the workers jointly hold ONE Poisson draw,
            // and their local noise shares merge to the core's per-group
            // stds exactly (see shard::engine). For per-device grouping
            // the sensitivity of the merged update is the per-device bound
            // summed in quadrature, sqrt(sum_k C_k^2), which is what the
            // equal-budget allocation calibrates against.
            // The quantile estimator normalizes each group's clip counts
            // by that group's expected example count: worker-owned groups
            // (per-device) each see only their slice, E[B]/N; flat and
            // per-layer groups see the whole draw.
            let quantile_batch = match grouping {
                WorkerGrouping::PerDevice => expected as f64 / sh.workers as f64,
                _ => expected as f64,
            };
            let core = DpCore::from_accountant(CoreCfg {
                privacy: &spec.privacy,
                clip: &spec.clip,
                sample_rate: rate,
                steps: total_steps.max(1),
                k,
                group_dims,
                expected_batch: quantile_batch,
                seed: spec.seed,
            })?;
            // Per-worker step executable: flat and per-layer groupings go
            // through the single-device Method mapping so flat_impl
            // (fused/ghost/naive) is honored — and adaptive x ghost is
            // rejected — exactly as on the single-device backend; the
            // worker-grouped per-device scheme clips each worker's full
            // gradient flat against its own C_w via the fused flat entry.
            let entry = if !spec.clip.is_private() {
                "nonprivate"
            } else {
                match grouping {
                    WorkerGrouping::PerDevice => "dp_flat",
                    _ => spec
                        .clip
                        .method()
                        .with_context(|| {
                            format!("config '{}' trains on the sharded backend", spec.config)
                        })?
                        .entry(),
                }
            };
            let wiring = ShardWiring {
                workers: sh.workers,
                fanout: sh.fanout,
                overlap: sh.overlap,
                link_latency: sh.link_latency,
                grouping,
                entry,
                private: spec.clip.is_private(),
                rate,
                expected_batch: expected,
                total_steps,
                n_data,
                optimizer: spec.optim.kind,
                lr: spec.optim.lr,
                weight_decay: spec.optim.weight_decay,
                lr_decay: spec.optim.lr_decay,
                compress: spec.compress,
                seed: spec.seed,
            };
            let engine = ShardEngine::with_core(runtime, &spec.config, wiring, &core)?;
            Ok(Session {
                backend: Backend::Sharded(engine),
                total_steps,
                steploop: StepLoop::with_threads(core, threads),
                spec,
            })
        } else {
            // ---------------- single-device backend -----------------------
            if !(spec.epochs > 0.0) {
                bail!("single-device runs need epochs > 0");
            }
            let method = spec
                .clip
                .method()
                .with_context(|| format!("config '{}' trains on the single-device backend", spec.config))?;
            let (expected, rate, steps) =
                derive_schedule(&cfg, n_data, spec.epochs, spec.expected_batch)?;
            let k = spec.clip.n_groups(cfg.groups.len(), 1);
            let group_dims = if k == cfg.groups.len() {
                cfg.group_dims.clone()
            } else {
                vec![cfg.n_trainable().max(1); k]
            };
            let core = DpCore::from_accountant(CoreCfg {
                privacy: &spec.privacy,
                clip: &spec.clip,
                sample_rate: rate,
                steps: steps.max(1),
                k,
                group_dims,
                expected_batch: expected as f64,
                seed: spec.seed,
            })?;
            let opts = TrainOpts {
                method,
                epsilon: spec.privacy.epsilon,
                delta: spec.privacy.delta,
                epochs: spec.epochs,
                expected_batch: spec.expected_batch,
                lr: spec.optim.lr,
                optimizer: spec.optim.kind,
                weight_decay: spec.optim.weight_decay,
                lr_decay: spec.optim.lr_decay,
                clip_init: spec.clip.clip_init,
                target_q: spec.clip.target_q,
                quantile_r: spec.privacy.quantile_r,
                quantile_eta: spec.clip.quantile_eta,
                allocation: spec.clip.allocation,
                rescale_global: spec.clip.rescale_global,
                seed: spec.seed,
            };
            let trainer = Trainer::with_core(runtime, &spec.config, n_data, opts, &core)?;
            let total_steps = trainer.total_steps;
            Ok(Session {
                backend: Backend::Single(trainer),
                total_steps,
                steploop: StepLoop::with_threads(core, threads),
                spec,
            })
        }
    }

    /// Build a session plus the (train, eval) datasets its [`DataSpec`]
    /// describes — the CLI path.
    #[allow(clippy::type_complexity)]
    pub fn build_with_data(self) -> Result<(Session<'r>, Box<dyn Dataset>, Box<dyn Dataset>)> {
        let cfg = self.runtime.manifest.config(&self.spec.config)?.clone();
        let (train, eval) = crate::data::build_for_config(&cfg, &self.spec.data)?;
        let session = self.build(train.len())?;
        Ok((session, train, eval))
    }
}

// ----------------------------------------------------------------- session

/// A configured training run: one backend, one shared [`StepLoop`]
/// (holding the one [`DpCore`]), one event stream.
pub struct Session<'r> {
    pub spec: RunSpec,
    pub backend: Backend<'r>,
    pub total_steps: u64,
    /// the DP-invariant step state machine every backend steps through
    pub steploop: StepLoop,
}

impl<'r> Session<'r> {
    pub fn builder(runtime: &'r Runtime, config: &str) -> SessionBuilder<'r> {
        SessionBuilder::new(runtime, config)
    }

    /// Shared DP state (plan, thresholds, noise, RNG).
    pub fn core(&self) -> &DpCore {
        &self.steploop.core
    }

    /// Mutable shared DP state (tests pin RNG stream positions here).
    pub fn core_mut(&mut self) -> &mut DpCore {
        &mut self.steploop.core
    }

    /// The accountant's plan (None only for non-private runs).
    pub fn plan(&self) -> Option<PrivacyPlan> {
        self.core().plan
    }

    /// Current per-group clipping thresholds.
    pub fn thresholds(&self) -> &[f64] {
        self.core().thresholds()
    }

    /// Group labels matching [`Session::thresholds`] (layer groups,
    /// `stage{i}` device labels, `worker{i}` replica labels, or
    /// `r{r}s{st}` hybrid piece labels).
    pub fn group_labels(&self) -> Vec<String> {
        match &self.backend {
            Backend::Single(t) => t.groups().to_vec(),
            Backend::Pipeline(_) => {
                (0..self.core().k()).map(|i| format!("stage{i}")).collect()
            }
            Backend::Sharded(e) => e.group_labels(),
            Backend::Hybrid(e) => e.group_labels(),
            Backend::Federated(e) => e.group_labels(),
        }
    }

    pub fn trainer(&self) -> Option<&Trainer<'r>> {
        match &self.backend {
            Backend::Single(t) => Some(t),
            _ => None,
        }
    }

    pub fn trainer_mut(&mut self) -> Option<&mut Trainer<'r>> {
        match &mut self.backend {
            Backend::Single(t) => Some(t),
            _ => None,
        }
    }

    pub fn engine(&self) -> Option<&PipelineEngine<'r>> {
        match &self.backend {
            Backend::Pipeline(e) => Some(e),
            _ => None,
        }
    }

    pub fn engine_mut(&mut self) -> Option<&mut PipelineEngine<'r>> {
        match &mut self.backend {
            Backend::Pipeline(e) => Some(e),
            _ => None,
        }
    }

    pub fn shard_engine(&self) -> Option<&ShardEngine<'r>> {
        match &self.backend {
            Backend::Sharded(e) => Some(e),
            _ => None,
        }
    }

    pub fn shard_engine_mut(&mut self) -> Option<&mut ShardEngine<'r>> {
        match &mut self.backend {
            Backend::Sharded(e) => Some(e),
            _ => None,
        }
    }

    pub fn hybrid_engine(&self) -> Option<&HybridEngine<'r>> {
        match &self.backend {
            Backend::Hybrid(e) => Some(e),
            _ => None,
        }
    }

    pub fn hybrid_engine_mut(&mut self) -> Option<&mut HybridEngine<'r>> {
        match &mut self.backend {
            Backend::Hybrid(e) => Some(e),
            _ => None,
        }
    }

    pub fn federated_engine(&self) -> Option<&FederatedEngine<'r>> {
        match &self.backend {
            Backend::Federated(e) => Some(e),
            _ => None,
        }
    }

    pub fn federated_engine_mut(&mut self) -> Option<&mut FederatedEngine<'r>> {
        match &mut self.backend {
            Backend::Federated(e) => Some(e),
            _ => None,
        }
    }

    /// Full-model parameters in manifest order (decoding / checkpoints).
    /// Sharded sessions return worker 0's replica — all replicas are kept
    /// bit-identical by the merged update.
    pub fn params(&self) -> Result<&[Tensor]> {
        match &self.backend {
            Backend::Single(t) => Ok(&t.params),
            Backend::Sharded(e) => Ok(e.params()),
            Backend::Federated(e) => Ok(e.params()),
            Backend::Pipeline(_) | Backend::Hybrid(_) => Err(anyhow!(
                "pipeline/hybrid sessions shard parameters per stage; use param_map()"
            )),
        }
    }

    /// Replace full-model parameters (pretrained checkpoints). Sharded
    /// sessions fan the set out to every replica.
    pub fn set_params(&mut self, params: Vec<Tensor>) -> Result<()> {
        match &mut self.backend {
            Backend::Single(t) => t.set_params(params),
            Backend::Sharded(e) => e.set_params_all(params),
            Backend::Federated(e) => e.set_params_all(params),
            Backend::Pipeline(_) | Backend::Hybrid(_) => Err(anyhow!(
                "pipeline/hybrid sessions load parameters by name; use load_param_map()"
            )),
        }
    }

    /// All parameters as a name -> tensor map, on any backend.
    pub fn param_map(&self) -> HashMap<String, Tensor> {
        match &self.backend {
            Backend::Single(t) => t
                .cfg
                .params
                .iter()
                .zip(&t.params)
                .map(|(p, v)| (p.name.clone(), v.clone()))
                .collect(),
            Backend::Pipeline(e) => e.dump_params(),
            Backend::Sharded(e) => e
                .cfg
                .params
                .iter()
                .zip(e.params())
                .map(|(p, v)| (p.name.clone(), v.clone()))
                .collect(),
            Backend::Federated(e) => e
                .cfg
                .params
                .iter()
                .zip(e.params())
                .map(|(p, v)| (p.name.clone(), v.clone()))
                .collect(),
            Backend::Hybrid(e) => e.dump_params(),
        }
    }

    /// Load parameters by name from a checkpoint map; names absent from
    /// the map keep their init values (LoRA adapters), on any backend.
    pub fn load_param_map(&mut self, map: &HashMap<String, Tensor>) -> Result<()> {
        match &mut self.backend {
            Backend::Single(t) => {
                let mut params = t.params.clone();
                for (i, p) in t.cfg.params.iter().enumerate() {
                    if let Some(v) = map.get(&p.name) {
                        if v.shape != p.shape {
                            return Err(anyhow!("shape mismatch for {}", p.name));
                        }
                        params[i] = v.clone();
                    }
                }
                t.set_params(params)
            }
            Backend::Pipeline(e) => e.load_params(map),
            Backend::Sharded(e) => e.load_param_map(map),
            Backend::Federated(e) => e.load_param_map(map),
            Backend::Hybrid(e) => e.load_params(map),
        }
    }

    /// Toggle per-step [B,K] norm collection (Figure 2/4 dumps;
    /// single-device backend only — the pipeline never materializes
    /// cross-device norm matrices).
    pub fn collect_norms(&mut self, on: bool) -> Result<()> {
        match &mut self.backend {
            Backend::Single(t) => {
                t.collect_norms = if on { Some(Vec::new()) } else { None };
                Ok(())
            }
            _ => Err(anyhow!("norm collection is single-device only")),
        }
    }

    pub fn collected_norms(&self) -> Option<&Vec<Vec<f32>>> {
        self.trainer().and_then(|t| t.collect_norms.as_ref())
    }

    /// One training step through the shared [`StepLoop`]: every backend
    /// runs the same DP phase sequence (draw, collect, noise shares,
    /// merge, /E[B] normalization, update, one quantile release) and
    /// emits the same [`StepEvent`].
    pub fn step(&mut self, data: &dyn Dataset) -> Result<StepEvent> {
        let Session { backend, steploop, .. } = self;
        match backend {
            Backend::Single(t) => steploop.step(t, data),
            Backend::Pipeline(e) => steploop.step(e, data),
            Backend::Sharded(e) => steploop.step(e, data),
            Backend::Hybrid(e) => steploop.step(e, data),
            Backend::Federated(e) => steploop.step(e, data),
        }
    }

    /// Override the step loop's OS-thread fan-out. Thread count is
    /// contractually bitwise-neutral (the PR 7 parity pins), so the
    /// serve daemon resolves it per session at submit time.
    pub fn set_threads(&mut self, n: usize) {
        self.steploop.threads = n.max(1);
    }

    /// Install a dispatched kernel vtable on the step loop and every
    /// backend hot loop (optimizers, reduction trees, compressors). The
    /// builder calls this with the spec's resolved mode; tests call it
    /// directly to pin explicit mode x ISA combinations.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.steploop.kernels = kernels;
        match &mut self.backend {
            Backend::Single(t) => t.set_kernels(kernels),
            Backend::Pipeline(e) => e.set_kernels(kernels),
            Backend::Sharded(e) => e.set_kernels(kernels),
            Backend::Hybrid(e) => e.set_kernels(kernels),
            Backend::Federated(e) => e.set_kernels(kernels),
        }
    }

    /// The kernel vtable the step loop currently runs with.
    pub fn kernels(&self) -> Kernels {
        self.steploop.kernels
    }

    /// Enable per-phase span tracing ([`crate::obs::trace`]). Tracing is
    /// contractually bitwise-neutral: spans record wall-clock only and
    /// never touch any RNG stream (the trace-on-vs-off parity pins).
    /// Idempotent — an already-attached tracer keeps its spans.
    pub fn enable_trace(&mut self) {
        if self.steploop.trace.is_none() {
            self.steploop.trace = Some(crate::obs::Tracer::new());
        }
    }

    /// The attached span recorder, if [`Session::enable_trace`] ran.
    pub fn tracer(&self) -> Option<&crate::obs::Tracer> {
        self.steploop.trace.as_ref()
    }

    /// Export the recorded spans as Chrome trace-event JSON (load in
    /// `chrome://tracing` or Perfetto). Errors if tracing was never
    /// enabled — an empty trace would silently hide the mistake.
    pub fn write_trace(&self, path: &std::path::Path) -> Result<()> {
        match &self.steploop.trace {
            Some(t) => t.write_chrome(path),
            None => bail!("tracing was not enabled on this session (--trace-out sets it up)"),
        }
    }

    /// Privacy spent so far: (eps, delta)-composition over the releases
    /// made in the first `steps_done` steps, at the plan's calibrated
    /// sigma. For Poisson-sampled backends `plan.steps == total_steps`
    /// and this composes exactly `steps_done` releases; for round-robin
    /// pipeline runs the plan composes per-example participations, so
    /// the spent fraction is scaled accordingly (rounded up — never
    /// under-reported). `None` for non-private runs.
    pub fn epsilon_spent(&self) -> Option<f64> {
        epsilon_spent_at(self.plan(), self.steploop.steps_done, self.total_steps)
    }

    /// A compact bitwise state certificate: step counter, an FNV-1a-64
    /// hash over the name-sorted parameter bit patterns, exact threshold
    /// bits, both RNG stream positions (incl. Marsaglia spare presence)
    /// and the privacy spent. Two sessions with equal digests took the
    /// same trajectory — the observable the kill-and-resume parity
    /// tests and the serve smoke script compare.
    pub fn digest(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use snapshot::{hex_f64, hex_u64};
        let map = self.param_map();
        let mut names: Vec<&String> = map.keys().collect();
        names.sort();
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        for name in names {
            eat(name.as_bytes());
            eat(&[0]);
            for x in &map[name].data {
                eat(&x.to_bits().to_le_bytes());
            }
        }
        let pos_json = |p: StreamPos| {
            let mut m = std::collections::BTreeMap::new();
            m.insert(
                "state".to_string(),
                Json::Arr(p.state.iter().map(|w| Json::Str(hex_u64(*w))).collect()),
            );
            m.insert("has_spare".to_string(), Json::Bool(p.has_spare));
            Json::Obj(m)
        };
        let (core_pos, draw_pos) = self.stream_pos();
        let mut m = std::collections::BTreeMap::new();
        m.insert("steps_done".to_string(), Json::Num(self.steploop.steps_done as f64));
        m.insert("params_fnv64".to_string(), Json::Str(hex_u64(h)));
        m.insert(
            "thresholds".to_string(),
            Json::Arr(self.thresholds().iter().map(|&t| Json::Str(hex_f64(t))).collect()),
        );
        m.insert("rng_core".to_string(), pos_json(core_pos));
        m.insert("rng_draw".to_string(), pos_json(draw_pos));
        m.insert(
            "eps_spent".to_string(),
            match self.epsilon_spent() {
                Some(e) => Json::Str(hex_f64(e)),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    /// The one-line progress label [`Session::run`] logs with.
    fn run_label(&self) -> &'static str {
        match &self.backend {
            Backend::Single(t) => t.opts.method.name(),
            Backend::Pipeline(e) => e.opts.mode.name(),
            Backend::Sharded(e) => match e.grouping() {
                WorkerGrouping::Flat => "sharded flat",
                WorkerGrouping::PerLayer => "sharded per-layer",
                WorkerGrouping::PerDevice => "sharded per-device",
            },
            Backend::Hybrid(e) => match e.grouping() {
                PieceGrouping::PerPiece => "hybrid per-piece",
                PieceGrouping::PerStage => "hybrid per-stage",
            },
            Backend::Federated(e) => match e.grouping() {
                CohortGrouping::Flat => "federated flat",
                CohortGrouping::PerUser => "federated per-user",
            },
        }
    }

    /// Train for the planned number of steps; returns the event stream.
    /// With `threads > 1` the loop runs the prefetching loader: step
    /// `t + 1`'s draw is dealt (on the dedicated draw stream) and its
    /// batches assembled in the background while step `t` collects —
    /// bitwise identical to the sequential loop, which deals the same
    /// draws in the same stream order, just later.
    pub fn run(&mut self, data: &dyn Dataset, log_every: u64) -> Result<Vec<StepEvent>> {
        let label = self.run_label();
        let total = self.total_steps;
        let Session { backend, steploop, .. } = self;
        match backend {
            Backend::Single(t) => run_loop(steploop, t, data, total, log_every, label),
            Backend::Pipeline(e) => run_loop(steploop, e, data, total, log_every, label),
            Backend::Sharded(e) => run_loop(steploop, e, data, total, log_every, label),
            Backend::Hybrid(e) => run_loop(steploop, e, data, total, log_every, label),
            Backend::Federated(e) => run_loop(steploop, e, data, total, log_every, label),
        }
    }

    /// Train to completion with periodic snapshots: step sequentially
    /// from wherever `steps_done` stands (freshly built or restored via
    /// [`snapshot::restore`]) and atomically publish a snapshot every
    /// `snapshot_every` steps plus one at completion. Steps run through
    /// [`Session::step`] — sequential stepping is bitwise identical to
    /// the threaded prefetch loop, and snapshotting at a step boundary
    /// is only sound without the prefetch lookahead (which deals draw
    /// `t + 1` before step `t` executes, so a mid-lookahead snapshot
    /// would double-consume the draw stream on resume).
    pub fn run_with_snapshots(
        &mut self,
        data: &dyn Dataset,
        log_every: u64,
        snapshot_every: u64,
        snapshot_dir: &std::path::Path,
    ) -> Result<Vec<StepEvent>> {
        std::fs::create_dir_all(snapshot_dir).with_context(|| {
            format!("creating snapshot directory {}", snapshot_dir.display())
        })?;
        let label = self.run_label();
        let total = self.total_steps;
        let mut events = Vec::new();
        while self.steploop.steps_done < total {
            let ev = self.step(data)?;
            if log_every > 0 && (ev.step % log_every == 0 || ev.step == total) {
                eprintln!("{}", ev.log_line(total, label));
            }
            let s = ev.step;
            events.push(ev);
            if (snapshot_every > 0 && s % snapshot_every == 0) || s == total {
                snapshot::write(self, &snapshot_dir.join(snapshot::file_name(s)))?;
            }
        }
        Ok(events)
    }

    /// Post-run RNG positions `(core stream, draw stream)` — the
    /// parity-pin observable: unlike sampling `uniform()`, a
    /// [`StreamPos`] also sees a buffered Marsaglia spare, so two runs
    /// that agree here consumed EXACTLY the same randomness.
    pub fn stream_pos(&self) -> (StreamPos, StreamPos) {
        (self.steploop.core.rng.stream_pos(), self.steploop.draw_rng.stream_pos())
    }

    /// (mean eval loss, accuracy). The pipeline backend has no accuracy
    /// head; it reports NaN accuracy.
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<(f64, f64)> {
        match &self.backend {
            Backend::Single(t) => t.evaluate(data),
            Backend::Pipeline(e) => Ok((e.evaluate(data)?, f64::NAN)),
            Backend::Sharded(e) => e.evaluate(data),
            Backend::Hybrid(e) => Ok((e.evaluate(data)?, f64::NAN)),
            Backend::Federated(e) => e.evaluate(data),
        }
    }

    /// Human-readable one-line description of the run's privacy wiring.
    /// Every backend prints the SAME plan-composition block — (eps,
    /// delta), q, the release count `plan.steps` and the sigma split —
    /// followed by its topology: stage count and thresholds for the
    /// pipeline, replica/worker count, reduction fanout, compression,
    /// grouping and thresholds for the sharded/hybrid backends.
    pub fn describe(&self) -> String {
        let be = self.backend.name();
        let base = match self.plan() {
            // (q, steps) are the plan's composition parameters — for a
            // round-robin pipeline, plan.steps is the per-example
            // participation count, not the run's total step count
            Some(p) => format!(
                "{be} | {} x {} | (eps={}, delta={}) {}-level q={:.4} over {} releases -> \
                 sigma={:.3} (grad {:.3}, quantile {:.2}, r={})",
                self.spec.clip.group_by.token(),
                self.spec.clip.mode.token(),
                p.epsilon,
                p.delta,
                p.unit.token(),
                p.q,
                p.steps,
                p.sigma_base,
                p.sigma_grad,
                p.sigma_quantile,
                p.quantile_fraction,
            ),
            None => format!(
                "{be} | {} x {} | non-private ({} steps)",
                self.spec.clip.group_by.token(),
                self.spec.clip.mode.token(),
                self.total_steps
            ),
        };
        let thresholds = self.thresholds();
        match &self.backend {
            Backend::Single(_) => base,
            Backend::Pipeline(e) => {
                let c: Vec<String> = thresholds.iter().map(|c| format!("{c:.4}")).collect();
                format!(
                    "{base} | stages={} n_micro={} thresholds=[{}]",
                    e.n_stages,
                    self.spec.pipe.n_micro,
                    c.join(", ")
                )
            }
            Backend::Sharded(e) => format!("{base} | {}", e.describe_topology(thresholds)),
            Backend::Hybrid(e) => format!("{base} | {}", e.describe_topology(thresholds)),
            Backend::Federated(e) => format!("{base} | {}", e.describe_topology(thresholds)),
        }
    }
}

/// Privacy spent after `steps_done` of `total_steps` planned steps:
/// (eps, delta)-composition over the releases made so far at the plan's
/// calibrated sigma — the body behind [`Session::epsilon_spent`], shared
/// with the step loop's per-event `eps_spent` field. Pure
/// post-processing of already-released values; the released count is
/// rounded up so privacy is never under-reported. `None` without a plan
/// (non-private runs).
pub(crate) fn epsilon_spent_at(
    plan: Option<PrivacyPlan>,
    steps_done: u64,
    total_steps: u64,
) -> Option<f64> {
    let p = plan?;
    let done = steps_done.min(total_steps);
    let released = if total_steps == 0 || done == 0 {
        0
    } else {
        let num = p.steps as u128 * done as u128;
        let den = total_steps as u128;
        ((num + den - 1) / den) as u64
    };
    if released == 0 {
        return Some(0.0);
    }
    Some(crate::coordinator::accountant::epsilon_for(p.q, p.sigma_base, released, p.delta).0)
}

/// The monomorphized training loop behind [`Session::run`]. Sequential
/// sessions step straight through; threaded sessions (`threads > 1`)
/// deal one draw ahead on the dedicated draw stream and feed the next
/// step's batch index lists to the background prefetching loader, so
/// batch assembly overlaps the current step's collect phase. Both paths
/// deal exactly `total` draws in the same stream order and read bitwise
/// identical batches (a prefetch miss assembles inline), so they emit
/// identical events.
fn run_loop<B: steploop::BackendStep>(
    lp: &mut StepLoop,
    backend: &mut B,
    data: &dyn Dataset,
    total: u64,
    log_every: u64,
    label: &str,
) -> Result<Vec<StepEvent>> {
    let emit = |ev: &StepEvent, s: u64| {
        if log_every > 0 && (s % log_every == 0 || s + 1 == total) {
            eprintln!("{}", ev.log_line(total, label));
        }
    };
    if lp.threads <= 1 {
        let mut events = Vec::with_capacity(total as usize);
        for s in 0..total {
            let ev = lp.step(backend, data)?;
            emit(&ev, s);
            events.push(ev);
        }
        return Ok(events);
    }
    prefetch::with_prefetch(data, |pf, tx| {
        let n = data.len();
        let mut events = Vec::with_capacity(total as usize);
        let mut pending = (total > 0).then(|| {
            let first = lp.deal(backend, n);
            let _ = tx.send(backend.prefetch_lists(&first));
            first
        });
        for s in 0..total {
            let slices = pending.take().expect("a dealt draw is always pending");
            if s + 1 < total {
                // lookahead: deal step s+1 NOW (draw stream only) and hand
                // its batches to the loader while step s collects below
                let ahead = lp.deal(backend, n);
                let _ = tx.send(backend.prefetch_lists(&ahead));
                pending = Some(ahead);
            }
            let ev = lp.step_dealt(backend, pf, &slices)?;
            emit(&ev, s);
            events.push(ev);
        }
        Ok(events)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::PhaseSecs;

    fn event() -> StepEvent {
        StepEvent {
            step: 3,
            loss: 1.5,
            batch_size: 8,
            clip_frac: vec![0.25],
            mean_norms: vec![0.5],
            host_secs: 1.0,
            sim_secs: 2.0,
            sim_overlap_secs: 3.0,
            sim_barrier_secs: 4.0,
            collect_wall_secs: 5.0,
            collect_busy_secs: 6.0,
            threads: 2,
            syncs: 1,
            calls: 4,
            truncated: 7,
            unit: "example",
            phase: PhaseSecs { deal: 0.125, collect: 5.0, ..Default::default() },
            eps_spent: Some(1.25),
        }
    }

    #[test]
    fn step_event_json_carries_every_field() {
        // the pin: this sorted key set IS the ndjson schema the daemon
        // streams; adding a StepEvent field without serializing it (the
        // old sim_overlap/sim_barrier/collect_wall/collect_busy bug)
        // breaks this assertion
        let j = event().to_json();
        let keys: Vec<&str> = j.obj().unwrap().keys().map(|s| s.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "batch_size",
                "calls",
                "clip_frac",
                "collect_busy_secs",
                "collect_wall_secs",
                "eps_spent",
                "host_secs",
                "loss",
                "mean_norms",
                "phase_secs",
                "sim_barrier_secs",
                "sim_overlap_secs",
                "sim_secs",
                "step",
                "syncs",
                "threads",
                "truncated",
                "unit",
            ]
        );
        // the once-dropped fields round-trip with their values
        assert_eq!(j.get("sim_overlap_secs").unwrap().f64().unwrap(), 3.0);
        assert_eq!(j.get("sim_barrier_secs").unwrap().f64().unwrap(), 4.0);
        assert_eq!(j.get("collect_wall_secs").unwrap().f64().unwrap(), 5.0);
        assert_eq!(j.get("collect_busy_secs").unwrap().f64().unwrap(), 6.0);
        assert_eq!(j.get("eps_spent").unwrap().f64().unwrap(), 1.25);
        let ph = j.get("phase_secs").unwrap();
        let mut names: Vec<&'static str> = PhaseSecs::NAMES.to_vec();
        names.sort_unstable();
        let got: Vec<&str> = ph.obj().unwrap().keys().map(|s| s.as_str()).collect();
        assert_eq!(got, names);
        assert_eq!(ph.get("deal").unwrap().f64().unwrap(), 0.125);
    }

    #[test]
    fn step_event_json_null_eps_for_nonprivate() {
        let ev = StepEvent { eps_spent: None, ..event() };
        let j = ev.to_json();
        assert_eq!(j.get("eps_spent").unwrap(), &crate::util::json::Json::Null);
        // and the key is still present (the schema does not shrink)
        assert!(j.obj().unwrap().contains_key("eps_spent"));
    }

    #[test]
    fn epsilon_spent_at_handles_edges() {
        assert_eq!(epsilon_spent_at(None, 5, 10), None, "non-private: no plan");
        let plan = PrivacyPlan {
            epsilon: 3.0,
            delta: 1e-5,
            q: 0.1,
            steps: 100,
            unit: crate::coordinator::accountant::PrivacyUnit::Example,
            sigma_base: 2.0,
            sigma_grad: 2.0,
            sigma_quantile: 0.0,
            quantile_fraction: 0.0,
        };
        assert_eq!(epsilon_spent_at(Some(plan), 0, 100), Some(0.0));
        assert_eq!(epsilon_spent_at(Some(plan), 0, 0), Some(0.0));
        let half = epsilon_spent_at(Some(plan), 50, 100).unwrap();
        let full = epsilon_spent_at(Some(plan), 100, 100).unwrap();
        assert!(half > 0.0 && half < full, "spending is monotone: {half} vs {full}");
        // overshoot clamps to the planned total
        assert_eq!(epsilon_spent_at(Some(plan), 150, 100), Some(full));
    }
}
