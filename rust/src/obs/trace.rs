//! Lightweight phase-span recorder with Chrome trace-event export.
//!
//! The `StepLoop` drives a [`Tracer`] (when enabled) with one span per
//! DP phase and one span per collect unit, recording monotonic start
//! offsets and durations into a bounded ring buffer. Everything here is
//! plain bookkeeping on the host thread — no RNG, no locks, no I/O
//! until export — so a traced run is bitwise identical to an untraced
//! one. When tracing is disabled the `StepLoop` holds `None` and the
//! per-phase cost is a branch on an `Option`.
//!
//! Export follows the Chrome trace-event JSON format (the `ph:"X"`
//! complete-event form plus `ph:"M"` thread-name metadata), loadable in
//! `chrome://tracing` / Perfetto: one track (`tid`) for the step loop
//! and one per observed collect worker thread, so the threaded collect
//! fan-out shows up as a flamegraph.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::Json;

/// Default ring capacity: at 8 spans/step this holds ~8k steps, far
/// beyond any smoke/bench run, while bounding memory for long serves.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Track id of the main step loop; worker tracks are assigned 1..= in
/// first-seen order.
pub const MAIN_TRACK: u64 = 0;

/// One completed phase (or per-unit collect task) interval.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Phase name from the fixed taxonomy (`deal`, `collect`, ...).
    pub name: &'static str,
    /// Start offset from the tracer's epoch, microseconds.
    pub start_us: u64,
    /// Duration, microseconds.
    pub dur_us: u64,
    /// Step the span belongs to (1-based, matching `StepEvent::step`).
    pub step: u64,
    /// Track: [`MAIN_TRACK`] for the step loop, worker ids otherwise.
    pub track: u64,
    /// Collect spans carry the unit index they processed.
    pub unit: Option<usize>,
}

/// Bounded span ring buffer anchored at a monotonic epoch.
pub struct Tracer {
    epoch: Instant,
    cap: usize,
    buf: Vec<Span>,
    /// Next overwrite position once the ring is full; also the oldest
    /// retained span, so chronological iteration starts here.
    head: usize,
    dropped: u64,
    /// Hashed worker-thread id -> small stable track id (1-based).
    tracks: BTreeMap<u64, u64>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(cap: usize) -> Self {
        Tracer {
            epoch: Instant::now(),
            cap: cap.max(1),
            buf: Vec::new(),
            head: 0,
            dropped: 0,
            tracks: BTreeMap::new(),
        }
    }

    /// The monotonic zero point all span offsets are measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Microseconds from the epoch to `t` (0 for pre-epoch instants).
    pub fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Map a hashed worker-thread id to a small stable track id
    /// (assigned 1, 2, ... in first-seen order; 0 is the step loop).
    pub fn track_for(&mut self, thread_hash: u64) -> u64 {
        let next = self.tracks.len() as u64 + 1;
        *self.tracks.entry(thread_hash).or_insert(next)
    }

    /// Append a span, overwriting the oldest once the ring is full.
    pub fn push(&mut self, span: Span) {
        if self.buf.len() < self.cap {
            self.buf.push(span);
        } else {
            self.buf[self.head] = span;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Convenience: record a main-track span from two instants.
    pub fn record(&mut self, name: &'static str, step: u64, start: Instant, end: Instant) {
        let start_us = self.us_since_epoch(start);
        let dur_us = end.saturating_duration_since(start).as_micros() as u64;
        self.push(Span { name, start_us, dur_us, step, track: MAIN_TRACK, unit: None });
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans evicted so far (ring overwrites).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained spans, oldest first.
    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    /// Render the retained spans as a Chrome trace-event JSON document.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        // thread_name metadata: one entry per track so the viewer shows
        // readable lane names instead of bare tids
        let mut track_ids: Vec<u64> = vec![MAIN_TRACK];
        track_ids.extend(self.tracks.values().copied());
        track_ids.sort_unstable();
        track_ids.dedup();
        for tid in track_ids {
            let label = if tid == MAIN_TRACK {
                "step loop".to_string()
            } else {
                format!("collect worker {tid}")
            };
            let mut args = BTreeMap::new();
            args.insert("name".to_string(), Json::Str(label));
            let mut m = BTreeMap::new();
            m.insert("ph".to_string(), Json::Str("M".to_string()));
            m.insert("name".to_string(), Json::Str("thread_name".to_string()));
            m.insert("pid".to_string(), Json::Num(0.0));
            m.insert("tid".to_string(), Json::Num(tid as f64));
            m.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(m));
        }
        for s in self.spans() {
            let name = match s.unit {
                Some(u) => format!("{}/unit{}", s.name, u),
                None => s.name.to_string(),
            };
            let mut args = BTreeMap::new();
            args.insert("step".to_string(), Json::Num(s.step as f64));
            if let Some(u) = s.unit {
                args.insert("unit".to_string(), Json::Num(u as f64));
            }
            let mut m = BTreeMap::new();
            m.insert("ph".to_string(), Json::Str("X".to_string()));
            m.insert("name".to_string(), Json::Str(name));
            m.insert("cat".to_string(), Json::Str("dp-phase".to_string()));
            m.insert("ts".to_string(), Json::Num(s.start_us as f64));
            m.insert("dur".to_string(), Json::Num(s.dur_us as f64));
            m.insert("pid".to_string(), Json::Num(0.0));
            m.insert("tid".to_string(), Json::Num(s.track as f64));
            m.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(m));
        }
        let mut doc = BTreeMap::new();
        doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        doc.insert("traceEvents".to_string(), Json::Arr(events));
        Json::Obj(doc)
    }

    /// Write the Chrome trace document atomically to `path`.
    pub fn write_chrome(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        crate::util::fsio::write_atomic(path, self.to_chrome_json().render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &'static str, step: u64, start_us: u64) -> Span {
        Span { name, start_us, dur_us: 5, step, track: MAIN_TRACK, unit: None }
    }

    #[test]
    fn ring_buffer_wraps_and_keeps_newest() {
        let mut t = Tracer::with_capacity(4);
        for i in 0..10u64 {
            t.push(span("deal", i, i));
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        let steps: Vec<u64> = t.spans().map(|s| s.step).collect();
        assert_eq!(steps, vec![6, 7, 8, 9], "oldest-first iteration after wraparound");
    }

    #[test]
    fn ring_buffer_below_capacity_keeps_all_in_order() {
        let mut t = Tracer::with_capacity(8);
        for i in 0..3u64 {
            t.push(span("noise", i, i));
        }
        assert_eq!(t.dropped(), 0);
        let steps: Vec<u64> = t.spans().map(|s| s.step).collect();
        assert_eq!(steps, vec![0, 1, 2]);
    }

    #[test]
    fn track_ids_are_stable_and_first_seen_ordered() {
        let mut t = Tracer::new();
        let a = t.track_for(0xdead);
        let b = t.track_for(0xbeef);
        assert_eq!(a, 1);
        assert_eq!(b, 2);
        assert_eq!(t.track_for(0xdead), 1, "same thread hash keeps its track");
    }

    #[test]
    fn chrome_export_shape() {
        let mut t = Tracer::with_capacity(16);
        t.push(span("deal", 1, 10));
        let w = t.track_for(42);
        t.push(Span { name: "collect", start_us: 20, dur_us: 7, step: 1, track: w, unit: Some(3) });
        let doc = t.to_chrome_json();
        let events = doc.get("traceEvents").unwrap().arr().unwrap();
        // 2 thread_name metadata entries (main + worker) + 2 spans
        assert_eq!(events.len(), 4);
        let metas: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").unwrap().str().unwrap() == "M").collect();
        assert_eq!(metas.len(), 2);
        for m in &metas {
            assert_eq!(m.get("name").unwrap().str().unwrap(), "thread_name");
        }
        let xs: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").unwrap().str().unwrap() == "X").collect();
        assert_eq!(xs.len(), 2);
        let collect = xs
            .iter()
            .find(|e| e.get("name").unwrap().str().unwrap() == "collect/unit3")
            .expect("per-unit collect span present");
        assert_eq!(collect.get("ts").unwrap().u64().unwrap(), 20);
        assert_eq!(collect.get("dur").unwrap().u64().unwrap(), 7);
        assert_eq!(collect.get("tid").unwrap().u64().unwrap(), w);
        assert_eq!(collect.get("args").unwrap().get("unit").unwrap().u64().unwrap(), 3);
        // the document round-trips through the in-tree parser
        let back = Json::parse(&doc.render()).unwrap();
        assert_eq!(back, doc);
    }
}
