//! Metrics registry: counters, gauges, and log-bucketed latency
//! histograms with a hand-rolled Prometheus text exposition (this
//! environment has no prometheus crate, mirroring `util::json` /
//! `serve::http`).
//!
//! Histograms use power-of-two bucket bounds starting at 1µs, which
//! covers every latency this repo measures (sub-µs snapshot syscalls up
//! to two-minute steps) with exact, platform-independent bucketing:
//! `le = 1e-6 * 2^i`. Quantiles (p50/p95/p99) are bucket upper bounds —
//! conservative by at most one octave, and deterministic.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

/// Number of finite histogram buckets: `1e-6 * 2^27` ≈ 134 s tops out
/// well above any single phase or snapshot this repo times.
pub const HIST_BUCKETS: usize = 28;

/// Upper bound (`le`) of finite bucket `i`.
pub fn bucket_bound(i: usize) -> f64 {
    1e-6 * (1u64 << i) as f64
}

/// Fixed-bound log₂ histogram. The last slot counts the +Inf overflow.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: [u64; HIST_BUCKETS + 1],
    sum: f64,
    count: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: [0; HIST_BUCKETS + 1], sum: 0.0, count: 0 }
    }

    /// The unique bucket a sample lands in (NaN maps to overflow —
    /// every comparison with NaN is false, so the scan falls through).
    pub fn bucket_index(v: f64) -> usize {
        for i in 0..HIST_BUCKETS {
            if v <= bucket_bound(i) {
                return i;
            }
        }
        HIST_BUCKETS
    }

    pub fn observe(&mut self, v: f64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Per-bucket (non-cumulative) counts, overflow last.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Quantile as the upper bound of the bucket where the cumulative
    /// count crosses `q * count`. Empty histogram -> 0.0; a crossing in
    /// the overflow bucket -> +Inf (honest: the sample exceeded every
    /// finite bound).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for i in 0..HIST_BUCKETS {
            cum += self.counts[i];
            if cum >= target {
                return bucket_bound(i);
            }
        }
        f64::INFINITY
    }

    /// Merge another histogram into this one. Bucket counts and totals
    /// add exactly, so `merge(a, b)` has identical bucket counts and
    /// quantiles to a histogram fed the concatenated sample stream.
    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..self.counts.len() {
            self.counts[i] += other.counts[i];
        }
        self.sum += other.sum;
        self.count += other.count;
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

enum Series {
    Val(f64),
    Hist(Histogram),
}

struct Family {
    kind: Kind,
    help: &'static str,
    /// Keyed by the rendered label pairs (e.g. `session="smoke"`; empty
    /// string for an unlabeled series) — BTreeMap keeps the exposition
    /// deterministically ordered.
    series: BTreeMap<String, Series>,
}

/// Thread-safe named-metric registry. One lives on the serve daemon
/// (shared by every session runner and the HTTP handler); standalone
/// runs can hold one locally.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn with_series<R>(
        &self,
        name: &str,
        kind: Kind,
        help: &'static str,
        labels: &str,
        f: impl FnOnce(&mut Series) -> R,
    ) -> Option<R> {
        let mut fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help,
            series: BTreeMap::new(),
        });
        if fam.kind != kind {
            debug_assert!(false, "metric '{name}' re-registered as a different kind");
            return None;
        }
        let s = fam.series.entry(labels.to_string()).or_insert_with(|| match kind {
            Kind::Histogram => Series::Hist(Histogram::new()),
            _ => Series::Val(0.0),
        });
        Some(f(s))
    }

    /// Add `v` to a (monotonic) counter series, creating it at 0.
    pub fn counter_add(&self, name: &str, help: &'static str, labels: &str, v: f64) {
        self.with_series(name, Kind::Counter, help, labels, |s| {
            if let Series::Val(x) = s {
                *x += v;
            }
        });
    }

    /// Set a gauge series to `v`.
    pub fn gauge_set(&self, name: &str, help: &'static str, labels: &str, v: f64) {
        self.with_series(name, Kind::Gauge, help, labels, |s| {
            if let Series::Val(x) = s {
                *x = v;
            }
        });
    }

    /// Record `v` into a histogram series.
    pub fn observe(&self, name: &str, help: &'static str, labels: &str, v: f64) {
        self.with_series(name, Kind::Histogram, help, labels, |s| {
            if let Series::Hist(h) = s {
                h.observe(v);
            }
        });
    }

    /// Current value of a counter/gauge series (tests, /phases).
    pub fn value(&self, name: &str, labels: &str) -> Option<f64> {
        let fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        match fams.get(name)?.series.get(labels)? {
            Series::Val(v) => Some(*v),
            Series::Hist(_) => None,
        }
    }

    /// Quantile of a histogram series.
    pub fn hist_quantile(&self, name: &str, labels: &str, q: f64) -> Option<f64> {
        let fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        match fams.get(name)?.series.get(labels)? {
            Series::Hist(h) => Some(h.quantile(q)),
            Series::Val(_) => None,
        }
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (one HELP/TYPE pair per family, series sorted by label set).
    pub fn render(&self) -> String {
        let fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            writeln!(out, "# HELP {name} {}", fam.help).unwrap();
            writeln!(out, "# TYPE {name} {}", fam.kind.as_str()).unwrap();
            for (labels, series) in fam.series.iter() {
                match series {
                    Series::Val(v) => {
                        if labels.is_empty() {
                            writeln!(out, "{name} {v}").unwrap();
                        } else {
                            writeln!(out, "{name}{{{labels}}} {v}").unwrap();
                        }
                    }
                    Series::Hist(h) => {
                        let mut cum = 0u64;
                        for (i, c) in h.bucket_counts().iter().enumerate() {
                            cum += c;
                            let le = if i < HIST_BUCKETS {
                                format!("{}", bucket_bound(i))
                            } else {
                                "+Inf".to_string()
                            };
                            let sep = if labels.is_empty() { "" } else { "," };
                            writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}")
                                .unwrap();
                        }
                        let (so, sc) = if labels.is_empty() {
                            (format!("{name}_sum"), format!("{name}_count"))
                        } else {
                            (format!("{name}_sum{{{labels}}}"), format!("{name}_count{{{labels}}}"))
                        };
                        writeln!(out, "{so} {}", h.sum()).unwrap();
                        writeln!(out, "{sc} {}", h.count()).unwrap();
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_sample_lands_in_exactly_one_bucket() {
        // dense sweep across the full range plus edge values; the index
        // function is total, so "exactly one" means counts sum to count
        let mut h = Histogram::new();
        let mut samples = vec![0.0, -1.0, 1e-9, 134.0, 1e6, f64::INFINITY];
        for i in 0..HIST_BUCKETS {
            let b = bucket_bound(i);
            samples.push(b); // boundary: lands in bucket i (le is inclusive)
            samples.push(b * 1.0000001); // just above: next bucket
        }
        for &v in &samples {
            let i = Histogram::bucket_index(v);
            assert!(i <= HIST_BUCKETS);
            if i < HIST_BUCKETS {
                assert!(v <= bucket_bound(i), "sample {v} above its bucket bound");
            }
            if i > 0 && v.is_finite() {
                assert!(v > bucket_bound(i - 1), "sample {v} belongs in an earlier bucket");
            }
            h.observe(v);
        }
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), h.count());
        assert_eq!(h.count(), samples.len() as u64);
    }

    #[test]
    fn quantiles_are_monotone() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.observe(i as f64 * 1e-5);
        }
        let (p50, p95, p99) = (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        assert!(p50 > 0.0);
        assert_eq!(Histogram::new().quantile(0.5), 0.0, "empty histogram quantile is 0");
    }

    #[test]
    fn merge_equals_concatenated_stream() {
        // dyadic sample values so the running sums are exact in f64 and
        // the equality below can be bitwise
        let xs: Vec<f64> = (0..500).map(|i| (i % 37) as f64 / 1024.0).collect();
        let ys: Vec<f64> = (0..300).map(|i| (i % 53) as f64 / 256.0).collect();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for &v in &xs {
            a.observe(v);
            whole.observe(v);
        }
        for &v in &ys {
            b.observe(v);
            whole.observe(v);
        }
        a.merge(&b);
        assert_eq!(a, whole);
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(a.quantile(q), whole.quantile(q));
        }
    }

    #[test]
    fn registry_renders_prometheus_exposition() {
        let r = Registry::new();
        r.counter_add("gwclip_steps_total", "Steps completed.", "session=\"a\"", 3.0);
        r.counter_add("gwclip_steps_total", "Steps completed.", "session=\"a\"", 2.0);
        r.gauge_set("gwclip_eps_spent", "Privacy spent.", "session=\"a\"", 1.25);
        r.observe("gwclip_step_seconds", "Step latency.", "", 0.5e-6);
        r.observe("gwclip_step_seconds", "Step latency.", "", 3e-6);
        let text = r.render();
        assert!(text.contains("# HELP gwclip_steps_total Steps completed.\n"));
        assert!(text.contains("# TYPE gwclip_steps_total counter\n"));
        assert!(text.contains("gwclip_steps_total{session=\"a\"} 5\n"));
        assert!(text.contains("gwclip_eps_spent{session=\"a\"} 1.25\n"));
        assert!(text.contains("# TYPE gwclip_step_seconds histogram\n"));
        // cumulative buckets: 1 sample <= 1e-6, both <= 4e-6
        assert!(text.contains("gwclip_step_seconds_bucket{le=\"0.000001\"} 1\n"));
        assert!(text.contains("gwclip_step_seconds_bucket{le=\"0.000004\"} 2\n"));
        assert!(text.contains("gwclip_step_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("gwclip_step_seconds_count 2\n"));
        // exactly one HELP line per family
        for fam in ["gwclip_steps_total", "gwclip_eps_spent", "gwclip_step_seconds"] {
            let n = text.matches(&format!("# HELP {fam} ")).count();
            assert_eq!(n, 1, "duplicate HELP for {fam}");
        }
        assert_eq!(r.value("gwclip_steps_total", "session=\"a\""), Some(5.0));
        assert_eq!(r.hist_quantile("gwclip_step_seconds", "", 0.5), Some(1e-6));
    }

    #[test]
    fn counters_and_gauges_track_independent_label_sets() {
        let r = Registry::new();
        r.counter_add("c", "h", "session=\"x\"", 1.0);
        r.counter_add("c", "h", "session=\"y\"", 7.0);
        assert_eq!(r.value("c", "session=\"x\""), Some(1.0));
        assert_eq!(r.value("c", "session=\"y\""), Some(7.0));
        assert_eq!(r.value("c", "session=\"z\""), None);
        assert_eq!(r.value("nope", ""), None);
    }
}
