//! Crate-wide observability: per-phase span tracing and a metrics
//! registry, surfaced through the serve daemon's `/metrics` endpoint
//! and `gwclip run --trace-out` Chrome-trace export.
//!
//! The hard contract of this module is **zero RNG impact**: nothing in
//! here draws from, splits, or reorders any random stream. Tracing and
//! metrics observe wall-clock time and already-released values only, so
//! every bitwise parity pin in the test suite holds with tracing on or
//! off. Timing is measured with `std::time::Instant` (monotonic) and
//! never feeds back into the training computation.

pub mod metrics;
pub mod trace;

pub use metrics::{Histogram, Registry};
pub use trace::{Span, Tracer};

/// Wall-clock seconds spent in each DP phase of one training step.
///
/// The phase taxonomy mirrors the `StepLoop` structure one-to-one:
/// `deal` (draw + host->device staging), `collect` (per-unit gradient +
/// norm work, possibly fanned across OS threads), `noise` (Gaussian
/// draw + add), `merge` (backend cross-unit reduction), `normalize`
/// (clip-scale application), `apply` (optimizer update), `quantile`
/// (adaptive threshold update). Phases a backend does not run are 0.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseSecs {
    pub deal: f64,
    pub collect: f64,
    pub noise: f64,
    pub merge: f64,
    pub normalize: f64,
    pub apply: f64,
    pub quantile: f64,
}

impl PhaseSecs {
    /// Phase names, in step-loop execution order.
    pub const NAMES: [&'static str; 7] =
        ["deal", "collect", "noise", "merge", "normalize", "apply", "quantile"];

    /// (name, seconds) pairs in execution order.
    pub fn iter(&self) -> [(&'static str, f64); 7] {
        [
            ("deal", self.deal),
            ("collect", self.collect),
            ("noise", self.noise),
            ("merge", self.merge),
            ("normalize", self.normalize),
            ("apply", self.apply),
            ("quantile", self.quantile),
        ]
    }

    /// Seconds attributed to a phase by name; `None` for unknown names.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.iter().iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    /// Sum over all phases (the instrumented fraction of `host_secs`).
    pub fn total(&self) -> f64 {
        self.iter().iter().map(|(_, v)| v).sum()
    }

    /// Accumulate another step's phase times into this one.
    pub fn add(&mut self, other: &PhaseSecs) {
        self.deal += other.deal;
        self.collect += other.collect;
        self.noise += other.noise;
        self.merge += other.merge;
        self.normalize += other.normalize;
        self.apply += other.apply;
        self.quantile += other.quantile;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_secs_iter_matches_names_and_total() {
        let mut p = PhaseSecs::default();
        p.deal = 1.0;
        p.collect = 2.0;
        p.noise = 4.0;
        p.merge = 8.0;
        p.normalize = 16.0;
        p.apply = 32.0;
        p.quantile = 64.0;
        let names: Vec<&str> = p.iter().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, PhaseSecs::NAMES);
        assert_eq!(p.total(), 127.0);
        assert_eq!(p.get("merge"), Some(8.0));
        assert_eq!(p.get("bogus"), None);
        let mut q = PhaseSecs::default();
        q.add(&p);
        q.add(&p);
        assert_eq!(q.total(), 254.0);
    }
}
