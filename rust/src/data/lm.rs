//! Synthetic language-modeling corpora.
//!
//! * [`MarkovCorpus`] — order-1 Markov chain with peaked transitions: the
//!   model can reduce loss far below log(V) by learning the transition
//!   table. Used for pretraining analogs and the e2e driver.
//! * [`TableToTextCorpus`] — E2E/DART analog: prefix encodes key/value
//!   fields, suffix is a deterministic templated "sentence" over the
//!   values. Fine-tuning learns the template; BLEU on the suffix is a
//!   meaningful metric (Table 5).
//! * [`DialogSumCorpus`] — SAMSum analog: a noisy "dialog" region followed
//!   by a separator and a "summary" that repeats the dialog's salient
//!   (rare) tokens in order (Table 6).

use crate::coordinator::noise::Rng;
use crate::runtime::IntTensor;

use super::{Dataset, ModelBatch};

/// Sequences drawn from a seeded order-1 Markov chain.
pub struct MarkovCorpus {
    pub seqs: Vec<Vec<i32>>, // each of length seq+1
    pub seq: usize,
    pub vocab: usize,
}

impl MarkovCorpus {
    /// The transition table (the "language") comes from a fixed task seed
    /// so every instance — train split, eval split — is the same language;
    /// `seed` only controls which sequences are drawn.
    pub fn new(n: usize, seq: usize, vocab: usize, branching: usize, seed: u64) -> Self {
        let mut task_rng = Rng::seeded(0x3A21);
        // each token has `branching` likely successors (90% mass) chosen at
        // random, remaining mass uniform.
        let succ: Vec<Vec<usize>> = (0..vocab)
            .map(|_| (0..branching).map(|_| task_rng.gen_range(vocab)).collect())
            .collect();
        let mut rng = Rng::seeded(seed.wrapping_add(0x51));
        let mut seqs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut s = Vec::with_capacity(seq + 1);
            let mut cur = rng.gen_range(vocab);
            s.push(cur as i32);
            for _ in 0..seq {
                cur = if rng.uniform() < 0.9 {
                    succ[cur][rng.gen_range(branching)]
                } else {
                    rng.gen_range(vocab)
                };
                s.push(cur as i32);
            }
            seqs.push(s);
        }
        MarkovCorpus { seqs, seq, vocab }
    }
}

impl Dataset for MarkovCorpus {
    fn len(&self) -> usize {
        self.seqs.len()
    }

    fn batch(&self, indices: &[usize]) -> ModelBatch {
        lm_batch(&self.seqs, self.seq, indices)
    }
}

fn lm_batch(seqs: &[Vec<i32>], seq: usize, indices: &[usize]) -> ModelBatch {
    let b = indices.len();
    let mut x = Vec::with_capacity(b * seq);
    let mut y = Vec::with_capacity(b * seq);
    for &i in indices {
        let s = &seqs[i];
        x.extend_from_slice(&s[..seq]);
        y.extend_from_slice(&s[1..seq + 1]);
    }
    ModelBatch::Lm {
        x: IntTensor::from_vec(&[b, seq], x).unwrap(),
        y: IntTensor::from_vec(&[b, seq], y).unwrap(),
    }
}

/// E2E/DART analog. Layout of each sequence (length seq+1):
///   [FIELD_0, val_0, FIELD_1, val_1, ..., SEP, sentence tokens...]
/// The sentence is a fixed template phrase per field interleaved with a
/// deterministic function of each value.
pub struct TableToTextCorpus {
    pub seqs: Vec<Vec<i32>>,
    pub seq: usize,
    pub vocab: usize,
    pub n_fields: usize,
    pub sep: i32,
    pub prefix_len: usize,
}

impl TableToTextCorpus {
    pub fn new(n: usize, seq: usize, vocab: usize, n_fields: usize, seed: u64) -> Self {
        assert!(vocab >= 64, "table-to-text wants vocab >= 64");
        let mut rng = Rng::seeded(seed);
        // vocab layout: [0, nf) field markers | nf..nf+nv values | sep |
        // phrase tokens from the upper half.
        let n_vals = (vocab / 4).max(8);
        let val_base = n_fields;
        let sep = (n_fields + n_vals) as i32;
        let phrase_base = n_fields + n_vals + 1;
        let prefix_len = 2 * n_fields + 1;
        assert!(seq + 1 > prefix_len + 2 * n_fields);

        let mut seqs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut s = Vec::with_capacity(seq + 1);
            let mut vals = Vec::with_capacity(n_fields);
            for f in 0..n_fields {
                s.push(f as i32);
                let v = rng.gen_range(n_vals);
                vals.push(v);
                s.push((val_base + v) as i32);
            }
            s.push(sep);
            // sentence: for each field f: phrase(f), value-echo(v)
            let mut k = 0usize;
            while s.len() < seq + 1 {
                let f = k % n_fields;
                let tok = if k % 2 == 0 {
                    phrase_base + (f * 7) % (vocab - phrase_base)
                } else {
                    phrase_base + (vals[f] * 3 + 1) % (vocab - phrase_base)
                };
                s.push(tok as i32);
                k += 1;
            }
            seqs.push(s);
        }
        TableToTextCorpus { seqs, seq, vocab, n_fields, sep, prefix_len }
    }

    /// Reference suffix (the "gold sentence") for BLEU scoring.
    pub fn reference_suffix(&self, i: usize) -> &[i32] {
        &self.seqs[i][self.prefix_len..]
    }

    pub fn prefix(&self, i: usize) -> &[i32] {
        &self.seqs[i][..self.prefix_len]
    }
}

impl Dataset for TableToTextCorpus {
    fn len(&self) -> usize {
        self.seqs.len()
    }

    fn batch(&self, indices: &[usize]) -> ModelBatch {
        lm_batch(&self.seqs, self.seq, indices)
    }
}

/// SAMSum analog: dialog region of mostly-common tokens with a few salient
/// rare tokens; after SEP the summary lists the salient tokens in order.
pub struct DialogSumCorpus {
    pub seqs: Vec<Vec<i32>>,
    pub seq: usize,
    pub vocab: usize,
    pub sep: i32,
    pub dialog_len: usize,
}

impl DialogSumCorpus {
    pub fn new(n: usize, seq: usize, vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 64);
        let mut rng = Rng::seeded(seed);
        let common = vocab / 2; // tokens [0, common) are filler
        let sep = common as i32;
        let rare_base = common + 1;
        let dialog_len = (seq * 2) / 3;
        let n_salient = 4.min((seq - dialog_len).saturating_sub(1)).max(1);
        let mut seqs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut s = Vec::with_capacity(seq + 1);
            let mut salient = Vec::new();
            // place salient tokens at spread positions in the dialog
            let stride = dialog_len / n_salient;
            for t in 0..dialog_len {
                if t % stride == stride / 2 && salient.len() < n_salient {
                    let tok = rare_base + rng.gen_range(vocab - rare_base);
                    salient.push(tok as i32);
                    s.push(tok as i32);
                } else {
                    s.push(rng.gen_range(common) as i32);
                }
            }
            s.push(sep);
            let mut k = 0;
            while s.len() < seq + 1 {
                s.push(salient[k % salient.len()]);
                k += 1;
            }
            seqs.push(s);
        }
        DialogSumCorpus { seqs, seq, vocab, sep, dialog_len }
    }

    pub fn reference_summary(&self, i: usize) -> &[i32] {
        &self.seqs[i][self.dialog_len + 1..]
    }

    pub fn prefix(&self, i: usize) -> &[i32] {
        &self.seqs[i][..self.dialog_len + 1]
    }
}

impl Dataset for DialogSumCorpus {
    fn len(&self) -> usize {
        self.seqs.len()
    }

    fn batch(&self, indices: &[usize]) -> ModelBatch {
        lm_batch(&self.seqs, self.seq, indices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_shapes_and_determinism() {
        let c1 = MarkovCorpus::new(10, 16, 64, 4, 7);
        let c2 = MarkovCorpus::new(10, 16, 64, 4, 7);
        assert_eq!(c1.seqs, c2.seqs);
        assert!(c1.seqs.iter().all(|s| s.len() == 17));
        assert!(c1.seqs.iter().flatten().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn markov_batch_is_shifted() {
        let c = MarkovCorpus::new(4, 8, 32, 4, 1);
        if let ModelBatch::Lm { x, y } = c.batch(&[0, 1]) {
            assert_eq!(x.shape, vec![2, 8]);
            // y is x shifted by one within each row
            assert_eq!(x.data[1], y.data[0]);
        } else {
            panic!("wrong batch kind");
        }
    }

    #[test]
    fn table_to_text_template_is_learnable() {
        // identical field values must produce identical suffixes
        let c = TableToTextCorpus::new(200, 31, 128, 2, 3);
        for i in 0..200 {
            for j in 0..i {
                if c.prefix(i) == c.prefix(j) {
                    assert_eq!(c.reference_suffix(i), c.reference_suffix(j));
                }
            }
        }
    }

    #[test]
    fn dialog_summary_repeats_salient_tokens() {
        let c = DialogSumCorpus::new(20, 30, 128, 5);
        for i in 0..20 {
            let dialog = &c.seqs[i][..c.dialog_len];
            for &tok in c.reference_summary(i) {
                assert!(dialog.contains(&tok), "summary token {tok} not in dialog");
            }
        }
    }
}
