//! Synthetic data substrates replacing the paper's gated datasets
//! (CIFAR-10, GLUE, E2E/DART, SAMSum) — see DESIGN.md section 3 for the
//! substitution rationale. Each generator is seeded and deterministic.

pub mod classif;
pub mod lm;

use crate::runtime::{IntTensor, Tensor};

/// A batch of model inputs assembled from dataset indices.
#[derive(Debug, Clone)]
pub enum ModelBatch {
    /// LM: tokens [B,T], targets [B,T]
    Lm { x: IntTensor, y: IntTensor },
    /// token classifier: tokens [B,T], labels [B]
    Cls { x: IntTensor, y: IntTensor },
    /// feature classifier: x [B,P], labels [B]
    Feat { x: Tensor, y: IntTensor },
}

impl ModelBatch {
    pub fn inputs(&self) -> (crate::runtime::HostValue, crate::runtime::HostValue) {
        use crate::runtime::HostValue as H;
        match self {
            ModelBatch::Lm { x, y } => (H::I32(x.clone()), H::I32(y.clone())),
            ModelBatch::Cls { x, y } => (H::I32(x.clone()), H::I32(y.clone())),
            ModelBatch::Feat { x, y } => (H::F32(x.clone()), H::I32(y.clone())),
        }
    }
}

/// Common dataset interface consumed by the trainer.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Assemble a batch for `indices` (len == static batch B of the config).
    fn batch(&self, indices: &[usize]) -> ModelBatch;
}

/// Build the (train, eval) dataset pair a [`DataSpec`] describes for a
/// manifest config — the one construction path shared by the CLI, the
/// session API and the experiment harness (previously copy-pasted).
///
/// `task = "auto"` picks the substrate from the config's model family;
/// explicit tasks select a specific generator. The eval split is a fresh
/// draw of n/4 examples at `seed + 1000` (the convention every harness
/// already used).
pub fn build_for_config(
    cfg: &crate::runtime::ConfigManifest,
    spec: &crate::session::DataSpec,
) -> anyhow::Result<(Box<dyn Dataset>, Box<dyn Dataset>)> {
    use self::classif::{MixtureImages, SentimentCorpus, TextTask};
    use self::lm::{DialogSumCorpus, MarkovCorpus, TableToTextCorpus};

    let n = spec.n_data;
    let n_eval = (n / 4).max(1);
    let seed = spec.seed;
    let eval_seed = seed + 1000;
    let h = &cfg.hyper;
    let task = if spec.task == "auto" {
        match cfg.model.as_str() {
            "resmlp" => "mixture",
            "lm" => "markov",
            "classifier" => "sst2",
            other => anyhow::bail!(
                "no default data substrate for model family '{other}'; set data.task explicitly"
            ),
        }
    } else {
        spec.task.as_str()
    };
    let text_task = |t: TextTask| -> (Box<dyn Dataset>, Box<dyn Dataset>) {
        (
            Box::new(SentimentCorpus::new(t, n, h.seq, h.vocab, seed)),
            Box::new(SentimentCorpus::new(t, n_eval, h.seq, h.vocab, eval_seed)),
        )
    };
    Ok(match task {
        "mixture" => (
            Box::new(MixtureImages::new(n, h.features, h.n_classes, seed)),
            Box::new(MixtureImages::new(n_eval, h.features, h.n_classes, eval_seed)),
        ),
        // the CIFAR-10 analog of the tables: harder spread, fixed task seed
        "cifar" => (
            Box::new(MixtureImages::with_spread(n, h.features, h.n_classes, 0xC1FA, seed, 0.55)),
            Box::new(MixtureImages::with_spread(
                n_eval, h.features, h.n_classes, 0xC1FA, eval_seed, 0.55,
            )),
        ),
        "sst2" => text_task(TextTask::Sst2),
        "qnli" => text_task(TextTask::Qnli),
        "qqp" => text_task(TextTask::Qqp),
        "mnli" => text_task(TextTask::MnliLike),
        "markov" => (
            Box::new(MarkovCorpus::new(n, h.seq, h.vocab, 4, seed)),
            Box::new(MarkovCorpus::new(n_eval, h.seq, h.vocab, 4, eval_seed)),
        ),
        "table2text" => (
            Box::new(TableToTextCorpus::new(n, h.seq, h.vocab, 3, seed)),
            Box::new(TableToTextCorpus::new(n_eval, h.seq, h.vocab, 3, eval_seed)),
        ),
        "dialogsum" => (
            Box::new(DialogSumCorpus::new(n, h.seq, h.vocab, seed)),
            Box::new(DialogSumCorpus::new(n_eval, h.seq, h.vocab, eval_seed)),
        ),
        other => anyhow::bail!(
            "unknown data task '{other}' \
             (auto|mixture|cifar|sst2|qnli|qqp|mnli|markov|table2text|dialogsum)"
        ),
    })
}
