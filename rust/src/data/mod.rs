//! Synthetic data substrates replacing the paper's gated datasets
//! (CIFAR-10, GLUE, E2E/DART, SAMSum) — see DESIGN.md section 3 for the
//! substitution rationale. Each generator is seeded and deterministic.

pub mod classif;
pub mod lm;

use crate::runtime::{IntTensor, Tensor};

/// A batch of model inputs assembled from dataset indices.
#[derive(Debug, Clone)]
pub enum ModelBatch {
    /// LM: tokens [B,T], targets [B,T]
    Lm { x: IntTensor, y: IntTensor },
    /// token classifier: tokens [B,T], labels [B]
    Cls { x: IntTensor, y: IntTensor },
    /// feature classifier: x [B,P], labels [B]
    Feat { x: Tensor, y: IntTensor },
}

impl ModelBatch {
    pub fn inputs(&self) -> (crate::runtime::HostValue, crate::runtime::HostValue) {
        use crate::runtime::HostValue as H;
        match self {
            ModelBatch::Lm { x, y } => (H::I32(x.clone()), H::I32(y.clone())),
            ModelBatch::Cls { x, y } => (H::I32(x.clone()), H::I32(y.clone())),
            ModelBatch::Feat { x, y } => (H::F32(x.clone()), H::I32(y.clone())),
        }
    }
}

/// Common dataset interface consumed by the trainer.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Assemble a batch for `indices` (len == static batch B of the config).
    fn batch(&self, indices: &[usize]) -> ModelBatch;
}
