//! Synthetic classification substrates.
//!
//! * [`MixtureImages`] — CIFAR-10 analog for the WideResMLP: 10 Gaussian
//!   clusters in feature space pushed through a fixed random nonlinearity,
//!   2% label noise. From-scratch training exhibits the same
//!   gradient-norm-distribution shift the paper plots in Figure 2.
//! * [`SentimentCorpus`] — SST-2/GLUE analog for the encoder classifier:
//!   the label is the majority sentiment of class-indicative tokens mixed
//!   with neutral filler; task variants change class count / length /
//!   indicative-token rate (MNLI/QQP/QNLI analogs, Table 3).

use crate::coordinator::noise::Rng;
use crate::runtime::{IntTensor, Tensor};

use super::{Dataset, ModelBatch};

pub struct MixtureImages {
    pub x: Vec<Vec<f32>>, // [n][features]
    pub y: Vec<i32>,
    pub features: usize,
    pub classes: usize,
}

impl MixtureImages {
    /// `task_seed` fixes the class structure (cluster means); `sample_seed`
    /// draws the examples. Train/test splits share the task seed.
    pub fn with_seeds(n: usize, features: usize, classes: usize, task_seed: u64, sample_seed: u64) -> Self {
        Self::with_spread(n, features, classes, task_seed, sample_seed, 1.2)
    }

    /// `spread` scales class-mean separation: smaller = harder task (more
    /// class overlap, lower accuracy ceiling) — used by the Table 1/2
    /// harnesses so clipping-scheme differences are visible above the
    /// ceiling.
    pub fn with_spread(n: usize, features: usize, classes: usize, task_seed: u64,
                       sample_seed: u64, spread: f32) -> Self {
        let mut task_rng = Rng::seeded(task_seed);
        // class means on a scaled simplex + per-class random direction
        let means: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..features).map(|_| spread * task_rng.gauss() as f32).collect())
            .collect();
        let mut rng = Rng::seeded(sample_seed.wrapping_add(0x9E37));
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.gen_range(classes);
            let mut v: Vec<f32> = (0..features)
                .map(|j| means[c][j] + rng.gauss() as f32)
                .collect();
            // fixed nonlinearity so the task is not linearly separable
            for j in 0..features {
                let a = v[j];
                let b = v[(j + 1) % features];
                v[j] = a + 0.3 * (a * b).tanh();
            }
            let label = if rng.uniform() < 0.02 { rng.gen_range(classes) } else { c };
            x.push(v);
            y.push(label as i32);
        }
        MixtureImages { x, y, features, classes }
    }

    /// Single-seed constructor: task structure from seed 0xC1FA, samples
    /// from `sample_seed` — all instances are views of the same task.
    pub fn new(n: usize, features: usize, classes: usize, sample_seed: u64) -> Self {
        Self::with_seeds(n, features, classes, 0xC1FA, sample_seed)
    }
}

impl Dataset for MixtureImages {
    fn len(&self) -> usize {
        self.x.len()
    }

    fn batch(&self, indices: &[usize]) -> ModelBatch {
        let b = indices.len();
        let mut xs = Vec::with_capacity(b * self.features);
        let mut ys = Vec::with_capacity(b);
        for &i in indices {
            xs.extend_from_slice(&self.x[i]);
            ys.push(self.y[i]);
        }
        ModelBatch::Feat {
            x: Tensor::from_vec(&[b, self.features], xs).unwrap(),
            y: IntTensor::from_vec(&[b], ys).unwrap(),
        }
    }
}

/// Task flavors for the GLUE-analog suite (Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TextTask {
    Sst2,
    Qnli,
    Qqp,
    MnliLike,
}

impl TextTask {
    pub fn name(&self) -> &'static str {
        match self {
            TextTask::Sst2 => "SST-2",
            TextTask::Qnli => "QNLI",
            TextTask::Qqp => "QQP",
            TextTask::MnliLike => "MNLI",
        }
    }

    fn classes(&self) -> usize {
        match self {
            TextTask::MnliLike => 3,
            _ => 2,
        }
    }

    fn indicative_rate(&self) -> f64 {
        match self {
            TextTask::Sst2 => 0.30,
            TextTask::Qnli => 0.22,
            TextTask::Qqp => 0.18,
            TextTask::MnliLike => 0.25,
        }
    }
}

pub struct SentimentCorpus {
    pub tokens: Vec<Vec<i32>>,
    pub labels: Vec<i32>,
    pub seq: usize,
    pub vocab: usize,
    pub classes: usize,
}

impl SentimentCorpus {
    pub fn new(task: TextTask, n: usize, seq: usize, vocab: usize, seed: u64) -> Self {
        let classes = task.classes();
        let mut rng = Rng::seeded(seed);
        // vocab split: class c owns tokens with tok % (classes+1) == c;
        // remainder (== classes) is neutral filler.
        let rate = task.indicative_rate();
        let mut tokens = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.gen_range(classes);
            let mut s = Vec::with_capacity(seq);
            for _ in 0..seq {
                if rng.uniform() < rate {
                    // indicative token of the true class (sometimes a decoy)
                    let cls = if rng.uniform() < 0.85 { c } else { rng.gen_range(classes) };
                    let mut t = rng.gen_range(vocab);
                    t = t - (t % (classes + 1)) + cls;
                    s.push((t % vocab) as i32);
                } else {
                    let mut t = rng.gen_range(vocab);
                    t = t - (t % (classes + 1)) + classes; // neutral
                    s.push((t % vocab) as i32);
                }
            }
            tokens.push(s);
            labels.push(c as i32);
        }
        SentimentCorpus { tokens, labels, seq, vocab, classes }
    }
}

impl Dataset for SentimentCorpus {
    fn len(&self) -> usize {
        self.tokens.len()
    }

    fn batch(&self, indices: &[usize]) -> ModelBatch {
        let b = indices.len();
        let mut xs = Vec::with_capacity(b * self.seq);
        let mut ys = Vec::with_capacity(b);
        for &i in indices {
            xs.extend_from_slice(&self.tokens[i]);
            ys.push(self.labels[i]);
        }
        ModelBatch::Cls {
            x: IntTensor::from_vec(&[b, self.seq], xs).unwrap(),
            y: IntTensor::from_vec(&[b], ys).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_is_deterministic_and_bounded() {
        let a = MixtureImages::new(50, 16, 10, 9);
        let b = MixtureImages::new(50, 16, 10, 9);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x[3], b.x[3]);
        assert!(a.y.iter().all(|&l| (0..10).contains(&(l as usize))));
    }

    #[test]
    fn mixture_classes_are_separated() {
        // nearest-class-mean classifier must beat chance comfortably
        let d = MixtureImages::new(500, 16, 4, 11);
        let mut means = vec![vec![0f64; 16]; 4];
        let mut counts = vec![0f64; 4];
        for (v, &l) in d.x.iter().zip(&d.y) {
            counts[l as usize] += 1.0;
            for j in 0..16 {
                means[l as usize][j] += v[j] as f64;
            }
        }
        for c in 0..4 {
            for j in 0..16 {
                means[c][j] /= counts[c].max(1.0);
            }
        }
        let mut correct = 0;
        for (v, &l) in d.x.iter().zip(&d.y) {
            let best = (0..4)
                .min_by(|&a, &b| {
                    let da: f64 = (0..16).map(|j| (v[j] as f64 - means[a][j]).powi(2)).sum();
                    let db: f64 = (0..16).map(|j| (v[j] as f64 - means[b][j]).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == l {
                correct += 1;
            }
        }
        assert!(correct > 350, "nearest-mean acc {correct}/500");
    }

    #[test]
    fn sentiment_labels_recoverable_by_counting() {
        let d = SentimentCorpus::new(TextTask::Sst2, 300, 32, 400, 5);
        let mut correct = 0;
        for (s, &l) in d.tokens.iter().zip(&d.labels) {
            let c0 = s.iter().filter(|&&t| t % 3 == 0).count();
            let c1 = s.iter().filter(|&&t| t % 3 == 1).count();
            if (c1 > c0) as i32 == l {
                correct += 1;
            }
        }
        assert!(correct > 240, "counting acc {correct}/300");
    }

    #[test]
    fn mnli_has_three_classes() {
        let d = SentimentCorpus::new(TextTask::MnliLike, 100, 16, 400, 6);
        assert_eq!(d.classes, 3);
        assert!(d.labels.iter().any(|&l| l == 2));
    }
}
