//! `HybridEngine` — R data-parallel replicas, each a full S-stage pipeline,
//! over disjoint slices of ONE global Poisson draw, with per-piece
//! clipping at the (replica, stage) granularity.
//!
//! Execution is sequential on the host (the PJRT CPU client already uses
//! every core per executable call), but each replica's stage calls are
//! timed and replayed: the GPipe schedule model yields per-stage
//! gradient-ready times, and [`ReduceModel`] overlays the cross-replica
//! reductions on top — stage `st`'s fanout-f tree all-reduce starts the
//! moment its gradient drains from the pipeline, while earlier stages are
//! still back-propagating.
//!
//! All DP state lives in the session's shared
//! [`StepLoop`](crate::session::StepLoop); this engine implements the
//! [`BackendStep`](crate::session::steploop::BackendStep) hooks only. The
//! unit layout it hands the loop encodes the documented RNG discipline —
//! per step the shared core RNG is consumed as (1) one global Poisson
//! draw, (2) gradient noise in replica-major, stage-major, tensor order
//! at the local share `sigma_g/sqrt(R)`, (3) the private quantile
//! release. With one replica this is the [`PipelineEngine`] sequence
//! verbatim.
//!
//! The merge hook shares the sharded backend's compression seam: with a
//! `[compress]` spec section each replica's already-noised share is
//! sparsified (error-feedback top-k / rand-k) before each stage's
//! cross-replica [`tree_reduce_with`], shrinking the simulated reduction
//! payload by the keep ratio — identical semantics under `[shard]` and
//! `[hybrid]` because the seam is shared.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::coordinator::noise::Rng;
use crate::coordinator::optimizer::{Optimizer, OptimizerKind};
use crate::data::Dataset;
use crate::kernels::Kernels;
use crate::pipeline::schedule::stage_grad_ready;
use crate::pipeline::{PipelineEngine, PipelineMode, PipelineOpts};
use crate::runtime::{ConfigManifest, Runtime, Tensor};
use crate::session::core::DpCore;
use crate::session::grad::{fold_parts, Collected, GradUnit, Merged, StepTiming, UnitCollected};
use crate::session::spec::CompressSpec;
use crate::session::steploop::{BackendStep, UnitTask};
use crate::shard::compress::Compressor;
use crate::shard::reduce::{tree_reduce_with, ReduceModel};
use crate::shard::sampler::{ShardBatch, ShardSampler};

/// How clipping-threshold groups tile the (replica, stage) grid (resolved
/// from `HybridSpec.grouping` by the session builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PieceGrouping {
    /// every (replica, stage) piece owns its own threshold (K = R x S) —
    /// the paper's per-device scheme on the full 2D grid
    PerPiece,
    /// one threshold per stage, shared across replicas (K = S)
    PerStage,
}

impl PieceGrouping {
    pub fn token(&self) -> &'static str {
        match self {
            PieceGrouping::PerPiece => "per-piece",
            PieceGrouping::PerStage => "per-stage",
        }
    }
}

/// Backend wiring computed by the session builder (crate-internal: like
/// the other engines, the hybrid backend has no public constructor).
pub(crate) struct HybridWiring {
    pub replicas: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub link_latency: f64,
    pub grouping: PieceGrouping,
    /// `PerDevice` (per-piece clipping) or `NonPrivate`
    pub mode: PipelineMode,
    pub n_micro: usize,
    /// global expected live batch E[B] (normalizes the merged update)
    pub expected_batch: usize,
    /// Poisson rate of the one global draw, q = E[B]/n
    pub rate: f64,
    pub total_steps: u64,
    pub n_data: usize,
    pub optimizer: OptimizerKind,
    pub lr: f64,
    pub seed: u64,
    /// echoed into each replica's `PipelineOpts`; like the per-device
    /// pipeline sim (whose `makespan` charges it only on the flat-sync
    /// regrad barrier), the hybrid makespans do NOT charge it — the
    /// cross-replica reduction's per-round cost is `link_latency`, and
    /// keeping the compute side identical is what makes the R = 1 sim
    /// equal the pipeline backend's
    pub sync_latency: f64,
    pub clip_init: f64,
    pub target_q: f64,
    pub quantile_eta: f64,
    /// error-feedback gradient sparsification on the reduction path
    pub compress: Option<CompressSpec>,
}

pub struct HybridEngine<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub cfg: ConfigManifest,
    /// data-parallel replicas R
    pub replicas_n: usize,
    /// pipeline stages S (from the manifest)
    pub n_stages: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub total_steps: u64,
    grouping: PieceGrouping,
    private: bool,
    n_micro: usize,
    replicas: Vec<PipelineEngine<'r>>,
    sampler: ShardSampler,
    /// global E[B] normalizing the merged update
    expected_batch: f64,
    /// trainable element count per stage (reduction payload sizing)
    stage_dims: Vec<f64>,
    /// trainable tensor count per stage (unit regrouping offsets)
    stage_tr_counts: Vec<usize>,
    reduce_model: ReduceModel,
    /// error-feedback sparsifier on the reduction seam (None = dense)
    compressor: Option<Compressor>,
    /// live counts of the most recent collect, per replica (per-piece
    /// clip_frac denominators read them)
    replica_lives: Vec<usize>,
    /// when compressing: the (overlap, barrier) makespans the SAME step
    /// timings would have produced without compression
    last_dense_sims: Option<(f64, f64)>,
    /// dispatched kernel vtable for the host-side reduction/apply loops
    kernels: Kernels,
}

impl<'r> HybridEngine<'r> {
    /// Crate-private constructor: all DP state lives in the session's
    /// `StepLoop` (`core` is borrowed to validate the group-count
    /// contract), all schedule/topology decisions in `wiring`. Only
    /// `session::SessionBuilder` builds these.
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        w: HybridWiring,
        core: &DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        let stages = cfg.stages.clone().ok_or_else(|| {
            anyhow!(
                "config {config_name} has no pipeline stages; the hybrid backend composes \
                 pipeline x data parallelism — use [shard] for pure data parallelism"
            )
        })?;
        let s = stages.stages.len();
        if w.replicas == 0 {
            return Err(anyhow!("hybrid backend needs replicas > 0"));
        }
        let private = w.mode == PipelineMode::PerDevice;
        if w.mode == PipelineMode::FlatSync {
            return Err(anyhow!(
                "the hybrid backend supports per-device clipping (or non-private); \
                 flat-sync is pipeline-only"
            ));
        }
        let expect_k = if private {
            match w.grouping {
                PieceGrouping::PerPiece => w.replicas * s,
                PieceGrouping::PerStage => s,
            }
        } else {
            1
        };
        if core.k() != expect_k {
            return Err(anyhow!(
                "DpCore has {} threshold groups but {} grouping over {} replicas x {} stages \
                 needs {}",
                core.k(),
                w.grouping.token(),
                w.replicas,
                s,
                expect_k
            ));
        }

        // R full pipeline replicas, driven entirely through the
        // collect_weighted/apply_flat seams: thresholds reach them
        // explicitly, noise and RNG live only in the session's core. One
        // checkpoint read fans out to every replica, so they start
        // bit-identical.
        let ck = crate::runtime::checkpoint::read(
            runtime.manifest.hlo_path(&cfg.init_checkpoint),
        )?;
        let mut replicas = Vec::with_capacity(w.replicas);
        for _ in 0..w.replicas {
            let opts = PipelineOpts {
                mode: w.mode,
                n_micro: w.n_micro,
                expected_batch: (w.expected_batch / w.replicas).max(1),
                clip: w.clip_init,
                lr: w.lr,
                optimizer: w.optimizer,
                seed: w.seed,
                sync_latency: w.sync_latency,
                adaptive: false,
                target_q: w.target_q,
                quantile_eta: w.quantile_eta,
            };
            replicas.push(PipelineEngine::with_core_from_ck(
                runtime,
                config_name,
                opts,
                None,
                &ck,
            )?);
        }
        let minibatch = replicas[0].minibatch();
        let stage_dims = replicas[0].stage_trainable_dims();
        let stage_tr_counts = replicas[0].stage_trainable_counts();

        let compressor = w
            .compress
            .as_ref()
            .map(|c| Compressor::new(c.kind, c.ratio, c.error_feedback, w.replicas, w.seed));
        Ok(HybridEngine {
            runtime,
            config_name: config_name.to_string(),
            replicas_n: w.replicas,
            n_stages: s,
            fanout: w.fanout,
            overlap: w.overlap,
            total_steps: w.total_steps,
            grouping: w.grouping,
            private,
            n_micro: w.n_micro,
            sampler: ShardSampler::new(w.n_data, w.rate, w.replicas, minibatch),
            expected_batch: w.expected_batch as f64,
            stage_dims,
            stage_tr_counts,
            reduce_model: ReduceModel::new(w.replicas, w.fanout, w.link_latency),
            compressor,
            replica_lives: vec![0; w.replicas],
            last_dense_sims: None,
            kernels: Kernels::default(),
            replicas,
            cfg,
        })
    }

    /// Install the session's dispatched kernel vtable on the engine, its
    /// compressor, and every replica's stage optimizers.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
        for e in self.replicas.iter_mut() {
            e.set_kernels(kernels);
        }
        if let Some(c) = self.compressor.as_mut() {
            c.set_kernels(kernels);
        }
    }

    /// The (overlap, barrier) makespans the most recent step's timings
    /// would have produced WITHOUT compression; `None` until a compressed
    /// step ran. Deterministically comparable to the step's reported sims
    /// (same measured timings, only the payload differs).
    pub fn last_dense_sims(&self) -> Option<(f64, f64)> {
        self.last_dense_sims
    }

    pub fn grouping(&self) -> PieceGrouping {
        self.grouping
    }

    /// Static per-replica pipeline minibatch (microbatch x J).
    pub fn minibatch(&self) -> usize {
        self.replicas[0].minibatch()
    }

    /// Global static capacity: replicas x the per-replica minibatch.
    pub fn capacity(&self) -> usize {
        self.replicas_n * self.minibatch()
    }

    /// Threshold-group labels (R x S `r{r}s{st}` labels for per-piece
    /// grouping, S `stage{st}` labels for per-stage).
    pub fn group_labels(&self) -> Vec<String> {
        if !self.private {
            return vec!["flat".to_string()];
        }
        match self.grouping {
            PieceGrouping::PerPiece => (0..self.replicas_n)
                .flat_map(|r| (0..self.n_stages).map(move |st| format!("r{r}s{st}")))
                .collect(),
            PieceGrouping::PerStage => {
                (0..self.n_stages).map(|st| format!("stage{st}")).collect()
            }
        }
    }

    /// Group index of piece (replica `r`, stage `st`).
    fn group_of(&self, r: usize, st: usize) -> usize {
        if !self.private {
            return 0;
        }
        match self.grouping {
            PieceGrouping::PerPiece => r * self.n_stages + st,
            PieceGrouping::PerStage => st,
        }
    }

    /// All parameters of replica 0 as a name -> tensor map (the merged
    /// update keeps every replica bit-identical; see
    /// [`HybridEngine::replicas_in_sync`]).
    pub fn dump_params(&self) -> HashMap<String, Tensor> {
        self.replicas[0].dump_params()
    }

    /// Load parameters by name on EVERY replica; names absent from the
    /// map keep their init values (LoRA adapters).
    pub fn load_params(&mut self, map: &HashMap<String, Tensor>) -> Result<()> {
        for e in self.replicas.iter_mut() {
            e.load_params(map)?;
        }
        Ok(())
    }

    /// Replica-0's per-stage optimizer states (all replicas stay
    /// bit-identical, so snapshots persist one replica's and fan them
    /// back out on restore).
    pub fn stage_optimizers(&self) -> Vec<&Optimizer> {
        self.replicas[0].stage_optimizers()
    }

    /// Restore per-stage optimizer states (stage order) into EVERY
    /// replica (snapshot fan-out, mirroring `load_params`).
    pub fn restore_stage_optimizers(
        &mut self,
        states: &[(u64, Vec<Vec<f32>>, Vec<Vec<f32>>)],
    ) -> Result<()> {
        for e in self.replicas.iter_mut() {
            let opts = e.stage_optimizers_mut();
            if opts.len() != states.len() {
                return Err(anyhow!(
                    "hybrid optimizer restore: {} stage states, engine has {} stages",
                    states.len(),
                    opts.len()
                ));
            }
            for (opt, (step, m, v)) in opts.into_iter().zip(states) {
                opt.restore_state(*step, m.clone(), v.clone())?;
            }
        }
        Ok(())
    }

    /// The error-feedback compressor, if `[compress]` is configured.
    pub fn compressor(&self) -> Option<&Compressor> {
        self.compressor.as_ref()
    }

    pub fn compressor_mut(&mut self) -> Option<&mut Compressor> {
        self.compressor.as_mut()
    }

    /// True when every replica's parameters are bitwise equal to replica
    /// 0's — the invariant the merged update maintains.
    pub fn replicas_in_sync(&self) -> bool {
        let p0 = self.replicas[0].dump_params();
        self.replicas.iter().skip(1).all(|e| {
            let p = e.dump_params();
            p.len() == p0.len()
                && p.iter().all(|(name, t)| {
                    p0.get(name)
                        .map(|t0| t0.shape == t.shape && t0.data == t.data)
                        .unwrap_or(false)
                })
        })
    }

    /// Topology line for `Session::describe` / the CLI, against the
    /// current per-group `thresholds` (owned by the session's core).
    pub fn describe_topology(&self, thresholds: &[f64]) -> String {
        let c: Vec<String> = thresholds.iter().map(|c| format!("{c:.4}")).collect();
        let compress = match &self.compressor {
            Some(c) => format!(" compress={}", c.describe()),
            None => String::new(),
        };
        format!(
            "replicas={} stages={} fanout={} reduction={}{compress} grouping={} thresholds=[{}]",
            self.replicas_n,
            self.n_stages,
            self.fanout,
            if self.overlap { "overlapped" } else { "barrier" },
            self.grouping.token(),
            c.join(", ")
        )
    }

    /// Mean eval loss over `data` through replica 0's pipeline.
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<f64> {
        self.replicas[0].evaluate(data)
    }
}

impl BackendStep for HybridEngine<'_> {
    type Slices = ShardBatch;

    fn deal(&mut self, _n_data: usize, rng: &mut Rng) -> ShardBatch {
        // ONE global Poisson draw dealt round-robin into disjoint padded
        // per-replica minibatches (the accountant sees the union)
        self.sampler.sample(rng)
    }

    fn collect_tasks<'a>(
        &'a mut self,
        data: &'a dyn Dataset,
        batch: &'a ShardBatch,
        thresholds: &'a [f64],
    ) -> Vec<UnitTask<'a>> {
        let s = self.n_stages;
        let k = thresholds.len();
        let private = self.private;
        let grouping = self.grouping;
        // one task per data-parallel replica: each owns its pipeline's
        // activation/accumulator state exclusively, so the R wavefronts can
        // run on separate OS threads
        self.replicas
            .iter_mut()
            .enumerate()
            .map(|(r, replica)| {
                let slice = &batch.slices[r];
                let task: UnitTask<'a> = Box::new(move || {
                    let group_of = |st: usize| {
                        if !private {
                            0
                        } else {
                            match grouping {
                                PieceGrouping::PerPiece => r * s + st,
                                PieceGrouping::PerStage => st,
                            }
                        }
                    };
                    let piece_thr: Vec<f64> = if private {
                        (0..s).map(|st| thresholds[group_of(st)]).collect()
                    } else {
                        vec![1e9; s]
                    };
                    let col = replica.collect_weighted(
                        data,
                        &slice.indices,
                        &slice.weights,
                        &piece_thr,
                    )?;
                    // replica-major, stage-major flattened unit layout:
                    // this IS the RNG discipline that makes R = 1
                    // bitwise-identical to the pipeline backend (whose
                    // noise loop is stage-major in the same tensor order)
                    let mut tensors = Vec::new();
                    let mut groups = Vec::new();
                    let mut clip_counts = vec![0f64; k];
                    for (st, g) in col.grads.into_iter().enumerate() {
                        let gi = group_of(st);
                        if private {
                            clip_counts[gi] += col.clip_counts[st];
                        }
                        for t in g {
                            tensors.push(t);
                            groups.push(gi);
                        }
                    }
                    let mut part = UnitCollected::new(GradUnit { tensors, groups }, k);
                    part.clip_counts = clip_counts;
                    part.loss_wsum = col.loss_wsum;
                    part.weight_sum = col.weight_sum;
                    part.live = slice.live();
                    part.calls = col.calls;
                    part.durations = col.durations;
                    Ok(part)
                });
                task
            })
            .collect()
    }

    fn finish_collect(&mut self, batch: &ShardBatch, parts: Vec<UnitCollected>) -> Result<Collected> {
        let s = self.n_stages;
        let k = parts.first().map(|p| p.clip_counts.len()).unwrap_or(0);
        let f = fold_parts(parts, k);
        self.replica_lives.copy_from_slice(&f.lives);
        // TRUE per-group denominators: a replica whose slice drew no live
        // example reports 0 and the loop's guarded division turns the
        // fraction into 0.0 rather than NaN
        let clip_denoms: Vec<f64> = if self.private {
            (0..k)
                .map(|g| match self.grouping {
                    PieceGrouping::PerPiece => self.replica_lives[g / s] as f64,
                    PieceGrouping::PerStage => batch.live as f64,
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(Collected {
            units: f.units,
            clip_counts: f.clip_counts,
            clip_denoms,
            mean_norms: Vec::new(),
            loss: f.loss_wsum / f.weight_sum.max(1.0),
            live: batch.live,
            truncated: batch.truncated,
            calls: f.calls,
            syncs: 0,
            timing: StepTiming { durations: f.durations, bwd_secs: Vec::new() },
        })
    }

    fn merge(&mut self, units: Vec<GradUnit>, timing: &StepTiming) -> Merged {
        let r_n = self.replicas_n;
        let s = self.n_stages;

        // -------- simulated R x S latency (overlap vs barrier) -----------
        // A real cluster runs the replicas concurrently, so the modeled
        // compute side is one representative replica (mean of the measured
        // per-op durations): per-stage gradient-ready times out of the
        // GPipe schedule, reductions queued FIFO in ready order.
        // Compression scales each stage's reduction payload by the ratio.
        let ratio = match &self.compressor {
            Some(c) if r_n > 1 => c.ratio().min(1.0),
            _ => 1.0,
        };
        let mut ready_mean = vec![0f64; s];
        for dur in &timing.durations {
            let (ready, _span) =
                stage_grad_ready(s, self.n_micro, &|op| dur.get(op).copied().unwrap_or(0.0));
            for (a, b) in ready_mean.iter_mut().zip(&ready) {
                *a += b / r_n as f64;
            }
        }
        // `overlap_makespan_at` requires its ready times non-decreasing
        // (FIFO network order) and debug-asserts it; sorting here is the
        // caller's side of that contract
        let mut order: Vec<usize> = (0..s).collect();
        order.sort_by(|&a, &b| ready_mean[a].partial_cmp(&ready_mean[b]).unwrap());
        let ready_sorted: Vec<f64> = order.iter().map(|&st| ready_mean[st]).collect();
        let red_sorted: Vec<f64> = order
            .iter()
            .map(|&st| self.reduce_model.layer_cost(4.0 * self.stage_dims[st] * ratio))
            .collect();
        let sim_overlap = self.reduce_model.overlap_makespan_at(&ready_sorted, &red_sorted);
        let sim_barrier = self.reduce_model.barrier_makespan_at(&ready_sorted, &red_sorted);
        // apples-to-apples dense baseline from the SAME timings, so the
        // compressed-beats-dense claim is deterministic, not host-noise
        self.last_dense_sims = (ratio < 1.0).then(|| {
            let red_dense: Vec<f64> = order
                .iter()
                .map(|&st| self.reduce_model.layer_cost(4.0 * self.stage_dims[st]))
                .collect();
            (
                self.reduce_model.overlap_makespan_at(&ready_sorted, &red_dense),
                self.reduce_model.barrier_makespan_at(&ready_sorted, &red_dense),
            )
        });

        // -------- compression + per-stage tree-reduction ------------------
        // Each replica sparsifies its ALREADY-NOISED share before its
        // pieces enter the per-stage trees (post-processing of a paid-for
        // release; residuals stay replica-local). A 1-replica tree is the
        // bitwise identity, so R = 1 keeps the pipeline backend's exact
        // float sequence.
        let mut flat: Vec<Vec<Tensor>> = units.into_iter().map(|u| u.tensors).collect();
        if let Some(c) = &mut self.compressor {
            if r_n > 1 {
                for (r, tensors) in flat.iter_mut().enumerate() {
                    c.compress_unit(r, tensors);
                }
            }
        }
        // regroup the flattened stage-major units into per-stage parts
        let mut parts_by_stage: Vec<Vec<Vec<Tensor>>> =
            (0..s).map(|_| Vec::with_capacity(r_n)).collect();
        for tensors in flat {
            let mut it = tensors.into_iter();
            for (st, &n) in self.stage_tr_counts.iter().enumerate() {
                parts_by_stage[st].push(it.by_ref().take(n).collect());
            }
        }
        let mut merged: Vec<Tensor> = Vec::new();
        for parts in parts_by_stage {
            merged.extend(tree_reduce_with(self.kernels, parts, self.fanout));
        }

        Merged {
            tensors: merged,
            sim_secs: if self.overlap { sim_overlap } else { sim_barrier },
            sim_overlap_secs: sim_overlap,
            sim_barrier_secs: sim_barrier,
            syncs: self.reduce_model.rounds(),
        }
    }

    fn apply(&mut self, grads: &[Tensor]) {
        // one merged update applied to every replica (identical optimizer
        // states + identical grads keep the replicas bit-identical)
        for e in self.replicas.iter_mut() {
            e.apply_flat(grads);
        }
    }

    fn update_scale(&self, _live: usize) -> f32 {
        // Algorithm 1 line 14: normalize the merged sum by the global E[B]
        (1.0 / self.expected_batch) as f32
    }

    fn prefetch_lists(&self, batch: &ShardBatch) -> Vec<Vec<usize>> {
        // each replica's collection assembles one ModelBatch per
        // microbatch, sliced from its dealt slice in J fixed-size chunks
        let b = self.replicas[0].micro_batch();
        batch
            .slices
            .iter()
            .flat_map(|slice| {
                (0..self.n_micro).map(move |m| slice.indices[m * b..(m + 1) * b].to_vec())
            })
            .collect()
    }
}
