//! `HybridEngine` — R data-parallel replicas, each a full S-stage pipeline,
//! over disjoint slices of ONE global Poisson draw, with per-piece
//! clipping at the (replica, stage) granularity.
//!
//! Execution is sequential on the host (the PJRT CPU client already uses
//! every core per executable call), but each replica's stage calls are
//! timed and replayed: the GPipe schedule model yields per-stage
//! gradient-ready times, and [`ReduceModel`] overlays the cross-replica
//! reductions on top — stage `st`'s fanout-f tree all-reduce starts the
//! moment its gradient drains from the pipeline, while earlier stages are
//! still back-propagating.
//!
//! RNG discipline (the parity contract with both 1D backends): per step
//! the shared [`DpCore`] RNG is consumed in exactly this order —
//! (1) one global Poisson draw, (2) gradient noise in replica-major,
//! stage-major, tensor order, (3) the private quantile release. With one
//! replica this is the [`PipelineEngine`] sequence verbatim; the noise
//! share each piece adds is `std_g / sqrt(R)`, so with one replica the
//! share IS the full per-stage std.
//!
//! [`DpCore`]: crate::session::DpCore

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::noise::add_noise;
use crate::coordinator::optimizer::OptimizerKind;
use crate::data::Dataset;
use crate::pipeline::schedule::stage_grad_ready;
use crate::pipeline::{PipelineEngine, PipelineMode, PipelineOpts};
use crate::runtime::{ConfigManifest, Runtime, Tensor};
use crate::session::core::DpCore;
use crate::shard::reduce::{tree_reduce, ReduceModel};
use crate::shard::sampler::ShardSampler;

/// How clipping-threshold groups tile the (replica, stage) grid (resolved
/// from `HybridSpec.grouping` by the session builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PieceGrouping {
    /// every (replica, stage) piece owns its own threshold (K = R x S) —
    /// the paper's per-device scheme on the full 2D grid
    PerPiece,
    /// one threshold per stage, shared across replicas (K = S)
    PerStage,
}

impl PieceGrouping {
    pub fn token(&self) -> &'static str {
        match self {
            PieceGrouping::PerPiece => "per-piece",
            PieceGrouping::PerStage => "per-stage",
        }
    }
}

/// Backend wiring computed by the session builder (crate-internal: like
/// the other engines, the hybrid backend has no public constructor).
pub(crate) struct HybridWiring {
    pub replicas: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub link_latency: f64,
    pub grouping: PieceGrouping,
    /// `PerDevice` (per-piece clipping) or `NonPrivate`
    pub mode: PipelineMode,
    pub n_micro: usize,
    /// global expected live batch E[B] (normalizes the merged update)
    pub expected_batch: usize,
    /// Poisson rate of the one global draw, q = E[B]/n
    pub rate: f64,
    pub total_steps: u64,
    pub n_data: usize,
    pub optimizer: OptimizerKind,
    pub lr: f64,
    pub seed: u64,
    /// echoed into each replica's `PipelineOpts`; like the per-device
    /// pipeline sim (whose `makespan` charges it only on the flat-sync
    /// regrad barrier), the hybrid makespans do NOT charge it — the
    /// cross-replica reduction's per-round cost is `link_latency`, and
    /// keeping the compute side identical is what makes the R = 1 sim
    /// equal the pipeline backend's
    pub sync_latency: f64,
    pub clip_init: f64,
    pub target_q: f64,
    pub quantile_eta: f64,
}

/// Per-step report of the hybrid backend.
#[derive(Debug, Clone)]
pub struct HybridStepStats {
    pub step: u64,
    pub loss: f64,
    /// live examples across all replicas this step
    pub batch_size: usize,
    /// fraction clipped per threshold group (empty for non-private runs)
    pub clip_frac: Vec<f64>,
    /// examples the global draw included but total capacity dropped
    pub truncated: usize,
    /// measured host seconds for the whole step
    pub host_secs: f64,
    /// simulated R x S step latency under the configured reduction
    pub sim_secs: f64,
    /// simulated latency with each stage's cross-replica reduction
    /// overlapped into the remaining backward pass
    pub sim_overlap_secs: f64,
    /// simulated latency with a reduce-after-backward barrier
    pub sim_barrier_secs: f64,
    /// depth of the cross-replica reduction tree, ceil(log_fanout R)
    pub syncs: usize,
    /// executable invocations across all replicas and stages
    pub calls: usize,
}

pub struct HybridEngine<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub cfg: ConfigManifest,
    /// the ONE shared DP state: plan, piece thresholds, noise, RNG
    pub core: DpCore,
    /// data-parallel replicas R
    pub replicas_n: usize,
    /// pipeline stages S (from the manifest)
    pub n_stages: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub total_steps: u64,
    pub step_count: u64,
    grouping: PieceGrouping,
    private: bool,
    n_micro: usize,
    replicas: Vec<PipelineEngine<'r>>,
    sampler: ShardSampler,
    /// global E[B] normalizing the merged update
    expected_batch: f64,
    /// trainable element count per stage (reduction payload sizing)
    stage_dims: Vec<f64>,
    reduce_model: ReduceModel,
}

impl<'r> HybridEngine<'r> {
    /// Crate-private constructor: all DP state arrives in `core` (K must
    /// match the resolved piece grouping), all schedule/topology decisions
    /// in `wiring`. Only `session::SessionBuilder` builds these.
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        w: HybridWiring,
        core: DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        let stages = cfg.stages.clone().ok_or_else(|| {
            anyhow!(
                "config {config_name} has no pipeline stages; the hybrid backend composes \
                 pipeline x data parallelism — use [shard] for pure data parallelism"
            )
        })?;
        let s = stages.stages.len();
        if w.replicas == 0 {
            return Err(anyhow!("hybrid backend needs replicas > 0"));
        }
        let private = w.mode == PipelineMode::PerDevice;
        if w.mode == PipelineMode::FlatSync {
            return Err(anyhow!(
                "the hybrid backend supports per-device clipping (or non-private); \
                 flat-sync is pipeline-only"
            ));
        }
        let expect_k = if private {
            match w.grouping {
                PieceGrouping::PerPiece => w.replicas * s,
                PieceGrouping::PerStage => s,
            }
        } else {
            1
        };
        if core.k() != expect_k {
            return Err(anyhow!(
                "DpCore has {} threshold groups but {} grouping over {} replicas x {} stages \
                 needs {}",
                core.k(),
                w.grouping.token(),
                w.replicas,
                s,
                expect_k
            ));
        }

        // R full pipeline replicas around inert shell cores: thresholds
        // reach them explicitly via collect_weighted, noise and RNG live
        // only in the hybrid's own core. One checkpoint read fans out to
        // every replica, so they start bit-identical.
        let ck = crate::runtime::checkpoint::read(
            runtime.manifest.hlo_path(&cfg.init_checkpoint),
        )?;
        let shell_k = if private { s } else { 1 };
        let mut replicas = Vec::with_capacity(w.replicas);
        for _ in 0..w.replicas {
            let opts = PipelineOpts {
                mode: w.mode,
                n_micro: w.n_micro,
                expected_batch: (w.expected_batch / w.replicas).max(1),
                clip: w.clip_init,
                sigma: 0.0,
                lr: w.lr,
                optimizer: w.optimizer,
                seed: w.seed,
                sync_latency: w.sync_latency,
                adaptive: false,
                target_q: w.target_q,
                quantile_eta: w.quantile_eta,
            };
            replicas.push(PipelineEngine::with_core_from_ck(
                runtime,
                config_name,
                opts,
                DpCore::shell(shell_k),
                &ck,
            )?);
        }
        let minibatch = replicas[0].minibatch();
        let stage_dims = replicas[0].stage_trainable_dims();

        Ok(HybridEngine {
            runtime,
            config_name: config_name.to_string(),
            core,
            replicas_n: w.replicas,
            n_stages: s,
            fanout: w.fanout,
            overlap: w.overlap,
            total_steps: w.total_steps,
            step_count: 0,
            grouping: w.grouping,
            private,
            n_micro: w.n_micro,
            sampler: ShardSampler::new(w.n_data, w.rate, w.replicas, minibatch),
            expected_batch: w.expected_batch as f64,
            stage_dims,
            reduce_model: ReduceModel::new(w.replicas, w.fanout, w.link_latency),
            replicas,
            cfg,
        })
    }

    pub fn grouping(&self) -> PieceGrouping {
        self.grouping
    }

    /// Static per-replica pipeline minibatch (microbatch x J).
    pub fn minibatch(&self) -> usize {
        self.replicas[0].minibatch()
    }

    /// Global static capacity: replicas x the per-replica minibatch.
    pub fn capacity(&self) -> usize {
        self.replicas_n * self.minibatch()
    }

    /// Current per-group clipping thresholds (R x S for per-piece
    /// grouping, S for per-stage).
    pub fn thresholds(&self) -> &[f64] {
        self.core.thresholds()
    }

    /// Threshold-group labels matching [`HybridEngine::thresholds`].
    pub fn group_labels(&self) -> Vec<String> {
        if !self.private {
            return vec!["flat".to_string()];
        }
        match self.grouping {
            PieceGrouping::PerPiece => (0..self.replicas_n)
                .flat_map(|r| (0..self.n_stages).map(move |st| format!("r{r}s{st}")))
                .collect(),
            PieceGrouping::PerStage => {
                (0..self.n_stages).map(|st| format!("stage{st}")).collect()
            }
        }
    }

    /// Group index of piece (replica `r`, stage `st`).
    fn group_of(&self, r: usize, st: usize) -> usize {
        if !self.private {
            return 0;
        }
        match self.grouping {
            PieceGrouping::PerPiece => r * self.n_stages + st,
            PieceGrouping::PerStage => st,
        }
    }

    /// All parameters of replica 0 as a name -> tensor map (the merged
    /// update keeps every replica bit-identical; see
    /// [`HybridEngine::replicas_in_sync`]).
    pub fn dump_params(&self) -> HashMap<String, Tensor> {
        self.replicas[0].dump_params()
    }

    /// Load parameters by name on EVERY replica; names absent from the
    /// map keep their init values (LoRA adapters).
    pub fn load_params(&mut self, map: &HashMap<String, Tensor>) -> Result<()> {
        for e in self.replicas.iter_mut() {
            e.load_params(map)?;
        }
        Ok(())
    }

    /// True when every replica's parameters are bitwise equal to replica
    /// 0's — the invariant the merged update maintains.
    pub fn replicas_in_sync(&self) -> bool {
        let p0 = self.replicas[0].dump_params();
        self.replicas.iter().skip(1).all(|e| {
            let p = e.dump_params();
            p.len() == p0.len()
                && p.iter().all(|(name, t)| {
                    p0.get(name)
                        .map(|t0| t0.shape == t.shape && t0.data == t.data)
                        .unwrap_or(false)
                })
        })
    }

    /// Topology line for `Session::describe` / the CLI.
    pub fn describe_topology(&self) -> String {
        let c: Vec<String> = self.core.thresholds().iter().map(|c| format!("{c:.4}")).collect();
        format!(
            "replicas={} stages={} fanout={} reduction={} grouping={} thresholds=[{}]",
            self.replicas_n,
            self.n_stages,
            self.fanout,
            if self.overlap { "overlapped" } else { "barrier" },
            self.grouping.token(),
            c.join(", ")
        )
    }

    /// One hybrid DP step: global Poisson draw dealt across replicas ->
    /// per-replica pipeline backward with per-piece clipping -> local
    /// noise shares sigma_g/sqrt(R) -> per-stage cross-replica
    /// tree-reduction -> one merged update broadcast to every replica ->
    /// private quantile release over all piece groups.
    pub fn step(&mut self, data: &dyn Dataset) -> Result<HybridStepStats> {
        let host_t0 = Instant::now();
        let r_n = self.replicas_n;
        let s = self.n_stages;
        let k = self.core.k();
        let batch = self.sampler.sample(&mut self.core.rng);
        let live_global = batch.live;
        let thr = self.core.thresholds().to_vec();

        let mut clip_counts = vec![0f64; k];
        let mut replica_lives = vec![0usize; r_n];
        let mut loss_wsum = 0f64;
        let mut weight_sum = 0f64;
        let mut calls = 0usize;
        let mut collected = Vec::with_capacity(r_n);
        for r in 0..r_n {
            let slice = &batch.slices[r];
            replica_lives[r] = slice.live();
            let piece_thr: Vec<f64> = if self.private {
                (0..s).map(|st| thr[self.group_of(r, st)]).collect()
            } else {
                vec![1e9; s]
            };
            let col =
                self.replicas[r].collect_weighted(data, &slice.indices, &slice.weights, &piece_thr)?;
            if self.private {
                for st in 0..s {
                    clip_counts[self.group_of(r, st)] += col.clip_counts[st];
                }
            }
            loss_wsum += col.loss_wsum;
            weight_sum += col.weight_sum;
            calls += col.calls;
            collected.push(col);
        }

        // -------- simulated R x S latency (overlap vs barrier) -----------
        // A real cluster runs the replicas concurrently, so the modeled
        // compute side is one representative replica (mean of the measured
        // per-op durations): per-stage gradient-ready times out of the
        // GPipe schedule, reductions queued FIFO in ready order.
        let mut ready_mean = vec![0f64; s];
        for col in &collected {
            let (ready, _span) =
                stage_grad_ready(s, self.n_micro, &|op| {
                    col.durations.get(op).copied().unwrap_or(0.0)
                });
            for (a, b) in ready_mean.iter_mut().zip(&ready) {
                *a += b / r_n as f64;
            }
        }
        let mut order: Vec<usize> = (0..s).collect();
        order.sort_by(|&a, &b| ready_mean[a].partial_cmp(&ready_mean[b]).unwrap());
        let ready_sorted: Vec<f64> = order.iter().map(|&st| ready_mean[st]).collect();
        let red_sorted: Vec<f64> = order
            .iter()
            .map(|&st| self.reduce_model.layer_cost(4.0 * self.stage_dims[st]))
            .collect();
        let sim_overlap = self.reduce_model.overlap_makespan_at(&ready_sorted, &red_sorted);
        let sim_barrier = self.reduce_model.barrier_makespan_at(&ready_sorted, &red_sorted);

        // -------- local noise shares, replica-major then stage-major ------
        // Piece (r, st) adds std_g / sqrt(R): the R independent shares
        // merge (variances add) to exactly the accountant's per-group std
        // on every stage's merged gradient. The iteration order is the RNG
        // discipline that makes R = 1 bitwise-identical to the pipeline
        // backend (its noise loop is stage-major in the same tensor order).
        let stds = if self.private { self.core.noise_stds() } else { vec![0.0; k] };
        let share = 1.0 / (r_n as f64).sqrt();
        for (r, col) in collected.iter_mut().enumerate() {
            for st in 0..s {
                let std = stds[self.group_of(r, st)] * share;
                for g in col.grads[st].iter_mut() {
                    add_noise(&mut g.data, std, &mut self.core.rng);
                }
            }
        }

        // -------- per-stage tree-reduction across replicas ----------------
        // Algorithm 1 line 14: normalize the merged sum by the global E[B]
        // (a 1-participant tree is the bitwise identity, so R = 1 keeps
        // the pipeline backend's exact float sequence: noise, /E[B], apply)
        let mut parts_by_stage: Vec<Vec<Vec<Tensor>>> =
            (0..s).map(|_| Vec::with_capacity(r_n)).collect();
        for col in collected {
            for (st, g) in col.grads.into_iter().enumerate() {
                parts_by_stage[st].push(g);
            }
        }
        let expected = self.expected_batch;
        let mut merged: Vec<Vec<Tensor>> = Vec::with_capacity(s);
        for parts in parts_by_stage {
            let mut m = tree_reduce(parts, self.fanout);
            for t in m.iter_mut() {
                for v in t.data.iter_mut() {
                    *v /= expected as f32;
                }
            }
            merged.push(m);
        }

        // one merged update applied to every replica (identical optimizer
        // states + identical grads keep the replicas bit-identical)
        for e in self.replicas.iter_mut() {
            e.apply_update(&merged);
        }

        // private quantile release over all R x S piece groups at once
        if self.private && self.core.is_adaptive() {
            self.core.update_thresholds(&clip_counts);
        }

        self.step_count += 1;
        let clip_frac: Vec<f64> = if self.private {
            (0..k)
                .map(|g| {
                    let denom = match self.grouping {
                        PieceGrouping::PerPiece => replica_lives[g / s],
                        PieceGrouping::PerStage => live_global,
                    }
                    .max(1) as f64;
                    1.0 - clip_counts[g] / denom
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(HybridStepStats {
            step: self.step_count,
            loss: loss_wsum / weight_sum.max(1.0),
            batch_size: live_global,
            clip_frac,
            truncated: batch.truncated,
            host_secs: host_t0.elapsed().as_secs_f64(),
            sim_secs: if self.overlap { sim_overlap } else { sim_barrier },
            sim_overlap_secs: sim_overlap,
            sim_barrier_secs: sim_barrier,
            syncs: self.reduce_model.rounds(),
            calls,
        })
    }

    /// Mean eval loss over `data` through replica 0's pipeline.
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<f64> {
        self.replicas[0].evaluate(data)
    }
}
