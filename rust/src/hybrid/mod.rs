//! Hybrid 2D-parallel DP training backend — pipeline stages x
//! data-parallel replicas, the paper's per-device clipping scheme on the
//! full (replica, stage) grid. This is the composition the headline
//! GPT-3 result implies: the model is partitioned into S pipeline stages
//! AND replicated R ways, and every one of the R x S *pieces* clips its
//! local per-example gradient piece against its own threshold on its own
//! host device.
//!
//! Each simulated replica owns a full S-stage pipeline (the
//! [`PipelineEngine`](crate::pipeline::PipelineEngine) machinery,
//! composed through its crate-private `collect_weighted` seam) and a
//! **disjoint slice of one global Poisson draw**: the engine samples once
//! at rate `q = E[B]/n` through the generalized
//! [`ShardSampler`](crate::shard::ShardSampler), deals the live examples
//! round-robin across replicas, and pads every slice to the static
//! pipeline minibatch. Replica `r` then
//!
//! 1. runs its GPipe forward/backward wavefront, clipping each
//!    per-example gradient piece on stage `st` against its threshold
//!    group `C_(r,st)` (per-piece grouping; per-stage grouping shares
//!    `C_st` across replicas),
//! 2. adds its **share** of the Gaussian noise locally — std
//!    `sigma_g / sqrt(R)` per group, so the merged sum carries exactly
//!    the per-group std the accountant calibrated (variances add across
//!    the R independent shares),
//! 3. feeds each stage's summed gradient into a **fanout-f cross-replica
//!    tree-reduction that overlaps the pipeline's own backward**: stage
//!    `st`'s reduction rounds start the moment its gradient drains from
//!    the schedule, while earlier stages are still back-propagating —
//!    the paper's clip-in-conjunction-with-backprop overlap lifted to
//!    the 2D grid (`ReduceModel::overlap_makespan_at` over
//!    `schedule::stage_grad_ready` times).
//!
//! **Sensitivity.** Every example lands on exactly one replica `r`; its
//! gradient spans that replica's S stage pieces, each clipped to
//! `C_(r,st)`, so removing one example moves the merged update by at most
//! `sqrt(sum_st C_(r,st)^2) <= sqrt(sum_(r,st) C_(r,st)^2)` — the
//! quadrature sum over the WHOLE R x S threshold grid (property-tested in
//! `prop_hybrid_2d_quadrature_bound_and_noise_shares`). The shared
//! [`DpCore`](crate::session::DpCore) therefore sees **one release per
//! step at `q = E[B]/n`, independent of both R and S**; the grid changes
//! wall-clock structure, never the privacy analysis.
//!
//! **Degeneracies** (the parity contracts pinned by integration tests):
//! with R = 1 the engine is the pipeline backend seed-for-seed (identity
//! tree, full noise share, same RNG order); a `[hybrid]` section on a
//! stage-less config routes to the sharded backend (the grid has no
//! pipeline axis), bit-identical to the same run spelled `[shard]`.
//!
//! Construction goes through `session::SessionBuilder` only (add a
//! `[hybrid]` section to the spec, or `.hybrid(HybridSpec::..)`); there
//! is no raw-sigma entry point.

pub mod engine;

pub use engine::{HybridEngine, PieceGrouping};
