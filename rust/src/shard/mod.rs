//! Sharded data-parallel DP training backend — the paper's per-device
//! clipping scheme instantiated over N model *replicas* instead of N
//! pipeline stages.
//!
//! Each simulated worker owns a full copy of the model and a **disjoint
//! slice of one global Poisson draw**: the session samples once at rate
//! `q = E[B]/n`, deals the live examples round-robin across workers, and
//! pads every slice to the compiled static batch. Worker `w` then
//!
//! 1. runs the same fused backprop+clip executable as the single-device
//!    backend on its slice, clipping each local per-example gradient
//!    against its threshold group (worker-owned `C_w` for per-device
//!    grouping, shared `C` / per-layer `C_g` otherwise),
//! 2. adds its **share** of the Gaussian noise locally — std
//!    `sigma_g / sqrt(N)` per group, so the merged sum carries exactly the
//!    noise the accountant calibrated (variances add across workers),
//! 3. feeds its summed gradient into an **overlapped tree-reduction**:
//!    layer L's reduction rounds proceed while layer L-1 is still
//!    back-propagating (the paper's clip-in-conjunction-with-backprop
//!    overlap, transplanted to the all-reduce), modeled by
//!    [`reduce::ReduceModel`] next to a barrier baseline.
//!
//! Because every example lands on exactly one worker and worker `w` clips
//! it to `C_w`, one example moves the merged update by at most `C_w <=
//! sqrt(sum_k C_k^2)` — the per-device bound summed in quadrature across
//! threshold groups (see `docs/SESSION_API.md`). The shared [`DpCore`]
//! therefore sees **one release per step at `q = E[B]/n`**, independent of
//! the worker count, and a 1-worker sharded run is seed-for-seed identical
//! to the single-device backend (same RNG discipline: one Poisson draw,
//! then per-tensor noise, then the quantile release).
//!
//! Construction goes through `session::SessionBuilder` only (add a
//! `[shard]` section to the spec, or `.shard(ShardSpec::..)`); there is no
//! raw-sigma entry point.
//!
//! [`DpCore`]: crate::session::DpCore

pub mod compress;
pub mod engine;
pub mod reduce;
pub mod sampler;

pub use compress::{CompressKind, Compressor};
pub use engine::{ShardEngine, WorkerGrouping};
pub use reduce::{quadrature_bound, tree_reduce, tree_rounds, ReduceModel};
pub use sampler::{ShardBatch, ShardSampler, WorkerSlice};
