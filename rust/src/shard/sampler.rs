//! Global-Poisson-draw sharding: one subsampled batch per step, dealt into
//! disjoint per-worker slices.
//!
//! DP accounting sees the *union* of the slices — a single Poisson release
//! at rate `q = E[B]/n` — so the draw must happen once, globally, before
//! any worker-local decision. Dealing is round-robin over the live draw
//! order; each slice is padded to the worker's static batch with index-0,
//! weight-0 slots exactly like [`PoissonSampler::sample_padded`], so the
//! compiled executables consume slices directly.
//!
//! With one worker this degenerates — by construction, not by accident —
//! to the single-device sampler: the inner [`PoissonSampler`] has the same
//! capacity and consumes the shared RNG identically, which is what makes
//! the 1-worker sharded backend seed-for-seed equal to the single-device
//! backend.

use crate::coordinator::noise::Rng;
use crate::coordinator::sampler::PoissonSampler;

/// One worker's view of a step: fixed-capacity padded indices + 0/1 mask.
#[derive(Debug, Clone)]
pub struct WorkerSlice {
    /// dataset indices, length == the worker's static batch (padded with 0)
    pub indices: Vec<usize>,
    /// 1.0 for live examples, 0.0 for padding; live slots form a prefix
    pub weights: Vec<f32>,
}

impl WorkerSlice {
    /// Number of live (weight 1) examples on this worker.
    pub fn live(&self) -> usize {
        self.weights.iter().filter(|&&w| w > 0.0).count()
    }
}

/// A dealt global Poisson draw.
#[derive(Debug, Clone)]
pub struct ShardBatch {
    /// one slice per worker, each padded to the per-worker capacity
    pub slices: Vec<WorkerSlice>,
    /// total live examples across all workers
    pub live: usize,
    /// examples the global draw included but total capacity dropped
    pub truncated: usize,
}

/// Poisson subsampler over `n` examples, dealt across `workers` slices of
/// `per_worker` capacity each.
pub struct ShardSampler {
    inner: PoissonSampler,
    pub workers: usize,
    pub per_worker: usize,
}

impl ShardSampler {
    pub fn new(n: usize, rate: f64, workers: usize, per_worker: usize) -> Self {
        assert!(workers > 0 && per_worker > 0);
        ShardSampler {
            inner: PoissonSampler::new(n, rate, workers * per_worker),
            workers,
            per_worker,
        }
    }

    /// Draw one global Poisson batch and deal it round-robin: live example
    /// `j` lands on worker `j % workers`. Round-robin can never overflow a
    /// slice (`live <= workers * per_worker` implies `ceil(live/workers)
    /// <= per_worker`), so per-worker capacity binds only through the
    /// global truncation already recorded by the inner sampler.
    pub fn sample(&self, rng: &mut Rng) -> ShardBatch {
        let base = self.inner.sample(rng);
        let live = base.indices.len();
        let mut slices: Vec<WorkerSlice> = (0..self.workers)
            .map(|_| WorkerSlice {
                indices: Vec::with_capacity(self.per_worker),
                weights: Vec::with_capacity(self.per_worker),
            })
            .collect();
        for (j, &idx) in base.indices.iter().enumerate() {
            let s = &mut slices[j % self.workers];
            s.indices.push(idx);
            s.weights.push(1.0);
        }
        for s in slices.iter_mut() {
            debug_assert!(s.indices.len() <= self.per_worker);
            s.indices.resize(self.per_worker, 0);
            s.weights.resize(self.per_worker, 0.0);
        }
        ShardBatch { slices, live, truncated: base.truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_worker_matches_single_device_sampler_seed_for_seed() {
        // same (n, rate, capacity): the dealt slice must be byte-identical
        // to sample_padded AND leave the RNG in the same state
        let (n, rate, cap) = (500usize, 0.06, 64usize);
        let mut r1 = Rng::seeded(42);
        let mut r2 = Rng::seeded(42);
        let shard = ShardSampler::new(n, rate, 1, cap);
        let single = PoissonSampler::new(n, rate, cap);
        for _ in 0..50 {
            let a = shard.sample(&mut r1);
            let b = single.sample_padded(&mut r2);
            assert_eq!(a.slices[0].indices, b.indices);
            assert_eq!(a.slices[0].weights, b.weights);
            assert_eq!(a.truncated, b.truncated);
            assert_eq!(a.live, b.live());
        }
        // RNG streams still aligned after many draws — compare the full
        // observable position: a uniform() sample is blind to a buffered
        // Marsaglia spare, a StreamPos is not
        assert_eq!(r1.stream_pos(), r2.stream_pos());
    }

    #[test]
    fn deal_is_disjoint_and_complete() {
        let s = ShardSampler::new(1000, 0.2, 4, 64);
        let mut rng = Rng::seeded(7);
        for _ in 0..20 {
            let b = s.sample(&mut rng);
            let mut seen = std::collections::HashSet::new();
            let mut total_live = 0usize;
            for slice in &b.slices {
                assert_eq!(slice.indices.len(), 64);
                assert_eq!(slice.weights.len(), 64);
                let live = slice.live();
                total_live += live;
                for (i, &w) in slice.weights.iter().enumerate() {
                    // live prefix, padded suffix
                    assert_eq!(w > 0.0, i < live);
                    if w > 0.0 {
                        assert!(seen.insert(slice.indices[i]), "example dealt twice");
                    } else {
                        assert_eq!(slice.indices[i], 0);
                    }
                }
            }
            assert_eq!(total_live, b.live);
        }
    }

    #[test]
    fn deal_balances_within_one() {
        let s = ShardSampler::new(2000, 0.1, 4, 64);
        let mut rng = Rng::seeded(9);
        let b = s.sample(&mut rng);
        let lives: Vec<usize> = b.slices.iter().map(|s| s.live()).collect();
        let (min, max) = (lives.iter().min().unwrap(), lives.iter().max().unwrap());
        assert!(max - min <= 1, "round-robin deal must balance: {lives:?}");
    }

    #[test]
    fn truncation_fills_every_slice() {
        // rate 1 over n >> capacity: every slice must be exactly full and
        // the overflow recorded once, globally
        let s = ShardSampler::new(100, 1.0, 2, 10);
        let mut rng = Rng::seeded(3);
        let b = s.sample(&mut rng);
        assert_eq!(b.truncated, 80);
        assert_eq!(b.live, 20);
        for slice in &b.slices {
            assert_eq!(slice.live(), 10);
        }
    }
}
