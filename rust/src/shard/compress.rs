//! Gradient compression on the cross-replica reduction path — the first
//! cross-backend plug-in riding the shared `StepLoop` merge seam.
//!
//! Each data-parallel unit (sharded worker / hybrid replica) sparsifies
//! its gradient contribution to **top-k** or **random-k** entries per
//! tensor before it enters `tree_reduce`, shrinking the bytes every
//! reduction round moves by the keep ratio. Dropped mass is carried in a
//! local **error-feedback residual** (Stich et al., "Sparsified SGD with
//! memory"): next step the residual is added back before selection, so
//! over time every coordinate's contribution is delivered — the property
//! test pins `sent + residual == input + previous residual` exactly.
//!
//! **Why this is DP-safe.** Compression runs strictly AFTER the
//! `StepLoop` noise phase: what a unit sparsifies is its already-noised
//! local share `clip(grads) + N(0, (sigma_g/sqrt(U))^2)`, i.e. a quantity
//! whose release the accountant already paid for. Selecting/zeroing
//! coordinates of a released quantity is post-processing, which cannot
//! weaken a DP guarantee; the residual never leaves the unit (it is
//! carried locally and re-enters only that unit's own next share), so no
//! unreleased function of the raw data ever crosses the reduction seam.
//! The accountant's (q, sigma, steps) are untouched by the ratio.
//!
//! Determinism: random-k draws from a dedicated [`Xoshiro`] stream seeded
//! from the run seed — never from the shared `DpCore` RNG — so enabling
//! compression cannot shift the Poisson/noise/quantile streams that the
//! backend parity pins rely on.

use std::str::FromStr;

use anyhow::{bail, Result};

use crate::kernels::Kernels;
use crate::runtime::Tensor;
use crate::util::rng::Xoshiro;

/// Selection rule for the kept coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressKind {
    /// keep the k largest-magnitude entries per tensor (deterministic,
    /// ties broken by index)
    TopK,
    /// keep k uniformly drawn entries per tensor (cheaper selection, the
    /// classic unbiased-sketch baseline; deterministic per run seed)
    RandK,
}

impl CompressKind {
    /// Canonical spec/CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            CompressKind::TopK => "topk",
            CompressKind::RandK => "randk",
        }
    }
}

impl FromStr for CompressKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "topk" | "top-k" | "top_k" => CompressKind::TopK,
            "randk" | "rand-k" | "rand_k" | "randomk" => CompressKind::RandK,
            _ => bail!("unknown compress kind '{s}' (topk|randk)"),
        })
    }
}

/// Per-unit error-feedback sparsifier applied inside the reduction seam.
pub struct Compressor {
    kind: CompressKind,
    /// keep ratio k/d in (0, 1]; 1.0 is the bitwise identity
    ratio: f64,
    error_feedback: bool,
    /// residuals[unit][tensor] — dropped mass carried locally
    residuals: Vec<Vec<Tensor>>,
    /// dedicated selection stream (random-k); NEVER the DpCore RNG
    rng: Xoshiro,
    /// dispatched vtable for the error-feedback add (bit-exact kernel)
    kernels: Kernels,
}

impl Compressor {
    /// `units` = number of data-parallel participants whose residual
    /// state is tracked independently. The RNG is derived from the run
    /// seed through a fixed tweak so it cannot collide with the DpCore
    /// stream seeded from the same value.
    pub fn new(
        kind: CompressKind,
        ratio: f64,
        error_feedback: bool,
        units: usize,
        seed: u64,
    ) -> Self {
        Compressor {
            kind,
            ratio,
            error_feedback,
            residuals: vec![Vec::new(); units],
            rng: Xoshiro::seeded(seed ^ 0x9E37_79B9_7F4A_7C15),
            kernels: Kernels::default(),
        }
    }

    /// Install the session's dispatched kernel vtable (the EF add is a
    /// bit-exact elementwise kernel, so this never changes selection).
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
    }

    pub fn kind(&self) -> CompressKind {
        self.kind
    }

    /// Keep ratio in (0, 1].
    pub fn ratio(&self) -> f64 {
        self.ratio
    }

    /// Kept entries for a tensor of `len` elements: ceil(ratio * len),
    /// clamped to [1, len].
    pub fn keep(&self, len: usize) -> usize {
        ((self.ratio * len as f64).ceil() as usize).clamp(1, len)
    }

    /// One-line spec echo for `Session::describe` / the CLI.
    pub fn describe(&self) -> String {
        format!(
            "{}:{:.3}{}",
            self.kind.token(),
            self.ratio,
            if self.error_feedback { "+ef" } else { "" }
        )
    }

    /// Sparsify `tensors` (unit `unit`'s noised share) in place: add the
    /// carried residual, keep the selected entries, zero the rest, store
    /// the dropped mass as the new residual. `ratio >= 1` is a bitwise
    /// no-op (nothing dropped, residual stays zero), which the k = 100%
    /// identity property pins.
    pub fn compress_unit(&mut self, unit: usize, tensors: &mut [Tensor]) {
        if self.ratio >= 1.0 {
            return;
        }
        let res = &mut self.residuals[unit];
        if res.len() != tensors.len() {
            *res = tensors.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        }
        for (t, r) in tensors.iter_mut().zip(res.iter_mut()) {
            let n = t.data.len();
            if n == 0 {
                continue;
            }
            if self.error_feedback {
                self.kernels.add_assign(&mut t.data, &r.data);
            }
            let k = self.keep(n);
            let kept = match self.kind {
                CompressKind::TopK => top_k_indices(&t.data, k),
                CompressKind::RandK => rand_k_indices(n, k, &mut self.rng),
            };
            let mut keep_mask = vec![false; n];
            for &i in &kept {
                keep_mask[i] = true;
            }
            for i in 0..n {
                if keep_mask[i] {
                    r.data[i] = 0.0;
                } else {
                    // the dropped (error-feedback-corrected) mass is the
                    // residual; without EF it is simply discarded
                    r.data[i] = if self.error_feedback { t.data[i] } else { 0.0 };
                    t.data[i] = 0.0;
                }
            }
        }
    }

    /// Unit `unit`'s current residual tensors (empty until first use).
    pub fn residual(&self, unit: usize) -> &[Tensor] {
        &self.residuals[unit]
    }

    /// All per-unit residuals, unit order (snapshot capture). Residuals
    /// DIFFER across units — error feedback is unit-local — so every
    /// unit's state must be persisted, not one fanned out.
    pub fn residuals(&self) -> &[Vec<Tensor>] {
        &self.residuals
    }

    /// Restore per-unit residuals captured via [`Compressor::residuals`].
    /// The unit count must match the configured participant count; a
    /// snapshot from a different topology is rejected, never silently
    /// mis-restored. (The selection RNG is reseeded from the spec on
    /// rebuild for rand-k; top-k is selection-stateless.)
    pub fn restore_residuals(&mut self, residuals: Vec<Vec<Tensor>>) -> anyhow::Result<()> {
        anyhow::ensure!(
            residuals.len() == self.residuals.len(),
            "compressor restore: {} residual units, expected {}",
            residuals.len(),
            self.residuals.len()
        );
        self.residuals = residuals;
        Ok(())
    }

    /// Selection-stream position (rand-k consumes it; top-k never does).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the selection stream (see [`Compressor::rng_state`]).
    pub fn restore_rng(&mut self, state: [u64; 4]) {
        self.rng = Xoshiro::from_state(state);
    }
}

/// Indices of the `k` largest-|v| entries, ties broken by lower index —
/// fully deterministic (the comparator is a total order via `total_cmp`,
/// so NaN/inf inputs cannot panic the selection). A linear-time
/// partition, not a sort: this runs per tensor per unit on the step hot
/// path, and only the kept SET matters (the caller builds a mask).
fn top_k_indices(v: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            v[b].abs().total_cmp(&v[a].abs()).then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx
}

/// `k` distinct uniform indices out of `n` via a partial Fisher-Yates
/// over a scratch permutation.
fn rand_k_indices(n: usize, k: usize, rng: &mut Xoshiro) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    for i in 0..k.min(n) {
        let j = i + rng.below(n - i);
        perm.swap(i, j);
    }
    perm.truncate(k);
    perm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[v.len()], v).unwrap()
    }

    #[test]
    fn full_ratio_is_bitwise_identity() {
        let mut c = Compressor::new(CompressKind::TopK, 1.0, true, 2, 9);
        let orig = vec![t(vec![0.5, -0.25, 1.5e-8, 3.0]), t(vec![-0.0, 7.0])];
        let mut x = orig.clone();
        for step in 0..3 {
            c.compress_unit(0, &mut x);
            for (a, b) in x.iter().zip(&orig) {
                assert_eq!(a.data, b.data, "step {step}: ratio 1.0 must be untouched");
            }
        }
    }

    #[test]
    fn top_k_keeps_largest_magnitudes() {
        let mut c = Compressor::new(CompressKind::TopK, 0.5, false, 1, 0);
        let mut x = vec![t(vec![0.1, -5.0, 0.2, 4.0, -0.3, 0.05])];
        c.compress_unit(0, &mut x);
        assert_eq!(x[0].data, vec![0.0, -5.0, 0.0, 4.0, -0.3, 0.0]);
    }

    #[test]
    fn error_feedback_partitions_exactly() {
        // per step: sent + residual == input + previous residual, exactly
        // (the kept/dropped split partitions the corrected vector)
        let mut c = Compressor::new(CompressKind::TopK, 0.34, true, 1, 0);
        let mut prev_res = vec![0.0f32; 6];
        for step in 0..5 {
            let input: Vec<f32> =
                (0..6).map(|i| ((i + 1) as f32) * 0.1 * ((step + 1) as f32) * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
            let corrected: Vec<f32> =
                input.iter().zip(&prev_res).map(|(a, b)| a + b).collect();
            let mut x = vec![t(input)];
            c.compress_unit(0, &mut x);
            let res = &c.residual(0)[0].data;
            for i in 0..6 {
                assert_eq!(
                    x[0].data[i] + res[i],
                    corrected[i],
                    "step {step} slot {i}: sent+residual must equal corrected input"
                );
                assert!(
                    x[0].data[i] == 0.0 || res[i] == 0.0,
                    "kept/dropped must partition"
                );
            }
            prev_res = res.clone();
        }
    }

    #[test]
    fn rand_k_is_seed_deterministic_and_k_sized() {
        let pick = |seed| {
            let mut c = Compressor::new(CompressKind::RandK, 0.5, false, 1, seed);
            let mut x = vec![t((0..10).map(|i| i as f32 + 1.0).collect())];
            c.compress_unit(0, &mut x);
            x[0].data.clone()
        };
        let a = pick(4);
        let b = pick(4);
        assert_eq!(a, b, "same seed, same selection");
        assert_eq!(a.iter().filter(|&&v| v != 0.0).count(), 5, "keeps exactly k");
        let c = pick(5);
        assert_ne!(a, c, "different seed should (overwhelmingly) differ");
    }

    #[test]
    fn keep_clamps_to_at_least_one() {
        let c = Compressor::new(CompressKind::TopK, 0.01, false, 1, 0);
        assert_eq!(c.keep(3), 1);
        assert_eq!(c.keep(1000), 10);
    }
}
