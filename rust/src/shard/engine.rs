//! `ShardEngine` — N data-parallel workers, each a full model replica over
//! a disjoint slice of one global Poisson draw, clipping per-device and
//! noising locally before an overlapped tree-reduction merges the deltas.
//!
//! Execution is sequential on the host (the PJRT CPU client already uses
//! every core per executable call), but each worker's executable call is
//! timed and fed to [`ReduceModel`], which replays what an N-worker
//! cluster would see: per-layer backward completion times against tree
//! all-reduce rounds, overlapped or behind a barrier.
//!
//! RNG discipline (the parity contract with the single-device backend):
//! per step the shared [`DpCore`] RNG is consumed in exactly this order —
//! (1) one global Poisson draw, (2) per-trainable-tensor gradient noise in
//! worker-major order, (3) the private quantile release. With one worker
//! this is the [`Trainer`](crate::coordinator::Trainer) sequence verbatim.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::noise::add_noise;
use crate::coordinator::optimizer::{Optimizer, OptimizerKind};
use crate::data::Dataset;
use crate::runtime::{ConfigManifest, Exec, HostValue, Runtime, Tensor};
use crate::session::core::DpCore;

use super::reduce::{tree_reduce, ReduceModel};
use super::sampler::ShardSampler;

/// How clipping-threshold groups map onto the worker topology (resolved
/// from `ShardSpec.grouping` x `ClipPolicy.group_by` by the session
/// builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerGrouping {
    /// one global threshold shared by every worker (K = 1)
    Flat,
    /// per-layer groups shared across workers (K = layer groups)
    PerLayer,
    /// the paper's per-device scheme: worker k owns threshold C_k and
    /// clips its local per-example gradients against it (K = workers)
    PerDevice,
}

impl WorkerGrouping {
    pub fn token(&self) -> &'static str {
        match self {
            WorkerGrouping::Flat => "flat",
            WorkerGrouping::PerLayer => "per-layer",
            WorkerGrouping::PerDevice => "per-device",
        }
    }
}

/// Backend wiring computed by the session builder (crate-internal: the
/// sharded backend has no public constructor surface, unlike the retired
/// `Trainer::new` / `PipelineEngine::new` shims).
pub(crate) struct ShardWiring {
    pub workers: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub link_latency: f64,
    pub grouping: WorkerGrouping,
    /// step-executable entry name, resolved by the builder from the clip
    /// policy ("nonprivate" / "dp_flat" / "dp_ghost" / "dp_naive" /
    /// "dp_perlayer")
    pub entry: &'static str,
    pub private: bool,
    /// Poisson rate of the one global draw, q = E[B]/n
    pub rate: f64,
    /// global expected live batch E[B] (normalizes the merged update)
    pub expected_batch: usize,
    pub total_steps: u64,
    pub n_data: usize,
    pub optimizer: OptimizerKind,
    pub lr: f64,
    pub weight_decay: f64,
    pub lr_decay: bool,
}

struct Replica {
    params: Vec<Tensor>,
    optimizer: Optimizer,
}

/// Per-step report of the sharded backend.
#[derive(Debug, Clone)]
pub struct ShardStepStats {
    pub step: u64,
    pub loss: f64,
    /// live examples across all workers this step
    pub batch_size: usize,
    /// fraction clipped per threshold group
    pub clip_frac: Vec<f64>,
    /// mean per-example norm per threshold group
    pub mean_norms: Vec<f64>,
    /// examples the global draw included but total capacity dropped
    pub truncated: usize,
    /// measured host seconds for the whole step
    pub host_secs: f64,
    /// simulated N-worker step latency under the configured reduction
    pub sim_secs: f64,
    /// simulated latency with the reduction overlapped into backprop
    pub sim_overlap_secs: f64,
    /// simulated latency with a reduce-after-backward barrier
    pub sim_barrier_secs: f64,
    /// depth of the reduction tree, ceil(log_fanout(workers)) — the
    /// rounds EACH layer's all-reduce traverses (layers pipeline through
    /// the same tree, so this is the latency-relevant count, not the
    /// total message count, which is ~depth x trainable tensors)
    pub syncs: usize,
    /// executable invocations (one per worker)
    pub calls: usize,
}

pub struct ShardEngine<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub cfg: ConfigManifest,
    /// shared DP state: plan, thresholds, noise allocation, RNG
    pub core: DpCore,
    pub workers: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub total_steps: u64,
    pub step_count: u64,
    grouping: WorkerGrouping,
    private: bool,
    exec: Arc<Exec>,
    eval_exec: Arc<Exec>,
    replicas: Vec<Replica>,
    sampler: ShardSampler,
    expected_batch: f64,
    trainable_idx: Vec<usize>,
    group_of_trainable: Vec<usize>,
    reduce_model: ReduceModel,
}

impl<'r> ShardEngine<'r> {
    /// Crate-private constructor: all DP state arrives in `core` (K must
    /// match the resolved grouping), all schedule/topology decisions in
    /// `wiring`. Only `session::SessionBuilder` builds these.
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        w: ShardWiring,
        core: DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        if cfg.stages.is_some() {
            return Err(anyhow!(
                "config {config_name} has pipeline stages; the sharded backend replicates \
                 a stage-less model"
            ));
        }
        if w.workers == 0 {
            return Err(anyhow!("sharded backend needs workers > 0"));
        }
        let expect_k = match w.grouping {
            WorkerGrouping::Flat => 1,
            WorkerGrouping::PerLayer => cfg.groups.len().max(1),
            WorkerGrouping::PerDevice => w.workers,
        };
        if w.private && core.k() != expect_k {
            return Err(anyhow!(
                "DpCore has {} threshold groups but {} grouping over {} workers needs {}",
                core.k(),
                w.grouping.token(),
                w.workers,
                expect_k
            ));
        }
        let exec = runtime.load(config_name, w.entry)?;
        let eval_exec = runtime.load(config_name, "eval")?;

        let (trainable_idx, group_of_trainable, schedule) =
            crate::coordinator::trainer::replica_wiring(&cfg, w.lr, w.lr_decay, w.total_steps);
        // one checkpoint read fanned out to N bit-identical replicas; each
        // replica carries its own optimizer state (kept identical by the
        // merged update) to model real data-parallel redundancy
        let replicas: Vec<Replica> = runtime
            .init_replicas(config_name, w.workers)?
            .into_iter()
            .map(|params| {
                let tr: Vec<Tensor> = trainable_idx.iter().map(|&i| params[i].clone()).collect();
                Replica {
                    optimizer: Optimizer::new(w.optimizer, schedule, w.weight_decay, &tr),
                    params,
                }
            })
            .collect();

        Ok(ShardEngine {
            runtime,
            config_name: config_name.to_string(),
            core,
            workers: w.workers,
            fanout: w.fanout,
            overlap: w.overlap,
            total_steps: w.total_steps,
            step_count: 0,
            grouping: w.grouping,
            private: w.private,
            exec,
            eval_exec,
            replicas,
            sampler: ShardSampler::new(w.n_data, w.rate, w.workers, cfg.batch),
            expected_batch: w.expected_batch as f64,
            trainable_idx,
            group_of_trainable,
            reduce_model: ReduceModel::new(w.workers, w.fanout, w.link_latency),
            cfg,
        })
    }

    pub fn grouping(&self) -> WorkerGrouping {
        self.grouping
    }

    /// Global static capacity: workers x the per-worker compiled batch.
    pub fn capacity(&self) -> usize {
        self.workers * self.cfg.batch
    }

    /// Current per-group clipping thresholds (one per worker for
    /// per-device grouping).
    pub fn thresholds(&self) -> &[f64] {
        self.core.thresholds()
    }

    /// Threshold-group labels matching [`ShardEngine::thresholds`].
    pub fn group_labels(&self) -> Vec<String> {
        match self.grouping {
            WorkerGrouping::Flat => vec!["flat".to_string()],
            WorkerGrouping::PerLayer => self.cfg.groups.clone(),
            WorkerGrouping::PerDevice => {
                (0..self.workers).map(|w| format!("worker{w}")).collect()
            }
        }
    }

    /// Worker-0's full-model parameters in manifest order (all replicas
    /// stay bit-identical; see [`ShardEngine::replicas_in_sync`]).
    pub fn params(&self) -> &[Tensor] {
        &self.replicas[0].params
    }

    /// Broadcast a full parameter set to every replica (checkpoint
    /// fan-out).
    pub fn set_params_all(&mut self, params: Vec<Tensor>) -> Result<()> {
        if params.len() != self.cfg.params.len() {
            return Err(anyhow!("param count mismatch"));
        }
        for r in self.replicas.iter_mut() {
            r.params = params.clone();
        }
        Ok(())
    }

    /// Load parameters by name; names absent from the map keep their init
    /// values. The result is fanned out to every replica.
    pub fn load_param_map(
        &mut self,
        map: &std::collections::HashMap<String, Tensor>,
    ) -> Result<()> {
        let mut params = self.replicas[0].params.clone();
        for (i, p) in self.cfg.params.iter().enumerate() {
            if let Some(v) = map.get(&p.name) {
                if v.shape != p.shape {
                    return Err(anyhow!("shape mismatch for {}", p.name));
                }
                params[i] = v.clone();
            }
        }
        self.set_params_all(params)
    }

    /// True when every replica's parameters are bitwise equal to
    /// worker 0's — the invariant the merged update maintains.
    pub fn replicas_in_sync(&self) -> bool {
        let r0 = &self.replicas[0].params;
        self.replicas.iter().skip(1).all(|r| {
            r.params
                .iter()
                .zip(r0)
                .all(|(a, b)| a.shape == b.shape && a.data == b.data)
        })
    }

    /// Topology line for `Session::describe` / the CLI: worker count,
    /// reduction fanout, overlap flag and the per-group thresholds.
    pub fn describe_topology(&self) -> String {
        let c: Vec<String> =
            self.core.thresholds().iter().map(|c| format!("{c:.4}")).collect();
        format!(
            "workers={} fanout={} reduction={} grouping={} thresholds=[{}]",
            self.workers,
            self.fanout,
            if self.overlap { "overlapped" } else { "barrier" },
            self.grouping.token(),
            c.join(", ")
        )
    }

    /// Threshold worker `w` clips against.
    fn worker_threshold(&self, w: usize) -> f64 {
        match self.grouping {
            WorkerGrouping::PerDevice => self.core.thresholds()[w],
            _ => self.core.thresholds()[0],
        }
    }

    /// One sharded DP step: global Poisson draw -> per-worker fused
    /// backprop+clip -> local noise shares -> tree-reduction -> one merged
    /// update broadcast to every replica -> private quantile release.
    pub fn step(&mut self, data: &dyn Dataset) -> Result<ShardStepStats> {
        let host_t0 = Instant::now();
        let batch = self.sampler.sample(&mut self.core.rng);
        let live_global = batch.live;
        let k = self.core.k();
        let n_tr = self.trainable_idx.len();
        let noise_share = 1.0 / (self.workers as f64).sqrt();
        let stds = if self.private { self.core.noise_stds() } else { vec![0.0; k] };

        let mut clip_counts = vec![0f64; k];
        let mut mean_norms = vec![0f64; k];
        let mut worker_lives = vec![0usize; self.workers];
        let mut worker_grads: Vec<Vec<Tensor>> = Vec::with_capacity(self.workers);
        let mut loss_wsum = 0f64;
        let mut loss_plain = 0f64;
        let mut bwd_secs = vec![0f64; self.workers];

        for w in 0..self.workers {
            let slice = &batch.slices[w];
            let live_w = slice.live();
            worker_lives[w] = live_w;
            let mb = data.batch(&slice.indices);
            let (x, y) = mb.inputs();
            let extras: Vec<HostValue> = if !self.private {
                vec![x, y]
            } else if self.grouping == WorkerGrouping::PerLayer {
                vec![
                    x,
                    y,
                    HostValue::F32(Tensor::from_vec(
                        &[k],
                        self.core.thresholds().iter().map(|&c| c as f32).collect(),
                    )?),
                    HostValue::F32(Tensor::from_vec(
                        &[slice.weights.len()],
                        slice.weights.clone(),
                    )?),
                ]
            } else {
                vec![
                    x,
                    y,
                    HostValue::F32(Tensor::scalar(self.worker_threshold(w) as f32)),
                    HostValue::F32(Tensor::from_vec(
                        &[slice.weights.len()],
                        slice.weights.clone(),
                    )?),
                ]
            };
            let t0 = Instant::now();
            let outs = self.exec.call(&self.replicas[w].params, &extras)?;
            bwd_secs[w] = t0.elapsed().as_secs_f64();
            let loss_w = outs[0].data[0] as f64;
            // private entries report a weighted mean over this worker's
            // live examples; recover the global mean via the live counts.
            // A worker whose slice drew empty reports a 0/0 loss — skip it.
            if live_w > 0 {
                loss_wsum += loss_w * live_w as f64;
            }
            loss_plain += loss_w;

            let mut grads: Vec<Tensor> = outs[1..1 + n_tr].to_vec();
            if !self.private && self.workers > 1 {
                // the nonprivate entry has no weight mask and emits a mean
                // over its full static batch; weight each worker's mean by
                // its live count so a sparsely-drawn (or empty) slice —
                // whose mean is dominated by index-0 pad slots, as on the
                // single-device backend — doesn't get an equal 1/N share
                // of the merged update
                let scale = live_w as f32;
                for t in grads.iter_mut() {
                    for v in t.data.iter_mut() {
                        *v *= scale;
                    }
                }
            }
            if self.private {
                // norms output: [B,K] for per-layer, [B] otherwise
                let norms = &outs[1 + n_tr];
                let k_exec = if self.grouping == WorkerGrouping::PerLayer { k } else { 1 };
                for i in 0..slice.weights.len() {
                    if slice.weights[i] == 0.0 {
                        continue;
                    }
                    for g in 0..k_exec {
                        let target = match self.grouping {
                            WorkerGrouping::PerLayer => g,
                            WorkerGrouping::Flat => 0,
                            WorkerGrouping::PerDevice => w,
                        };
                        let v = norms.data[i * k_exec + g] as f64;
                        mean_norms[target] += v;
                        if v <= self.core.thresholds()[target] {
                            clip_counts[target] += 1.0;
                        }
                    }
                }
                // local noise share: std_g / sqrt(N) per worker, so the
                // merged sum carries exactly the accountant's std_g
                // (variances add across the N independent shares)
                for (t, &g) in grads.iter_mut().zip(&self.group_of_trainable) {
                    let gi = match self.grouping {
                        WorkerGrouping::PerLayer => g,
                        WorkerGrouping::Flat => 0,
                        WorkerGrouping::PerDevice => w,
                    };
                    add_noise(&mut t.data, stds[gi] * noise_share, &mut self.core.rng);
                }
            }
            worker_grads.push(grads);
        }

        // normalize the mean-norm diagnostics by the examples that fed
        // each group (per-device groups see only their worker's slice)
        match self.grouping {
            WorkerGrouping::PerDevice => {
                for (g, m) in mean_norms.iter_mut().enumerate() {
                    *m /= worker_lives[g].max(1) as f64;
                }
            }
            _ => {
                for m in mean_norms.iter_mut() {
                    *m /= live_global.max(1) as f64;
                }
            }
        }

        // -------- overlapped tree-reduction of the worker deltas ---------
        let mut merged = tree_reduce(worker_grads, self.fanout);
        if self.private {
            // Algorithm 1 line 14: normalize the merged sum by E[B]
            let inv = (1.0 / self.expected_batch) as f32;
            for t in merged.iter_mut() {
                for v in t.data.iter_mut() {
                    *v *= inv;
                }
            }
        } else if self.workers > 1 {
            // complete the live-weighted mean of the per-worker means
            // (the 1-worker case needs no rescale at all — the worker's
            // mean IS the global mean, kept bitwise untouched for parity)
            let inv = 1.0 / (live_global.max(1) as f32);
            for t in merged.iter_mut() {
                for v in t.data.iter_mut() {
                    *v *= inv;
                }
            }
        }

        // one merged update applied to every replica (identical optimizer
        // states + identical grads keep the replicas bit-identical)
        for r in self.replicas.iter_mut() {
            r.optimizer.apply_indexed(&mut r.params, &self.trainable_idx, &merged);
        }

        // private quantile release over all threshold groups at once
        if self.private && self.core.is_adaptive() {
            self.core.update_thresholds(&clip_counts);
        }

        // -------- simulated N-worker latency (overlap vs barrier) --------
        // A real cluster runs the replicas concurrently, so the modeled
        // compute time is one representative worker (host measurements are
        // near-identical across replicas); its backward is split across
        // trainable tensors proportional to size, reductions queue behind
        // it in backprop (reverse) order.
        let rep_bwd = bwd_secs.iter().sum::<f64>() / self.workers as f64;
        let total_dim: f64 = self
            .trainable_idx
            .iter()
            .map(|&i| self.cfg.params[i].size as f64)
            .sum::<f64>()
            .max(1.0);
        let mut bwd_layers = Vec::with_capacity(n_tr);
        let mut red_layers = Vec::with_capacity(n_tr);
        for &i in self.trainable_idx.iter().rev() {
            let d = self.cfg.params[i].size as f64;
            bwd_layers.push(rep_bwd * d / total_dim);
            red_layers.push(self.reduce_model.layer_cost(4.0 * d));
        }
        let sim_overlap = self.reduce_model.overlap_makespan(&bwd_layers, &red_layers);
        let sim_barrier = self.reduce_model.barrier_makespan(&bwd_layers, &red_layers);

        self.step_count += 1;
        let clip_frac: Vec<f64> = match self.grouping {
            WorkerGrouping::PerDevice => clip_counts
                .iter()
                .enumerate()
                .map(|(w, &c)| 1.0 - c / (worker_lives[w].max(1) as f64))
                .collect(),
            _ => clip_counts
                .iter()
                .map(|&c| 1.0 - c / (live_global.max(1) as f64))
                .collect(),
        };
        let loss = if self.private {
            loss_wsum / (live_global.max(1) as f64)
        } else {
            loss_plain / self.workers as f64
        };
        Ok(ShardStepStats {
            step: self.step_count,
            loss,
            batch_size: live_global,
            clip_frac,
            mean_norms,
            truncated: batch.truncated,
            host_secs: host_t0.elapsed().as_secs_f64(),
            sim_secs: if self.overlap { sim_overlap } else { sim_barrier },
            sim_overlap_secs: sim_overlap,
            sim_barrier_secs: sim_barrier,
            syncs: self.reduce_model.rounds(),
            calls: self.workers,
        })
    }

    /// Full-dataset evaluation on worker 0's replica: (mean loss, acc).
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<(f64, f64)> {
        crate::coordinator::trainer::evaluate_full(
            &self.eval_exec,
            &self.replicas[0].params,
            self.cfg.batch,
            data,
        )
    }
}
