//! `ShardEngine` — N data-parallel workers, each a full model replica over
//! a disjoint slice of one global Poisson draw, clipping per-device before
//! an overlapped tree-reduction merges the deltas.
//!
//! Execution is sequential on the host (the PJRT CPU client already uses
//! every core per executable call), but each worker's executable call is
//! timed and fed to [`ReduceModel`], which replays what an N-worker
//! cluster would see: per-layer backward completion times against tree
//! all-reduce rounds, overlapped or behind a barrier.
//!
//! All DP state lives in the session's shared
//! [`StepLoop`](crate::session::StepLoop); this engine implements the
//! [`BackendStep`](crate::session::steploop::BackendStep) hooks only. The
//! unit layout it hands the loop encodes the documented RNG discipline —
//! per step the shared core RNG is consumed as (1) one global Poisson
//! draw, (2) per-trainable-tensor gradient noise in worker-major order at
//! the local share `sigma_g/sqrt(N)`, (3) the private quantile release.
//! With one worker this is the [`Trainer`](crate::coordinator::Trainer)
//! sequence verbatim.
//!
//! The merge hook is also the crate's gradient-compression seam: with a
//! `[compress]` spec section each worker's already-noised share is
//! sparsified (error-feedback top-k / rand-k, see
//! [`super::compress`]) before entering [`tree_reduce`], shrinking the
//! simulated reduction payload by the keep ratio — DP-safe post-processing
//! because the noise phase has already run.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::noise::Rng;
use crate::coordinator::optimizer::{Optimizer, OptimizerKind};
use crate::data::Dataset;
use crate::kernels::Kernels;
use crate::runtime::{ConfigManifest, Exec, HostValue, Runtime, Tensor};
use crate::session::core::DpCore;
use crate::session::grad::{fold_parts, Collected, GradUnit, Merged, StepTiming, UnitCollected};
use crate::session::spec::CompressSpec;
use crate::session::steploop::{BackendStep, UnitTask};

use super::compress::Compressor;
use super::reduce::{tree_reduce_with, ReduceModel};
use super::sampler::{ShardBatch, ShardSampler};

/// How clipping-threshold groups map onto the worker topology (resolved
/// from `ShardSpec.grouping` x `ClipPolicy.group_by` by the session
/// builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerGrouping {
    /// one global threshold shared by every worker (K = 1)
    Flat,
    /// per-layer groups shared across workers (K = layer groups)
    PerLayer,
    /// the paper's per-device scheme: worker k owns threshold C_k and
    /// clips its local per-example gradients against it (K = workers)
    PerDevice,
}

impl WorkerGrouping {
    pub fn token(&self) -> &'static str {
        match self {
            WorkerGrouping::Flat => "flat",
            WorkerGrouping::PerLayer => "per-layer",
            WorkerGrouping::PerDevice => "per-device",
        }
    }
}

/// Backend wiring computed by the session builder (crate-internal: the
/// sharded backend has no public constructor surface, unlike the retired
/// `Trainer::new` / `PipelineEngine::new` shims).
pub(crate) struct ShardWiring {
    pub workers: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub link_latency: f64,
    pub grouping: WorkerGrouping,
    /// step-executable entry name, resolved by the builder from the clip
    /// policy ("nonprivate" / "dp_flat" / "dp_ghost" / "dp_naive" /
    /// "dp_perlayer")
    pub entry: &'static str,
    pub private: bool,
    /// Poisson rate of the one global draw, q = E[B]/n
    pub rate: f64,
    /// global expected live batch E[B] (normalizes the merged update)
    pub expected_batch: usize,
    pub total_steps: u64,
    pub n_data: usize,
    pub optimizer: OptimizerKind,
    pub lr: f64,
    pub weight_decay: f64,
    pub lr_decay: bool,
    /// error-feedback gradient sparsification on the reduction path
    pub compress: Option<CompressSpec>,
    pub seed: u64,
}

struct Replica {
    params: Vec<Tensor>,
    optimizer: Optimizer,
}

pub struct ShardEngine<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub cfg: ConfigManifest,
    pub workers: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub total_steps: u64,
    grouping: WorkerGrouping,
    private: bool,
    exec: Arc<Exec>,
    eval_exec: Arc<Exec>,
    replicas: Vec<Replica>,
    sampler: ShardSampler,
    expected_batch: f64,
    trainable_idx: Vec<usize>,
    group_of_trainable: Vec<usize>,
    reduce_model: ReduceModel,
    /// error-feedback sparsifier on the reduction seam (None = dense)
    compressor: Option<Compressor>,
    /// live counts of the most recent collect, per worker (clip_frac and
    /// non-private loss weighting read them)
    worker_lives: Vec<usize>,
    /// when compressing: the (overlap, barrier) makespans the SAME step
    /// timings would have produced without compression — the
    /// apples-to-apples baseline benches assert against
    last_dense_sims: Option<(f64, f64)>,
    /// dispatched SIMD vtable for the engine's own hot loops (nonprivate
    /// rescale, tree-reduce folds); forwarded into optimizers/compressor
    kernels: Kernels,
}

impl<'r> ShardEngine<'r> {
    /// Crate-private constructor: all DP state lives in the session's
    /// `StepLoop` (`core` is borrowed to validate the group-count
    /// contract), all schedule/topology decisions in `wiring`. Only
    /// `session::SessionBuilder` builds these.
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        w: ShardWiring,
        core: &DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        if cfg.stages.is_some() {
            return Err(anyhow!(
                "config {config_name} has pipeline stages; the sharded backend replicates \
                 a stage-less model"
            ));
        }
        if w.workers == 0 {
            return Err(anyhow!("sharded backend needs workers > 0"));
        }
        let expect_k = match w.grouping {
            WorkerGrouping::Flat => 1,
            WorkerGrouping::PerLayer => cfg.groups.len().max(1),
            WorkerGrouping::PerDevice => w.workers,
        };
        if w.private && core.k() != expect_k {
            return Err(anyhow!(
                "DpCore has {} threshold groups but {} grouping over {} workers needs {}",
                core.k(),
                w.grouping.token(),
                w.workers,
                expect_k
            ));
        }
        let exec = runtime.load(config_name, w.entry)?;
        let eval_exec = runtime.load(config_name, "eval")?;

        let (trainable_idx, group_of_trainable, schedule) =
            crate::coordinator::trainer::replica_wiring(&cfg, w.lr, w.lr_decay, w.total_steps);
        // one checkpoint read fanned out to N bit-identical replicas; each
        // replica carries its own optimizer state (kept identical by the
        // merged update) to model real data-parallel redundancy
        let replicas: Vec<Replica> = runtime
            .init_replicas(config_name, w.workers)?
            .into_iter()
            .map(|params| {
                let tr: Vec<Tensor> = trainable_idx.iter().map(|&i| params[i].clone()).collect();
                Replica {
                    optimizer: Optimizer::new(w.optimizer, schedule, w.weight_decay, &tr),
                    params,
                }
            })
            .collect();

        let compressor = w
            .compress
            .as_ref()
            .map(|c| Compressor::new(c.kind, c.ratio, c.error_feedback, w.workers, w.seed));
        Ok(ShardEngine {
            runtime,
            config_name: config_name.to_string(),
            workers: w.workers,
            fanout: w.fanout,
            overlap: w.overlap,
            total_steps: w.total_steps,
            grouping: w.grouping,
            private: w.private,
            exec,
            eval_exec,
            replicas,
            sampler: ShardSampler::new(w.n_data, w.rate, w.workers, cfg.batch),
            expected_batch: w.expected_batch as f64,
            trainable_idx,
            group_of_trainable,
            reduce_model: ReduceModel::new(w.workers, w.fanout, w.link_latency),
            compressor,
            worker_lives: vec![0; w.workers],
            last_dense_sims: None,
            kernels: Kernels::default(),
            cfg,
        })
    }

    /// Install the session's dispatched kernel vtable on the engine and
    /// every replica optimizer / the compressor.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
        for r in self.replicas.iter_mut() {
            r.optimizer.set_kernels(kernels);
        }
        if let Some(c) = self.compressor.as_mut() {
            c.set_kernels(kernels);
        }
    }

    /// The (overlap, barrier) makespans the most recent step's timings
    /// would have produced WITHOUT compression; `None` until a compressed
    /// step ran. Deterministically comparable to the step's reported sims
    /// (same measured timings, only the payload differs).
    pub fn last_dense_sims(&self) -> Option<(f64, f64)> {
        self.last_dense_sims
    }

    pub fn grouping(&self) -> WorkerGrouping {
        self.grouping
    }

    /// Global static capacity: workers x the per-worker compiled batch.
    pub fn capacity(&self) -> usize {
        self.workers * self.cfg.batch
    }

    /// Threshold-group labels (one per worker for per-device grouping).
    pub fn group_labels(&self) -> Vec<String> {
        match self.grouping {
            WorkerGrouping::Flat => vec!["flat".to_string()],
            WorkerGrouping::PerLayer => self.cfg.groups.clone(),
            WorkerGrouping::PerDevice => {
                (0..self.workers).map(|w| format!("worker{w}")).collect()
            }
        }
    }

    /// Worker-0's full-model parameters in manifest order (all replicas
    /// stay bit-identical; see [`ShardEngine::replicas_in_sync`]).
    pub fn params(&self) -> &[Tensor] {
        &self.replicas[0].params
    }

    /// Worker-0's optimizer state (all replicas stay bit-identical, so
    /// snapshots persist one and fan it back out on restore).
    pub fn optimizer(&self) -> &Optimizer {
        &self.replicas[0].optimizer
    }

    /// Restore one optimizer state into every replica (snapshot fan-out,
    /// mirroring `set_params_all`).
    pub fn restore_optimizers(
        &mut self,
        step: u64,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    ) -> Result<()> {
        for r in self.replicas.iter_mut() {
            r.optimizer.restore_state(step, m.clone(), v.clone())?;
        }
        Ok(())
    }

    /// The error-feedback compressor, if `[compress]` is configured.
    /// Its per-unit residuals are mutable cross-step state and must be
    /// snapshotted (they differ per unit — error feedback is unit-local).
    pub fn compressor(&self) -> Option<&Compressor> {
        self.compressor.as_ref()
    }

    pub fn compressor_mut(&mut self) -> Option<&mut Compressor> {
        self.compressor.as_mut()
    }

    /// Broadcast a full parameter set to every replica (checkpoint
    /// fan-out).
    pub fn set_params_all(&mut self, params: Vec<Tensor>) -> Result<()> {
        if params.len() != self.cfg.params.len() {
            return Err(anyhow!("param count mismatch"));
        }
        for r in self.replicas.iter_mut() {
            r.params = params.clone();
        }
        Ok(())
    }

    /// Load parameters by name; names absent from the map keep their init
    /// values. The result is fanned out to every replica.
    pub fn load_param_map(
        &mut self,
        map: &std::collections::HashMap<String, Tensor>,
    ) -> Result<()> {
        let mut params = self.replicas[0].params.clone();
        for (i, p) in self.cfg.params.iter().enumerate() {
            if let Some(v) = map.get(&p.name) {
                if v.shape != p.shape {
                    return Err(anyhow!("shape mismatch for {}", p.name));
                }
                params[i] = v.clone();
            }
        }
        self.set_params_all(params)
    }

    /// True when every replica's parameters are bitwise equal to
    /// worker 0's — the invariant the merged update maintains.
    pub fn replicas_in_sync(&self) -> bool {
        let r0 = &self.replicas[0].params;
        self.replicas.iter().skip(1).all(|r| {
            r.params
                .iter()
                .zip(r0)
                .all(|(a, b)| a.shape == b.shape && a.data == b.data)
        })
    }

    /// Topology line for `Session::describe` / the CLI: worker count,
    /// reduction fanout, overlap flag, compression and the current
    /// per-group `thresholds` (owned by the session's core).
    pub fn describe_topology(&self, thresholds: &[f64]) -> String {
        let c: Vec<String> = thresholds.iter().map(|c| format!("{c:.4}")).collect();
        let compress = match &self.compressor {
            Some(c) => format!(" compress={}", c.describe()),
            None => String::new(),
        };
        format!(
            "workers={} fanout={} reduction={}{compress} grouping={} thresholds=[{}]",
            self.workers,
            self.fanout,
            if self.overlap { "overlapped" } else { "barrier" },
            self.grouping.token(),
            c.join(", ")
        )
    }

    /// Full-dataset evaluation on worker 0's replica: (mean loss, acc).
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<(f64, f64)> {
        crate::coordinator::trainer::evaluate_full(
            &self.eval_exec,
            &self.replicas[0].params,
            self.cfg.batch,
            data,
        )
    }

    /// Threshold group a tensor of worker `w` noises/clips under.
    fn group_of(&self, w: usize, layer_group: usize) -> usize {
        match self.grouping {
            WorkerGrouping::PerLayer => layer_group,
            WorkerGrouping::Flat => 0,
            WorkerGrouping::PerDevice => w,
        }
    }
}

impl BackendStep for ShardEngine<'_> {
    type Slices = ShardBatch;

    fn deal(&mut self, _n_data: usize, rng: &mut Rng) -> ShardBatch {
        // ONE global Poisson draw dealt round-robin into disjoint padded
        // per-worker slices (the accountant sees the union)
        self.sampler.sample(rng)
    }

    fn collect_tasks<'a>(
        &'a mut self,
        data: &'a dyn Dataset,
        batch: &'a ShardBatch,
        thresholds: &'a [f64],
    ) -> Vec<UnitTask<'a>> {
        // one Send task per worker: each borrows ITS replica immutably
        // plus shared read-only context, so the loop can run them on real
        // OS threads; all cross-worker accumulation happens afterwards in
        // `finish_collect` on the main thread, in worker order
        let k = thresholds.len();
        let n_tr = self.trainable_idx.len();
        let grouping = self.grouping;
        let private = self.private;
        let workers = self.workers;
        let kn = self.kernels;
        let group_of_trainable: &'a [usize] = &self.group_of_trainable;
        self.replicas
            .iter()
            .enumerate()
            .map(|(w, replica)| {
                let exec = self.exec.clone();
                let slice = &batch.slices[w];
                let task: UnitTask<'a> = Box::new(move || {
                    let group_of = |layer_group: usize| match grouping {
                        WorkerGrouping::PerLayer => layer_group,
                        WorkerGrouping::Flat => 0,
                        WorkerGrouping::PerDevice => w,
                    };
                    let live_w = slice.live();
                    let mb = data.batch(&slice.indices);
                    let (x, y) = mb.inputs();
                    let extras: Vec<HostValue> = if !private {
                        vec![x, y]
                    } else if grouping == WorkerGrouping::PerLayer {
                        vec![
                            x,
                            y,
                            HostValue::F32(Tensor::from_vec(
                                &[k],
                                thresholds.iter().map(|&c| c as f32).collect(),
                            )?),
                            HostValue::F32(Tensor::from_vec(
                                &[slice.weights.len()],
                                slice.weights.clone(),
                            )?),
                        ]
                    } else {
                        let thr_w = match grouping {
                            WorkerGrouping::PerDevice => thresholds[w],
                            _ => thresholds[0],
                        };
                        vec![
                            x,
                            y,
                            HostValue::F32(Tensor::scalar(thr_w as f32)),
                            HostValue::F32(Tensor::from_vec(
                                &[slice.weights.len()],
                                slice.weights.clone(),
                            )?),
                        ]
                    };
                    let t0 = Instant::now();
                    let outs = exec.call(&replica.params, &extras)?;
                    let bwd_secs = t0.elapsed().as_secs_f64();
                    let loss_w = outs[0].data[0] as f64;

                    let mut grads: Vec<Tensor> = outs[1..1 + n_tr].to_vec();
                    if !private && workers > 1 {
                        // the nonprivate entry has no weight mask and emits
                        // a mean over its full static batch; weight each
                        // worker's mean by its live count so a
                        // sparsely-drawn (or empty) slice — whose mean is
                        // dominated by index-0 pad slots, as on the
                        // single-device backend — doesn't get an equal 1/N
                        // share of the merged update
                        let scale = live_w as f32;
                        for t in grads.iter_mut() {
                            kn.scale(&mut t.data, scale);
                        }
                    }
                    // worker-major unit order with the per-tensor group
                    // mapping: this layout IS the noise discipline the
                    // StepLoop replays
                    let groups: Vec<usize> =
                        group_of_trainable.iter().map(|&g| group_of(g)).collect();
                    let mut part = UnitCollected::new(GradUnit { tensors: grads, groups }, k);
                    part.live = live_w;
                    part.calls = 1;
                    part.bwd_secs = bwd_secs;
                    // private entries report a weighted mean over this
                    // worker's live examples; the finish fold recovers the
                    // global mean via the live counts. A worker whose
                    // slice drew empty reports a 0/0 loss — weight it 0.
                    if private {
                        if live_w > 0 {
                            part.loss_wsum = loss_w * live_w as f64;
                        }
                        part.weight_sum = live_w as f64;
                    } else {
                        part.loss_wsum = loss_w;
                        part.weight_sum = 1.0;
                    }
                    if private {
                        // norms output: [B,K] for per-layer, [B] otherwise
                        let norms = &outs[1 + n_tr];
                        let k_exec = if grouping == WorkerGrouping::PerLayer { k } else { 1 };
                        for i in 0..slice.weights.len() {
                            if slice.weights[i] == 0.0 {
                                continue;
                            }
                            for g in 0..k_exec {
                                let target = group_of(g);
                                let v = norms.data[i * k_exec + g] as f64;
                                part.norm_sums[target] += v;
                                if v <= thresholds[target] {
                                    part.clip_counts[target] += 1.0;
                                }
                            }
                        }
                    }
                    Ok(part)
                });
                task
            })
            .collect()
    }

    fn finish_collect(
        &mut self,
        batch: &ShardBatch,
        parts: Vec<UnitCollected>,
    ) -> Result<Collected> {
        let live_global = batch.live;
        let k = parts.first().map(|p| p.clip_counts.len()).unwrap_or(0);
        let f = fold_parts(parts, k);
        self.worker_lives.copy_from_slice(&f.lives);

        // normalize the mean-norm diagnostics by the examples that fed
        // each group (per-device groups see only their worker's slice)
        let mut mean_norms = f.norm_sums;
        match self.grouping {
            WorkerGrouping::PerDevice => {
                for (g, m) in mean_norms.iter_mut().enumerate() {
                    *m /= self.worker_lives[g].max(1) as f64;
                }
            }
            _ => {
                for m in mean_norms.iter_mut() {
                    *m /= live_global.max(1) as f64;
                }
            }
        }
        // TRUE denominators — 0 where a slice (or the whole draw) came up
        // empty; the loop guards the clip_frac division
        let clip_denoms: Vec<f64> = match self.grouping {
            WorkerGrouping::PerDevice => (0..k).map(|g| self.worker_lives[g] as f64).collect(),
            _ => vec![live_global as f64; k],
        };
        let loss = f.loss_wsum / f.weight_sum.max(1.0);
        Ok(Collected {
            units: f.units,
            clip_counts: f.clip_counts,
            clip_denoms,
            mean_norms,
            loss,
            live: live_global,
            truncated: batch.truncated,
            calls: f.calls,
            syncs: f.syncs,
            timing: StepTiming { durations: Vec::new(), bwd_secs: f.bwd_secs },
        })
    }

    fn prefetch_lists(&self, batch: &ShardBatch) -> Vec<Vec<usize>> {
        batch.slices.iter().map(|s| s.indices.clone()).collect()
    }

    fn merge(&mut self, units: Vec<GradUnit>, timing: &StepTiming) -> Merged {
        let n_tr = self.trainable_idx.len();
        let mut parts: Vec<Vec<Tensor>> = units.into_iter().map(|u| u.tensors).collect();

        // -------- compression on the reduction seam ----------------------
        // Each worker sparsifies its ALREADY-NOISED share before it enters
        // the tree (post-processing of a paid-for release; residuals stay
        // local). A 1-worker tree moves nothing, so there is nothing to
        // compress — the identity path stays bitwise.
        let ratio = match (&mut self.compressor, self.workers > 1) {
            (Some(c), true) => {
                for (w, p) in parts.iter_mut().enumerate() {
                    c.compress_unit(w, p);
                }
                c.ratio().min(1.0)
            }
            _ => 1.0,
        };

        let merged = tree_reduce_with(self.kernels, parts, self.fanout);

        // -------- simulated N-worker latency (overlap vs barrier) --------
        // A real cluster runs the replicas concurrently, so the modeled
        // compute time is one representative worker (host measurements are
        // near-identical across replicas); its backward is split across
        // trainable tensors proportional to size, reductions queue behind
        // it in backprop (reverse) order. Compression scales each layer's
        // reduction payload by the keep ratio.
        let rep_bwd = timing.bwd_secs.iter().sum::<f64>() / self.workers as f64;
        let total_dim: f64 = self
            .trainable_idx
            .iter()
            .map(|&i| self.cfg.params[i].size as f64)
            .sum::<f64>()
            .max(1.0);
        let mut bwd_layers = Vec::with_capacity(n_tr);
        let mut red_layers = Vec::with_capacity(n_tr);
        for &i in self.trainable_idx.iter().rev() {
            let d = self.cfg.params[i].size as f64;
            bwd_layers.push(rep_bwd * d / total_dim);
            red_layers.push(self.reduce_model.layer_cost(4.0 * d * ratio));
        }
        let sim_overlap = self.reduce_model.overlap_makespan(&bwd_layers, &red_layers);
        let sim_barrier = self.reduce_model.barrier_makespan(&bwd_layers, &red_layers);
        // apples-to-apples dense baseline from the SAME timings, so the
        // compressed-beats-dense claim is deterministic, not host-noise
        self.last_dense_sims = (ratio < 1.0).then(|| {
            let red_dense: Vec<f64> = self
                .trainable_idx
                .iter()
                .rev()
                .map(|&i| self.reduce_model.layer_cost(4.0 * self.cfg.params[i].size as f64))
                .collect();
            (
                self.reduce_model.overlap_makespan(&bwd_layers, &red_dense),
                self.reduce_model.barrier_makespan(&bwd_layers, &red_dense),
            )
        });

        Merged {
            tensors: merged,
            sim_secs: if self.overlap { sim_overlap } else { sim_barrier },
            sim_overlap_secs: sim_overlap,
            sim_barrier_secs: sim_barrier,
            syncs: self.reduce_model.rounds(),
        }
    }

    fn apply(&mut self, grads: &[Tensor]) {
        // one merged update applied to every replica (identical optimizer
        // states + identical grads keep the replicas bit-identical)
        for r in self.replicas.iter_mut() {
            r.optimizer.apply_indexed(&mut r.params, &self.trainable_idx, grads);
        }
    }

    fn update_scale(&self, live: usize) -> f32 {
        if self.private {
            // Algorithm 1 line 14: normalize the merged sum by E[B]
            (1.0 / self.expected_batch) as f32
        } else if self.workers > 1 {
            // complete the live-weighted mean of the per-worker means
            1.0 / (live.max(1) as f32)
        } else {
            // the 1-worker case needs no rescale at all — the worker's
            // mean IS the global mean, kept bitwise untouched for parity
            1.0
        }
    }
}
