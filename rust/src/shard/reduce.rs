//! Tree-reduction of worker gradient deltas + the overlapped-vs-barrier
//! latency model.
//!
//! The numeric merge is a fanout-f tree: deterministic grouping of
//! consecutive participants per round, so results are reproducible for a
//! given worker count and fanout (and the 1-worker tree is the identity).
//!
//! The latency model mirrors `pipeline::schedule`: we execute workers
//! sequentially on the host (the PJRT CPU client already saturates the
//! cores) but replay the dependency structure a real N-worker cluster
//! would see. Backward produces layer gradients in reverse layer order;
//! each layer's all-reduce needs `rounds = ceil(log_fanout N)` tree rounds
//! of `link_latency + bytes/bandwidth` each. **Overlapped** reduction
//! starts a layer's rounds the moment its gradient is ready, while earlier
//! layers are still back-propagating — the paper's
//! clip-in-conjunction-with-backprop overlap applied to communication.
//! **Barrier** reduction waits for the whole backward pass, then reduces
//! every layer — the naive data-parallel baseline.

use crate::kernels::Kernels;
use crate::runtime::Tensor;

/// Rounds a fanout-`f` reduction tree needs over `workers` participants.
/// One worker needs none; fanout is clamped to >= 2.
pub fn tree_rounds(workers: usize, fanout: usize) -> usize {
    let f = fanout.max(2);
    let mut rounds = 0usize;
    let mut n = workers.max(1);
    while n > 1 {
        n = n.div_ceil(f);
        rounds += 1;
    }
    rounds
}

/// The quadrature sensitivity bound for per-device threshold groups: one
/// example lives on exactly one worker and is clipped to that worker's
/// C_k, so its influence on the merged update is at most
/// `max_k C_k <= sqrt(sum_k C_k^2)` — the bound the noise is calibrated
/// against (docs/SESSION_API.md, "Sharded backend").
pub fn quadrature_bound(thresholds: &[f64]) -> f64 {
    thresholds.iter().map(|c| c * c).sum::<f64>().sqrt()
}

/// Merge per-worker gradient sets with a fanout-`f` tree: each round sums
/// groups of `f` consecutive participants into the group's first slot.
/// A single participant passes through untouched (bitwise), which the
/// 1-worker parity test relies on.
pub fn tree_reduce(parts: Vec<Vec<Tensor>>, fanout: usize) -> Vec<Tensor> {
    tree_reduce_with(Kernels::scalar(), parts, fanout)
}

/// [`tree_reduce`] through a dispatched kernel vtable. In scalar mode the
/// folds run one participant at a time through the bit-exact `add_assign`
/// kernel — bitwise identical to the legacy loop on every ISA. In auto
/// mode ([`Kernels::reassociate`]) participants within a group fold in
/// PAIRS (`acc += a + b`), halving the passes over the accumulator at the
/// cost of a reassociated summation order — which is exactly why the pair
/// fold is gated behind the `kernels` knob (drift-bounded, see
/// `tests/kernels.rs`).
pub fn tree_reduce_with(k: Kernels, mut parts: Vec<Vec<Tensor>>, fanout: usize) -> Vec<Tensor> {
    assert!(!parts.is_empty());
    let f = fanout.max(2);
    while parts.len() > 1 {
        let mut next: Vec<Vec<Tensor>> = Vec::with_capacity(parts.len().div_ceil(f));
        let mut it = parts.into_iter();
        loop {
            let Some(mut acc) = it.next() else { break };
            let mut group: Vec<Vec<Tensor>> = Vec::with_capacity(f - 1);
            for _ in 1..f {
                let Some(other) = it.next() else { break };
                group.push(other);
            }
            if k.reassociate() {
                let mut gi = group.chunks_exact(2);
                for pair in gi.by_ref() {
                    for ((t, x), y) in acc.iter_mut().zip(&pair[0]).zip(&pair[1]) {
                        k.add2_assign(&mut t.data, &x.data, &y.data);
                    }
                }
                for other in gi.remainder() {
                    for (a, o) in acc.iter_mut().zip(other) {
                        k.add_assign(&mut a.data, &o.data);
                    }
                }
            } else {
                for other in &group {
                    for (a, o) in acc.iter_mut().zip(other) {
                        k.add_assign(&mut a.data, &o.data);
                    }
                }
            }
            next.push(acc);
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// Latency model of the reduction phase.
#[derive(Debug, Clone, Copy)]
pub struct ReduceModel {
    pub workers: usize,
    pub fanout: usize,
    /// per-round link latency (alpha term), seconds
    pub link_latency: f64,
    /// modeled interconnect bandwidth (beta term), bytes/second
    pub bytes_per_sec: f64,
}

impl ReduceModel {
    pub fn new(workers: usize, fanout: usize, link_latency: f64) -> Self {
        // 16 GB/s: a deliberately modest PCIe-class figure so the bytes
        // term is visible next to the latency term even on small models
        ReduceModel { workers, fanout, link_latency, bytes_per_sec: 16e9 }
    }

    pub fn rounds(&self) -> usize {
        tree_rounds(self.workers, self.fanout)
    }

    /// Wall time to all-reduce one layer of `bytes` gradient bytes.
    pub fn layer_cost(&self, bytes: f64) -> f64 {
        self.rounds() as f64 * (self.link_latency + bytes / self.bytes_per_sec)
    }

    /// Makespan with the reduction overlapped into backprop: layer `l`'s
    /// rounds start as soon as its gradient is ready (layers arrive in
    /// backprop order), sharing one FIFO network resource.
    pub fn overlap_makespan(&self, bwd: &[f64], red: &[f64]) -> f64 {
        assert_eq!(bwd.len(), red.len());
        let mut ready = Vec::with_capacity(bwd.len());
        let mut compute_t = 0.0f64;
        for b in bwd {
            compute_t += b;
            ready.push(compute_t);
        }
        self.overlap_makespan_at(&ready, red)
    }

    /// The general overlapped makespan: piece `i`'s gradient becomes
    /// available at ABSOLUTE time `ready[i]` (non-decreasing — the order
    /// pieces reach the FIFO network) and needs `red[i]` seconds of
    /// network time. This is [`ReduceModel::overlap_makespan`] with the
    /// prefix-sum compute model replaced by arbitrary ready times, which
    /// is what the hybrid backend feeds it: per-STAGE gradient-ready
    /// times out of the GPipe schedule
    /// ([`stage_grad_ready`](crate::pipeline::schedule::stage_grad_ready)),
    /// so each stage's cross-replica reduction overlaps the earlier
    /// stages' still-running backward — the paper's
    /// clip-in-conjunction-with-backprop overlap lifted to the 2D grid.
    pub fn overlap_makespan_at(&self, ready: &[f64], red: &[f64]) -> f64 {
        assert_eq!(ready.len(), red.len());
        // the FIFO recurrence below is only a valid makespan when pieces
        // enter the network in ready order — an out-of-order piece would
        // let a LATER arrival start before an earlier one finished
        // queueing, understating the contention. Callers sort (hybrid) or
        // construct prefix sums (overlap_makespan); hold them to it.
        debug_assert!(
            ready.windows(2).all(|w| w[0] <= w[1]),
            "overlap_makespan_at needs non-decreasing ready times, got {ready:?}"
        );
        // each piece waits for its gradient AND the network: the finish
        // time already dominates every ready time (net_free >= ready[i])
        let mut net_free = 0.0f64;
        let mut end = 0.0f64;
        for (t, r) in ready.iter().zip(red) {
            net_free = net_free.max(*t) + r;
            end = end.max(net_free);
        }
        end
    }

    /// Barrier baseline for ready-time pieces: every reduction waits for
    /// the LAST gradient, then runs back-to-back.
    pub fn barrier_makespan_at(&self, ready: &[f64], red: &[f64]) -> f64 {
        assert_eq!(ready.len(), red.len());
        ready.iter().cloned().fold(0.0, f64::max) + red.iter().sum::<f64>()
    }

    /// Makespan with a barrier: the whole backward pass, then every
    /// layer's reduction back-to-back.
    pub fn barrier_makespan(&self, bwd: &[f64], red: &[f64]) -> f64 {
        assert_eq!(bwd.len(), red.len());
        bwd.iter().sum::<f64>() + red.iter().sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_match_log_fanout() {
        assert_eq!(tree_rounds(1, 2), 0);
        assert_eq!(tree_rounds(2, 2), 1);
        assert_eq!(tree_rounds(4, 2), 2);
        assert_eq!(tree_rounds(8, 2), 3);
        assert_eq!(tree_rounds(5, 2), 3);
        assert_eq!(tree_rounds(8, 4), 2);
        assert_eq!(tree_rounds(16, 4), 2);
        assert_eq!(tree_rounds(17, 4), 3);
    }

    #[test]
    fn tree_reduce_matches_flat_sum() {
        let mk = |seed: u64| {
            let mut v = Vec::new();
            let mut x = seed;
            for len in [5usize, 3] {
                let data: Vec<f32> = (0..len)
                    .map(|_| {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((x >> 33) as f32 / 2e9) - 1.0
                    })
                    .collect();
                v.push(Tensor::from_vec(&[len], data).unwrap());
            }
            v
        };
        for workers in [1usize, 2, 3, 4, 7, 8] {
            for fanout in [2usize, 3, 4] {
                let parts: Vec<Vec<Tensor>> = (0..workers).map(|w| mk(w as u64 + 1)).collect();
                let flat: Vec<Vec<f64>> = (0..2)
                    .map(|t| {
                        (0..parts[0][t].data.len())
                            .map(|i| parts.iter().map(|p| p[t].data[i] as f64).sum())
                            .collect()
                    })
                    .collect();
                let merged = tree_reduce(parts, fanout);
                for (t, m) in merged.iter().enumerate() {
                    for (i, &v) in m.data.iter().enumerate() {
                        assert!(
                            (v as f64 - flat[t][i]).abs() < 1e-4,
                            "workers={workers} fanout={fanout} t={t} i={i}: {v} vs {}",
                            flat[t][i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn single_participant_is_bitwise_identity() {
        let t = Tensor::from_vec(&[3], vec![0.1, -0.2, 0.3]).unwrap();
        let merged = tree_reduce(vec![vec![t.clone()]], 2);
        assert_eq!(merged[0].data, t.data);
        // the auto pair-fold degenerates to the same identity
        let k = Kernels::for_mode(crate::kernels::KernelMode::Auto);
        let merged = tree_reduce_with(k, vec![vec![t.clone()]], 2);
        assert_eq!(merged[0].data, t.data);
    }

    #[test]
    fn pair_fold_tree_stays_within_fp_drift_of_the_sequential_tree() {
        let mk = |seed: u64, len: usize| {
            let mut x = seed;
            let data: Vec<f32> = (0..len)
                .map(|_| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((x >> 33) as f32 / 2e9) - 1.0
                })
                .collect();
            vec![Tensor::from_vec(&[len], data).unwrap()]
        };
        let auto = Kernels::for_mode(crate::kernels::KernelMode::Auto);
        for workers in [2usize, 3, 5, 8, 13] {
            for fanout in [2usize, 3, 4, 8] {
                let parts = |s| (0..workers).map(|w| mk(w as u64 + s, 37)).collect::<Vec<_>>();
                let seq = tree_reduce(parts(1), fanout);
                let par = tree_reduce_with(auto, parts(1), fanout);
                for (a, b) in seq[0].data.iter().zip(&par[0].data) {
                    let tol = 1e-5 * a.abs().max(1.0);
                    assert!(
                        (a - b).abs() <= tol,
                        "workers={workers} fanout={fanout}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn overlap_beats_barrier_with_multiple_layers() {
        for workers in [2usize, 4, 8] {
            let m = ReduceModel::new(workers, 2, 1e-3);
            let bwd = [0.004, 0.003, 0.005, 0.002];
            let red: Vec<f64> = [4096.0, 1024.0, 8192.0, 512.0]
                .iter()
                .map(|&b| m.layer_cost(b))
                .collect();
            let o = m.overlap_makespan(&bwd, &red);
            let b = m.barrier_makespan(&bwd, &red);
            assert!(o < b, "workers={workers}: overlap {o} !< barrier {b}");
            // and never better than either critical path alone
            assert!(o >= bwd.iter().sum::<f64>());
            assert!(o >= red.iter().sum::<f64>());
        }
    }

    #[test]
    fn overlap_at_generalizes_the_prefix_sum_form() {
        let m = ReduceModel::new(4, 2, 1e-3);
        let bwd = [0.004, 0.003, 0.005, 0.002];
        let red: Vec<f64> =
            [4096.0, 1024.0, 8192.0, 512.0].iter().map(|&b| m.layer_cost(b)).collect();
        let mut ready = Vec::new();
        let mut t = 0.0;
        for b in &bwd {
            t += b;
            ready.push(t);
        }
        assert!(
            (m.overlap_makespan(&bwd, &red) - m.overlap_makespan_at(&ready, &red)).abs() < 1e-15
        );
        assert!(
            (m.barrier_makespan(&bwd, &red) - m.barrier_makespan_at(&ready, &red)).abs() < 1e-12
        );
        let o = m.overlap_makespan_at(&ready, &red);
        assert!(o <= m.barrier_makespan_at(&ready, &red) + 1e-15);
        assert!(o >= *ready.last().unwrap());
        assert!(o >= red.iter().sum::<f64>());
    }

    #[test]
    fn one_worker_reduction_is_free() {
        let m = ReduceModel::new(1, 2, 1e-3);
        assert_eq!(m.rounds(), 0);
        let bwd = [0.01, 0.02];
        let red = [m.layer_cost(1e6), m.layer_cost(2e6)];
        assert_eq!(red, [0.0, 0.0]);
        let total: f64 = bwd.iter().sum();
        assert!((m.overlap_makespan(&bwd, &red) - total).abs() < 1e-15);
        assert!((m.barrier_makespan(&bwd, &red) - total).abs() < 1e-15);
    }
}
