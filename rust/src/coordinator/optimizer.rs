//! First-order optimizers applied by the coordinator to the host-side
//! parameter buffers after noising (Algorithm 1 line 14). The paper's
//! experiments use DP-SGD (momentum) for vision and DP-Adam for language.

use crate::kernels::{AdamCoeffs, Kernels, SgdCoeffs};
use crate::runtime::Tensor;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    Sgd { momentum: f64 },
    Adam { beta1: f64, beta2: f64, eps: f64 },
}

#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub base_lr: f64,
    pub warmup: u64,
    pub total: u64,
    pub decay: Decay,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decay {
    Constant,
    Linear,
}

impl Schedule {
    pub fn constant(lr: f64) -> Self {
        Schedule { base_lr: lr, warmup: 0, total: 1, decay: Decay::Constant }
    }

    pub fn linear(lr: f64, warmup: u64, total: u64) -> Self {
        Schedule { base_lr: lr, warmup, total: total.max(1), decay: Decay::Linear }
    }

    pub fn lr_at(&self, step: u64) -> f64 {
        let warm = if self.warmup > 0 && step < self.warmup {
            (step + 1) as f64 / self.warmup as f64
        } else {
            1.0
        };
        let decay = match self.decay {
            Decay::Constant => 1.0,
            Decay::Linear => {
                let p = (step.min(self.total)) as f64 / self.total as f64;
                (1.0 - p).max(0.0)
            }
        };
        self.base_lr * warm * decay
    }
}

pub struct Optimizer {
    pub kind: OptimizerKind,
    pub schedule: Schedule,
    pub weight_decay: f64,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    kernels: Kernels,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, schedule: Schedule, weight_decay: f64, params: &[Tensor]) -> Self {
        let m = params.iter().map(|p| vec![0f32; p.len()]).collect();
        let v = match kind {
            OptimizerKind::Adam { .. } => params.iter().map(|p| vec![0f32; p.len()]).collect(),
            _ => Vec::new(),
        };
        Optimizer { kind, schedule, weight_decay, step: 0, m, v, kernels: Kernels::default() }
    }

    /// Install the session's dispatched kernel vtable. The optimizer
    /// update kernels are bit-exact across ISAs, so this never changes
    /// the trained parameters — only how fast they move.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// First-moment buffers (one `Vec<f32>` per trainable tensor).
    pub fn moments_m(&self) -> &[Vec<f32>] {
        &self.m
    }

    /// Second-moment buffers — empty for SGD, per-tensor for Adam.
    pub fn moments_v(&self) -> &[Vec<f32>] {
        &self.v
    }

    /// Restore the full mutable state (step counter + moment buffers)
    /// captured via `step_count`/`moments_m`/`moments_v`. The buffer
    /// layout must match this optimizer's parameter set exactly —
    /// a snapshot taken under a different spec is rejected, never
    /// silently mis-restored.
    pub fn restore_state(
        &mut self,
        step: u64,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            m.len() == self.m.len(),
            "optimizer restore: {} first-moment buffers, expected {}",
            m.len(),
            self.m.len()
        );
        anyhow::ensure!(
            v.len() == self.v.len(),
            "optimizer restore: {} second-moment buffers, expected {}",
            v.len(),
            self.v.len()
        );
        for (i, (new, cur)) in m.iter().zip(&self.m).enumerate() {
            anyhow::ensure!(
                new.len() == cur.len(),
                "optimizer restore: moment m[{i}] has {} elements, expected {}",
                new.len(),
                cur.len()
            );
        }
        for (i, (new, cur)) in v.iter().zip(&self.v).enumerate() {
            anyhow::ensure!(
                new.len() == cur.len(),
                "optimizer restore: moment v[{i}] has {} elements, expected {}",
                new.len(),
                cur.len()
            );
        }
        self.step = step;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// Apply one update: params[i] -= lr * f(grads[i]). `grads` must align
    /// with `params` (only trainable tensors are passed).
    pub fn apply(&mut self, params: &mut [&mut Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        let lr = self.schedule.lr_at(self.step);
        self.step += 1;
        match self.kind {
            OptimizerKind::Sgd { momentum } => {
                let c = SgdCoeffs {
                    weight_decay: self.weight_decay as f32,
                    momentum: momentum as f32,
                    lr: lr as f32,
                };
                for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    self.kernels.sgd_update(&mut p.data, &g.data, &mut self.m[i], c);
                }
            }
            OptimizerKind::Adam { beta1, beta2, eps } => {
                let t = self.step as f64;
                let c = AdamCoeffs {
                    weight_decay: self.weight_decay as f32,
                    beta1: beta1 as f32,
                    one_minus_beta1: 1.0 - beta1 as f32,
                    beta2: beta2 as f32,
                    one_minus_beta2: 1.0 - beta2 as f32,
                    bias1: 1.0 - beta1.powf(t),
                    bias2: 1.0 - beta2.powf(t),
                    lr,
                    eps,
                };
                for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    self.kernels.adam_update(
                        &mut p.data,
                        &g.data,
                        &mut self.m[i],
                        &mut self.v[i],
                        c,
                    );
                }
            }
        }
    }

    /// Apply one update to the trainable subset of a full-model parameter
    /// vector: `idx` (strictly increasing manifest positions) selects the
    /// tensors `grads` aligns with. This is the one split-borrow used by
    /// every backend (single-device, pipeline stages, sharded replicas) —
    /// a safe cursor walk, so no backend carries its own pointer dance.
    pub fn apply_indexed(&mut self, params: &mut [Tensor], idx: &[usize], grads: &[Tensor]) {
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be increasing");
        let mut refs: Vec<&mut Tensor> = Vec::with_capacity(idx.len());
        let mut next = idx.iter().peekable();
        for (i, p) in params.iter_mut().enumerate() {
            if next.peek() == Some(&&i) {
                refs.push(p);
                next.next();
            }
        }
        assert_eq!(refs.len(), idx.len(), "trainable index out of range");
        self.apply(&mut refs, grads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[v.len()], v).unwrap()
    }

    #[test]
    fn apply_indexed_touches_only_selected_tensors() {
        let mut params = vec![t(vec![1.0]), t(vec![2.0]), t(vec![3.0])];
        let tr = [0usize, 2];
        let grads = vec![t(vec![1.0]), t(vec![1.0])];
        let init: Vec<Tensor> = tr.iter().map(|&i| params[i].clone()).collect();
        let mut opt = Optimizer::new(
            OptimizerKind::Sgd { momentum: 0.0 },
            Schedule::constant(0.1),
            0.0,
            &init,
        );
        opt.apply_indexed(&mut params, &tr, &grads);
        assert!((params[0].data[0] - 0.9).abs() < 1e-6);
        assert_eq!(params[1].data[0], 2.0, "non-trainable tensor untouched");
        assert!((params[2].data[0] - 2.9).abs() < 1e-6);
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = t(vec![1.0, -1.0]);
        let g = t(vec![0.5, -0.5]);
        let mut opt = Optimizer::new(
            OptimizerKind::Sgd { momentum: 0.0 },
            Schedule::constant(0.1),
            0.0,
            std::slice::from_ref(&p),
        );
        opt.apply(&mut [&mut p], &[g]);
        assert!((p.data[0] - 0.95).abs() < 1e-6);
        assert!((p.data[1] + 0.95).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = t(vec![0.0]);
        let g = t(vec![1.0]);
        let mut opt = Optimizer::new(
            OptimizerKind::Sgd { momentum: 0.9 },
            Schedule::constant(1.0),
            0.0,
            std::slice::from_ref(&p),
        );
        opt.apply(&mut [&mut p], std::slice::from_ref(&g));
        let after1 = p.data[0];
        opt.apply(&mut [&mut p], std::slice::from_ref(&g));
        let delta2 = p.data[0] - after1;
        assert!((after1 + 1.0).abs() < 1e-6);
        assert!((delta2 + 1.9).abs() < 1e-6);
    }

    #[test]
    fn adam_step_magnitude_is_lr_at_start() {
        // with constant grads, the first adam step is ~lr in magnitude
        let mut p = t(vec![0.0]);
        let g = t(vec![3.7]);
        let mut opt = Optimizer::new(
            OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            Schedule::constant(0.01),
            0.0,
            std::slice::from_ref(&p),
        );
        opt.apply(&mut [&mut p], std::slice::from_ref(&g));
        assert!((p.data[0] + 0.01).abs() < 1e-4, "{}", p.data[0]);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize (x-3)^2
        let mut p = t(vec![0.0]);
        let mut opt = Optimizer::new(
            OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            Schedule::constant(0.1),
            0.0,
            std::slice::from_ref(&p),
        );
        for _ in 0..500 {
            let g = t(vec![2.0 * (p.data[0] - 3.0)]);
            opt.apply(&mut [&mut p], &[g]);
        }
        assert!((p.data[0] - 3.0).abs() < 0.05, "{}", p.data[0]);
    }

    #[test]
    fn restore_state_round_trips_bitwise() {
        fn mk(template: &Tensor) -> Optimizer {
            Optimizer::new(
                OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
                Schedule::linear(0.05, 2, 20),
                0.01,
                std::slice::from_ref(template),
            )
        }
        let init = t(vec![0.3, -0.7, 2.0]);
        let mut p1 = init.clone();
        let mut a = mk(&init);
        for s in 0..7 {
            let g = t(vec![0.1 * s as f32, -0.2, 0.05]);
            a.apply(&mut [&mut p1], &[g]);
        }
        // clone params + exported optimizer state into a fresh instance,
        // then replay an identical tail on both — must match bitwise
        let mut p2 = p1.clone();
        let mut c = mk(&init);
        c.restore_state(a.step_count(), a.moments_m().to_vec(), a.moments_v().to_vec()).unwrap();
        for _ in 0..3 {
            let g = t(vec![0.4, -0.1, 0.25]);
            a.apply(&mut [&mut p1], &[g.clone()]);
            c.apply(&mut [&mut p2], &[g]);
        }
        assert_eq!(p1.data, p2.data, "restored optimizer diverged bitwise");
    }

    #[test]
    fn restore_state_rejects_mismatched_layout() {
        let p = t(vec![1.0, 2.0]);
        let mut opt = Optimizer::new(
            OptimizerKind::Sgd { momentum: 0.9 },
            Schedule::constant(0.1),
            0.0,
            std::slice::from_ref(&p),
        );
        assert!(opt.restore_state(3, vec![vec![0.0; 5]], vec![]).is_err(), "wrong tensor len");
        assert!(opt.restore_state(3, vec![], vec![]).is_err(), "wrong buffer count");
        assert!(
            opt.restore_state(3, vec![vec![0.0; 2]], vec![vec![0.0; 2]]).is_err(),
            "sgd has no v buffers"
        );
        assert!(opt.restore_state(3, vec![vec![0.5, 0.5]], vec![]).is_ok());
        assert_eq!(opt.step_count(), 3);
    }

    #[test]
    fn schedule_warmup_and_linear_decay() {
        let s = Schedule::linear(1.0, 10, 100);
        assert!(s.lr_at(0) < 0.2);
        assert!((s.lr_at(9) - 0.91).abs() < 1e-9); // warmup done, decay = 1 - 9/100
        assert!(s.lr_at(50) < s.lr_at(20));
        assert!(s.lr_at(100) == 0.0);
    }
}
