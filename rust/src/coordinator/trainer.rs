//! Single-device DP training backend — Algorithm 1 of the paper.
//!
//! The compiled L2 step executable performs the fused backprop+clip
//! (lines 7-12); everything DP-critical around it — the Poisson draw
//! (line 6), gradient noise (line 13), the `/E[B]` normalization
//! (line 14) and the private quantile release (lines 15-18) — runs in the
//! shared [`StepLoop`](crate::session::StepLoop); this module only
//! implements the backend's [`BackendStep`] hooks (deal / collect /
//! merge) and holds no noise, quantile or accountant wiring of its own.
//!
//! Construction goes through [`crate::session::SessionBuilder`] only: the
//! legacy `Trainer::new` raw-opts shim is retired, and
//! [`Trainer::with_core`] is crate-private so every run's DP state is
//! derived from a declarative spec in exactly one place.
//!
//! [`BackendStep`]: crate::session::steploop::BackendStep

use std::str::FromStr;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::runtime::{ConfigManifest, Exec, HostValue, Runtime, Tensor};
use crate::session::core::DpCore;
use crate::session::grad::{Collected, GradUnit, Merged, StepTiming, UnitCollected};
use crate::session::spec::ClipPolicy;
use crate::session::steploop::{BackendStep, UnitTask};

use super::noise::{Allocation, Rng};
use super::optimizer::{Optimizer, OptimizerKind, Schedule};
use super::sampler::{Batch, PoissonSampler};

/// Which clipping scheme drives the step (paper sections 2-3). This is the
/// single-device *backend* view; the API-surface equivalent is
/// [`crate::session::ClipPolicy`], which maps onto it via
/// `ClipPolicy::method()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    NonPrivate,
    FlatFixed,
    FlatAdaptive,
    PerLayerFixed,
    PerLayerAdaptive,
    /// flat via double-backward (efficiency baseline; same math as Flat*)
    Ghost,
    /// flat via materialized per-example grads (efficiency baseline)
    Naive,
}

impl Method {
    pub fn entry(&self) -> &'static str {
        match self {
            Method::NonPrivate => "nonprivate",
            Method::FlatFixed | Method::FlatAdaptive => "dp_flat",
            Method::PerLayerFixed | Method::PerLayerAdaptive => "dp_perlayer",
            Method::Ghost => "dp_ghost",
            Method::Naive => "dp_naive",
        }
    }

    pub fn per_layer(&self) -> bool {
        matches!(self, Method::PerLayerFixed | Method::PerLayerAdaptive)
    }

    pub fn adaptive(&self) -> bool {
        matches!(self, Method::FlatAdaptive | Method::PerLayerAdaptive)
    }

    pub fn private(&self) -> bool {
        !matches!(self, Method::NonPrivate)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::NonPrivate => "non-private",
            Method::FlatFixed => "fixed flat",
            Method::FlatAdaptive => "adaptive flat",
            Method::PerLayerFixed => "fixed per-layer",
            Method::PerLayerAdaptive => "adaptive per-layer",
            Method::Ghost => "ghost",
            Method::Naive => "naive flat",
        }
    }

    /// Canonical CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            Method::NonPrivate => "non-private",
            Method::FlatFixed => "flat",
            Method::FlatAdaptive => "adaptive-flat",
            Method::PerLayerFixed => "per-layer",
            Method::PerLayerAdaptive => "adaptive-per-layer",
            Method::Ghost => "ghost",
            Method::Naive => "naive",
        }
    }

    /// All variants, for exhaustive CLI help / tests.
    pub fn all() -> [Method; 7] {
        [
            Method::NonPrivate,
            Method::FlatFixed,
            Method::FlatAdaptive,
            Method::PerLayerFixed,
            Method::PerLayerAdaptive,
            Method::Ghost,
            Method::Naive,
        ]
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "non-private" | "nonprivate" => Method::NonPrivate,
            "flat" | "fixed-flat" => Method::FlatFixed,
            "adaptive-flat" => Method::FlatAdaptive,
            "per-layer" | "fixed-per-layer" => Method::PerLayerFixed,
            "adaptive-per-layer" => Method::PerLayerAdaptive,
            "ghost" => Method::Ghost,
            "naive" => Method::Naive,
            _ => {
                return Err(anyhow!(
                    "unknown method '{s}' (non-private|flat|adaptive-flat|per-layer|\
                     adaptive-per-layer|ghost|naive)"
                ))
            }
        })
    }
}

/// Single-device backend parameter bundle. This is no longer a public
/// construction surface — no public constructor consumes it since the
/// `Trainer::new` shim was retired; the session builder fills it from a
/// declarative [`crate::session::RunSpec`].
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub method: Method,
    pub epsilon: f64,
    pub delta: f64,
    pub epochs: f64,
    /// expected (Poisson) batch size; must be <= the config's static B.
    pub expected_batch: usize,
    pub lr: f64,
    pub optimizer: OptimizerKind,
    pub weight_decay: f64,
    pub lr_decay: bool,
    /// initial *global-equivalent* clipping threshold C (per-layer methods
    /// start each group at C/sqrt(K), the paper's A.1 convention).
    pub clip_init: f64,
    /// target gradient-norm quantile for adaptive methods
    pub target_q: f64,
    /// budget fraction for quantile estimation (paper: 0.01-0.1)
    pub quantile_r: f64,
    /// quantile learning rate eta (paper: 0.3)
    pub quantile_eta: f64,
    pub allocation: Allocation,
    /// Appendix A.1 convention: after each adaptive update, rescale the
    /// per-layer thresholds so their global-equivalent norm stays at
    /// `clip_init` (C~_k = C * C_k / sqrt(sum C_k^2)). Keeps the *relative*
    /// structure the quantiles learned while pinning total sensitivity, so
    /// adaptive runs are comparable to flat runs at the same C.
    pub rescale_global: bool,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            method: Method::PerLayerAdaptive,
            epsilon: 3.0,
            delta: 1e-5,
            epochs: 3.0,
            expected_batch: 0,
            lr: 0.5,
            optimizer: OptimizerKind::Sgd { momentum: 0.0 },
            weight_decay: 0.0,
            lr_decay: false,
            clip_init: 1.0,
            target_q: 0.5,
            quantile_r: 0.01,
            quantile_eta: 0.3,
            allocation: Allocation::Global,
            rescale_global: true,
            seed: 0,
        }
    }
}

impl TrainOpts {
    /// The session-spec view of these options (shim direction).
    pub fn privacy_spec(&self) -> crate::session::PrivacySpec {
        crate::session::PrivacySpec {
            epsilon: self.epsilon,
            delta: self.delta,
            quantile_r: self.quantile_r,
        }
    }

    /// The unified clip policy these options encode (shim direction).
    pub fn clip_policy(&self) -> ClipPolicy {
        ClipPolicy {
            clip_init: self.clip_init,
            target_q: self.target_q,
            quantile_eta: self.quantile_eta,
            allocation: self.allocation,
            rescale_global: self.rescale_global,
            ..ClipPolicy::from_method(self.method)
        }
    }
}

/// Derived schedule for one full-model replica: (expected batch, Poisson
/// rate, total steps). The 1-worker view of [`derive_schedule_n`].
pub fn derive_schedule(
    cfg: &ConfigManifest,
    n_data: usize,
    epochs: f64,
    expected_batch: usize,
) -> Result<(usize, f64, u64)> {
    derive_schedule_n(cfg, n_data, epochs, expected_batch, 1)
}

/// The one schedule formula every replica-holding backend derives from:
/// per-worker E[B] defaults to the 0.8x-headroom convention round(0.8 x
/// batch) (an explicit global E[B] is split evenly), the global expected
/// batch is N x that, and `(rate, steps)` follow as `min(E[B]/n, 1)` and
/// `ceil(epochs x n / E[B])`. Single-device (N = 1) and sharded backends
/// both call this, so the amplified accounting inputs — and therefore the
/// 1-worker parity contract — cannot silently diverge.
pub(crate) fn derive_schedule_n(
    cfg: &ConfigManifest,
    n_data: usize,
    epochs: f64,
    expected_batch: usize,
    workers: usize,
) -> Result<(usize, f64, u64)> {
    if n_data == 0 {
        return Err(anyhow!("dataset is empty"));
    }
    if workers == 0 {
        return Err(anyhow!("schedule needs workers > 0"));
    }
    let b_static = cfg.batch;
    let per_worker = if expected_batch == 0 {
        ((b_static as f64) * 0.8).round() as usize
    } else {
        // defense in depth behind RunSpec::validate's divisibility check
        if expected_batch % workers != 0 {
            return Err(anyhow!(
                "expected batch {expected_batch} is not divisible across {workers} workers"
            ));
        }
        expected_batch / workers
    };
    if per_worker > b_static {
        return Err(anyhow!(
            "expected batch {} exceeds compiled batch {}",
            per_worker * workers,
            b_static * workers
        ));
    }
    let expected = per_worker * workers;
    let rate = (expected as f64 / n_data as f64).min(1.0);
    let total_steps = ((epochs * n_data as f64) / expected as f64).ceil() as u64;
    Ok((expected, rate, total_steps))
}

/// Shared full-replica backend wiring: (trainable manifest indices,
/// layer-group index per trainable tensor, LR schedule). Used by the
/// single-device trainer and each sharded worker so the trainable-filter
/// semantics and the warmup fraction can never silently diverge between
/// backends (the 1-worker parity test pins them equal).
pub(crate) fn replica_wiring(
    cfg: &ConfigManifest,
    lr: f64,
    lr_decay: bool,
    total_steps: u64,
) -> (Vec<usize>, Vec<usize>, Schedule) {
    let trainable_idx: Vec<usize> = cfg
        .params
        .iter()
        .enumerate()
        .filter(|(_, p)| p.trainable)
        .map(|(i, _)| i)
        .collect();
    let gidx = cfg.group_index();
    let group_of_trainable: Vec<usize> = cfg
        .params
        .iter()
        .filter(|p| p.trainable)
        .map(|p| gidx[p.group.as_str()])
        .collect();
    let schedule = if lr_decay {
        Schedule::linear(lr, total_steps / 20, total_steps)
    } else {
        Schedule::constant(lr)
    };
    (trainable_idx, group_of_trainable, schedule)
}

/// Full-dataset evaluation through an `eval` entry (mean loss, accuracy),
/// shared by every backend that holds a full model replica (single-device
/// trainer, sharded workers): sequential padded batches, weighted sums
/// from the executable's (loss, correct, weight) outputs.
pub(crate) fn evaluate_full(
    eval_exec: &Exec,
    params: &[Tensor],
    batch: usize,
    data: &dyn Dataset,
) -> Result<(f64, f64)> {
    let mut loss_sum = 0f64;
    let mut correct = 0f64;
    let mut weight = 0f64;
    for b in super::sampler::EvalIter::new(data.len(), batch) {
        let mb = data.batch(&b.indices);
        let (x, y) = mb.inputs();
        let extras = vec![
            x,
            y,
            HostValue::F32(Tensor::from_vec(&[batch], b.weights.clone())?),
        ];
        let outs = eval_exec.call(params, &extras)?;
        loss_sum += outs[0].data[0] as f64;
        correct += outs[1].data[0] as f64;
        weight += outs[2].data[0] as f64;
    }
    Ok((loss_sum / weight.max(1.0), correct / weight.max(1.0)))
}

pub struct Trainer<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub cfg: ConfigManifest,
    pub opts: TrainOpts,
    pub params: Vec<Tensor>,
    exec: Arc<Exec>,
    eval_exec: Arc<Exec>,
    optimizer: Optimizer,
    sampler: PoissonSampler,
    /// threshold-group count (mirrors the shared core's K)
    k: usize,
    expected_batch: f64,
    trainable_idx: Vec<usize>,
    group_of_trainable: Vec<usize>,
    pub total_steps: u64,
    /// when set, per-step [B,K] norms are appended here (Figure 2/4 dumps)
    pub collect_norms: Option<Vec<Vec<f32>>>,
}

impl<'r> Trainer<'r> {
    /// Crate-private constructor: backend wiring only. All DP state (plan,
    /// thresholds, noise, RNG) lives in the session's `StepLoop`; `core`
    /// is borrowed here only to validate the group-count contract.
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        n_data: usize,
        opts: TrainOpts,
        core: &DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        let (expected_batch, rate, total_steps) =
            derive_schedule(&cfg, n_data, opts.epochs, opts.expected_batch)?;
        let b_static = cfg.batch;
        let expect_k = if opts.method.per_layer() { cfg.groups.len() } else { 1 };
        if core.k() != expect_k {
            return Err(anyhow!(
                "DpCore has {} groups but method {} needs {}",
                core.k(),
                opts.method.name(),
                expect_k
            ));
        }

        let exec = runtime.load(config_name, opts.method.entry())?;
        let eval_exec = runtime.load(config_name, "eval")?;
        let params = runtime.init_params(config_name)?;

        let (trainable_idx, group_of_trainable, schedule) =
            replica_wiring(&cfg, opts.lr, opts.lr_decay, total_steps);
        let tr_params: Vec<Tensor> =
            trainable_idx.iter().map(|&i| params[i].clone()).collect();
        let optimizer = Optimizer::new(opts.optimizer, schedule, opts.weight_decay, &tr_params);

        Ok(Trainer {
            runtime,
            config_name: config_name.to_string(),
            opts,
            params,
            exec,
            eval_exec,
            optimizer,
            sampler: PoissonSampler::new(n_data, rate, b_static),
            k: expect_k,
            expected_batch: expected_batch as f64,
            trainable_idx,
            group_of_trainable,
            total_steps,
            collect_norms: None,
            cfg,
        })
    }

    /// Replace parameters (e.g. load a pretrained checkpoint for the
    /// fine-tuning experiments).
    pub fn set_params(&mut self, params: Vec<Tensor>) -> Result<()> {
        if params.len() != self.params.len() {
            return Err(anyhow!("param count mismatch"));
        }
        self.params = params;
        Ok(())
    }

    pub fn groups(&self) -> &[String] {
        &self.cfg.groups
    }

    /// Optimizer state (step counter + moment buffers) for snapshots.
    pub fn optimizer(&self) -> &Optimizer {
        &self.optimizer
    }

    pub fn optimizer_mut(&mut self) -> &mut Optimizer {
        &mut self.optimizer
    }

    /// Install the session's dispatched kernel vtable on the optimizer
    /// (the single-device backend's only host-side hot loop).
    pub fn set_kernels(&mut self, kernels: crate::kernels::Kernels) {
        self.optimizer.set_kernels(kernels);
    }

    /// Full-dataset evaluation: (mean loss, accuracy).
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<(f64, f64)> {
        evaluate_full(&self.eval_exec, &self.params, self.cfg.batch, data)
    }
}

impl BackendStep for Trainer<'_> {
    type Slices = Batch;

    fn deal(&mut self, _n_data: usize, rng: &mut Rng) -> Batch {
        // one Poisson draw padded to the static capacity with index-0,
        // weight-0 slots (Algorithm 1 line 6)
        self.sampler.sample_padded(rng)
    }

    fn collect_tasks<'a>(
        &'a mut self,
        data: &'a dyn Dataset,
        batch: &'a Batch,
        thresholds: &'a [f64],
    ) -> Vec<UnitTask<'a>> {
        // the single-device backend is one unit: one task owning the one
        // fused executable call (still RNG-free; all backend state it
        // touches is read-only or returned in the part)
        let exec = self.exec.clone();
        let params: &'a [Tensor] = &self.params;
        let group_of_trainable: &'a [usize] = &self.group_of_trainable;
        let method = self.opts.method;
        let k = self.k;
        let n_tr = self.trainable_idx.len();
        let keep_norms = self.collect_norms.is_some();
        vec![Box::new(move || {
            let mb = data.batch(&batch.indices);
            let (x, y) = mb.inputs();
            let live = batch.live();

            let extras: Vec<HostValue> = match method {
                Method::NonPrivate => vec![x, y],
                m if m.per_layer() => vec![
                    x,
                    y,
                    HostValue::F32(Tensor::from_vec(
                        &[k],
                        thresholds.iter().map(|&c| c as f32).collect(),
                    )?),
                    HostValue::F32(Tensor::from_vec(
                        &[batch.weights.len()],
                        batch.weights.clone(),
                    )?),
                ],
                _ => vec![
                    x,
                    y,
                    HostValue::F32(Tensor::scalar(thresholds[0] as f32)),
                    HostValue::F32(Tensor::from_vec(
                        &[batch.weights.len()],
                        batch.weights.clone(),
                    )?),
                ],
            };

            let call_t0 = std::time::Instant::now();
            let outs = exec.call(params, &extras)?;
            let bwd_secs = call_t0.elapsed().as_secs_f64();
            let loss = outs[0].data[0] as f64;
            let grads: Vec<Tensor> = outs[1..1 + n_tr].to_vec();

            let groups = if method.per_layer() {
                group_of_trainable.to_vec()
            } else {
                vec![0; n_tr]
            };
            let mut part = UnitCollected::new(GradUnit { tensors: grads, groups }, k);
            part.live = live;
            part.loss_wsum = loss;
            part.weight_sum = 1.0;
            part.bwd_secs = bwd_secs;
            if method.private() {
                // norms output: [B,K] (per-layer) or [B] (flat-family)
                let norms = &outs[1 + n_tr];
                let b = batch.weights.len();
                for i in 0..b {
                    if batch.weights[i] == 0.0 {
                        continue;
                    }
                    for g in 0..k {
                        let v = norms.data[i * k + g] as f64;
                        part.norm_sums[g] += v;
                        if v <= thresholds[g] {
                            part.clip_counts[g] += 1.0;
                        }
                    }
                }
                if keep_norms {
                    part.norms = norms.data.clone();
                }
            }
            Ok(part)
        })]
    }

    fn finish_collect(&mut self, batch: &Batch, mut parts: Vec<UnitCollected>) -> Result<Collected> {
        let p = parts.pop().ok_or_else(|| anyhow!("single-device backend lost its unit"))?;
        debug_assert!(parts.is_empty());
        let live = p.live;
        let k = self.k;
        let mut mean_norms = p.norm_sums;
        if self.opts.method.private() {
            for m in mean_norms.iter_mut() {
                *m /= (live.max(1)) as f64;
            }
            if let Some(c) = &mut self.collect_norms {
                c.push(p.norms);
            }
        }
        Ok(Collected {
            units: vec![p.unit],
            clip_counts: p.clip_counts,
            // TRUE denominator: 0 on an empty draw (the loop guards the
            // clip_frac division), no .max(1) masking
            clip_denoms: vec![live as f64; k],
            mean_norms,
            loss: p.loss_wsum,
            live,
            truncated: batch.truncated,
            calls: 0,
            syncs: 0,
            timing: StepTiming::default(),
        })
    }

    fn prefetch_lists(&self, batch: &Batch) -> Vec<Vec<usize>> {
        vec![batch.indices.clone()]
    }

    fn merge(&mut self, units: Vec<GradUnit>, _timing: &StepTiming) -> Merged {
        Merged::identity(units)
    }

    fn apply(&mut self, grads: &[Tensor]) {
        self.optimizer.apply_indexed(&mut self.params, &self.trainable_idx, grads);
    }

    fn update_scale(&self, _live: usize) -> f32 {
        if self.opts.method.private() {
            // Algorithm 1 line 14: normalize by the EXPECTED batch
            (1.0 / self.expected_batch) as f32
        } else {
            // the non-private entry already emits a batch mean
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_tokens_roundtrip() {
        for m in Method::all() {
            assert_eq!(m.token().parse::<Method>().unwrap(), m, "token {}", m.token());
        }
    }

    #[test]
    fn method_aliases_parse() {
        for (alias, want) in [
            ("non-private", Method::NonPrivate),
            ("nonprivate", Method::NonPrivate),
            ("flat", Method::FlatFixed),
            ("fixed-flat", Method::FlatFixed),
            ("adaptive-flat", Method::FlatAdaptive),
            ("per-layer", Method::PerLayerFixed),
            ("fixed-per-layer", Method::PerLayerFixed),
            ("adaptive-per-layer", Method::PerLayerAdaptive),
            ("ghost", Method::Ghost),
            ("naive", Method::Naive),
        ] {
            assert_eq!(alias.parse::<Method>().unwrap(), want, "alias {alias}");
        }
        assert!("per-device".parse::<Method>().is_err());
        assert!("".parse::<Method>().is_err());
    }

    #[test]
    fn trainopts_policy_shim_matches_method() {
        for m in Method::all() {
            let opts = TrainOpts { method: m, ..Default::default() };
            assert_eq!(opts.clip_policy().method().unwrap(), m);
        }
    }
}
