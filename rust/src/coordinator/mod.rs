//! L3 coordinator: the paper's coordination contribution.
//!
//! * [`accountant`] — RDP privacy accounting + Proposition 3.1 budget split
//! * [`quantile`]   — private quantile estimation (adaptive thresholds)
//! * [`noise`]      — Gaussian mechanism + allocation strategies
//! * [`optimizer`]  — DP-SGD / DP-Adam parameter updates
//! * [`sampler`]    — Poisson subsampling
//! * [`trainer`]    — Algorithm 1 end to end on one device (the
//!   single-device backend of [`crate::session`]; accounting, thresholds,
//!   noise and RNG live in the shared `session::DpCore`)

pub mod accountant;
pub mod noise;
pub mod optimizer;
pub mod quantile;
pub mod sampler;
pub mod trainer;

pub use noise::Allocation;
pub use trainer::{Method, TrainOpts, Trainer};
