//! Minibatch samplers. DP-SGD's privacy analysis assumes Poisson
//! subsampling: each example joins the batch independently with
//! probability rho. The compiled executables have a static batch dimension
//! B, so Poisson draws are padded (weight 0) or truncated to B; truncation
//! is recorded on the batch (and surfaced on `StepEvent`) and kept rare by
//! sizing B ~ 1.25 * rho * n.

use super::noise::Rng;

#[derive(Debug, Clone)]
pub struct Batch {
    /// dataset indices; length <= capacity ([`PoissonSampler::sample`]) or
    /// exactly capacity ([`PoissonSampler::sample_padded`])
    pub indices: Vec<usize>,
    /// 1.0 for real examples, 0.0 for padding, length == capacity
    pub weights: Vec<f32>,
    /// examples the draw included but the static capacity dropped
    pub truncated: usize,
}

impl Batch {
    /// Number of live (weight 1) examples.
    pub fn live(&self) -> usize {
        self.weights.iter().filter(|&&w| w > 0.0).count()
    }
}

/// Poisson subsampler over a dataset of `n` examples.
pub struct PoissonSampler {
    pub n: usize,
    pub rate: f64,
    pub capacity: usize,
}

impl PoissonSampler {
    pub fn new(n: usize, rate: f64, capacity: usize) -> Self {
        assert!(n > 0 && rate > 0.0 && rate <= 1.0 && capacity > 0);
        PoissonSampler { n, rate, capacity }
    }

    pub fn sample(&self, rng: &mut Rng) -> Batch {
        let mut idx = Vec::new();
        for i in 0..self.n {
            if rng.uniform() < self.rate {
                idx.push(i);
            }
        }
        let truncated = idx.len().saturating_sub(self.capacity);
        if truncated > 0 {
            // drop a uniform subset to stay unbiased-ish under truncation
            rng.shuffle(&mut idx);
            idx.truncate(self.capacity);
        }
        let mut weights = vec![0f32; self.capacity];
        for w in weights.iter_mut().take(idx.len()) {
            *w = 1.0;
        }
        Batch { indices: idx, weights, truncated }
    }

    /// Like [`PoissonSampler::sample`], but with `indices` padded to
    /// exactly `capacity` entries so fixed-batch executables can consume
    /// the draw directly: padding slots carry dataset index 0 and weight
    /// 0.0. Invariant: `weights[i] == 0.0` iff slot `i` is padding (live
    /// examples occupy the prefix).
    pub fn sample_padded(&self, rng: &mut Rng) -> Batch {
        let mut b = self.sample(rng);
        while b.indices.len() < self.capacity {
            b.indices.push(0);
        }
        b
    }
}

/// Epoch-shuffled fixed-size batches (non-private training / eval).
pub struct ShuffleSampler {
    order: Vec<usize>,
    pos: usize,
    pub batch: usize,
}

impl ShuffleSampler {
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> Self {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        ShuffleSampler { order, pos: 0, batch }
    }

    /// Next batch; reshuffles at epoch end. Always returns `batch` indices
    /// (wrapping), with weight 1 everywhere.
    pub fn sample(&mut self, rng: &mut Rng) -> Batch {
        let mut idx = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.pos >= self.order.len() {
                rng.shuffle(&mut self.order);
                self.pos = 0;
            }
            idx.push(self.order[self.pos]);
            self.pos += 1;
        }
        Batch { weights: vec![1.0; idx.len()], indices: idx, truncated: 0 }
    }
}

/// Sequential batches for evaluation, final batch padded with weight 0.
pub struct EvalIter {
    n: usize,
    pos: usize,
    batch: usize,
}

impl EvalIter {
    pub fn new(n: usize, batch: usize) -> Self {
        EvalIter { n, pos: 0, batch }
    }
}

impl Iterator for EvalIter {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos >= self.n {
            return None;
        }
        let mut idx = Vec::with_capacity(self.batch);
        let mut weights = vec![0f32; self.batch];
        for k in 0..self.batch {
            if self.pos < self.n {
                idx.push(self.pos);
                weights[k] = 1.0;
                self.pos += 1;
            } else {
                idx.push(0); // pad with example 0, weight 0
            }
        }
        Some(Batch { indices: idx, weights, truncated: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_batch_size() {
        let s = PoissonSampler::new(1000, 0.05, 200);
        let mut rng = Rng::seeded(1);
        let mut total = 0usize;
        for _ in 0..200 {
            total += s.sample(&mut rng).indices.len();
        }
        let mean = total as f64 / 200.0;
        assert!((mean - 50.0).abs() < 5.0, "mean {mean}");
    }

    #[test]
    fn poisson_weights_match_indices() {
        let s = PoissonSampler::new(100, 0.1, 32);
        let mut rng = Rng::seeded(2);
        let b = s.sample(&mut rng);
        let live = b.weights.iter().filter(|&&w| w == 1.0).count();
        assert_eq!(live, b.indices.len());
        assert!(b.indices.len() <= 32);
    }

    #[test]
    fn poisson_truncates_at_capacity() {
        let s = PoissonSampler::new(100, 1.0, 10);
        let mut rng = Rng::seeded(3);
        let b = s.sample(&mut rng);
        assert_eq!(b.indices.len(), 10);
        assert_eq!(b.truncated, 90);
    }

    #[test]
    fn poisson_truncation_never_inflates_weights() {
        // at rate 1 every draw overflows a small capacity: the batch must
        // report the overflow, weights must stay 0/1, and the live count
        // must equal the capacity — truncation never manufactures weight
        for cap in [1usize, 7, 10] {
            let s = PoissonSampler::new(100, 1.0, cap);
            let mut rng = Rng::seeded(13);
            for _ in 0..20 {
                let b = s.sample_padded(&mut rng);
                assert_eq!(b.truncated, 100 - cap);
                assert_eq!(b.indices.len(), cap);
                assert!(b.weights.iter().all(|&w| w == 0.0 || w == 1.0));
                assert_eq!(b.live(), cap);
                assert!(b.weights.iter().sum::<f32>() as usize <= cap);
            }
        }
    }

    #[test]
    fn padded_batches_have_full_capacity_and_consistent_mask() {
        let s = PoissonSampler::new(500, 0.05, 64);
        let mut rng = Rng::seeded(14);
        for _ in 0..50 {
            let b = s.sample_padded(&mut rng);
            assert_eq!(b.indices.len(), 64);
            assert_eq!(b.weights.len(), 64);
            let live = b.live();
            // live prefix, padded suffix: weight 0 <=> padding slot
            for (i, &w) in b.weights.iter().enumerate() {
                assert_eq!(w > 0.0, i < live, "slot {i} live {live}");
                if w == 0.0 {
                    assert_eq!(b.indices[i], 0, "padding carries index 0");
                }
            }
        }
    }

    #[test]
    fn shuffle_covers_everything_each_epoch() {
        let mut rng = Rng::seeded(4);
        let mut s = ShuffleSampler::new(10, 5, &mut rng);
        let mut seen = vec![false; 10];
        for _ in 0..2 {
            for i in s.sample(&mut rng).indices {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn eval_iter_covers_exactly_once_with_padding() {
        let mut count = 0.0;
        let mut batches = 0;
        for b in EvalIter::new(10, 4) {
            count += b.weights.iter().sum::<f32>();
            batches += 1;
            assert_eq!(b.indices.len(), 4);
        }
        assert_eq!(count, 10.0);
        assert_eq!(batches, 3);
    }
}
