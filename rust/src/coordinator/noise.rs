//! Gaussian noise generation and the paper's noise-allocation strategies
//! (section 3.3 "Allocating Noise", Appendix E).
//!
//! Scaling group k by a public gamma_k before the Gaussian mechanism and
//! unscaling after is equivalent to adding noise with std proportional to
//! gamma_k. With thresholds C_1..C_K and the scaled sensitivity
//!     S = sqrt(sum_k C_k^2 / gamma_k^2),
//! group k receives noise std = sigma * S * gamma_k (Algorithm 1 line 13).

use crate::util::rng::Xoshiro;

/// Deterministic RNG with a Box-Muller gaussian; one instance per trainer.
pub struct Rng {
    inner: Xoshiro,
    spare: Option<f64>,
}

/// The observable position of an [`Rng`] stream: the xoshiro state PLUS
/// whether a Marsaglia spare is buffered. Two streams at the same
/// `StreamPos` produce identical output forever — comparing a single
/// `uniform()` draw cannot see the spare, so two "equal" streams could
/// still diverge on their next `gauss()`. Parity pins must compare this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamPos {
    pub state: [u64; 4],
    pub has_spare: bool,
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        Rng { inner: Xoshiro::seeded(seed), spare: None }
    }

    /// Full observable stream position (xoshiro state + spare presence).
    pub fn stream_pos(&self) -> StreamPos {
        StreamPos { state: self.inner.state(), has_spare: self.spare.is_some() }
    }

    /// The raw 256-bit xoshiro state (snapshot serialization).
    pub fn state(&self) -> [u64; 4] {
        self.inner.state()
    }

    /// The buffered Marsaglia spare, if any. `stream_pos` records only
    /// its *presence*; a bitwise resume needs the buffered *value* too,
    /// because the next `gauss()` returns it verbatim.
    pub fn spare(&self) -> Option<f64> {
        self.spare
    }

    /// Rebuild a stream at an exact position (state + buffered spare).
    /// The restored stream continues bitwise — the restore half of the
    /// `session::snapshot` contract.
    pub fn from_parts(state: [u64; 4], spare: Option<f64>) -> Self {
        Rng { inner: Xoshiro::from_state(state), spare }
    }

    /// Discard any buffered Marsaglia spare. Phase boundaries in the
    /// step loop drain so a phase's gaussian consumption cannot leak a
    /// half-drawn pair into the next phase (e.g. noise into the quantile
    /// release when a unit's element count is odd), keeping pre-split
    /// per-unit streams well-defined.
    pub fn drain_spare(&mut self) {
        self.spare = None;
    }

    /// Derive an independent child stream: one `next_u64` from this
    /// stream seeds a fresh generator through the splitmix64 expansion
    /// (the same path `seeded` takes). The parent advances by exactly
    /// one draw per split regardless of how much the child consumes —
    /// which is what lets each `GradUnit` get its own pre-split noise
    /// stream (Marsaglia rejection makes position-splitting impossible:
    /// the uniforms-per-gaussian count is data-dependent).
    pub fn split(&mut self) -> Rng {
        Rng { inner: Xoshiro::seeded(self.inner.next_u64()), spare: None }
    }

    pub fn uniform(&mut self) -> f64 {
        self.inner.uniform()
    }

    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // Marsaglia polar method: ~27% faster than Box-Muller here because
        // it avoids sin/cos (measured in bench coordinator_hotpath; noise
        // generation is the coordinator's dominant per-step cost at 1M+
        // params — see EXPERIMENTS.md §Perf).
        loop {
            let u = 2.0 * self.inner.uniform() - 1.0;
            let v = 2.0 * self.inner.uniform() - 1.0;
            let s = u * u + v * v;
            if s >= 1.0 || s == 0.0 {
                continue;
            }
            let m = (-2.0 * s.ln() / s).sqrt();
            self.spare = Some(v * m);
            return u * m;
        }
    }

    pub fn gen_range(&mut self, n: usize) -> usize {
        self.inner.below(n)
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.inner.below(i + 1);
            v.swap(i, j);
        }
    }
}

/// Noise-allocation strategy across clipping groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Allocation {
    /// gamma_k = 1: same std everywhere. V_G ~ (sum C_k^2)(sum d_k).
    Global,
    /// gamma_k = C_k: same budget per group; device k's noise depends only
    /// on its own C_k — this is what makes per-device clipping
    /// communication-free (section 4). V_E ~ K sum d_k C_k^2.
    EqualBudget,
    /// gamma_k = C_k / sqrt(d_k): equal per-coordinate SNR (Appendix E).
    Weighted,
}

impl Allocation {
    /// Canonical token accepted back by [`Allocation::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            Allocation::Global => "global",
            Allocation::EqualBudget => "equal",
            Allocation::Weighted => "weighted",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "global" => Ok(Allocation::Global),
            "equal" | "equal-budget" => Ok(Allocation::EqualBudget),
            "weighted" => Ok(Allocation::Weighted),
            _ => anyhow::bail!("unknown allocation '{s}' (global|equal|weighted)"),
        }
    }

    /// Per-group noise std for thresholds C and group dims d, given the
    /// gradient noise multiplier sigma (Algorithm 1 line 13).
    pub fn stds(&self, sigma: f64, thresholds: &[f64], dims: &[u64]) -> Vec<f64> {
        assert_eq!(thresholds.len(), dims.len());
        let gammas: Vec<f64> = match self {
            Allocation::Global => vec![1.0; thresholds.len()],
            Allocation::EqualBudget => thresholds.to_vec(),
            Allocation::Weighted => thresholds
                .iter()
                .zip(dims)
                .map(|(c, &d)| c / (d.max(1) as f64).sqrt())
                .collect(),
        };
        // A zero threshold makes gamma_k = 0 under EqualBudget/Weighted,
        // and 0/0 would poison S with NaN. A group clipped to C_k = 0
        // contributes nothing to the release, so its sensitivity share is
        // exactly 0 (and its std below is sigma * S * 0 = 0).
        let s2: f64 = thresholds
            .iter()
            .zip(&gammas)
            .map(|(c, g)| if *g == 0.0 { 0.0 } else { (c / g) * (c / g) })
            .sum();
        let s = s2.sqrt();
        gammas.iter().map(|g| sigma * s * g).collect()
    }

    /// Total expected squared noise norm (for tests / ablation reporting).
    pub fn total_noise_sq(&self, sigma: f64, thresholds: &[f64], dims: &[u64]) -> f64 {
        self.stds(sigma, thresholds, dims)
            .iter()
            .zip(dims)
            .map(|(s, &d)| s * s * d as f64)
            .sum()
    }
}

/// Per-device clipping noise std (Algorithm 2 line 6): the equal-budget
/// strategy makes device k's std depend only on local C_k and the device
/// count, so no communication is needed.
pub fn per_device_std(sigma: f64, c_k: f64, n_devices: usize) -> f64 {
    sigma * (n_devices as f64).sqrt() * c_k
}

/// Add iid gaussian noise with std `std` to a buffer.
pub fn add_noise(buf: &mut [f32], std: f64, rng: &mut Rng) {
    if std == 0.0 {
        return;
    }
    for x in buf.iter_mut() {
        *x += (std * rng.gauss()) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::seeded(42);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gauss();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn global_gives_uniform_std() {
        let stds = Allocation::Global.stds(1.0, &[1.0, 2.0, 3.0], &[10, 10, 10]);
        let s = (1.0f64 + 4.0 + 9.0).sqrt();
        for x in &stds {
            assert!((x - s).abs() < 1e-12);
        }
    }

    #[test]
    fn equal_budget_scales_with_threshold() {
        let stds = Allocation::EqualBudget.stds(1.0, &[1.0, 2.0], &[10, 10]);
        // S = sqrt(K) = sqrt(2); std_k = sqrt(2) * C_k
        assert!((stds[0] - 2f64.sqrt()).abs() < 1e-12);
        assert!((stds[1] - 2.0 * 2f64.sqrt()).abs() < 1e-12);
        // matches the communication-free per-device formula
        assert!((per_device_std(1.0, 1.0, 2) - stds[0]).abs() < 1e-12);
        assert!((per_device_std(1.0, 2.0, 2) - stds[1]).abs() < 1e-12);
    }

    #[test]
    fn paper_noise_norm_formulas() {
        // V_G ~ (sum C_k^2)(sum d_k); V_E ~ K sum d_k C_k^2 (section 3.3)
        let (c, d) = ([0.5f64, 1.5, 2.0], [100u64, 50, 10]);
        let vg = Allocation::Global.total_noise_sq(1.0, &c, &d);
        let want_g: f64 = c.iter().map(|x| x * x).sum::<f64>() * d.iter().sum::<u64>() as f64;
        assert!((vg - want_g).abs() / want_g < 1e-12);
        let ve = Allocation::EqualBudget.total_noise_sq(1.0, &c, &d);
        let want_e: f64 =
            3.0 * c.iter().zip(&d).map(|(x, &dd)| x * x * dd as f64).sum::<f64>();
        assert!((ve - want_e).abs() / want_e < 1e-12);
    }

    #[test]
    fn weighted_equalizes_per_coordinate_snr() {
        let (c, d) = ([1.0f64, 3.0], [4u64, 400]);
        let stds = Allocation::Weighted.stds(2.0, &c, &d);
        // per-coordinate snr ~ C_k/sqrt(d_k)/std_k identical across groups
        let r0 = c[0] / (d[0] as f64).sqrt() / stds[0];
        let r1 = c[1] / (d[1] as f64).sqrt() / stds[1];
        assert!((r0 - r1).abs() < 1e-12);
    }

    #[test]
    fn zero_threshold_yields_zero_std_not_nan() {
        // regression: C_k = 0 under EqualBudget/Weighted made gamma_k = 0
        // and the sensitivity term c/g = 0/0 = NaN, poisoning every std
        for alloc in [Allocation::EqualBudget, Allocation::Weighted] {
            let stds = alloc.stds(1.5, &[0.0, 2.0], &[10, 10]);
            assert!(stds.iter().all(|s| s.is_finite()), "{alloc:?}: {stds:?}");
            assert_eq!(stds[0], 0.0, "{alloc:?}: zero-C group gets zero std");
            assert!(stds[1] > 0.0, "{alloc:?}: nonzero group keeps noise");
            // the nonzero group is calibrated as if the zero group were
            // absent: S^2 only sums over groups that release anything
            let alone = alloc.stds(1.5, &[2.0], &[10]);
            assert!((stds[1] - alone[0]).abs() < 1e-12, "{alloc:?}");
            assert!(alloc.total_noise_sq(1.5, &[0.0, 2.0], &[10, 10]).is_finite());
        }
        // all-zero thresholds: nothing is released, nothing is noised
        let stds = Allocation::EqualBudget.stds(1.5, &[0.0, 0.0], &[4, 4]);
        assert_eq!(stds, vec![0.0, 0.0]);
    }

    #[test]
    fn noise_respects_std_zero() {
        let mut buf = vec![1.0f32; 8];
        let mut rng = Rng::seeded(7);
        add_noise(&mut buf, 0.0, &mut rng);
        assert_eq!(buf, vec![1.0; 8]);
    }

    #[test]
    fn stream_pos_sees_the_marsaglia_spare_where_uniform_cannot() {
        // two streams, one draws a single gauss (leaving a buffered
        // spare), the other draws gausses until its xoshiro state happens
        // to... — simpler and exact: same stream before/after drain. The
        // uniform()-only pin is blind to the spare; stream_pos is not.
        let mut a = Rng::seeded(11);
        let mut b = Rng::seeded(11);
        a.gauss();
        b.gauss();
        assert_eq!(a.stream_pos(), b.stream_pos());
        assert!(a.stream_pos().has_spare, "one gauss must buffer a spare");
        b.drain_spare();
        // xoshiro states still equal — a uniform() comparison passes...
        assert_eq!(a.stream_pos().state, b.stream_pos().state);
        // ...but the observable positions differ, and the next gauss
        // diverges exactly as the ISSUE's failure mode describes
        assert_ne!(a.stream_pos(), b.stream_pos());
        assert_ne!(a.gauss(), b.gauss());
    }

    #[test]
    fn drain_spare_resets_to_a_well_defined_position() {
        let mut a = Rng::seeded(12);
        let mut b = Rng::seeded(12);
        a.gauss(); // buffers a spare
        a.drain_spare();
        b.gauss();
        b.drain_spare();
        assert_eq!(a.stream_pos(), b.stream_pos());
        assert!(!a.stream_pos().has_spare);
        assert_eq!(a.gauss(), b.gauss());
    }

    #[test]
    fn split_children_are_independent_and_advance_parent_by_one() {
        let mut parent = Rng::seeded(13);
        let mut witness = Rng::seeded(13);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        // parent advanced exactly one u64 per split: replaying two
        // uniform()s on the witness lands on the same position
        witness.uniform();
        witness.uniform();
        assert_eq!(parent.stream_pos(), witness.stream_pos());
        // children are distinct streams, each deterministic from the
        // parent position (re-splitting a same-seed parent reproduces)
        let mut parent2 = Rng::seeded(13);
        let mut d1 = parent2.split();
        let mut d2 = parent2.split();
        assert_eq!(c1.stream_pos(), d1.stream_pos());
        assert_eq!(c2.stream_pos(), d2.stream_pos());
        assert_ne!(c1.stream_pos(), c2.stream_pos());
        for _ in 0..16 {
            assert_eq!(c1.gauss(), d1.gauss());
            assert_eq!(c2.gauss(), d2.gauss());
        }
        assert_ne!(c1.uniform(), c2.uniform());
    }
}
