//! Renyi-DP accountant for the Poisson-subsampled Gaussian mechanism, plus
//! the paper's Proposition 3.1 budget split between gradient noising and
//! private quantile estimation.
//!
//! This is the substrate Algorithm 1 line 2 calls `PrivacyAccountant`:
//! given (epsilon, delta, sampling rate rho, steps T) find the noise
//! multiplier sigma. We implement the standard integer-order RDP bound
//! (Mironov 2017; Abadi et al. 2016 moments accountant):
//!
//!   RDP(alpha) = 1/(alpha-1) * log sum_{k=0}^{alpha}
//!                C(alpha,k) (1-q)^{alpha-k} q^k exp(k(k-1)/(2 sigma^2))
//!
//! converted via epsilon = min_alpha [ T * RDP(alpha) + log(1/delta)/(alpha-1) ].
//!
//! Both backends account through this one path: the single-device trainer
//! and (since the Poisson-pipeline rework) the pipeline backend at
//! q = E[B]/n, with the legacy round-robin pipeline composing on the
//! q = 1 branch. Both branches are pinned against an independent
//! reference implementation of the TF-Privacy/Opacus integer-order
//! accountant by `tests/accountant_golden.rs`.

const ORDERS: std::ops::RangeInclusive<u32> = 2..=512;

/// RDP of one subsampled-Gaussian release at integer order `alpha`.
pub fn rdp_subsampled_gaussian(q: f64, sigma: f64, alpha: u32) -> f64 {
    assert!(alpha >= 2 && sigma > 0.0 && (0.0..=1.0).contains(&q));
    if q == 0.0 {
        return 0.0;
    }
    if (q - 1.0).abs() < 1e-12 {
        // no amplification: plain Gaussian RDP
        return alpha as f64 / (2.0 * sigma * sigma);
    }
    // log-sum-exp over k of log C(alpha,k) + (alpha-k) ln(1-q) + k ln q
    //                       + k(k-1)/(2 sigma^2)
    let a = alpha as f64;
    let lnq = q.ln();
    let ln1q = (1.0 - q).ln();
    let mut terms = Vec::with_capacity(alpha as usize + 1);
    let mut log_binom = 0.0; // log C(alpha, 0)
    for k in 0..=alpha {
        let kf = k as f64;
        terms.push(log_binom + (a - kf) * ln1q + kf * lnq + kf * (kf - 1.0) / (2.0 * sigma * sigma));
        // log C(alpha, k+1) = log C(alpha,k) + ln(alpha-k) - ln(k+1)
        if k < alpha {
            log_binom += ((a - kf).ln()) - ((kf + 1.0).ln());
        }
    }
    let m = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let lse = m + terms.iter().map(|t| (t - m).exp()).sum::<f64>().ln();
    (lse / (a - 1.0)).max(0.0)
}

/// (epsilon, best alpha) after `steps` compositions at sampling rate `q`.
pub fn epsilon_for(q: f64, sigma: f64, steps: u64, delta: f64) -> (f64, u32) {
    let mut best = (f64::INFINITY, 2u32);
    for alpha in ORDERS {
        let rdp = steps as f64 * rdp_subsampled_gaussian(q, sigma, alpha);
        let eps = rdp + (1.0 / delta).ln() / (alpha as f64 - 1.0);
        if eps < best.0 {
            best = (eps, alpha);
        }
    }
    best
}

/// Binary-search the noise multiplier achieving (epsilon, delta) over
/// `steps` releases at sampling rate `q` — Algorithm 1 line 2.
pub fn noise_multiplier(q: f64, steps: u64, epsilon: f64, delta: f64) -> f64 {
    assert!(epsilon > 0.0 && delta > 0.0 && steps > 0);
    let (mut lo, mut hi) = (1e-2, 1e4);
    // expand if even hi is insufficient (shouldn't happen for sane inputs)
    for _ in 0..200 {
        if epsilon_for(q, hi, steps, delta).0 <= epsilon {
            break;
        }
        hi *= 2.0;
    }
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if epsilon_for(q, mid, steps, delta).0 <= epsilon {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

/// Proposition 3.1: with `sigma` the no-quantile noise multiplier and
/// `sigma_b` the quantile-release multiplier for K groups, the gradient
/// noise multiplier becomes
///   sigma_new = (sigma^-2 - K / (2 sigma_b)^2)^(-1/2).
pub fn sigma_new(sigma: f64, sigma_b: f64, k_groups: usize) -> f64 {
    let inv = sigma.powi(-2) - (k_groups as f64) / (4.0 * sigma_b * sigma_b);
    assert!(
        inv > 0.0,
        "quantile budget too large: sigma_b={sigma_b} cannot support K={k_groups} at sigma={sigma}"
    );
    inv.powf(-0.5)
}

/// Remark 3.1: fraction of (RDP) budget consumed by quantile estimation.
pub fn quantile_budget_fraction(sigma: f64, sigma_b: f64, k_groups: usize) -> f64 {
    (k_groups as f64) * sigma * sigma / (4.0 * sigma_b * sigma_b)
}

/// Inverse of Remark 3.1: pick sigma_b so quantile estimation uses fraction
/// `r` of the budget (the paper uses r in [0.01%, 10%]).
pub fn sigma_b_for_fraction(sigma: f64, r: f64, k_groups: usize) -> f64 {
    assert!(r > 0.0 && r < 1.0);
    ((k_groups as f64) * sigma * sigma / (4.0 * r)).sqrt()
}

/// The unit of privacy the (epsilon, delta) guarantee protects.
///
/// Every release composed by the accountant is one Poisson-subsampled
/// Gaussian at rate `q`; the formula does not care whether the subsampled
/// record is an *example* or a *user's entire contribution*. What changes
/// is the neighbouring relation: under [`PrivacyUnit::User`] the clipped
/// quantity is the full per-user model delta, so adding or removing one
/// user (all of their examples at once) moves the aggregate by at most C,
/// and `q = E[U]/population` is a *user* sampling rate. The plan records
/// which reading applies so `describe()` and step events can report the
/// guarantee honestly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrivacyUnit {
    /// add/remove one training example (DP-SGD style)
    Example,
    /// add/remove one user and every example they contribute (DP-FedAvg style)
    User,
}

impl PrivacyUnit {
    pub fn token(&self) -> &'static str {
        match self {
            PrivacyUnit::Example => "example",
            PrivacyUnit::User => "user",
        }
    }
}

/// Everything the trainer needs, bundled.
#[derive(Debug, Clone, Copy)]
pub struct PrivacyPlan {
    pub epsilon: f64,
    pub delta: f64,
    pub q: f64,
    pub steps: u64,
    /// what one subsampled record is: an example or a whole user
    pub unit: PrivacyUnit,
    /// multiplier if all budget went to gradients
    pub sigma_base: f64,
    /// multiplier actually applied to gradients (after Prop 3.1 split)
    pub sigma_grad: f64,
    /// multiplier for the clip-count releases (0 if no quantile estimation)
    pub sigma_quantile: f64,
    pub quantile_fraction: f64,
}

/// Build a privacy plan. `r` = budget fraction for quantile estimation
/// (0 disables adaptive estimation), `k_groups` = number of clipped groups.
pub fn plan(
    epsilon: f64,
    delta: f64,
    q: f64,
    steps: u64,
    r: f64,
    k_groups: usize,
) -> PrivacyPlan {
    let sigma_base = noise_multiplier(q, steps, epsilon, delta);
    if r <= 0.0 {
        return PrivacyPlan {
            epsilon,
            delta,
            q,
            steps,
            unit: PrivacyUnit::Example,
            sigma_base,
            sigma_grad: sigma_base,
            sigma_quantile: 0.0,
            quantile_fraction: 0.0,
        };
    }
    let sigma_b = sigma_b_for_fraction(sigma_base, r, k_groups);
    PrivacyPlan {
        epsilon,
        delta,
        q,
        steps,
        unit: PrivacyUnit::Example,
        sigma_base,
        sigma_grad: sigma_new(sigma_base, sigma_b, k_groups),
        sigma_quantile: sigma_b,
        quantile_fraction: r,
    }
}

impl PrivacyPlan {
    /// Re-read the same calibrated plan as a user-level guarantee. The
    /// multipliers are untouched — the subsampled-Gaussian composition is
    /// identical — only the neighbouring relation recorded for reporting
    /// changes, which is exactly the DP-FedAvg argument: clip the per-user
    /// delta to C, noise with the same sigma, and (epsilon, delta) holds at
    /// q = E[U]/population with *user* in place of *example*.
    pub fn at_user_level(mut self) -> Self {
        self.unit = PrivacyUnit::User;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_rdp_no_subsampling() {
        // q=1: RDP(alpha) = alpha / (2 sigma^2)
        let r = rdp_subsampled_gaussian(1.0, 2.0, 8);
        assert!((r - 8.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn rdp_monotone_in_q_and_sigma() {
        let base = rdp_subsampled_gaussian(0.01, 1.0, 16);
        assert!(rdp_subsampled_gaussian(0.05, 1.0, 16) > base);
        assert!(rdp_subsampled_gaussian(0.01, 2.0, 16) < base);
        assert!(base > 0.0);
    }

    #[test]
    fn epsilon_decreases_with_sigma() {
        let e1 = epsilon_for(0.01, 0.8, 1000, 1e-5).0;
        let e2 = epsilon_for(0.01, 1.6, 1000, 1e-5).0;
        assert!(e2 < e1);
    }

    #[test]
    fn noise_multiplier_achieves_target() {
        for &(q, steps, eps) in &[(0.01, 1000u64, 3.0), (0.1, 500, 8.0), (0.02, 2000, 1.0)] {
            let sigma = noise_multiplier(q, steps, eps, 1e-5);
            let achieved = epsilon_for(q, sigma, steps, 1e-5).0;
            assert!(achieved <= eps * 1.001, "q={q} achieved={achieved} > {eps}");
            // and not over-noised by more than the search tolerance
            let slack = epsilon_for(q, sigma * 0.98, steps, 1e-5).0;
            assert!(slack > eps, "sigma not tight: {slack} <= {eps}");
        }
    }

    #[test]
    fn known_magnitude_sanity() {
        // Classic MNIST-ish setting: q=0.01, T=10000, delta=1e-5, eps~2
        // literature places sigma in the low single digits.
        let sigma = noise_multiplier(0.01, 10_000, 2.0, 1e-5);
        assert!(sigma > 0.5 && sigma < 5.0, "sigma={sigma}");
    }

    #[test]
    fn prop31_roundtrip() {
        let sigma = 1.3;
        let k = 20;
        let r = 0.1;
        let sb = sigma_b_for_fraction(sigma, r, k);
        assert!((quantile_budget_fraction(sigma, sb, k) - r).abs() < 1e-12);
        let sn = sigma_new(sigma, sb, k);
        // splitting budget must increase the gradient noise, mildly for small r
        assert!(sn > sigma);
        assert!(sn < sigma * 1.1);
        // closed form: sigma_new = sigma / sqrt(1 - r)
        assert!((sn - sigma / (1.0 - r).sqrt()).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "quantile budget too large")]
    fn prop31_rejects_overspend() {
        sigma_new(1.0, 0.1, 100);
    }

    #[test]
    fn plan_with_r0_is_pure_gradient_budget() {
        let p = plan(3.0, 1e-5, 0.05, 300, 0.0, 10);
        assert_eq!(p.sigma_base, p.sigma_grad);
        assert_eq!(p.sigma_quantile, 0.0);
    }
}
