//! Private quantile estimation for adaptive clipping thresholds
//! (Andrew et al. 2019, geometric update; Algorithm 1 lines 15-18).
//!
//! Each group k maintains a threshold C_k. After every step the trainer
//! reports b_k = #examples with ||g_k|| <= C_k; we privatize the fraction
//! with Gaussian noise sigma_b and update
//!     C_k <- C_k * exp(-eta * (b~_k - q_target)).

use super::noise::Rng;

#[derive(Debug, Clone)]
pub struct QuantileEstimator {
    pub thresholds: Vec<f64>,
    pub target_q: f64,
    pub eta: f64,
    /// noise std (in counts) applied to each b_k release; 0 = non-private
    /// (used only for the fixed-threshold ablations / tests).
    pub sigma_b: f64,
    /// expected batch size B used to normalize counts (Algorithm 1 line 16).
    pub batch: f64,
    adaptive: bool,
}

impl QuantileEstimator {
    pub fn adaptive(
        init: Vec<f64>,
        target_q: f64,
        eta: f64,
        sigma_b: f64,
        batch: f64,
    ) -> Self {
        QuantileEstimator { thresholds: init, target_q, eta, sigma_b, batch, adaptive: true }
    }

    /// Fixed thresholds: update() is a no-op (the paper's "fixed per-layer").
    pub fn fixed(init: Vec<f64>) -> Self {
        QuantileEstimator {
            thresholds: init,
            target_q: 0.0,
            eta: 0.0,
            sigma_b: 0.0,
            batch: 1.0,
            adaptive: false,
        }
    }

    pub fn k(&self) -> usize {
        self.thresholds.len()
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    /// One update from clip counts b_k (privatized inside). Returns the
    /// noisy fractions for diagnostics.
    pub fn update(&mut self, clip_counts: &[f64], rng: &mut Rng) -> Vec<f64> {
        assert_eq!(clip_counts.len(), self.thresholds.len());
        if !self.adaptive {
            return clip_counts.iter().map(|b| b / self.batch).collect();
        }
        let mut fracs = Vec::with_capacity(clip_counts.len());
        for (c, &b) in self.thresholds.iter_mut().zip(clip_counts) {
            let noisy = b + self.sigma_b * rng.gauss();
            let frac = noisy / self.batch;
            *c *= (-self.eta * (frac - self.target_q)).exp();
            // keep thresholds sane under extreme noise
            *c = c.clamp(1e-10, 1e10);
            fracs.push(frac);
        }
        fracs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_moves() {
        let mut q = QuantileEstimator::fixed(vec![1.0, 2.0]);
        let mut rng = Rng::seeded(0);
        q.update(&[0.0, 64.0], &mut rng);
        assert_eq!(q.thresholds, vec![1.0, 2.0]);
    }

    #[test]
    fn adapts_toward_target_quantile() {
        // norms drawn ~ U(0,1); target median -> threshold should approach
        // the 0.5 quantile (0.5) from a bad init.
        let mut q = QuantileEstimator::adaptive(vec![8.0], 0.5, 0.3, 0.0, 64.0);
        let mut rng = Rng::seeded(1);
        for _ in 0..400 {
            let c = q.thresholds[0];
            let below = (0..64).filter(|_| rng.uniform() <= c).count() as f64;
            q.update(&[below], &mut rng);
        }
        assert!(
            (q.thresholds[0] - 0.5).abs() < 0.15,
            "threshold {} should be near the median 0.5",
            q.thresholds[0]
        );
    }

    #[test]
    fn too_many_clipped_raises_threshold() {
        let mut q = QuantileEstimator::adaptive(vec![1.0], 0.5, 0.3, 0.0, 10.0);
        let mut rng = Rng::seeded(2);
        // b = 0 examples under the threshold (all clipped) -> C must grow
        q.update(&[0.0], &mut rng);
        assert!(q.thresholds[0] > 1.0);
        // everything under the threshold -> C must shrink
        let before = q.thresholds[0];
        q.update(&[10.0], &mut rng);
        assert!(q.thresholds[0] < before);
    }

    #[test]
    fn noise_is_applied_when_sigma_b_positive() {
        let mut a = QuantileEstimator::adaptive(vec![1.0], 0.5, 0.3, 5.0, 10.0);
        let mut b = QuantileEstimator::adaptive(vec![1.0], 0.5, 0.3, 5.0, 10.0);
        let mut r1 = Rng::seeded(3);
        let mut r2 = Rng::seeded(4);
        a.update(&[5.0], &mut r1);
        b.update(&[5.0], &mut r2);
        assert_ne!(a.thresholds[0], b.thresholds[0]);
    }
}
