//! Pipeline parallelism with per-device clipping (paper section 4).

pub mod engine;
pub mod schedule;

pub use engine::{merge_lora, PipelineEngine, PipelineMode, PipelineOpts};
