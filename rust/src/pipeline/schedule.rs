//! GPipe-style pipeline schedule (Huang et al. 2019) and its makespan
//! model.
//!
//! The paper's section-4 argument is about *schedule structure*: flat
//! clipping inserts synchronization barriers and a rematerialization pass
//! into the pipeline, per-device clipping does not. We execute ops
//! sequentially on the host (the PJRT CPU client already uses all cores
//! for a single executable, so real thread-parallel stages would just
//! contend), but time each op and replay the dependency DAG to compute the
//! makespan a real S-device pipeline would see. Both the measured total
//! and the simulated makespan are reported.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// forward of stage s, microbatch j
    Fwd,
    /// backward (any flavor) of stage s, microbatch j
    Bwd,
    /// rematerialization/regrad pass (flat-sync baseline only)
    Regrad,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Op {
    pub stage: usize,
    pub micro: usize,
    pub phase: Phase,
}

/// Sequential execution order for a GPipe step over `s` stages and `j`
/// microbatches: all forwards (wavefront), then all backwards (reverse
/// wavefront). The last stage's Fwd is fused with its Bwd (loss_bwd).
pub fn gpipe_order(s: usize, j: usize, with_regrad: bool) -> Vec<Op> {
    let mut ops = Vec::new();
    for m in 0..j {
        for st in 0..s.saturating_sub(1) {
            ops.push(Op { stage: st, micro: m, phase: Phase::Fwd });
        }
    }
    for m in 0..j {
        for st in (0..s).rev() {
            ops.push(Op { stage: st, micro: m, phase: Phase::Bwd });
        }
    }
    if with_regrad {
        for m in 0..j {
            for st in 0..s {
                ops.push(Op { stage: st, micro: m, phase: Phase::Regrad });
            }
        }
    }
    ops
}

/// Dependencies of an op under GPipe rules.
fn deps(op: &Op, s: usize) -> Vec<Op> {
    let mut d = Vec::new();
    match op.phase {
        Phase::Fwd => {
            if op.stage > 0 {
                d.push(Op { stage: op.stage - 1, micro: op.micro, phase: Phase::Fwd });
            }
        }
        Phase::Bwd => {
            if op.stage == s - 1 {
                // loss_bwd needs the incoming activation
                if s > 1 {
                    d.push(Op { stage: s - 2, micro: op.micro, phase: Phase::Fwd });
                }
            } else {
                d.push(Op { stage: op.stage + 1, micro: op.micro, phase: Phase::Bwd });
            }
        }
        Phase::Regrad => {
            // regrad waits on the global norm barrier: handled separately
        }
    }
    d
}

/// Simulated makespan of a step given per-op durations (seconds).
/// `barrier_before_regrad`: all Bwd ops must finish before any Regrad
/// starts (the flat-clipping all-gather of per-example norms), plus a
/// per-sync latency charge.
pub fn makespan(
    s: usize,
    j: usize,
    durations: &dyn Fn(&Op) -> f64,
    with_regrad: bool,
    sync_latency: f64,
) -> f64 {
    use std::collections::HashMap;
    let ops = gpipe_order(s, j, with_regrad);
    let mut finish: HashMap<Op, f64> = HashMap::new();
    let mut device_free = vec![0f64; s];
    let mut bwd_done = 0f64;
    // ops is already a valid topological order
    for op in &ops {
        if op.phase == Phase::Regrad {
            continue;
        }
        let mut start: f64 = device_free[op.stage];
        for dep in deps(op, s) {
            if let Some(&f) = finish.get(&dep) {
                start = start.max(f);
            }
        }
        let end = start + durations(op);
        finish.insert(*op, end);
        device_free[op.stage] = end;
        if op.phase == Phase::Bwd {
            bwd_done = bwd_done.max(end);
        }
    }
    if with_regrad {
        // barrier: leader gathers norms from every device
        let barrier = bwd_done + sync_latency;
        for d in device_free.iter_mut() {
            *d = d.max(barrier);
        }
        for op in &ops {
            if op.phase != Phase::Regrad {
                continue;
            }
            let start = device_free[op.stage];
            let end = start + durations(op);
            device_free[op.stage] = end;
        }
    }
    device_free.iter().cloned().fold(0.0, f64::max)
}

/// Per-stage gradient-ready times for a per-device GPipe step (no
/// regrad): entry `st` is the finish time of stage `st`'s LAST backward
/// op — the moment its summed gradient can enter a cross-replica
/// reduction. Returns `(ready, makespan)`; the makespan equals
/// [`makespan`] with `with_regrad = false`. Because backward drains from
/// the last stage toward the first, the ready times are non-decreasing in
/// `S-1, S-2, …, 0` order — the order the hybrid backend feeds them to
/// the FIFO reduction model.
pub fn stage_grad_ready(
    s: usize,
    j: usize,
    durations: &dyn Fn(&Op) -> f64,
) -> (Vec<f64>, f64) {
    use std::collections::HashMap;
    let ops = gpipe_order(s, j, false);
    let mut finish: HashMap<Op, f64> = HashMap::new();
    let mut device_free = vec![0f64; s];
    let mut ready = vec![0f64; s];
    for op in &ops {
        let mut start: f64 = device_free[op.stage];
        for dep in deps(op, s) {
            if let Some(&f) = finish.get(&dep) {
                start = start.max(f);
            }
        }
        let end = start + durations(op);
        finish.insert(*op, end);
        device_free[op.stage] = end;
        if op.phase == Phase::Bwd {
            ready[op.stage] = ready[op.stage].max(end);
        }
    }
    let span = device_free.iter().cloned().fold(0.0, f64::max);
    (ready, span)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_contains_all_ops() {
        let ops = gpipe_order(3, 4, false);
        // fwd: (3-1)*4, bwd: 3*4
        assert_eq!(ops.len(), 2 * 4 + 3 * 4);
        let ops_r = gpipe_order(3, 4, true);
        assert_eq!(ops_r.len(), ops.len() + 12);
    }

    #[test]
    fn pipeline_overlaps_microbatches() {
        // with unit op costs, a pipelined step is much shorter than
        // serial execution of all ops
        let dur = |_: &Op| 1.0;
        let m = makespan(4, 8, &dur, false, 0.0);
        let serial = (3 * 8 + 4 * 8) as f64;
        assert!(m < 0.6 * serial, "makespan {m} vs serial {serial}");
        // and no shorter than the critical path: J bwd ops on one device
        assert!(m >= 8.0);
    }

    #[test]
    fn regrad_strictly_slower() {
        let dur = |_: &Op| 1.0;
        let a = makespan(4, 4, &dur, false, 0.0);
        let b = makespan(4, 4, &dur, true, 0.5);
        assert!(b > a + 4.0 - 1e-9, "regrad {b} vs perdevice {a}");
    }

    #[test]
    fn single_stage_degenerates_to_serial() {
        let dur = |_: &Op| 2.0;
        // one stage: J fused loss_bwd ops only
        let m = makespan(1, 5, &dur, false, 0.0);
        assert!((m - 10.0).abs() < 1e-9);
    }

    #[test]
    fn stage_grad_ready_orders_stages_and_matches_makespan() {
        let dur = |op: &Op| match op.phase {
            Phase::Fwd => 1.0,
            _ => 2.0,
        };
        for (s, j) in [(1usize, 3usize), (2, 2), (4, 4)] {
            let (ready, span) = stage_grad_ready(s, j, &dur);
            assert_eq!(ready.len(), s);
            // backward drains last stage -> first: ready times non-increasing
            // from stage 0 down to stage S-1
            for st in 1..s {
                assert!(
                    ready[st] <= ready[st - 1] + 1e-12,
                    "s={s} j={j}: stage {st} ready {} before stage {}'s {}",
                    ready[st],
                    st - 1,
                    ready[st - 1]
                );
            }
            // the last gradient to arrive defines the backward makespan
            let m = makespan(s, j, &dur, false, 0.0);
            assert!((span - m).abs() < 1e-12);
            assert!((ready[0] - m).abs() < 1e-12, "stage 0 finishes last");
        }
    }
}
