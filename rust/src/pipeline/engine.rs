//! Private pipeline-parallel training backend (section 4, Algorithm 2).
//!
//! The model is partitioned into S stages ("devices"); each device owns its
//! parameter shard, its compiled stage executables, and its optimizer
//! state. Two DP training modes:
//!
//! * **Per-device clipping** (the paper's contribution): each device clips
//!   its local per-example gradient piece against its own threshold C_k and
//!   noises it with the equal-budget allocation — no cross-device
//!   communication beyond the usual activations (Algorithm 2).
//! * **Flat-sync baseline** (approach (iii) of section 4): backward pass 1
//!   computes local per-example norms only; a barrier all-gathers norms so
//!   the leader can form global clip factors; pass 2 *rematerializes*
//!   forward+backward on every device to emit the clipped sums.
//!
//! All DP state — thresholds, noise multiplier, quantile estimators, RNG —
//! lives in the session's shared [`StepLoop`](crate::session::StepLoop)
//! core (one estimator with S thresholds for per-device clipping), built
//! by `session::SessionBuilder` from the accountant; this engine only
//! implements the [`BackendStep`](crate::session::steploop::BackendStep)
//! hooks (deal / collect / merge) and touches no RNG, noise or quantile
//! state of its own. The legacy raw-sigma `PipelineEngine::new` shim is
//! retired; construction is crate-private and sigma is always
//! accountant-derived.
//!
//! Collection consumes fixed-capacity minibatches with a per-example 0/1
//! weight mask (`collect_weighted` / `collect_flat_sync`): Poisson draws
//! padded below the static minibatch carry weight-0 slots that every
//! stage executable multiplies into its clip coefficients, so padded
//! examples contribute zero gradient to every clip group — this is what
//! lets the session account the pipeline with subsampling amplification.
//!
//! Every executable call is timed and fed to the GPipe makespan model
//! (schedule.rs), so each step reports both measured host time and the
//! simulated S-device step latency.

use std::collections::HashMap;
use std::str::FromStr;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::noise::Rng;
use crate::coordinator::optimizer::{Optimizer, OptimizerKind, Schedule};
use crate::coordinator::sampler::{Batch, PoissonSampler};
use crate::data::{Dataset, ModelBatch};
use crate::kernels::Kernels;
use crate::runtime::{checkpoint, Exec, HostValue, Runtime, Tensor};
use crate::session::core::DpCore;
use crate::session::grad::{Collected, GradUnit, Merged, StepTiming, UnitCollected};
use crate::session::steploop::{BackendStep, UnitTask};

use super::schedule::{makespan, Op, Phase};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Algorithm 2: local clip + local equal-budget noise, zero extra comms
    PerDevice,
    /// flat clipping over the pipeline: norm all-gather + remat regrad
    FlatSync,
    /// no clipping, no noise (pretraining / utility ceiling)
    NonPrivate,
}

impl PipelineMode {
    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::PerDevice => "per-device clipping",
            PipelineMode::FlatSync => "flat clipping (sync + remat)",
            PipelineMode::NonPrivate => "non-private",
        }
    }

    /// Canonical CLI token; guaranteed to parse back via [`FromStr`].
    pub fn token(&self) -> &'static str {
        match self {
            PipelineMode::PerDevice => "per-device",
            PipelineMode::FlatSync => "flat-sync",
            PipelineMode::NonPrivate => "non-private",
        }
    }

    pub fn all() -> [PipelineMode; 3] {
        [PipelineMode::PerDevice, PipelineMode::FlatSync, PipelineMode::NonPrivate]
    }
}

impl FromStr for PipelineMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "per-device" | "perdevice" | "per_device" => PipelineMode::PerDevice,
            "flat-sync" | "flatsync" | "flat" => PipelineMode::FlatSync,
            "non-private" | "nonprivate" => PipelineMode::NonPrivate,
            _ => {
                return Err(anyhow!(
                    "unknown pipeline mode '{s}' (per-device|flat-sync|non-private)"
                ))
            }
        })
    }
}

/// Pipeline backend parameter bundle. No longer a public construction
/// surface — the raw-sigma `PipelineEngine::new` shim is retired and the
/// session builder fills this from a declarative
/// [`crate::session::RunSpec`]; noise never appears here (the session's
/// shared `StepLoop` core owns it).
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    pub mode: PipelineMode,
    /// microbatches per minibatch (J in Algorithm 2)
    pub n_micro: usize,
    /// expected live batch E[B] normalizing the summed gradients (Poisson
    /// sampling leaves some slots padded); 0 = the full static minibatch
    pub expected_batch: usize,
    /// per-device threshold init (PerDevice) or global threshold (FlatSync)
    pub clip: f64,
    pub lr: f64,
    pub optimizer: OptimizerKind,
    pub seed: u64,
    /// simulated all-gather latency charged per sync barrier (seconds)
    pub sync_latency: f64,
    /// adapt per-device thresholds with the quantile estimator
    pub adaptive: bool,
    pub target_q: f64,
    pub quantile_eta: f64,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts {
            mode: PipelineMode::PerDevice,
            n_micro: 4,
            expected_batch: 0,
            clip: 1.0,
            lr: 1e-3,
            optimizer: OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            seed: 0,
            sync_latency: 0.002,
            adaptive: false,
            target_q: 0.85,
            quantile_eta: 0.3,
        }
    }
}

struct StageDevice {
    params: Vec<Tensor>,
    param_names: Vec<String>,
    trainable_pos: Vec<usize>,
    optimizer: Optimizer,
    /// gradient accumulator, one per trainable tensor
    accum: Vec<Tensor>,
    fwd: Option<Arc<Exec>>,
    bwd: Option<Arc<Exec>>,
    bwd_norm: Option<Arc<Exec>>,
    regrad: Option<Arc<Exec>>,
    loss_bwd: Option<Arc<Exec>>,
    loss_norm: Option<Arc<Exec>>,
    loss_regrad: Option<Arc<Exec>>,
    eval: Option<Arc<Exec>>,
}

/// Pre-noise output of one collected per-device step: the raw per-stage
/// summed trainable gradients plus everything the caller needs to finish
/// the step (noise, normalization, threshold update, makespan). This is
/// the seam the hybrid backend composes through — one `CollectedStep` per
/// data-parallel replica, merged across replicas before noise is applied.
pub(crate) struct CollectedStep {
    /// summed trainable gradients per stage, pre-noise, un-normalized
    pub grads: Vec<Vec<Tensor>>,
    /// live examples whose stage-piece norm fell at or under the stage
    /// threshold, per stage (the adaptive quantile statistic)
    pub clip_counts: Vec<f64>,
    /// measured per-op durations for the makespan model
    pub durations: HashMap<Op, f64>,
    pub loss_wsum: f64,
    pub weight_sum: f64,
    pub calls: usize,
    /// synchronization barriers this collection required (1 end-of-step
    /// optimizer barrier; flat-sync adds its norm all-gather)
    pub syncs: usize,
}

/// Live (weight > 0) examples whose reported norm is at or under `thr`;
/// padded slots carry real norms for masked content and must not leak
/// into the private quantile statistic.
fn count_clipped(norms: &Tensor, weights: &[f32], thr: f64) -> f64 {
    norms
        .data
        .iter()
        .zip(weights)
        .filter(|&(&n, &w)| w > 0.0 && (n as f64) <= thr)
        .count() as f64
}

pub struct PipelineEngine<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub opts: PipelineOpts,
    pub n_stages: usize,
    micro_batch: usize,
    devices: Vec<StageDevice>,
    /// Poisson draw source for the session path (None = the legacy
    /// round-robin cursor); hybrid replica engines never set one — the
    /// hybrid backend deals ONE global draw itself
    sampler: Option<PoissonSampler>,
    /// round-robin minibatch cursor (sampling = round_robin)
    cursor: usize,
    /// dispatched SIMD vtable (gradient accumulation; forwarded into the
    /// per-stage optimizers)
    kernels: Kernels,
}

impl<'r> PipelineEngine<'r> {
    /// Crate-private constructor: backend wiring only. All DP state lives
    /// in the session's `StepLoop`; `core` is borrowed here only to
    /// validate the group-count contract (K = stage count for per-device
    /// clipping, 1 otherwise).
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        opts: PipelineOpts,
        core: &DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        let ck = checkpoint::read(runtime.manifest.hlo_path(&cfg.init_checkpoint))?;
        Self::with_core_from_ck(runtime, config_name, opts, Some(core), &ck)
    }

    /// [`PipelineEngine::with_core`] against an already-read init
    /// checkpoint map: the hybrid backend reads the checkpoint ONCE and
    /// fans it out to its R replica engines (the same single-read pattern
    /// as `Runtime::init_replicas`), passing `core = None` — replica
    /// engines receive thresholds explicitly through `collect_weighted`
    /// and are never driven by a core of their own.
    pub(crate) fn with_core_from_ck(
        runtime: &'r Runtime,
        config_name: &str,
        opts: PipelineOpts,
        core: Option<&DpCore>,
        ck: &HashMap<String, Tensor>,
    ) -> Result<Self> {
        if opts.n_micro == 0 {
            return Err(anyhow!("pipeline needs n_micro > 0"));
        }
        let cfg = runtime.manifest.config(config_name)?.clone();
        let stages = cfg
            .stages
            .clone()
            .ok_or_else(|| anyhow!("config {config_name} has no pipeline stages"))?;
        let n_stages = stages.stages.len();
        let expect_k = if opts.mode == PipelineMode::PerDevice { n_stages } else { 1 };
        if let Some(core) = core {
            if core.k() != expect_k {
                return Err(anyhow!(
                    "DpCore has {} thresholds but {} over {} stages needs {}",
                    core.k(),
                    opts.mode.name(),
                    n_stages,
                    expect_k
                ));
            }
        }

        let mut devices = Vec::with_capacity(n_stages);
        for (s, sinfo) in stages.stages.iter().enumerate() {
            let last = s == n_stages - 1;
            let params: Vec<Tensor> = sinfo
                .params
                .iter()
                .map(|n| ck.get(n).cloned().ok_or_else(|| anyhow!("checkpoint missing {n}")))
                .collect::<Result<_>>()?;
            let trainable_pos: Vec<usize> = sinfo
                .params
                .iter()
                .enumerate()
                .filter(|(_, n)| sinfo.trainable.contains(n))
                .map(|(i, _)| i)
                .collect();
            let tr: Vec<Tensor> = trainable_pos.iter().map(|&i| params[i].clone()).collect();
            let accum = tr.iter().map(|t| Tensor::zeros(&t.shape)).collect();
            let load = |e: String| runtime.load(config_name, &e).ok();
            let pre = format!("stage{s}");
            devices.push(StageDevice {
                optimizer: Optimizer::new(opts.optimizer, Schedule::constant(opts.lr), 0.0, &tr),
                params,
                param_names: sinfo.params.clone(),
                trainable_pos,
                accum,
                fwd: if last { None } else { load(format!("{pre}_fwd")) },
                bwd: if last { None } else { load(format!("{pre}_bwd")) },
                bwd_norm: if last { None } else { load(format!("{pre}_bwd_norm")) },
                regrad: if last { None } else { load(format!("{pre}_regrad")) },
                loss_bwd: if last { load(format!("{pre}_loss_bwd")) } else { None },
                loss_norm: if last { load(format!("{pre}_loss_norm")) } else { None },
                loss_regrad: if last { load(format!("{pre}_loss_regrad")) } else { None },
                eval: if last { load(format!("{pre}_eval")) } else { None },
            });
        }
        Ok(PipelineEngine {
            runtime,
            config_name: config_name.to_string(),
            n_stages,
            micro_batch: cfg.batch,
            devices,
            sampler: None,
            cursor: 0,
            kernels: Kernels::default(),
            opts,
        })
    }

    /// Install the session's dispatched kernel vtable on the engine and
    /// every stage optimizer.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
        for d in self.devices.iter_mut() {
            d.optimizer.set_kernels(kernels);
        }
    }

    /// Install the session's Poisson draw source (None keeps the legacy
    /// round-robin cursor). Called by the builder only.
    pub(crate) fn set_sampler(&mut self, sampler: Option<PoissonSampler>) {
        self.sampler = sampler;
    }

    pub fn micro_batch(&self) -> usize {
        self.micro_batch
    }

    /// minibatch size = microbatch * J
    pub fn minibatch(&self) -> usize {
        self.micro_batch * self.opts.n_micro
    }

    /// Load stage parameters from a full-model checkpoint map (e.g. a
    /// non-privately pretrained model for the fine-tuning experiments).
    /// Missing names keep their init values (LoRA adapters).
    pub fn load_params(&mut self, map: &HashMap<String, Tensor>) -> Result<()> {
        for d in &mut self.devices {
            for (i, n) in d.param_names.iter().enumerate() {
                if let Some(t) = map.get(n) {
                    if t.shape != d.params[i].shape {
                        return Err(anyhow!("shape mismatch for {n}"));
                    }
                    d.params[i] = t.clone();
                }
            }
        }
        Ok(())
    }

    /// Round-robin data cursor (legacy non-Poisson sampling). The one
    /// piece of engine-held mutable draw state, so snapshots persist it.
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    pub fn set_cursor(&mut self, cursor: usize) {
        self.cursor = cursor;
    }

    /// Per-stage optimizer states, stage order (snapshot capture).
    pub fn stage_optimizers(&self) -> Vec<&Optimizer> {
        self.devices.iter().map(|d| &d.optimizer).collect()
    }

    pub fn stage_optimizers_mut(&mut self) -> Vec<&mut Optimizer> {
        self.devices.iter_mut().map(|d| &mut d.optimizer).collect()
    }

    /// Dump all stage parameters into one map (checkpointing / LoRA merge).
    pub fn dump_params(&self) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        for d in &self.devices {
            for (n, t) in d.param_names.iter().zip(&d.params) {
                m.insert(n.clone(), t.clone());
            }
        }
        m
    }

    fn stage_x_in(
        &self,
        st: usize,
        m: usize,
        tokens: &[(HostValue, HostValue)],
        acts: &[Vec<Option<Tensor>>],
    ) -> HostValue {
        if st == 0 {
            tokens[m].0.clone()
        } else {
            HostValue::F32(acts[st][m].clone().unwrap())
        }
    }

    /// Expected live batch E[B] normalizing the summed gradients
    /// (Algorithm 1 line 14): the spec's override, or the full static
    /// minibatch.
    fn expected(&self) -> f64 {
        if self.opts.expected_batch > 0 {
            self.opts.expected_batch as f64
        } else {
            self.minibatch() as f64
        }
    }

    /// Run one per-device (or non-private) step up to — but not including —
    /// noise, normalization and the optimizer update: forward wavefront,
    /// fused backward+clip against the EXPLICIT per-stage `thresholds`,
    /// gradient accumulation, clip counting. Consumes no RNG and reads no
    /// thresholds from the core, which is what lets the hybrid backend
    /// drive R replica engines from one shared `DpCore` and merge their
    /// pre-noise per-stage gradient sums across replicas.
    pub(crate) fn collect_weighted(
        &mut self,
        data: &dyn Dataset,
        indices: &[usize],
        weights: &[f32],
        thresholds: &[f64],
    ) -> Result<CollectedStep> {
        assert_eq!(indices.len(), self.minibatch());
        assert_eq!(weights.len(), self.minibatch());
        let s = self.n_stages;
        assert_eq!(thresholds.len(), s);
        if self.opts.mode == PipelineMode::FlatSync {
            return Err(anyhow!("collect_weighted serves per-device/non-private modes only"));
        }
        let nonpriv = self.opts.mode == PipelineMode::NonPrivate;
        let j = self.opts.n_micro;
        let b = self.micro_batch;
        let mut durations: HashMap<Op, f64> = HashMap::new();
        let mut calls = 0usize;

        let micro: Vec<ModelBatch> =
            (0..j).map(|m| data.batch(&indices[m * b..(m + 1) * b])).collect();
        let tokens: Vec<(HostValue, HostValue)> = micro.iter().map(|m| m.inputs()).collect();
        // per-microbatch weight tensors fed to every backward executable
        let micro_w: Vec<Tensor> = (0..j)
            .map(|m| Tensor::from_vec(&[b], weights[m * b..(m + 1) * b].to_vec()))
            .collect::<Result<_>>()?;

        let acts = self.forward_wavefront(&tokens, &mut durations, &mut calls)?;

        let mut clip_counts = vec![0f64; s];
        // global weighted mean across ALL live examples (sum_m loss_m *
        // livecount_m / sum_m livecount_m), so unevenly padded microbatches
        // weigh examples equally — matching the single-device backend's
        // definition
        let mut loss_wsum = 0f64;
        let mut weight_sum = 0f64;

        for m in 0..j {
            // last stage: fused loss+bwd, clipping local piece
            let c_last = if nonpriv { 1e9 } else { thresholds[s - 1] };
            let x_in = self.stage_x_in(s - 1, m, &tokens, &acts);
            let dlast = &self.devices[s - 1];
            let exec = dlast.loss_bwd.as_ref().unwrap().clone();
            let t0 = Instant::now();
            let outs = exec.call(
                &dlast.params,
                &[
                    x_in,
                    tokens[m].1.clone(),
                    HostValue::F32(Tensor::scalar(c_last as f32)),
                    HostValue::F32(micro_w[m].clone()),
                ],
            )?;
            durations.insert(
                Op { stage: s - 1, micro: m, phase: Phase::Bwd },
                t0.elapsed().as_secs_f64(),
            );
            calls += 1;
            // the executable reports the weighted MEAN over this
            // microbatch; recover the weighted sum via the live weight
            // mass so the step loss is a global mean
            let w_m: f64 = weights[m * b..(m + 1) * b].iter().map(|&w| w as f64).sum();
            loss_wsum += outs[0].data[0] as f64 * w_m;
            weight_sum += w_m;
            let mut dy = outs[1].clone();
            let n_tr = self.devices[s - 1].trainable_pos.len();
            let norms = outs[2 + n_tr].clone();
            self.accumulate(s - 1, &outs[2..2 + n_tr]);
            clip_counts[s - 1] +=
                count_clipped(&norms, &weights[m * b..(m + 1) * b], thresholds[s - 1]);

            for st in (0..s - 1).rev() {
                let c = if nonpriv { 1e9 } else { thresholds[st] };
                let x_in = self.stage_x_in(st, m, &tokens, &acts);
                let d = &self.devices[st];
                let exec = d.bwd.as_ref().unwrap().clone();
                let t0 = Instant::now();
                let outs = exec.call(
                    &d.params,
                    &[
                        x_in,
                        HostValue::F32(dy),
                        HostValue::F32(Tensor::scalar(c as f32)),
                        HostValue::F32(micro_w[m].clone()),
                    ],
                )?;
                durations.insert(
                    Op { stage: st, micro: m, phase: Phase::Bwd },
                    t0.elapsed().as_secs_f64(),
                );
                calls += 1;
                dy = outs[0].clone();
                let n_tr = self.devices[st].trainable_pos.len();
                let norms = outs[1 + n_tr].clone();
                self.accumulate(st, &outs[1..1 + n_tr]);
                clip_counts[st] +=
                    count_clipped(&norms, &weights[m * b..(m + 1) * b], thresholds[st]);
            }
        }

        // drain the per-stage accumulators into the returned gradient set
        let grads: Vec<Vec<Tensor>> = self
            .devices
            .iter_mut()
            .map(|d| {
                d.accum
                    .iter_mut()
                    .map(|a| std::mem::replace(a, Tensor::zeros(&a.shape)))
                    .collect()
            })
            .collect();

        Ok(CollectedStep {
            grads,
            clip_counts,
            durations,
            loss_wsum,
            weight_sum,
            calls,
            syncs: 1, // end-of-step optimizer barrier
        })
    }

    /// Apply an already-noised, already-normalized flattened
    /// (stage-major) gradient set through this replica's per-stage
    /// optimizers — the [`BackendStep`] update path, also used by the
    /// hybrid backend to broadcast the merged update to its replicas.
    pub(crate) fn apply_flat(&mut self, grads: &[Tensor]) {
        let mut off = 0usize;
        for d in self.devices.iter_mut() {
            let n = d.trainable_pos.len();
            d.optimizer.apply_indexed(&mut d.params, &d.trainable_pos, &grads[off..off + n]);
            off += n;
        }
        debug_assert_eq!(off, grads.len());
    }

    /// Trainable tensor count per stage (the hybrid backend regroups its
    /// flattened stage-major units with these offsets).
    pub(crate) fn stage_trainable_counts(&self) -> Vec<usize> {
        self.devices.iter().map(|d| d.trainable_pos.len()).collect()
    }

    /// Trainable element count per stage (sizes the cross-replica
    /// reduction payload in the hybrid makespan model).
    pub(crate) fn stage_trainable_dims(&self) -> Vec<f64> {
        self.devices
            .iter()
            .map(|d| {
                d.trainable_pos
                    .iter()
                    .map(|&i| d.params[i].data.len() as f64)
                    .sum()
            })
            .collect()
    }

    /// Forward wavefront: `acts[st][m]` = input activation of stage `st`
    /// for microbatch `m` (stage 0 consumes the tokens directly).
    fn forward_wavefront(
        &self,
        tokens: &[(HostValue, HostValue)],
        durations: &mut HashMap<Op, f64>,
        calls: &mut usize,
    ) -> Result<Vec<Vec<Option<Tensor>>>> {
        let s = self.n_stages;
        let j = self.opts.n_micro;
        let mut acts: Vec<Vec<Option<Tensor>>> = vec![vec![None; j]; s];
        for m in 0..j {
            for st in 0..s - 1 {
                let x_in = self.stage_x_in(st, m, tokens, &acts);
                let d = &self.devices[st];
                let t0 = Instant::now();
                let out = d.fwd.as_ref().unwrap().call(&d.params, &[x_in])?;
                durations.insert(
                    Op { stage: st, micro: m, phase: Phase::Fwd },
                    t0.elapsed().as_secs_f64(),
                );
                *calls += 1;
                acts[st + 1][m] = Some(out.into_iter().next().unwrap());
            }
        }
        Ok(acts)
    }

    /// The flat-sync baseline collection (approach (iii) of section 4):
    /// pass 1 computes local per-example norms, a barrier all-gathers
    /// them so the leader can form global clip coefficients against the
    /// EXPLICIT `c_global`, pass 2 rematerializes forward+backward to
    /// emit the clipped sums. Like [`PipelineEngine::collect_weighted`]
    /// this stops BEFORE noise/normalization/update and consumes no RNG.
    fn collect_flat_sync(
        &mut self,
        data: &dyn Dataset,
        indices: &[usize],
        weights: &[f32],
        c_global: f64,
    ) -> Result<CollectedStep> {
        assert_eq!(indices.len(), self.minibatch());
        assert_eq!(weights.len(), self.minibatch());
        let j = self.opts.n_micro;
        let s = self.n_stages;
        let b = self.micro_batch;
        let mut durations: HashMap<Op, f64> = HashMap::new();
        let mut calls = 0usize;

        let micro: Vec<ModelBatch> =
            (0..j).map(|m| data.batch(&indices[m * b..(m + 1) * b])).collect();
        let tokens: Vec<(HostValue, HostValue)> = micro.iter().map(|m| m.inputs()).collect();

        let acts = self.forward_wavefront(&tokens, &mut durations, &mut calls)?;

        let mut loss_total = 0f64;
        let mut syncs = 1usize; // end-of-step optimizer barrier

        // pass 1 -> norm barrier -> rematerialized pass 2 (temporaries scoped)
        {
            // pass 1: local norms only; cache the dy each stage consumed
            let mut dy_in: Vec<Vec<Option<Tensor>>> = vec![vec![None; j]; s];
            let mut local_norms: Vec<Vec<Vec<f32>>> =
                (0..s).map(|_| vec![Vec::new(); j]).collect();
            for m in 0..j {
                let x_in = self.stage_x_in(s - 1, m, &tokens, &acts);
                let dlast = &self.devices[s - 1];
                let exec = dlast.loss_norm.as_ref().unwrap().clone();
                let t0 = Instant::now();
                let outs = exec.call(&dlast.params, &[x_in, tokens[m].1.clone()])?;
                durations.insert(
                    Op { stage: s - 1, micro: m, phase: Phase::Bwd },
                    t0.elapsed().as_secs_f64(),
                );
                calls += 1;
                // pass-1 loss is the executable's unweighted mean (the
                // norm pass takes no weights); with padded batches the
                // reported loss is a diagnostic approximation, while
                // the gradients below are exactly masked via coeffs
                loss_total += outs[0].data[0] as f64;
                let mut dy = outs[1].clone();
                local_norms[s - 1][m] = outs[2].data.clone();

                for st in (0..s - 1).rev() {
                    dy_in[st][m] = Some(dy.clone());
                    let x_in = self.stage_x_in(st, m, &tokens, &acts);
                    let d = &self.devices[st];
                    let exec = d.bwd_norm.as_ref().unwrap().clone();
                    let t0 = Instant::now();
                    let outs = exec.call(&d.params, &[x_in, HostValue::F32(dy)])?;
                    durations.insert(
                        Op { stage: st, micro: m, phase: Phase::Bwd },
                        t0.elapsed().as_secs_f64(),
                    );
                    calls += 1;
                    dy = outs[0].clone();
                    local_norms[st][m] = outs[1].data.clone();
                }
            }

            // barrier: all-gather per-example norms, form global coeffs
            // (each coeff carries the example's 0/1 weight so padded
            // slots emit zero gradient from the regrad pass)
            syncs += 1;
            let mut coeffs: Vec<Tensor> = Vec::with_capacity(j);
            for m in 0..j {
                let mut c = Vec::with_capacity(b);
                for i in 0..b {
                    let sq: f64 = (0..s)
                        .map(|st| {
                            let v = local_norms[st][m][i] as f64;
                            v * v
                        })
                        .sum();
                    let w = weights[m * b + i] as f64;
                    c.push((w * (c_global / sq.sqrt().max(1e-12)).min(1.0)) as f32);
                }
                coeffs.push(Tensor::from_vec(&[b], c)?);
            }

            // pass 2: rematerialize + clipped sums
            for m in 0..j {
                for st in 0..s {
                    let last = st == s - 1;
                    let x_in = self.stage_x_in(st, m, &tokens, &acts);
                    let d = &self.devices[st];
                    let t0 = Instant::now();
                    let outs = if last {
                        d.loss_regrad.as_ref().unwrap().call(
                            &d.params,
                            &[x_in, tokens[m].1.clone(), HostValue::F32(coeffs[m].clone())],
                        )?
                    } else {
                        d.regrad.as_ref().unwrap().call(
                            &d.params,
                            &[
                                x_in,
                                HostValue::F32(dy_in[st][m].clone().unwrap()),
                                HostValue::F32(coeffs[m].clone()),
                            ],
                        )?
                    };
                    durations.insert(
                        Op { stage: st, micro: m, phase: Phase::Regrad },
                        t0.elapsed().as_secs_f64(),
                    );
                    calls += 1;
                    self.accumulate(st, &outs);
                }
            }
        }

        // drain the per-stage accumulators into the returned gradient set
        // (noise, normalization and the update happen in the StepLoop)
        let grads: Vec<Vec<Tensor>> = self
            .devices
            .iter_mut()
            .map(|d| {
                d.accum
                    .iter_mut()
                    .map(|a| std::mem::replace(a, Tensor::zeros(&a.shape)))
                    .collect()
            })
            .collect();

        Ok(CollectedStep {
            grads,
            clip_counts: vec![0.0; s],
            durations,
            // flat-sync pass 1 reports unweighted per-micro means only;
            // encode the loss convention as (sum of means, count)
            loss_wsum: loss_total,
            weight_sum: j as f64,
            calls,
            syncs,
        })
    }

    fn accumulate(&mut self, stage: usize, grads: &[Tensor]) {
        let d = &mut self.devices[stage];
        for (a, g) in d.accum.iter_mut().zip(grads) {
            self.kernels.add_assign(&mut a.data, &g.data);
        }
    }

    /// Mean eval loss over `data` through the pipeline.
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<f64> {
        let b = self.micro_batch;
        let s = self.n_stages;
        let mut loss_sum = 0f64;
        let mut weight = 0f64;
        for batch in crate::coordinator::sampler::EvalIter::new(data.len(), b) {
            let mb = data.batch(&batch.indices);
            let (x, y) = mb.inputs();
            let mut cur = x;
            for st in 0..s - 1 {
                let d = &self.devices[st];
                let out = d.fwd.as_ref().unwrap().call(&d.params, &[cur])?;
                cur = HostValue::F32(out.into_iter().next().unwrap());
            }
            let dlast = &self.devices[s - 1];
            let outs = dlast.eval.as_ref().unwrap().call(
                &dlast.params,
                &[cur, y, HostValue::F32(Tensor::from_vec(&[b], batch.weights.clone())?)],
            )?;
            loss_sum += outs[0].data[0] as f64;
            weight += outs[1].data[0] as f64;
        }
        Ok(loss_sum / weight.max(1.0))
    }

    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let map = self.dump_params();
        let mut items: Vec<(String, &Tensor)> = map.iter().map(|(k, v)| (k.clone(), v)).collect();
        items.sort_by(|a, b| a.0.cmp(&b.0));
        checkpoint::write(path, &items)
    }
}

impl BackendStep for PipelineEngine<'_> {
    type Slices = Batch;

    fn deal(&mut self, n_data: usize, rng: &mut Rng) -> Batch {
        match &self.sampler {
            // padded Poisson draw from the shared core RNG — the same
            // sampler discipline as the single-device backend
            Some(s) => s.sample_padded(rng),
            // legacy deterministic cursor (sampling = round_robin): every
            // slot live, no RNG consumed
            None => {
                let mb = self.minibatch();
                let base = self.cursor * mb;
                self.cursor += 1;
                Batch {
                    indices: (0..mb).map(|i| (base + i) % n_data.max(1)).collect(),
                    weights: vec![1.0; mb],
                    truncated: 0,
                }
            }
        }
    }

    fn collect_tasks<'a>(
        &'a mut self,
        data: &'a dyn Dataset,
        batch: &'a Batch,
        thresholds: &'a [f64],
    ) -> Vec<UnitTask<'a>> {
        // one pipeline is ONE data-parallel unit: the whole wavefront is a
        // single task (the S simulated stages share the engine's activation
        // and accumulator state), so the task moves the &mut engine borrow
        // into the closure wholesale
        vec![Box::new(move || {
            let s = self.n_stages;
            let per_device = self.opts.mode == PipelineMode::PerDevice;
            let col = match self.opts.mode {
                PipelineMode::FlatSync => {
                    self.collect_flat_sync(data, &batch.indices, &batch.weights, thresholds[0])?
                }
                PipelineMode::PerDevice => {
                    assert_eq!(thresholds.len(), s);
                    self.collect_weighted(data, &batch.indices, &batch.weights, thresholds)?
                }
                // non-private: thresholds are ignored stage-side (clip = 1e9)
                PipelineMode::NonPrivate => {
                    let thr = vec![thresholds[0]; s];
                    self.collect_weighted(data, &batch.indices, &batch.weights, &thr)?
                }
            };
            // flatten stage-major: the unit layout IS the engine's
            // documented noise order (stage-major, tensor order within the
            // stage)
            let mut tensors = Vec::new();
            let mut groups = Vec::new();
            for (st, g) in col.grads.into_iter().enumerate() {
                let gi = if per_device { st } else { 0 };
                for t in g {
                    tensors.push(t);
                    groups.push(gi);
                }
            }
            let mut part = UnitCollected::new(GradUnit { tensors, groups }, thresholds.len());
            if per_device {
                part.clip_counts = col.clip_counts;
            }
            part.loss_wsum = col.loss_wsum;
            part.weight_sum = col.weight_sum;
            part.live = batch.live();
            part.calls = col.calls;
            part.syncs = col.syncs;
            part.durations = col.durations;
            Ok(part)
        })]
    }

    fn finish_collect(&mut self, batch: &Batch, parts: Vec<UnitCollected>) -> Result<Collected> {
        let mut parts = parts;
        let p = parts.pop().expect("pipeline collection emits exactly one task");
        debug_assert!(parts.is_empty());
        Ok(Collected {
            units: vec![p.unit],
            clip_counts: p.clip_counts,
            // the pipeline never reports clip fractions (cross-device norm
            // matrices are never materialized)
            clip_denoms: Vec::new(),
            mean_norms: Vec::new(),
            loss: p.loss_wsum / p.weight_sum.max(1.0),
            live: batch.live(),
            truncated: batch.truncated,
            calls: p.calls,
            syncs: p.syncs,
            timing: StepTiming { durations: vec![p.durations], bwd_secs: Vec::new() },
        })
    }

    fn merge(&mut self, units: Vec<GradUnit>, timing: &StepTiming) -> Merged {
        // one pipeline is one data-parallel unit: the merge is the bitwise
        // identity, and the "reduction" model is the GPipe schedule replay
        let sim = makespan(
            self.n_stages,
            self.opts.n_micro,
            &|op| timing.durations[0].get(op).copied().unwrap_or(0.0),
            self.opts.mode == PipelineMode::FlatSync,
            self.opts.sync_latency,
        );
        let mut m = Merged::identity(units);
        m.sim_secs = sim;
        m
    }

    fn apply(&mut self, grads: &[Tensor]) {
        self.apply_flat(grads);
    }

    fn update_scale(&self, _live: usize) -> f32 {
        // every pipeline mode normalizes the summed gradients by E[B]
        (1.0 / self.expected()) as f32
    }

    fn prefetch_lists(&self, batch: &Batch) -> Vec<Vec<usize>> {
        // collection assembles one ModelBatch per microbatch, sliced from
        // the dealt minibatch in J fixed-size chunks
        let b = self.micro_batch;
        (0..self.opts.n_micro)
            .map(|m| batch.indices[m * b..(m + 1) * b].to_vec())
            .collect()
    }
}

/// Merge LoRA adapters into base weights: W_eff = W + (scale/r) * A @ B.
/// Used to decode from a LoRA-fine-tuned pipeline with the full-model
/// `logits` entry of the base config.
pub fn merge_lora(
    base: &mut HashMap<String, Tensor>,
    lora: &HashMap<String, Tensor>,
    rank: usize,
    scale: f64,
) -> Result<usize> {
    let alpha = (scale / rank as f64) as f32;
    let mut merged = 0;
    let keys: Vec<String> = lora
        .keys()
        .filter(|k| k.ends_with(".lora_a"))
        .cloned()
        .collect();
    for ka in keys {
        let stem = ka.trim_end_matches(".lora_a");
        let kb = format!("{stem}.lora_b");
        let kw = format!("{stem}.w");
        let a = &lora[&ka];
        let b = lora
            .get(&kb)
            .ok_or_else(|| anyhow!("missing {kb}"))?;
        let w = base
            .get_mut(&kw)
            .ok_or_else(|| anyhow!("missing base weight {kw}"))?;
        let (d_in, r) = (a.shape[0], a.shape[1]);
        let d_out = b.shape[1];
        if w.shape != vec![d_in, d_out] || b.shape[0] != r {
            return Err(anyhow!("lora shape mismatch at {stem}"));
        }
        for i in 0..d_in {
            for k in 0..r {
                let av = a.data[i * r + k] * alpha;
                if av == 0.0 {
                    continue;
                }
                for o in 0..d_out {
                    w.data[i * d_out + o] += av * b.data[k * d_out + o];
                }
            }
        }
        merged += 1;
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_lora_rank1_by_hand() {
        let mut base = HashMap::new();
        base.insert(
            "l.w".to_string(),
            Tensor::from_vec(&[2, 2], vec![1., 0., 0., 1.]).unwrap(),
        );
        let mut lora = HashMap::new();
        lora.insert("l.lora_a".to_string(), Tensor::from_vec(&[2, 1], vec![1., 2.]).unwrap());
        lora.insert("l.lora_b".to_string(), Tensor::from_vec(&[1, 2], vec![3., 4.]).unwrap());
        let n = merge_lora(&mut base, &lora, 1, 1.0).unwrap();
        assert_eq!(n, 1);
        // W + A@B = [[1+3, 4],[6, 1+8]]
        assert_eq!(base["l.w"].data, vec![4., 4., 6., 9.]);
    }

    /// Pad-content invariance of the RNG-free collect seam: weight-0
    /// slots must contribute nothing to the pre-noise gradients, the
    /// loss, or the clip counts, whatever dataset indices they carry.
    /// (Moved from tests/properties.rs when the noise/update phases were
    /// lifted into the StepLoop — the invariance is a property of the
    /// collection alone, and collect_weighted consumes no RNG, so the
    /// comparison is exact.) Artifact-gated: skips without `make
    /// artifacts`.
    #[test]
    fn masked_collect_ignores_pad_content() {
        use crate::data::lm::MarkovCorpus;
        use crate::data::Dataset;
        use crate::runtime::Runtime;
        use crate::session::{
            Backend, ClipMode, ClipPolicy, GroupBy, OptimSpec, PrivacySpec, Session,
        };

        let dir = std::env::var("GWCLIP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let rt = match Runtime::new(&dir) {
            Ok(rt) => rt,
            Err(_) => {
                eprintln!("[skip] masked_collect_ignores_pad_content: no artifacts in {dir}");
                return;
            }
        };
        let cfg = rt.manifest.config("lm_mid_pipe_lora").unwrap().clone();
        let data = MarkovCorpus::new(64, cfg.hyper.seq, cfg.hyper.vocab, 4, 9);

        for seed in 0..3u64 {
            let build = || {
                Session::builder(&rt, "lm_mid_pipe_lora")
                    .privacy(PrivacySpec { epsilon: 2.0, delta: 1e-5, quantile_r: 0.0 })
                    .clip(ClipPolicy {
                        clip_init: 1e-2,
                        ..ClipPolicy::new(GroupBy::PerDevice, ClipMode::Fixed)
                    })
                    .optim(OptimSpec::adam(1e-3))
                    .n_micro(2)
                    .steps(4)
                    .seed(seed)
                    .build(data.len())
                    .unwrap()
            };
            let mut sa = build();
            let mut sb = build();
            let thr = sa.thresholds().to_vec();
            let (Backend::Pipeline(a), Backend::Pipeline(b)) =
                (&mut sa.backend, &mut sb.backend)
            else {
                panic!("staged config must select the pipeline backend");
            };
            let mb = a.minibatch();
            let live = mb - 1 - (seed as usize % (mb - 1)); // at least one pad slot
            let mut weights = vec![0f32; mb];
            for w in weights.iter_mut().take(live) {
                *w = 1.0;
            }
            // canonical padding (what sample_padded emits) vs adversarial
            // pad content: same live prefix, different masked suffix
            let mut idx_canon: Vec<usize> = (0..live).map(|i| (7 * i + 1) % data.len()).collect();
            let mut idx_junk = idx_canon.clone();
            idx_canon.resize(mb, 0);
            for i in live..mb {
                idx_junk.push((13 * i + 5) % data.len());
            }
            let ca = a.collect_weighted(&data, &idx_canon, &weights, &thr).unwrap();
            let cb = b.collect_weighted(&data, &idx_junk, &weights, &thr).unwrap();
            assert_eq!(ca.clip_counts, cb.clip_counts, "seed {seed}");
            assert!(
                (ca.loss_wsum - cb.loss_wsum).abs() < 1e-9,
                "seed {seed}: loss {} vs {}",
                ca.loss_wsum,
                cb.loss_wsum
            );
            assert_eq!(ca.weight_sum, cb.weight_sum, "seed {seed}");
            for (st, (ga, gb)) in ca.grads.iter().zip(&cb.grads).enumerate() {
                for (ta, tb) in ga.iter().zip(gb) {
                    assert_eq!(
                        ta.data, tb.data,
                        "seed {seed} stage {st}: pre-noise grads diverged under pad content"
                    );
                }
            }
        }
    }

    #[test]
    fn pipeline_mode_tokens_roundtrip() {
        for m in PipelineMode::all() {
            assert_eq!(m.token().parse::<PipelineMode>().unwrap(), m);
        }
        for (alias, want) in [
            ("per-device", PipelineMode::PerDevice),
            ("perdevice", PipelineMode::PerDevice),
            ("flat-sync", PipelineMode::FlatSync),
            ("flat", PipelineMode::FlatSync),
            ("non-private", PipelineMode::NonPrivate),
            ("nonprivate", PipelineMode::NonPrivate),
        ] {
            assert_eq!(alias.parse::<PipelineMode>().unwrap(), want, "alias {alias}");
        }
        assert!("per-layer".parse::<PipelineMode>().is_err());
    }
}
