//! Federated user-level DP backend — group-wise clipping taken to its
//! natural limit: **groups = users**.
//!
//! The paper treats per-layer and per-device clipping as instances of one
//! abstraction, group-wise clipping; DP-FedAvg's per-user clipping is the
//! same abstraction with a user's entire contribution as the clipped
//! group. This backend simulates that regime over a large population:
//!
//! 1. the session Poisson-samples **users** (not examples) at rate
//!    `q = E[U]/population` — one global draw over user ids, dealt
//!    round-robin across aggregation slots by the same
//!    [`ShardSampler`](crate::shard::ShardSampler) machinery the sharded
//!    backend deals examples with,
//! 2. each sampled user runs its local update (`local_steps` full-batch
//!    gradient steps over its own examples) against the current
//!    checkpoint and transmits a model delta,
//! 3. the **full per-user delta** is clipped to threshold C — one L2
//!    norm across every trainable tensor, so adding or removing one user
//!    moves the aggregate by at most C regardless of how many examples
//!    they contribute or how many local steps they take,
//! 4. each slot adds its local noise share `sigma_g/sqrt(slots)` (the
//!    shared [`StepLoop`](crate::session::StepLoop) phase — variances add
//!    to exactly the accountant's per-group std at any realized cohort
//!    size), and the slot sums aggregate on the existing
//!    [`tree_reduce`](crate::shard::tree_reduce) seam.
//!
//! The accountant composes the same subsampled-Gaussian releases as every
//! other backend — only the *neighbouring relation* changes, recorded as
//! [`PrivacyUnit::User`](crate::coordinator::accountant::PrivacyUnit) in
//! the [`PrivacyPlan`](crate::coordinator::accountant::PrivacyPlan) and
//! surfaced through `describe()` / `StepEvent.unit`.
//!
//! With `population == n_data`, one example per user and one local step,
//! a user *is* an example and the whole construction degenerates —
//! bitwise, including RNG stream positions — to the example-level sharded
//! backend (pinned in `tests/integration.rs`).
//!
//! Construction goes through `session::SessionBuilder` only (add a
//! `[federated]` section to the spec, or `.federated(FederatedSpec::..)`);
//! there is no raw-sigma entry point, and the backend is private-only.

pub mod engine;

pub use engine::{CohortGrouping, FederatedEngine};
