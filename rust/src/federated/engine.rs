//! `FederatedEngine` — user-level DP training over a simulated population:
//! Poisson-sample users, run each sampled user's local update against the
//! current checkpoint, clip the full per-user model delta, and aggregate
//! on the tree-reduction seam.
//!
//! The dealt unit is the **user**: one global Poisson draw at rate
//! `q = E[U]/population` over user ids (the same [`ShardSampler`]
//! machinery the sharded backend deals examples with), dealt round-robin
//! across `slots` aggregation slots. Each slot holds a full model replica
//! and processes its users in deal order, so walking the slot-major
//! [`GradUnit`] layout visits users in user-major order — the
//! layout-encodes-order invariant the shared
//! [`StepLoop`](crate::session::StepLoop) noise phase relies on. Each
//! slot's unit adds the local noise share `sigma_g/sqrt(slots)`; the
//! merged sum therefore carries exactly the accountant's per-group std,
//! for every realized cohort size U_t.
//!
//! Per-user clipping is group-wise clipping in the paper's sense with
//! groups = users: adding or removing one user (every example they
//! contribute, over every local step) moves the aggregate by at most the
//! threshold C, so the accountant's subsampled-Gaussian composition reads
//! at the user level ([`PrivacyUnit::User`]).
//!
//! Two collection paths share one contract:
//!
//! * **fused** (every user contributes exactly one example and takes one
//!   local step): a user's delta IS its example's gradient, so each slot
//!   runs the same fused backprop+clip executable as the sharded backend
//!   over its users' examples. With `population == n_data` and the
//!   identity user partition this is *bitwise* the example-level sharded
//!   step — the degenerate-parity pin in `tests/integration.rs`.
//! * **general** (`examples_per_user > 1`, heterogeneous cohorts, or
//!   `local_steps > 1`): each sampled user runs `local_steps` full-batch
//!   gradient steps over its own examples on a scratch copy of the
//!   checkpoint (plain SGD at the base lr), accumulates the per-step
//!   gradient sums into one per-user delta, and the engine clips that
//!   delta's global L2 norm against the user's threshold group before
//!   summing it into the slot's unit. The unclipped gradients come from
//!   the same fused executable called with an effectively infinite
//!   threshold (the per-example clip factors saturate at 1), so the two
//!   paths cannot drift in kernel semantics.
//!
//! All DP state lives in the session's shared `StepLoop`; this engine
//! implements the [`BackendStep`] hooks only and touches no
//! RNG/noise/quantile/accountant state.
//!
//! [`PrivacyUnit::User`]: crate::coordinator::accountant::PrivacyUnit

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::noise::Rng;
use crate::coordinator::optimizer::{Optimizer, OptimizerKind};
use crate::data::Dataset;
use crate::kernels::Kernels;
use crate::runtime::{ConfigManifest, Exec, HostValue, Runtime, Tensor};
use crate::session::core::DpCore;
use crate::session::grad::{fold_parts, Collected, GradUnit, Merged, StepTiming, UnitCollected};
use crate::session::steploop::{BackendStep, UnitTask};
use crate::shard::reduce::{tree_reduce_with, ReduceModel};
use crate::shard::sampler::{ShardBatch, ShardSampler};

/// Stand-in for an unbounded clipping threshold on the fused executable:
/// per-example clip factors `min(1, thr/norm)` saturate at 1, so the
/// entry returns the *raw* weighted gradient sum the general path clips
/// per user on the host. Finite (not `f32::MAX`) so the kernel's
/// `thr/norm` division stays well-behaved.
const NO_CLIP: f32 = 1e30;

/// How clipping-threshold groups map onto the sampled cohort (resolved
/// from `FederatedSpec.grouping` x `ClipPolicy.group_by` by the session
/// builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CohortGrouping {
    /// one global threshold shared by every user's delta (K = 1)
    Flat,
    /// per-user adaptive thresholds, factorized over the aggregation
    /// slots: slot k owns threshold C_k and clips the deltas of the users
    /// dealt to it (K = slots) — the per-device taxonomy cell with users
    /// as the clipped records
    PerUser,
}

impl CohortGrouping {
    pub fn token(&self) -> &'static str {
        match self {
            CohortGrouping::Flat => "flat",
            CohortGrouping::PerUser => "per-user",
        }
    }
}

/// Backend wiring computed by the session builder (crate-internal: the
/// federated backend has no public constructor surface).
pub(crate) struct FederatedWiring {
    /// aggregation slots (one model replica each; the cohort is dealt
    /// round-robin across them)
    pub slots: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub link_latency: f64,
    pub grouping: CohortGrouping,
    /// user sampling rate q = E[U]/population of the one global draw
    pub rate: f64,
    /// expected sampled cohort size E[U] (normalizes the merged update)
    pub expected_users: usize,
    pub total_steps: u64,
    /// simulated user population (the accountant's denominator)
    pub population: usize,
    /// local update steps each sampled user takes before transmitting
    pub local_steps: usize,
    /// user id -> the dataset indices that user contributes
    pub partition: Vec<Vec<usize>>,
    pub optimizer: OptimizerKind,
    pub lr: f64,
    pub weight_decay: f64,
    pub lr_decay: bool,
}

struct Replica {
    params: Vec<Tensor>,
    optimizer: Optimizer,
}

pub struct FederatedEngine<'r> {
    pub runtime: &'r Runtime,
    pub config_name: String,
    pub cfg: ConfigManifest,
    pub slots: usize,
    pub fanout: usize,
    pub overlap: bool,
    pub total_steps: u64,
    pub population: usize,
    pub local_steps: usize,
    grouping: CohortGrouping,
    exec: Arc<Exec>,
    eval_exec: Arc<Exec>,
    replicas: Vec<Replica>,
    sampler: ShardSampler,
    expected_users: f64,
    lr: f64,
    trainable_idx: Vec<usize>,
    group_of_trainable: Vec<usize>,
    reduce_model: ReduceModel,
    partition: Vec<Vec<usize>>,
    /// every user contributes exactly one example and takes one local
    /// step: collection runs the sharded backend's fused per-example path
    fused: bool,
    /// live user counts of the most recent collect, per slot (clip_frac
    /// denominators for per-user grouping read them)
    slot_lives: Vec<usize>,
    /// dispatched kernel vtable for the host-side delta/reduction loops
    kernels: Kernels,
}

impl<'r> FederatedEngine<'r> {
    /// Crate-private constructor: all DP state lives in the session's
    /// `StepLoop` (`core` is borrowed to validate the group-count
    /// contract), all schedule/topology decisions in `wiring`. Only
    /// `session::SessionBuilder` builds these.
    pub(crate) fn with_core(
        runtime: &'r Runtime,
        config_name: &str,
        w: FederatedWiring,
        core: &DpCore,
    ) -> Result<Self> {
        let cfg = runtime.manifest.config(config_name)?.clone();
        if cfg.stages.is_some() {
            return Err(anyhow!(
                "config {config_name} has pipeline stages; the federated backend replicates \
                 a stage-less model"
            ));
        }
        if w.slots == 0 {
            return Err(anyhow!("federated backend needs at least one aggregation slot"));
        }
        if w.partition.len() != w.population {
            return Err(anyhow!(
                "user partition covers {} users but the population is {}",
                w.partition.len(),
                w.population
            ));
        }
        for (u, block) in w.partition.iter().enumerate() {
            if block.is_empty() {
                return Err(anyhow!("user {u} contributes no examples"));
            }
            if block.len() > cfg.batch {
                return Err(anyhow!(
                    "user {u} contributes {} examples but the compiled batch holds {}",
                    block.len(),
                    cfg.batch
                ));
            }
        }
        let expect_k = match w.grouping {
            CohortGrouping::Flat => 1,
            CohortGrouping::PerUser => w.slots,
        };
        if core.k() != expect_k {
            return Err(anyhow!(
                "DpCore has {} threshold groups but {} grouping over {} slots needs {}",
                core.k(),
                w.grouping.token(),
                w.slots,
                expect_k
            ));
        }
        // the fused flat entry serves both paths: per-example clipping for
        // single-example single-step users, raw gradient sums (threshold
        // NO_CLIP) for the host-side per-user delta clip
        let exec = runtime.load(config_name, "dp_flat")?;
        let eval_exec = runtime.load(config_name, "eval")?;

        let (trainable_idx, group_of_trainable, schedule) =
            crate::coordinator::trainer::replica_wiring(&cfg, w.lr, w.lr_decay, w.total_steps);
        let replicas: Vec<Replica> = runtime
            .init_replicas(config_name, w.slots)?
            .into_iter()
            .map(|params| {
                let tr: Vec<Tensor> = trainable_idx.iter().map(|&i| params[i].clone()).collect();
                Replica {
                    optimizer: Optimizer::new(w.optimizer, schedule, w.weight_decay, &tr),
                    params,
                }
            })
            .collect();
        let fused = w.local_steps == 1 && w.partition.iter().all(|b| b.len() == 1);
        Ok(FederatedEngine {
            runtime,
            config_name: config_name.to_string(),
            slots: w.slots,
            fanout: w.fanout,
            overlap: w.overlap,
            total_steps: w.total_steps,
            population: w.population,
            local_steps: w.local_steps,
            grouping: w.grouping,
            exec,
            eval_exec,
            replicas,
            // users are the dealt unit: one global Poisson draw over user
            // ids at rate q, dealt round-robin across the slots with the
            // same padded fixed-capacity convention as example dealing
            sampler: ShardSampler::new(w.population, w.rate, w.slots, cfg.batch),
            expected_users: w.expected_users as f64,
            lr: w.lr,
            trainable_idx,
            group_of_trainable,
            reduce_model: ReduceModel::new(w.slots, w.fanout, w.link_latency),
            partition: w.partition,
            fused,
            slot_lives: vec![0; w.slots],
            kernels: Kernels::default(),
            cfg,
        })
    }

    /// Install the session's dispatched kernel vtable on the engine and
    /// every slot's optimizer.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
        for r in self.replicas.iter_mut() {
            r.optimizer.set_kernels(kernels);
        }
    }

    pub fn grouping(&self) -> CohortGrouping {
        self.grouping
    }

    /// True when collection takes the fused per-example path (every user
    /// = one example, one local step) — the degenerate-parity regime.
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// Static cohort capacity: slots x the per-slot compiled batch.
    pub fn capacity(&self) -> usize {
        self.slots * self.cfg.batch
    }

    /// Threshold-group labels (one per slot for per-user grouping).
    pub fn group_labels(&self) -> Vec<String> {
        match self.grouping {
            CohortGrouping::Flat => vec!["users".to_string()],
            CohortGrouping::PerUser => (0..self.slots).map(|s| format!("users@slot{s}")).collect(),
        }
    }

    /// Slot-0's full-model parameters in manifest order (all replicas
    /// stay bit-identical; see [`FederatedEngine::replicas_in_sync`]).
    pub fn params(&self) -> &[Tensor] {
        &self.replicas[0].params
    }

    /// Broadcast a full parameter set to every replica (checkpoint
    /// fan-out).
    pub fn set_params_all(&mut self, params: Vec<Tensor>) -> Result<()> {
        if params.len() != self.cfg.params.len() {
            return Err(anyhow!("param count mismatch"));
        }
        for r in self.replicas.iter_mut() {
            r.params = params.clone();
        }
        Ok(())
    }

    /// Slot-0's optimizer state (all slots stay bit-identical, so
    /// snapshots persist one and fan it back out on restore).
    pub fn optimizer(&self) -> &Optimizer {
        &self.replicas[0].optimizer
    }

    /// Restore one optimizer state into every slot (snapshot fan-out,
    /// mirroring `set_params_all`).
    pub fn restore_optimizers(
        &mut self,
        step: u64,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    ) -> Result<()> {
        for r in self.replicas.iter_mut() {
            r.optimizer.restore_state(step, m.clone(), v.clone())?;
        }
        Ok(())
    }

    /// Load parameters by name; names absent from the map keep their init
    /// values. The result is fanned out to every replica.
    pub fn load_param_map(
        &mut self,
        map: &std::collections::HashMap<String, Tensor>,
    ) -> Result<()> {
        let mut params = self.replicas[0].params.clone();
        for (i, p) in self.cfg.params.iter().enumerate() {
            if let Some(v) = map.get(&p.name) {
                if v.shape != p.shape {
                    return Err(anyhow!("shape mismatch for {}", p.name));
                }
                params[i] = v.clone();
            }
        }
        self.set_params_all(params)
    }

    /// True when every replica's parameters are bitwise equal to
    /// slot 0's — the invariant the merged update maintains.
    pub fn replicas_in_sync(&self) -> bool {
        let r0 = &self.replicas[0].params;
        self.replicas.iter().skip(1).all(|r| {
            r.params
                .iter()
                .zip(r0)
                .all(|(a, b)| a.shape == b.shape && a.data == b.data)
        })
    }

    /// Topology line for `Session::describe` / the CLI: population and
    /// cohort shape, the aggregation sim knobs and the current per-group
    /// `thresholds` (owned by the session's core).
    pub fn describe_topology(&self, thresholds: &[f64]) -> String {
        let c: Vec<String> = thresholds.iter().map(|c| format!("{c:.4}")).collect();
        format!(
            "population={} E[U]={} local_steps={} slots={} fanout={} reduction={} \
             grouping={} thresholds=[{}]",
            self.population,
            self.expected_users as usize,
            self.local_steps,
            self.slots,
            self.fanout,
            if self.overlap { "overlapped" } else { "barrier" },
            self.grouping.token(),
            c.join(", ")
        )
    }

    /// Full-dataset evaluation on slot 0's replica: (mean loss, acc).
    pub fn evaluate(&self, data: &dyn Dataset) -> Result<(f64, f64)> {
        crate::coordinator::trainer::evaluate_full(
            &self.eval_exec,
            &self.replicas[0].params,
            self.cfg.batch,
            data,
        )
    }

    /// Threshold group slot `s`'s users clip and noise under.
    fn group_of(&self, s: usize) -> usize {
        match self.grouping {
            CohortGrouping::Flat => 0,
            CohortGrouping::PerUser => s,
        }
    }

}

impl BackendStep for FederatedEngine<'_> {
    type Slices = ShardBatch;

    fn deal(&mut self, _n_data: usize, rng: &mut Rng) -> ShardBatch {
        // ONE global Poisson draw over USER ids, dealt round-robin into
        // padded per-slot slices (the accountant sees the union at
        // q = E[U]/population, user level)
        self.sampler.sample(rng)
    }

    fn collect_tasks<'a>(
        &'a mut self,
        data: &'a dyn Dataset,
        batch: &'a ShardBatch,
        thresholds: &'a [f64],
    ) -> Vec<UnitTask<'a>> {
        // one task per aggregation slot: every task reads only its own
        // slot's checkpoint replica (the fused path calls the executable
        // against it, the general path clones scratch copies from it), so
        // the slots can run on separate OS threads
        let this = &*self;
        let n_tr = this.trainable_idx.len();
        let k = thresholds.len();
        let fused = this.fused;
        let local_steps = this.local_steps;
        let lr = this.lr;
        let kn = this.kernels;
        (0..this.slots)
            .map(|s| {
                let exec = this.exec.clone();
                let slice = &batch.slices[s];
                let params: &'a [Tensor] = &this.replicas[s].params;
                let partition: &'a [Vec<usize>] = &this.partition;
                let trainable_idx: &'a [usize] = &this.trainable_idx;
                let cfg = &this.cfg;
                let target = this.group_of(s);
                let task: UnitTask<'a> = if fused {
                    // Fused path: every user is one example taking one
                    // local step, so the slot's whole user slice runs
                    // through the per-example clipping executable in one
                    // call — structurally (and, with the identity
                    // partition, bitwise) the sharded backend's collect.
                    Box::new(move || {
                        let live_s = slice.live();
                        // dealt ids are users; each owns one dataset index
                        let indices: Vec<usize> =
                            slice.indices.iter().map(|&u| partition[u][0]).collect();
                        let mb = data.batch(&indices);
                        let (x, y) = mb.inputs();
                        let extras = vec![
                            x,
                            y,
                            HostValue::F32(Tensor::scalar(thresholds[target] as f32)),
                            HostValue::F32(Tensor::from_vec(
                                &[slice.weights.len()],
                                slice.weights.clone(),
                            )?),
                        ];
                        let t0 = Instant::now();
                        let outs = exec.call(params, &extras)?;
                        let mut part = UnitCollected::new(
                            GradUnit {
                                tensors: outs[1..1 + n_tr].to_vec(),
                                groups: vec![target; n_tr],
                            },
                            k,
                        );
                        part.bwd_secs = t0.elapsed().as_secs_f64();
                        // the entry reports a weighted mean over this
                        // slot's live users; recover the global mean via
                        // the live counts. A slot whose slice drew empty
                        // reports a 0/0 loss — skip it.
                        if live_s > 0 {
                            part.loss_wsum = outs[0].data[0] as f64 * live_s as f64;
                        }
                        part.weight_sum = live_s as f64;
                        part.live = live_s;
                        part.calls = 1;
                        // per-example norms ARE per-user delta norms here
                        let norms = &outs[1 + n_tr];
                        for i in 0..slice.weights.len() {
                            if slice.weights[i] == 0.0 {
                                continue;
                            }
                            let v = norms.data[i] as f64;
                            part.norm_sums[target] += v;
                            if v <= thresholds[target] {
                                part.clip_counts[target] += 1.0;
                            }
                        }
                        Ok(part)
                    })
                } else {
                    // General path: per sampled user, `local_steps`
                    // full-batch gradient steps over the user's own
                    // examples on a scratch checkpoint copy; the
                    // accumulated gradient sums form the per-user delta,
                    // clipped as one group against the user's threshold
                    // before joining the slot's unit sum. Measured in
                    // gradient units (the plain-SGD local delta divided by
                    // the local lr) so the server optimizer treats it
                    // exactly like a gradient.
                    Box::new(move || {
                        let live_s = slice.live();
                        let mut loss_wsum = 0f64;
                        let mut example_total = 0usize;
                        let mut calls = 0usize;
                        let mut clip_counts = vec![0f64; k];
                        let mut norm_sums = vec![0f64; k];
                        // slot accumulator over its users' clipped deltas
                        let mut acc: Vec<Tensor> = trainable_idx
                            .iter()
                            .map(|&i| Tensor::zeros(&cfg.params[i].shape))
                            .collect();
                        let t0 = Instant::now();
                        for i in 0..live_s {
                            let user = slice.indices[i];
                            let block = &partition[user];
                            let ex = block.len();
                            let mut idx = block.clone();
                            idx.resize(cfg.batch, 0);
                            let mut wts = vec![1.0f32; ex];
                            wts.resize(cfg.batch, 0.0);
                            // local scratch copy of this slot's checkpoint
                            let mut local = params.to_vec();
                            let mut delta: Vec<Tensor> = Vec::new();
                            for step in 0..local_steps {
                                let mb = data.batch(&idx);
                                let (x, y) = mb.inputs();
                                let extras = vec![
                                    x,
                                    y,
                                    HostValue::F32(Tensor::scalar(NO_CLIP)),
                                    HostValue::F32(Tensor::from_vec(&[wts.len()], wts.clone())?),
                                ];
                                let outs = exec.call(&local, &extras)?;
                                calls += 1;
                                if step == 0 {
                                    // weighted mean loss over the user's
                                    // live examples
                                    loss_wsum += outs[0].data[0] as f64 * ex as f64;
                                    example_total += ex;
                                }
                                let g: Vec<Tensor> = outs[1..1 + n_tr].to_vec();
                                if delta.is_empty() {
                                    delta = g.clone();
                                } else {
                                    for (d, t) in delta.iter_mut().zip(&g) {
                                        kn.add_assign(&mut d.data, &t.data);
                                    }
                                }
                                if step + 1 < local_steps {
                                    // plain local SGD at the base lr on the
                                    // mean gradient (sum / example count)
                                    let lr = (lr / ex as f64) as f32;
                                    for (j, &pi) in trainable_idx.iter().enumerate() {
                                        kn.axpy(&mut local[pi].data, &g[j].data, -lr);
                                    }
                                }
                            }
                            // clip the FULL per-user delta: one global L2
                            // norm across every trainable tensor, bounded
                            // by the user's threshold
                            let mut sq = 0f64;
                            for t in &delta {
                                sq = kn.sq_norm(sq, &t.data);
                            }
                            let norm = sq.sqrt();
                            norm_sums[target] += norm;
                            if norm <= thresholds[target] {
                                clip_counts[target] += 1.0;
                            }
                            let factor = if norm > thresholds[target] {
                                (thresholds[target] / norm) as f32
                            } else {
                                1.0
                            };
                            for (a, d) in acc.iter_mut().zip(&delta) {
                                kn.axpy(&mut a.data, &d.data, factor);
                            }
                        }
                        let mut part = UnitCollected::new(
                            GradUnit { tensors: acc, groups: vec![target; n_tr] },
                            k,
                        );
                        part.bwd_secs = t0.elapsed().as_secs_f64();
                        part.clip_counts = clip_counts;
                        part.norm_sums = norm_sums;
                        part.loss_wsum = loss_wsum;
                        part.weight_sum = example_total as f64;
                        part.live = live_s;
                        part.calls = calls;
                        Ok(part)
                    })
                };
                task
            })
            .collect()
    }

    fn finish_collect(&mut self, batch: &ShardBatch, parts: Vec<UnitCollected>) -> Result<Collected> {
        let k = parts.first().map(|p| p.clip_counts.len()).unwrap_or(0);
        let f = fold_parts(parts, k);
        self.slot_lives.copy_from_slice(&f.lives);
        let live_global = batch.live;
        // normalize the mean-norm diagnostics by the users that fed each
        // group (per-user slot groups see only their cohort slice)
        let mut mean_norms = f.norm_sums;
        match self.grouping {
            CohortGrouping::PerUser => {
                for (g, m) in mean_norms.iter_mut().enumerate() {
                    *m /= self.slot_lives[g].max(1) as f64;
                }
            }
            CohortGrouping::Flat => {
                for m in mean_norms.iter_mut() {
                    *m /= live_global.max(1) as f64;
                }
            }
        }
        // TRUE per-group denominators: an empty cohort (or an empty slot
        // under per-user grouping) reports 0 and the loop's guarded
        // division turns the clip fraction into 0.0 rather than NaN
        let clip_denoms: Vec<f64> = match self.grouping {
            CohortGrouping::PerUser => (0..k).map(|g| self.slot_lives[g] as f64).collect(),
            CohortGrouping::Flat => vec![live_global as f64; k],
        };
        Ok(Collected {
            units: f.units,
            clip_counts: f.clip_counts,
            clip_denoms,
            mean_norms,
            loss: f.loss_wsum / f.weight_sum.max(1.0),
            live: live_global,
            truncated: batch.truncated,
            calls: f.calls,
            syncs: 0,
            timing: StepTiming { durations: Vec::new(), bwd_secs: f.bwd_secs },
        })
    }

    fn merge(&mut self, units: Vec<GradUnit>, timing: &StepTiming) -> Merged {
        let parts: Vec<Vec<Tensor>> = units.into_iter().map(|u| u.tensors).collect();
        let merged = tree_reduce_with(self.kernels, parts, self.fanout);

        // simulated aggregation latency: a real deployment aggregates the
        // slots concurrently, so the modeled compute time is one
        // representative slot; its backward is split across trainable
        // tensors proportional to size, reduction rounds queue behind it
        // in backprop (reverse) order — same model as the sharded seam
        let rep_bwd = timing.bwd_secs.iter().sum::<f64>() / self.slots as f64;
        let total_dim: f64 = self
            .trainable_idx
            .iter()
            .map(|&i| self.cfg.params[i].size as f64)
            .sum::<f64>()
            .max(1.0);
        let n_tr = self.trainable_idx.len();
        let mut bwd_layers = Vec::with_capacity(n_tr);
        let mut red_layers = Vec::with_capacity(n_tr);
        for &i in self.trainable_idx.iter().rev() {
            let d = self.cfg.params[i].size as f64;
            bwd_layers.push(rep_bwd * d / total_dim);
            red_layers.push(self.reduce_model.layer_cost(4.0 * d));
        }
        let sim_overlap = self.reduce_model.overlap_makespan(&bwd_layers, &red_layers);
        let sim_barrier = self.reduce_model.barrier_makespan(&bwd_layers, &red_layers);

        Merged {
            tensors: merged,
            sim_secs: if self.overlap { sim_overlap } else { sim_barrier },
            sim_overlap_secs: sim_overlap,
            sim_barrier_secs: sim_barrier,
            syncs: self.reduce_model.rounds(),
        }
    }

    fn apply(&mut self, grads: &[Tensor]) {
        // one merged update applied to every replica (identical optimizer
        // states + identical grads keep the replicas bit-identical)
        for r in self.replicas.iter_mut() {
            r.optimizer.apply_indexed(&mut r.params, &self.trainable_idx, grads);
        }
    }

    fn update_scale(&self, _live: usize) -> f32 {
        // Algorithm 1 line 14 at the user level: normalize the merged sum
        // of clipped per-user deltas by the EXPECTED cohort size E[U]
        (1.0 / self.expected_users) as f32
    }

    fn prefetch_lists(&self, batch: &ShardBatch) -> Vec<Vec<usize>> {
        if self.fused {
            // one ModelBatch per slot, over the users' single examples
            batch
                .slices
                .iter()
                .map(|slice| slice.indices.iter().map(|&u| self.partition[u][0]).collect())
                .collect()
        } else {
            // one padded ModelBatch per live user (each local step reuses
            // the same index list, so assembling it once suffices)
            let mut lists = Vec::new();
            for slice in &batch.slices {
                for i in 0..slice.live() {
                    let mut idx = self.partition[slice.indices[i]].clone();
                    idx.resize(self.cfg.batch, 0);
                    lists.push(idx);
                }
            }
            lists
        }
    }
}
