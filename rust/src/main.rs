//! gwclip CLI — leader entrypoint.
//!
//! `run` executes any scenario from a declarative TOML/JSON spec file;
//! `train` and `pipeline` are flag-driven shorthands over the same
//! session API (both backends, accountant-derived noise everywhere).
//! `exp` subcommands map one-to-one onto the paper's tables and figures
//! (DESIGN.md section 6).

use anyhow::{bail, Result};

use gwclip::coordinator::noise::Allocation;
use gwclip::coordinator::trainer::Method;
use gwclip::pipeline::PipelineMode;
use gwclip::runtime::Runtime;
use gwclip::session::snapshot;
use gwclip::session::{
    ClipMode, ClipPolicy, DataSpec, GroupBy, HybridGrouping, HybridSpec, OptimSpec, PrivacySpec,
    RunSpec, Sampling, Session, SessionBuilder, ShardGrouping,
};
use gwclip::util::cli::Args;

const USAGE: &str = "\
gwclip — group-wise clipping for DP deep learning (ICLR 2023 reproduction)

USAGE:
  gwclip run      --spec run.toml|run.json   (one declarative file, any
                  backend incl. [federated] user-level DP; see
                  docs/SESSION_API.md) [--print-spec]
                  [--snapshot-every N] [--snapshot-dir D]   (publish an atomic
                  resumable snapshot every N steps + one at completion)
                  [--trace-out trace.json]   (record per-phase spans — deal/
                  collect per unit+thread/noise/merge/normalize/apply/
                  quantile — and export Chrome trace-event JSON; zero RNG
                  impact, the run stays bitwise identical)
  gwclip resume   <snapshot.json> [--snapshot-every N] [--snapshot-dir D]
                  [--trace-out trace.json]
                  (rebuild the session a snapshot describes, restore its
                  bitwise state — params, optimizer moments, thresholds,
                  RNG stream positions, accountant ledger — and train the
                  remaining steps; any backend. The continued run is
                  bitwise identical to the uninterrupted one)
  gwclip serve    [--addr 127.0.0.1:7700] [--state-dir serve-state]
                  [--snapshot-every 25]
                  (multi-session training daemon: submit named TOML/JSON
                  specs over a local HTTP JSON API, stream per-step events
                  as ndjson, snapshot each session on its cadence, and
                  resume every resident session from its latest snapshot
                  on restart; GET /metrics serves a Prometheus exposition
                  and GET /sessions/N/phases the per-phase time breakdown;
                  see docs/SESSION_API.md \"Serving\" + \"Observability\")
  gwclip train    [--config resmlp] [--method adaptive-per-layer] [--epsilon 3]
                  [--delta 1e-5] [--epochs 3] [--lr 0.5] [--n-data 4096]
                  [--seed 0] [--allocation global|equal|weighted]
                  [--clip 1] [--quantile 0.5] [--opt sgd|momentum|adam]
  gwclip pipeline [--config lm_mid_pipe_lora] [--mode per-device|flat-sync|non-private]
                  [--epsilon 1] [--delta 1e-5] [--steps 10] [--n-micro 4]
                  [--clip 0.01] [--lr 5e-3] [--n-data 2048] [--seed 0]
                  [--sampling poisson|round_robin]   (poisson = amplified accountant)
  gwclip shard    [--spec run.toml] [--config resmlp] [--workers 4] [--fanout 2]
                  [--no-overlap] [--grouping auto|flat|per-device]
                  [--mode fixed|adaptive|non-private] [--epsilon 3] [--delta 1e-5]
                  [--epochs 1] [--lr 0.25] [--clip 1] [--n-data 4096] [--seed 0]
                  [--compress topk|randk] [--compress-ratio 0.25] [--no-error-feedback]
                  (sharded data-parallel backend: per-device clipping across N
                  replicas, overlapped tree-reduction, optional error-feedback
                  gradient compression; flags override the spec)
  gwclip hybrid   [--spec run.toml] [--config lm_mid_pipe_lora] [--replicas 2]
                  [--fanout 2] [--no-overlap] [--grouping auto|per-piece|per-stage]
                  [--mode fixed|adaptive|non-private] [--epsilon 1] [--delta 1e-5]
                  [--epochs 1] [--steps N] [--n-micro 4] [--clip 0.01] [--lr 5e-3]
                  [--n-data 2048] [--seed 0]
                  [--compress topk|randk] [--compress-ratio 0.25] [--no-error-feedback]
                  (hybrid 2D backend: R data-parallel replicas x the config's
                  pipeline stages, per-piece clipping, overlapped cross-replica
                  tree-reduction; flags override the spec; steps default to
                  epochs-derived)
  gwclip exp <which>   table1|table2|table3|table4|table5|table6|table10|table11|
                       fig1|fig2|fig3|fig5|fig6|fig7|pipeline-overhead|accountant|
                       shard-scaling|compress-scaling|hybrid-scaling|
                       user-vs-example|all
                       [--paper-scale]
  gwclip bench-diff --old DIR [--new DIR] [--max-regress 0.15]
                  (CI gate: diff the BENCH_*.json step-hot-path rows against a
                  previous trajectory; fails loudly on a regression. Also
                  surfaces the per-backend measured collect-wall and
                  per-phase rows, informational only)
  common: [--artifacts DIR] [--threads N]   (N > 1 fans the collect phase
                  across N OS threads — bitwise identical to sequential;
                  GWCLIP_THREADS overrides) [--kernels scalar|auto]
                  (host kernel dispatch: scalar = the bit-reference
                  default, auto = detected-ISA elementwise kernels plus
                  reassociated norm/reduce/gaussian kernels — a different,
                  still deterministic, bit trace; GWCLIP_KERNELS
                  overrides) [--digest]   (print the bitwise
                  state certificate — params FNV, thresholds, RNG stream
                  positions, eps spent — after the run)
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(
        &argv,
        &["paper-scale", "print-spec", "no-overlap", "no-error-feedback", "digest"],
    )?;
    if args.positional.first().map(|s| s.as_str()) == Some("bench-diff") {
        // trajectory gate only reads JSON files — no artifacts, no runtime
        return cmd_bench_diff(&args);
    }
    if args.positional.first().map(|s| s.as_str()) == Some("serve") {
        // the daemon binds before touching artifacts: each session runner
        // thread loads its own Runtime (the PJRT client is not Send), so
        // the main thread never needs one
        return cmd_serve(&args);
    }
    let dir = args
        .flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(gwclip::artifact_dir);
    let rt = Runtime::new(&dir)?;

    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&rt, &args),
        Some("resume") => cmd_resume(&rt, &args),
        Some("train") => cmd_train(&rt, &args),
        Some("pipeline") => cmd_pipeline(&rt, &args),
        Some("shard") => cmd_shard(&rt, &args),
        Some("hybrid") => cmd_hybrid(&rt, &args),
        Some("exp") => {
            let which = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("exp needs a name; see --help"))?;
            gwclip::exp::run(&rt, which, args.has("paper-scale"))
        }
        _ => {
            print!("{USAGE}");
            bail!("unknown subcommand")
        }
    }
}

/// Execute a session described by a TOML/JSON spec file — the single
/// declarative entry point for every clipping scenario on both backends.
fn cmd_run(rt: &Runtime, args: &Args) -> Result<()> {
    let path = args
        .flags
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("run needs --spec <file>; see docs/SESSION_API.md"))?;
    let mut spec = RunSpec::from_path(path)?;
    spec.threads = args.get_usize("threads", spec.threads)?;
    if args.has("print-spec") {
        println!("{}", spec.render_json());
    }
    run_session(SessionBuilder::from_spec(rt, spec), args)
}

/// Rebuild the session a snapshot describes, restore its bitwise state
/// and train the remaining steps — any backend. New snapshots continue
/// into the source snapshot's directory unless `--snapshot-dir` says
/// otherwise.
fn cmd_resume(rt: &Runtime, args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("resume needs a snapshot file; see --help"))?;
    let path = std::path::Path::new(path);
    let snap = snapshot::read_file(path)?;
    let mut spec = snapshot::spec_of(&snap)?;
    // thread count is bitwise-neutral, so the override composes with a
    // resume (GWCLIP_THREADS still wins inside the builder)
    spec.threads = args.get_usize("threads", spec.threads)?;
    // kernel mode is NOT bitwise-neutral; the override is allowed here so
    // a resume can re-assert the snapshot's mode, and restore() refuses
    // any mode that mismatches the one the snapshot recorded
    if let Some(k) = args.flags.get("kernels") {
        spec.kernels = k.parse()?;
    }
    let (mut sess, train, eval) = SessionBuilder::from_spec(rt, spec).build_with_data()?;
    snapshot::restore(&mut sess, &snap)?;
    let trace_out = args.flags.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        sess.enable_trace();
    }
    eprintln!("{}", sess.describe());
    eprintln!(
        "resumed {} at step {} of {}",
        path.display(),
        sess.steploop.steps_done,
        sess.total_steps
    );
    let dir = args
        .flags
        .get("snapshot-dir")
        .map(std::path::PathBuf::from)
        .or_else(|| path.parent().map(std::path::Path::to_path_buf))
        .unwrap_or_else(|| std::path::PathBuf::from("snapshots"));
    sess.run_with_snapshots(&*train, 10, args.get_u64("snapshot-every", 0)?, &dir)?;
    if let Some(p) = &trace_out {
        sess.write_trace(p)?;
        eprintln!("trace: wrote Chrome trace events to {}", p.display());
    }
    finish_session(&sess, &*eval, args)
}

/// Start the multi-session training daemon (see `gwclip::serve`).
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = gwclip::serve::ServeOpts {
        addr: args.get("addr", "127.0.0.1:7700"),
        artifacts: args
            .flags
            .get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(gwclip::artifact_dir),
        state_dir: std::path::PathBuf::from(args.get("state-dir", "serve-state")),
        snapshot_every: args.get_u64("snapshot-every", 25)?,
    };
    gwclip::serve::Daemon::bind(opts)?.run()
}

fn run_session(mut builder: SessionBuilder, args: &Args) -> Result<()> {
    // every run subcommand funnels through here, so one insertion point
    // gives them all the --kernels override (spec < flag < GWCLIP_KERNELS;
    // the builder applies the env half when it resolves the spec)
    if let Some(k) = args.flags.get("kernels") {
        builder = builder.kernels(k.parse()?);
    }
    let (mut sess, train, eval) = builder.build_with_data()?;
    // span recording is observational only (no RNG, no feedback), so
    // enabling it cannot change what the run computes
    let trace_out = args.flags.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        sess.enable_trace();
    }
    eprintln!("{}", sess.describe());
    let snapshot_every = args.get_u64("snapshot-every", 0)?;
    let snapshot_dir = args.flags.get("snapshot-dir");
    if snapshot_every > 0 || snapshot_dir.is_some() {
        let dir = snapshot_dir
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("snapshots"));
        sess.run_with_snapshots(&*train, 10, snapshot_every, &dir)?;
    } else {
        sess.run(&*train, 10)?;
    }
    if let Some(p) = &trace_out {
        sess.write_trace(p)?;
        eprintln!("trace: wrote Chrome trace events to {}", p.display());
    }
    finish_session(&sess, &*eval, args)
}

fn finish_session(sess: &Session, eval: &dyn gwclip::data::Dataset, args: &Args) -> Result<()> {
    let (loss, acc) = sess.evaluate(eval)?;
    if acc.is_nan() {
        println!("final: eval loss {loss:.4}");
    } else {
        println!("final: eval loss {loss:.4} acc {acc:.4}");
    }
    let labels = sess.group_labels();
    if sess.thresholds().len() > 1 {
        eprint!("thresholds:");
        for (g, c) in labels.iter().zip(sess.thresholds()).take(8) {
            eprint!(" {g}={c:.4}");
        }
        eprintln!();
    }
    if args.has("digest") {
        println!("digest: {}", sess.digest().render());
    }
    Ok(())
}

/// Flag-driven single-device (or pipeline, if the config has stages) run.
fn cmd_train(rt: &Runtime, args: &Args) -> Result<()> {
    let config = args.get("config", "resmlp");
    let method: Method = args.get("method", "adaptive-per-layer").parse()?;
    let seed = args.get_u64("seed", 0)?;
    let optim = match args.get("opt", "sgd").as_str() {
        "sgd" => OptimSpec::sgd(args.get_f64("lr", 0.5)?),
        "momentum" => OptimSpec::momentum(args.get_f64("lr", 0.5)?, 0.9),
        "adam" => OptimSpec::adam(args.get_f64("lr", 0.5)?),
        o => bail!("unknown optimizer {o}"),
    };
    let clip = ClipPolicy {
        clip_init: args.get_f64("clip", 1.0)?,
        target_q: args.get_f64("quantile", 0.5)?,
        allocation: Allocation::parse(&args.get("allocation", "global"))?,
        ..ClipPolicy::from_method(method)
    };
    let privacy = PrivacySpec {
        epsilon: args.get_f64("epsilon", 3.0)?,
        delta: args.get_f64("delta", 1e-5)?,
        quantile_r: args.get_f64("quantile-r", 0.01)?,
    };
    let data = DataSpec {
        task: args.get("task", "auto"),
        n_data: args.get_usize("n-data", 4096)?,
        seed,
    };
    run_session(
        Session::builder(rt, &config)
            .privacy(privacy)
            .clip(clip)
            .optim(optim)
            .data(data)
            .epochs(args.get_f64("epochs", 3.0)?)
            .threads(args.get_usize("threads", 1)?)
            .seed(seed),
        args,
    )
}

/// Diff the `BENCH_*.json` step-hot-path rows in `--new` (default `.`)
/// against the previous trajectory in `--old`; any row whose mean step
/// time regressed by more than `--max-regress` (default 15%) fails the
/// run loudly — the CI gate for the step hot path.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let old = args
        .flags
        .get("old")
        .ok_or_else(|| anyhow::anyhow!("bench-diff needs --old <dir with prior BENCH_*.json>"))?;
    let new = args.get("new", ".");
    let threshold = args.get_f64("max-regress", 0.15)?;
    let diff = gwclip::util::bench::diff_dirs(old, &new, threshold)?;
    println!(
        "bench-diff: {} step-path row(s) compared against {old} \
         (threshold {:.0}%)",
        diff.compared,
        100.0 * threshold
    );
    // suites/rows with no prior trajectory (a freshly landed bench) are
    // additions: reported so the trajectory's growth is visible in CI
    // logs, but never a failure
    for a in &diff.additions {
        println!("ADDITION {a}: no prior trajectory, gated from the next run on");
    }
    // per-backend measured collect wall-clock, printed next to whatever
    // prior the trajectory holds — informational, never a gate (real
    // thread scheduling is machine-dependent in a way the simulated
    // makespans are not)
    for (name, new_s, old_s) in &diff.measured {
        match old_s {
            Some(o) => println!(
                "MEASURED {name}: collect wall {:.4} ms (prior {:.4} ms)",
                1e3 * new_s,
                1e3 * o
            ),
            None => println!("MEASURED {name}: collect wall {:.4} ms (no prior)", 1e3 * new_s),
        }
    }
    // per-phase splits of the step hot path — informational for the same
    // reason: wall-clock phase shares are machine-dependent; the /step
    // totals above are the gate
    for (name, new_s, old_s) in &diff.phases {
        match old_s {
            Some(o) => {
                println!("PHASE {name}: {:.4} ms (prior {:.4} ms)", 1e3 * new_s, 1e3 * o)
            }
            None => println!("PHASE {name}: {:.4} ms (no prior)", 1e3 * new_s),
        }
    }
    // per-kernel micro-bench rows (scalar vs detected-ISA variants) —
    // informational for the same reason: per-ISA wall-clock is
    // machine-dependent; the /step totals above are the gate
    for (name, new_s, old_s) in &diff.kernels {
        match old_s {
            Some(o) => {
                println!("KERNEL {name}: {:.4} ms (prior {:.4} ms)", 1e3 * new_s, 1e3 * o)
            }
            None => println!("KERNEL {name}: {:.4} ms (no prior)", 1e3 * new_s),
        }
    }
    for r in &diff.regressions {
        println!(
            "REGRESSION [{}] {}: {:.4} ms -> {:.4} ms ({:.2}x)",
            r.suite,
            r.name,
            1e3 * r.old_mean_s,
            1e3 * r.new_mean_s,
            r.ratio()
        );
    }
    if !diff.regressions.is_empty() {
        bail!(
            "{} step-hot-path regression(s) above {:.0}%",
            diff.regressions.len(),
            100.0 * threshold
        );
    }
    println!("bench-diff: no step-hot-path regressions");
    Ok(())
}

/// Shared `--spec` flag-override block for the shard/hybrid shorthands:
/// every documented common flag overrides the spec file; absent flags
/// keep the spec's values.
fn apply_common_overrides(s: &mut RunSpec, args: &Args) -> Result<()> {
    if let Some(c) = args.flags.get("config") {
        s.config = c.clone();
    }
    if let Some(m) = args.flags.get("mode") {
        s.clip.mode = m.parse()?;
    }
    s.privacy.epsilon = args.get_f64("epsilon", s.privacy.epsilon)?;
    s.privacy.delta = args.get_f64("delta", s.privacy.delta)?;
    s.privacy.quantile_r = args.get_f64("quantile-r", s.privacy.quantile_r)?;
    s.clip.clip_init = args.get_f64("clip", s.clip.clip_init)?;
    s.clip.target_q = args.get_f64("quantile", s.clip.target_q)?;
    s.optim.lr = args.get_f64("lr", s.optim.lr)?;
    s.epochs = args.get_f64("epochs", s.epochs)?;
    s.data.n_data = args.get_usize("n-data", s.data.n_data)?;
    s.seed = args.get_u64("seed", s.seed)?;
    s.threads = args.get_usize("threads", s.threads)?;
    if let Some(k) = args.flags.get("kernels") {
        s.kernels = k.parse()?;
    }
    Ok(())
}

/// `--compress` / `--compress-ratio` / `--no-error-feedback` overrides
/// for the backends with a reduction path (shard, hybrid): `--compress`
/// enables a `[compress]` section (or re-kinds an existing one), the
/// other flags tune whichever section is active.
fn apply_compress_overrides(s: &mut RunSpec, args: &Args) -> Result<()> {
    if let Some(kind) = args.flags.get("compress") {
        let mut c = s.compress.unwrap_or_default();
        c.kind = kind.parse()?;
        s.compress = Some(c);
    }
    if let Some(c) = s.compress.as_mut() {
        c.ratio = args.get_f64("compress-ratio", c.ratio)?;
        if args.has("no-error-feedback") {
            c.error_feedback = false;
        }
    }
    Ok(())
}

/// Sharded data-parallel run: N full replicas, per-device (or flat)
/// clipping, local noise shares, overlapped tree-reduction. Starts from a
/// `--spec` file when given (injecting a default `[shard]` section if the
/// file lacks one) and applies flag overrides on top; otherwise builds the
/// spec from flags alone. Sigma is always accountant-derived; the
/// accountant sees one release per step at q = E[B]/n regardless of the
/// worker count.
fn cmd_shard(rt: &Runtime, args: &Args) -> Result<()> {
    let mut spec = match args.flags.get("spec") {
        Some(path) => {
            let mut s = RunSpec::from_path(path)?;
            apply_common_overrides(&mut s, args)?;
            s
        }
        None => {
            let seed = args.get_u64("seed", 0)?;
            let mode: ClipMode = args.get("mode", "fixed").parse()?;
            let group_by = if mode == ClipMode::NonPrivate {
                GroupBy::Flat
            } else {
                match args.get("grouping", "auto").parse::<ShardGrouping>()? {
                    ShardGrouping::Flat => GroupBy::Flat,
                    // auto defaults the flag-driven path to the paper's
                    // per-device scheme (one threshold per worker)
                    ShardGrouping::Auto | ShardGrouping::PerDevice => GroupBy::PerDevice,
                }
            };
            let mut s = RunSpec::for_config(&args.get("config", "resmlp"));
            s.clip = ClipPolicy {
                clip_init: args.get_f64("clip", 1.0)?,
                target_q: args.get_f64("quantile", 0.5)?,
                ..ClipPolicy::new(group_by, mode)
            };
            s.privacy = PrivacySpec {
                epsilon: args.get_f64("epsilon", 3.0)?,
                delta: args.get_f64("delta", 1e-5)?,
                quantile_r: args.get_f64(
                    "quantile-r",
                    if mode == ClipMode::Adaptive { 0.01 } else { 0.0 },
                )?,
            };
            s.optim = OptimSpec::sgd(args.get_f64("lr", 0.25)?);
            s.data = DataSpec {
                task: args.get("task", "auto"),
                n_data: args.get_usize("n-data", 4096)?,
                seed,
            };
            s.epochs = args.get_f64("epochs", 1.0)?;
            s.seed = seed;
            s
        }
    };
    spec.threads = args.get_usize("threads", spec.threads)?;
    let mut sh = spec.shard.unwrap_or_default();
    sh.workers = args.get_usize("workers", sh.workers)?;
    sh.fanout = args.get_usize("fanout", sh.fanout)?;
    if args.has("no-overlap") {
        sh.overlap = false;
    }
    if let Some(g) = args.flags.get("grouping") {
        let g: ShardGrouping = g.parse()?;
        sh.grouping = g;
        // make the override usable on any spec: an explicit grouping also
        // re-aligns the clip policy it must agree with (no-op when the
        // flags already built them aligned, or for non-private runs)
        if spec.clip.mode != ClipMode::NonPrivate {
            match g {
                ShardGrouping::Flat => spec.clip.group_by = GroupBy::Flat,
                ShardGrouping::PerDevice => spec.clip.group_by = GroupBy::PerDevice,
                ShardGrouping::Auto => {}
            }
        }
    }
    spec.shard = Some(sh);
    spec.hybrid = None; // the shard section governs this run
    apply_compress_overrides(&mut spec, args)?;
    spec.validate()?;
    if args.has("print-spec") {
        println!("{}", spec.render_json());
    }
    run_session(SessionBuilder::from_spec(rt, spec), args)
}

/// Hybrid 2D-parallel run: R data-parallel replicas, each a full pipeline
/// over the config's stages, per-piece clipping, local noise shares,
/// overlapped cross-replica tree-reduction. Starts from a `--spec` file
/// when given (injecting a default `[hybrid]` section if the file lacks
/// one) and applies flag overrides on top; otherwise builds the spec from
/// flags alone. The accountant sees one release per step at q = E[B]/n
/// regardless of the replica or stage count; per-step reports carry both
/// the overlapped and barrier reduction makespans plus truncated draws.
fn cmd_hybrid(rt: &Runtime, args: &Args) -> Result<()> {
    let mut spec = match args.flags.get("spec") {
        Some(path) => {
            let mut s = RunSpec::from_path(path)?;
            apply_common_overrides(&mut s, args)?;
            s.pipe.n_micro = args.get_usize("n-micro", s.pipe.n_micro)?;
            s.pipe.steps = args.get_usize("steps", s.pipe.steps)?;
            s
        }
        None => {
            let seed = args.get_u64("seed", 0)?;
            let mode: ClipMode = args.get("mode", "fixed").parse()?;
            let clip = if mode == ClipMode::NonPrivate {
                ClipPolicy::non_private()
            } else {
                ClipPolicy {
                    clip_init: args.get_f64("clip", 1e-2)?,
                    target_q: args.get_f64("quantile", 0.5)?,
                    ..ClipPolicy::new(GroupBy::PerDevice, mode)
                }
            };
            let mut s = RunSpec::for_config(&args.get("config", "lm_mid_pipe_lora"));
            s.clip = clip;
            s.privacy = PrivacySpec {
                epsilon: args.get_f64("epsilon", 1.0)?,
                delta: args.get_f64("delta", 1e-5)?,
                quantile_r: args.get_f64(
                    "quantile-r",
                    if mode == ClipMode::Adaptive { 0.01 } else { 0.0 },
                )?,
            };
            s.optim = OptimSpec::adam(args.get_f64("lr", 5e-3)?);
            s.data = DataSpec {
                task: args.get("task", "auto"),
                n_data: args.get_usize("n-data", 2048)?,
                seed,
            };
            s.epochs = args.get_f64("epochs", 1.0)?;
            s.pipe.n_micro = args.get_usize("n-micro", 4)?;
            // 0 = derive the step count from epochs; an explicit --steps
            // needs a staged config (stage-less [hybrid] runs degenerate
            // to the sharded backend, which schedules from epochs only)
            s.pipe.steps = args.get_usize("steps", 0)?;
            s.seed = seed;
            s
        }
    };
    spec.threads = args.get_usize("threads", spec.threads)?;
    let mut hy = spec.hybrid.unwrap_or_default();
    hy.replicas = args.get_usize("replicas", hy.replicas)?;
    hy.fanout = args.get_usize("fanout", hy.fanout)?;
    if args.has("no-overlap") {
        hy.overlap = false;
    }
    if let Some(g) = args.flags.get("grouping") {
        hy.grouping = g.parse::<HybridGrouping>()?;
    }
    spec.hybrid = Some(hy);
    spec.shard = None; // the hybrid section governs this run
    apply_compress_overrides(&mut spec, args)?;
    spec.validate()?;
    if args.has("print-spec") {
        println!("{}", spec.render_json());
    }
    run_session(SessionBuilder::from_spec(rt, spec), args)
}

/// Flag-driven pipeline run. Sigma is always accountant-derived from
/// (--epsilon, --delta) over the requested steps — the old hardcoded
/// `sigma: 0.5` privacy hole is gone. With the default Poisson sampling
/// the accountant claims subsampling amplification at q = E[B]/n (E[B] =
/// 0.8x the minibatch by default); `--sampling round_robin` restores the
/// legacy deterministic minibatches (and their conservative q = 1
/// composition).
fn cmd_pipeline(rt: &Runtime, args: &Args) -> Result<()> {
    let config = args.get("config", "lm_mid_pipe_lora");
    let mode: PipelineMode = args.get("mode", "per-device").parse()?;
    let sampling: Sampling = args.get("sampling", "poisson").parse()?;
    let seed = args.get_u64("seed", 0)?;
    let clip = ClipPolicy {
        clip_init: args.get_f64("clip", 1e-2)?,
        ..ClipPolicy::from_pipeline_mode(mode, false)
    };
    let privacy = PrivacySpec {
        epsilon: args.get_f64("epsilon", 1.0)?,
        delta: args.get_f64("delta", 1e-5)?,
        quantile_r: 0.0,
    };
    let data = DataSpec {
        task: args.get("task", "auto"),
        n_data: args.get_usize("n-data", 2048)?,
        seed,
    };
    run_session(
        Session::builder(rt, &config)
            .privacy(privacy)
            .clip(clip)
            .optim(OptimSpec {
                kind: gwclip::coordinator::optimizer::OptimizerKind::Adam {
                    beta1: 0.9,
                    beta2: 0.999,
                    eps: 1e-8,
                },
                lr: args.get_f64("lr", 5e-3)?,
                weight_decay: 0.0,
                lr_decay: false,
            })
            .data(data)
            .epochs(args.get_f64("epochs", 1.0)?)
            .n_micro(args.get_usize("n-micro", 4)?)
            .steps(args.get_usize("steps", 10)?)
            .sampling(sampling)
            .threads(args.get_usize("threads", 1)?)
            .seed(seed),
        args,
    )
}
