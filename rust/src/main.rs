//! gwclip CLI — leader entrypoint.
//!
//! Subcommands map one-to-one onto the paper's tables and figures
//! (DESIGN.md section 6); `train` and `pipeline` expose the library for
//! ad-hoc runs.

use anyhow::{bail, Result};

use gwclip::coordinator::{Allocation, Method, TrainOpts, Trainer};
use gwclip::data::classif::MixtureImages;
use gwclip::data::lm::MarkovCorpus;
use gwclip::data::Dataset;
use gwclip::pipeline::{PipelineEngine, PipelineMode, PipelineOpts};
use gwclip::runtime::Runtime;
use gwclip::util::cli::Args;

const USAGE: &str = "\
gwclip — group-wise clipping for DP deep learning (ICLR 2023 reproduction)

USAGE:
  gwclip train    [--config resmlp] [--method adaptive-per-layer] [--epsilon 3]
                  [--epochs 3] [--lr 0.5] [--n-data 4096] [--seed 0]
                  [--allocation global|equal|weighted]
  gwclip pipeline [--config lm_mid_pipe_lora] [--mode per-device|flat-sync|non-private]
                  [--steps 10] [--n-micro 4]
  gwclip exp <which>   table1|table2|table3|table4|table5|table6|table10|table11|
                       fig1|fig2|fig3|fig5|fig6|fig7|pipeline-overhead|accountant|all
                       [--paper-scale]
  common: [--artifacts DIR]
";

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "non-private" | "nonprivate" => Method::NonPrivate,
        "flat" | "fixed-flat" => Method::FlatFixed,
        "adaptive-flat" => Method::FlatAdaptive,
        "per-layer" | "fixed-per-layer" => Method::PerLayerFixed,
        "adaptive-per-layer" => Method::PerLayerAdaptive,
        "ghost" => Method::Ghost,
        "naive" => Method::Naive,
        _ => bail!("unknown method '{s}'"),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv, &["paper-scale"])?;
    let dir = args
        .flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(gwclip::artifact_dir);
    let rt = Runtime::new(&dir)?;

    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&rt, &args),
        Some("pipeline") => cmd_pipeline(&rt, &args),
        Some("exp") => {
            let which = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("exp needs a name; see --help"))?;
            gwclip::exp::run(&rt, which, args.has("paper-scale"))
        }
        _ => {
            print!("{USAGE}");
            bail!("unknown subcommand")
        }
    }
}

fn cmd_train(rt: &Runtime, args: &Args) -> Result<()> {
    let config = args.get("config", "resmlp");
    let method = parse_method(&args.get("method", "adaptive-per-layer"))?;
    let seed = args.get_u64("seed", 0)?;
    let n_data = args.get_usize("n-data", 4096)?;
    let optimizer = match args.get("opt", "sgd").as_str() {
        "sgd" => gwclip::coordinator::optimizer::OptimizerKind::Sgd { momentum: 0.0 },
        "momentum" => gwclip::coordinator::optimizer::OptimizerKind::Sgd { momentum: 0.9 },
        "adam" => gwclip::coordinator::optimizer::OptimizerKind::Adam {
            beta1: 0.9, beta2: 0.98, eps: 1e-6,
        },
        o => bail!("unknown optimizer {o}"),
    };
    let opts = TrainOpts {
        method,
        epsilon: args.get_f64("epsilon", 3.0)?,
        epochs: args.get_f64("epochs", 3.0)?,
        lr: args.get_f64("lr", 0.5)?,
        seed,
        optimizer,
        clip_init: args.get_f64("clip", 1.0)?,
        target_q: args.get_f64("quantile", 0.5)?,
        allocation: Allocation::parse(&args.get("allocation", "global"))?,
        ..Default::default()
    };
    let cfgm = rt.manifest.config(&config)?;
    let (train, eval): (Box<dyn Dataset>, Box<dyn Dataset>) = match cfgm.model.as_str() {
        "resmlp" => (
            Box::new(MixtureImages::new(n_data, cfgm.hyper.features, cfgm.hyper.n_classes, seed)),
            Box::new(MixtureImages::new(
                n_data / 4,
                cfgm.hyper.features,
                cfgm.hyper.n_classes,
                seed + 1000,
            )),
        ),
        "lm" => (
            Box::new(MarkovCorpus::new(n_data, cfgm.hyper.seq, cfgm.hyper.vocab, 4, seed)),
            Box::new(MarkovCorpus::new(n_data / 4, cfgm.hyper.seq, cfgm.hyper.vocab, 4, seed + 1000)),
        ),
        "classifier" => {
            use gwclip::data::classif::{SentimentCorpus, TextTask};
            (
                Box::new(SentimentCorpus::new(TextTask::Sst2, n_data, cfgm.hyper.seq, cfgm.hyper.vocab, seed)),
                Box::new(SentimentCorpus::new(TextTask::Sst2, n_data / 4, cfgm.hyper.seq, cfgm.hyper.vocab, seed + 1000)),
            )
        }
        other => bail!("train subcommand supports resmlp/lm/classifier configs, not {other}"),
    };
    let mut tr = Trainer::new(rt, &config, train.len(), opts)?;
    if let Some(p) = tr.plan {
        eprintln!(
            "privacy plan: sigma={:.3} sigma_grad={:.3} sigma_b={:.3} (r={}) steps={}",
            p.sigma_base, p.sigma_grad, p.sigma_quantile, p.quantile_fraction, tr.total_steps
        );
    }
    tr.run(&*train, 10)?;
    let (loss, acc) = tr.evaluate(&*eval)?;
    println!("final: eval loss {loss:.4} acc {acc:.4}");
    Ok(())
}

fn cmd_pipeline(rt: &Runtime, args: &Args) -> Result<()> {
    let config = args.get("config", "lm_mid_pipe_lora");
    let mode = match args.get("mode", "per-device").as_str() {
        "per-device" => PipelineMode::PerDevice,
        "flat-sync" => PipelineMode::FlatSync,
        "non-private" => PipelineMode::NonPrivate,
        m => bail!("mode '{m}': per-device|flat-sync|non-private"),
    };
    let steps = args.get_usize("steps", 10)?;
    let opts = PipelineOpts {
        mode,
        n_micro: args.get_usize("n-micro", 4)?,
        sigma: 0.5,
        clip: 1e-2,
        ..Default::default()
    };
    let cfgm = rt.manifest.config(&config)?;
    let data = MarkovCorpus::new(2048, cfgm.hyper.seq, cfgm.hyper.vocab, 4, 0);
    let mut eng = PipelineEngine::new(rt, &config, opts)?;
    let mb = eng.minibatch();
    for s in 0..steps {
        let idx: Vec<usize> = (0..mb).map(|i| (s * mb + i) % data.len()).collect();
        let st = eng.step(&data, &idx)?;
        println!(
            "step {s}: loss {:.4} host {:.2}s sim {:.3}s syncs {} calls {}",
            st.loss, st.host_secs, st.sim_secs, st.syncs, st.calls
        );
    }
    Ok(())
}
