//! # gwclip — Group-wise Clipping for Differentially Private Deep Learning
//!
//! Production-quality reproduction of *"Exploring the Limits of
//! Differentially Private Deep Learning with Group-wise Clipping"*
//! (ICLR 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** Pallas kernels (`python/compile/kernels`): ghost-norm and fused
//!   clip+reduce — the compute hot-spot, AOT-lowered, never run from python
//!   at train time.
//! * **L2** JAX models (`python/compile`): manual-backprop transformer /
//!   residual-MLP with per-layer clipping fused into the backward pass,
//!   exported once to `artifacts/*.hlo.txt`.
//! * **L3** this crate: PJRT runtime, privacy accountant, the unified
//!   [`session`] API over the single-device, pipeline-parallel and
//!   sharded data-parallel backends, adaptive quantile state, noise
//!   allocation, DP optimizers, Poisson sampling, data substrates, and
//!   the experiment harness regenerating every table and figure.
//!
//! ## Quick start (after `make artifacts`)
//!
//! Every training scenario — flat / per-layer / per-device clipping,
//! fixed or adaptive thresholds, one device or a pipeline — is one
//! [`session::RunSpec`] away. The builder selects the backend from the
//! manifest (configs with pipeline stages run on the pipeline engine) and
//! derives all noise from the accountant:
//!
//! ```no_run
//! use gwclip::runtime::Runtime;
//! use gwclip::session::{ClipMode, ClipPolicy, GroupBy, PrivacySpec, Session};
//!
//! let rt = Runtime::new("artifacts").unwrap();
//! let (mut sess, train, eval) = Session::builder(&rt, "resmlp")
//!     .privacy(PrivacySpec::new(3.0, 1e-5))
//!     .clip(ClipPolicy::new(GroupBy::PerLayer, ClipMode::Adaptive))
//!     .epochs(3.0)
//!     .build_with_data()
//!     .unwrap();
//! println!("{}", sess.describe());
//! sess.run(&*train, 10).unwrap();
//! let (loss, acc) = sess.evaluate(&*eval).unwrap();
//! println!("loss {loss:.3} acc {acc:.3}");
//! ```
//!
//! Runs are also declarable as TOML/JSON spec files executed by
//! `gwclip run --spec run.toml` (see `docs/SESSION_API.md`). The session
//! builder is the *only* construction surface: the legacy `Trainer::new` /
//! `PipelineEngine::new` raw-sigma shims are retired, and every backend —
//! single-device, pipeline-parallel, the sharded data-parallel
//! [`shard::ShardEngine`], the hybrid 2D-parallel
//! [`hybrid::HybridEngine`] (pipeline stages x data-parallel replicas),
//! and the user-level federated [`federated::FederatedEngine`] —
//! receives its DP state through the same shared [`session::DpCore`].

pub mod coordinator;
pub mod data;
pub mod exp;
pub mod federated;
pub mod hybrid;
pub mod kernels;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod shard;
pub mod util;

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Resolve the artifact dir: $GWCLIP_ARTIFACTS or ./artifacts.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("GWCLIP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(ARTIFACT_DIR))
}
