//! # gwclip — Group-wise Clipping for Differentially Private Deep Learning
//!
//! Production-quality reproduction of *"Exploring the Limits of
//! Differentially Private Deep Learning with Group-wise Clipping"*
//! (ICLR 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** Pallas kernels (`python/compile/kernels`): ghost-norm and fused
//!   clip+reduce — the compute hot-spot, AOT-lowered, never run from python
//!   at train time.
//! * **L2** JAX models (`python/compile`): manual-backprop transformer /
//!   residual-MLP with per-layer clipping fused into the backward pass,
//!   exported once to `artifacts/*.hlo.txt`.
//! * **L3** this crate: PJRT runtime, privacy accountant, adaptive quantile
//!   state, noise allocation, DP optimizers, Poisson sampling, the
//!   pipeline-parallel engine with per-device clipping, data substrates,
//!   and the experiment harness regenerating every table and figure.
//!
//! Quick start (after `make artifacts`):
//! ```no_run
//! use gwclip::coordinator::{Method, TrainOpts, Trainer};
//! use gwclip::data::classif::MixtureImages;
//! use gwclip::runtime::Runtime;
//!
//! let rt = Runtime::new("artifacts").unwrap();
//! let data = MixtureImages::new(4096, 64, 10, 0);
//! let opts = TrainOpts { method: Method::PerLayerAdaptive, epsilon: 3.0, ..Default::default() };
//! let mut t = Trainer::new(&rt, "resmlp", 4096, opts).unwrap();
//! t.run(&data, 10).unwrap();
//! let (loss, acc) = t.evaluate(&data).unwrap();
//! println!("loss {loss:.3} acc {acc:.3}");
//! ```

pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod util;

/// Default artifact directory (relative to the repo root).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Resolve the artifact dir: $GWCLIP_ARTIFACTS or ./artifacts.
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("GWCLIP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(ARTIFACT_DIR))
}
