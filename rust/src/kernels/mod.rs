//! Runtime-dispatched SIMD kernels for the host-side hot path.
//!
//! Every per-step host loop the profiler sees — gaussian noise fill,
//! clip-coefficient application, squared-norm accumulation, tree-reduce
//! summation, optimizer apply — routes through one [`Kernels`] vtable.
//! The vtable is populated once per session from the host's CPU features
//! (`is_x86_feature_detected!("avx2")`, cached process-wide) and the
//! session's `kernels` mode knob, then passed by value (it is `Copy`, a
//! bundle of function pointers) into the step loop, the engines and the
//! optimizer.
//!
//! ## The reproducibility contract
//!
//! Kernels split into two classes:
//!
//! * **Bit-exact elementwise** — clip/axpy apply, tensor add, scaling,
//!   SGD/Adam update, noise add from a pre-filled gaussian buffer. Each
//!   output element is produced by the same IEEE-754 operations in the
//!   same order as the scalar reference (AVX2 `mul`/`add`/`div`/`sqrt`
//!   and `cvtpd_ps` round exactly like their scalar counterparts; no FMA
//!   contraction is ever used), so the SIMD variants are bitwise
//!   identical to scalar on every input. These dispatch purely on ISA.
//! * **Reassociating** — squared-norm accumulation (blocked partial
//!   sums), tree-reduce pair folding, and the batched gaussian draw
//!   (block candidate generation over four interleaved xoshiro lanes
//!   with a polynomial `ln`). They change summation order or the RNG
//!   consumption pattern and therefore sit behind the `kernels` mode:
//!   [`KernelMode::Scalar`] (the default) keeps the sequential
//!   bit-reference; [`KernelMode::Auto`] enables them. `Auto` is itself
//!   deterministic ACROSS hosts — the batched algorithms are specified
//!   exactly (same lane layout, same polynomial, same acceptance order)
//!   and the scalar and AVX2 implementations of each batched kernel are
//!   bitwise identical to each other — so the mode, not the host,
//!   decides the bits.
//!
//! See `docs/SESSION_API.md`, "Kernels".

use std::sync::OnceLock;

use crate::util::rng::Xoshiro;

pub mod scalar;
#[cfg(target_arch = "x86_64")]
pub mod avx2;

// ----------------------------------------------------------------- mode

/// The `kernels` spec knob: which summation/draw semantics the run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Sequential bit-reference semantics everywhere (the default):
    /// left-to-right summation, one Marsaglia-polar gaussian at a time.
    #[default]
    Scalar,
    /// Reassociated summations (blocked squared-norm partials, paired
    /// tree-reduce folds) and the batched 4-lane gaussian fill. Bitwise
    /// self-consistent across hosts, but a DIFFERENT bit-stream than
    /// `scalar` — snapshots record the mode so resume can refuse a
    /// switch (`session::snapshot`).
    Auto,
}

impl KernelMode {
    pub fn token(self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Auto => "auto",
        }
    }
}

impl std::str::FromStr for KernelMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(KernelMode::Scalar),
            "auto" => Ok(KernelMode::Auto),
            other => anyhow::bail!("unknown kernels mode {other:?} (expected scalar | auto)"),
        }
    }
}

// ------------------------------------------------------------------ isa

/// The instruction set a [`Kernels`] vtable was built for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    Scalar,
    Avx2,
}

impl KernelIsa {
    pub fn token(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
        }
    }

    /// Whether this ISA's kernels can run on the current host.
    pub fn available(self) -> bool {
        match self {
            KernelIsa::Scalar => true,
            KernelIsa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
        }
    }

    /// The best ISA the host supports, detected once per process
    /// (extensible to avx512 by adding a variant and a probe here).
    pub fn detect() -> KernelIsa {
        static DETECTED: OnceLock<KernelIsa> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            if KernelIsa::Avx2.available() {
                KernelIsa::Avx2
            } else {
                KernelIsa::Scalar
            }
        })
    }
}

// --------------------------------------------------------------- vtable

/// Per-element SGD coefficients (all pre-cast to f32, matching the
/// scalar reference loop in `coordinator::optimizer`).
#[derive(Clone, Copy, Debug)]
pub struct SgdCoeffs {
    pub weight_decay: f32,
    pub momentum: f32,
    pub lr: f32,
}

/// Per-element Adam coefficients. The f32 fields drive the moment
/// updates; the f64 fields drive the bias-corrected step, exactly as the
/// scalar reference computes them.
#[derive(Clone, Copy, Debug)]
pub struct AdamCoeffs {
    pub weight_decay: f32,
    pub beta1: f32,
    /// `1.0 - beta1 as f32`, precomputed once (the reference hoists it).
    pub one_minus_beta1: f32,
    pub beta2: f32,
    pub one_minus_beta2: f32,
    /// `1 - beta1^t` / `1 - beta2^t` bias corrections at this step.
    pub bias1: f64,
    pub bias2: f64,
    pub lr: f64,
    pub eps: f64,
}

/// The dispatched kernel vtable: one set of function pointers chosen at
/// construction from (mode, ISA). `Copy` so engines and closures carry
/// it by value with no indirection beyond the call itself.
#[derive(Clone, Copy)]
pub struct Kernels {
    mode: KernelMode,
    isa: KernelIsa,
    // bit-exact elementwise (ISA-dispatched, mode-independent)
    axpy: fn(&mut [f32], &[f32], f32),
    add_assign: fn(&mut [f32], &[f32]),
    add2_assign: fn(&mut [f32], &[f32], &[f32]),
    scale: fn(&mut [f32], f32),
    add_noise_from: fn(&mut [f32], &[f64], f64),
    sgd_update: fn(&mut [f32], &[f32], &mut [f32], SgdCoeffs),
    adam_update: fn(&mut [f32], &[f32], &mut [f32], &mut [f32], AdamCoeffs),
    // reassociating (used only when mode == Auto)
    sq_norm_wide: fn(&[f32]) -> f64,
    gauss_block: fn(&mut [Xoshiro; 4], &mut Vec<f64>),
}

impl std::fmt::Debug for Kernels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernels").field("mode", &self.mode).field("isa", &self.isa).finish()
    }
}

impl Default for Kernels {
    fn default() -> Self {
        Kernels::scalar()
    }
}

impl Kernels {
    /// The pure bit-reference: scalar mode on the scalar ISA. This is
    /// what every session runs unless the `kernels` knob says otherwise.
    pub fn scalar() -> Kernels {
        Kernels::with(KernelMode::Scalar, KernelIsa::Scalar)
    }

    /// The vtable a session resolves from its `kernels` mode: `scalar`
    /// stays on the scalar ISA end to end (maximally conservative —
    /// byte-for-byte the pre-kernel-layer behavior), `auto` takes the
    /// best detected ISA plus the reassociating kernels.
    pub fn for_mode(mode: KernelMode) -> Kernels {
        match mode {
            KernelMode::Scalar => Kernels::scalar(),
            KernelMode::Auto => Kernels::with(KernelMode::Auto, KernelIsa::detect()),
        }
    }

    /// Explicit (mode, ISA) construction — the test/bench surface for
    /// pinning scalar-vs-SIMD parity on the same mode. Panics if the
    /// ISA is unavailable on this host.
    pub fn with(mode: KernelMode, isa: KernelIsa) -> Kernels {
        assert!(isa.available(), "kernel ISA {} unavailable on this host", isa.token());
        match isa {
            KernelIsa::Scalar => Kernels {
                mode,
                isa,
                axpy: scalar::axpy,
                add_assign: scalar::add_assign,
                add2_assign: scalar::add2_assign,
                scale: scalar::scale,
                add_noise_from: scalar::add_noise_from,
                sgd_update: scalar::sgd_update,
                adam_update: scalar::adam_update,
                sq_norm_wide: scalar::sq_norm_wide,
                gauss_block: scalar::gauss_block,
            },
            KernelIsa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    Kernels {
                        mode,
                        isa,
                        axpy: avx2::axpy,
                        add_assign: avx2::add_assign,
                        add2_assign: avx2::add2_assign,
                        scale: avx2::scale,
                        add_noise_from: avx2::add_noise_from,
                        sgd_update: avx2::sgd_update,
                        adam_update: avx2::adam_update,
                        sq_norm_wide: avx2::sq_norm_wide,
                        gauss_block: avx2::gauss_block,
                    }
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    unreachable!("avx2 availability is gated above")
                }
            }
        }
    }

    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    pub fn isa(&self) -> KernelIsa {
        self.isa
    }

    /// Whether reassociating kernels are enabled (tree-reduce pair
    /// folding, blocked squared-norm, batched gaussian fill).
    pub fn reassociate(&self) -> bool {
        self.mode == KernelMode::Auto
    }

    /// `acc[i] += f * x[i]` — clip-coefficient / local-SGD apply.
    #[inline]
    pub fn axpy(&self, acc: &mut [f32], x: &[f32], f: f32) {
        debug_assert_eq!(acc.len(), x.len());
        (self.axpy)(acc, x, f)
    }

    /// `acc[i] += x[i]` — gradient accumulation / error-feedback add.
    #[inline]
    pub fn add_assign(&self, acc: &mut [f32], x: &[f32]) {
        debug_assert_eq!(acc.len(), x.len());
        (self.add_assign)(acc, x)
    }

    /// `acc[i] += a[i] + b[i]` — the paired tree-reduce fold. NOTE this
    /// reassociates relative to two sequential [`Kernels::add_assign`]
    /// calls; callers gate it on [`Kernels::reassociate`].
    #[inline]
    pub fn add2_assign(&self, acc: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert_eq!(acc.len(), a.len());
        debug_assert_eq!(acc.len(), b.len());
        (self.add2_assign)(acc, a, b)
    }

    /// `x[i] *= f` — worker-mean / update-scale rescale.
    #[inline]
    pub fn scale(&self, x: &mut [f32], f: f32) {
        (self.scale)(x, f)
    }

    /// `buf[i] += (std * gauss[i]) as f32` — noise add from a pre-filled
    /// standard-gaussian buffer. Bit-exact across ISAs.
    #[inline]
    pub fn add_noise_from(&self, buf: &mut [f32], gauss: &[f64], std: f64) {
        debug_assert_eq!(buf.len(), gauss.len());
        (self.add_noise_from)(buf, gauss, std)
    }

    /// One SGD(-momentum) update over a parameter buffer, bit-exact to
    /// the scalar reference in `coordinator::optimizer`.
    #[inline]
    pub fn sgd_update(&self, p: &mut [f32], g: &[f32], m: &mut [f32], c: SgdCoeffs) {
        debug_assert_eq!(p.len(), g.len());
        debug_assert_eq!(p.len(), m.len());
        (self.sgd_update)(p, g, m, c)
    }

    /// One Adam update over a parameter buffer, bit-exact to the scalar
    /// reference in `coordinator::optimizer`.
    #[inline]
    pub fn adam_update(&self, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], c: AdamCoeffs) {
        debug_assert_eq!(p.len(), g.len());
        debug_assert_eq!(p.len(), m.len());
        debug_assert_eq!(p.len(), v.len());
        (self.adam_update)(p, g, m, v, c)
    }

    /// `init + sum x[i]^2` in f64. Scalar mode folds left-to-right (the
    /// bit-reference); auto mode uses 8 blocked partial accumulators
    /// with a fixed reduction tree — reassociated, so drift-bounded
    /// rather than bit-pinned (see `tests/kernels.rs`).
    #[inline]
    pub fn sq_norm(&self, init: f64, x: &[f32]) -> f64 {
        match self.mode {
            KernelMode::Scalar => scalar::sq_norm_seq(init, x),
            KernelMode::Auto => init + (self.sq_norm_wide)(x),
        }
    }

    /// Append one block of standard gaussians drawn from the four
    /// interleaved lanes (see [`GaussFill`]). Bitwise identical across
    /// ISAs by construction.
    #[inline]
    pub fn gauss_block(&self, lanes: &mut [Xoshiro; 4], out: &mut Vec<f64>) {
        (self.gauss_block)(lanes, out)
    }
}

// ------------------------------------------------------- batched gauss

/// Candidate rounds per [`Kernels::gauss_block`] call; each round draws
/// one (u, v) candidate per lane, so a block yields ~`4 * ROUNDS * pi/4`
/// accepted gaussians.
pub const GAUSS_ROUNDS: usize = 64;

pub(crate) const TWO_NEG53: f64 = 1.0 / (1u64 << 53) as f64;

/// A batched standard-gaussian source: four xoshiro256++ lanes split off
/// a parent [`Rng`](crate::coordinator::noise::Rng) (consuming exactly
/// four child splits), generating Marsaglia-polar candidates in blocks.
/// The candidate order (round-major, lane-minor), the acceptance rule
/// (`s < 1 && s != 0`) and the `sqrt(-2 ln s / s)` transform via
/// [`poly_ln`] are fixed by specification, so the stream depends only on
/// the parent's split states — never on the ISA.
pub struct GaussFill {
    lanes: [Xoshiro; 4],
    pending: Vec<f64>,
    cursor: usize,
}

impl GaussFill {
    /// Seed the four lanes from `rng` (four `split()`s, advancing the
    /// parent stream by four draws).
    pub fn new(rng: &mut crate::coordinator::noise::Rng) -> GaussFill {
        let lanes = std::array::from_fn(|_| Xoshiro::from_state(rng.split().state()));
        GaussFill { lanes, pending: Vec::new(), cursor: 0 }
    }

    /// Seed the lanes directly (tests pin ISA parity on fixed states).
    pub fn from_lanes(lanes: [Xoshiro; 4]) -> GaussFill {
        GaussFill { lanes, pending: Vec::new(), cursor: 0 }
    }

    /// Fill `out` with the next standard gaussians of this stream.
    pub fn fill(&mut self, k: &Kernels, out: &mut [f64]) {
        let mut i = 0;
        while i < out.len() {
            if self.cursor == self.pending.len() {
                self.pending.clear();
                self.cursor = 0;
                while self.pending.is_empty() {
                    k.gauss_block(&mut self.lanes, &mut self.pending);
                }
            }
            let n = (out.len() - i).min(self.pending.len() - self.cursor);
            out[i..i + n].copy_from_slice(&self.pending[self.cursor..self.cursor + n]);
            self.cursor += n;
            i += n;
        }
    }
}

// ---------------------------------------------------------- polynomial ln

pub(crate) const C3: f64 = 1.0 / 3.0;
pub(crate) const C5: f64 = 1.0 / 5.0;
pub(crate) const C7: f64 = 1.0 / 7.0;
pub(crate) const C9: f64 = 1.0 / 9.0;
pub(crate) const C11: f64 = 1.0 / 11.0;
pub(crate) const C13: f64 = 1.0 / 13.0;
pub(crate) const C15: f64 = 1.0 / 15.0;
pub(crate) const C17: f64 = 1.0 / 17.0;
pub(crate) const C19: f64 = 1.0 / 19.0;

/// Polynomial natural log for finite positive *normal* f64 inputs, used
/// by the batched gaussian transform on every ISA (libm `ln`
/// implementations vary across platforms; this one is pinned down to the
/// operation order, so the batched stream is host-independent).
///
/// Decomposes `x = m * 2^e` with `m` in `[1, 2)` and sums the odd atanh
/// series of `t = (m-1)/(m+1)` (|t| < 1/3) through `t^19/19` by Horner —
/// truncation plus rounding stays under ~1e-10 relative (pinned by a
/// property test against `f64::ln`).
#[inline]
pub fn poly_ln(x: f64) -> f64 {
    debug_assert!(x > 0.0 && x.is_finite() && x >= f64::MIN_POSITIVE);
    let bits = x.to_bits();
    let e = (((bits >> 52) & 0x7ff) as i64 - 1023) as f64;
    let m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000);
    let t = (m - 1.0) / (m + 1.0);
    let t2 = t * t;
    let mut p = C19;
    p = p * t2 + C17;
    p = p * t2 + C15;
    p = p * t2 + C13;
    p = p * t2 + C11;
    p = p * t2 + C9;
    p = p * t2 + C7;
    p = p * t2 + C5;
    p = p * t2 + C3;
    p = p * t2 + 1.0;
    e * std::f64::consts::LN_2 + (2.0 * t) * p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_tokens_round_trip_and_bad_tokens_fail() {
        for m in [KernelMode::Scalar, KernelMode::Auto] {
            assert_eq!(m.token().parse::<KernelMode>().unwrap(), m);
        }
        assert!("avx2".parse::<KernelMode>().is_err());
        assert!("".parse::<KernelMode>().is_err());
        assert!("Scalar".parse::<KernelMode>().is_err());
    }

    #[test]
    fn default_mode_is_scalar_and_default_vtable_is_the_bit_reference() {
        assert_eq!(KernelMode::default(), KernelMode::Scalar);
        let k = Kernels::default();
        assert_eq!(k.mode(), KernelMode::Scalar);
        assert_eq!(k.isa(), KernelIsa::Scalar);
        assert!(!k.reassociate());
    }

    #[test]
    fn for_mode_scalar_stays_on_the_scalar_isa() {
        let k = Kernels::for_mode(KernelMode::Scalar);
        assert_eq!(k.isa(), KernelIsa::Scalar);
        let k = Kernels::for_mode(KernelMode::Auto);
        assert_eq!(k.isa(), KernelIsa::detect());
        assert!(k.reassociate());
    }

    #[test]
    fn detect_is_stable_across_calls() {
        assert_eq!(KernelIsa::detect(), KernelIsa::detect());
        assert!(KernelIsa::detect().available());
    }

    #[test]
    fn poly_ln_tracks_libm_ln() {
        let mut r = Xoshiro::seeded(9);
        for _ in 0..20_000 {
            // spread across magnitudes: s = u * 2^k, k in [-300, 300)
            let u = r.uniform().max(1e-3);
            let k = (r.below(600) as i32) - 300;
            let x = u * 2f64.powi(k);
            let got = poly_ln(x);
            let want = x.ln();
            assert!(
                (got - want).abs() <= 1e-10 * want.abs().max(1.0),
                "poly_ln({x}) = {got}, ln = {want}"
            );
        }
        // the polar-method domain specifically
        for s in [1e-300, 1e-12, 0.017, 0.5, 0.999_999, 1.0 - f64::EPSILON] {
            assert!((poly_ln(s) - s.ln()).abs() <= 1e-10 * s.ln().abs().max(1.0));
        }
    }

    #[test]
    fn gauss_fill_is_deterministic_for_fixed_lane_states() {
        let lanes = || std::array::from_fn(|j| Xoshiro::seeded(100 + j as u64));
        let k = Kernels::scalar();
        let mut a = vec![0.0; 1000];
        let mut b = vec![0.0; 1000];
        GaussFill::from_lanes(lanes()).fill(&k, &mut a);
        GaussFill::from_lanes(lanes()).fill(&k, &mut b);
        assert_eq!(a, b);
        // and invariant to how the output is chunked
        let mut c = vec![0.0; 1000];
        let mut g = GaussFill::from_lanes(lanes());
        g.fill(&k, &mut c[..137]);
        g.fill(&k, &mut c[137..612]);
        g.fill(&k, &mut c[612..]);
        assert_eq!(a, c);
    }

    #[test]
    fn gauss_block_is_bitwise_identical_across_isas() {
        if KernelIsa::detect() == KernelIsa::Scalar {
            return; // scalar-only host: the pin is vacuous here, CI x86 covers it
        }
        let lanes = || -> [Xoshiro; 4] { std::array::from_fn(|j| Xoshiro::seeded(7 + j as u64)) };
        let (ks, kv) = (
            Kernels::with(KernelMode::Auto, KernelIsa::Scalar),
            Kernels::with(KernelMode::Auto, KernelIsa::detect()),
        );
        let (mut ls, mut lv) = (lanes(), lanes());
        let (mut outs, mut outv) = (Vec::new(), Vec::new());
        for _ in 0..50 {
            ks.gauss_block(&mut ls, &mut outs);
            kv.gauss_block(&mut lv, &mut outv);
        }
        assert_eq!(outs.len(), outv.len());
        for (a, b) in outs.iter().zip(&outv) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // lane states advanced identically too
        for j in 0..4 {
            assert_eq!(ls[j].state(), lv[j].state());
        }
    }
}
