//! Scalar kernel variants — the bit-reference every SIMD variant is
//! pinned against. The elementwise loops here reproduce, operation for
//! operation, the inner loops they replaced in `coordinator::optimizer`,
//! `coordinator::noise`, `shard::reduce` and the engine collect paths;
//! the blocked/batched variants (`sq_norm_wide`, `gauss_block`) mirror
//! the AVX2 lane layout exactly so both ISAs produce the same bits in
//! `auto` mode.

use crate::util::rng::Xoshiro;

use super::{poly_ln, AdamCoeffs, SgdCoeffs, GAUSS_ROUNDS, TWO_NEG53};

pub fn axpy(acc: &mut [f32], x: &[f32], f: f32) {
    for (a, v) in acc.iter_mut().zip(x) {
        *a += f * *v;
    }
}

pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    for (a, v) in acc.iter_mut().zip(x) {
        *a += *v;
    }
}

pub fn add2_assign(acc: &mut [f32], a: &[f32], b: &[f32]) {
    for ((t, x), y) in acc.iter_mut().zip(a).zip(b) {
        *t += *x + *y;
    }
}

pub fn scale(x: &mut [f32], f: f32) {
    for v in x.iter_mut() {
        *v *= f;
    }
}

pub fn add_noise_from(buf: &mut [f32], gauss: &[f64], std: f64) {
    for (x, g) in buf.iter_mut().zip(gauss) {
        *x += (std * *g) as f32;
    }
}

pub fn sgd_update(p: &mut [f32], g: &[f32], m: &mut [f32], c: SgdCoeffs) {
    for ((pj, gj), mj) in p.iter_mut().zip(g).zip(m.iter_mut()) {
        let grad = *gj + c.weight_decay * *pj;
        *mj = c.momentum * *mj + grad;
        *pj -= c.lr * *mj;
    }
}

pub fn adam_update(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], c: AdamCoeffs) {
    for (((pj, gj), mj), vj) in p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
        let grad = *gj + c.weight_decay * *pj;
        *mj = c.beta1 * *mj + c.one_minus_beta1 * grad;
        *vj = c.beta2 * *vj + c.one_minus_beta2 * grad * grad;
        let mhat = *mj as f64 / c.bias1;
        let vhat = *vj as f64 / c.bias2;
        *pj -= (c.lr * mhat / (vhat.sqrt() + c.eps)) as f32;
    }
}

/// Left-to-right `init + sum x^2` in f64 — the sequential bit-reference
/// used in scalar mode (identical to the engines' original loops).
pub fn sq_norm_seq(init: f64, x: &[f32]) -> f64 {
    let mut sq = init;
    for &v in x {
        let v = v as f64;
        sq += v * v;
    }
    sq
}

/// Blocked `sum x^2`: 8 partial f64 accumulators over chunks of 8
/// elements, combined by the fixed tree
/// `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))`, then a sequential tail —
/// the exact reduction the AVX2 variant performs, so both ISAs agree
/// bitwise in `auto` mode.
pub fn sq_norm_wide(x: &[f32]) -> f64 {
    let mut acc = [0f64; 8];
    let chunks = x.len() / 8;
    for c in 0..chunks {
        for (j, a) in acc.iter_mut().enumerate() {
            let v = x[c * 8 + j] as f64;
            *a += v * v;
        }
    }
    let mut total =
        ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
    for &v in &x[chunks * 8..] {
        let v = v as f64;
        total += v * v;
    }
    total
}

#[inline]
fn u64_unit(x: u64) -> f64 {
    (x >> 11) as f64 * TWO_NEG53
}

/// One block of batched Marsaglia-polar gaussians: `GAUSS_ROUNDS` rounds,
/// each drawing one (u, v) candidate per lane — all four u-draws, then
/// all four v-draws, candidates consumed round-major/lane-minor — with
/// acceptance `s < 1 && s != 0` and the [`poly_ln`] transform. This
/// order matches the AVX2 variant's vectorized draws exactly.
pub fn gauss_block(lanes: &mut [Xoshiro; 4], out: &mut Vec<f64>) {
    for _ in 0..GAUSS_ROUNDS {
        let mut a = [0u64; 4];
        for (j, w) in a.iter_mut().enumerate() {
            *w = lanes[j].next_u64();
        }
        let mut b = [0u64; 4];
        for (j, w) in b.iter_mut().enumerate() {
            *w = lanes[j].next_u64();
        }
        for j in 0..4 {
            let u = 2.0 * u64_unit(a[j]) - 1.0;
            let v = 2.0 * u64_unit(b[j]) - 1.0;
            let s = u * u + v * v;
            if s < 1.0 && s != 0.0 {
                let r = ((-2.0 * poly_ln(s)) / s).sqrt();
                out.push(u * r);
                out.push(v * r);
            }
        }
    }
}
