//! AVX2 kernel variants. Every elementwise kernel here performs the same
//! IEEE-754 operations, in the same per-element order, as its scalar
//! reference in [`super::scalar`] — `vmulps`/`vaddps`/`vdivpd`/`vsqrtpd`
//! and the `cvtpd2ps`/`cvtps2pd` conversions are correctly rounded
//! exactly like the corresponding scalar Rust ops, and no FMA contraction
//! is used — so the outputs are bitwise identical on every input. The
//! blocked kernels (`sq_norm_wide`, `gauss_block`) implement the exact
//! lane layout their scalar mirrors specify, so `auto` mode produces the
//! same bits regardless of which ISA was dispatched.
//!
//! Safety: every public function is a safe wrapper around a
//! `#[target_feature(enable = "avx2")]` body; the wrappers are only ever
//! installed into a [`super::Kernels`] vtable after
//! `KernelIsa::Avx2.available()` verified the host supports AVX2.

#![allow(clippy::missing_safety_doc)]

use std::arch::x86_64::*;

use crate::util::rng::Xoshiro;

use super::{
    poly_ln, AdamCoeffs, SgdCoeffs, C11, C13, C15, C17, C19, C3, C5, C7, C9, GAUSS_ROUNDS,
    TWO_NEG53,
};

pub fn axpy(acc: &mut [f32], x: &[f32], f: f32) {
    unsafe { axpy_impl(acc, x, f) }
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_impl(acc: &mut [f32], x: &[f32], f: f32) {
    let n = acc.len().min(x.len());
    let fv = _mm256_set1_ps(f);
    let mut i = 0;
    while i + 8 <= n {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, _mm256_mul_ps(fv, v)));
        i += 8;
    }
    while i < n {
        acc[i] += f * x[i];
        i += 1;
    }
}

pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    unsafe { add_assign_impl(acc, x) }
}

#[target_feature(enable = "avx2")]
unsafe fn add_assign_impl(acc: &mut [f32], x: &[f32]) {
    let n = acc.len().min(x.len());
    let mut i = 0;
    while i + 8 <= n {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, v));
        i += 8;
    }
    while i < n {
        acc[i] += x[i];
        i += 1;
    }
}

pub fn add2_assign(acc: &mut [f32], a: &[f32], b: &[f32]) {
    unsafe { add2_assign_impl(acc, a, b) }
}

#[target_feature(enable = "avx2")]
unsafe fn add2_assign_impl(acc: &mut [f32], a: &[f32], b: &[f32]) {
    let n = acc.len().min(a.len()).min(b.len());
    let mut i = 0;
    while i + 8 <= n {
        let t = _mm256_loadu_ps(acc.as_ptr().add(i));
        let x = _mm256_loadu_ps(a.as_ptr().add(i));
        let y = _mm256_loadu_ps(b.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(t, _mm256_add_ps(x, y)));
        i += 8;
    }
    while i < n {
        acc[i] += a[i] + b[i];
        i += 1;
    }
}

pub fn scale(x: &mut [f32], f: f32) {
    unsafe { scale_impl(x, f) }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_impl(x: &mut [f32], f: f32) {
    let n = x.len();
    let fv = _mm256_set1_ps(f);
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_mul_ps(v, fv));
        i += 8;
    }
    while i < n {
        x[i] *= f;
        i += 1;
    }
}

pub fn add_noise_from(buf: &mut [f32], gauss: &[f64], std: f64) {
    unsafe { add_noise_from_impl(buf, gauss, std) }
}

#[target_feature(enable = "avx2")]
unsafe fn add_noise_from_impl(buf: &mut [f32], gauss: &[f64], std: f64) {
    let n = buf.len().min(gauss.len());
    let sv = _mm256_set1_pd(std);
    let mut i = 0;
    while i + 4 <= n {
        let g = _mm256_loadu_pd(gauss.as_ptr().add(i));
        // (std * g) rounded to f32 — vcvtpd2ps rounds to nearest-even
        // exactly like the scalar `as f32` cast
        let f4 = _mm256_cvtpd_ps(_mm256_mul_pd(sv, g));
        let b = _mm_loadu_ps(buf.as_ptr().add(i));
        _mm_storeu_ps(buf.as_mut_ptr().add(i), _mm_add_ps(b, f4));
        i += 4;
    }
    while i < n {
        buf[i] += (std * gauss[i]) as f32;
        i += 1;
    }
}

pub fn sgd_update(p: &mut [f32], g: &[f32], m: &mut [f32], c: SgdCoeffs) {
    unsafe { sgd_update_impl(p, g, m, c) }
}

#[target_feature(enable = "avx2")]
unsafe fn sgd_update_impl(p: &mut [f32], g: &[f32], m: &mut [f32], c: SgdCoeffs) {
    let n = p.len().min(g.len()).min(m.len());
    let wd = _mm256_set1_ps(c.weight_decay);
    let mom = _mm256_set1_ps(c.momentum);
    let lr = _mm256_set1_ps(c.lr);
    let mut i = 0;
    while i + 8 <= n {
        let pv = _mm256_loadu_ps(p.as_ptr().add(i));
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let mv = _mm256_loadu_ps(m.as_ptr().add(i));
        let grad = _mm256_add_ps(gv, _mm256_mul_ps(wd, pv));
        let m2 = _mm256_add_ps(_mm256_mul_ps(mom, mv), grad);
        _mm256_storeu_ps(m.as_mut_ptr().add(i), m2);
        _mm256_storeu_ps(p.as_mut_ptr().add(i), _mm256_sub_ps(pv, _mm256_mul_ps(lr, m2)));
        i += 8;
    }
    while i < n {
        let grad = g[i] + c.weight_decay * p[i];
        m[i] = c.momentum * m[i] + grad;
        p[i] -= c.lr * m[i];
        i += 1;
    }
}

pub fn adam_update(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], c: AdamCoeffs) {
    unsafe { adam_update_impl(p, g, m, v, c) }
}

#[target_feature(enable = "avx2")]
unsafe fn adam_update_impl(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], c: AdamCoeffs) {
    let n = p.len().min(g.len()).min(m.len()).min(v.len());
    let wd = _mm_set1_ps(c.weight_decay);
    let b1 = _mm_set1_ps(c.beta1);
    let c1 = _mm_set1_ps(c.one_minus_beta1);
    let b2 = _mm_set1_ps(c.beta2);
    let c2 = _mm_set1_ps(c.one_minus_beta2);
    let bias1 = _mm256_set1_pd(c.bias1);
    let bias2 = _mm256_set1_pd(c.bias2);
    let lr = _mm256_set1_pd(c.lr);
    let eps = _mm256_set1_pd(c.eps);
    let mut i = 0;
    while i + 4 <= n {
        let pv = _mm_loadu_ps(p.as_ptr().add(i));
        let gv = _mm_loadu_ps(g.as_ptr().add(i));
        let mv = _mm_loadu_ps(m.as_ptr().add(i));
        let vv = _mm_loadu_ps(v.as_ptr().add(i));
        let grad = _mm_add_ps(gv, _mm_mul_ps(wd, pv));
        let m2 = _mm_add_ps(_mm_mul_ps(b1, mv), _mm_mul_ps(c1, grad));
        // (1-beta2) * grad * grad is left-associated in the reference
        let v2 = _mm_add_ps(_mm_mul_ps(b2, vv), _mm_mul_ps(_mm_mul_ps(c2, grad), grad));
        _mm_storeu_ps(m.as_mut_ptr().add(i), m2);
        _mm_storeu_ps(v.as_mut_ptr().add(i), v2);
        let mhat = _mm256_div_pd(_mm256_cvtps_pd(m2), bias1);
        let vhat = _mm256_div_pd(_mm256_cvtps_pd(v2), bias2);
        let upd = _mm256_div_pd(
            _mm256_mul_pd(lr, mhat),
            _mm256_add_pd(_mm256_sqrt_pd(vhat), eps),
        );
        _mm_storeu_ps(p.as_mut_ptr().add(i), _mm_sub_ps(pv, _mm256_cvtpd_ps(upd)));
        i += 4;
    }
    while i < n {
        let grad = g[i] + c.weight_decay * p[i];
        m[i] = c.beta1 * m[i] + c.one_minus_beta1 * grad;
        v[i] = c.beta2 * v[i] + c.one_minus_beta2 * grad * grad;
        let mhat = m[i] as f64 / c.bias1;
        let vhat = v[i] as f64 / c.bias2;
        p[i] -= (c.lr * mhat / (vhat.sqrt() + c.eps)) as f32;
        i += 1;
    }
}

pub fn sq_norm_wide(x: &[f32]) -> f64 {
    unsafe { sq_norm_wide_impl(x) }
}

#[target_feature(enable = "avx2")]
unsafe fn sq_norm_wide_impl(x: &[f32]) -> f64 {
    let n = x.len();
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut i = 0;
    while i + 8 <= n {
        let x8 = _mm256_loadu_ps(x.as_ptr().add(i));
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(x8));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(x8));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(lo, lo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(hi, hi));
        i += 8;
    }
    // fixed reduction tree, mirrored by scalar::sq_norm_wide:
    // ((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))
    let s = _mm256_add_pd(acc_lo, acc_hi);
    let pair = _mm_add_pd(_mm256_castpd256_pd128(s), _mm256_extractf128_pd::<1>(s));
    let mut total = _mm_cvtsd_f64(pair) + _mm_cvtsd_f64(_mm_unpackhi_pd(pair, pair));
    while i < n {
        let v = x[i] as f64;
        total += v * v;
        i += 1;
    }
    total
}

// ----------------------------------------------------- batched gaussians

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn rotl64<const K: i32, const INV: i32>(x: __m256i) -> __m256i {
    _mm256_or_si256(_mm256_slli_epi64::<K>(x), _mm256_srli_epi64::<INV>(x))
}

/// Four xoshiro256++ steps in lockstep — lane `j` advances exactly like
/// the scalar `Xoshiro::next_u64` on lane `j`'s state.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn next4(s: &mut [__m256i; 4]) -> __m256i {
    let r = _mm256_add_epi64(rotl64::<23, 41>(_mm256_add_epi64(s[0], s[3])), s[0]);
    let t = _mm256_slli_epi64::<17>(s[1]);
    s[2] = _mm256_xor_si256(s[2], s[0]);
    s[3] = _mm256_xor_si256(s[3], s[1]);
    s[1] = _mm256_xor_si256(s[1], s[2]);
    s[0] = _mm256_xor_si256(s[0], s[3]);
    s[2] = _mm256_xor_si256(s[2], t);
    s[3] = rotl64::<45, 19>(s[3]);
    r
}

/// `(x >> 11) as f64 * 2^-53` for four u64 lanes, bit-exact to the
/// scalar conversion: split the 53-bit value into its top 52 bits plus
/// its lsb (both exactly representable via the 2^52 magic-number trick),
/// recombine exactly, then scale by the exact power of two.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn to_unit(x: __m256i) -> __m256d {
    let magic_i = _mm256_set1_epi64x(0x4330_0000_0000_0000);
    let magic_d = _mm256_set1_pd(4503599627370496.0); // 2^52
    let hi = _mm256_srli_epi64::<12>(x);
    let lsb = _mm256_and_si256(_mm256_srli_epi64::<11>(x), _mm256_set1_epi64x(1));
    let dhi = _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(hi, magic_i)), magic_d);
    let dlsb = _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(lsb, magic_i)), magic_d);
    _mm256_mul_pd(_mm256_add_pd(_mm256_add_pd(dhi, dhi), dlsb), _mm256_set1_pd(TWO_NEG53))
}

/// [`super::poly_ln`] on four lanes — identical operation order, so each
/// lane's result is bitwise equal to the scalar function on that input.
/// Inputs must be positive normal f64 (the polar method's `s` is).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn poly_ln_pd(x: __m256d) -> __m256d {
    let bits = _mm256_castpd_si256(x);
    // positive input -> sign bit 0, so the shift alone isolates the
    // biased exponent; it fits an i32 lane for the exact i32->f64 convert
    let biased = _mm256_srli_epi64::<52>(bits);
    let idx = _mm256_setr_epi32(0, 2, 4, 6, 0, 0, 0, 0);
    let packed = _mm256_permutevar8x32_epi32(biased, idx);
    let e = _mm256_sub_pd(
        _mm256_cvtepi32_pd(_mm256_castsi256_si128(packed)),
        _mm256_set1_pd(1023.0),
    );
    let m = _mm256_castsi256_pd(_mm256_or_si256(
        _mm256_and_si256(bits, _mm256_set1_epi64x(0x000f_ffff_ffff_ffff)),
        _mm256_set1_epi64x(0x3ff0_0000_0000_0000),
    ));
    let one = _mm256_set1_pd(1.0);
    let t = _mm256_div_pd(_mm256_sub_pd(m, one), _mm256_add_pd(m, one));
    let t2 = _mm256_mul_pd(t, t);
    let mut p = _mm256_set1_pd(C19);
    for c in [C17, C15, C13, C11, C9, C7, C5, C3] {
        p = _mm256_add_pd(_mm256_mul_pd(p, t2), _mm256_set1_pd(c));
    }
    p = _mm256_add_pd(_mm256_mul_pd(p, t2), one);
    _mm256_add_pd(
        _mm256_mul_pd(e, _mm256_set1_pd(std::f64::consts::LN_2)),
        _mm256_mul_pd(_mm256_add_pd(t, t), p),
    )
}

pub fn gauss_block(lanes: &mut [Xoshiro; 4], out: &mut Vec<f64>) {
    unsafe { gauss_block_impl(lanes, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn gauss_block_impl(lanes: &mut [Xoshiro; 4], out: &mut Vec<f64>) {
    const N: usize = GAUSS_ROUNDS * 4;
    // transpose the four lane states into word-major vectors:
    // s[i] lane j = lanes[j].state()[i]
    let st = [lanes[0].state(), lanes[1].state(), lanes[2].state(), lanes[3].state()];
    let mut s = [_mm256_setzero_si256(); 4];
    for (i, sv) in s.iter_mut().enumerate() {
        *sv = _mm256_set_epi64x(
            st[3][i] as i64,
            st[2][i] as i64,
            st[1][i] as i64,
            st[0][i] as i64,
        );
    }
    let one = _mm256_set1_pd(1.0);
    let mut us = [0f64; N];
    let mut vs = [0f64; N];
    let mut ss = [0f64; N];
    for r in 0..GAUSS_ROUNDS {
        let ra = next4(&mut s);
        let rb = next4(&mut s);
        let ua = to_unit(ra);
        let ub = to_unit(rb);
        let u = _mm256_sub_pd(_mm256_add_pd(ua, ua), one);
        let v = _mm256_sub_pd(_mm256_add_pd(ub, ub), one);
        let sq = _mm256_add_pd(_mm256_mul_pd(u, u), _mm256_mul_pd(v, v));
        _mm256_storeu_pd(us.as_mut_ptr().add(4 * r), u);
        _mm256_storeu_pd(vs.as_mut_ptr().add(4 * r), v);
        _mm256_storeu_pd(ss.as_mut_ptr().add(4 * r), sq);
    }
    // write the advanced lane states back
    let mut back = [[0u64; 4]; 4];
    for (i, sv) in s.iter().enumerate() {
        _mm256_storeu_si256(back[i].as_mut_ptr() as *mut __m256i, *sv);
    }
    for (j, lane) in lanes.iter_mut().enumerate() {
        *lane = Xoshiro::from_state([back[0][j], back[1][j], back[2][j], back[3][j]]);
    }
    // acceptance compaction in candidate order (round-major, lane-minor)
    let mut ua = [0f64; N];
    let mut va = [0f64; N];
    let mut sa = [0f64; N];
    let mut cnt = 0;
    for i in 0..N {
        let sv = ss[i];
        if sv < 1.0 && sv != 0.0 {
            ua[cnt] = us[i];
            va[cnt] = vs[i];
            sa[cnt] = sv;
            cnt += 1;
        }
    }
    // vectorized transform over the accepted candidates; the tail uses
    // the scalar poly_ln, which is lane-identical to poly_ln_pd
    let neg2 = _mm256_set1_pd(-2.0);
    let mut i = 0;
    while i + 4 <= cnt {
        let sv = _mm256_loadu_pd(sa.as_ptr().add(i));
        let rr = _mm256_sqrt_pd(_mm256_div_pd(_mm256_mul_pd(neg2, poly_ln_pd(sv)), sv));
        let mut rbuf = [0f64; 4];
        _mm256_storeu_pd(rbuf.as_mut_ptr(), rr);
        for (j, r) in rbuf.iter().enumerate() {
            out.push(ua[i + j] * r);
            out.push(va[i + j] * r);
        }
        i += 4;
    }
    while i < cnt {
        let r = ((-2.0 * poly_ln(sa[i])) / sa[i]).sqrt();
        out.push(ua[i] * r);
        out.push(va[i] * r);
        i += 1;
    }
}
