//! Minimal HTTP/1.1 on `std::net` — just enough for the serve daemon's
//! local JSON API (no new dependencies, mirroring `util::json`). Every
//! response is `Connection: close`, so clients read to EOF; the ndjson
//! event stream omits `Content-Length` for the same reason.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Largest request body the daemon accepts (specs are a few KB; this
/// bound keeps a bad client from ballooning the daemon).
const MAX_BODY: usize = 16 << 20;

pub struct Request {
    pub method: String,
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub body: String,
}

impl Request {
    /// Non-empty path segments: `/sessions/a/events` -> `["sessions",
    /// "a", "events"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// One accepted connection: buffered request reading + response writing.
pub struct Conn {
    reader: BufReader<TcpStream>,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Result<Conn> {
        // a stalled client must not pin a handler thread forever
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        Ok(Conn { reader: BufReader::new(stream) })
    }

    pub fn read_request(&mut self) -> Result<Request> {
        let mut line = String::new();
        self.reader.read_line(&mut line).context("reading request line")?;
        let mut parts = line.split_whitespace();
        let method = parts.next().context("empty request line")?.to_string();
        let target = parts.next().context("request line has no target")?.to_string();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).context("reading header")?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().context("bad content-length")?;
                }
            }
        }
        if content_length > MAX_BODY {
            bail!("request body of {content_length} bytes exceeds the {MAX_BODY} byte limit");
        }
        let mut body = vec![0u8; content_length];
        self.reader.read_exact(&mut body).context("reading request body")?;
        let body = String::from_utf8(body).context("request body is not utf-8")?;
        let (path, query_str) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q),
            None => (target.clone(), ""),
        };
        let mut query = BTreeMap::new();
        for pair in query_str.split('&').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.insert(k.to_string(), v.to_string());
        }
        Ok(Request { method, path, query, body })
    }

    fn write_head(&mut self, status: u16, content_type: &str, length: Option<usize>) -> Result<()> {
        let mut head = format!(
            "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nConnection: close\r\n",
            status_text(status)
        );
        if let Some(n) = length {
            head.push_str(&format!("Content-Length: {n}\r\n"));
        }
        head.push_str("\r\n");
        self.reader.get_mut().write_all(head.as_bytes())?;
        Ok(())
    }

    /// One complete JSON response; the connection is done after this.
    pub fn respond_json(&mut self, status: u16, body: &Json) -> Result<()> {
        let text = body.render();
        self.write_head(status, "application/json", Some(text.len()))?;
        self.reader.get_mut().write_all(text.as_bytes())?;
        self.reader.get_mut().flush()?;
        Ok(())
    }

    /// One complete plain-text response (the Prometheus exposition on
    /// `GET /metrics` uses `text/plain; version=0.0.4`).
    pub fn respond_text(&mut self, status: u16, content_type: &str, body: &str) -> Result<()> {
        self.write_head(status, content_type, Some(body.len()))?;
        self.reader.get_mut().write_all(body.as_bytes())?;
        self.reader.get_mut().flush()?;
        Ok(())
    }

    /// An error response with the message under `"error"`.
    pub fn respond_error(&mut self, status: u16, msg: &str) -> Result<()> {
        let mut m = BTreeMap::new();
        m.insert("error".to_string(), Json::Str(msg.to_string()));
        self.respond_json(status, &Json::Obj(m))
    }

    /// Begin a newline-delimited JSON stream (no Content-Length; the
    /// close delimits it). Follow with [`Conn::write_line`] calls.
    pub fn start_ndjson(&mut self) -> Result<()> {
        self.write_head(200, "application/x-ndjson", None)
    }

    /// One ndjson line, flushed immediately so a tailing client sees
    /// each event as it happens.
    pub fn write_line(&mut self, line: &str) -> Result<()> {
        let s = self.reader.get_mut();
        s.write_all(line.as_bytes())?;
        s.write_all(b"\n")?;
        s.flush()?;
        Ok(())
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        500 => "Internal Server Error",
        _ => "Status",
    }
}
