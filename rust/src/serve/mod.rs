//! `gwclip serve` — a long-running daemon owning many concurrent named
//! training sessions, with crash-safe checkpoint/resume.
//!
//! The daemon is the production wrapper the ROADMAP names: specs are
//! submitted as TOML/JSON over a local HTTP/1.1 JSON API (hand-rolled
//! on `std::net::TcpListener`, zero new dependencies), each session
//! trains on its own OS thread, per-session [`StepEvent`] streams are
//! queryable as ndjson, snapshots are published on a per-session
//! cadence, and on restart every resident session resumes from its
//! latest snapshot — bitwise, by the `session::snapshot` contract.
//!
//! **Threading model.** The PJRT client behind `Runtime` is neither
//! `Send` nor `Sync`, so a session cannot migrate between threads: each
//! runner thread constructs its own `Runtime` and owns its session end
//! to end; only spec text and JSON cross thread boundaries. *Within* a
//! session, the step loop's scoped-thread collect fan-out (the PR 7
//! machinery, `threads` knob) still applies — the daemon is a pool of
//! session threads, each of which may itself fan collect across
//! threads. Stepping is sequential per session (no prefetch lookahead):
//! snapshots are only sound at a true step boundary, and sequential
//! stepping is bitwise identical to the prefetch loop by contract.
//!
//! **Thread-count precedence** (`session::spec::resolve_threads`): a
//! submit's `threads` field overrides the spec's, and the daemon
//! process's `GWCLIP_THREADS` overrides both — resolved per session at
//! submit time, not frozen at daemon start.
//!
//! **API** (all JSON; `Connection: close`):
//!
//! | method & path                  | effect                                      |
//! |--------------------------------|---------------------------------------------|
//! | GET  /healthz                  | liveness + session count                    |
//! | GET  /sessions                 | list every resident session's status        |
//! | POST /sessions                 | submit `{name, spec, threads?, snapshot_every?}` |
//! | GET  /metrics                  | Prometheus text exposition, labeled per session |
//! | GET  /sessions/N               | one session's status (+ digest when done)   |
//! | GET  /sessions/N/events        | ndjson event stream (`?from=K&wait=0`)      |
//! | GET  /sessions/N/phases        | cumulative per-phase time breakdown (JSON)  |
//! | POST /sessions/N/snapshot      | snapshot after the current step             |
//! | POST /sessions/N/stop          | stop at the next step boundary (+ snapshot) |
//! | DELETE /sessions/N             | stop, drop from the registry, remove state  |
//! | POST /shutdown                 | stop every session, exit the accept loop    |
//!
//! On-disk layout under `--state-dir`: one directory per session
//! holding `serve.json` (the submitted spec + options, written
//! atomically) and `step-*.json` snapshots. The bound address is
//! published to `<state-dir>/addr` so `--addr 127.0.0.1:0` (ephemeral
//! port, used by the CI smoke script) is discoverable.

pub mod http;

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::{PhaseSecs, Registry as Metrics};
use crate::runtime::Runtime;
use crate::session::snapshot;
use crate::session::spec::resolve_threads;
use crate::session::{RunSpec, SessionBuilder};
use crate::util::fsio;
use crate::util::json::Json;

use http::{Conn, Request};

// ------------------------------------------------------------------ state

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// runner is building the session (artifacts, data, accountant)
    Pending,
    Running,
    /// finished all planned steps
    Done,
    /// stopped at a step boundary by request; resumable
    Stopped,
    Failed,
}

impl Phase {
    fn token(self) -> &'static str {
        match self {
            Phase::Pending => "pending",
            Phase::Running => "running",
            Phase::Done => "done",
            Phase::Stopped => "stopped",
            Phase::Failed => "failed",
        }
    }

    fn terminal(self) -> bool {
        matches!(self, Phase::Done | Phase::Stopped | Phase::Failed)
    }
}

struct Status {
    phase: Phase,
    /// error message when Failed
    detail: String,
    step: u64,
    total: u64,
    threads: usize,
    backend: String,
    eps_spent: Option<f64>,
    snapshot_step: Option<u64>,
    /// cumulative wall seconds per DP phase across all steps run by
    /// THIS process (resets on daemon restart, like the event list)
    phase_secs: PhaseSecs,
    /// cumulative collect wall/busy seconds (their ratio is the
    /// measured thread-fan-out overlap `/phases` reports)
    collect_wall: f64,
    collect_busy: f64,
    /// bitwise state certificate, set when the run reaches a terminal
    /// phase (see `Session::digest`)
    digest: Option<Json>,
}

/// One resident session: immutable submit data + shared mutable status,
/// events and control flags. The runner thread is the only writer of
/// status/events; API handler threads read them and flip the flags.
struct SessionEntry {
    name: String,
    spec_text: String,
    threads: Option<usize>,
    snapshot_every: u64,
    status: Mutex<Status>,
    events: Mutex<Vec<Json>>,
    /// paired with `events`; also rung on status transitions so event
    /// tails and status waiters wake promptly (they re-check with
    /// timeouts, so a missed ring only costs latency)
    bell: Condvar,
    stop: AtomicBool,
    snap_req: AtomicBool,
    runner: Mutex<Option<JoinHandle<()>>>,
}

impl SessionEntry {
    fn new(name: String, spec_text: String, threads: Option<usize>, snapshot_every: u64) -> Self {
        SessionEntry {
            name,
            spec_text,
            threads,
            snapshot_every,
            status: Mutex::new(Status {
                phase: Phase::Pending,
                detail: String::new(),
                step: 0,
                total: 0,
                threads: 0,
                backend: String::new(),
                eps_spent: None,
                snapshot_step: None,
                phase_secs: PhaseSecs::default(),
                collect_wall: 0.0,
                collect_busy: 0.0,
                digest: None,
            }),
            events: Mutex::new(Vec::new()),
            bell: Condvar::new(),
            stop: AtomicBool::new(false),
            snap_req: AtomicBool::new(false),
            runner: Mutex::new(None),
        }
    }

    fn ring(&self) {
        self.bell.notify_all();
    }

    fn status_json(&self) -> Json {
        // lock order is events -> status everywhere (stream_events holds
        // events while peeking at the phase)
        let n_events = self.events.lock().unwrap().len();
        let st = self.status.lock().unwrap();
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("phase".to_string(), Json::Str(st.phase.token().to_string()));
        m.insert("step".to_string(), Json::Num(st.step as f64));
        m.insert("total_steps".to_string(), Json::Num(st.total as f64));
        m.insert("threads".to_string(), Json::Num(st.threads as f64));
        m.insert("backend".to_string(), Json::Str(st.backend.clone()));
        m.insert("events".to_string(), Json::Num(n_events as f64));
        m.insert(
            "eps_spent".to_string(),
            match st.eps_spent {
                Some(e) => Json::Num(e),
                None => Json::Null,
            },
        );
        m.insert(
            "snapshot_step".to_string(),
            match st.snapshot_step {
                Some(s) => Json::Num(s as f64),
                None => Json::Null,
            },
        );
        if !st.detail.is_empty() {
            m.insert("detail".to_string(), Json::Str(st.detail.clone()));
        }
        if let Some(d) = &st.digest {
            m.insert("digest".to_string(), d.clone());
        }
        Json::Obj(m)
    }

    /// Per-phase time breakdown for `GET /sessions/N/phases`: cumulative
    /// wall seconds per DP phase (this process's steps only) plus the
    /// collect busy/wall overlap ratio. `collect_busy_ratio > 1` means
    /// the per-unit thread fan-out genuinely overlapped work.
    fn phases_json(&self) -> Json {
        let st = self.status.lock().unwrap();
        let mut phases = BTreeMap::new();
        for (name, secs) in st.phase_secs.iter() {
            phases.insert(name.to_string(), Json::Num(secs));
        }
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("steps".to_string(), Json::Num(st.step as f64));
        m.insert("phase_secs".to_string(), Json::Obj(phases));
        m.insert("total_secs".to_string(), Json::Num(st.phase_secs.total()));
        m.insert("collect_wall_secs".to_string(), Json::Num(st.collect_wall));
        m.insert("collect_busy_secs".to_string(), Json::Num(st.collect_busy));
        m.insert(
            "collect_busy_ratio".to_string(),
            if st.collect_wall > 0.0 {
                Json::Num(st.collect_busy / st.collect_wall)
            } else {
                Json::Null
            },
        );
        Json::Obj(m)
    }
}

type Registry = Arc<Mutex<BTreeMap<String, Arc<SessionEntry>>>>;

// ----------------------------------------------------------------- daemon

pub struct ServeOpts {
    /// bind address, e.g. `127.0.0.1:7777` or `127.0.0.1:0` (ephemeral)
    pub addr: String,
    /// AOT artifact directory each runner's `Runtime` loads from
    pub artifacts: PathBuf,
    /// root of per-session state (sidecars + snapshots)
    pub state_dir: PathBuf,
    /// default snapshot cadence for submits that don't set one (0 = only
    /// on stop/completion)
    pub snapshot_every: u64,
}

pub struct Daemon {
    opts: Arc<ServeOpts>,
    listener: TcpListener,
    registry: Registry,
    /// process-wide metric registry: every session runner records into
    /// it (labeled `session="name"`), `GET /metrics` renders it
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
}

impl Daemon {
    /// Bind the listener, publish the bound address to
    /// `<state-dir>/addr`, and re-register every resident session found
    /// under the state dir (each resumes from its latest snapshot on
    /// its own runner thread).
    pub fn bind(opts: ServeOpts) -> Result<Daemon> {
        std::fs::create_dir_all(&opts.state_dir).with_context(|| {
            format!("creating state dir {}", opts.state_dir.display())
        })?;
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let local = listener.local_addr()?;
        fsio::write_atomic(&opts.state_dir.join("addr"), local.to_string().as_bytes())?;
        let daemon = Daemon {
            opts: Arc::new(opts),
            listener,
            registry: Arc::new(Mutex::new(BTreeMap::new())),
            metrics: Arc::new(Metrics::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
        };
        daemon.resume_residents();
        Ok(daemon)
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("listener is bound")
    }

    /// Scan the state dir for `serve.json` sidecars and restart each
    /// session found — resuming from its latest snapshot if one exists,
    /// from step 0 otherwise. A broken sidecar skips that session with
    /// a warning; it never takes the daemon down.
    fn resume_residents(&self) {
        let entries = match std::fs::read_dir(&self.opts.state_dir) {
            Ok(e) => e,
            Err(_) => return,
        };
        for entry in entries.flatten() {
            let sidecar = entry.path().join("serve.json");
            if !sidecar.is_file() {
                continue;
            }
            let resume = (|| -> Result<()> {
                let text = std::fs::read_to_string(&sidecar)?;
                let j = Json::parse(&text)?;
                let name = j.get("name")?.str()?.to_string();
                let spec_text = j.get("spec")?.str()?.to_string();
                let threads = match j.opt("threads") {
                    Some(v) => Some(v.usize()?),
                    None => None,
                };
                let every = j.get("snapshot_every")?.u64()?;
                let entry = Arc::new(SessionEntry::new(name.clone(), spec_text, threads, every));
                self.registry.lock().unwrap().insert(name, Arc::clone(&entry));
                spawn_runner(entry, Arc::clone(&self.opts), Arc::clone(&self.metrics));
                Ok(())
            })();
            if let Err(e) = resume {
                eprintln!("[serve] skipping resident {}: {e:#}", sidecar.display());
            }
        }
    }

    /// Accept loop; returns after `POST /shutdown`, with every runner
    /// stopped at a step boundary (snapshotted) and joined.
    pub fn run(&self) -> Result<()> {
        eprintln!(
            "[serve] listening on http://{} (state {})",
            self.local_addr(),
            self.opts.state_dir.display()
        );
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let registry = Arc::clone(&self.registry);
            let opts = Arc::clone(&self.opts);
            let metrics = Arc::clone(&self.metrics);
            let shutdown = Arc::clone(&self.shutdown);
            let addr = self.local_addr();
            std::thread::spawn(move || {
                let mut conn = match Conn::new(stream) {
                    Ok(c) => c,
                    Err(_) => return,
                };
                let req = match conn.read_request() {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = conn.respond_error(400, &format!("{e:#}"));
                        return;
                    }
                };
                if let Err(e) = handle(&mut conn, &req, &registry, &opts, &metrics, &shutdown) {
                    let _ = conn.respond_error(500, &format!("{e:#}"));
                }
                // unblock the accept loop so it observes the flag
                if shutdown.load(Ordering::SeqCst) {
                    let _ = TcpStream::connect(addr);
                }
            });
        }
        // stop and join every runner so their final snapshots land
        let entries: Vec<Arc<SessionEntry>> =
            self.registry.lock().unwrap().values().cloned().collect();
        for e in &entries {
            e.stop.store(true, Ordering::SeqCst);
            e.ring();
        }
        for e in &entries {
            if let Some(h) = e.runner.lock().unwrap().take() {
                let _ = h.join();
            }
        }
        eprintln!("[serve] shut down");
        Ok(())
    }
}

// ----------------------------------------------------------------- runner

fn spawn_runner(entry: Arc<SessionEntry>, opts: Arc<ServeOpts>, metrics: Arc<Metrics>) {
    let for_thread = Arc::clone(&entry);
    let handle = std::thread::Builder::new()
        .name(format!("gwclip-serve-{}", entry.name))
        .spawn(move || {
            if let Err(e) = run_session(&for_thread, &opts, &metrics) {
                let mut st = for_thread.status.lock().unwrap();
                st.phase = Phase::Failed;
                st.detail = format!("{e:#}");
                drop(st);
                for_thread.ring();
            }
        })
        .expect("spawning a session runner thread");
    *entry.runner.lock().unwrap() = Some(handle);
}

/// The whole life of one session, on its own thread: build (or resume
/// from the latest snapshot), step to completion or stop, snapshot on
/// cadence/demand, publish events and the final digest.
fn run_session(entry: &SessionEntry, opts: &ServeOpts, metrics: &Metrics) -> Result<()> {
    // the PJRT runtime is thread-local by construction (!Send): built
    // here, owned here, dropped here
    let rt = Runtime::new(&opts.artifacts).with_context(|| {
        format!(
            "loading artifacts from {} (run `make artifacts` first)",
            opts.artifacts.display()
        )
    })?;
    let sdir = opts.state_dir.join(&entry.name);
    std::fs::create_dir_all(&sdir)?;
    let latest = snapshot::latest_in_dir(&sdir)?;
    let (mut sess, train, _eval) = match &latest {
        Some(path) => {
            let snap = snapshot::read_file(path)?;
            let mut spec = snapshot::spec_of(&snap)?;
            if let Some(t) = entry.threads {
                spec.threads = t;
            }
            let (mut sess, train, eval) = SessionBuilder::from_spec(&rt, spec).build_with_data()?;
            snapshot::restore(&mut sess, &snap)
                .with_context(|| format!("resuming from {}", path.display()))?;
            (sess, train, eval)
        }
        None => {
            let mut spec = RunSpec::parse(&entry.spec_text)?;
            if let Some(t) = entry.threads {
                spec.threads = t;
            }
            SessionBuilder::from_spec(&rt, spec).build_with_data()?
        }
    };
    {
        let mut st = entry.status.lock().unwrap();
        st.phase = Phase::Running;
        st.step = sess.steploop.steps_done;
        st.total = sess.total_steps;
        st.threads = sess.steploop.threads;
        st.backend = sess.backend.name().to_string();
        st.eps_spent = sess.epsilon_spent();
        st.snapshot_step = latest.as_ref().map(|_| sess.steploop.steps_done);
    }
    entry.ring();

    let every = entry.snapshot_every;
    let labels = session_labels(&entry.name);
    let groups = sess.group_labels();
    while sess.steploop.steps_done < sess.total_steps {
        if entry.stop.load(Ordering::SeqCst) {
            break;
        }
        let ev = sess.step(&*train)?;
        let s = ev.step;
        record_step_metrics(metrics, &entry.name, &ev, sess.thresholds(), &groups);
        {
            let mut st = entry.status.lock().unwrap();
            st.step = s;
            st.eps_spent = sess.epsilon_spent();
            st.phase_secs.add(&ev.phase);
            st.collect_wall += ev.collect_wall_secs;
            st.collect_busy += ev.collect_busy_secs;
        }
        entry.events.lock().unwrap().push(ev.to_json());
        if entry.snap_req.swap(false, Ordering::SeqCst)
            || (every > 0 && s % every == 0)
            || s == sess.total_steps
        {
            let t0 = Instant::now();
            snapshot::write(&sess, &sdir.join(snapshot::file_name(s)))?;
            metrics.observe(
                "gwclip_snapshot_write_seconds",
                "Snapshot serialize+atomic-write latency.",
                &labels,
                t0.elapsed().as_secs_f64(),
            );
            entry.status.lock().unwrap().snapshot_step = Some(s);
        }
        entry.ring();
    }

    let finished = sess.steploop.steps_done >= sess.total_steps;
    if !finished {
        // stopped by request: publish a parting snapshot at this exact
        // boundary so the next start resumes bitwise from here
        let s = sess.steploop.steps_done;
        let t0 = Instant::now();
        snapshot::write(&sess, &sdir.join(snapshot::file_name(s)))?;
        metrics.observe(
            "gwclip_snapshot_write_seconds",
            "Snapshot serialize+atomic-write latency.",
            &labels,
            t0.elapsed().as_secs_f64(),
        );
        entry.status.lock().unwrap().snapshot_step = Some(s);
    }
    {
        let mut st = entry.status.lock().unwrap();
        st.phase = if finished { Phase::Done } else { Phase::Stopped };
        st.eps_spent = sess.epsilon_spent();
        st.digest = Some(sess.digest());
    }
    entry.ring();
    Ok(())
}

/// Rendered label set keying every per-session series (`valid_name`
/// admits only `[a-zA-Z0-9_-]`, so no escaping is ever needed).
fn session_labels(name: &str) -> String {
    format!("session=\"{name}\"")
}

/// Publish one step's already-released values into the daemon metric
/// registry. Strictly post-processing: every input was computed by the
/// step itself — no new accountant queries, no RNG, no feedback into
/// training (the `obs` zero-RNG contract).
fn record_step_metrics(
    m: &Metrics,
    name: &str,
    ev: &crate::session::StepEvent,
    thresholds: &[f64],
    groups: &[String],
) {
    let l = session_labels(name);
    m.counter_add("gwclip_steps_total", "DP training steps completed.", &l, 1.0);
    m.counter_add(
        "gwclip_examples_total",
        "Live examples processed across all steps.",
        &l,
        ev.batch_size as f64,
    );
    m.counter_add(
        "gwclip_truncated_draws_total",
        "Sampled examples dropped by the static batch capacity.",
        &l,
        ev.truncated as f64,
    );
    if let Some(e) = ev.eps_spent {
        m.gauge_set("gwclip_eps_spent", "Privacy budget spent so far (epsilon).", &l, e);
    }
    for (i, &t) in thresholds.iter().enumerate() {
        let g = groups.get(i).map(String::as_str).unwrap_or("?");
        m.gauge_set(
            "gwclip_group_threshold",
            "Current per-group clipping threshold.",
            &format!("session=\"{name}\",group=\"{g}\""),
            t,
        );
    }
    for (i, &f) in ev.clip_frac.iter().enumerate() {
        let g = groups.get(i).map(String::as_str).unwrap_or("?");
        m.gauge_set(
            "gwclip_clip_fraction",
            "Fraction of examples clipped last step, per group.",
            &format!("session=\"{name}\",group=\"{g}\""),
            f,
        );
    }
    for (ph, secs) in ev.phase.iter() {
        m.counter_add(
            "gwclip_phase_seconds_total",
            "Cumulative wall seconds per DP phase.",
            &format!("session=\"{name}\",phase=\"{ph}\""),
            secs,
        );
    }
    m.counter_add(
        "gwclip_collect_wall_seconds_total",
        "Cumulative collect-phase wall seconds.",
        &l,
        ev.collect_wall_secs,
    );
    m.counter_add(
        "gwclip_collect_busy_seconds_total",
        "Cumulative summed per-unit collect busy seconds.",
        &l,
        ev.collect_busy_secs,
    );
    m.observe("gwclip_step_seconds", "Host wall seconds per training step.", &l, ev.host_secs);
}

// --------------------------------------------------------------- handlers

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 64
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
}

fn get_entry(registry: &Registry, name: &str) -> Option<Arc<SessionEntry>> {
    registry.lock().unwrap().get(name).cloned()
}

fn handle(
    conn: &mut Conn,
    req: &Request,
    registry: &Registry,
    opts: &Arc<ServeOpts>,
    metrics: &Arc<Metrics>,
    shutdown: &Arc<AtomicBool>,
) -> Result<()> {
    let segs = req.segments();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["metrics"]) => {
            // refreshed at scrape time so the family exists (and is
            // correct) even before any session has run a step
            metrics.gauge_set(
                "gwclip_sessions",
                "Sessions resident in the daemon registry.",
                "",
                registry.lock().unwrap().len() as f64,
            );
            conn.respond_text(200, "text/plain; version=0.0.4", &metrics.render())
        }
        ("GET", ["healthz"]) => {
            let mut m = BTreeMap::new();
            m.insert("ok".to_string(), Json::Bool(true));
            m.insert("sessions".to_string(), Json::Num(registry.lock().unwrap().len() as f64));
            conn.respond_json(200, &Json::Obj(m))
        }
        ("GET", ["sessions"]) => {
            let entries: Vec<Arc<SessionEntry>> =
                registry.lock().unwrap().values().cloned().collect();
            let list: Vec<Json> = entries.iter().map(|e| e.status_json()).collect();
            conn.respond_json(200, &Json::Arr(list))
        }
        ("POST", ["sessions"]) => submit(conn, req, registry, opts, metrics),
        ("GET", [s, name]) if *s == "sessions" => match get_entry(registry, name) {
            Some(e) => conn.respond_json(200, &e.status_json()),
            None => conn.respond_error(404, &format!("no session named {name:?}")),
        },
        ("GET", [s, name, ev]) if *s == "sessions" && *ev == "events" => {
            match get_entry(registry, name) {
                Some(e) => stream_events(conn, req, &e),
                None => conn.respond_error(404, &format!("no session named {name:?}")),
            }
        }
        ("GET", [s, name, ph]) if *s == "sessions" && *ph == "phases" => {
            match get_entry(registry, name) {
                Some(e) => conn.respond_json(200, &e.phases_json()),
                None => conn.respond_error(404, &format!("no session named {name:?}")),
            }
        }
        ("POST", [s, name, act]) if *s == "sessions" && *act == "snapshot" => {
            match get_entry(registry, name) {
                Some(e) => {
                    e.snap_req.store(true, Ordering::SeqCst);
                    let mut m = BTreeMap::new();
                    m.insert("requested".to_string(), Json::Bool(true));
                    conn.respond_json(202, &Json::Obj(m))
                }
                None => conn.respond_error(404, &format!("no session named {name:?}")),
            }
        }
        ("POST", [s, name, act]) if *s == "sessions" && *act == "stop" => {
            match get_entry(registry, name) {
                Some(e) => {
                    e.stop.store(true, Ordering::SeqCst);
                    e.ring();
                    let mut m = BTreeMap::new();
                    m.insert("stopping".to_string(), Json::Bool(true));
                    conn.respond_json(202, &Json::Obj(m))
                }
                None => conn.respond_error(404, &format!("no session named {name:?}")),
            }
        }
        ("DELETE", [s, name]) if *s == "sessions" => delete_session(conn, registry, opts, name),
        ("POST", ["shutdown"]) => {
            shutdown.store(true, Ordering::SeqCst);
            for e in registry.lock().unwrap().values() {
                e.stop.store(true, Ordering::SeqCst);
                e.ring();
            }
            let mut m = BTreeMap::new();
            m.insert("ok".to_string(), Json::Bool(true));
            conn.respond_json(200, &Json::Obj(m))
        }
        (_, ["healthz" | "sessions" | "shutdown" | "metrics", ..]) => {
            conn.respond_error(405, &format!("{} not allowed on {}", req.method, req.path))
        }
        _ => conn.respond_error(404, &format!("no route for {} {}", req.method, req.path)),
    }
}

fn submit(
    conn: &mut Conn,
    req: &Request,
    registry: &Registry,
    opts: &Arc<ServeOpts>,
    metrics: &Arc<Metrics>,
) -> Result<()> {
    let body = match Json::parse(&req.body) {
        Ok(j) => j,
        Err(e) => return conn.respond_error(400, &format!("submit body is not JSON: {e:#}")),
    };
    let parsed = (|| -> Result<(String, String, Option<usize>, u64)> {
        let name = body.get("name")?.str()?.to_string();
        if !valid_name(&name) {
            bail!("session name must be 1-64 chars of [a-zA-Z0-9_-], got {name:?}");
        }
        // the spec rides as embedded TOML/JSON text, or as an inline
        // JSON object (rendered back to text for the sidecar)
        let spec_text = match body.get("spec")? {
            Json::Str(s) => s.clone(),
            obj @ Json::Obj(_) => obj.render(),
            _ => bail!("`spec` must be a spec string (TOML/JSON) or a JSON object"),
        };
        // parse + validate NOW so a bad spec fails the submit, not the
        // runner thread minutes later
        let spec = RunSpec::parse(&spec_text).context("invalid spec")?;
        let threads = match body.opt("threads") {
            Some(v) => Some(v.usize()?),
            None => None,
        };
        let every = match body.opt("snapshot_every") {
            Some(v) => v.u64()?,
            None => opts.snapshot_every,
        };
        // resolved per session at submit time: spec < submit < env
        let resolved = resolve_threads(
            spec.threads,
            threads,
            std::env::var("GWCLIP_THREADS").ok().as_deref(),
        );
        Ok((name, spec_text, threads.map(|_| resolved), every))
    })();
    let (name, spec_text, threads, every) = match parsed {
        Ok(v) => v,
        Err(e) => return conn.respond_error(400, &format!("{e:#}")),
    };

    let entry = Arc::new(SessionEntry::new(name.clone(), spec_text.clone(), threads, every));
    {
        let mut reg = registry.lock().unwrap();
        if reg.contains_key(&name) {
            drop(reg);
            return conn.respond_error(409, &format!("session {name:?} already exists"));
        }
        reg.insert(name.clone(), Arc::clone(&entry));
    }

    // persist the sidecar so a daemon restart re-registers this session
    let sdir = opts.state_dir.join(&name);
    std::fs::create_dir_all(&sdir)?;
    let mut sc = BTreeMap::new();
    sc.insert("name".to_string(), Json::Str(name.clone()));
    sc.insert("spec".to_string(), Json::Str(spec_text));
    sc.insert(
        "threads".to_string(),
        match threads {
            Some(t) => Json::Num(t as f64),
            None => Json::Null,
        },
    );
    sc.insert("snapshot_every".to_string(), Json::Num(every as f64));
    fsio::write_atomic(&sdir.join("serve.json"), Json::Obj(sc).render().as_bytes())?;

    spawn_runner(entry, Arc::clone(opts), Arc::clone(metrics));

    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name));
    m.insert("snapshot_every".to_string(), Json::Num(every as f64));
    conn.respond_json(201, &Json::Obj(m))
}

fn delete_session(
    conn: &mut Conn,
    registry: &Registry,
    opts: &Arc<ServeOpts>,
    name: &str,
) -> Result<()> {
    let entry = match get_entry(registry, name) {
        Some(e) => e,
        None => return conn.respond_error(404, &format!("no session named {name:?}")),
    };
    entry.stop.store(true, Ordering::SeqCst);
    entry.ring();
    // runners check the stop flag at step boundaries; a session still
    // building can't be interrupted, so bound the wait and let the
    // client retry
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if entry.status.lock().unwrap().phase.terminal() {
            break;
        }
        if Instant::now() >= deadline {
            return conn.respond_error(409, &format!("session {name:?} is still stopping; retry"));
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    if let Some(h) = entry.runner.lock().unwrap().take() {
        let _ = h.join();
    }
    registry.lock().unwrap().remove(name);
    // dropping the state dir makes the removal permanent: a daemon
    // restart will NOT resurrect this session
    let _ = std::fs::remove_dir_all(opts.state_dir.join(name));
    let mut m = BTreeMap::new();
    m.insert("deleted".to_string(), Json::Bool(true));
    conn.respond_json(200, &Json::Obj(m))
}

/// Stream a session's events as ndjson from `?from=K` (default 0).
/// With `?wait=0` the stream ends at the current tail; by default it
/// follows the session until a terminal phase, then emits one final
/// status line (phase + digest) and closes — the continuity marker the
/// smoke script asserts on.
fn stream_events(conn: &mut Conn, req: &Request, entry: &Arc<SessionEntry>) -> Result<()> {
    let from: usize = match req.query.get("from").map(|v| v.parse()) {
        None => 0,
        Some(Ok(v)) => v,
        Some(Err(_)) => return conn.respond_error(400, "bad ?from= value"),
    };
    let follow = req.query.get("wait").map(|v| v != "0").unwrap_or(true);
    conn.start_ndjson()?;
    let mut cursor = from;
    loop {
        let (lines, terminal) = {
            let evs = entry.events.lock().unwrap();
            let start = cursor.min(evs.len());
            let lines: Vec<String> = evs[start..].iter().map(|j| j.render()).collect();
            cursor = evs.len();
            (lines, entry.status.lock().unwrap().phase.terminal())
        };
        for line in &lines {
            if conn.write_line(line).is_err() {
                return Ok(()); // client went away
            }
        }
        if terminal || !follow {
            if terminal {
                let _ = conn.write_line(&entry.status_json().render());
            }
            return Ok(());
        }
        let evs = entry.events.lock().unwrap();
        if evs.len() > cursor {
            continue;
        }
        let (guard, _timed_out) = entry
            .bell
            .wait_timeout(evs, Duration::from_millis(200))
            .map_err(|_| anyhow!("events mutex poisoned"))?;
        drop(guard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "gwclip_serve_{tag}_{}_{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").replace("::", "_")
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Raw HTTP round trip; responses are Connection: close, so read to
    /// EOF and split status/body by hand.
    fn req(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        let msg = format!(
            "{method} {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        s.write_all(msg.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let status: u16 = buf
            .split_whitespace()
            .nth(1)
            .and_then(|c| c.parse().ok())
            .unwrap_or_else(|| panic!("bad response: {buf:?}"));
        let payload = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, payload)
    }

    fn start(tag: &str) -> (Arc<Daemon>, std::net::SocketAddr, PathBuf) {
        let state = tmpdir(tag);
        let daemon = Arc::new(
            Daemon::bind(ServeOpts {
                addr: "127.0.0.1:0".to_string(),
                // deliberately nonexistent: runner builds fail fast,
                // which is exactly what the artifact-free API tests need
                artifacts: PathBuf::from("/nonexistent-artifacts-for-tests"),
                state_dir: state.clone(),
                snapshot_every: 0,
            })
            .unwrap(),
        );
        let addr = daemon.local_addr();
        let d2 = Arc::clone(&daemon);
        std::thread::spawn(move || d2.run().unwrap());
        (daemon, addr, state)
    }

    fn shutdown(addr: std::net::SocketAddr) {
        let (code, _) = req(addr, "POST", "/shutdown", "");
        assert_eq!(code, 200);
    }

    const SPEC: &str = "config = \"resmlp_tiny\"\nepochs = 0.05\n";

    #[test]
    fn healthz_and_unknown_routes() {
        let (_d, addr, state) = start("health");
        let (code, body) = req(addr, "GET", "/healthz", "");
        assert_eq!(code, 200);
        assert!(body.contains("\"ok\":true"), "{body}");
        let (code, _) = req(addr, "GET", "/nope", "");
        assert_eq!(code, 404);
        let (code, _) = req(addr, "GET", "/sessions/ghost", "");
        assert_eq!(code, 404);
        let (code, _) = req(addr, "GET", "/sessions/ghost/events", "");
        assert_eq!(code, 404);
        let (code, _) = req(addr, "DELETE", "/healthz", "");
        assert_eq!(code, 405);
        shutdown(addr);
        std::fs::remove_dir_all(state).ok();
    }

    #[test]
    fn submit_validation_and_failed_build_surface() {
        let (_d, addr, state) = start("submit");
        // bad name
        let bad_name = "{\"name\":\"no/slash\",\"spec\":\"x\"}";
        let (code, body) = req(addr, "POST", "/sessions", bad_name);
        assert_eq!(code, 400, "{body}");
        // bad spec fails the submit, not the runner
        let (code, body) =
            req(addr, "POST", "/sessions", "{\"name\":\"bad\",\"spec\":\"config = 7\"}");
        assert_eq!(code, 400, "{body}");
        // not json at all
        let (code, _) = req(addr, "POST", "/sessions", "not json");
        assert_eq!(code, 400);
        // valid spec: accepted, then fails in the runner (no artifacts
        // in this environment) and surfaces the error in status
        let submit =
            format!("{{\"name\":\"s1\",\"spec\":{}}}", Json::Str(SPEC.to_string()).render());
        let (code, body) = req(addr, "POST", "/sessions", &submit);
        assert_eq!(code, 201, "{body}");
        // duplicate name
        let (code, _) = req(addr, "POST", "/sessions", &submit);
        assert_eq!(code, 409);
        // sidecar persisted for restart
        assert!(state.join("s1").join("serve.json").is_file());
        // runner fails fast; status shows failed + detail
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let (code, body) = req(addr, "GET", "/sessions/s1", "");
            assert_eq!(code, 200);
            if body.contains("\"phase\":\"failed\"") {
                assert!(body.contains("artifacts"), "{body}");
                break;
            }
            assert!(Instant::now() < deadline, "never failed: {body}");
            std::thread::sleep(Duration::from_millis(50));
        }
        // the event stream of a failed session terminates with a status line
        let (code, body) = req(addr, "GET", "/sessions/s1/events", "");
        assert_eq!(code, 200);
        assert!(body.contains("\"phase\":\"failed\""), "{body}");
        shutdown(addr);
        std::fs::remove_dir_all(state).ok();
    }

    #[test]
    fn restart_scan_reregisters_resident_sessions() {
        let (_d, addr, state) = start("restart");
        let submit = format!(
            "{{\"name\":\"resident\",\"spec\":{},\"threads\":3,\"snapshot_every\":5}}",
            Json::Str(SPEC.to_string()).render()
        );
        let (code, _) = req(addr, "POST", "/sessions", &submit);
        assert_eq!(code, 201);
        shutdown(addr);
        // wait for the listener to actually exit so rebinding the state
        // dir is the "restart"
        std::thread::sleep(Duration::from_millis(100));

        let daemon2 = Daemon::bind(ServeOpts {
            addr: "127.0.0.1:0".to_string(),
            artifacts: PathBuf::from("/nonexistent-artifacts-for-tests"),
            state_dir: state.clone(),
            snapshot_every: 0,
        })
        .unwrap();
        let addr2 = daemon2.local_addr();
        let d2 = Arc::new(daemon2);
        let d3 = Arc::clone(&d2);
        std::thread::spawn(move || d3.run().unwrap());
        let (code, body) = req(addr2, "GET", "/sessions/resident", "");
        assert_eq!(code, 200, "{body}");
        // broken sidecars are skipped, not fatal
        std::fs::create_dir_all(state.join("broken")).unwrap();
        std::fs::write(state.join("broken").join("serve.json"), b"{{{").unwrap();
        shutdown(addr2);
        std::thread::sleep(Duration::from_millis(100));
        let daemon3 = Daemon::bind(ServeOpts {
            addr: "127.0.0.1:0".to_string(),
            artifacts: PathBuf::from("/nonexistent-artifacts-for-tests"),
            state_dir: state.clone(),
            snapshot_every: 0,
        })
        .unwrap();
        assert!(daemon3.registry.lock().unwrap().contains_key("resident"));
        assert!(!daemon3.registry.lock().unwrap().contains_key("broken"));
        let addr3 = daemon3.local_addr();
        let d4 = Arc::new(daemon3);
        let d5 = Arc::clone(&d4);
        std::thread::spawn(move || d5.run().unwrap());
        shutdown(addr3);
        std::fs::remove_dir_all(state).ok();
    }

    #[test]
    fn delete_removes_session_and_state() {
        let (_d, addr, state) = start("delete");
        let submit =
            format!("{{\"name\":\"gone\",\"spec\":{}}}", Json::Str(SPEC.to_string()).render());
        let (code, _) = req(addr, "POST", "/sessions", &submit);
        assert_eq!(code, 201);
        // wait until terminal (failed: no artifacts) so DELETE is instant
        let deadline = Instant::now() + Duration::from_secs(30);
        while !req(addr, "GET", "/sessions/gone", "").1.contains("\"phase\":\"failed\"") {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(50));
        }
        let (code, body) = req(addr, "DELETE", "/sessions/gone", "");
        assert_eq!(code, 200, "{body}");
        let (code, _) = req(addr, "GET", "/sessions/gone", "");
        assert_eq!(code, 404);
        assert!(!state.join("gone").exists(), "state dir must be removed");
        shutdown(addr);
        std::fs::remove_dir_all(state).ok();
    }

    #[test]
    fn metrics_endpoint_exposes_prometheus_text() {
        let (_d, addr, state) = start("metrics");
        // the daemon-level gauge renders even with zero sessions, so an
        // artifact-free scrape is never empty
        let (code, body) = req(addr, "GET", "/metrics", "");
        assert_eq!(code, 200);
        assert!(body.contains("# HELP gwclip_sessions "), "{body}");
        assert!(body.contains("# TYPE gwclip_sessions gauge\n"), "{body}");
        assert!(body.contains("gwclip_sessions 0\n"), "{body}");
        // exactly one HELP line per family
        assert_eq!(body.matches("# HELP gwclip_sessions ").count(), 1);
        // wrong method is 405 (named prefix), not the 404 catch-all
        let (code, _) = req(addr, "POST", "/metrics", "");
        assert_eq!(code, 405);
        // the gauge tracks the registry at scrape time
        let submit =
            format!("{{\"name\":\"m1\",\"spec\":{}}}", Json::Str(SPEC.to_string()).render());
        let (code, _) = req(addr, "POST", "/sessions", &submit);
        assert_eq!(code, 201);
        let (_, body) = req(addr, "GET", "/metrics", "");
        assert!(body.contains("gwclip_sessions 1\n"), "{body}");
        shutdown(addr);
        std::fs::remove_dir_all(state).ok();
    }

    #[test]
    fn phases_endpoint_reports_full_taxonomy() {
        let (_d, addr, state) = start("phases");
        let (code, _) = req(addr, "GET", "/sessions/ghost/phases", "");
        assert_eq!(code, 404);
        let submit =
            format!("{{\"name\":\"p1\",\"spec\":{}}}", Json::Str(SPEC.to_string()).render());
        let (code, _) = req(addr, "POST", "/sessions", &submit);
        assert_eq!(code, 201);
        // even a session that never stepped (build fails: no artifacts)
        // answers with every phase of the taxonomy, zeroed
        let (code, body) = req(addr, "GET", "/sessions/p1/phases", "");
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("name").unwrap().str().unwrap(), "p1");
        let phases = j.get("phase_secs").unwrap();
        for ph in crate::obs::PhaseSecs::NAMES {
            assert!(phases.opt(ph).is_some(), "missing phase {ph}: {body}");
        }
        assert!(j.opt("collect_wall_secs").is_some(), "{body}");
        assert!(j.opt("collect_busy_ratio").is_some(), "{body}");
        shutdown(addr);
        std::fs::remove_dir_all(state).ok();
    }

    #[test]
    fn name_validation() {
        assert!(valid_name("a"));
        assert!(valid_name("train-1_b"));
        assert!(!valid_name(""));
        assert!(!valid_name("a/b"));
        assert!(!valid_name("a b"));
        assert!(!valid_name(&"x".repeat(65)));
    }
}
