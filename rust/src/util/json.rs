//! Minimal JSON parser/emitter (this environment has no serde_json; the
//! manifest and checkpoint headers are JSON for human inspection and
//! python interop, so we parse the subset python's json module emits).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors --------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.f64()? as usize)
    }

    pub fn u64(&self) -> Result<u64> {
        Ok(self.f64()? as u64)
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usizes(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    pub fn strings(&self) -> Result<Vec<String>> {
        self.arr()?.iter().map(|v| Ok(v.str()?.to_string())).collect()
    }

    // -- emit ---------------------------------------------------------------
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(s, "{}", *n as i64).unwrap();
                } else {
                    write!(s, "{n}").unwrap();
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            write!(s, "\\u{:04x}", c as u32).unwrap()
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(v) => {
                s.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    x.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    x.write(s);
                }
                s.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of json"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // collect the full utf8 char
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"version": 1, "configs": {"a": {"batch": 8,
            "groups": ["x", "y"], "flag": true, "f": 0.125,
            "nested": [{"k": "v\n"}, null]}}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().usize().unwrap(), 1);
        let a = j.get("configs").unwrap().get("a").unwrap();
        assert_eq!(a.get("batch").unwrap().usize().unwrap(), 8);
        assert_eq!(a.get("groups").unwrap().strings().unwrap(), vec!["x", "y"]);
        assert!(a.get("flag").unwrap().bool().unwrap());
        assert_eq!(a.get("f").unwrap().f64().unwrap(), 0.125);
        assert_eq!(
            a.get("nested").unwrap().arr().unwrap()[0].get("k").unwrap().str().unwrap(),
            "v\n"
        );
    }

    #[test]
    fn roundtrips_render_parse() {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str("tok \"emb\"".into()));
        m.insert("shape".into(), Json::Arr(vec![Json::Num(3.0), Json::Num(4.0)]));
        m.insert("neg".into(), Json::Num(-2.5));
        let j = Json::Obj(m);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn scientific_notation() {
        let j = Json::parse("[1e-05, 2.5E3]").unwrap();
        assert_eq!(j.arr().unwrap()[0].f64().unwrap(), 1e-5);
        assert_eq!(j.arr().unwrap()[1].f64().unwrap(), 2500.0);
    }
}
