//! Minimal TOML-subset parser for run-spec files (no `toml` crate in this
//! environment — same rationale as [`super::json`]). Parses the subset a
//! hand-written `run.toml` uses and lowers it into the in-tree [`Json`]
//! value so spec deserialization has exactly one code path:
//!
//! * `[table]` and `[nested.table]` headers
//! * `key = value` with string / number / bool / inline `[a, b]` arrays
//! * `#` comments, blank lines
//!
//! Not supported (rejected loudly, never silently misread): multi-line
//! strings, dates, inline tables, arrays-of-tables (`[[x]]`).

use anyhow::{anyhow, bail, Result};

use super::json::Json;

/// Parse TOML text into a [`Json::Obj`] tree.
pub fn parse(text: &str) -> Result<Json> {
    let mut root = std::collections::BTreeMap::new();
    // path of the table currently being filled; empty = root
    let mut current: Vec<String> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| anyhow!("toml line {}: {m}: `{}`", lineno + 1, raw.trim());
        if let Some(head) = line.strip_prefix('[') {
            if head.starts_with('[') {
                return Err(err("arrays of tables ([[..]]) are not supported"));
            }
            let head = head
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated table header"))?;
            current = head
                .split('.')
                .map(|s| {
                    let s = s.trim();
                    if s.is_empty() {
                        Err(err("empty table-name segment"))
                    } else {
                        Ok(s.to_string())
                    }
                })
                .collect::<Result<_>>()?;
            // materialize the table so empty sections still round-trip
            insert_at(&mut root, &current, None, Json::Obj(Default::default()), false)
                .map_err(|e| err(&e.to_string()))?;
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected `key = value`"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let val = value(line[eq + 1..].trim()).map_err(|e| err(&e.to_string()))?;
        insert_at(&mut root, &current, Some(key), val, true)
            .map_err(|e| err(&e.to_string()))?;
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Descend to `path` (creating tables), then insert `key` (or nothing when
/// just materializing a header). `strict` rejects overwriting a key.
fn insert_at(
    root: &mut std::collections::BTreeMap<String, Json>,
    path: &[String],
    key: Option<&str>,
    val: Json,
    strict: bool,
) -> Result<()> {
    let mut cur = root;
    for seg in path {
        let entry = cur
            .entry(seg.clone())
            .or_insert_with(|| Json::Obj(Default::default()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => bail!("`{seg}` is both a value and a table"),
        };
    }
    if let Some(k) = key {
        if strict && cur.contains_key(k) {
            bail!("duplicate key `{k}`");
        }
        cur.insert(k.to_string(), val);
    }
    Ok(())
}

fn value(s: &str) -> Result<Json> {
    if let Some(q) = s.strip_prefix('"') {
        let body = q.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        if body.contains('"') {
            bail!("embedded quotes are not supported");
        }
        return Ok(Json::Str(body.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Json::Arr(Vec::new()));
        }
        return Ok(Json::Arr(
            split_top(inner)?.iter().map(|e| value(e.trim())).collect::<Result<_>>()?,
        ));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    // TOML allows numeric underscores and `inf`
    let cleaned = s.replace('_', "");
    match cleaned.as_str() {
        "inf" | "+inf" => return Ok(Json::Num(f64::INFINITY)),
        _ => {}
    }
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| anyhow!("cannot parse value `{s}`"))
}

/// Split an inline-array body on top-level commas (quotes respected).
fn split_top(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut depth = 0usize;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or_else(|| anyhow!("unbalanced ]"))?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        bail!("unterminated string in array");
    }
    out.push(cur);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_spec_shaped_document() {
        let doc = r#"
# a run spec
config = "resmlp"
epochs = 3.5
seed = 7

[privacy]
epsilon = 3.0
delta = 1e-5

[clip]
group_by = "per-layer"
adaptive = true
thresholds = [0.1, 0.2]
"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("config").unwrap().str().unwrap(), "resmlp");
        assert_eq!(j.get("epochs").unwrap().f64().unwrap(), 3.5);
        assert_eq!(j.get("seed").unwrap().u64().unwrap(), 7);
        let p = j.get("privacy").unwrap();
        assert_eq!(p.get("epsilon").unwrap().f64().unwrap(), 3.0);
        assert_eq!(p.get("delta").unwrap().f64().unwrap(), 1e-5);
        let c = j.get("clip").unwrap();
        assert!(c.get("adaptive").unwrap().bool().unwrap());
        assert_eq!(c.get("thresholds").unwrap().arr().unwrap().len(), 2);
    }

    #[test]
    fn nested_tables_and_comments() {
        let j = parse("[a.b]\nx = 1 # trailing\ns = \"ha#sh\"\n").unwrap();
        let b = j.get("a").unwrap().get("b").unwrap();
        assert_eq!(b.get("x").unwrap().usize().unwrap(), 1);
        assert_eq!(b.get("s").unwrap().str().unwrap(), "ha#sh");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("[unterminated").is_err());
        assert!(parse("[[array.of.tables]]").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"open").is_err());
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn numbers_with_underscores_and_bools() {
        let j = parse("big = 1_000_000\nflag = false\nneg = -2.5e-3").unwrap();
        assert_eq!(j.get("big").unwrap().u64().unwrap(), 1_000_000);
        assert!(!j.get("flag").unwrap().bool().unwrap());
        assert_eq!(j.get("neg").unwrap().f64().unwrap(), -2.5e-3);
    }
}
