//! Dependency-free utilities: JSON, TOML, RNG, CLI flags, micro-bench
//! timing.

pub mod bench;
pub mod cli;
pub mod fsio;
pub mod json;
pub mod rng;
pub mod toml;
