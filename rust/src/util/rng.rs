//! Seeded RNG: splitmix64-seeded xoshiro256++ (Blackman & Vigna), plus
//! Box-Muller gaussians. Deterministic across platforms — every experiment
//! in the repo is reproducible from its seed.

#[derive(Debug, Clone)]
pub struct Xoshiro {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro {
    pub fn seeded(seed: u64) -> Self {
        let mut x = seed;
        Xoshiro { s: [splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x)] }
    }

    /// The full 256-bit generator state. Two generators with equal state
    /// produce identical streams forever — the basis of the
    /// `Rng::stream_pos` parity pins.
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position captured via
    /// [`Xoshiro::state`]. The restored generator continues the stream
    /// bitwise — this is the restore half of the snapshot/resume
    /// contract (`session::snapshot`).
    #[inline]
    pub fn from_state(s: [u64; 4]) -> Self {
        Xoshiro { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // top 53 bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free Lemire-style is overkill here; modulo bias is
        // negligible for n << 2^64
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro::seeded(7);
        let mut b = Xoshiro::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn from_state_continues_stream() {
        let mut a = Xoshiro::seeded(11);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Xoshiro::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro::seeded(1);
        let mut b = Xoshiro::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Xoshiro::seeded(3);
        let n = 100_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Xoshiro::seeded(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }
}
