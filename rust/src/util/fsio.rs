//! Crash-safe filesystem writes.
//!
//! Every on-disk artifact that a later run consumes (bench
//! trajectories, `gwclip exp` tables, session snapshots) goes through
//! [`write_atomic`]: the bytes land in a temp file in the *same
//! directory* as the destination and are published with a single
//! `rename`, so a reader can never observe a truncated file — it sees
//! either the old content or the new content, never a prefix.

use std::path::Path;

use anyhow::{Context, Result};

/// Write `contents` to `path` atomically (temp file + rename).
///
/// The temp file lives next to the destination so the rename stays on
/// one filesystem (cross-device renames are not atomic and fail on
/// most platforms). The temp name is keyed by pid so two concurrent
/// writers of *different* destinations in one directory cannot
/// collide; concurrent writers of the *same* destination last-write
/// wins, which is the same contract as `std::fs::write`.
pub fn write_atomic(path: &Path, contents: &[u8]) -> Result<()> {
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .with_context(|| format!("write_atomic: no file name in {}", path.display()))?;
    let mut tmp_name = std::ffi::OsString::from(format!(".{}.tmp-", std::process::id()));
    tmp_name.push(file_name);
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    std::fs::write(&tmp, contents)
        .with_context(|| format!("write_atomic: writing temp file {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        // best-effort cleanup so a failed publish doesn't litter
        let _ = std::fs::remove_file(&tmp);
        format!("write_atomic: renaming {} -> {}", tmp.display(), path.display())
    })?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("gwclip_fsio_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_replaces() {
        let d = tmpdir("basic");
        let p = d.join("out.json");
        write_atomic(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        write_atomic(&p, b"second, longer than before").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer than before");
        // no temp litter left behind
        let leftovers: Vec<_> = std::fs::read_dir(&d)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_parent_fails_loudly() {
        let d = tmpdir("noparent");
        let p = d.join("nope").join("out.json");
        let err = write_atomic(&p, b"x").unwrap_err();
        assert!(err.to_string().contains("write_atomic"), "{err:#}");
        std::fs::remove_dir_all(&d).ok();
    }
}
