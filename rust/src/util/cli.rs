//! Tiny `--flag value` argument parser (no clap in this environment).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse argv[1..]; `switch_names` take no value (e.g. --paper-scale).
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if switch_names.contains(&name) {
                    a.switches.push(name.to_string());
                    i += 1;
                } else {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    a.flags.insert(name.to_string(), val.clone());
                    i += 2;
                }
            } else {
                a.positional.push(tok.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad number '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_positional_switches() {
        let a = Args::parse(
            &sv(&["train", "--epsilon", "3.0", "--paper-scale", "--seed", "7"]),
            &["paper-scale"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_f64("epsilon", 0.0).unwrap(), 3.0);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert!(a.has("paper-scale"));
        assert_eq!(a.get("missing", "dflt"), "dflt");
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--epsilon"]), &[]).is_err());
    }
}
