//! Micro-bench harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/std/min reporting, plus a machine-readable
//! `BENCH_<name>.json` emitter so the repo accumulates a perf trajectory
//! across commits (every `cargo bench` run overwrites its file; diff them
//! in review).

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms/iter (±{:.4}, min {:.4}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }

    /// A labeled scalar (e.g. a simulated makespan) coerced into the
    /// result shape so it rides along in the same JSON trajectory file.
    pub fn scalar(name: &str, value_s: f64) -> Self {
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_s: value_s,
            std_s: 0.0,
            min_s: value_s,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("iters".into(), Json::Num(self.iters as f64));
        m.insert("mean_s".into(), Json::Num(self.mean_s));
        m.insert("std_s".into(), Json::Num(self.std_s));
        m.insert("min_s".into(), Json::Num(self.min_s));
        Json::Obj(m)
    }
}

/// Resolve where `BENCH_<suite>.json` files land: `$GWCLIP_BENCH_DIR`, or
/// the repository root (one directory above the crate), falling back to
/// the current directory.
pub fn bench_json_path(suite: &str) -> PathBuf {
    let file = format!("BENCH_{suite}.json");
    if let Ok(dir) = std::env::var("GWCLIP_BENCH_DIR") {
        return Path::new(&dir).join(file);
    }
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    if repo_root.is_dir() {
        repo_root.join(file)
    } else {
        PathBuf::from(file)
    }
}

/// Write a suite's results as `BENCH_<suite>.json` at the default
/// location (see [`bench_json_path`]). Returns the path written so the
/// bench can print it.
pub fn write_json(suite: &str, results: &[BenchResult]) -> std::io::Result<PathBuf> {
    write_json_to(bench_json_path(suite), suite, results)
}

/// Write a suite's results to an explicit path (units: seconds).
pub fn write_json_to(
    path: impl AsRef<Path>,
    suite: &str,
    results: &[BenchResult],
) -> std::io::Result<PathBuf> {
    let mut top = std::collections::BTreeMap::new();
    top.insert("suite".to_string(), Json::Str(suite.to_string()));
    top.insert("unit".to_string(), Json::Str("seconds".to_string()));
    top.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    // atomic publish: a crash mid-write must not leave a truncated
    // trajectory that poisons the next `gwclip bench-diff`
    crate::util::fsio::write_atomic(path.as_ref(), Json::Obj(top).render().as_bytes())
        .map_err(std::io::Error::other)?;
    Ok(path.as_ref().to_path_buf())
}

/// True when benches run in CI smoke mode (`GWCLIP_BENCH_SMOKE=1`):
/// minimal iteration counts, and artifact-dependent benches publish an
/// empty trajectory file instead of erroring when the AOT artifacts are
/// absent — so every CI run uploads a full set of `BENCH_*.json`.
pub fn smoke() -> bool {
    std::env::var("GWCLIP_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Iteration-count helper: `full` normally, 1 under smoke mode.
pub fn iters(full: usize) -> usize {
    if smoke() {
        1
    } else {
        full
    }
}

/// Smoke-mode escape hatch for benches that cannot run without the AOT
/// artifacts: under `GWCLIP_BENCH_SMOKE=1` this writes an empty
/// `BENCH_<suite>.json` (so the CI artifact upload stays complete) and
/// returns Ok; otherwise the original error propagates.
pub fn smoke_skip(suite: &str, err: anyhow::Error) -> anyhow::Result<()> {
    if smoke() {
        let path = write_json(suite, &[])?;
        println!(
            "[smoke] {suite}: artifacts unavailable ({err:#}); wrote empty {}",
            path.display()
        );
        Ok(())
    } else {
        Err(err)
    }
}

// ------------------------------------------------------------- trajectory

/// One step-hot-path regression found by [`diff_dirs`].
#[derive(Debug, Clone)]
pub struct BenchRegression {
    pub suite: String,
    pub name: String,
    pub old_mean_s: f64,
    pub new_mean_s: f64,
}

impl BenchRegression {
    pub fn ratio(&self) -> f64 {
        if self.old_mean_s > 0.0 {
            self.new_mean_s / self.old_mean_s
        } else {
            1.0
        }
    }
}

/// Parse one `BENCH_<suite>.json` file into (suite, name -> mean_s).
fn read_suite(path: &Path) -> anyhow::Result<(String, Vec<(String, f64)>)> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text)?;
    let suite = j
        .get("suite")
        .ok()
        .and_then(|s| s.str().ok())
        .unwrap_or_default()
        .to_string();
    let mut rows = Vec::new();
    if let Ok(rs) = j.get("results").and_then(|r| r.arr()) {
        for r in rs {
            if let (Some(name), Some(mean)) = (
                r.get("name").ok().and_then(|v| v.str().ok()),
                r.get("mean_s").ok().and_then(|v| v.f64().ok()),
            ) {
                rows.push((name.to_string(), mean));
            }
        }
    }
    Ok((suite, rows))
}

/// Outcome of [`diff_dirs`]: the regression gate's verdict plus the
/// suites/rows that have no prior trajectory to regress against.
#[derive(Debug, Clone, Default)]
pub struct BenchDiff {
    /// step-hot-path rows compared against a prior mean
    pub compared: usize,
    /// rows whose mean regressed beyond the threshold — these fail CI
    pub regressions: Vec<BenchRegression>,
    /// suites present only in the NEW trajectory (`"suite"`) or rows
    /// present only in the new side of a shared suite (`"suite/row"`) —
    /// a freshly added bench has no history, so these are REPORTED as
    /// additions and never fail the gate
    pub additions: Vec<String>,
    /// per-backend measured collect wall-clock rows (`"collect-wall"`
    /// scalars) in the NEW trajectory as `(suite/name, new_s, old_s)` —
    /// surfaced so the measured-vs-simulated trajectory is visible in CI
    /// logs, informational only, never gated
    pub measured: Vec<(String, f64, Option<f64>)>,
    /// per-phase mean-seconds rows (names containing `"/phase-"`, e.g.
    /// `step/phase-noise`) in the NEW trajectory as `(suite/name, new_s,
    /// old_s)` — like `measured`, informational only: phase splits are
    /// machine-dependent wall-clock, the `/step` totals are the gate
    pub phases: Vec<(String, f64, Option<f64>)>,
    /// per-kernel micro-bench rows (names containing `"/kernel-"`, e.g.
    /// `hotpath/kernel-gauss-fill/avx2`) in the NEW trajectory as
    /// `(suite/name, new_s, old_s)` — informational only: the kernel rows
    /// exist so the scalar-vs-SIMD trajectory is visible per ISA, while
    /// the `/step` totals remain the sole gate
    pub kernels: Vec<(String, f64, Option<f64>)>,
}

/// List the `BENCH_<suite>.json` files in a directory (empty if absent).
fn bench_files(dir: &Path) -> Vec<String> {
    let mut files = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let fname = entry.file_name().to_string_lossy().to_string();
            if fname.starts_with("BENCH_") && fname.ends_with(".json") {
                files.push(fname);
            }
        }
    }
    files.sort();
    files
}

/// Diff the `BENCH_*.json` trajectory between two directories: for every
/// suite present in BOTH, compare the rows whose name marks the step hot
/// path (contains "/step") and report those whose mean regressed by more
/// than `threshold` (e.g. 0.15 = 15%). Suites or rows present only in the
/// NEW trajectory are additions — a bench the prior trajectory has never
/// seen (e.g. a freshly landed backend) is reported, never failed;
/// old-only suites (a retired bench) are skipped entirely.
pub fn diff_dirs(
    old_dir: impl AsRef<Path>,
    new_dir: impl AsRef<Path>,
    threshold: f64,
) -> anyhow::Result<BenchDiff> {
    let (old_dir, new_dir) = (old_dir.as_ref(), new_dir.as_ref());
    let mut diff = BenchDiff::default();
    let old_files = bench_files(old_dir);
    for fname in bench_files(new_dir) {
        if old_files.contains(&fname) {
            continue;
        }
        // suite with no prior trajectory: an addition, not a regression
        let (suite, new_rows) = read_suite(&new_dir.join(&fname))?;
        for (name, mean) in &new_rows {
            if name.contains("collect-wall") {
                diff.measured.push((format!("{suite}/{name}"), *mean, None));
            }
            if name.contains("/phase-") {
                diff.phases.push((format!("{suite}/{name}"), *mean, None));
            }
            if name.contains("/kernel-") {
                diff.kernels.push((format!("{suite}/{name}"), *mean, None));
            }
        }
        diff.additions.push(if suite.is_empty() {
            fname.clone()
        } else {
            suite
        });
    }
    for fname in &old_files {
        let new_path = new_dir.join(fname);
        if !new_path.is_file() {
            continue; // retired bench: nothing to gate
        }
        let (suite, old_rows) = read_suite(&old_dir.join(fname))?;
        let (_, new_rows) = read_suite(&new_path)?;
        for (name, new_mean) in &new_rows {
            // new step-path rows inside a known suite are additions too
            // (phase splits are carved out: they ride under /step names
            // but report through `phases`, not the gate)
            if name.contains("/step")
                && !name.contains("/phase-")
                && !old_rows.iter().any(|(n, _)| n == name)
            {
                diff.additions.push(format!("{suite}/{name}"));
            }
            if name.contains("collect-wall") {
                let prior = old_rows.iter().find(|(n, _)| n == name).map(|(_, m)| *m);
                diff.measured.push((format!("{suite}/{name}"), *new_mean, prior));
            }
            if name.contains("/phase-") {
                let prior = old_rows.iter().find(|(n, _)| n == name).map(|(_, m)| *m);
                diff.phases.push((format!("{suite}/{name}"), *new_mean, prior));
            }
            if name.contains("/kernel-") {
                let prior = old_rows.iter().find(|(n, _)| n == name).map(|(_, m)| *m);
                diff.kernels.push((format!("{suite}/{name}"), *new_mean, prior));
            }
            let Some((_, old_mean)) = old_rows.iter().find(|(n, _)| n == name) else {
                continue;
            };
            if !name.contains("/step") || name.contains("/phase-") || *old_mean <= 0.0 {
                continue;
            }
            diff.compared += 1;
            if *new_mean > old_mean * (1.0 + threshold) {
                diff.regressions.push(BenchRegression {
                    suite: suite.clone(),
                    name: name.clone(),
                    old_mean_s: *old_mean,
                    new_mean_s: *new_mean,
                });
            }
        }
    }
    Ok(diff)
}

/// Run `f` for `warmup` + `iters` iterations and time each.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult { name: name.to_string(), iters, mean_s: mean, std_s: var.sqrt(), min_s: min }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_output_parses_back() {
        let dir = std::env::temp_dir().join(format!("gw_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rows = vec![
            BenchResult { name: "a/b".into(), iters: 4, mean_s: 0.5, std_s: 0.1, min_s: 0.4 },
            BenchResult::scalar("sim/overlap", 0.25),
        ];
        // explicit path: no process-global env mutation in tests
        let path = write_json_to(dir.join("BENCH_testsuite.json"), "testsuite", &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("suite").unwrap().str().unwrap(), "testsuite");
        assert_eq!(j.get("unit").unwrap().str().unwrap(), "seconds");
        let rs = j.get("results").unwrap().arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().str().unwrap(), "a/b");
        assert_eq!(rs[0].get("mean_s").unwrap().f64().unwrap(), 0.5);
        assert_eq!(rs[1].get("iters").unwrap().usize().unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn diff_reports_new_suites_as_additions_not_regressions() {
        let base = std::env::temp_dir().join(format!("gw_benchdiff_{}", std::process::id()));
        let (old, new) = (base.join("old"), base.join("new"));
        std::fs::create_dir_all(&old).unwrap();
        std::fs::create_dir_all(&new).unwrap();
        let shared_old = vec![
            BenchResult { name: "x/step".into(), iters: 3, mean_s: 1.0, std_s: 0.0, min_s: 1.0 },
            BenchResult::scalar("x/collect-wall", 0.2),
        ];
        let shared_new = vec![
            // 3x regression on the known row...
            BenchResult { name: "x/step".into(), iters: 3, mean_s: 3.0, std_s: 0.0, min_s: 3.0 },
            // ...plus a step row the trajectory has never seen
            BenchResult { name: "y/step".into(), iters: 3, mean_s: 9.0, std_s: 0.0, min_s: 9.0 },
            // measured wall-clock rows are surfaced, never gated
            BenchResult::scalar("x/collect-wall", 0.9),
            // per-phase splits likewise surface without gating, even
            // when wildly slower than any prior
            BenchResult::scalar("x/step/phase-noise", 0.8),
            // per-kernel rows surface the scalar-vs-SIMD trajectory,
            // informational like the phase rows
            BenchResult { name: "x/kernel-sq-norm/avx2".into(), iters: 3, mean_s: 0.7, std_s: 0.0, min_s: 0.7 },
        ];
        write_json_to(old.join("BENCH_shared.json"), "shared", &shared_old).unwrap();
        write_json_to(new.join("BENCH_shared.json"), "shared", &shared_new).unwrap();
        // a whole suite present only on the new side (the fresh-bench case)
        write_json_to(new.join("BENCH_federated.json"), "federated", &shared_new).unwrap();
        // and one retired on the old side: skipped entirely
        write_json_to(old.join("BENCH_retired.json"), "retired", &shared_old).unwrap();

        let d = diff_dirs(&old, &new, 0.15).unwrap();
        assert_eq!(d.compared, 1, "only the shared row is gated");
        assert_eq!(d.regressions.len(), 1);
        assert_eq!(d.regressions[0].name, "x/step");
        assert!(d.additions.contains(&"federated".to_string()), "{:?}", d.additions);
        assert!(d.additions.contains(&"shared/y/step".to_string()), "{:?}", d.additions);
        assert!(!d.additions.iter().any(|a| a.contains("retired")), "{:?}", d.additions);
        // the 4.5x-slower collect-wall row is surfaced with its prior but
        // never counted as a regression — measured wall-clock is
        // informational
        assert!(
            d.measured.contains(&("shared/x/collect-wall".to_string(), 0.9, Some(0.2))),
            "{:?}",
            d.measured
        );
        assert!(
            d.measured.contains(&("federated/x/collect-wall".to_string(), 0.9, None)),
            "{:?}",
            d.measured
        );
        // phase rows: no prior in the shared suite (fresh), and never a
        // regression even at 0.8 s vs nothing
        assert!(
            d.phases.contains(&("shared/x/step/phase-noise".to_string(), 0.8, None)),
            "{:?}",
            d.phases
        );
        assert!(
            d.phases.contains(&("federated/x/step/phase-noise".to_string(), 0.8, None)),
            "{:?}",
            d.phases
        );
        assert_eq!(d.regressions.len(), 1, "phase rows must not gate");
        assert!(
            !d.additions.iter().any(|a| a.contains("/phase-")),
            "phase rows are not step-gate additions: {:?}",
            d.additions
        );
        // kernel rows: surfaced per ISA with no prior, never gated, never
        // counted as step-gate additions
        assert!(
            d.kernels.contains(&("shared/x/kernel-sq-norm/avx2".to_string(), 0.7, None)),
            "{:?}",
            d.kernels
        );
        assert!(
            d.kernels.contains(&("federated/x/kernel-sq-norm/avx2".to_string(), 0.7, None)),
            "{:?}",
            d.kernels
        );
        assert!(
            !d.additions.iter().any(|a| a.contains("/kernel-")),
            "kernel rows are not step-gate additions: {:?}",
            d.additions
        );
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn bench_times_something() {
        let r = super::bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_s > 0.0 && r.min_s <= r.mean_s);
        assert!(r.report().contains("spin"));
    }
}
