//! Micro-bench harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/std/min reporting.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms/iter (±{:.4}, min {:.4}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }
}

/// Run `f` for `warmup` + `iters` iterations and time each.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult { name: name.to_string(), iters, mean_s: mean, std_s: var.sqrt(), min_s: min }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_times_something() {
        let r = super::bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_s > 0.0 && r.min_s <= r.mean_s);
        assert!(r.report().contains("spin"));
    }
}
