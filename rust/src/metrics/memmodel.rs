//! Analytic training-memory model for Figure 1's memory panel.
//!
//! We cannot meter device memory through the PJRT CPU client the way the
//! paper meters CUDA, so the memory side of Figure 1 is reproduced from a
//! byte-accounting model of what each clipping scheme must materialize.
//! The wall-time panel IS measured (criterion bench + `gwclip fig1`).
//!
//! Buffers counted, per scheme, for a transformer step at batch B, seq T,
//! width D, layers L, params P (all f32):
//!   base (non-private): params + grads + optimizer state + activations
//!   naive flat (Opacus): base + B per-example gradient copies  (B * P)
//!   ghost (Li et al.):   base + per-example norms (the second backward
//!                        reuses activation storage)
//!   per-layer fused:     base + per-example norms  [B * K]
//!   flat w/ ghost norms: base + retained (a, delta) pairs ~= 2x activations

#[derive(Debug, Clone, Copy)]
pub struct WorkloadDims {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub n_params: u64,
    pub n_groups: usize,
}

/// Activation floats that standard backprop stores per example: the per-
/// layer inputs of each matmul plus attention probabilities.
fn activation_floats(w: &WorkloadDims) -> u64 {
    let per_layer =
        // ln1 out, qkv out (3D), attn probs (T heads folded into T), attn out,
        // ln2 out, mlp hidden, mlp out
        (w.seq * (3 * w.d_model + 3 * w.d_model + w.seq + w.d_ff)) as u64;
    (w.batch as u64) * ((w.n_layers as u64) * per_layer + (w.seq * w.d_model) as u64)
        + (w.batch * w.seq * w.vocab) as u64 // logits
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    NonPrivate,
    NaiveFlat,
    Ghost,
    FlatGhostNorms,
    PerLayerFused,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::NonPrivate => "non-private",
            Scheme::NaiveFlat => "flat (materialized, Opacus-style)",
            Scheme::Ghost => "ghost (Li et al. 2022b)",
            Scheme::FlatGhostNorms => "flat (ghost norms, cached deltas)",
            Scheme::PerLayerFused => "per-layer fused (ours)",
        }
    }

    /// Peak training memory in bytes under the model above.
    pub fn peak_bytes(&self, w: &WorkloadDims) -> u64 {
        let p = w.n_params;
        let acts = activation_floats(w);
        let base = 4 * (p /*params*/ + p /*grads*/ + 2 * p /*adam*/ + acts);
        let extra = match self {
            Scheme::NonPrivate => 0,
            Scheme::NaiveFlat => 4 * (w.batch as u64) * p,
            Scheme::Ghost => 4 * (w.batch as u64),
            // deltas mirror activations until the global norm is known
            Scheme::FlatGhostNorms => 4 * acts,
            Scheme::PerLayerFused => 4 * (w.batch as u64) * (w.n_groups as u64),
        };
        base + extra
    }

    /// Extra backward passes this scheme performs.
    pub fn n_backwards(&self) -> u32 {
        match self {
            Scheme::Ghost => 2,
            _ => 1,
        }
    }
}

pub const ALL_SCHEMES: [Scheme; 5] = [
    Scheme::NonPrivate,
    Scheme::NaiveFlat,
    Scheme::Ghost,
    Scheme::FlatGhostNorms,
    Scheme::PerLayerFused,
];

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> WorkloadDims {
        WorkloadDims {
            batch: 32,
            seq: 128,
            d_model: 768,
            d_ff: 3072,
            n_layers: 12,
            vocab: 50257,
            n_params: 124_000_000,
            n_groups: 50,
        }
    }

    #[test]
    fn paper_ordering_holds() {
        // Figure 1: naive >> flat-cached > ghost ~= per-layer ~= non-private
        let w = dims();
        let naive = Scheme::NaiveFlat.peak_bytes(&w);
        let cached = Scheme::FlatGhostNorms.peak_bytes(&w);
        let ghost = Scheme::Ghost.peak_bytes(&w);
        let fused = Scheme::PerLayerFused.peak_bytes(&w);
        let base = Scheme::NonPrivate.peak_bytes(&w);
        assert!(naive > 4 * base, "naive {naive} vs base {base}");
        assert!(cached > base && cached < naive);
        assert!(ghost < cached);
        assert!(fused < cached);
        // the headline: fused per-layer within 1% of non-private memory
        assert!((fused as f64 - base as f64) / (base as f64) < 0.01);
        assert!((ghost as f64 - base as f64) / (base as f64) < 0.01);
    }

    #[test]
    fn naive_scales_with_batch() {
        let mut w = dims();
        let a = Scheme::NaiveFlat.peak_bytes(&w);
        w.batch *= 2;
        let b = Scheme::NaiveFlat.peak_bytes(&w);
        assert!(b as f64 > 1.8 * a as f64);
    }

    #[test]
    fn ghost_costs_double_backward() {
        assert_eq!(Scheme::Ghost.n_backwards(), 2);
        assert_eq!(Scheme::PerLayerFused.n_backwards(), 1);
    }
}
