//! Corpus BLEU-4 over token-id sequences (for the Table 5/6 generation
//! analogs). Standard Papineni et al. definition with brevity penalty and
//! flat n-gram weights.

use std::collections::HashMap;

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_default() += 1;
        }
    }
    m
}

/// Corpus BLEU with max n-gram order `max_n` (use 4 for BLEU-4).
pub fn corpus_bleu(hyps: &[Vec<i32>], refs: &[Vec<i32>], max_n: usize) -> f64 {
    assert_eq!(hyps.len(), refs.len());
    assert!(max_n >= 1);
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    let mut matches = vec![0usize; max_n];
    let mut totals = vec![0usize; max_n];
    for (h, r) in hyps.iter().zip(refs) {
        hyp_len += h.len();
        ref_len += r.len();
        for n in 1..=max_n {
            let hc = ngram_counts(h, n);
            let rc = ngram_counts(r, n);
            for (g, c) in &hc {
                totals[n - 1] += c;
                if let Some(rcount) = rc.get(g) {
                    matches[n - 1] += (*c).min(*rcount);
                }
            }
        }
    }
    let mut logp = 0.0;
    for n in 0..max_n {
        if totals[n] == 0 || matches[n] == 0 {
            return 0.0;
        }
        logp += (matches[n] as f64 / totals[n] as f64).ln();
    }
    logp /= max_n as f64;
    let bp = if hyp_len >= ref_len || hyp_len == 0 {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    bp * logp.exp()
}

/// ROUGE-L F1 (longest common subsequence) over token ids, averaged over
/// the corpus — the Table 5/6 companion metric.
pub fn rouge_l(hyps: &[Vec<i32>], refs: &[Vec<i32>]) -> f64 {
    assert_eq!(hyps.len(), refs.len());
    let mut total = 0.0;
    for (h, r) in hyps.iter().zip(refs) {
        if h.is_empty() || r.is_empty() {
            continue;
        }
        let l = lcs(h, r) as f64;
        let p = l / h.len() as f64;
        let rec = l / r.len() as f64;
        if p + rec > 0.0 {
            total += 2.0 * p * rec / (p + rec);
        }
    }
    total / hyps.len().max(1) as f64
}

fn lcs(a: &[i32], b: &[i32]) -> usize {
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_one() {
        let s = vec![vec![1, 2, 3, 4, 5, 6]];
        assert!((corpus_bleu(&s, &s, 4) - 1.0).abs() < 1e-12);
        assert!((rouge_l(&s, &s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_is_zero() {
        let h = vec![vec![1, 2, 3, 4]];
        let r = vec![vec![5, 6, 7, 8]];
        assert_eq!(corpus_bleu(&h, &r, 4), 0.0);
        assert_eq!(rouge_l(&h, &r), 0.0);
    }

    #[test]
    fn partial_overlap_between_zero_and_one() {
        let h = vec![vec![1, 2, 3, 4, 9, 9]];
        let r = vec![vec![1, 2, 3, 4, 5, 6]];
        let b = corpus_bleu(&h, &r, 4);
        assert!(b > 0.0 && b < 1.0, "bleu {b}");
        let rl = rouge_l(&h, &r);
        assert!(rl > 0.5 && rl < 1.0, "rouge {rl}");
    }

    #[test]
    fn brevity_penalty_punishes_short_hyps() {
        let r = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let full = corpus_bleu(&r.clone(), &r, 2);
        let short = corpus_bleu(&[r[0][..4].to_vec()].to_vec(), &r, 2);
        assert!(short < full);
    }

    #[test]
    fn lcs_basic() {
        assert_eq!(lcs(&[1, 3, 5, 7], &[1, 5, 7, 9]), 3);
        assert_eq!(lcs(&[], &[1]), 0);
    }
}
